"""Artifact-consistency tests: if `make artifacts` has run, the emitted
JSON/HLO must be mutually consistent (these are the files the Rust side
trusts)."""

import json
import pathlib

import numpy as np
import pytest

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(), reason="run `make artifacts` first"
)


def _manifest():
    return json.load(open(ART / "manifest.json"))


def test_manifest_covers_all_30_configs():
    m = _manifest()
    assert len(m["configs"]) == 30
    for ds in ["bs", "derm", "iris", "seeds", "v3"]:
        for strat in ["ovr", "ovo"]:
            for bits in [4, 8, 16]:
                assert f"{ds}_{strat}_w{bits}" in m["configs"]


def test_all_referenced_files_exist():
    m = _manifest()
    for cfg in m["configs"].values():
        assert (ART / cfg["weights"]).exists()
        assert (ART / cfg["golden"]).exists()
        for rel in cfg["hlo"].values():
            assert (ART / rel).exists()
    for d in m["datasets"].values():
        assert (ART / d["file"]).exists()


def test_golden_consistent_with_weights():
    """Recompute golden scores from the weights JSON: the two files must
    encode the same model."""
    m = _manifest()
    for key in ["iris_ovr_w4", "derm_ovo_w16", "bs_ovr_w8"]:
        cfg = m["configs"][key]
        w = json.load(open(ART / cfg["weights"]))
        g = json.load(open(ART / cfg["golden"]))
        W = np.array(w["weights"], np.int64)
        b = np.array(w["biases"], np.int64)
        x = np.array(g["x_q"], np.int64)
        scores = x @ W.T + 15 * b
        np.testing.assert_array_equal(scores, np.array(g["scores"], np.int64))


def test_hlo_artifacts_have_full_constants():
    """Regression test for the xla_extension-0.5.1 elided-literal trap."""
    for p in (ART / "hlo").glob("*.hlo.txt"):
        text = p.read_text()
        assert "constant({...})" not in text, p.name
        assert "{ ... }" not in text, p.name


def test_metrics_match_manifest_accuracy():
    m = _manifest()
    metrics = json.load(open(ART / "metrics.json"))
    for key, cfg in m["configs"].items():
        assert abs(metrics[key]["accuracy"] - cfg["accuracy"]) < 1e-12


def test_weight_ranges_fit_declared_bits():
    m = _manifest()
    for key, cfg in m["configs"].items():
        w = json.load(open(ART / cfg["weights"]))
        qmax = (1 << (w["bits"] - 1)) - 1
        assert np.abs(np.array(w["weights"])).max() <= qmax, key
        assert np.abs(np.array(w["biases"])).max() <= qmax, key


def test_datasets_quantized_inputs_in_range():
    m = _manifest()
    for d in m["datasets"].values():
        data = json.load(open(ART / d["file"]))
        x = np.array(data["x_q_test"])
        assert x.min() >= 0 and x.max() <= 15
        assert len(data["y_test"]) == data["n_test"]
