"""From-scratch LinearSVC-equivalent training tests."""

import numpy as np
import pytest

from compile import datasets as D
from compile import train as T


def _blobs(n=60, f=3, margin=2.0, seed=0):
    rng = np.random.default_rng(seed)
    x0 = rng.normal(-margin / 2, 0.4, size=(n // 2, f))
    x1 = rng.normal(margin / 2, 0.4, size=(n // 2, f))
    x = np.vstack([x0, x1])
    y = np.array([-1.0] * (n // 2) + [1.0] * (n // 2))
    return x, y


def test_binary_separable_converges():
    x, y = _blobs()
    w, b = T.train_binary(x, y, steps=1500)
    pred = np.sign(x @ w + b)
    assert np.mean(pred == y) == 1.0


def test_binary_margin_property():
    """On separable data the squared-hinge solution leaves most points
    outside the margin (|f(x)| >= 1)."""
    x, y = _blobs(margin=4.0)
    w, b = T.train_binary(x, y, steps=3000)
    margins = y * (x @ w + b)
    assert np.mean(margins >= 0.99) > 0.9


def test_ovr_model_shape():
    ds = D.load("iris")
    m = T.train_ovr(ds.x_train, ds.y_train, 3, steps=500)
    assert m.weights.shape == (3, 4)
    assert m.biases.shape == (3,)
    assert m.pairs == [(0, 0), (1, 1), (2, 2)]
    assert m.strategy == "ovr"


def test_ovo_model_shape():
    ds = D.load("derm")
    m = T.train_ovo(ds.x_train, ds.y_train, 6, steps=200)
    assert m.weights.shape == (15, 34)  # C(6,2)
    assert len(m.pairs) == 15
    assert m.pairs[0] == (0, 1)
    assert m.pairs[-1] == (4, 5)
    assert all(i < j for i, j in m.pairs)


@pytest.mark.parametrize("name,floor", [("iris", 0.9), ("derm", 0.95), ("seeds", 0.85)])
def test_reasonable_accuracy(name, floor):
    ds = D.load(name)
    m = T.train_ovr(ds.x_train, ds.y_train, ds.n_classes)
    acc = T.accuracy(T.predict_float(m, ds.x_test), ds.y_test)
    assert acc >= floor, f"{name}: {acc}"


def test_ovo_votes_tie_break_first_max():
    # two classes with one classifier: degenerate but well-defined
    m = T.SvmModel("ovo", 2, np.array([[0.0]]), np.array([0.0]), [(0, 1)])
    pred = T.predict_float(m, np.array([[1.0]]))
    # score 0 counts as >= 0 -> vote class 0
    assert pred[0] == 0


def test_training_is_deterministic():
    x, y = _blobs(seed=3)
    w1, b1 = T.train_binary(x, y, steps=500)
    w2, b2 = T.train_binary(x, y, steps=500)
    assert np.allclose(w1, w2)
    assert b1 == b2
