"""Dataset substrate tests: shapes, normalisation, determinism, and the
exactness of the Balance Scale generation."""

import numpy as np
import pytest

from compile import datasets as D


@pytest.mark.parametrize("name", D.DATASET_NAMES)
def test_shapes_and_split(name):
    ds = D.load(name)
    expect = {
        "bs": (625, 4, 3),
        "derm": (366, 34, 6),
        "iris": (150, 4, 3),
        "seeds": (210, 7, 3),
        "v3": (310, 6, 3),
    }[name]
    n, f, c = expect
    assert ds.n_train + ds.n_test == n
    assert ds.n_features == f
    assert ds.n_classes == c
    # 80/20 split
    assert abs(ds.n_train - round(0.8 * n)) <= 1
    assert ds.x_train.shape == (ds.n_train, f)
    assert ds.y_test.shape == (ds.n_test,)


@pytest.mark.parametrize("name", D.DATASET_NAMES)
def test_normalised_to_unit_interval(name):
    ds = D.load(name)
    for x in (ds.x_train, ds.x_test):
        assert x.min() >= 0.0
        assert x.max() <= 1.0
    # train set spans the full range per feature (min-max normalisation)
    assert np.allclose(ds.x_train.min(axis=0), 0.0)
    assert np.allclose(ds.x_train.max(axis=0), 1.0)


@pytest.mark.parametrize("name", D.DATASET_NAMES)
def test_all_classes_present_in_both_splits(name):
    ds = D.load(name)
    assert set(np.unique(ds.y_train)) == set(range(ds.n_classes))
    assert set(np.unique(ds.y_test)) <= set(range(ds.n_classes))


def test_deterministic_generation():
    a = D.load("iris")
    b = D.load("iris")
    assert np.array_equal(a.x_train, b.x_train)
    assert np.array_equal(a.y_test, b.y_test)


def test_balance_scale_is_exact():
    """BS is not synthetic-approximate: it IS the UCI dataset (the UCI
    file itself is generated from the torque rule)."""
    ds = D.balance_scale()
    n = ds.n_train + ds.n_test
    assert n == 625
    # class distribution of the real dataset: L=288, B=49, R=288
    y = np.concatenate([ds.y_train, ds.y_test])
    counts = np.bincount(y, minlength=3)
    assert counts[0] == 288
    assert counts[1] == 49
    assert counts[2] == 288


def test_balance_scale_rule_holds():
    """Reconstruct the torque rule from the normalised features."""
    ds = D.balance_scale()
    # denormalise: features were 1..5 min-max mapped to [0,1]
    x = ds.x_train * 4 + 1
    lw, ldist, rw, rdist = x[:, 0], x[:, 1], x[:, 2], x[:, 3]
    left, right = lw * ldist, rw * rdist
    expect = np.where(left > right, 0, np.where(left == right, 1, 2))
    assert np.array_equal(expect.astype(np.int32), ds.y_train)


def test_unknown_dataset_raises():
    with pytest.raises(KeyError):
        D.load("nope")


def test_derm_ordinal_grid():
    """The 33 clinical attributes of the derm generator live on the
    real dataset's 0..3 ordinal grid (before normalisation)."""
    ds = D.dermatology_like()
    # after min-max normalisation an ordinal grid has ≤ 4 distinct values
    for j in range(ds.n_features - 1):
        distinct = np.unique(np.concatenate([ds.x_train[:, j], ds.x_test[:, j]]))
        assert len(distinct) <= 4, f"feature {j} has {len(distinct)} levels"
