"""Layer-1 Pallas PE kernel vs the pure-jnp oracle (ref.py), including
hypothesis sweeps over shapes, precisions and values — the CORE
correctness signal of the compile path."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, svm_pe


def _rand(rng, b, k, f, bits):
    qmax = (1 << (bits - 1)) - 1
    x = rng.integers(0, 16, size=(b, f)).astype(np.int32)
    w = rng.integers(-qmax, qmax + 1, size=(k, f)).astype(np.int32)
    bias = rng.integers(-qmax, qmax + 1, size=(k,)).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias)


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_pe_scores_match_ref(bits):
    rng = np.random.default_rng(bits)
    x, w, b = _rand(rng, 37, 5, 11, bits)
    got = svm_pe.pe_scores(x, w, b, bits=bits)
    want = ref.scores_ref(x, w, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_fused_argmax_matches_ref(bits):
    rng = np.random.default_rng(100 + bits)
    x, w, b = _rand(rng, 50, 7, 6, bits)
    scores, ids = svm_pe.pe_scores_argmax(x, w, b, bits=bits)
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(ref.scores_ref(x, w, b)))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref.ovr_predict_ref(x, w, b)))


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 130),
    k=st.integers(1, 16),
    f=st.integers(1, 35),
    bits=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pe_scores_hypothesis_sweep(b, k, f, bits, seed):
    """Shape/precision sweep: any (batch, classifiers, features) combo —
    including batches that don't divide the block size — must be
    bit-exact against the oracle."""
    rng = np.random.default_rng(seed)
    x, w, bias = _rand(rng, b, k, f, bits)
    got = svm_pe.pe_scores(x, w, bias, bits=bits, block_b=32)
    want = ref.scores_ref(x, w, bias)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 70),
    k=st.integers(2, 10),
    bits=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_argmax_first_max_semantics(b, k, bits, seed):
    """Ties must resolve to the FIRST maximum (hardware strict-greater
    update) — force ties by duplicating classifier rows."""
    rng = np.random.default_rng(seed)
    x, w, bias = _rand(rng, b, k, 4, bits)
    # duplicate classifier 0 at the end: a guaranteed tie candidate
    w = jnp.concatenate([w, w[:1]], axis=0)
    bias = jnp.concatenate([bias, bias[:1]])
    _, ids = svm_pe.pe_scores_argmax(x, w, bias, bits=bits)
    want = np.argmax(np.asarray(ref.scores_ref(x, w, bias)), axis=1)
    np.testing.assert_array_equal(np.asarray(ids), want)


def test_extreme_values_no_overflow():
    """Worst case: F=35 features at 15 with 16-bit full-scale weights
    stays far inside int32 (the accumulator width argument, DESIGN §8)."""
    f = 35
    x = jnp.full((4, f), 15, jnp.int32)
    w = jnp.full((3, f), 32767, jnp.int32)
    b = jnp.full((3,), 32767, jnp.int32)
    got = np.asarray(svm_pe.pe_scores(x, w, b, bits=16))
    expect = f * 15 * 32767 + 15 * 32767
    assert (got == expect).all()
    assert expect < 2**31 - 1


def test_negative_weight_sign_magnitude_path():
    """Directed case for the sign-magnitude module: w = -1 has magnitude
    nibbles (1, 0, 0, 0) and must subtract."""
    x = jnp.asarray([[7]], jnp.int32)
    w = jnp.asarray([[-1]], jnp.int32)
    b = jnp.asarray([0], jnp.int32)
    for bits in (4, 8, 16):
        got = np.asarray(svm_pe.pe_scores(x, w, b, bits=bits))
        assert got[0, 0] == -7, f"bits={bits}"


def test_ovo_votes_ref_tally():
    scores = jnp.asarray([[5, -3, 0]], jnp.int32)  # pairs (0,1),(0,2),(1,2)
    pi = jnp.asarray([0, 0, 1], jnp.int32)
    pj = jnp.asarray([1, 2, 2], jnp.int32)
    votes = np.asarray(ref.ovo_votes_ref(scores, pi, pj, 3))
    # +5 -> vote 0; -3 -> vote 2; 0 (>=0) -> vote 1
    np.testing.assert_array_equal(votes, [[1, 1, 1]])


def test_vmem_estimate_is_tiny():
    """The paper-scale worst case (derm OvO 16-bit) uses a few hundred
    KiB of VMEM per block — far under a 16 MiB budget (DESIGN.md §9)."""
    est = svm_pe.vmem_estimate_bytes(svm_pe.DEFAULT_BLOCK_B, 35, 15)
    assert est < 1 << 20
