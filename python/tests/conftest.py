import pathlib
import sys

# make `compile` importable when pytest runs from the repo root
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
