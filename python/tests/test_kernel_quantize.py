"""Kernel-machine spec tests (ISSUE 8): LUT formula pinning, integer
feature-map properties, constant validation, the fit pipeline, and the
three-way differential numpy spec == jnp oracle == Pallas kernel PE."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import datasets as D
from compile import quantize as Q
from compile.kernels import kernel_pe as KP
from compile.kernels import ref


def test_exp2_lut_pins_formula():
    """The hardcoded table IS round(KSCALE * 2^(-i/32)) — the same table
    is hardcoded in rust/src/kernel/mod.rs; this test is the tripwire
    for editing one side only."""
    want = np.round(Q.KSCALE * 2.0 ** (-np.arange(32) / 32.0)).astype(np.int64)
    np.testing.assert_array_equal(np.asarray(Q.EXP2_LUT), want)
    np.testing.assert_array_equal(np.asarray(ref.EXP2_LUT), want)
    assert (Q.KFRAC, Q.GSHIFT, Q.LUTB, Q.KCLAMP) == (
        ref.KFRAC, ref.GSHIFT, ref.LUTB, ref.KCLAMP
    )


def test_rbf_phi_range_and_identity():
    """phi is in [0, KSCALE]; identical points score full scale."""
    rng = np.random.default_rng(0)
    sv = rng.integers(0, 16, size=(8, 6)).astype(np.int32)
    consts = Q.quantize_kernel_constants("rbf", 6, gamma=2.0 / 6)
    phi = Q.rbf_phi_int(sv, sv, consts["g2_q"])
    assert phi.min() >= 0 and phi.max() <= Q.KSCALE
    np.testing.assert_array_equal(np.diag(phi), Q.KSCALE)


def test_rbf_phi_monotone_in_distance():
    """Farther points never score higher (2^-x is monotone, and the
    LUT+shift construction must preserve that)."""
    sv = np.zeros((1, 4), np.int32)
    consts = Q.quantize_kernel_constants("rbf", 4, gamma=0.5)
    xs = np.stack([np.full(4, v, np.int32) for v in range(16)])
    phi = Q.rbf_phi_int(xs, sv, consts["g2_q"])[:, 0]
    assert (np.diff(phi) <= 0).all()
    assert phi[0] == Q.KSCALE


def test_poly_phi_clamp_and_degree_one():
    consts = Q.quantize_kernel_constants("poly", 3, gamma=1.0 / 3, degree=1)
    x = np.array([[15, 15, 15]], np.int32)
    sv = np.array([[15, 15, 15]], np.int32)
    phi = Q.poly_phi_int(x, sv, consts["gamma_q"], consts["coef0_q"], 1)
    assert abs(int(phi[0, 0])) <= Q.KCLAMP
    # degree 1 is just the clamped affine map
    d = int(x.astype(np.int64) @ sv.astype(np.int64).T)
    want = np.clip(
        (consts["gamma_q"] * d >> Q.GSHIFT) + consts["coef0_q"],
        -Q.KCLAMP, Q.KCLAMP,
    )
    assert int(phi[0, 0]) == int(want)


def test_kernel_constants_validation():
    with pytest.raises(ValueError):
        Q.quantize_kernel_constants("rbf", 4, gamma=-1.0)
    with pytest.raises(ValueError):
        Q.quantize_kernel_constants("rbf", 4, gamma=1e-9)  # quantizes to 0
    with pytest.raises(ValueError):
        Q.quantize_kernel_constants("poly", 4, gamma=0.25, degree=0)
    with pytest.raises(ValueError):
        Q.quantize_kernel_constants("sigmoid", 4, gamma=0.25)
    with pytest.raises(ValueError):
        Q.validate_kernel_accumulator(16, 1 << 20)
    Q.validate_kernel_accumulator(16, 64)  # the default S is safe at 16-bit


def test_select_support_stratified_deterministic():
    rng = np.random.default_rng(7)
    x = rng.integers(0, 16, size=(40, 5)).astype(np.int32)
    y = np.array([0] * 30 + [1] * 8 + [2] * 2)
    sv_a = Q.select_support(x, y, 12, seed=3)
    sv_b = Q.select_support(x, y, 12, seed=3)
    np.testing.assert_array_equal(sv_a, sv_b)
    assert sv_a.shape == (12, 5)
    # the 2-sample class must still be represented
    assert any((sv_a == x[i]).all(1).any() for i in (38, 39))


@pytest.mark.parametrize("kernel", ["rbf", "poly"])
@pytest.mark.parametrize("strategy", ["ovr", "ovo"])
def test_fit_kernel_machine_iris(kernel, strategy):
    """End-to-end fit: a kernel machine on iris must beat guessing by a
    wide margin and carry a well-formed quantized spec."""
    ds = D.load("iris")
    x_q = Q.quantize_inputs(ds.x_train)
    qm = Q.fit_kernel_machine(
        kernel, x_q, ds.y_train, 3, strategy, 8, steps=1500
    )
    assert qm.kernel == kernel
    assert qm.support is not None and qm.support.shape[1] == 4
    assert qm.weights.shape == (qm.n_classifiers, qm.n_support)
    assert qm.support.min() >= 0 and qm.support.max() <= 15
    x_q_test = Q.quantize_inputs(ds.x_test)
    from compile import train as T

    acc = T.accuracy(Q.predict_int(qm, x_q_test), ds.y_test)
    assert acc > 0.8, f"{kernel}/{strategy}: acc={acc}"


def _rand_kernel_setup(rng, b, s, k, f, bits, kind):
    qmax = (1 << (bits - 1)) - 1
    x = rng.integers(0, 16, size=(b, f)).astype(np.int32)
    sv = rng.integers(0, 16, size=(s, f)).astype(np.int32)
    w = rng.integers(-qmax, qmax + 1, size=(k, s)).astype(np.int32)
    bias = rng.integers(-qmax, qmax + 1, size=(k,)).astype(np.int32)
    if kind == "rbf":
        consts = {"g2_q": int(rng.integers(1, 5000)), "gamma_q": 0,
                  "coef0_q": 0, "degree": 0}
    else:
        consts = {
            "g2_q": 0,
            "gamma_q": int(rng.integers(1, 5000)),
            "coef0_q": int(rng.integers(-Q.KCLAMP, Q.KCLAMP + 1)),
            "degree": int(rng.integers(1, 5)),
        }
    return x, sv, w, bias, consts


def _spec_scores(x, sv, w, bias, kind, consts):
    if kind == "rbf":
        phi = Q.rbf_phi_int(x, sv, consts["g2_q"])
    else:
        phi = Q.poly_phi_int(
            x, sv, consts["gamma_q"], consts["coef0_q"], consts["degree"]
        )
    return phi @ w.T.astype(np.int64) + Q.KSCALE * bias.astype(np.int64)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 90),
    s=st.integers(1, 24),
    k=st.integers(1, 8),
    f=st.integers(1, 20),
    bits=st.sampled_from([4, 8, 16]),
    kind=st.sampled_from(["rbf", "poly"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_pe_matches_spec_hypothesis(b, s, k, f, bits, kind, seed):
    """Random kernel machines x 4/8/16-bit: the Pallas kernel PE, the
    jnp oracle, and the numpy spec must agree bit-exactly."""
    rng = np.random.default_rng(seed)
    x, sv, w, bias, consts = _rand_kernel_setup(rng, b, s, k, f, bits, kind)
    want = _spec_scores(x, sv, w, bias, kind, consts)
    if kind == "rbf":
        phi_ref = ref.rbf_phi_ref(jnp.asarray(x), jnp.asarray(sv), consts["g2_q"])
    else:
        phi_ref = ref.poly_phi_ref(
            jnp.asarray(x), jnp.asarray(sv), consts["gamma_q"],
            consts["coef0_q"], consts["degree"],
        )
    oracle = ref.kernel_scores_ref(phi_ref, jnp.asarray(w), jnp.asarray(bias))
    np.testing.assert_array_equal(np.asarray(oracle).astype(np.int64), want)
    got = KP.kernel_pe_scores(
        jnp.asarray(x), jnp.asarray(sv), jnp.asarray(w), jnp.asarray(bias),
        kind=kind, bits=bits, block_b=32, **consts,
    )
    np.testing.assert_array_equal(np.asarray(got).astype(np.int64), want)


def test_kernel_accumulator_extreme_no_overflow():
    """Worst-case accumulation (S=64 supports, 16-bit duals, phi at the
    poly clamp) stays inside int32 — the i32-oracle headroom argument."""
    s = 64
    worst = s * 32767 * Q.KCLAMP + Q.KSCALE * 32767
    assert worst < 2**31
    x = np.full((2, 4), 15, np.int32)
    sv = np.full((s, 4), 15, np.int32)
    w = np.full((3, s), 32767, np.int32)
    bias = np.full(3, 32767, np.int32)
    consts = {"g2_q": 0, "gamma_q": 4999, "coef0_q": Q.KCLAMP, "degree": 3}
    want = _spec_scores(x, sv, w, bias, "poly", consts)
    got = KP.kernel_pe_scores(
        jnp.asarray(x), jnp.asarray(sv), jnp.asarray(w), jnp.asarray(bias),
        kind="poly", bits=16, **consts,
    )
    np.testing.assert_array_equal(np.asarray(got).astype(np.int64), want)


def test_kernel_vmem_estimate_is_tiny():
    est = KP.kernel_vmem_estimate_bytes(KP.DEFAULT_BLOCK_B, 35, 64, 15)
    assert est < 1 << 20
