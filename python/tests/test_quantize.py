"""Quantization pipeline tests: ranges, scale sharing, monotonicity."""

import numpy as np
import pytest

from compile import datasets as D
from compile import quantize as Q
from compile import train as T


@pytest.fixture(scope="module")
def iris_model():
    ds = D.load("iris")
    return ds, T.train_ovr(ds.x_train, ds.y_train, 3, steps=1500)


def test_input_quantization_range():
    x = np.array([[0.0, 0.5, 1.0], [0.26, 0.74, 0.99]])
    q = Q.quantize_inputs(x)
    assert q.dtype == np.int32
    assert q.min() >= 0 and q.max() <= 15
    assert q[0, 0] == 0 and q[0, 2] == 15
    assert q[0, 1] == 8  # round(7.5) banker's -> 8? np.round(7.5)=8.0? np.round uses
    # banker's rounding: np.round(7.5) == 8.0 is FALSE (it's 8? -> 7.5 rounds to 8? no: to even = 8)
    # 0.5*15 = 7.5 -> nearest even is 8
    assert q[1, 0] == 4  # 3.9 -> 4


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_weight_range_symmetric(iris_model, bits):
    _, m = iris_model
    qm = Q.quantize_model(m, bits)
    qmax = (1 << (bits - 1)) - 1
    assert np.abs(qm.weights).max() <= qmax
    assert np.abs(qm.biases).max() <= qmax
    # the largest coefficient maps to full scale
    assert max(np.abs(qm.weights).max(), np.abs(qm.biases).max()) == qmax


def test_shared_scale_across_classifiers(iris_model):
    """OvR argmax requires one scale for the whole model."""
    _, m = iris_model
    qm = Q.quantize_model(m, 8)
    # dequantised weights approximate originals under the SINGLE scale
    deq = qm.weights / qm.scale
    assert np.abs(deq - m.weights).max() <= 0.5 / qm.scale + 1e-9


def test_bits_rejected():
    _, m = (None, T.SvmModel("ovr", 2, np.zeros((2, 2)), np.zeros(2), [(0, 0), (1, 1)]))
    with pytest.raises(ValueError):
        Q.quantize_model(m, 5)


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_16bit_close_to_float(iris_model, bits):
    ds, m = iris_model
    qm = Q.quantize_model(m, bits)
    x_q = Q.quantize_inputs(ds.x_test)
    acc_q = T.accuracy(Q.predict_int(qm, x_q), ds.y_test)
    acc_f = T.accuracy(T.predict_float(m, ds.x_test), ds.y_test)
    # 16-bit must track float closely; 4-bit may lose a few points
    tol = {4: 0.15, 8: 0.06, 16: 0.03}[bits]
    assert abs(acc_q - acc_f) <= tol


def test_scores_monotone_with_float(iris_model):
    """Integer scores are a positive monotone map of float scores, so
    per-classifier rankings are preserved up to quantization error."""
    ds, m = iris_model
    qm = Q.quantize_model(m, 16)
    x_q = Q.quantize_inputs(ds.x_test[:20])
    s_int = Q.scores_int(qm, x_q).astype(np.float64)
    s_float = ds.x_test[:20] @ m.weights.T + m.biases
    # correlation per classifier should be ~1
    for k in range(3):
        c = np.corrcoef(s_int[:, k], s_float[:, k])[0, 1]
        assert c > 0.97, f"classifier {k}: corr {c}"


def test_predict_int_tie_first_max():
    qm = Q.QuantModel(
        strategy="ovr", n_classes=2, bits=4,
        weights=np.array([[1], [1]], np.int32),
        biases=np.array([0, 0], np.int32),
        pairs=[(0, 0), (1, 1)], scale=1.0,
    )
    pred = Q.predict_int(qm, np.array([[5]], np.int32))
    assert pred[0] == 0  # tie -> first
