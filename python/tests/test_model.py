"""Layer-2 model graph tests: predictions vs the numpy spec, OvO voting,
and the AOT lowering invariants (HLO text properties the Rust loader
depends on)."""

import numpy as np
import pytest

from compile import datasets as D
from compile import model as M
from compile import quantize as Q
from compile import train as T


@pytest.fixture(scope="module")
def v3_models():
    ds = D.load("v3")
    ovr = T.train_ovr(ds.x_train, ds.y_train, 3, steps=800)
    ovo = T.train_ovo(ds.x_train, ds.y_train, 3, steps=800)
    return ds, ovr, ovo


@pytest.mark.parametrize("strategy", ["ovr", "ovo"])
@pytest.mark.parametrize("bits", [4, 8, 16])
def test_l2_matches_numpy_spec(v3_models, strategy, bits):
    ds, ovr, ovo = v3_models
    qm = Q.quantize_model(ovr if strategy == "ovr" else ovo, bits)
    x_q = Q.quantize_inputs(ds.x_test)
    pred, scores = M.predict_np(qm, x_q)
    np.testing.assert_array_equal(pred, Q.predict_int(qm, x_q))
    np.testing.assert_array_equal(scores.astype(np.int64), Q.scores_int(qm, x_q))


def test_ovo_graph_vote_tally(v3_models):
    """The OvO graph's argmax must implement first-max vote resolution."""
    ds, _, ovo = v3_models
    qm = Q.quantize_model(ovo, 8)
    x_q = Q.quantize_inputs(ds.x_test[:40])
    pred, scores = M.predict_np(qm, x_q)
    # recompute votes in numpy
    votes = np.zeros((len(x_q), qm.n_classes), np.int32)
    for k, (i, j) in enumerate(qm.pairs):
        pos = scores[:, k] >= 0
        votes[pos, i] += 1
        votes[~pos, j] += 1
    np.testing.assert_array_equal(pred, np.argmax(votes, axis=1))


@pytest.mark.parametrize("batch", [1, 64])
def test_hlo_text_lowering(v3_models, batch):
    ds, ovr, _ = v3_models
    qm = Q.quantize_model(ovr, 4)
    hlo = M.lower_to_hlo_text(qm, batch)
    # single s32 parameter of the right shape
    assert f"s32[{batch},{qm.n_features}]" in hlo
    assert "ENTRY" in hlo
    # the load-bearing property: no elided literals (xla 0.5.1 would
    # silently fill `constant({...})` with iota garbage)
    assert "constant({...})" not in hlo
    assert "{ ... }" not in hlo


def test_hlo_constants_contain_weights(v3_models):
    """The classifier weights must be baked into the artifact verbatim."""
    ds, ovr, _ = v3_models
    qm = Q.quantize_model(ovr, 8)
    hlo = M.lower_to_hlo_text(qm, 1)
    # pick a distinctive weight value and find it in some constant body
    w = int(qm.weights[0, 0])
    assert str(w) in hlo


def test_lowering_is_deterministic(v3_models):
    _, ovr, _ = v3_models
    qm = Q.quantize_model(ovr, 16)
    assert M.lower_to_hlo_text(qm, 1) == M.lower_to_hlo_text(qm, 1)
