"""Layer-1 Pallas kernel: the SVM accelerator's PE datapath (Fig. 6/7).

The paper's Processing Engine is eight parallel 4×4-bit **unsigned**
multipliers.  Signed {4,8,16}-bit weights are handled by a
2's-complement→sign-magnitude converter: each weight contributes its
unsigned magnitude, split into 4-bit nibbles, and a sign flag that turns
the accumulate into an add or subtract.  A mux stage shifts each nibble
product left by 0/4/8/12 before accumulation (Fig. 7).

Hardware adaptation (DESIGN.md §3/L1): the paper targets a 52 kHz
flexible ASIC, not a GPU, so there is nothing to port mechanically —
instead the PE's *structure* is what the kernel mirrors:

  * the eight physical multipliers  → the vectorised nibble axis
    ``k ∈ 0..bits/4`` plus lane-parallel 4×4 products,
  * the sign-magnitude module       → ``sign``/``mag`` decomposition,
  * the shift-mux stage             → ``<< 4k`` on each nibble product,
  * the bias-as-extra-input trick   → ``XMAX * b_q`` epilogue,
  * the running max_sum/max_id regs → the fused argmax variant.

BlockSpec tiles the batch axis so one block's working set
(x: TB×F, w: K×F, out: TB×K, all int32) stays ≤ a few KiB — far inside
a TPU core's ~16 MiB VMEM; on a real TPU this kernel is VPU-bound
(int4-magnitude arithmetic, no MXU), see DESIGN.md §9.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO, which is exactly what
the Rust runtime loads (see /opt/xla-example/README.md).

Every kernel here must agree bit-exactly with kernels/ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

XMAX = 15  # 4-bit unsigned full scale; also the bias "input" value

# Default batch tile.  Small models (K·F ≤ 34·15) make the x-tile the
# dominant VMEM term: 64·34·4 B ≈ 8.5 KiB per block.
DEFAULT_BLOCK_B = 64


def _pe_scores_kernel(x_ref, w_ref, b_ref, o_ref, *, nibbles: int):
    """One grid step: scores for a TB×F tile of inputs against all K
    classifiers, nibble-decomposed exactly like the PE datapath."""
    x = x_ref[...].astype(jnp.int32)          # [TB, F] values 0..15
    w = w_ref[...].astype(jnp.int32)          # [K, F]  signed
    # 2's-complement -> sign-magnitude (the converter module in Fig. 6)
    sign = jnp.where(w < 0, -1, 1).astype(jnp.int32)
    mag = jnp.abs(w)
    acc = jnp.zeros((x.shape[0], w.shape[0]), jnp.int32)
    for k in range(nibbles):                  # the eight-multiplier array,
        nib = (mag >> (4 * k)) & 0xF          # one nibble plane per pass
        signed_nib = sign * nib               # add-or-subtract select
        # 4×4 unsigned product + shift-mux (<< 4k), accumulated in cur_sum
        acc = acc + (
            jnp.dot(x, signed_nib.T, preferred_element_type=jnp.int32) << (4 * k)
        )
    # bias as an extra (input = XMAX, weight = b_q) pair
    o_ref[...] = acc + XMAX * b_ref[...].astype(jnp.int32)[None, :]


def _pad_batch(x_q, block_b):
    b = x_q.shape[0]
    pad = (-b) % block_b
    if pad:
        x_q = jnp.concatenate([x_q, jnp.zeros((pad, x_q.shape[1]), x_q.dtype)], axis=0)
    return x_q, b


@functools.partial(jax.jit, static_argnames=("bits", "block_b"))
def pe_scores(x_q, w_q, b_q, *, bits: int, block_b: int = DEFAULT_BLOCK_B):
    """Integer classifier scores [B, K] via the PE datapath.

    x_q: [B, F] int32 with values in 0..15 (4-bit unsigned features)
    w_q: [K, F] int32 signed, magnitudes < 2**(bits-1)
    b_q: [K]    int32 signed
    """
    assert bits in (4, 8, 16), bits
    nibbles = bits // 4
    x_pad, b_real = _pad_batch(x_q, block_b)
    n_blocks = x_pad.shape[0] // block_b
    k = w_q.shape[0]
    f = w_q.shape[1]
    out = pl.pallas_call(
        functools.partial(_pe_scores_kernel, nibbles=nibbles),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x_pad.shape[0], k), jnp.int32),
        interpret=True,
    )(x_pad, w_q, b_q)
    return out[:b_real]


def _pe_argmax_kernel(x_ref, w_ref, b_ref, s_ref, id_ref, *, nibbles: int):
    """Fused scores + running argmax — mirrors the max_sum/max_id registers
    updated concurrently with the PE calculation (paper §IV-A)."""
    _pe_scores_kernel(x_ref, w_ref, b_ref, s_ref, nibbles=nibbles)
    s = s_ref[...]
    # strictly-greater update == first maximum wins, like the hardware
    id_ref[...] = jnp.argmax(s, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "block_b"))
def pe_scores_argmax(x_q, w_q, b_q, *, bits: int, block_b: int = DEFAULT_BLOCK_B):
    """(scores [B,K], argmax-id [B]) in one fused kernel (OvR fast path)."""
    assert bits in (4, 8, 16), bits
    nibbles = bits // 4
    x_pad, b_real = _pad_batch(x_q, block_b)
    n_blocks = x_pad.shape[0] // block_b
    k = w_q.shape[0]
    f = w_q.shape[1]
    scores, ids = pl.pallas_call(
        functools.partial(_pe_argmax_kernel, nibbles=nibbles),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x_pad.shape[0], k), jnp.int32),
            jax.ShapeDtypeStruct((x_pad.shape[0],), jnp.int32),
        ],
        interpret=True,
    )(x_pad, w_q, b_q)
    return scores[:b_real], ids[:b_real]


def vmem_estimate_bytes(block_b: int, n_feat: int, n_classifiers: int) -> int:
    """Static VMEM footprint of one grid step (all operands int32).

    Used by DESIGN.md §9 and tests to assert the block stays tiny
    relative to a 16 MiB VMEM budget.
    """
    x = block_b * n_feat * 4
    w = n_classifiers * n_feat * 4
    b = n_classifiers * 4
    out = block_b * n_classifiers * 4
    scratch = block_b * n_classifiers * 4  # accumulator
    return x + w + b + out + scratch
