"""Layer-1 Pallas kernel: the kernel-capable PE datapath (ISSUE 8).

Extends the linear PE (svm_pe.py) to kernel machines.  The datapath is
the same two-stage structure the KSVM CFU implements:

  stage 1 — feature map: per support vector, either the squared
    distance (RBF) or the dot product (poly) of the 4-bit input against
    the 4-bit support vector, then the fixed-point kernel evaluation
    (32-entry 2^-x LUT for RBF; clamp/square ladder for poly),
  stage 2 — dual accumulate: the signed alpha weights ride the linear
    PE's sign-magnitude nibble datapath against phi, and the bias rides
    as an (input = KSCALE, weight = b_q) pair.

Support vectors and inputs are 4-bit unsigned, so stage 1 reuses the
eight 4x4 multipliers directly; stage 2 is the identical shift-mux
accumulate as the linear PE with phi as the "input" lane.

``interpret=True`` always, as in svm_pe.py.  Every kernel here must
agree bit-exactly with kernels/ref.py (and so with compile/quantize.py
and the whole Rust stack).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EXP2_LUT, GSHIFT, KCLAMP, KFRAC, KSCALE, LUTB
from .svm_pe import DEFAULT_BLOCK_B, _pad_batch


def _phi_block(x, sv, lut, *, kind, g2_q, gamma_q, coef0_q, degree):
    """Stage 1: integer feature map [TB, S] for one batch tile (int32)."""
    if kind == "rbf":
        diff = x[:, None, :] - sv[None, :, :]
        d2 = jnp.sum(diff * diff, axis=-1)
        z = jnp.int32(g2_q) * d2
        zi = z >> GSHIFT
        zf = (z >> (GSHIFT - LUTB)) & ((1 << LUTB) - 1)
        return jnp.where(zi >= 31, 0, lut[zf] >> jnp.minimum(zi, 31))
    d = jnp.dot(x, sv.T, preferred_element_type=jnp.int32)
    t = jnp.clip((jnp.int32(gamma_q) * d >> GSHIFT) + coef0_q, -KCLAMP, KCLAMP)
    p = t
    for _ in range(degree - 1):
        p = jnp.clip(p * t >> KFRAC, -KCLAMP, KCLAMP)
    return p


def _kpe_scores_kernel(
    x_ref, sv_ref, w_ref, b_ref, lut_ref, o_ref, *, kind, nibbles, g2_q,
    gamma_q, coef0_q, degree
):
    """One grid step: kernel-machine scores for a TB x F input tile.

    The 2^-x LUT rides as an input ref (pallas kernels may not capture
    array constants), mirroring the CFU's LUT ROM."""
    x = x_ref[...].astype(jnp.int32)    # [TB, F] values 0..15
    sv = sv_ref[...].astype(jnp.int32)  # [S, F]  values 0..15
    w = w_ref[...].astype(jnp.int32)    # [K, S]  signed dual coefficients
    phi = _phi_block(
        x, sv, lut_ref[...], kind=kind, g2_q=g2_q, gamma_q=gamma_q,
        coef0_q=coef0_q, degree=degree,
    )
    # stage 2: the linear PE's sign-magnitude nibble accumulate, with
    # phi standing in for the input lanes
    sign = jnp.where(w < 0, -1, 1).astype(jnp.int32)
    mag = jnp.abs(w)
    acc = jnp.zeros((x.shape[0], w.shape[0]), jnp.int32)
    for k in range(nibbles):
        nib = (mag >> (4 * k)) & 0xF
        signed_nib = sign * nib
        acc = acc + (
            jnp.dot(phi, signed_nib.T, preferred_element_type=jnp.int32) << (4 * k)
        )
    o_ref[...] = acc + KSCALE * b_ref[...].astype(jnp.int32)[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("kind", "bits", "g2_q", "gamma_q", "coef0_q", "degree",
                     "block_b"),
)
def kernel_pe_scores(
    x_q, sv_q, w_q, b_q, *, kind: str, bits: int, g2_q: int = 0,
    gamma_q: int = 0, coef0_q: int = 0, degree: int = 0,
    block_b: int = DEFAULT_BLOCK_B,
):
    """Integer kernel-machine scores [B, K] via the kernel PE datapath.

    x_q:  [B, F] int32 values 0..15      sv_q: [S, F] int32 values 0..15
    w_q:  [K, S] int32 signed duals      b_q:  [K]    int32 signed
    """
    assert kind in ("rbf", "poly"), kind
    assert bits in (4, 8, 16), bits
    nibbles = bits // 4
    x_pad, b_real = _pad_batch(x_q, block_b)
    n_blocks = x_pad.shape[0] // block_b
    s, f = sv_q.shape
    k = w_q.shape[0]
    out = pl.pallas_call(
        functools.partial(
            _kpe_scores_kernel, kind=kind, nibbles=nibbles, g2_q=g2_q,
            gamma_q=gamma_q, coef0_q=coef0_q, degree=degree,
        ),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((s, f), lambda i: (0, 0)),
            pl.BlockSpec((k, s), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((EXP2_LUT.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x_pad.shape[0], k), jnp.int32),
        interpret=True,
    )(x_pad, sv_q, w_q, b_q, EXP2_LUT)
    return out[:b_real]


def qm_pe_scores(qm, x_q, *, block_b: int = DEFAULT_BLOCK_B):
    """Convenience wrapper: run the kernel PE straight off a QuantModel."""
    return kernel_pe_scores(
        x_q, jnp.asarray(qm.support), jnp.asarray(qm.weights),
        jnp.asarray(qm.biases), kind=qm.kernel, bits=qm.bits,
        g2_q=qm.g2_q, gamma_q=qm.gamma_q, coef0_q=qm.coef0_q,
        degree=qm.degree, block_b=block_b,
    )


def kernel_vmem_estimate_bytes(
    block_b: int, n_feat: int, n_support: int, n_classifiers: int
) -> int:
    """Static VMEM footprint of one grid step (all operands int32)."""
    x = block_b * n_feat * 4
    sv = n_support * n_feat * 4
    w = n_classifiers * n_support * 4
    b = n_classifiers * 4
    phi = block_b * n_support * 4
    out = block_b * n_classifiers * 4
    scratch = block_b * n_classifiers * 4
    return x + sv + w + b + phi + out + scratch
