"""Pure-jnp oracle for the SVM PE kernel (Layer-1 correctness anchor).

These functions define the integer semantics every other layer must
reproduce bit-exactly:

    kernels/svm_pe.py  (Pallas, nibble-decomposed PE datapath)
    rust/src/svm/      (native integer inference)
    rust/src/accel/    (cycle-level accelerator model)
    SERV-executed programs (rust/src/program/)

Score:  score[n, k] = sum_f x_q[n, f] * w_q[k, f]  +  15 * b_q[k]
OvR:    argmax over k (first max wins).
OvO:    classifier k for pair (i, j), i<j: score >= 0 votes i, else j;
        winner = argmax votes (first max wins).
"""

from __future__ import annotations

import jax.numpy as jnp

XMAX = 15


def scores_ref(x_q, w_q, b_q):
    """[B,F] u4-in-i32, [K,F] i32, [K] i32 -> [B,K] i32 integer scores."""
    return (
        jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32).T,
                preferred_element_type=jnp.int32)
        + XMAX * b_q.astype(jnp.int32)[None, :]
    )


def ovr_predict_ref(x_q, w_q, b_q):
    """OvR: winning class id per sample (first maximum on ties)."""
    return jnp.argmax(scores_ref(x_q, w_q, b_q), axis=1).astype(jnp.int32)


def ovo_votes_ref(scores, pairs_i, pairs_j, n_classes):
    """Vote tally [B, C] from pairwise scores [B, K] and pair index arrays."""
    pos = scores >= 0  # [B, K]
    winner = jnp.where(pos, pairs_i[None, :], pairs_j[None, :])  # [B, K]
    onehot = jnp.equal(winner[:, :, None], jnp.arange(n_classes)[None, None, :])
    return jnp.sum(onehot.astype(jnp.int32), axis=1)


def ovo_predict_ref(x_q, w_q, b_q, pairs_i, pairs_j, n_classes):
    s = scores_ref(x_q, w_q, b_q)
    votes = ovo_votes_ref(s, pairs_i, pairs_j, n_classes)
    return jnp.argmax(votes, axis=1).astype(jnp.int32)
