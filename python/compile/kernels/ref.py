"""Pure-jnp oracle for the SVM PE kernel (Layer-1 correctness anchor).

These functions define the integer semantics every other layer must
reproduce bit-exactly:

    kernels/svm_pe.py  (Pallas, nibble-decomposed PE datapath)
    rust/src/svm/      (native integer inference)
    rust/src/accel/    (cycle-level accelerator model)
    SERV-executed programs (rust/src/program/)

Score:  score[n, k] = sum_f x_q[n, f] * w_q[k, f]  +  15 * b_q[k]
OvR:    argmax over k (first max wins).
OvO:    classifier k for pair (i, j), i<j: score >= 0 votes i, else j;
        winner = argmax votes (first max wins).
"""

from __future__ import annotations

import jax.numpy as jnp

XMAX = 15


def scores_ref(x_q, w_q, b_q):
    """[B,F] u4-in-i32, [K,F] i32, [K] i32 -> [B,K] i32 integer scores."""
    return (
        jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32).T,
                preferred_element_type=jnp.int32)
        + XMAX * b_q.astype(jnp.int32)[None, :]
    )


def ovr_predict_ref(x_q, w_q, b_q):
    """OvR: winning class id per sample (first maximum on ties)."""
    return jnp.argmax(scores_ref(x_q, w_q, b_q), axis=1).astype(jnp.int32)


def ovo_votes_ref(scores, pairs_i, pairs_j, n_classes):
    """Vote tally [B, C] from pairwise scores [B, K] and pair index arrays."""
    pos = scores >= 0  # [B, K]
    winner = jnp.where(pos, pairs_i[None, :], pairs_j[None, :])  # [B, K]
    onehot = jnp.equal(winner[:, :, None], jnp.arange(n_classes)[None, None, :])
    return jnp.sum(onehot.astype(jnp.int32), axis=1)


def ovo_predict_ref(x_q, w_q, b_q, pairs_i, pairs_j, n_classes):
    s = scores_ref(x_q, w_q, b_q)
    votes = ovo_votes_ref(s, pairs_i, pairs_j, n_classes)
    return jnp.argmax(votes, axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# kernel machines (ISSUE 8): integer feature map + scores, all-int32.
# Constants are textual twins of compile/quantize.py and
# rust/src/kernel/mod.rs; test_kernel_quantize.py pins them equal.
# ---------------------------------------------------------------------------

KFRAC = 8
KSCALE = 1 << KFRAC
GSHIFT = 12
LUTB = 5
KCLAMP = 1 << 10

EXP2_LUT = jnp.array(
    [256, 251, 245, 240, 235, 230, 225, 220, 215, 211, 206, 202, 197, 193,
     189, 185, 181, 177, 173, 170, 166, 162, 159, 156, 152, 149, 146, 143,
     140, 137, 134, 131],
    dtype=jnp.int32,
)


def rbf_phi_ref(x_q, sv_q, g2_q):
    """[B,F] u4, [S,F] u4 -> phi [B,S] i32 (quantize.rbf_phi_int twin).

    int32 is safe end to end: quantize_kernel_constants guarantees
    g2_q * F * 225 < 2^31."""
    x = x_q.astype(jnp.int32)
    sv = sv_q.astype(jnp.int32)
    diff = x[:, None, :] - sv[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)  # [B, S]
    z = jnp.int32(g2_q) * d2
    zi = z >> GSHIFT
    zf = (z >> (GSHIFT - LUTB)) & ((1 << LUTB) - 1)
    return jnp.where(zi >= 31, 0, EXP2_LUT[zf] >> jnp.minimum(zi, 31))


def poly_phi_ref(x_q, sv_q, gamma_q, coef0_q, degree):
    """[B,F] u4, [S,F] u4 -> phi [B,S] i32 (quantize.poly_phi_int twin).

    The ±KCLAMP clamp bounds every product inside int32; degree is a
    static python int (trace-time unrolled, like the nibble loop)."""
    x = x_q.astype(jnp.int32)
    sv = sv_q.astype(jnp.int32)
    d = jnp.dot(x, sv.T, preferred_element_type=jnp.int32)  # [B, S]
    t = jnp.clip((jnp.int32(gamma_q) * d >> GSHIFT) + coef0_q, -KCLAMP, KCLAMP)
    p = t
    for _ in range(degree - 1):
        p = jnp.clip(p * t >> KFRAC, -KCLAMP, KCLAMP)
    return p


def kernel_scores_ref(phi, w_q, b_q):
    """[B,S] i32 feature map, [K,S] i32 duals, [K] i32 -> scores [B,K] i32.

    A kernel machine is a linear machine over phi with the bias riding
    as an (input = KSCALE, weight = b_q) pair."""
    return (
        jnp.dot(phi.astype(jnp.int32), w_q.astype(jnp.int32).T,
                preferred_element_type=jnp.int32)
        + KSCALE * b_q.astype(jnp.int32)[None, :]
    )
