"""From-scratch linear-SVM training in JAX (scikit-learn substitute).

The paper trains with scikit-learn's ``LinearSVC`` (liblinear: L2-regularised
squared-hinge loss).  scikit-learn is not available offline, so we implement
the same objective family from scratch and optimise it with full-batch Adam
until convergence — the problems are tiny (≤ 500 samples, ≤ 34 features), so
full-batch gradient descent converges to the same solutions liblinear finds.

Objective (per binary classifier, matching LinearSVC defaults):

    min_{w,b}  0.5 * ||w||^2  +  C * sum_i max(0, 1 - y_i (w.x_i + b))^2

Multi-class schemes (paper §IV-A):
  * OvR — one classifier per class, winner = argmax score.
  * OvO — one classifier per ordered pair (i, j), i < j, trained with
    class i as +1 and class j as -1; winner by majority vote, where a
    non-negative score votes i and a negative score votes j.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# binary squared-hinge SVM
# ---------------------------------------------------------------------------


def _svm_loss(params, x, y, c_reg):
    w, b = params
    margin = y * (x @ w + b)
    hinge = jnp.maximum(0.0, 1.0 - margin)
    return 0.5 * jnp.sum(w * w) + c_reg * jnp.sum(hinge * hinge)


@functools.partial(jax.jit, static_argnames=("steps",))
def _adam_train(x, y, c_reg, steps, lr):
    """Full-batch Adam on the squared-hinge objective.  Returns (w, b)."""
    n_feat = x.shape[1]
    params = (jnp.zeros(n_feat), jnp.asarray(0.0))
    grad_fn = jax.grad(_svm_loss)
    beta1, beta2, eps = 0.9, 0.999, 1e-8

    def step(carry, i):
        params, m, v = carry
        g = grad_fn(params, x, y, c_reg)
        m = jax.tree.map(lambda m_, g_: beta1 * m_ + (1 - beta1) * g_, m, g)
        v = jax.tree.map(lambda v_, g_: beta2 * v_ + (1 - beta2) * g_ * g_, v, g)
        t = i + 1.0
        mhat = jax.tree.map(lambda m_: m_ / (1 - beta1**t), m)
        vhat = jax.tree.map(lambda v_: v_ / (1 - beta2**t), v)
        params = jax.tree.map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
        )
        return (params, m, v), ()

    zeros = jax.tree.map(jnp.zeros_like, params)
    (params, _, _), _ = jax.lax.scan(
        step, (params, zeros, zeros), jnp.arange(steps, dtype=jnp.float32)
    )
    return params


def train_binary(
    x: np.ndarray,
    y_pm1: np.ndarray,
    c_reg: float = 1.0,
    steps: int = 4000,
    lr: float = 0.05,
) -> tuple[np.ndarray, float]:
    """Train one binary SVM; y in {-1, +1}.  Returns (w [F], b)."""
    w, b = _adam_train(
        jnp.asarray(x, jnp.float32),
        jnp.asarray(y_pm1, jnp.float32),
        jnp.asarray(c_reg, jnp.float32),
        steps,
        jnp.asarray(lr, jnp.float32),
    )
    return np.asarray(w, np.float64), float(b)


# ---------------------------------------------------------------------------
# multi-class models
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SvmModel:
    """A trained multi-class linear SVM (float coefficients).

    ``strategy`` is "ovr" or "ovo".  For OvR there are C classifiers, one
    per class, ``pairs[k] = (k, k)``.  For OvO there are C(C-1)/2, and
    ``pairs[k] = (i, j)`` with i < j: positive score votes i.
    """

    strategy: str
    n_classes: int
    weights: np.ndarray  # [K, F] float
    biases: np.ndarray   # [K]    float
    pairs: list[tuple[int, int]]

    @property
    def n_classifiers(self) -> int:
        return int(self.weights.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.weights.shape[1])


def train_ovr(x, y, n_classes, c_reg=5.0, steps=4000) -> SvmModel:
    ws, bs, pairs = [], [], []
    for c in range(n_classes):
        y_pm1 = np.where(y == c, 1.0, -1.0)
        w, b = train_binary(x, y_pm1, c_reg=c_reg, steps=steps)
        ws.append(w)
        bs.append(b)
        pairs.append((c, c))
    return SvmModel("ovr", n_classes, np.stack(ws), np.asarray(bs), pairs)


def train_ovo(x, y, n_classes, c_reg=5.0, steps=4000) -> SvmModel:
    ws, bs, pairs = [], [], []
    for i, j in itertools.combinations(range(n_classes), 2):
        mask = (y == i) | (y == j)
        xs = x[mask]
        y_pm1 = np.where(y[mask] == i, 1.0, -1.0)
        w, b = train_binary(xs, y_pm1, c_reg=c_reg, steps=steps)
        ws.append(w)
        bs.append(b)
        pairs.append((i, j))
    return SvmModel("ovo", n_classes, np.stack(ws), np.asarray(bs), pairs)


# ---------------------------------------------------------------------------
# float inference (reference; quantized inference lives in quantize/ref)
# ---------------------------------------------------------------------------


def predict_float(model: SvmModel, x: np.ndarray) -> np.ndarray:
    scores = x @ model.weights.T + model.biases  # [N, K]
    if model.strategy == "ovr":
        return np.argmax(scores, axis=1).astype(np.int32)
    votes = np.zeros((x.shape[0], model.n_classes), dtype=np.int32)
    for k, (i, j) in enumerate(model.pairs):
        pos = scores[:, k] >= 0.0
        votes[pos, i] += 1
        votes[~pos, j] += 1
    return np.argmax(votes, axis=1).astype(np.int32)


def accuracy(pred: np.ndarray, y: np.ndarray) -> float:
    return float(np.mean(pred == y))
