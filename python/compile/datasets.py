"""Dataset substrate for the Flex-SVM reproduction.

The paper evaluates on five UCI datasets: Balance Scale (BS), Dermatology
(Derm.), Iris, Seeds and Vertebral 3C (V3).  This environment has no
network access, so (per the substitution rule in DESIGN.md §2):

* **Balance Scale is generated exactly.**  The UCI dataset is itself
  synthetic and fully deterministic: the 625 rows are the cartesian
  product of four features (left-weight, left-distance, right-weight,
  right-distance) each in 1..5, and the label compares the torques
  ``lw*ld`` vs ``rw*rd`` (L / B / R).  What we produce IS the dataset.
* The other four are **calibrated synthetic generators** that match the
  published shape (n_samples, n_features, n_classes) and the
  linear-separability regime of the real data, so that a linear SVM and
  its 4/8/16-bit quantized variants land in the same accuracy band the
  paper reports.  Class-conditional Gaussians with per-dataset center
  geometry and anisotropic noise; a small fraction of boundary overlap
  is injected where the real dataset is known not to be separable.

All features are normalised to [0, 1] with train-set min/max (paper §V-A)
and split 80/20 with a fixed seed (paper: 80/20 ratio).
"""

from __future__ import annotations

import dataclasses

import numpy as np

# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Dataset:
    """A loaded, normalised, split classification dataset."""

    name: str
    x_train: np.ndarray  # float32 [n_tr, F] in [0, 1]
    y_train: np.ndarray  # int32   [n_tr]
    x_test: np.ndarray   # float32 [n_te, F] in [0, 1]
    y_test: np.ndarray   # int32   [n_te]
    n_classes: int
    class_names: list[str]

    @property
    def n_features(self) -> int:
        return int(self.x_train.shape[1])

    @property
    def n_train(self) -> int:
        return int(self.x_train.shape[0])

    @property
    def n_test(self) -> int:
        return int(self.x_test.shape[0])


DATASET_NAMES = ["bs", "derm", "iris", "seeds", "v3"]

# Pretty names used in Table I.
PRETTY = {
    "bs": "BS",
    "derm": "Derm.",
    "iris": "Iris",
    "seeds": "Seeds",
    "v3": "V3",
}


# ---------------------------------------------------------------------------
# split + normalisation helpers
# ---------------------------------------------------------------------------


def _split_normalise(name, x, y, n_classes, class_names, seed=1302):
    """Shuffle, 80/20 split, min-max normalise to [0,1] with train stats."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(x))
    x, y = x[order], y[order]
    n_tr = int(round(0.8 * len(x)))
    x_tr, x_te = x[:n_tr], x[n_tr:]
    y_tr, y_te = y[:n_tr], y[n_tr:]
    lo = x_tr.min(axis=0)
    hi = x_tr.max(axis=0)
    span = np.where(hi - lo < 1e-12, 1.0, hi - lo)
    norm = lambda a: np.clip((a - lo) / span, 0.0, 1.0).astype(np.float32)
    return Dataset(
        name=name,
        x_train=norm(x_tr),
        y_train=y_tr.astype(np.int32),
        x_test=norm(x_te),
        y_test=y_te.astype(np.int32),
        n_classes=n_classes,
        class_names=class_names,
    )


def _gaussian_classes(
    rng: np.random.Generator,
    n_per_class: list[int],
    centers: np.ndarray,        # [C, F]
    scales: np.ndarray,         # [C, F] per-class per-feature std
    flip_frac: float = 0.0,     # fraction of labels flipped to a neighbour
):
    """Class-conditional Gaussian clusters with optional boundary noise."""
    xs, ys = [], []
    n_classes = len(n_per_class)
    for c, n in enumerate(n_per_class):
        pts = rng.normal(loc=centers[c], scale=scales[c], size=(n, centers.shape[1]))
        xs.append(pts)
        ys.append(np.full(n, c))
    x = np.concatenate(xs).astype(np.float64)
    y = np.concatenate(ys).astype(np.int64)
    if flip_frac > 0:
        n_flip = int(round(flip_frac * len(y)))
        idx = rng.choice(len(y), size=n_flip, replace=False)
        y[idx] = (y[idx] + rng.integers(1, n_classes, size=n_flip)) % n_classes
    return x, y


# ---------------------------------------------------------------------------
# the five datasets
# ---------------------------------------------------------------------------


def balance_scale() -> Dataset:
    """Exact UCI Balance Scale: 625 rows, 4 features in 1..5, 3 classes.

    Label: torque comparison of left vs right arm (L > / B = / R <).
    Class ids: 0=L, 1=B, 2=R (alphabetical, as scikit-learn would encode).
    """
    rows, labels = [], []
    for lw in range(1, 6):
        for ld in range(1, 6):
            for rw in range(1, 6):
                for rd in range(1, 6):
                    left, right = lw * ld, rw * rd
                    lab = 0 if left > right else (1 if left == right else 2)
                    rows.append((lw, ld, rw, rd))
                    labels.append(lab)
    x = np.asarray(rows, dtype=np.float64)
    y = np.asarray(labels, dtype=np.int64)
    return _split_normalise("bs", x, y, 3, ["L", "B", "R"])


def iris_like() -> Dataset:
    """Iris-shaped: 150×4, 3 classes; one separable class, two overlapping.

    Mirrors the real Iris geometry: setosa is linearly separable from the
    other two; versicolor/virginica overlap along petal dimensions.
    """
    rng = np.random.default_rng(42)
    centers = np.array(
        [
            [5.0, 3.4, 1.5, 0.25],   # setosa-ish: small petals
            [5.9, 2.8, 4.3, 1.35],   # versicolor-ish
            [6.6, 3.0, 5.5, 2.05],   # virginica-ish — petals overlap versicolor
        ]
    )
    scales = np.array(
        [
            [0.35, 0.38, 0.17, 0.10],
            [0.51, 0.31, 0.47, 0.20],
            [0.63, 0.32, 0.55, 0.27],
        ]
    )
    x, y = _gaussian_classes(rng, [50, 50, 50], centers, scales)
    return _split_normalise("iris", x, y, 3, ["setosa", "versicolor", "virginica"])


def seeds_like() -> Dataset:
    """Seeds-shaped: 210×7, 3 wheat varieties, correlated geometric features."""
    rng = np.random.default_rng(7)
    # area, perimeter, compactness, length, width, asymmetry, groove-length
    centers = np.array(
        [
            [14.3, 14.3, 0.880, 5.51, 3.24, 2.7, 5.09],  # Kama
            [18.3, 16.1, 0.884, 6.15, 3.68, 3.6, 6.02],  # Rosa
            [11.9, 13.2, 0.849, 5.23, 2.85, 4.8, 5.12],  # Canadian
        ]
    )
    scales = np.array(
        [
            [1.21, 0.57, 0.016, 0.23, 0.18, 1.2, 0.26],
            [1.44, 0.62, 0.016, 0.25, 0.19, 1.3, 0.25],
            [0.72, 0.34, 0.022, 0.14, 0.15, 1.3, 0.16],
        ]
    )
    x, y = _gaussian_classes(rng, [70, 70, 70], centers, scales, flip_frac=0.02)
    names = ["Kama", "Rosa", "Canadian"]
    return _split_normalise("seeds", x, y, 3, names)


def dermatology_like() -> Dataset:
    """Dermatology-shaped: 366×34, 6 classes.

    The real dataset has 33 clinical/histopathological attributes scored
    0..3 plus age; classes are well linearly separable (LinearSVC reaches
    ~97-100%).  We generate 0..3-ish ordinal scores with class-specific
    signatures over disjoint-but-overlapping attribute subsets, plus an
    age column, and quantise the scores to the ordinal grid like the
    real data.
    """
    rng = np.random.default_rng(1973)
    n_feat = 34
    n_classes = 6
    # class prevalence roughly matching UCI (112, 61, 72, 49, 52, 20)
    counts = [112, 61, 72, 49, 52, 20]
    centers = np.zeros((n_classes, n_feat))
    # each class activates a signature block of ~8 attributes with
    # strength 2-3 and shares a common "erythema-like" block.
    common = np.arange(0, 5)
    for c in range(n_classes):
        centers[c, common] = 1.8
        sig = np.arange(5 + c * 4, 5 + c * 4 + 6) % (n_feat - 1)
        centers[c, sig] = 2.6
        weak = np.arange(5 + ((c + 3) % 6) * 4, 5 + ((c + 3) % 6) * 4 + 3) % (n_feat - 1)
        centers[c, weak] = 0.9
    centers[:, -1] = [36, 43, 41, 29, 46, 15]  # age column
    scales = np.full((n_classes, n_feat), 0.55)
    scales[:, -1] = 12.0
    x, y = _gaussian_classes(rng, counts, centers, scales)
    # ordinal 0..3 grid for the 33 clinical attributes, like the real data
    x[:, :-1] = np.clip(np.round(x[:, :-1]), 0, 3)
    x[:, -1] = np.clip(x[:, -1], 0, 75)
    names = [
        "psoriasis", "seboreic dermatitis", "lichen planus",
        "pityriasis rosea", "cronic dermatitis", "pityriasis rubra pilaris",
    ]
    return _split_normalise("derm", x, y, n_classes, names)


def vertebral_like() -> Dataset:
    """Vertebral-3C-shaped: 310×6, 3 classes with real overlap.

    The real dataset (normal / disk-hernia / spondylolisthesis) has six
    biomechanical attributes; hernia vs normal overlap substantially
    (linear accuracy ~85-88%), spondylolisthesis is mostly separable.
    """
    rng = np.random.default_rng(310)
    # incidence, tilt, lordosis angle, sacral slope, pelvic radius, grade
    centers = np.array(
        [
            [47.4, 17.4, 35.5, 30.0, 116.5, 2.5],    # hernia
            [51.7, 12.8, 43.5, 38.9, 123.9, 2.2],    # normal — overlaps hernia
            [71.5, 20.7, 64.1, 50.8, 114.5, 51.9],   # spondylolisthesis
        ]
    )
    scales = np.array(
        [
            [10.5, 7.0, 9.7, 7.5, 9.3, 5.4],
            [12.3, 6.7, 12.3, 9.6, 9.0, 6.3],
            [15.1, 11.5, 14.9, 12.3, 15.6, 36.7],
        ]
    )
    x, y = _gaussian_classes(rng, [60, 100, 150], centers, scales, flip_frac=0.03)
    names = ["hernia", "normal", "spondylolisthesis"]
    return _split_normalise("v3", x, y, 3, names)


_LOADERS = {
    "bs": balance_scale,
    "derm": dermatology_like,
    "iris": iris_like,
    "seeds": seeds_like,
    "v3": vertebral_like,
}


def load(name: str) -> Dataset:
    """Load one of the five Table-I datasets by short name."""
    try:
        return _LOADERS[name]()
    except KeyError:
        raise KeyError(f"unknown dataset {name!r}; choose from {DATASET_NAMES}")


def load_all() -> dict[str, Dataset]:
    return {n: load(n) for n in DATASET_NAMES}
