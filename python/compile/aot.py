"""AOT build driver: train → quantize → lower → emit artifacts/.

Run once at build time (``make artifacts``); Python is never on the Rust
request path.  Emits, under ``--out-dir`` (default ../artifacts):

  manifest.json                      index of everything below
  metrics.json                       accuracy per (dataset, strategy, bits)
  hlo/<ds>_<strat>_w<bits>_b<B>.hlo.txt   AOT inference graphs (HLO TEXT —
                                     xla_extension 0.5.1 rejects jax≥0.5
                                     serialized HloModuleProto because of
                                     64-bit instruction ids; the text
                                     parser reassigns ids cleanly)
  weights/<ds>_<strat>_w<bits>.json  quantized coefficients for the Rust
                                     accelerator model + program generators
  datasets/<ds>.json                 4-bit-quantized test set + labels
  golden/<ds>_<strat>_w<bits>.json   input→scores→prediction vectors used
                                     by the Rust cross-layer bit-exactness
                                     tests (svm, accel, SERV program, PJRT)
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from . import datasets as D
from . import train as T
from . import quantize as Q
from . import model as M

BATCH_SIZES = (1, 64)
STRATEGIES = ("ovr", "ovo")
BITS = Q.SUPPORTED_BITS
KERNELS = ("rbf", "poly")  # non-linear configs emitted per dataset (ISSUE 8)
N_GOLDEN = 32


def _jsonable(a):
    if isinstance(a, np.ndarray):
        return a.tolist()
    return a


def build_dataset_artifacts(ds: D.Dataset, out: pathlib.Path, manifest: dict, metrics: dict):
    x_q_test = Q.quantize_inputs(ds.x_test)
    x_q_train = Q.quantize_inputs(ds.x_train)

    (out / "datasets").mkdir(exist_ok=True)
    with open(out / "datasets" / f"{ds.name}.json", "w") as f:
        json.dump(
            {
                "name": ds.name,
                "n_classes": ds.n_classes,
                "n_features": ds.n_features,
                "class_names": ds.class_names,
                "x_q_test": _jsonable(x_q_test),
                "y_test": _jsonable(ds.y_test),
                "n_test": ds.n_test,
                "n_train": ds.n_train,
            },
            f,
        )

    models = {
        "ovr": T.train_ovr(ds.x_train, ds.y_train, ds.n_classes),
        "ovo": T.train_ovo(ds.x_train, ds.y_train, ds.n_classes),
    }

    for strat in STRATEGIES:
        fm = models[strat]
        float_acc = T.accuracy(T.predict_float(fm, ds.x_test), ds.y_test)
        for bits in BITS:
            qm = Q.quantize_model(fm, bits)
            t0 = time.time()
            pred_q = Q.predict_int(qm, x_q_test)
            acc_q = T.accuracy(pred_q, ds.y_test)
            # cross-check the L2 graph (pallas kernel) against the numpy spec
            pred_l2, scores_l2 = M.predict_np(qm, x_q_test)
            assert np.array_equal(pred_l2, pred_q), (
                f"L2/pallas vs numpy-int mismatch for {ds.name}/{strat}/w{bits}"
            )
            scores_spec = Q.scores_int(qm, x_q_test).astype(np.int64)
            assert np.array_equal(scores_l2.astype(np.int64), scores_spec)

            key = f"{ds.name}_{strat}_w{bits}"
            metrics[key] = {
                "dataset": ds.name,
                "strategy": strat,
                "bits": bits,
                "accuracy": acc_q,
                "accuracy_float": float_acc,
                "n_classifiers": qm.n_classifiers,
                "n_features": qm.n_features,
                "n_classes": qm.n_classes,
            }

            (out / "weights").mkdir(exist_ok=True)
            with open(out / "weights" / f"{key}.json", "w") as f:
                json.dump(
                    {
                        "dataset": ds.name,
                        "strategy": strat,
                        "bits": bits,
                        "n_classes": qm.n_classes,
                        "n_features": qm.n_features,
                        "n_classifiers": qm.n_classifiers,
                        "weights": _jsonable(qm.weights),
                        "biases": _jsonable(qm.biases),
                        "pairs": [list(p) for p in qm.pairs],
                        "scale": qm.scale,
                    },
                    f,
                )

            n_g = min(N_GOLDEN, x_q_test.shape[0])
            gx = x_q_test[:n_g]
            g_scores = Q.scores_int(qm, gx)
            g_pred = Q.predict_int(qm, gx)
            (out / "golden").mkdir(exist_ok=True)
            with open(out / "golden" / f"{key}.json", "w") as f:
                json.dump(
                    {
                        "config": key,
                        "x_q": _jsonable(gx),
                        "scores": _jsonable(g_scores),
                        "pred": _jsonable(g_pred),
                        "y_true": _jsonable(ds.y_test[:n_g]),
                    },
                    f,
                )

            hlo_files = {}
            (out / "hlo").mkdir(exist_ok=True)
            for batch in BATCH_SIZES:
                hlo = M.lower_to_hlo_text(qm, batch)
                rel = f"hlo/{key}_b{batch}.hlo.txt"
                with open(out / rel, "w") as f:
                    f.write(hlo)
                hlo_files[str(batch)] = rel

            manifest["configs"][key] = {
                "dataset": ds.name,
                "strategy": strat,
                "bits": bits,
                "n_classes": qm.n_classes,
                "n_features": qm.n_features,
                "n_classifiers": qm.n_classifiers,
                "weights": f"weights/{key}.json",
                "golden": f"golden/{key}.json",
                "hlo": hlo_files,
                "accuracy": acc_q,
            }
            print(
                f"  {key}: acc={acc_q:.3f} (float {float_acc:.3f}) "
                f"K={qm.n_classifiers} F={qm.n_features}  [{time.time()-t0:.1f}s]"
            )

    for kernel in KERNELS:
        for strat in STRATEGIES:
            for bits in BITS:
                build_kernel_config(
                    ds, kernel, strat, bits, x_q_train, x_q_test, out, manifest,
                    metrics,
                )


def build_kernel_config(
    ds: D.Dataset,
    kernel: str,
    strat: str,
    bits: int,
    x_q_train: np.ndarray,
    x_q_test: np.ndarray,
    out: pathlib.Path,
    manifest: dict,
    metrics: dict,
):
    """Train, quantize, cross-check, and emit one kernel-machine config.

    Kernel configs have no HLO graphs (the PJRT backend is linear-only);
    the Rust side serves them on the native/sim path, where the KSVM CFU
    keeps them bit-exact against these golden vectors.
    """
    from .kernels import kernel_pe as KP

    t0 = time.time()
    qm = Q.fit_kernel_machine(
        kernel, x_q_train, ds.y_train, ds.n_classes, strat, bits
    )
    pred_q = Q.predict_int(qm, x_q_test)
    acc_q = T.accuracy(pred_q, ds.y_test)
    # cross-check the L1 pallas kernel PE against the numpy spec
    scores_pe = np.asarray(KP.qm_pe_scores(qm, x_q_test)).astype(np.int64)
    scores_spec = Q.scores_int(qm, x_q_test).astype(np.int64)
    key = f"{ds.name}_{kernel}_{strat}_w{bits}"
    assert np.array_equal(scores_pe, scores_spec), (
        f"L1/pallas vs numpy-int mismatch for {key}"
    )

    metrics[key] = {
        "dataset": ds.name,
        "strategy": strat,
        "bits": bits,
        "kernel": kernel,
        "accuracy": acc_q,
        "n_classifiers": qm.n_classifiers,
        "n_features": qm.n_features,
        "n_support": qm.n_support,
        "n_classes": qm.n_classes,
    }

    (out / "weights").mkdir(exist_ok=True)
    with open(out / "weights" / f"{key}.json", "w") as f:
        json.dump(
            {
                "dataset": ds.name,
                "strategy": strat,
                "bits": bits,
                "kernel": kernel,
                "n_classes": qm.n_classes,
                "n_features": qm.n_features,
                "n_classifiers": qm.n_classifiers,
                "weights": _jsonable(qm.weights),
                "biases": _jsonable(qm.biases),
                "pairs": [list(p) for p in qm.pairs],
                "scale": qm.scale,
                "support": _jsonable(qm.support),
                "g2_q": qm.g2_q,
                "gamma_q": qm.gamma_q,
                "coef0_q": qm.coef0_q,
                "degree": qm.degree,
            },
            f,
        )

    n_g = min(N_GOLDEN, x_q_test.shape[0])
    gx = x_q_test[:n_g]
    (out / "golden").mkdir(exist_ok=True)
    with open(out / "golden" / f"{key}.json", "w") as f:
        json.dump(
            {
                "config": key,
                "x_q": _jsonable(gx),
                "scores": _jsonable(Q.scores_int(qm, gx)),
                "pred": _jsonable(Q.predict_int(qm, gx)),
                "y_true": _jsonable(ds.y_test[:n_g]),
            },
            f,
        )

    manifest["configs"][key] = {
        "dataset": ds.name,
        "strategy": strat,
        "bits": bits,
        "kernel": kernel,
        "n_classes": qm.n_classes,
        "n_features": qm.n_features,
        "n_classifiers": qm.n_classifiers,
        "weights": f"weights/{key}.json",
        "golden": f"golden/{key}.json",
        "hlo": {},
        "accuracy": acc_q,
    }
    print(
        f"  {key}: acc={acc_q:.3f} K={qm.n_classifiers} "
        f"S={qm.n_support} F={qm.n_features}  [{time.time()-t0:.1f}s]"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--datasets", nargs="*", default=list(D.DATASET_NAMES))
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    manifest = {
        "version": 1,
        "batch_sizes": list(BATCH_SIZES),
        "datasets": {},
        "configs": {},
    }
    metrics: dict = {}
    t0 = time.time()
    for name in args.datasets:
        ds = D.load(name)
        print(f"[{name}] n={ds.n_train}+{ds.n_test} F={ds.n_features} C={ds.n_classes}")
        manifest["datasets"][name] = {
            "file": f"datasets/{name}.json",
            "n_classes": ds.n_classes,
            "n_features": ds.n_features,
            "n_test": ds.n_test,
        }
        build_dataset_artifacts(ds, out, manifest, metrics)

    with open(out / "metrics.json", "w") as f:
        json.dump(metrics, f, indent=1)
    with open(out / "manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts complete in {time.time()-t0:.1f}s -> {out}")


if __name__ == "__main__":
    main()
