"""Post-training uniform quantization (paper §V-A and §IV-A).

The accelerator consumes:
  * 4-bit **unsigned** input features (paper: "4-bit unsigned input
    features ... since such low bitwidth is typically sufficient").
    Features are already normalised to [0, 1], so x_q = round(x * 15).
  * {4, 8, 16}-bit **signed** weights and biases, uniformly quantized.

Scale convention
----------------
One symmetric scale per (model, bitwidth), shared by every classifier of
the model and by the biases.  Sharing across classifiers is REQUIRED for
OvR: the hardware argmax (max_sum/max_id registers) compares raw integer
sums across classifiers, which is only meaningful if they share a scale.

    qmax  = 2^(bits-1) - 1
    s_w   = qmax / max(|W|_inf, |b|_inf)
    w_q   = clip(round(w * s_w), -qmax, qmax)      (never -2^(b-1): keeps
                                                    magnitudes in b-1 bits,
                                                    matching the sign-
                                                    magnitude PE datapath)
    b_q   = clip(round(b * s_w), -qmax, qmax)

Bias handling (paper: "The bias is treated as an input with its own
weight for scaling"): the integer score is

    score_int = sum_f x_q[f] * w_q[f]  +  XMAX * b_q,   XMAX = 15

i.e. the bias rides through the PE as one extra (input=15, weight=b_q)
pair, so score_int ≈ 15 * s_w * (x·w + b) — a positive monotone map of
the float score, preserving both the OvR argmax and the OvO sign.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .train import SvmModel, train_ovo, train_ovr

XMAX = 15  # 4-bit unsigned input full-scale; also the bias "input"
SUPPORTED_BITS = (4, 8, 16)

# ---------------------------------------------------------------------------
# kernel fixed-point spec (ISSUE 8): every constant here has a bit-exact
# twin in rust/src/kernel/mod.rs — change both or neither
# ---------------------------------------------------------------------------

SUPPORTED_KERNELS = ("linear", "rbf", "poly")

KFRAC = 8                 # fractional bits of the kernel feature map phi
KSCALE = 1 << KFRAC       # phi full scale; also the kernel bias "input"
GSHIFT = 12               # fractional bits of the quantized gamma constants
LUTB = 5                  # log2(EXP2_LUT entries)
KCLAMP = 1 << 10          # poly feature-map clamp: keeps every product i32
XMAX2 = XMAX * XMAX       # 225 — the integer full-scale of x·sv and |x-sv|²

# EXP2_LUT[i] = round(KSCALE * 2^(-i/32)): one 2^-x period, KFRAC-scaled.
# Hardcoded (not computed) so the Rust twin is textually identical; the
# formula is pinned by test_kernel_quantize.py.
EXP2_LUT = np.array(
    [256, 251, 245, 240, 235, 230, 225, 220, 215, 211, 206, 202, 197, 193,
     189, 185, 181, 177, 173, 170, 166, 162, 159, 156, 152, 149, 146, 143,
     140, 137, 134, 131],
    dtype=np.int64,
)


@dataclasses.dataclass
class QuantModel:
    """A quantized multi-class SVM, bit-exact spec for all lower layers.

    ``kernel == "linear"``: ``weights`` is [K, F] and scores follow the
    paper's integer law.  ``kernel in ("rbf", "poly")``: the model is a
    *kernel machine* — ``support`` holds S quantized support vectors
    [S, F], ``weights`` is [K, S] (dual coefficients over the integer
    feature map ``phi_int``), and the bias rides as ``KSCALE * b_q``.
    """

    strategy: str
    n_classes: int
    bits: int
    weights: np.ndarray  # linear: [K, F]; kernel: [K, S] — int32 in [-qmax, qmax]
    biases: np.ndarray   # [K]    int32
    pairs: list[tuple[int, int]]
    scale: float         # s_w — kept for de-quantization / reporting
    kernel: str = "linear"
    support: np.ndarray | None = None  # [S, F] int32 values 0..15 (kernel only)
    g2_q: int = 0        # rbf:  round(gamma * log2(e) * 2^GSHIFT / 225)
    gamma_q: int = 0     # poly: round(gamma * 2^(KFRAC+GSHIFT) / 225)
    coef0_q: int = 0     # poly: round(coef0 * KSCALE)
    degree: int = 0      # poly: exponent (>= 1)

    @property
    def n_classifiers(self) -> int:
        return int(self.weights.shape[0])

    @property
    def n_features(self) -> int:
        if self.kernel != "linear":
            return int(self.support.shape[1])
        return int(self.weights.shape[1])

    @property
    def n_support(self) -> int:
        return 0 if self.support is None else int(self.support.shape[0])

    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1


def quantize_inputs(x: np.ndarray) -> np.ndarray:
    """[0,1] floats -> 4-bit unsigned ints (int32 storage)."""
    return np.clip(np.round(x * XMAX), 0, XMAX).astype(np.int32)


def quantize_model(model: SvmModel, bits: int) -> QuantModel:
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {bits}")
    qmax = (1 << (bits - 1)) - 1
    full = max(
        float(np.max(np.abs(model.weights))),
        float(np.max(np.abs(model.biases))),
        1e-12,
    )
    s_w = qmax / full
    w_q = np.clip(np.round(model.weights * s_w), -qmax, qmax).astype(np.int32)
    b_q = np.clip(np.round(model.biases * s_w), -qmax, qmax).astype(np.int32)
    return QuantModel(
        strategy=model.strategy,
        n_classes=model.n_classes,
        bits=bits,
        weights=w_q,
        biases=b_q,
        pairs=list(model.pairs),
        scale=s_w,
    )


# ---------------------------------------------------------------------------
# integer kernel feature map (numpy int64; jnp oracle in kernels/ref.py)
# ---------------------------------------------------------------------------


def rbf_phi_int(x_q: np.ndarray, sv_q: np.ndarray, g2_q: int) -> np.ndarray:
    """phi[n, s] = KSCALE * 2^-(g2_q * |x_n - sv_s|² / 2^GSHIFT), by LUT.

    All-integer: squared distance, a GSHIFT-fixed-point exponent, then a
    32-entry 2^-x table indexed by the exponent's fraction and shifted by
    its integer part.  Exponents with integer part >= 31 underflow to 0.
    """
    x = x_q.astype(np.int64)
    sv = sv_q.astype(np.int64)
    d2 = np.sum((x[:, None, :] - sv[None, :, :]) ** 2, axis=-1)  # [N, S]
    z = np.int64(g2_q) * d2
    zi = z >> GSHIFT
    zf = (z >> (GSHIFT - LUTB)) & ((1 << LUTB) - 1)
    return np.where(zi >= 31, 0, EXP2_LUT[zf] >> np.minimum(zi, 62))


def poly_phi_int(
    x_q: np.ndarray, sv_q: np.ndarray, gamma_q: int, coef0_q: int, degree: int
) -> np.ndarray:
    """phi[n, s] = clamp((gamma_q·(x_n·sv_s) >> GSHIFT) + coef0_q)^degree,
    every product taken in KFRAC fixed point and clamped to ±KCLAMP —
    the clamp is part of the feature-map definition (training sees it),
    and bounds every intermediate inside int32."""
    x = x_q.astype(np.int64)
    sv = sv_q.astype(np.int64)
    d = x @ sv.T  # [N, S]
    t = np.clip((np.int64(gamma_q) * d >> GSHIFT) + coef0_q, -KCLAMP, KCLAMP)
    p = t.copy()
    for _ in range(degree - 1):
        p = np.clip(p * t >> KFRAC, -KCLAMP, KCLAMP)
    return p


def phi_int(qm: QuantModel, x_q: np.ndarray) -> np.ndarray:
    """The integer kernel feature map [N, S] of a kernel QuantModel."""
    if qm.kernel == "rbf":
        return rbf_phi_int(x_q, qm.support, qm.g2_q)
    if qm.kernel == "poly":
        return poly_phi_int(x_q, qm.support, qm.gamma_q, qm.coef0_q, qm.degree)
    raise ValueError(f"phi_int is for kernel machines, not {qm.kernel!r}")


# ---------------------------------------------------------------------------
# integer reference inference (numpy; the jnp oracle lives in kernels/ref.py)
# ---------------------------------------------------------------------------


def scores_int(qm: QuantModel, x_q: np.ndarray) -> np.ndarray:
    """Integer classifier scores [N, K]; the spec every layer must match.

    Kernel machines are linear machines over ``phi_int``: the dual
    coefficients dot the feature map and the bias rides as an
    (input = KSCALE, weight = b_q) pair."""
    if qm.kernel != "linear":
        return phi_int(qm, x_q) @ qm.weights.T.astype(np.int64) + KSCALE * qm.biases.astype(
            np.int64
        )
    return x_q.astype(np.int64) @ qm.weights.T.astype(np.int64) + XMAX * qm.biases.astype(
        np.int64
    )


def predict_int(qm: QuantModel, x_q: np.ndarray) -> np.ndarray:
    """Integer predictions; ties resolved to the FIRST maximum (this is
    what the hardware's strictly-greater max_sum update does, and what
    jnp.argmax does — all layers must agree)."""
    s = scores_int(qm, x_q)
    if qm.strategy == "ovr":
        return np.argmax(s, axis=1).astype(np.int32)
    votes = np.zeros((x_q.shape[0], qm.n_classes), dtype=np.int32)
    for k, (i, j) in enumerate(qm.pairs):
        pos = s[:, k] >= 0
        votes[pos, i] += 1
        votes[~pos, j] += 1
    return np.argmax(votes, axis=1).astype(np.int32)


# ---------------------------------------------------------------------------
# kernel-machine fitting: constants, support selection, train-on-phi
# ---------------------------------------------------------------------------


def quantize_kernel_constants(
    kernel: str, n_features: int, gamma: float, coef0: float = 1.0, degree: int = 3
) -> dict:
    """Quantize the kernel hyper-parameters and validate i32 headroom.

    The gamma constants fold in the 1/225 input rescale (x_q = 15·x, so
    x·sv = 225·(x̂·ŝv) and |x_q-sv_q|² = 225·|x̂-ŝv|²)."""
    if gamma <= 0.0:
        raise ValueError(f"gamma must be positive, got {gamma}")
    if kernel == "rbf":
        g2_q = int(round(gamma * np.log2(np.e) * (1 << GSHIFT) / XMAX2))
        if g2_q <= 0:
            raise ValueError(f"gamma {gamma} quantizes to a zero exponent constant")
        if g2_q * n_features * XMAX2 >= 1 << 31:
            raise ValueError(f"rbf exponent overflows i32: g2_q={g2_q} F={n_features}")
        return {"g2_q": g2_q}
    if kernel == "poly":
        if degree < 1:
            raise ValueError(f"degree must be >= 1, got {degree}")
        gamma_q = int(round(gamma * (1 << (KFRAC + GSHIFT)) / XMAX2))
        coef0_q = int(round(coef0 * KSCALE))
        if gamma_q <= 0:
            raise ValueError(f"gamma {gamma} quantizes to zero")
        if gamma_q * n_features * XMAX2 >= 1 << 31:
            raise ValueError(f"poly gamma overflows i32: gamma_q={gamma_q} F={n_features}")
        if abs(coef0_q) > KCLAMP:
            raise ValueError(f"coef0 {coef0} exceeds the ±{KCLAMP} clamp")
        return {"gamma_q": gamma_q, "coef0_q": coef0_q, "degree": int(degree)}
    raise ValueError(f"unknown kernel {kernel!r} (want rbf or poly)")


def select_support(
    x_q: np.ndarray, y: np.ndarray, n_support: int, seed: int = 0
) -> np.ndarray:
    """Stratified anchor selection: round-robin the classes, random
    without replacement inside each (deterministic under ``seed``)."""
    rng = np.random.default_rng(seed)
    by_class = [rng.permutation(np.flatnonzero(y == c)) for c in np.unique(y)]
    picked: list[int] = []
    depth = 0
    while len(picked) < min(n_support, x_q.shape[0]):
        took = False
        for idxs in by_class:
            if depth < len(idxs) and len(picked) < n_support:
                picked.append(int(idxs[depth]))
                took = True
        if not took:
            break
        depth += 1
    return x_q[np.sort(np.asarray(picked, dtype=np.int64))].astype(np.int32)


def validate_kernel_accumulator(bits: int, n_support: int) -> None:
    """The score accumulator Σ_s α·phi + KSCALE·b must stay inside i32 —
    that is what lets the jnp oracle run int32 and the CFU run a 32-bit
    adder, like the linear PE."""
    qmax = (1 << (bits - 1)) - 1
    if n_support * qmax * KCLAMP + KSCALE * qmax >= 1 << 31:
        raise ValueError(
            f"S={n_support} at {bits}-bit overflows the i32 score accumulator"
        )


def fit_kernel_machine(
    kernel: str,
    x_q: np.ndarray,
    y: np.ndarray,
    n_classes: int,
    strategy: str,
    bits: int,
    *,
    gamma: float | None = None,
    coef0: float = 1.0,
    degree: int = 3,
    n_support: int = 32,
    seed: int = 0,
    c_reg: float = 5.0,
    steps: int = 4000,
) -> QuantModel:
    """Train + quantize a kernel machine end to end.

    The trick that keeps every layer bit-exact: support vectors and
    kernel constants are quantized FIRST, the training features are the
    *hardware's own* integer feature map (``phi_int / KSCALE``), and the
    dual coefficients are then quantized exactly like linear weights.
    Training therefore absorbs every fixed-point artifact (LUT steps,
    clamping) instead of being approximated by them.
    """
    f = int(x_q.shape[1])
    if gamma is None:
        gamma = (2.0 if kernel == "rbf" else 1.0) / f
    consts = quantize_kernel_constants(kernel, f, gamma, coef0, degree)
    support = select_support(x_q, y, n_support, seed)
    validate_kernel_accumulator(bits, support.shape[0])
    probe = dataclasses.replace(
        _KPROBE, kernel=kernel, support=support, **consts
    )
    phi = phi_int(probe, x_q).astype(np.float64) / KSCALE  # [N, S]
    train = train_ovr if strategy == "ovr" else train_ovo
    fm = train(phi, y, n_classes, c_reg=c_reg, steps=steps)
    qm = quantize_model(fm, bits)
    return dataclasses.replace(qm, kernel=kernel, support=support, **consts)


# A template QuantModel for phi evaluation before training exists (only
# the kernel fields are ever read through it).
_KPROBE = QuantModel(
    strategy="ovr",
    n_classes=2,
    bits=4,
    weights=np.zeros((1, 1), np.int32),
    biases=np.zeros(1, np.int32),
    pairs=[(0, 0)],
    scale=1.0,
)
