"""Post-training uniform quantization (paper §V-A and §IV-A).

The accelerator consumes:
  * 4-bit **unsigned** input features (paper: "4-bit unsigned input
    features ... since such low bitwidth is typically sufficient").
    Features are already normalised to [0, 1], so x_q = round(x * 15).
  * {4, 8, 16}-bit **signed** weights and biases, uniformly quantized.

Scale convention
----------------
One symmetric scale per (model, bitwidth), shared by every classifier of
the model and by the biases.  Sharing across classifiers is REQUIRED for
OvR: the hardware argmax (max_sum/max_id registers) compares raw integer
sums across classifiers, which is only meaningful if they share a scale.

    qmax  = 2^(bits-1) - 1
    s_w   = qmax / max(|W|_inf, |b|_inf)
    w_q   = clip(round(w * s_w), -qmax, qmax)      (never -2^(b-1): keeps
                                                    magnitudes in b-1 bits,
                                                    matching the sign-
                                                    magnitude PE datapath)
    b_q   = clip(round(b * s_w), -qmax, qmax)

Bias handling (paper: "The bias is treated as an input with its own
weight for scaling"): the integer score is

    score_int = sum_f x_q[f] * w_q[f]  +  XMAX * b_q,   XMAX = 15

i.e. the bias rides through the PE as one extra (input=15, weight=b_q)
pair, so score_int ≈ 15 * s_w * (x·w + b) — a positive monotone map of
the float score, preserving both the OvR argmax and the OvO sign.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .train import SvmModel

XMAX = 15  # 4-bit unsigned input full-scale; also the bias "input"
SUPPORTED_BITS = (4, 8, 16)


@dataclasses.dataclass
class QuantModel:
    """A quantized multi-class SVM, bit-exact spec for all lower layers."""

    strategy: str
    n_classes: int
    bits: int
    weights: np.ndarray  # [K, F] int32, values in [-qmax, qmax]
    biases: np.ndarray   # [K]    int32
    pairs: list[tuple[int, int]]
    scale: float         # s_w — kept for de-quantization / reporting

    @property
    def n_classifiers(self) -> int:
        return int(self.weights.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.weights.shape[1])

    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1


def quantize_inputs(x: np.ndarray) -> np.ndarray:
    """[0,1] floats -> 4-bit unsigned ints (int32 storage)."""
    return np.clip(np.round(x * XMAX), 0, XMAX).astype(np.int32)


def quantize_model(model: SvmModel, bits: int) -> QuantModel:
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {bits}")
    qmax = (1 << (bits - 1)) - 1
    full = max(
        float(np.max(np.abs(model.weights))),
        float(np.max(np.abs(model.biases))),
        1e-12,
    )
    s_w = qmax / full
    w_q = np.clip(np.round(model.weights * s_w), -qmax, qmax).astype(np.int32)
    b_q = np.clip(np.round(model.biases * s_w), -qmax, qmax).astype(np.int32)
    return QuantModel(
        strategy=model.strategy,
        n_classes=model.n_classes,
        bits=bits,
        weights=w_q,
        biases=b_q,
        pairs=list(model.pairs),
        scale=s_w,
    )


# ---------------------------------------------------------------------------
# integer reference inference (numpy; the jnp oracle lives in kernels/ref.py)
# ---------------------------------------------------------------------------


def scores_int(qm: QuantModel, x_q: np.ndarray) -> np.ndarray:
    """Integer classifier scores [N, K]; the spec every layer must match."""
    return x_q.astype(np.int64) @ qm.weights.T.astype(np.int64) + XMAX * qm.biases.astype(
        np.int64
    )


def predict_int(qm: QuantModel, x_q: np.ndarray) -> np.ndarray:
    """Integer predictions; ties resolved to the FIRST maximum (this is
    what the hardware's strictly-greater max_sum update does, and what
    jnp.argmax does — all layers must agree)."""
    s = scores_int(qm, x_q)
    if qm.strategy == "ovr":
        return np.argmax(s, axis=1).astype(np.int32)
    votes = np.zeros((x_q.shape[0], qm.n_classes), dtype=np.int32)
    for k, (i, j) in enumerate(qm.pairs):
        pos = s[:, k] >= 0
        votes[pos, i] += 1
        votes[~pos, j] += 1
    return np.argmax(votes, axis=1).astype(np.int32)
