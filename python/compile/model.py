"""Layer-2 JAX model: quantized multi-class SVM inference graphs.

One jitted graph per (dataset, strategy, bits) configuration.  The graph
consumes a batch of 4-bit-quantized feature vectors (stored int32) and
returns integer predictions plus raw integer scores; classifier weights
are baked in as constants (they are what the accelerator would hold in
its weight stream), so the AOT artifact is fully self-contained and the
Rust hot path only ships activations.

The dot-product hot-spot is the Layer-1 Pallas PE kernel
(kernels/svm_pe.py); the OvR argmax uses the fused kernel variant, the
OvO vote tally is cheap jnp glue that XLA fuses around it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .quantize import QuantModel
from .kernels import svm_pe
from .kernels.ref import ovo_votes_ref


def _pairs_arrays(qm: QuantModel) -> tuple[jnp.ndarray, jnp.ndarray]:
    pi = jnp.asarray(np.array([p[0] for p in qm.pairs], np.int32))
    pj = jnp.asarray(np.array([p[1] for p in qm.pairs], np.int32))
    return pi, pj


def build_predict_fn(qm: QuantModel):
    """Returns fn(x_q [B,F] i32) -> (pred [B] i32, scores [B,K] i32)."""
    w_q = jnp.asarray(qm.weights, jnp.int32)
    b_q = jnp.asarray(qm.biases, jnp.int32)
    bits = qm.bits

    if qm.strategy == "ovr":

        def predict(x_q):
            scores, ids = svm_pe.pe_scores_argmax(x_q, w_q, b_q, bits=bits)
            return ids, scores

        return predict

    pi, pj = _pairs_arrays(qm)
    n_classes = qm.n_classes

    def predict(x_q):
        scores = svm_pe.pe_scores(x_q, w_q, b_q, bits=bits)
        votes = ovo_votes_ref(scores, pi, pj, n_classes)
        return jnp.argmax(votes, axis=1).astype(jnp.int32), scores

    return predict


def predict_np(qm: QuantModel, x_q: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Convenience eager wrapper (used by tests and metric computation)."""
    fn = build_predict_fn(qm)
    pred, scores = fn(jnp.asarray(x_q, jnp.int32))
    return np.asarray(pred), np.asarray(scores)


# ---------------------------------------------------------------------------
# AOT lowering (HLO text — see aot.py for why text, not serialized proto)
# ---------------------------------------------------------------------------


def lower_to_hlo_text(qm: QuantModel, batch: int) -> str:
    """Lower the inference graph at a fixed batch size to HLO text.

    The lowered computation has ONE parameter (x_q i32[batch, F]) and
    returns a tuple (pred i32[batch], scores i32[batch, K]) — the Rust
    runtime unwraps the tuple.
    """
    from jax._src.lib import xla_client as xc

    predict = build_predict_fn(qm)
    spec = jax.ShapeDtypeStruct((batch, qm.n_features), jnp.int32)
    lowered = jax.jit(predict).lower(spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is LOAD-BEARING: the default printer
    # elides big literals as `constant({...})`, and xla_extension 0.5.1's
    # text parser silently materialises those as iota garbage — the
    # baked-in classifier weights would be destroyed.  (Found the hard
    # way; see rust/tests/runtime_pjrt.rs which pins bit-exactness.)
    return comp.as_hlo_text(print_large_constants=True)
