//! Coordinator integration: routing, batching, metrics, and backend
//! equivalence over the real artifacts.  Requires `make artifacts`.

use std::time::Duration;

use flexsvm::coordinator::{Backend, Server, ServerOpts};
use flexsvm::svm::model::artifacts_root;
use flexsvm::svm::{infer, Manifest};

fn native_opts() -> ServerOpts {
    ServerOpts { backend: Backend::Native, linger: Duration::from_micros(200), ..Default::default() }
}

#[test]
fn native_backend_serves_correct_predictions() {
    let manifest = Manifest::load(&artifacts_root()).unwrap();
    let keys = vec!["iris_ovr_w4".to_string(), "v3_ovo_w8".to_string()];
    let server = Server::start(artifacts_root(), keys.clone(), native_opts()).unwrap();
    let client = server.client();
    for key in &keys {
        let entry = manifest.config(key).unwrap();
        let model = manifest.model(entry).unwrap();
        let test = manifest.test_set(&entry.dataset).unwrap();
        for x in test.x_q.iter().take(20) {
            let resp = client.infer(key, x).unwrap();
            assert_eq!(resp.pred, infer::predict(&model, x), "{key}");
        }
    }
}

#[test]
fn pjrt_and_native_backends_agree() {
    let manifest = Manifest::load(&artifacts_root()).unwrap();
    let keys = vec!["seeds_ovo_w16".to_string()];
    let pjrt = Server::start(
        artifacts_root(),
        keys.clone(),
        ServerOpts { backend: Backend::Pjrt, ..native_opts() },
    )
    .unwrap();
    let native = Server::start(artifacts_root(), keys.clone(), native_opts()).unwrap();
    let test = manifest.test_set("seeds").unwrap();
    let (pc, nc) = (pjrt.client(), native.client());
    for x in test.x_q.iter().take(30) {
        let a = pc.infer("seeds_ovo_w16", x).unwrap();
        let b = nc.infer("seeds_ovo_w16", x).unwrap();
        assert_eq!(a.pred, b.pred);
    }
}

#[test]
fn batching_aggregates_concurrent_requests() {
    let manifest = Manifest::load(&artifacts_root()).unwrap();
    let key = "bs_ovr_w4".to_string();
    let server = Server::start(
        artifacts_root(),
        vec![key.clone()],
        ServerOpts {
            backend: Backend::Native,
            batch_max: 16,
            linger: Duration::from_millis(5),
            ..Default::default()
        },
    )
    .unwrap();
    let client = server.client();
    let test = manifest.test_set("bs").unwrap();
    let n = 64usize;
    std::thread::scope(|s| {
        for w in 0..8 {
            let client = client.clone();
            let key = key.clone();
            let xs = &test.x_q;
            s.spawn(move || {
                for i in 0..n / 8 {
                    let x = &xs[(w * 13 + i) % xs.len()];
                    client.infer(&key, x).unwrap();
                }
            });
        }
    });
    let m = client.metrics().unwrap();
    let cm = &m[&key];
    assert_eq!(cm.requests, n as u64);
    assert!(
        cm.batches < n as u64,
        "expected batching: {} batches for {} requests",
        cm.batches,
        n
    );
    assert!(cm.mean_batch() > 1.0);
    let h = cm.latency.as_ref().unwrap();
    assert_eq!(h.count(), n as u64);
}

#[test]
fn unknown_config_is_rejected_per_request() {
    let server =
        Server::start(artifacts_root(), vec!["iris_ovr_w4".to_string()], native_opts()).unwrap();
    let client = server.client();
    let err = client.infer("nope_ovr_w4", &[0, 0, 0, 0]).unwrap_err();
    assert!(err.to_string().contains("not served"), "{err}");
    // server still healthy afterwards
    let ok = client.infer("iris_ovr_w4", &[5, 5, 5, 5]);
    assert!(ok.is_ok());
}

#[test]
fn server_start_fails_fast_on_bad_config() {
    let err = Server::start(artifacts_root(), vec!["bogus".to_string()], native_opts());
    assert!(err.is_err());
}

#[test]
fn linger_flush_answers_single_requests() {
    // a lone request must not wait forever for batchmates
    let server = Server::start(
        artifacts_root(),
        vec!["iris_ovr_w4".to_string()],
        ServerOpts {
            backend: Backend::Native,
            batch_max: 64,
            linger: Duration::from_millis(1),
            ..Default::default()
        },
    )
    .unwrap();
    let client = server.client();
    let t0 = std::time::Instant::now();
    let resp = client.infer("iris_ovr_w4", &[1, 2, 3, 4]).unwrap();
    assert!(t0.elapsed() < Duration::from_secs(1));
    assert_eq!(resp.batch_size, 1);
}
