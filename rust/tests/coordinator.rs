//! Coordinator integration: routing, batching, metrics, and backend
//! equivalence over the pluggable engine API.
//!
//! The `MockEngine` cases exercise batching, linger/eager flush,
//! backpressure and per-sample failure isolation with no artifacts and
//! no SoC simulation; Native/Accel cases serve in-memory models;
//! artifact-backed cases skip when `make artifacts` has not run.

use std::time::{Duration, Instant};

use flexsvm::coordinator::{Backend, Server, ServeError};
use flexsvm::engine::SimCost;
use flexsvm::farm::FarmOpts;
use flexsvm::manifest_or_return;
use flexsvm::obs::{Stage, TraceId};
use flexsvm::serv::TimingConfig;
use flexsvm::svm::infer;
use flexsvm::svm::model::{artifacts_root, QuantModel};
use flexsvm::testing::{gen, MockEngine};

/// Accel farm opts tuned for tests: tiny models, ideal memory, no
/// baseline calibration (covered separately), bounded farm queues.
fn test_farm() -> FarmOpts {
    FarmOpts {
        shards: 2,
        timing: TimingConfig::ideal_mem(),
        calibrate_baseline: false,
        ..Default::default()
    }
}

fn tiny_model(key: &str, flip: bool) -> (String, QuantModel) {
    (key.to_string(), gen::tiny_model(key, flip))
}

// ----------------------------------------------------------- mock engine

#[test]
fn mock_engine_serves_and_batches_without_artifacts() {
    // eager flush: co-arriving requests batch together and nobody
    // waits out the (deliberately huge) linger
    let engine = MockEngine::new().with_delays(vec![Duration::from_millis(20)]);
    let log = engine.batch_log();
    let server = Server::builder()
        .keys(["m"])
        .engine(Box::new(engine))
        .batch_max(64)
        .linger(Duration::from_secs(10))
        .start()
        .unwrap();
    let client = server.client();

    let t0 = Instant::now();
    let n = 16;
    let handles: Vec<_> = (0..n).map(|i| client.submit("m", &[i, 0]).unwrap()).collect();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait().unwrap();
        assert_eq!(resp.pred, i as i32, "mock predicts x[0]");
    }
    assert!(t0.elapsed() < Duration::from_secs(5), "eager flush must beat the 10s linger");

    let sizes = log.lock().unwrap().clone();
    assert_eq!(sizes.iter().sum::<usize>(), n as usize, "every sample executed");
    assert!(sizes.len() < n as usize, "expected batching: {sizes:?}");
    server.shutdown().unwrap();
}

#[test]
fn mock_linger_flushes_queued_requests_together() {
    // eager flush off: requests queue until the oldest exceeds the
    // linger, then flush as one batch
    let engine = MockEngine::new();
    let server = Server::builder()
        .keys(["m"])
        .engine(Box::new(engine))
        .batch_max(64)
        .linger(Duration::from_millis(300))
        .eager_flush(false)
        .start()
        .unwrap();
    let client = server.client();

    let t0 = Instant::now();
    let handles: Vec<_> = (0..4).map(|i| client.submit("m", &[i, 0]).unwrap()).collect();
    for h in handles {
        let resp = h.wait().unwrap();
        assert_eq!(resp.batch_size, 4, "all four queued requests share the linger flush");
    }
    let elapsed = t0.elapsed();
    assert!(elapsed >= Duration::from_millis(150), "must wait out the linger, took {elapsed:?}");
    assert!(elapsed < Duration::from_secs(5));
    server.shutdown().unwrap();
}

#[test]
fn mock_per_sample_failures_do_not_poison_batchmates() {
    let engine = MockEngine::new()
        .fail_when_first_feature_is(13)
        .with_delays(vec![Duration::from_millis(30)]);
    let log = engine.batch_log();
    let server = Server::builder()
        .keys(["m"])
        .engine(Box::new(engine))
        .linger(Duration::from_millis(5))
        .start()
        .unwrap();
    let client = server.client();

    // occupy the engine so the next three requests share a batch
    let warmup = client.submit("m", &[5, 0]).unwrap();
    let outs = client.infer_many("m", &[vec![1, 0], vec![13, 0], vec![2, 0]]).unwrap();
    assert_eq!(outs[0].as_ref().unwrap().pred, 1);
    assert!(matches!(&outs[1], Err(ServeError::Engine(_))), "marked sample fails alone");
    assert_eq!(outs[2].as_ref().unwrap().pred, 2);
    warmup.wait().unwrap();

    let sizes = log.lock().unwrap().clone();
    assert!(sizes.iter().any(|&s| s >= 2), "failure isolation exercised inside a real batch: {sizes:?}");
    server.shutdown().unwrap();
}

#[test]
fn mock_backpressure_floods_without_loss() {
    // tight ingress queue + slow engine: submission blocks rather than
    // drops, and every request gets an answer
    let engine = MockEngine::new().with_delays(vec![Duration::from_millis(2)]);
    let server = Server::builder()
        .keys(["m"])
        .engine(Box::new(engine))
        .queue_cap(4)
        .batch_max(2)
        .linger(Duration::from_micros(200))
        .start()
        .unwrap();
    let client = server.client();
    let n_threads = 8;
    let per_thread = 8;
    std::thread::scope(|s| {
        for w in 0..n_threads {
            let client = client.clone();
            s.spawn(move || {
                for i in 0..per_thread {
                    let x = vec![((w + i) % 16) as i32, 0];
                    let resp = client.infer("m", &x).unwrap();
                    assert_eq!(resp.pred, x[0]);
                }
            });
        }
    });
    let metrics = client.metrics().unwrap();
    let m = &metrics["m"];
    assert_eq!(m.requests, (n_threads * per_thread) as u64, "no request lost under backpressure");
    assert_eq!(m.latency.as_ref().unwrap().count(), m.requests);
    server.shutdown().unwrap();
}

#[test]
fn mock_sim_cost_flows_through_generic_metrics_path() {
    // sim accounting is engine-generic, not farm-only
    let engine = MockEngine::new().with_sim(SimCost { cycles: 1_000, energy_mj: 0.25 });
    let server = Server::builder().keys(["m"]).engine(Box::new(engine)).start().unwrap();
    let client = server.client();
    for i in 0..4 {
        let resp = client.infer("m", &[i, 0]).unwrap();
        let sim = resp.sim.expect("scripted sim cost reaches the response");
        assert_eq!(sim.cycles, 1_000);
    }
    let metrics = client.metrics().unwrap();
    let m = &metrics["m"];
    assert_eq!(m.sim_samples, 4);
    assert_eq!(m.sim_cycles, 4_000);
    assert!((m.energy_mj - 1.0).abs() < 1e-12);
    let em = client.engine_metrics().unwrap();
    assert_eq!(em.engine, "mock");
    assert!(em.farm.is_none());
    server.shutdown().unwrap();
}

#[test]
fn dispatcher_panic_surfaces_in_shutdown() {
    let engine = MockEngine::new().panic_when_first_feature_is(7);
    let server = Server::builder().keys(["m"]).engine(Box::new(engine)).start().unwrap();
    let client = server.client();
    client.infer("m", &[1, 0]).unwrap();
    let err = client.infer("m", &[7, 0]).unwrap_err();
    assert_eq!(err, ServeError::Dropped, "panicked dispatcher drops the request");
    let err = server.shutdown().unwrap_err();
    assert!(err.to_string().contains("scripted panic"), "panic payload surfaced: {err:#}");
}

#[test]
fn clean_shutdown_returns_ok_then_clients_see_server_down() {
    let server = Server::builder().keys(["m"]).engine(Box::new(MockEngine::new())).start().unwrap();
    let client = server.client();
    client.infer("m", &[3, 0]).unwrap();
    server.shutdown().unwrap();
    let err = client.infer("m", &[3, 0]).unwrap_err();
    assert_eq!(err, ServeError::ServerDown);
}

#[test]
fn submit_returns_nonblocking_pending_handles() {
    let server = Server::builder().keys(["m"]).engine(Box::new(MockEngine::new())).start().unwrap();
    let client = server.client();
    let a = client.submit("m", &[1, 0]).unwrap();
    let b = client.submit("m", &[2, 0]).unwrap();
    // redeem out of submission order — the handles are independent
    assert_eq!(b.wait().unwrap().pred, 2);
    assert_eq!(a.wait().unwrap().pred, 1);
    // try_wait sees an answered request without blocking
    let mut c = client.submit("m", &[3, 0]).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match c.try_wait() {
            Some(r) => {
                assert_eq!(r.unwrap().pred, 3);
                break;
            }
            None => {
                assert!(Instant::now() < deadline, "answer never arrived");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    // the handle is spent: polling again is None, not a phantom error
    assert!(c.try_wait().is_none());
}

#[test]
fn builder_rejects_bad_configurations() {
    assert!(Server::builder().start().is_err(), "no model source");
    assert!(Server::builder().models(vec![]).start().is_err(), "no models");
    assert!(Server::builder().keys(Vec::<String>::new()).start().is_err(), "no keys");
    assert!(
        Server::builder().keys(["m"]).engine(Box::new(MockEngine::new())).batch_max(0).start().is_err(),
        "batch_max 0"
    );
    assert!(
        Server::builder()
            .models(vec![tiny_model("dup", false), tiny_model("dup", true)])
            .start()
            .is_err(),
        "duplicate keys"
    );
    #[cfg(not(feature = "pjrt"))]
    assert!(
        Server::builder().models(vec![tiny_model("m", false)]).backend(Backend::Pjrt).start().is_err(),
        "pjrt backend without the pjrt feature"
    );
}

// (Backend FromStr/Display round-trips are unit-tested in
// rust/src/engine/mod.rs.)

// ---------------------------------------------------------------- accel farm

#[test]
fn accel_backend_matches_native_inference_and_reports_energy() {
    let models = vec![tiny_model("cfg_a", false), tiny_model("cfg_b", true)];
    let server = Server::builder()
        .models(models.clone())
        .backend(Backend::Accel)
        .linger(Duration::from_micros(200))
        .farm(test_farm())
        .start()
        .unwrap();
    let client = server.client();
    let xs: Vec<Vec<i32>> = vec![vec![15, 0, 3], vec![0, 15, 9], vec![7, 7, 7], vec![2, 11, 0]];
    for (key, model) in &models {
        for x in &xs {
            let resp = client.infer(key, x).unwrap();
            assert_eq!(resp.pred, infer::predict(model, x), "{key} {x:?}");
            let sim = resp.sim.expect("accel responses carry sim cost");
            assert!(sim.cycles > 0);
            assert!(sim.energy_mj > 0.0);
        }
    }
    let metrics = client.metrics().unwrap();
    for (key, _) in &models {
        let m = &metrics[key];
        assert_eq!(m.requests, xs.len() as u64);
        assert_eq!(m.sim_samples, xs.len() as u64);
        assert!(m.sim_cycles > 0);
        assert!(m.energy_mj > 0.0);
        assert_eq!(m.accel_speedup(), 0.0, "calibration disabled");
    }
    let em = client.engine_metrics().unwrap();
    assert_eq!(em.engine, "accel");
    let farm = em.farm.expect("accel engine exposes farm metrics");
    assert_eq!(farm.shards.len(), 2);
    assert_eq!(farm.total_jobs(), (models.len() * xs.len()) as u64);
}

#[test]
fn accel_baseline_calibration_yields_speedup_ratio() {
    let server = Server::builder()
        .models(vec![tiny_model("cal", false)])
        .backend(Backend::Accel)
        .linger(Duration::from_micros(200))
        .farm(FarmOpts { calibrate_baseline: true, ..test_farm() })
        .start()
        .unwrap();
    let client = server.client();
    for _ in 0..3 {
        client.infer("cal", &[9, 2, 4]).unwrap();
    }
    let metrics = client.metrics().unwrap();
    let m = &metrics["cal"];
    assert!(m.baseline_cycles_per_inf > 0.0);
    // software mul32 loops make the baseline strictly slower even on a
    // tiny model — the ratio is Table I's speedup measured while serving
    assert!(m.accel_speedup() > 1.0, "speedup {}", m.accel_speedup());
}

#[test]
fn accel_farm_backpressure_floods_without_loss() {
    // tight queues everywhere: ingress 8, per-shard 2 — submission
    // blocks rather than drops, and every request gets an answer
    let models = vec![tiny_model("hot", false), tiny_model("cold", true)];
    let server = Server::builder()
        .models(models.clone())
        .backend(Backend::Accel)
        .queue_cap(8)
        .batch_max(4)
        .linger(Duration::from_micros(200))
        .farm(FarmOpts { queue_cap: 2, spill_threshold: 1, ..test_farm() })
        .start()
        .unwrap();
    let client = server.client();
    let n_threads = 8;
    let per_thread = 16;
    std::thread::scope(|s| {
        for w in 0..n_threads {
            let client = client.clone();
            let models = &models;
            s.spawn(move || {
                for i in 0..per_thread {
                    // skew 3:1 toward "hot" to exercise the spill path
                    let key = if (w + i) % 4 == 0 { &models[1].0 } else { &models[0].0 };
                    let x = vec![(i % 16) as i32, (w % 16) as i32, 5];
                    client.infer(key, &x).unwrap();
                }
            });
        }
    });
    let metrics = client.metrics().unwrap();
    let total: u64 = metrics.values().map(|m| m.requests).sum();
    assert_eq!(total, (n_threads * per_thread) as u64, "no request lost under backpressure");
    let answered: u64 = metrics.values().map(|m| m.sim_samples).sum();
    assert_eq!(answered, total);
}

#[test]
fn accel_bad_request_fails_alone_not_its_batchmates() {
    // a request with out-of-range features must error without failing
    // valid requests that share its batch
    let server = Server::builder()
        .models(vec![tiny_model("mix", false)])
        .backend(Backend::Accel)
        .linger(Duration::from_millis(5))
        .farm(test_farm())
        .start()
        .unwrap();
    let client = server.client();
    std::thread::scope(|s| {
        let good = s.spawn(|| client.infer("mix", &[1, 2, 3]));
        let bad = s.spawn(|| client.infer("mix", &[99, 0, 0]));
        assert!(good.join().unwrap().is_ok(), "valid batchmate must succeed");
        assert!(bad.join().unwrap().is_err(), "invalid features must error");
    });
}

#[test]
fn accel_clean_shutdown_then_rejects_new_requests() {
    let server = Server::builder()
        .models(vec![tiny_model("s", false)])
        .backend(Backend::Accel)
        .linger(Duration::from_micros(200))
        .farm(test_farm())
        .start()
        .unwrap();
    let client = server.client();
    client.infer("s", &[1, 2, 3]).unwrap();
    // shutdown joins the dispatcher, which drops (and joins) the farm
    server.shutdown().unwrap();
    let err = client.infer("s", &[1, 2, 3]).unwrap_err();
    assert_eq!(err, ServeError::ServerDown);
}

// ----------------------------------------------------------- observability

#[test]
fn traced_requests_carry_spans_with_consistent_stage_timings() {
    let server = Server::builder()
        .models(vec![tiny_model("tr", false)])
        .backend(Backend::Accel)
        .linger(Duration::from_micros(200))
        .farm(test_farm())
        .start()
        .unwrap();
    let client = server.client();

    // plain traffic: a trace id is minted, but the response carries no
    // span tree (no assembly cost on the default path)
    let plain = client.infer("tr", &[1, 2, 3]).unwrap();
    assert!(plain.span.is_none(), "plain traffic pays no span assembly");

    let t = TraceId::parse("00000000abad1dea").unwrap();
    let resp = client.submit_traced("tr", &[4, 5, 6], t).unwrap().wait().unwrap();
    assert_eq!(resp.trace, t);
    let span = resp.span.expect("explicitly-traced responses carry the span tree");
    assert_eq!(span.trace, t);
    assert_eq!(span.config, "tr");

    // stage decomposition: coordinator stages always present, farm
    // stages present on the accel path, and the parts never exceed
    // the measured whole
    for stage in [Stage::QueueWait, Stage::BatchLinger, Stage::Dispatch, Stage::Execute] {
        assert!(span.stages.get(stage).is_some(), "{} missing: {:?}", stage.name(), span.stages);
    }
    assert!(
        span.stages.sum_us() <= span.total_us,
        "stage sum {} exceeds end-to-end total {}",
        span.stages.sum_us(),
        span.total_us
    );
    assert_eq!(span.total_us, resp.latency.as_micros() as u64);
    assert_eq!(span.mode.as_deref(), Some("sim"));
    assert!(span.cycles.unwrap() > 0, "sim cycles attributed to the span");
    assert!(span.energy_mj.unwrap() > 0.0, "energy attributed to the span");

    // every request (traced or not) lands in the stage histograms
    let obs = client.obs();
    assert_eq!(obs.observed(), 2);
    let snap = obs.stage_snapshot();
    assert_eq!(snap["tr"].get(Stage::Execute).unwrap().count(), 2);
    assert_eq!(snap["tr"].get(Stage::QueueWait).unwrap().count(), 2);
    server.shutdown().unwrap();
}

// ------------------------------------------------------- artifact-backed

#[test]
fn native_backend_serves_correct_predictions() {
    let manifest = manifest_or_return!("native_backend_serves_correct_predictions");
    let keys = vec!["iris_ovr_w4".to_string(), "v3_ovo_w8".to_string()];
    let server = Server::builder()
        .artifacts(artifacts_root(), keys.clone())
        .linger(Duration::from_micros(200))
        .start()
        .unwrap();
    let client = server.client();
    for key in &keys {
        let entry = manifest.config(key).unwrap();
        let model = manifest.model(entry).unwrap();
        let test = manifest.test_set(&entry.dataset).unwrap();
        for x in test.x_q.iter().take(20) {
            let resp = client.infer(key, x).unwrap();
            assert_eq!(resp.pred, infer::predict(&model, x), "{key}");
            assert!(resp.sim.is_none(), "native responses carry no sim cost");
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_backends_agree() {
    let manifest = manifest_or_return!("pjrt_and_native_backends_agree");
    let keys = vec!["seeds_ovo_w16".to_string()];
    let pjrt = Server::builder()
        .artifacts(artifacts_root(), keys.clone())
        .backend(Backend::Pjrt)
        .linger(Duration::from_micros(200))
        .start()
        .unwrap();
    let native = Server::builder()
        .artifacts(artifacts_root(), keys.clone())
        .linger(Duration::from_micros(200))
        .start()
        .unwrap();
    let test = manifest.test_set("seeds").unwrap();
    let (pc, nc) = (pjrt.client(), native.client());
    for x in test.x_q.iter().take(30) {
        let a = pc.infer("seeds_ovo_w16", x).unwrap();
        let b = nc.infer("seeds_ovo_w16", x).unwrap();
        assert_eq!(a.pred, b.pred);
    }
}

#[test]
fn batching_aggregates_concurrent_requests() {
    let manifest = manifest_or_return!("batching_aggregates_concurrent_requests");
    let key = "bs_ovr_w4".to_string();
    let server = Server::builder()
        .artifacts(artifacts_root(), [key.clone()])
        .batch_max(16)
        .linger(Duration::from_millis(5))
        .start()
        .unwrap();
    let client = server.client();
    let test = manifest.test_set("bs").unwrap();
    let n = 64usize;
    std::thread::scope(|s| {
        for w in 0..8 {
            let client = client.clone();
            let key = key.clone();
            let xs = &test.x_q;
            s.spawn(move || {
                for i in 0..n / 8 {
                    let x = &xs[(w * 13 + i) % xs.len()];
                    client.infer(&key, x).unwrap();
                }
            });
        }
    });
    let m = client.metrics().unwrap();
    let cm = &m[&key];
    assert_eq!(cm.requests, n as u64);
    assert!(
        cm.batches < n as u64,
        "expected batching: {} batches for {} requests",
        cm.batches,
        n
    );
    assert!(cm.mean_batch() > 1.0);
    let h = cm.latency.as_ref().unwrap();
    assert_eq!(h.count(), n as u64);
}

#[test]
fn unknown_config_is_rejected_per_request() {
    let server = Server::builder()
        .models(vec![tiny_model("known", false)])
        .linger(Duration::from_micros(200))
        .start()
        .unwrap();
    let client = server.client();
    let err = client.infer("nope_ovr_w4", &[0, 0, 0]).unwrap_err();
    assert_eq!(err, ServeError::UnknownConfig("nope_ovr_w4".to_string()));
    assert!(err.to_string().contains("not served"), "{err}");
    // server still healthy afterwards
    let ok = client.infer("known", &[5, 5, 5]);
    assert!(ok.is_ok());
}

#[test]
fn server_start_fails_fast_on_bad_config() {
    let _ = manifest_or_return!("server_start_fails_fast_on_bad_config");
    let err = Server::builder().artifacts(artifacts_root(), ["bogus"]).start();
    assert!(err.is_err());
}

#[test]
fn linger_flush_answers_single_requests() {
    // a lone request must not wait forever for batchmates
    let server = Server::builder()
        .models(vec![tiny_model("lone", false)])
        .batch_max(64)
        .linger(Duration::from_millis(1))
        .start()
        .unwrap();
    let client = server.client();
    let t0 = std::time::Instant::now();
    let resp = client.infer("lone", &[1, 2, 3]).unwrap();
    assert!(t0.elapsed() < Duration::from_secs(1));
    assert_eq!(resp.batch_size, 1);
}
