//! Coordinator integration: routing, batching, metrics, and backend
//! equivalence.  Native/Accel cases serve in-memory models (no
//! artifacts needed); artifact-backed cases skip when `make artifacts`
//! has not run.

use std::time::Duration;

use flexsvm::coordinator::{Backend, Server, ServerOpts};
use flexsvm::farm::FarmOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::manifest_or_return;
use flexsvm::svm::infer;
use flexsvm::svm::model::{artifacts_root, QuantModel};
use flexsvm::testing::gen;

fn native_opts() -> ServerOpts {
    ServerOpts { backend: Backend::Native, linger: Duration::from_micros(200), ..Default::default() }
}

/// Accel opts tuned for tests: tiny models, ideal memory, no baseline
/// calibration (it is covered separately), bounded farm queues.
fn accel_opts() -> ServerOpts {
    ServerOpts {
        backend: Backend::Accel,
        linger: Duration::from_micros(200),
        farm: FarmOpts {
            shards: 2,
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn tiny_model(key: &str, flip: bool) -> (String, QuantModel) {
    (key.to_string(), gen::tiny_model(key, flip))
}

// ---------------------------------------------------------------- accel farm

#[test]
fn accel_backend_matches_native_inference_and_reports_energy() {
    let models = vec![tiny_model("cfg_a", false), tiny_model("cfg_b", true)];
    let server = Server::start_with_models(models.clone(), accel_opts()).unwrap();
    let client = server.client();
    let xs: Vec<Vec<i32>> = vec![vec![15, 0, 3], vec![0, 15, 9], vec![7, 7, 7], vec![2, 11, 0]];
    for (key, model) in &models {
        for x in &xs {
            let resp = client.infer(key, x).unwrap();
            assert_eq!(resp.pred, infer::predict(model, x), "{key} {x:?}");
            let sim = resp.sim.expect("accel responses carry sim cost");
            assert!(sim.cycles > 0);
            assert!(sim.energy_mj > 0.0);
        }
    }
    let metrics = client.metrics().unwrap();
    for (key, _) in &models {
        let m = &metrics[key];
        assert_eq!(m.requests, xs.len() as u64);
        assert_eq!(m.sim_samples, xs.len() as u64);
        assert!(m.sim_cycles > 0);
        assert!(m.energy_mj > 0.0);
        assert_eq!(m.accel_speedup(), 0.0, "calibration disabled");
    }
    let farm = client.farm_metrics().unwrap().expect("accel backend exposes farm metrics");
    assert_eq!(farm.shards.len(), 2);
    assert_eq!(farm.total_jobs(), (models.len() * xs.len()) as u64);
}

#[test]
fn accel_baseline_calibration_yields_speedup_ratio() {
    let opts = ServerOpts {
        farm: FarmOpts { calibrate_baseline: true, ..accel_opts().farm },
        ..accel_opts()
    };
    let server = Server::start_with_models(vec![tiny_model("cal", false)], opts).unwrap();
    let client = server.client();
    for _ in 0..3 {
        client.infer("cal", &[9, 2, 4]).unwrap();
    }
    let metrics = client.metrics().unwrap();
    let m = &metrics["cal"];
    assert!(m.baseline_cycles_per_inf > 0.0);
    // software mul32 loops make the baseline strictly slower even on a
    // tiny model — the ratio is Table I's speedup measured while serving
    assert!(m.accel_speedup() > 1.0, "speedup {}", m.accel_speedup());
}

#[test]
fn accel_farm_backpressure_floods_without_loss() {
    // tight queues everywhere: ingress 8, per-shard 2 — submission
    // blocks rather than drops, and every request gets an answer
    let opts = ServerOpts {
        queue_cap: 8,
        batch_max: 4,
        compiled_batch: 4,
        farm: FarmOpts { queue_cap: 2, spill_threshold: 1, ..accel_opts().farm },
        ..accel_opts()
    };
    let models = vec![tiny_model("hot", false), tiny_model("cold", true)];
    let server = Server::start_with_models(models.clone(), opts).unwrap();
    let client = server.client();
    let n_threads = 8;
    let per_thread = 16;
    std::thread::scope(|s| {
        for w in 0..n_threads {
            let client = client.clone();
            let models = &models;
            s.spawn(move || {
                for i in 0..per_thread {
                    // skew 3:1 toward "hot" to exercise the spill path
                    let key = if (w + i) % 4 == 0 { &models[1].0 } else { &models[0].0 };
                    let x = vec![(i % 16) as i32, (w % 16) as i32, 5];
                    client.infer(key, &x).unwrap();
                }
            });
        }
    });
    let metrics = client.metrics().unwrap();
    let total: u64 = metrics.values().map(|m| m.requests).sum();
    assert_eq!(total, (n_threads * per_thread) as u64, "no request lost under backpressure");
    let answered: u64 = metrics.values().map(|m| m.sim_samples).sum();
    assert_eq!(answered, total);
}

#[test]
fn accel_bad_request_fails_alone_not_its_batchmates() {
    // a request with out-of-range features must error without failing
    // valid requests that share its batch
    let server = Server::start_with_models(
        vec![tiny_model("mix", false)],
        ServerOpts { linger: Duration::from_millis(5), ..accel_opts() },
    )
    .unwrap();
    let client = server.client();
    std::thread::scope(|s| {
        let good = s.spawn(|| client.infer("mix", &[1, 2, 3]));
        let bad = s.spawn(|| client.infer("mix", &[99, 0, 0]));
        assert!(good.join().unwrap().is_ok(), "valid batchmate must succeed");
        assert!(bad.join().unwrap().is_err(), "invalid features must error");
    });
}

#[test]
fn accel_clean_shutdown_then_rejects_new_requests() {
    let server = Server::start_with_models(vec![tiny_model("s", false)], accel_opts()).unwrap();
    let client = server.client();
    client.infer("s", &[1, 2, 3]).unwrap();
    drop(server); // joins dispatcher, which drops (and joins) the farm
    let err = client.infer("s", &[1, 2, 3]).unwrap_err();
    assert!(err.to_string().contains("server is down"), "{err}");
}

#[test]
fn start_with_models_rejects_pjrt_and_empty() {
    let opts = ServerOpts { backend: Backend::Pjrt, ..Default::default() };
    assert!(Server::start_with_models(vec![tiny_model("x", false)], opts).is_err());
    assert!(Server::start_with_models(vec![], native_opts()).is_err());
}

// ------------------------------------------------------- artifact-backed

#[test]
fn native_backend_serves_correct_predictions() {
    let manifest = manifest_or_return!("native_backend_serves_correct_predictions");
    let keys = vec!["iris_ovr_w4".to_string(), "v3_ovo_w8".to_string()];
    let server = Server::start(artifacts_root(), keys.clone(), native_opts()).unwrap();
    let client = server.client();
    for key in &keys {
        let entry = manifest.config(key).unwrap();
        let model = manifest.model(entry).unwrap();
        let test = manifest.test_set(&entry.dataset).unwrap();
        for x in test.x_q.iter().take(20) {
            let resp = client.infer(key, x).unwrap();
            assert_eq!(resp.pred, infer::predict(&model, x), "{key}");
            assert!(resp.sim.is_none(), "native responses carry no sim cost");
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_backends_agree() {
    let manifest = manifest_or_return!("pjrt_and_native_backends_agree");
    let keys = vec!["seeds_ovo_w16".to_string()];
    let pjrt = Server::start(
        artifacts_root(),
        keys.clone(),
        ServerOpts { backend: Backend::Pjrt, ..native_opts() },
    )
    .unwrap();
    let native = Server::start(artifacts_root(), keys.clone(), native_opts()).unwrap();
    let test = manifest.test_set("seeds").unwrap();
    let (pc, nc) = (pjrt.client(), native.client());
    for x in test.x_q.iter().take(30) {
        let a = pc.infer("seeds_ovo_w16", x).unwrap();
        let b = nc.infer("seeds_ovo_w16", x).unwrap();
        assert_eq!(a.pred, b.pred);
    }
}

#[test]
fn batching_aggregates_concurrent_requests() {
    let manifest = manifest_or_return!("batching_aggregates_concurrent_requests");
    let key = "bs_ovr_w4".to_string();
    let server = Server::start(
        artifacts_root(),
        vec![key.clone()],
        ServerOpts {
            backend: Backend::Native,
            batch_max: 16,
            linger: Duration::from_millis(5),
            ..Default::default()
        },
    )
    .unwrap();
    let client = server.client();
    let test = manifest.test_set("bs").unwrap();
    let n = 64usize;
    std::thread::scope(|s| {
        for w in 0..8 {
            let client = client.clone();
            let key = key.clone();
            let xs = &test.x_q;
            s.spawn(move || {
                for i in 0..n / 8 {
                    let x = &xs[(w * 13 + i) % xs.len()];
                    client.infer(&key, x).unwrap();
                }
            });
        }
    });
    let m = client.metrics().unwrap();
    let cm = &m[&key];
    assert_eq!(cm.requests, n as u64);
    assert!(
        cm.batches < n as u64,
        "expected batching: {} batches for {} requests",
        cm.batches,
        n
    );
    assert!(cm.mean_batch() > 1.0);
    let h = cm.latency.as_ref().unwrap();
    assert_eq!(h.count(), n as u64);
}

#[test]
fn unknown_config_is_rejected_per_request() {
    let server =
        Server::start_with_models(vec![tiny_model("known", false)], native_opts()).unwrap();
    let client = server.client();
    let err = client.infer("nope_ovr_w4", &[0, 0, 0]).unwrap_err();
    assert!(err.to_string().contains("not served"), "{err}");
    // server still healthy afterwards
    let ok = client.infer("known", &[5, 5, 5]);
    assert!(ok.is_ok());
}

#[test]
fn server_start_fails_fast_on_bad_config() {
    let _ = manifest_or_return!("server_start_fails_fast_on_bad_config");
    let err = Server::start(artifacts_root(), vec!["bogus".to_string()], native_opts());
    assert!(err.is_err());
}

#[test]
fn linger_flush_answers_single_requests() {
    // a lone request must not wait forever for batchmates
    let server = Server::start_with_models(
        vec![tiny_model("lone", false)],
        ServerOpts {
            backend: Backend::Native,
            batch_max: 64,
            linger: Duration::from_millis(1),
            ..Default::default()
        },
    )
    .unwrap();
    let client = server.client();
    let t0 = std::time::Instant::now();
    let resp = client.infer("lone", &[1, 2, 3]).unwrap();
    assert!(t0.elapsed() < Duration::from_secs(1));
    assert_eq!(resp.batch_size, 1);
}
