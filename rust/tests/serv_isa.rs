//! RV32I conformance mini-suite for the bit-serial SERV core: every
//! instruction class is exercised by a program whose result is checked
//! architecturally (no artifacts needed).

use flexsvm::isa::reg::*;
use flexsvm::isa::Asm;
use flexsvm::serv::TimingConfig;
use flexsvm::soc::Soc;

fn run(a: &Asm) -> u32 {
    let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::ideal_mem());
    soc.run(10_000_000).unwrap().value()
}

fn case(build: impl FnOnce(&mut Asm)) -> u32 {
    let mut a = Asm::new(0);
    build(&mut a);
    a.ecall();
    run(&a)
}

#[test]
fn arithmetic_ops() {
    assert_eq!(case(|a| { a.li(T0, 100); a.li(T1, -58); a.add(A0, T0, T1); }), 42);
    assert_eq!(case(|a| { a.li(T0, 5); a.li(T1, 12); a.sub(A0, T0, T1); }), (-7i32) as u32);
    assert_eq!(case(|a| { a.li(T0, 0x0f0f); a.li(T1, 0x00ff); a.and(A0, T0, T1); }), 0x000f);
    assert_eq!(case(|a| { a.li(T0, 0x0f00); a.li(T1, 0x00f0); a.or(A0, T0, T1); }), 0x0ff0);
    assert_eq!(case(|a| { a.li(T0, -1); a.li(T1, 0x0ff0); a.xor(A0, T0, T1); }), !0x0ff0u32);
}

#[test]
fn compare_ops() {
    // slt/sltu across sign boundary
    assert_eq!(case(|a| { a.li(T0, -1); a.li(T1, 1); a.slt(A0, T0, T1); }), 1);
    assert_eq!(case(|a| { a.li(T0, -1); a.li(T1, 1); a.sltu(A0, T0, T1); }), 0);
    assert_eq!(case(|a| { a.li(T0, i32::MIN); a.li(T1, i32::MAX); a.slt(A0, T0, T1); }), 1);
    assert_eq!(case(|a| { a.slti(A0, ZERO, -5); }), 0);
    assert_eq!(case(|a| { a.slti(A0, ZERO, 5); }), 1);
}

#[test]
fn shift_ops() {
    assert_eq!(case(|a| { a.li(T0, 1); a.slli(A0, T0, 31); }), 0x8000_0000);
    assert_eq!(case(|a| { a.li(T0, -16); a.srai(A0, T0, 2); }), (-4i32) as u32);
    assert_eq!(case(|a| { a.li(T0, -16); a.srli(A0, T0, 28); }), 0xf);
    // register-count shifts use only the low 5 bits of rs2
    assert_eq!(case(|a| { a.li(T0, 4); a.li(T1, 33); a.sll(A0, T0, T1); }), 8);
    assert_eq!(case(|a| { a.li(T0, 0x100); a.li(T1, 4); a.srl(A0, T0, T1); }), 0x10);
    assert_eq!(case(|a| { a.li(T0, i32::MIN); a.li(T1, 31); a.sra(A0, T0, T1); }), u32::MAX);
}

#[test]
fn upper_immediates_and_jumps() {
    assert_eq!(case(|a| { a.lui(A0, 0xabcde << 12); }), 0xabcd_e000);
    // auipc at pc=0 gives the immediate itself
    assert_eq!(case(|a| { a.auipc(A0, 0x1000); }), 0x1000);
    // jal link register: first instruction, so ra = 4
    let v = case(|a| {
        a.jal(RA, "t");
        a.label("t");
        a.mv(A0, RA);
    });
    assert_eq!(v, 4);
    // jalr clears bit 0 of the target
    let mut a = Asm::new(0);
    a.la(T0, "odd_target"); // address of label
    a.addi(T0, T0, 1); // make it odd
    a.jalr(ZERO, T0, 0); // must land on the label anyway
    a.label("odd_target");
    a.li(A0, 77);
    a.ecall();
    assert_eq!(run(&a), 77);
}

#[test]
fn all_branch_conditions() {
    // (builder, rs1, rs2, expect_taken)
    let cases: Vec<(&str, i32, i32, bool)> = vec![
        ("beq", 5, 5, true),
        ("beq", 5, 6, false),
        ("bne", 5, 6, true),
        ("bne", 5, 5, false),
        ("blt", -1, 0, true),
        ("blt", 0, -1, false),
        ("bge", 0, -1, true),
        ("bge", -1, 0, false),
        ("bltu", 1, 2, true),
        ("bltu", 0xffff, 1, false),
        ("bgeu", -1, 1, true), // 0xffffffff >= 1 unsigned
        ("bgeu", 1, -1, false),
    ];
    for (op, x, y, taken) in cases {
        let mut a = Asm::new(0);
        a.li(T0, x);
        a.li(T1, y);
        match op {
            "beq" => a.beq(T0, T1, "yes"),
            "bne" => a.bne(T0, T1, "yes"),
            "blt" => a.blt(T0, T1, "yes"),
            "bge" => a.bge(T0, T1, "yes"),
            "bltu" => a.bltu(T0, T1, "yes"),
            "bgeu" => a.bgeu(T0, T1, "yes"),
            _ => unreachable!(),
        };
        a.li(A0, 0);
        a.ecall();
        a.label("yes");
        a.li(A0, 1);
        a.ecall();
        assert_eq!(run(&a) == 1, taken, "{op} {x} {y}");
    }
}

#[test]
fn memory_access_widths() {
    let mut a = Asm::new(0);
    a.la(S0, "buf");
    a.li(T0, 0x8081_8283u32 as i32);
    a.sw(S0, T0, 0);
    a.lb(A0, S0, 0); // 0x83 sign-extends
    a.lbu(A1, S0, 1); // 0x82
    a.ecall();
    a.label("buf");
    a.zeros(2);
    let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::ideal_mem());
    let r = soc.run(1_000_000).unwrap();
    match r.exit {
        flexsvm::serv::Exit::Ecall { a0, a1 } => {
            assert_eq!(a0, 0xffff_ff83);
            assert_eq!(a1, 0x82);
        }
        e => panic!("{e:?}"),
    }
}

#[test]
fn halfword_sign_extension() {
    // lh sign-extends, lhu zero-extends; sh writes only 16 bits
    let mut a = Asm::new(0);
    a.la(S0, "buf");
    a.li(T0, -1);
    a.sw(S0, T0, 0); // buf = 0xffffffff
    a.li(T0, 0x8000);
    a.sh(S0, T0, 0); // low half = 0x8000, high half still 0xffff
    a.lh(A0, S0, 0); // -32768
    a.lhu(A1, S0, 0); // 0x8000
    a.ecall();
    a.label("buf");
    a.zeros(1);
    let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::ideal_mem());
    let r = soc.run(1_000_000).unwrap();
    match r.exit {
        flexsvm::serv::Exit::Ecall { a0, a1 } => {
            assert_eq!(a0 as i32, -32768);
            assert_eq!(a1, 0x8000);
        }
        e => panic!("{e:?}"),
    }
    // and the untouched high halfword survives the sh
    let mut a2 = Asm::new(0);
    a2.la(S0, "buf");
    a2.li(T0, -1);
    a2.sw(S0, T0, 0);
    a2.li(T0, 0x1234);
    a2.sh(S0, T0, 0);
    a2.lw(A0, S0, 0);
    a2.ecall();
    a2.label("buf");
    a2.zeros(1);
    let mut soc2 = Soc::new(&a2.assemble_bytes().unwrap(), TimingConfig::ideal_mem());
    assert_eq!(soc2.run(1_000_000).unwrap().value(), 0xffff_1234);
}

/// Run one image on both engines and require identical results and
/// cycle accounting (the self-modifying-code differential).
fn block_step_agree(a: &Asm, budget: u64) -> u32 {
    let image = a.assemble_bytes().unwrap();
    let mut blk = Soc::new(&image, TimingConfig::flexic());
    let mut stp = Soc::new(&image, TimingConfig::flexic());
    let rb = blk.run(budget).unwrap();
    let rs = stp.run_traced(budget, None).unwrap();
    assert_eq!(rb.exit, rs.exit, "exit must match the step interpreter");
    assert_eq!(rb.stats, rs.stats, "cycle accounting must match the step interpreter");
    assert_eq!(blk.core.regs, stp.core.regs);
    rb.value()
}

#[test]
fn smc_store_into_text_retranslates_the_block() {
    use flexsvm::isa::encode::encode;
    use flexsvm::isa::{AluOp, Instr};
    // overwrite an upcoming `addi a0,a0,1` with `slli a0,a0,3` — the
    // patched instruction must execute with its new semantics AND its
    // new cycle cost (shift amount adds serial cycles)
    let patch = encode(Instr::OpImm { op: AluOp::Sll, rd: A0, rs1: A0, imm: 3 });
    let mut a = Asm::new(0);
    a.li(A0, 5);
    a.la(T0, "site");
    a.li(T1, patch as i32);
    a.sw(T0, T1, 0);
    a.label("site");
    a.addi(A0, A0, 1); // dead after the patch
    a.ecall();
    assert_eq!(block_step_agree(&a, 1_000_000), 40, "5 << 3, not 5 + 1");
}

#[test]
fn smc_patch_can_change_the_block_shape() {
    use flexsvm::isa::encode::encode;
    use flexsvm::isa::Instr;
    // patch a nop into `j +8`: the patched word turns a straight-line
    // block into a terminator, skipping the poison instruction
    let patch = encode(Instr::Jal { rd: ZERO, offset: 8 });
    let mut a = Asm::new(0);
    a.li(A0, 7);
    a.la(T0, "site");
    a.li(T1, patch as i32);
    a.sw(T0, T1, 0);
    a.label("site");
    a.nop(); // becomes j +8
    a.li(A0, -1); // must be skipped
    a.ecall();
    assert_eq!(block_step_agree(&a, 1_000_000), 7);
}

#[test]
fn smc_loop_over_patched_site_stays_consistent() {
    use flexsvm::isa::encode::encode;
    use flexsvm::isa::{AluOp, Instr};
    // a loop whose body patches its own next iteration: add -> xor
    let patch = encode(Instr::Op { op: AluOp::Xor, rd: A0, rs1: A0, rs2: T2 });
    let mut a = Asm::new(0);
    a.li(A0, 0);
    a.li(T2, 3);
    a.li(T0, 4); // iterations
    a.la(T1, "site");
    a.li(T3, patch as i32);
    a.label("loop");
    a.sw(T1, T3, 0); // every iteration re-stores the patch word
    a.label("site");
    a.add(A0, A0, T2); // patched to xor before its first execution
    a.addi(T0, T0, -1);
    a.bne(T0, ZERO, "loop");
    a.ecall();
    // the site executes as xor on all four passes: 0^3^3^3^3 = 0
    assert_eq!(block_step_agree(&a, 1_000_000), 0, "four self-inverse xors");
}

#[test]
fn smc_from_interpreted_code_invalidates_translations() {
    use flexsvm::isa::encode::encode;
    use flexsvm::isa::{AluOp, Instr, StoreOp};
    // main writes a 2-instruction trampoline into its DATA section
    // (executed via the step-interpreter fallback), and the trampoline
    // stores a patch into translated TEXT: `addi a0,a0,1` -> `addi
    // a0,a0,41`.  The interpreted store must invalidate the block
    // translation just like a block-mode store.
    let patch = encode(Instr::OpImm { op: AluOp::Add, rd: A0, rs1: A0, imm: 41 });
    let tramp_sw = encode(Instr::Store { op: StoreOp::Sw, rs1: T3, rs2: T2, offset: 0 });
    let tramp_ret = encode(Instr::Jalr { rd: ZERO, rs1: RA, offset: 0 });
    let mut a = Asm::new(0);
    a.li(A0, 1);
    a.la(T3, "site");
    a.li(T2, patch as i32);
    a.la(T0, "tramp");
    a.li(T1, tramp_sw as i32);
    a.sw(T0, T1, 0);
    a.li(T1, tramp_ret as i32);
    a.sw(T0, T1, 4);
    a.jalr(RA, T0, 0); // call the freshly written trampoline
    a.label("site");
    a.addi(A0, A0, 1); // patched to addi a0,a0,41 by the trampoline
    a.ecall();
    a.label("tramp");
    a.zeros(2);
    assert_eq!(block_step_agree(&a, 1_000_000), 42, "1 + 41 via the patched site");
}

#[test]
fn stores_into_data_do_not_disturb_the_block_engine() {
    // plain data stores (the mem_loop pattern) must not trigger any
    // re-translation; results and accounting stay identical
    let mut a = Asm::new(0);
    a.la(S0, "buf");
    a.li(T0, 50);
    a.label("loop");
    a.lw(T1, S0, 0);
    a.addi(T1, T1, 7);
    a.sw(S0, T1, 0);
    a.addi(T0, T0, -1);
    a.bne(T0, ZERO, "loop");
    a.lw(A0, S0, 0);
    a.ecall();
    a.label("buf");
    a.zeros(2);
    assert_eq!(block_step_agree(&a, 10_000_000), 350);
}

#[test]
fn bit_serial_timing_costs() {
    // a dependent chain of N adds costs N * (fetch + 32) under ideal mem
    let t = TimingConfig::ideal_mem();
    let mut a = Asm::new(0);
    for _ in 0..10 {
        a.addi(A0, A0, 1);
    }
    a.ecall();
    let mut soc = Soc::new(&a.assemble_bytes().unwrap(), t);
    let r = soc.run(1_000_000).unwrap();
    let per_instr = t.fetch_cost() + 32;
    assert_eq!(r.stats.total(), 11 * per_instr, "10 addi + ecall");
    // shifts cost shamt extra serial cycles
    let mut a2 = Asm::new(0);
    a2.slli(A0, A0, 9);
    a2.ecall();
    let mut soc2 = Soc::new(&a2.assemble_bytes().unwrap(), t);
    let r2 = soc2.run(1_000_000).unwrap();
    assert_eq!(r2.stats.total(), 2 * per_instr + 9);
}
