//! Cross-layer bit-exactness over the real artifacts (DESIGN.md §6):
//! golden JSON (Python spec) ⇔ native Rust ⇔ accelerator emulation
//! (linear PE array or KSVM op stream) ⇔ SERV-executed program — for
//! every one of the 90 configs (linear + RBF + poly families).
//! Requires `make artifacts`; skips when the artifacts are absent.

use flexsvm::accel::pe;
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::svm::{infer, pack};
use flexsvm::manifest_or_return;

#[test]
fn all_configs_native_matches_golden() {
    let m = manifest_or_return!("all_configs_native_matches_golden");
    assert_eq!(
        m.configs.len(),
        90,
        "expected 5 datasets x 18 configs (3 kernels x 2 strategies x 3 bit-widths)"
    );
    for entry in &m.configs {
        let model = m.model(entry).unwrap();
        let golden = m.golden(entry).unwrap();
        for (i, x) in golden.x_q.iter().enumerate() {
            assert_eq!(
                infer::scores(&model, x),
                golden.scores[i],
                "{} sample {i}: native scores vs python spec",
                entry.key
            );
            assert_eq!(infer::predict(&model, x), golden.pred[i], "{} sample {i}", entry.key);
        }
    }
}

#[test]
fn all_configs_pe_emulation_matches_golden() {
    let m = manifest_or_return!("all_configs_pe_emulation_matches_golden");
    for entry in &m.configs {
        let model = m.model(entry).unwrap();
        let golden = m.golden(entry).unwrap();
        if model.is_kernel() {
            // kernel machines: drive the KSVM accelerator's op stream
            for (i, x) in golden.x_q.iter().enumerate() {
                let scores = flexsvm::testing::ksvm_emulate_scores(&model, x).unwrap();
                assert_eq!(scores, golden.scores[i], "{} sample {i}", entry.key);
            }
            continue;
        }
        let mode = pack::mode_for_bits(model.bits);
        for (i, x) in golden.x_q.iter().enumerate() {
            let fw = pack::feature_words(x, model.bits);
            for (k, &expect) in golden.scores[i].iter().enumerate() {
                let ww = pack::weight_words(&model, k);
                let s: i64 = fw.iter().zip(&ww).map(|(&a, &b)| pe::compute(a, b, mode)).sum();
                assert_eq!(s, expect, "{} sample {i} classifier {k}", entry.key);
            }
        }
    }
}

#[test]
fn serv_programs_match_golden_predictions() {
    let m = manifest_or_return!("serv_programs_match_golden_predictions");
    for entry in &m.configs {
        let model = m.model(entry).unwrap();
        let golden = m.golden(entry).unwrap();
        // ideal memory keeps this sweep fast; numerics are timing-free
        let mut acc =
            ProgramRunner::accelerated(&model, TimingConfig::ideal_mem(), ProgramOpts::default())
                .unwrap();
        // kernel machines have no software-only baseline program
        let mut base = if model.is_kernel() {
            None
        } else {
            Some(ProgramRunner::baseline(&model, TimingConfig::ideal_mem()).unwrap())
        };
        for (i, x) in golden.x_q.iter().enumerate().take(8) {
            let (pa, _) = acc.run_sample(x).unwrap();
            assert_eq!(pa, golden.pred[i], "{} accel sample {i}", entry.key);
            if let Some(base) = base.as_mut() {
                let (pb, _) = base.run_sample(x).unwrap();
                assert_eq!(pb, golden.pred[i], "{} baseline sample {i}", entry.key);
            }
        }
    }
}

#[test]
fn accuracy_reproduces_manifest_metrics() {
    let m = manifest_or_return!("accuracy_reproduces_manifest_metrics");
    for entry in &m.configs {
        let model = m.model(entry).unwrap();
        let test = m.test_set(&entry.dataset).unwrap();
        let acc = infer::accuracy(&model, &test);
        assert!(
            (acc - entry.accuracy).abs() < 1e-9,
            "{}: native accuracy {acc} vs build-time {}",
            entry.key,
            entry.accuracy
        );
    }
}

/// Paper claim (§V-B): OvO beats OvR in accuracy on average.
#[test]
fn ovo_accuracy_advantage_on_average() {
    let m = manifest_or_return!("ovo_accuracy_advantage_on_average");
    let mean = |strategy: &str| {
        let rows: Vec<f64> = m
            .configs
            .iter()
            .filter(|c| c.strategy.to_string() == strategy)
            .map(|c| c.accuracy)
            .collect();
        rows.iter().sum::<f64>() / rows.len() as f64
    };
    let (ovr, ovo) = (mean("ovr"), mean("ovo"));
    assert!(
        ovo + 1e-9 >= ovr,
        "expected OvO mean accuracy >= OvR (paper reports +3.4%): ovr={ovr:.3} ovo={ovo:.3}"
    );
}
