//! Integration: PJRT-executed AOT artifacts vs golden vectors and the
//! native Rust inference — the cross-layer bit-exactness anchor
//! (DESIGN.md §6, level 4).  Requires the `pjrt` feature and
//! `make artifacts`; skips silently when the artifacts are absent.
#![cfg(feature = "pjrt")]

use flexsvm::runtime::Engine;
use flexsvm::svm::{infer, Manifest};
use flexsvm::manifest_or_return;

#[test]
fn golden_vectors_match_on_pjrt() {
    let m: Manifest = manifest_or_return!("golden_vectors_match_on_pjrt");
    let mut engine = Engine::new().unwrap();
    // one config per (strategy, bits) — full 30-config sweep happens in
    // the report; keep the test suite fast but representative.
    let keys = [
        "iris_ovr_w4",
        "iris_ovo_w8",
        "bs_ovr_w16",
        "seeds_ovo_w4",
        "v3_ovr_w8",
        "derm_ovo_w16",
    ];
    for key in keys {
        let entry = m.config(key).unwrap();
        let golden = m.golden(entry).unwrap();
        engine.load(&m, entry, 1).unwrap();
        let preds = engine.predict(key, 1, &golden.x_q).unwrap();
        assert_eq!(preds, golden.pred, "{key}: PJRT vs golden predictions");
    }
}

#[test]
fn pjrt_scores_match_native_rust() {
    let m = manifest_or_return!("pjrt_scores_match_native_rust");
    let mut engine = Engine::new().unwrap();
    let entry = m.config("seeds_ovr_w8").unwrap();
    let model = m.model(entry).unwrap();
    let golden = m.golden(entry).unwrap();
    engine.load(&m, entry, 1).unwrap();
    let cfg = engine.get("seeds_ovr_w8", 1).unwrap();
    for (i, x) in golden.x_q.iter().enumerate() {
        let out = cfg.execute(x).unwrap();
        let native = infer::scores(&model, x);
        let pjrt: Vec<i64> = out.scores.iter().map(|&s| s as i64).collect();
        assert_eq!(pjrt, native, "sample {i}");
        assert_eq!(out.preds[0] as i64, golden.pred[i] as i64);
    }
}

#[test]
fn batched_execution_matches_single() {
    let m = manifest_or_return!("batched_execution_matches_single");
    let mut engine = Engine::new().unwrap();
    let entry = m.config("bs_ovo_w4").unwrap();
    let test = m.test_set("bs").unwrap();
    engine.load(&m, entry, 1).unwrap();
    engine.load(&m, entry, 64).unwrap();
    let n = 100.min(test.len());
    let singles = engine.predict("bs_ovo_w4", 1, &test.x_q[..n]).unwrap();
    let batched = engine.predict("bs_ovo_w4", 64, &test.x_q[..n]).unwrap();
    assert_eq!(singles, batched);
}

#[test]
fn accuracy_matches_manifest_metric() {
    let m = manifest_or_return!("accuracy_matches_manifest_metric");
    let mut engine = Engine::new().unwrap();
    for key in ["iris_ovr_w4", "v3_ovo_w16"] {
        let entry = m.config(key).unwrap();
        let test = m.test_set(&entry.dataset).unwrap();
        engine.load(&m, entry, 64).unwrap();
        let preds = engine.predict(key, 64, &test.x_q).unwrap();
        let correct = preds.iter().zip(&test.y).filter(|(p, y)| p == y).count();
        let acc = correct as f64 / test.len() as f64;
        assert!(
            (acc - entry.accuracy).abs() < 1e-9,
            "{key}: PJRT accuracy {acc} vs build-time {}",
            entry.accuracy
        );
    }
}
