//! Property-based invariants across the Rust stack (mini-harness in
//! flexsvm::testing — proptest is unavailable offline).

use flexsvm::accel::pe;
use flexsvm::accel::svm::{result_class_id, result_sign_negative, SvmAccel};
use flexsvm::accel::Cfu;
use flexsvm::farm::{Farm, FarmOpts};
use flexsvm::isa::{decode, encode::encode, svm_ops, CFU_FUNCT7_SVM};
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::svm::model::Strategy;
use flexsvm::svm::{infer, pack};
use flexsvm::testing::{check, gen, ksvm_emulate_scores};

/// Encode→decode is the identity over random well-formed instructions.
#[test]
fn prop_isa_roundtrip() {
    check("isa-roundtrip", 0x150, 2000, |rng| {
        use flexsvm::isa::{AluOp, BranchOp, Instr, LoadOp, StoreOp};
        let rd = rng.below(32) as u8;
        let rs1 = rng.below(32) as u8;
        let rs2 = rng.below(32) as u8;
        let pick = rng.below(8);
        let instr = match pick {
            0 => Instr::Op {
                op: *rng.choose(&[
                    AluOp::Add,
                    AluOp::Sub,
                    AluOp::Xor,
                    AluOp::Or,
                    AluOp::And,
                    AluOp::Slt,
                    AluOp::Sltu,
                    AluOp::Sll,
                    AluOp::Srl,
                    AluOp::Sra,
                ]),
                rd,
                rs1,
                rs2,
            },
            1 => Instr::OpImm {
                op: *rng.choose(&[AluOp::Add, AluOp::Xor, AluOp::Or, AluOp::And, AluOp::Slt]),
                rd,
                rs1,
                imm: rng.range_i32(-2048, 2047),
            },
            2 => Instr::Load {
                op: *rng.choose(&[LoadOp::Lb, LoadOp::Lh, LoadOp::Lw, LoadOp::Lbu, LoadOp::Lhu]),
                rd,
                rs1,
                offset: rng.range_i32(-2048, 2047),
            },
            3 => Instr::Store {
                op: *rng.choose(&[StoreOp::Sb, StoreOp::Sh, StoreOp::Sw]),
                rs1,
                rs2,
                offset: rng.range_i32(-2048, 2047),
            },
            4 => Instr::Branch {
                op: *rng.choose(&[
                    BranchOp::Beq,
                    BranchOp::Bne,
                    BranchOp::Blt,
                    BranchOp::Bge,
                    BranchOp::Bltu,
                    BranchOp::Bgeu,
                ]),
                rs1,
                rs2,
                offset: rng.range_i32(-2048, 2047) * 2,
            },
            5 => Instr::Jal { rd, offset: rng.range_i32(-10000, 10000) * 2 },
            6 => Instr::Lui { rd, imm: rng.range_i32(0, 0xfffff) << 12 },
            _ => Instr::Custom {
                funct7: 1 + rng.below(31) as u8,
                funct3: rng.below(8) as u8,
                rd,
                rs1,
                rs2,
            },
        };
        // funct7 = 0x20 is SERV's sub/sra space, not a CFU slot
        if let Instr::Custom { funct7: 0x20, .. } = instr {
            return;
        }
        assert_eq!(decode(encode(instr)).unwrap(), instr);
    });
}

fn calc_res_f3(bits: u8) -> (u8, u8) {
    match bits {
        4 => (svm_ops::SV_CALC4, svm_ops::SV_RES4),
        8 => (svm_ops::SV_CALC8, svm_ops::SV_RES8),
        _ => (svm_ops::SV_CALC16, svm_ops::SV_RES16),
    }
}

/// The accelerator driven by raw Fig.-8 instruction sequences computes
/// the same prediction as the native integer spec (OvR path).
#[test]
fn prop_accel_ovr_equals_native() {
    check("accel-ovr", 0x151, 300, |rng| {
        let mut m = gen::quant_model(rng);
        // force OvR shape: one classifier per class
        m.strategy = Strategy::Ovr;
        m.weights.truncate(m.n_classes);
        m.biases.truncate(m.n_classes);
        while m.weights.len() < m.n_classes {
            m.weights.push(vec![0; m.n_features]);
            m.biases.push(0);
        }
        m.pairs = (0..m.n_classes).map(|i| (i, i)).collect();
        let x = gen::features(rng, m.n_features);

        let mut accel = SvmAccel::new();
        accel.execute(svm_ops::CREATE_ENV, 0, 0).unwrap();
        let (calc, res) = calc_res_f3(m.bits);
        let fw = pack::feature_words(&x, m.bits);
        let mut last = 0u32;
        for k in 0..m.weights.len() {
            for (a, b) in fw.iter().zip(pack::weight_words(&m, k)) {
                accel.execute(calc, *a, b).unwrap();
            }
            last = accel.execute(res, 0, 0).unwrap().value;
        }
        assert_eq!(result_class_id(last) as i32, infer::predict(&m, &x));
    });
}

/// OvO sign bits from the accelerator match the spec's score signs.
#[test]
fn prop_accel_ovo_signs() {
    check("accel-ovo-signs", 0x152, 300, |rng| {
        let m = gen::quant_model(rng);
        let x = gen::features(rng, m.n_features);
        let spec = infer::scores(&m, &x);
        let mut accel = SvmAccel::new();
        accel.execute(svm_ops::CREATE_ENV, 0, 0).unwrap();
        let (calc, res) = calc_res_f3(m.bits);
        let fw = pack::feature_words(&x, m.bits);
        for (k, &s) in spec.iter().enumerate() {
            for (a, b) in fw.iter().zip(pack::weight_words(&m, k)) {
                accel.execute(calc, *a, b).unwrap();
            }
            let r = accel.execute(res, 0, 0).unwrap().value;
            assert_eq!(result_sign_negative(r), s < 0, "classifier {k} score {s}");
        }
    });
}

/// End-to-end: SERV-executed programs (both variants) match native
/// inference on random models — every backend gives the same answer.
#[test]
fn prop_serv_programs_match_native() {
    check("serv-programs", 0x153, 40, |rng| {
        let m = gen::quant_model(rng);
        let x = gen::features(rng, m.n_features);
        let expect = infer::predict(&m, &x);
        let mut base = ProgramRunner::baseline(&m, TimingConfig::ideal_mem()).unwrap();
        let (bp, _) = base.run_sample(&x).unwrap();
        assert_eq!(bp, expect, "baseline {m:?} x={x:?}");
        let mut acc =
            ProgramRunner::accelerated(&m, TimingConfig::ideal_mem(), ProgramOpts::default())
                .unwrap();
        let (ap, _) = acc.run_sample(&x).unwrap();
        assert_eq!(ap, expect, "accel {m:?} x={x:?}");
    });
}

/// Differential: the sharded SoC farm answers exactly like the native
/// integer spec on random quantized models across all bit-widths
/// (4/8/16) — the full `Backend::Accel` serving path minus the
/// coordinator, with batches fanning out over multiple shards.
#[test]
fn prop_farm_predictions_match_native() {
    check("farm-vs-native", 0x156, 10, |rng| {
        let models: Vec<_> = (0..2)
            .map(|i| {
                let m = gen::quant_model(rng);
                // index prefix keeps keys unique when shapes collide
                (format!("m{i}_{}", m.config_key()), m)
            })
            .collect();
        let farm = Farm::start(
            models.clone(),
            FarmOpts {
                shards: 2,
                timing: TimingConfig::ideal_mem(),
                calibrate_baseline: false,
                ..Default::default()
            },
        )
        .unwrap();
        for (key, m) in &models {
            let xs: Vec<Vec<i32>> = (0..4).map(|_| gen::features(rng, m.n_features)).collect();
            let outs = farm.predict_batch(key, &xs).unwrap();
            for (x, o) in xs.iter().zip(outs) {
                let o = o.unwrap();
                assert_eq!(o.pred, infer::predict(m, x), "{key} bits={} x={x:?}", m.bits);
                assert!(o.cycles > 0, "{key}: simulated cycles must be charged");
                assert!(o.energy_mj > 0.0, "{key}: energy must be charged");
            }
        }
    });
}

/// Random-but-terminating RV32I programs for the block-vs-step
/// differential: straight-line ALU work, aligned loads/stores into a
/// scratch buffer, forward branches, bounded down-counting loops, and
/// calls to a leaf function.
fn random_program(rng: &mut flexsvm::testing::Pcg32) -> flexsvm::isa::Asm {
    use flexsvm::isa::reg::*;
    use flexsvm::isa::Asm;
    // rd pool: never S0 (scratch pointer), SP, RA or T5 (loop counter)
    const RD: [u8; 9] = [T0, T1, T2, T4, A0, A1, A2, A3, S1];
    const RS: [u8; 11] = [T0, T1, T2, T4, A0, A1, A2, A3, S1, ZERO, S0];
    let mut a = Asm::new(0);
    a.la(S0, "buf");
    for r in [T0, T1, T2, T4, A0, A1, A2, A3, S1] {
        a.li(r, rng.range_i32(-1_000_000, 1_000_000));
    }
    let mut label = 0usize;
    let mut fresh = || {
        label += 1;
        format!("l{label}")
    };
    let n_segments = 5 + rng.below(40);
    for _ in 0..n_segments {
        let rd = *rng.choose(&RD);
        let rs1 = *rng.choose(&RS);
        let rs2 = *rng.choose(&RS);
        match rng.below(12) {
            0 => {
                a.add(rd, rs1, rs2);
            }
            1 => {
                a.sub(rd, rs1, rs2);
            }
            2 => match rng.below(5) {
                0 => {
                    a.xor(rd, rs1, rs2);
                }
                1 => {
                    a.or(rd, rs1, rs2);
                }
                2 => {
                    a.and(rd, rs1, rs2);
                }
                3 => {
                    a.slt(rd, rs1, rs2);
                }
                _ => {
                    a.sltu(rd, rs1, rs2);
                }
            },
            3 => {
                // immediate shifts: static shamt cycles
                let sh = rng.below(32) as i32;
                match rng.below(3) {
                    0 => a.slli(rd, rs1, sh),
                    1 => a.srli(rd, rs1, sh),
                    _ => a.srai(rd, rs1, sh),
                };
            }
            4 => {
                // register-count shifts: dynamic shamt cycles
                match rng.below(3) {
                    0 => a.sll(rd, rs1, rs2),
                    1 => a.srl(rd, rs1, rs2),
                    _ => a.sra(rd, rs1, rs2),
                };
            }
            5 => {
                let imm = rng.range_i32(-2048, 2047);
                match rng.below(4) {
                    0 => a.addi(rd, rs1, imm),
                    1 => a.xori(rd, rs1, imm),
                    2 => a.ori(rd, rs1, imm),
                    _ => a.andi(rd, rs1, imm),
                };
            }
            6 => {
                // aligned scratch-buffer store
                match rng.below(3) {
                    0 => a.sw(S0, rs1, (rng.below(16) * 4) as i32),
                    1 => a.sh(S0, rs1, (rng.below(32) * 2) as i32),
                    _ => a.sb(S0, rs1, rng.below(64) as i32),
                };
            }
            7 => {
                match rng.below(5) {
                    0 => a.lw(rd, S0, (rng.below(16) * 4) as i32),
                    1 => a.lh(rd, S0, (rng.below(32) * 2) as i32),
                    2 => a.lhu(rd, S0, (rng.below(32) * 2) as i32),
                    3 => a.lb(rd, S0, rng.below(64) as i32),
                    _ => a.lbu(rd, S0, rng.below(64) as i32),
                };
            }
            8 => {
                // forward branch over a couple of filler ops
                let l = fresh();
                match rng.below(6) {
                    0 => a.beq(rs1, rs2, &l),
                    1 => a.bne(rs1, rs2, &l),
                    2 => a.blt(rs1, rs2, &l),
                    3 => a.bge(rs1, rs2, &l),
                    4 => a.bltu(rs1, rs2, &l),
                    _ => a.bgeu(rs1, rs2, &l),
                };
                a.addi(rd, rd, 1);
                a.xori(rd, rd, 0x2a);
                a.label(&l);
            }
            9 => {
                // bounded down-counting loop
                let l = fresh();
                a.li(T5, 1 + rng.below(5) as i32);
                a.label(&l);
                a.add(rd, rd, rs1);
                a.addi(T5, T5, -1);
                a.bne(T5, ZERO, &l);
            }
            10 => {
                // leaf call (jal/jalr link + return)
                a.call("leaf");
            }
            _ => {
                match rng.below(2) {
                    0 => a.lui(rd, rng.range_i32(0, 0xfffff) << 12),
                    _ => a.auipc(rd, rng.range_i32(0, 0xfff) << 12),
                };
            }
        }
    }
    a.mv(A0, *rng.choose(&RD));
    a.j("end");
    a.label("leaf");
    a.add(A1, A1, A1);
    a.ret();
    a.label("end");
    a.ecall();
    a.label("buf");
    a.zeros(16);
    a
}

/// Tentpole differential: the block-compiled engine and the step
/// interpreter produce identical exit value, registers and *full*
/// `CycleStats` on random programs under randomized SoC timing.
#[test]
fn prop_block_engine_matches_step_interpreter() {
    use flexsvm::soc::Soc;
    check("block-vs-step-programs", 0x157, 120, |rng| {
        let a = random_program(rng);
        let image = a.assemble_bytes().unwrap();
        let mut t = TimingConfig::flexic();
        t.mem_read = 1 + rng.below(80) as u64;
        t.mem_write = 1 + rng.below(80) as u64;
        t.mem_overhead = rng.below(80) as u64;
        t.branch_taken_extra = rng.below(40) as u64;
        t.load_shift_in = rng.below(40) as u64;
        let mut blk = Soc::new(&image, t);
        let mut stp = Soc::new(&image, t);
        let rb = blk.run(50_000_000).unwrap();
        let rs = stp.run_traced(50_000_000, None).unwrap();
        assert_eq!(rb.exit, rs.exit, "exit value");
        assert_eq!(rb.stats, rs.stats, "full CycleStats must be bit-identical");
        assert_eq!(blk.core.regs, stp.core.regs, "architectural registers");
        assert_eq!(blk.core.pc, stp.core.pc);
        assert_eq!(blk.mem.counters, stp.mem.counters, "memory transaction counters");
    });
}

/// The same differential over the real workload: baseline and
/// accelerated inference programs for random quantized models at
/// 4/8/16 bits — prediction and cycle accounting agree between the
/// block engine (`run_sample`) and the step interpreter.
#[test]
fn prop_block_engine_matches_step_on_models() {
    use flexsvm::program::run::DEFAULT_BUDGET;
    check("block-vs-step-models", 0x158, 12, |rng| {
        let m = gen::quant_model(rng);
        let x = gen::features(rng, m.n_features);
        let runners = [
            ProgramRunner::baseline(&m, TimingConfig::flexic()).unwrap(),
            ProgramRunner::accelerated(&m, TimingConfig::flexic(), ProgramOpts::default())
                .unwrap(),
        ];
        for mut runner in runners {
            let (pred, stats) = runner.run_sample(&x).unwrap();
            // step-interpreted reference over the same rearm/poke flow
            runner.soc_mut().rearm();
            runner.poke_features(&x).unwrap();
            let r = runner.soc_mut().run_traced(DEFAULT_BUDGET, None).unwrap();
            assert_eq!(pred, r.value() as i32, "bits={}", m.bits);
            assert_eq!(stats, r.stats, "bits={}: block and step cycle accounting", m.bits);
        }
    });
}

/// PE is linear in the feature vector under every mode.
#[test]
fn prop_pe_linear_in_features() {
    check("pe-linearity", 0x154, 500, |rng| {
        let mode = *rng.choose(&[pe::Mode::W4, pe::Mode::W8, pe::Mode::W16]);
        let lanes = mode.lanes();
        let qmax = (1i32 << (mode.bits() - 1)) - 1;
        let ws: Vec<i32> = (0..lanes).map(|_| rng.range_i32(-qmax, qmax)).collect();
        let x1: Vec<u32> = (0..lanes).map(|_| rng.below(8)).collect();
        let x2: Vec<u32> = (0..lanes).map(|_| rng.below(8)).collect();
        let xs: Vec<u32> = x1.iter().zip(&x2).map(|(a, b)| a + b).collect();
        let w = pe::pack_weights(&ws, mode);
        assert_eq!(
            pe::compute(pe::pack_features(&xs, mode), w, mode),
            pe::compute(pe::pack_features(&x1, mode), w, mode)
                + pe::compute(pe::pack_features(&x2, mode), w, mode)
        );
    });
}

/// CFU timing: a Res instruction (writes rd) costs exactly `cfu_wb` more
/// than a Calc (rd = x0) under any memory timing.
#[test]
fn prop_cfu_writeback_timing() {
    check("cfu-timing", 0x155, 100, |rng| {
        use flexsvm::accel::CfuBank;
        use flexsvm::isa::{reg, Asm};
        use flexsvm::serv::{CycleStats, ServCore};
        use flexsvm::soc::Memory;
        let mut t = TimingConfig::flexic();
        t.mem_read = 1 + rng.below(100) as u64;
        t.mem_overhead = rng.below(100) as u64;

        let run_one = |f3: u8, rd: u8| {
            let mut a = Asm::new(0);
            a.cfu(CFU_FUNCT7_SVM, f3, rd, reg::A1, reg::A2);
            let mut img = a.assemble_bytes().unwrap();
            img.resize(256, 0);
            let mut mem = Memory::with_image(&img, 256);
            let mut core = ServCore::new(0);
            let mut bank = CfuBank::new();
            bank.register(CFU_FUNCT7_SVM, Box::new(SvmAccel::new())).unwrap();
            let mut stats = CycleStats::default();
            core.step(&mut mem, &mut bank, &t, &mut stats).unwrap();
            stats.total()
        };
        let calc = run_one(svm_ops::SV_CALC4, reg::ZERO);
        let res = run_one(svm_ops::SV_RES4, reg::A0);
        assert_eq!(res, calc + t.cfu_wb, "writeback must add exactly cfu_wb");
    });
}

/// ISSUE 6 tentpole invariant: the analytic cost model is bit-exact
/// against the block-compiled SoC — prediction and every `CycleStats`
/// lane — for random quantized models at 4/8/16 bits, both program
/// forms (looped and unrolled) and both memory timings.
#[test]
fn prop_analytic_cost_model_is_bit_exact() {
    use flexsvm::program::cost::AnalyticModel;
    use flexsvm::program::run::CompiledProgram;
    check("analytic-vs-sim", 0x159, 10, |rng| {
        let m = gen::quant_model(rng);
        let timing = *rng.choose(&[TimingConfig::flexic(), TimingConfig::ideal_mem()]);
        let unroll_limit = *rng.choose(&[0usize, 4096]);
        let c = CompiledProgram::accelerated(&m, ProgramOpts { unroll_limit }).unwrap();
        let am = AnalyticModel::derive(&m, &c, timing)
            .expect("derivation must succeed for accelerated programs");
        let mut runner = ProgramRunner::from_compiled(&c, timing).unwrap();
        for _ in 0..4 {
            let x = gen::features(rng, m.n_features);
            let (pred, stats) = am.predict(&x).unwrap();
            let (sim_pred, sim_stats) = runner.run_sample(&x).unwrap();
            assert_eq!(pred, sim_pred, "bits={} {:?}", m.bits, m.strategy);
            assert_eq!(
                stats, sim_stats,
                "bits={} {:?}: analytic bill must be bit-exact",
                m.bits, m.strategy
            );
        }
    });
}

/// Kernel tentpole differential (ISSUE 8): the integer spec
/// (`infer::scores`, the Rust twin of the Python oracle), the KSVM
/// accelerator op stream, and the SERV-executed kernel program produce
/// identical integers on random RBF/poly machines at 4/8/16 bits.
#[test]
fn prop_kernel_oracle_accel_and_serv_agree() {
    check("kernel-three-layers", 0x15b, 40, |rng| {
        let m = gen::kernel_model(rng);
        let x = gen::features(rng, m.n_features);
        let native = infer::scores(&m, &x);
        let emu = ksvm_emulate_scores(&m, &x).unwrap();
        assert_eq!(emu, native, "{} bits={} x={x:?}", m.kernel, m.bits);
        let mut acc =
            ProgramRunner::accelerated(&m, TimingConfig::ideal_mem(), ProgramOpts::default())
                .unwrap();
        let (pred, _) = acc.run_sample(&x).unwrap();
        assert_eq!(pred, infer::predict(&m, &x), "{} bits={} x={x:?}", m.kernel, m.bits);
    });
}

/// The analytic fast path extends to kernel programs: prediction and
/// the full cycle bill are bit-exact against the simulated SoC.
#[test]
fn prop_kernel_analytic_cost_is_bit_exact() {
    use flexsvm::program::cost::AnalyticModel;
    use flexsvm::program::run::CompiledProgram;
    check("kernel-analytic-vs-sim", 0x15c, 10, |rng| {
        let m = gen::kernel_model(rng);
        let timing = *rng.choose(&[TimingConfig::flexic(), TimingConfig::ideal_mem()]);
        let c = CompiledProgram::accelerated(&m, ProgramOpts::default()).unwrap();
        let am = AnalyticModel::derive(&m, &c, timing)
            .expect("derivation must succeed for kernel programs");
        let mut runner = ProgramRunner::from_compiled(&c, timing).unwrap();
        for _ in 0..3 {
            let x = gen::features(rng, m.n_features);
            let (pred, stats) = am.predict(&x).unwrap();
            let (sim_pred, sim_stats) = runner.run_sample(&x).unwrap();
            assert_eq!(pred, sim_pred, "{} bits={}", m.kernel, m.bits);
            assert_eq!(
                stats, sim_stats,
                "{} bits={}: analytic bill must be bit-exact",
                m.kernel, m.bits
            );
        }
    });
}

/// The kernel fast path never returns a wrong answer: a poisoned
/// analytic model on a random RBF/poly config is caught by the first
/// audit, the config demotes to full simulation, and every prediction
/// still matches the native spec.
#[test]
fn prop_kernel_fastpath_audit_never_wrong() {
    use flexsvm::farm::ExecMode;
    check("kernel-audit", 0x15d, 6, |rng| {
        let m = gen::kernel_model(rng);
        let nf = m.n_features;
        let farm = Farm::start(
            vec![("k".to_string(), m.clone())],
            FarmOpts {
                shards: 1,
                timing: TimingConfig::ideal_mem(),
                calibrate_baseline: false,
                fastpath: true,
                audit_rate: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let skew = 1 + rng.below(1000) as u64;
        farm.inject_analytic_skew("k", skew).unwrap();
        for i in 0..6 {
            let x = gen::features(rng, nf);
            let o = farm.predict("k", &x).unwrap();
            assert_eq!(o.pred, infer::predict(&m, &x), "{}: ground truth survives", m.kernel);
            let want = if i == 0 { ExecMode::Audited } else { ExecMode::Sim };
            assert_eq!(o.mode, want, "{} request {i}", m.kernel);
        }
        let f = farm.metrics().fast;
        assert_eq!(f.audits, 1);
        assert_eq!(f.mismatches, 1);
        assert_eq!(f.poisoned_configs, 1);
        assert_eq!(f.fast_jobs, 0);
    });
}

/// A poisoned analytic model must be caught by the differential audit:
/// the config demotes to full simulation and the mismatch surfaces in
/// the farm's metrics — while answers stay correct throughout.
#[test]
fn prop_audit_catches_poisoned_cost_models() {
    use flexsvm::farm::ExecMode;
    check("audit-poison", 0x15a, 6, |rng| {
        let m = gen::quant_model(rng);
        let nf = m.n_features;
        let farm = Farm::start(
            vec![("p".to_string(), m.clone())],
            FarmOpts {
                shards: 1,
                timing: TimingConfig::ideal_mem(),
                calibrate_baseline: false,
                fastpath: true,
                audit_rate: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let skew = 1 + rng.below(1000) as u64;
        farm.inject_analytic_skew("p", skew).unwrap();
        for i in 0..6 {
            let x = gen::features(rng, nf);
            let o = farm.predict("p", &x).unwrap();
            assert_eq!(o.pred, infer::predict(&m, &x), "ground truth survives the fault");
            let want = if i == 0 { ExecMode::Audited } else { ExecMode::Sim };
            assert_eq!(o.mode, want, "request {i}");
        }
        let f = farm.metrics().fast;
        assert_eq!(f.audits, 1);
        assert_eq!(f.mismatches, 1);
        assert_eq!(f.poisoned_configs, 1);
        assert_eq!(f.fast_jobs, 0);
    });
}

/// Conservation of the continuous profiler: over random models (linear
/// and kernel machines, 4/8/16-bit) and random memory timings, the
/// per-block attributed cycles (+ CFU busy) equal `CycleStats::total()`
/// bit-exactly, the profiled run answers bit-identically to the
/// unprofiled one, and every attributed cycle lands in a named codegen
/// region (accel programs carry a complete region map).
#[test]
fn prop_profiler_attribution_conserves_cycles() {
    use flexsvm::obs::{BlockProfiler, ConfigProfile};
    check("profiler-conservation", 0x15e, 12, |rng| {
        let m = if rng.below(2) == 0 { gen::quant_model(rng) } else { gen::kernel_model(rng) };
        let mut t = TimingConfig::flexic();
        t.mem_read = 1 + rng.below(8) as u64;
        t.mem_write = 1 + rng.below(8) as u64;
        t.mem_overhead = rng.below(4) as u64;
        let mut runner =
            ProgramRunner::accelerated(&m, t, ProgramOpts::default()).unwrap();
        let x = gen::features(rng, m.n_features);
        let (pred_ref, stats_ref) = runner.run_sample(&x).unwrap();
        let mut prof = BlockProfiler::new();
        let (pred, stats) = runner.run_sample_profiled(&x, &mut prof).unwrap();
        assert_eq!(pred, pred_ref, "profiling must not change the answer");
        assert_eq!(stats, stats_ref, "profiling must not change the cycle accounting");
        assert_eq!(
            prof.attributed(),
            stats.total(),
            "bits={} kernel={}: attributed == total",
            m.bits,
            m.kernel,
        );
        let mut cp = ConfigProfile::new();
        cp.absorb(&prof, &runner.program().regions);
        assert_eq!(cp.total_cycles, stats.total(), "region aggregation conserves too");
        assert!(
            !cp.regions.contains_key("other"),
            "accel codegen regions must cover every executed block: {:?}",
            cp.regions
        );
    });
}
