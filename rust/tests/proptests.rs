//! Property-based invariants across the Rust stack (mini-harness in
//! flexsvm::testing — proptest is unavailable offline).

use flexsvm::accel::pe;
use flexsvm::accel::svm::{result_class_id, result_sign_negative, SvmAccel};
use flexsvm::accel::Cfu;
use flexsvm::farm::{Farm, FarmOpts};
use flexsvm::isa::{decode, encode::encode, svm_ops, CFU_FUNCT7_SVM};
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::svm::model::Strategy;
use flexsvm::svm::{infer, pack};
use flexsvm::testing::{check, gen};

/// Encode→decode is the identity over random well-formed instructions.
#[test]
fn prop_isa_roundtrip() {
    check("isa-roundtrip", 0x150, 2000, |rng| {
        use flexsvm::isa::{AluOp, BranchOp, Instr, LoadOp, StoreOp};
        let rd = rng.below(32) as u8;
        let rs1 = rng.below(32) as u8;
        let rs2 = rng.below(32) as u8;
        let pick = rng.below(8);
        let instr = match pick {
            0 => Instr::Op {
                op: *rng.choose(&[
                    AluOp::Add,
                    AluOp::Sub,
                    AluOp::Xor,
                    AluOp::Or,
                    AluOp::And,
                    AluOp::Slt,
                    AluOp::Sltu,
                    AluOp::Sll,
                    AluOp::Srl,
                    AluOp::Sra,
                ]),
                rd,
                rs1,
                rs2,
            },
            1 => Instr::OpImm {
                op: *rng.choose(&[AluOp::Add, AluOp::Xor, AluOp::Or, AluOp::And, AluOp::Slt]),
                rd,
                rs1,
                imm: rng.range_i32(-2048, 2047),
            },
            2 => Instr::Load {
                op: *rng.choose(&[LoadOp::Lb, LoadOp::Lh, LoadOp::Lw, LoadOp::Lbu, LoadOp::Lhu]),
                rd,
                rs1,
                offset: rng.range_i32(-2048, 2047),
            },
            3 => Instr::Store {
                op: *rng.choose(&[StoreOp::Sb, StoreOp::Sh, StoreOp::Sw]),
                rs1,
                rs2,
                offset: rng.range_i32(-2048, 2047),
            },
            4 => Instr::Branch {
                op: *rng.choose(&[
                    BranchOp::Beq,
                    BranchOp::Bne,
                    BranchOp::Blt,
                    BranchOp::Bge,
                    BranchOp::Bltu,
                    BranchOp::Bgeu,
                ]),
                rs1,
                rs2,
                offset: rng.range_i32(-2048, 2047) * 2,
            },
            5 => Instr::Jal { rd, offset: rng.range_i32(-10000, 10000) * 2 },
            6 => Instr::Lui { rd, imm: rng.range_i32(0, 0xfffff) << 12 },
            _ => Instr::Custom {
                funct7: 1 + rng.below(31) as u8,
                funct3: rng.below(8) as u8,
                rd,
                rs1,
                rs2,
            },
        };
        // funct7 = 0x20 is SERV's sub/sra space, not a CFU slot
        if let Instr::Custom { funct7: 0x20, .. } = instr {
            return;
        }
        assert_eq!(decode(encode(instr)).unwrap(), instr);
    });
}

fn calc_res_f3(bits: u8) -> (u8, u8) {
    match bits {
        4 => (svm_ops::SV_CALC4, svm_ops::SV_RES4),
        8 => (svm_ops::SV_CALC8, svm_ops::SV_RES8),
        _ => (svm_ops::SV_CALC16, svm_ops::SV_RES16),
    }
}

/// The accelerator driven by raw Fig.-8 instruction sequences computes
/// the same prediction as the native integer spec (OvR path).
#[test]
fn prop_accel_ovr_equals_native() {
    check("accel-ovr", 0x151, 300, |rng| {
        let mut m = gen::quant_model(rng);
        // force OvR shape: one classifier per class
        m.strategy = Strategy::Ovr;
        m.weights.truncate(m.n_classes);
        m.biases.truncate(m.n_classes);
        while m.weights.len() < m.n_classes {
            m.weights.push(vec![0; m.n_features]);
            m.biases.push(0);
        }
        m.pairs = (0..m.n_classes).map(|i| (i, i)).collect();
        let x = gen::features(rng, m.n_features);

        let mut accel = SvmAccel::new();
        accel.execute(svm_ops::CREATE_ENV, 0, 0).unwrap();
        let (calc, res) = calc_res_f3(m.bits);
        let fw = pack::feature_words(&x, m.bits);
        let mut last = 0u32;
        for k in 0..m.weights.len() {
            for (a, b) in fw.iter().zip(pack::weight_words(&m, k)) {
                accel.execute(calc, *a, b).unwrap();
            }
            last = accel.execute(res, 0, 0).unwrap().value;
        }
        assert_eq!(result_class_id(last) as i32, infer::predict(&m, &x));
    });
}

/// OvO sign bits from the accelerator match the spec's score signs.
#[test]
fn prop_accel_ovo_signs() {
    check("accel-ovo-signs", 0x152, 300, |rng| {
        let m = gen::quant_model(rng);
        let x = gen::features(rng, m.n_features);
        let spec = infer::scores(&m, &x);
        let mut accel = SvmAccel::new();
        accel.execute(svm_ops::CREATE_ENV, 0, 0).unwrap();
        let (calc, res) = calc_res_f3(m.bits);
        let fw = pack::feature_words(&x, m.bits);
        for (k, &s) in spec.iter().enumerate() {
            for (a, b) in fw.iter().zip(pack::weight_words(&m, k)) {
                accel.execute(calc, *a, b).unwrap();
            }
            let r = accel.execute(res, 0, 0).unwrap().value;
            assert_eq!(result_sign_negative(r), s < 0, "classifier {k} score {s}");
        }
    });
}

/// End-to-end: SERV-executed programs (both variants) match native
/// inference on random models — every backend gives the same answer.
#[test]
fn prop_serv_programs_match_native() {
    check("serv-programs", 0x153, 40, |rng| {
        let m = gen::quant_model(rng);
        let x = gen::features(rng, m.n_features);
        let expect = infer::predict(&m, &x);
        let mut base = ProgramRunner::baseline(&m, TimingConfig::ideal_mem()).unwrap();
        let (bp, _) = base.run_sample(&x).unwrap();
        assert_eq!(bp, expect, "baseline {m:?} x={x:?}");
        let mut acc =
            ProgramRunner::accelerated(&m, TimingConfig::ideal_mem(), ProgramOpts::default())
                .unwrap();
        let (ap, _) = acc.run_sample(&x).unwrap();
        assert_eq!(ap, expect, "accel {m:?} x={x:?}");
    });
}

/// Differential: the sharded SoC farm answers exactly like the native
/// integer spec on random quantized models across all bit-widths
/// (4/8/16) — the full `Backend::Accel` serving path minus the
/// coordinator, with batches fanning out over multiple shards.
#[test]
fn prop_farm_predictions_match_native() {
    check("farm-vs-native", 0x156, 10, |rng| {
        let models: Vec<_> = (0..2)
            .map(|i| {
                let m = gen::quant_model(rng);
                // index prefix keeps keys unique when shapes collide
                (format!("m{i}_{}", m.config_key()), m)
            })
            .collect();
        let farm = Farm::start(
            models.clone(),
            FarmOpts {
                shards: 2,
                timing: TimingConfig::ideal_mem(),
                calibrate_baseline: false,
                ..Default::default()
            },
        )
        .unwrap();
        for (key, m) in &models {
            let xs: Vec<Vec<i32>> = (0..4).map(|_| gen::features(rng, m.n_features)).collect();
            let outs = farm.predict_batch(key, &xs).unwrap();
            for (x, o) in xs.iter().zip(outs) {
                let o = o.unwrap();
                assert_eq!(o.pred, infer::predict(m, x), "{key} bits={} x={x:?}", m.bits);
                assert!(o.cycles > 0, "{key}: simulated cycles must be charged");
                assert!(o.energy_mj > 0.0, "{key}: energy must be charged");
            }
        }
    });
}

/// PE is linear in the feature vector under every mode.
#[test]
fn prop_pe_linear_in_features() {
    check("pe-linearity", 0x154, 500, |rng| {
        let mode = *rng.choose(&[pe::Mode::W4, pe::Mode::W8, pe::Mode::W16]);
        let lanes = mode.lanes();
        let qmax = (1i32 << (mode.bits() - 1)) - 1;
        let ws: Vec<i32> = (0..lanes).map(|_| rng.range_i32(-qmax, qmax)).collect();
        let x1: Vec<u32> = (0..lanes).map(|_| rng.below(8)).collect();
        let x2: Vec<u32> = (0..lanes).map(|_| rng.below(8)).collect();
        let xs: Vec<u32> = x1.iter().zip(&x2).map(|(a, b)| a + b).collect();
        let w = pe::pack_weights(&ws, mode);
        assert_eq!(
            pe::compute(pe::pack_features(&xs, mode), w, mode),
            pe::compute(pe::pack_features(&x1, mode), w, mode)
                + pe::compute(pe::pack_features(&x2, mode), w, mode)
        );
    });
}

/// CFU timing: a Res instruction (writes rd) costs exactly `cfu_wb` more
/// than a Calc (rd = x0) under any memory timing.
#[test]
fn prop_cfu_writeback_timing() {
    check("cfu-timing", 0x155, 100, |rng| {
        use flexsvm::accel::CfuBank;
        use flexsvm::isa::{reg, Asm};
        use flexsvm::serv::{CycleStats, ServCore};
        use flexsvm::soc::Memory;
        let mut t = TimingConfig::flexic();
        t.mem_read = 1 + rng.below(100) as u64;
        t.mem_overhead = rng.below(100) as u64;

        let run_one = |f3: u8, rd: u8| {
            let mut a = Asm::new(0);
            a.cfu(CFU_FUNCT7_SVM, f3, rd, reg::A1, reg::A2);
            let mut img = a.assemble_bytes().unwrap();
            img.resize(256, 0);
            let mut mem = Memory::with_image(&img, 256);
            let mut core = ServCore::new(0);
            let mut bank = CfuBank::new();
            bank.register(CFU_FUNCT7_SVM, Box::new(SvmAccel::new())).unwrap();
            let mut stats = CycleStats::default();
            core.step(&mut mem, &mut bank, &t, &mut stats).unwrap();
            stats.total()
        };
        let calc = run_one(svm_ops::SV_CALC4, reg::ZERO);
        let res = run_one(svm_ops::SV_RES4, reg::A0);
        assert_eq!(res, calc + t.cfu_wb, "writeback must add exactly cfu_wb");
    });
}
