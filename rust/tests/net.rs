//! Wire-front integration: the §6 bit-exactness contract across
//! loopback sockets, the engine contract over the wire
//! (`RemoteEngine` against a live local server), and admission
//! control (`503 + Retry-After` under a saturated ingress).
//!
//! The admission, slow-read, streaming and shutdown contracts run
//! against **both** fronts (`pool` and, on Linux, `epoll`) — the
//! fronts must be behaviorally interchangeable.  Everything here runs
//! artifact-free: servers carry in-memory tiny / random quantized
//! models or the scripted `MockEngine`.

use std::time::{Duration, Instant};

use flexsvm::coordinator::{Server, ServeError};
use flexsvm::engine::{Engine, ModelSource, SimCost};
use flexsvm::farm::scenario::Streaming;
use flexsvm::net::{
    drive_streaming, wire, HttpClient, HttpClientOpts, NetFront, NetOpts, NetServer, RemoteEngine,
};
use flexsvm::obs::{Span, TraceId};
use flexsvm::svm::{infer, QuantModel};
use flexsvm::testing::{gen, MockEngine};
use flexsvm::util::Pcg32;

fn tiny_models() -> Vec<(String, QuantModel)> {
    vec![
        ("cfg_a".to_string(), gen::tiny_model("cfg_a", false)),
        ("cfg_b".to_string(), gen::tiny_model("cfg_b", true)),
    ]
}

/// Every front the platform supports: the epoll readiness loop is
/// Linux-only, so elsewhere the pool runs alone.
fn fronts() -> Vec<NetFront> {
    if cfg!(target_os = "linux") {
        vec![NetFront::Pool, NetFront::Epoll]
    } else {
        vec![NetFront::Pool]
    }
}

/// A native-engine coordinator on a loopback socket.
fn native_net_server(models: Vec<(String, QuantModel)>, opts: NetOpts) -> NetServer {
    let server = Server::builder()
        .models(models)
        .linger(Duration::from_micros(200))
        .start()
        .unwrap();
    NetServer::bind(server, "127.0.0.1:0", opts).unwrap()
}

/// A MockEngine coordinator (pred = x[0]) on a loopback socket.
fn mock_net_server(engine: MockEngine, queue_cap: usize, batch_max: usize) -> NetServer {
    mock_net_server_on(NetFront::default_for_platform(), engine, queue_cap, batch_max)
}

/// Same, pinned to one wire front.
fn mock_net_server_on(
    front: NetFront,
    engine: MockEngine,
    queue_cap: usize,
    batch_max: usize,
) -> NetServer {
    let server = Server::builder()
        .keys(["m"])
        .engine(Box::new(engine))
        .queue_cap(queue_cap)
        .batch_max(batch_max)
        .linger(Duration::from_micros(200))
        .start()
        .unwrap();
    let opts = NetOpts { front, workers: 12, ..Default::default() };
    NetServer::bind(server, "127.0.0.1:0", opts).unwrap()
}

// ------------------------------------------------- §6 across the wire

#[test]
fn served_predictions_over_http_are_bit_identical_to_in_process_client() {
    let mut models = tiny_models();
    let mut rng = Pcg32::seeded(0x3e7);
    for i in 0..2 {
        let m = gen::quant_model(&mut rng);
        models.push((format!("rand{i}_{}", m.dataset), m));
    }
    let net = native_net_server(models.clone(), NetOpts::default());
    let local = net.client();
    let mut http = HttpClient::new(net.addr().to_string());

    for (key, model) in &models {
        // single-sample route
        for _ in 0..8 {
            let x = gen::features(&mut rng, model.n_features);
            let in_process = local.infer(key, &x).unwrap().pred;
            let resp = http.post_json("/v1/infer", &wire::infer_body(key, &x)).unwrap();
            assert_eq!(resp.status, 200, "{}", resp.body);
            let wire_pred = resp.json().unwrap().get("pred").unwrap().as_i32().unwrap();
            assert_eq!(wire_pred, in_process, "{key}: wire != in-process");
            assert_eq!(wire_pred, infer::predict(model, &x), "{key}: wire != native spec");
        }
        // batch route, same contract per slot
        let xs: Vec<Vec<i32>> =
            (0..8).map(|_| gen::features(&mut rng, model.n_features)).collect();
        let resp = http.post_json("/v1/infer", &wire::infer_batch_body(key, &xs)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let doc = resp.json().unwrap();
        let results = doc.get("results").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(results.len(), xs.len());
        for (item, x) in results.iter().zip(&xs) {
            let pred = item.get("pred").unwrap().as_i32().unwrap();
            assert_eq!(pred, infer::predict(model, x), "{key} batch slot diverges");
        }
    }
    drop(http);
    net.shutdown().unwrap();
}

#[test]
fn kernel_configs_serve_over_http_bit_exactly_and_tag_their_metrics() {
    use flexsvm::kernel::Kernel;
    let models = vec![
        ("rbf_cfg".to_string(), gen::tiny_kernel_model("rbf_cfg", Kernel::Rbf)),
        ("poly_cfg".to_string(), gen::tiny_kernel_model("poly_cfg", Kernel::Poly)),
    ];
    let net = native_net_server(models.clone(), NetOpts::default());
    let mut http = HttpClient::new(net.addr().to_string());
    let mut rng = Pcg32::seeded(0x6e77);

    // healthz names each config's kernel family
    let doc = http.get("/healthz").unwrap().json().unwrap();
    for c in doc.get("configs").unwrap().as_arr().unwrap() {
        let key = c.get("key").unwrap().as_str().unwrap();
        let want = if key == "rbf_cfg" { "rbf" } else { "poly" };
        assert_eq!(c.get("kernel").unwrap().as_str().unwrap(), want);
        assert_eq!(c.get("bits").unwrap().as_i64().unwrap(), 4);
    }

    // served predictions match the native kernel-machine spec
    for (key, model) in &models {
        for _ in 0..16 {
            let x = gen::features(&mut rng, model.n_features);
            let resp = http.post_json("/v1/infer", &wire::infer_body(key, &x)).unwrap();
            assert_eq!(resp.status, 200, "{}", resp.body);
            let pred = resp.json().unwrap().get("pred").unwrap().as_i32().unwrap();
            assert_eq!(pred, infer::predict(model, &x), "{key}: wire != native kernel spec");
        }
    }

    // the metrics document tags each config with its kernel id
    let doc = http.get("/v1/metrics").unwrap().json().unwrap();
    for (key, want) in [("rbf_cfg", "rbf"), ("poly_cfg", "poly")] {
        let m = doc.get("configs").unwrap().get(key).unwrap().clone();
        assert_eq!(m.get("kernel").unwrap().as_str().unwrap(), want, "{key}");
        assert_eq!(m.get("requests").unwrap().as_i64().unwrap(), 16);
        let back = wire::config_metrics_from_json(&m).unwrap();
        assert_eq!(back.kernel, want);
        assert_eq!(back.bits, 4);
    }
    drop(http);
    net.shutdown().unwrap();
}

// ------------------------------------- engine contract over the wire

#[test]
fn remote_engine_passes_the_engine_contract_against_a_live_server() {
    let engine = MockEngine::new()
        .fail_when_first_feature_is(13)
        .with_sim(SimCost { cycles: 7, energy_mj: 0.25 })
        .with_delays(vec![Duration::from_millis(20)]);
    let log = engine.batch_log();
    let net = mock_net_server(engine, 1024, 64);
    let addr = net.addr().to_string();

    // direct contract calls against the live node ------------------
    let mut re = RemoteEngine::new([addr.clone()]).unwrap();
    re.warm(&ModelSource::None, &["m".to_string()]).unwrap();
    let out = re.run_batch("m", &[vec![4, 0], vec![13, 0], vec![9, 0]]);
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].as_ref().unwrap().pred, 4);
    assert!(
        matches!(&out[1], Err(ServeError::Engine(msg)) if msg.contains("scripted failure")),
        "typed per-sample failure must cross the wire: {out:?}"
    );
    assert_eq!(out[2].as_ref().unwrap().pred, 9);
    let sim = out[0].as_ref().unwrap().sim.expect("sim cost crosses the wire");
    assert_eq!(sim.cycles, 7);
    assert!((sim.energy_mj - 0.25).abs() < 1e-12);
    // unknown config comes back typed
    let out = re.run_batch("nope", &[vec![1, 0]]);
    assert!(matches!(&out[0], Err(ServeError::UnknownConfig(k)) if k == "nope"), "{out:?}");
    // the mock has no baseline story; snapshot names the node
    assert!(re.baseline_cycles("m").is_none());
    assert!(re.snapshot().engine.contains(&addr));
    // warm must reject keys the node does not serve
    let mut re2 = RemoteEngine::new([addr.clone()]).unwrap();
    let err = re2.warm(&ModelSource::None, &["m".to_string(), "ghost".to_string()]).unwrap_err();
    assert!(err.to_string().contains("ghost"), "{err:#}");

    // the same engine behind a *front* coordinator -----------------
    let front = Server::builder()
        .keys(["m"])
        .engine(Box::new(RemoteEngine::new([addr.clone()]).unwrap()))
        .linger(Duration::from_millis(2))
        .start()
        .unwrap();
    let fc = front.client();
    // occupy the pipe so the next three share a front batch
    let warm = fc.submit("m", &[5, 0]).unwrap();
    let outs = fc.infer_many("m", &[vec![1, 0], vec![13, 0], vec![2, 0]]).unwrap();
    assert_eq!(outs[0].as_ref().unwrap().pred, 1);
    assert!(
        matches!(&outs[1], Err(ServeError::Engine(_))),
        "failure isolation holds across two coordinators + a socket"
    );
    assert_eq!(outs[2].as_ref().unwrap().pred, 2);
    assert_eq!(outs[0].as_ref().unwrap().sim.unwrap().cycles, 7);
    warm.wait().unwrap();
    front.shutdown().unwrap();

    // batching survived the pipe: the backend engine saw real batches
    // 3 direct + 4 through the front coordinator ("nope" never
    // reaches the engine — the backend dispatcher rejects it)
    let sizes = log.lock().unwrap().clone();
    assert_eq!(sizes.iter().sum::<usize>(), 7, "all samples executed: {sizes:?}");
    assert!(sizes.iter().any(|&s| s >= 2), "expected wire batching: {sizes:?}");
    // release the engines' keep-alive connections before joining
    drop(re);
    drop(re2);
    net.shutdown().unwrap();
}

#[test]
fn remote_engine_fans_one_batch_out_to_two_nodes() {
    let net_a = mock_net_server(MockEngine::new(), 1024, 64);
    let net_b = mock_net_server(MockEngine::new(), 1024, 64);
    let (addr_a, addr_b) = (net_a.addr().to_string(), net_b.addr().to_string());

    let mut re = RemoteEngine::new([addr_a, addr_b]).unwrap();
    assert_eq!(re.n_nodes(), 2);
    re.warm(&ModelSource::None, &["m".to_string()]).unwrap();
    let xs: Vec<Vec<i32>> = (0..8).map(|i| vec![i as i32, 0]).collect();
    let out = re.run_batch("m", &xs);
    assert_eq!(out.len(), 8);
    for (i, r) in out.iter().enumerate() {
        assert_eq!(r.as_ref().unwrap().pred, i as i32, "answers stay in input order");
    }
    // the batch was split across both nodes (4 samples each)
    let (ra, rb) = (net_a.client().metrics().unwrap(), net_b.client().metrics().unwrap());
    assert_eq!(ra["m"].requests, 4, "node A serves its contiguous chunk");
    assert_eq!(rb["m"].requests, 4, "node B serves its contiguous chunk");
    drop(re);
    net_a.shutdown().unwrap();
    net_b.shutdown().unwrap();
}

// ------------------------------------------------------ observability

#[test]
fn explicit_trace_ids_survive_the_wire_and_are_retrievable() {
    let net = native_net_server(tiny_models(), NetOpts::default());
    let mut c = HttpClient::new(net.addr().to_string());

    // trace in the JSON body: the answer echoes it in the body, the
    // X-Trace-Id header, and the attached span tree
    let t = TraceId::parse("00000000deadbeef").unwrap();
    let r = c.post_json("/v1/infer", &wire::infer_body_traced("cfg_a", &[1, 2, 3], t)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.header("X-Trace-Id"), Some(t.to_hex().as_str()), "{}", r.body);
    let doc = r.json().unwrap();
    assert_eq!(doc.get("trace").unwrap().as_str().unwrap(), t.to_hex());
    let span = Span::from_json(doc.get("span").unwrap()).unwrap();
    assert_eq!(span.trace, t);
    assert_eq!(span.config, "cfg_a");
    assert!(span.stages.sum_us() <= span.total_us.max(1), "{span:?}");

    // header-only propagation (no "trace" field in the body)
    let t2 = TraceId::parse("00000000cafebabe").unwrap();
    let r = c
        .request_with(
            "POST",
            "/v1/infer",
            Some(wire::infer_body("cfg_b", &[4, 5, 6]).to_string()),
            &[("X-Trace-Id".to_string(), t2.to_hex())],
        )
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.header("X-Trace-Id"), Some(t2.to_hex().as_str()));

    // both explicit spans are retrievable from the ring by id
    for id in [t, t2] {
        let tr = c.get(&format!("/v1/traces?id={}", id.to_hex())).unwrap();
        assert_eq!(tr.status, 200, "{}", tr.body);
        let sp = Span::from_json(&tr.json().unwrap()).unwrap();
        assert_eq!(sp.trace, id);
    }
    // unknown id answers 404, malformed id answers 400
    assert_eq!(c.get("/v1/traces?id=0000000000000001").unwrap().status, 404);
    assert_eq!(c.get("/v1/traces?id=zzz").unwrap().status, 400);

    // the trace listing and the Prometheus endpoint serve after traffic
    let l = c.get("/v1/traces").unwrap();
    assert_eq!(l.status, 200, "{}", l.body);
    let ld = l.json().unwrap();
    assert!(ld.get("observed").unwrap().as_i64().unwrap() >= 2, "{}", l.body);
    assert!(ld.get("retained").unwrap().as_i64().unwrap() >= 2, "{}", l.body);
    let p = c.get("/metrics").unwrap();
    assert_eq!(p.status, 200);
    assert!(p.header("Content-Type").unwrap().starts_with("text/plain"), "{:?}", p.headers);
    assert!(p.body.contains("# TYPE"), "{}", p.body);
    assert!(p.body.contains("flexsvm_"), "{}", p.body);
    drop(c);
    net.shutdown().unwrap();
}

#[test]
fn traced_fan_out_yields_one_span_tree_with_per_node_children() {
    // two leaf nodes, one front coordinator fanning out over the wire,
    // and the front itself on a socket — the full multi-node topology
    let net_a = mock_net_server(MockEngine::new(), 1024, 64);
    let net_b = mock_net_server(MockEngine::new(), 1024, 64);
    let (addr_a, addr_b) = (net_a.addr().to_string(), net_b.addr().to_string());

    let front = Server::builder()
        .keys(["m"])
        .engine(Box::new(RemoteEngine::new([addr_a.clone(), addr_b.clone()]).unwrap()))
        .linger(Duration::from_millis(2))
        .start()
        .unwrap();
    let fnet = NetServer::bind(front, "127.0.0.1:0", NetOpts::default()).unwrap();
    let mut c = HttpClient::new(fnet.addr().to_string());

    let t = TraceId::parse("00000000feedface").unwrap();
    let xs: Vec<Vec<i32>> = (0..8).map(|i| vec![i as i32, 0]).collect();
    let r = c
        .post_json_with(
            "/v1/infer",
            &wire::infer_batch_body("m", &xs),
            &[("X-Trace-Id".to_string(), t.to_hex())],
        )
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let results = r.json().unwrap().get("results").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(results.len(), 8);
    for item in &results {
        assert_eq!(item.get("trace").unwrap().as_str().unwrap(), t.to_hex());
        assert!(item.opt("span").is_some(), "explicitly-traced answers carry spans: {item:?}");
    }

    // one retained tree on the front: batch root → per-sample spans →
    // remote child spans stamped with the node that executed the chunk
    let tr = c.get(&format!("/v1/traces?id={}", t.to_hex())).unwrap();
    assert_eq!(tr.status, 200, "{}", tr.body);
    let root = Span::from_json(&tr.json().unwrap()).unwrap();
    assert_eq!(root.trace, t);
    assert_eq!(root.children.len(), 8, "one child per batch sample");
    let mut nodes = std::collections::HashSet::new();
    for child in &root.children {
        assert_eq!(child.trace, t, "the trace id survives every hop");
        assert_eq!(child.children.len(), 1, "each sample has its remote node's span: {child:?}");
        let remote = &child.children[0];
        assert_eq!(remote.trace, t);
        assert!(!remote.node.is_empty(), "fan-out children are stamped with the node addr");
        nodes.insert(remote.node.clone());
    }
    assert_eq!(
        nodes,
        [addr_a.clone(), addr_b.clone()].into_iter().collect(),
        "the 8-sample batch crossed both nodes"
    );

    // the leaf nodes also retained their view of the same trace
    assert!(net_a.client().obs().get(t).is_some(), "node A kept its span");
    assert!(net_b.client().obs().get(t).is_some(), "node B kept its span");
    drop(c);
    fnet.shutdown().unwrap();
    net_a.shutdown().unwrap();
    net_b.shutdown().unwrap();
}

// ---------------------------------------------------- admission control

#[test]
fn saturated_ingress_sheds_503_with_retry_after_while_accepted_complete() {
    for front in fronts() {
        saturated_ingress_case(front);
    }
}

fn saturated_ingress_case(front: NetFront) {
    // 1-slot ingress + 500 ms batches: while the dispatcher is
    // mid-batch, at most one more request fits; a concurrent burst
    // must shed fast with 503 + Retry-After, not block the socket
    let engine = MockEngine::new().with_delays(vec![Duration::from_millis(500)]);
    let net = mock_net_server_on(front, engine, 1, 1);
    let addr = net.addr().to_string();

    let warm = std::thread::spawn({
        let addr = addr.clone();
        move || {
            let mut c = HttpClient::new(&addr);
            c.post_json("/v1/infer", &wire::infer_body("m", &[3, 0])).unwrap()
        }
    });
    std::thread::sleep(Duration::from_millis(150)); // dispatcher is now mid-batch

    let results: Vec<(u16, Option<String>, String)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..10)
            .map(|i| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = HttpClient::new(&addr);
                    let resp =
                        c.post_json("/v1/infer", &wire::infer_body("m", &[i as i32, 0])).unwrap();
                    let retry = resp.header("Retry-After").map(|v| v.to_string());
                    (resp.status, retry, resp.body)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let warm_resp = warm.join().unwrap();
    assert_eq!(warm_resp.status, 200, "{front}: in-flight request drains: {}", warm_resp.body);
    let shed = results.iter().filter(|(s, _, _)| *s == 503).count();
    let ok = results.iter().filter(|(s, _, _)| *s == 200).count();
    assert_eq!(shed + ok, 10, "{front}: {results:?}");
    assert!(shed >= 5, "{front}: most of the burst must shed: {results:?}");
    assert!(ok >= 1, "{front}: the request that won the ingress slot completes: {results:?}");
    for (status, retry, body) in &results {
        if *status == 503 {
            assert_eq!(retry.as_deref(), Some("1"), "503 must carry Retry-After: {body}");
            assert!(body.contains("overloaded"), "{body}");
        }
    }
    assert!(net.metrics().shed >= shed as u64);
    // the server stays healthy after shedding
    let mut c = HttpClient::new(&addr);
    assert_eq!(c.get("/healthz").unwrap().status, 200);
    drop(c);
    net.shutdown().unwrap();
}

// --------------------------------------------------------- endpoints

#[test]
fn healthz_metrics_and_error_routes() {
    let net = native_net_server(tiny_models(), NetOpts::default());
    let mut c = HttpClient::new(net.addr().to_string());

    let h = c.get("/healthz").unwrap();
    assert_eq!(h.status, 200, "{}", h.body);
    let doc = h.json().unwrap();
    assert_eq!(doc.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(doc.get("engine").unwrap().as_str().unwrap(), "native");
    let configs = doc.get("configs").unwrap().as_arr().unwrap().to_vec();
    let names: Vec<String> =
        configs.iter().map(|c| c.get("key").unwrap().as_str().unwrap().to_string()).collect();
    assert!(names.iter().any(|n| n == "cfg_a") && names.iter().any(|n| n == "cfg_b"), "{names:?}");
    // served-config entries carry the model family facts (ISSUE 8)
    for c in &configs {
        assert_eq!(c.get("kernel").unwrap().as_str().unwrap(), "linear");
        assert_eq!(c.get("bits").unwrap().as_i64().unwrap(), 4);
    }

    // some traffic, then the metrics document
    let r = c.post_json("/v1/infer", &wire::infer_body("cfg_a", &[1, 2, 3])).unwrap();
    assert_eq!(r.status, 200);
    let answer = r.json().unwrap();
    assert!(answer.get("latency_us").unwrap().as_i64().unwrap() >= 0);
    assert!(answer.get("batch_size").unwrap().as_i64().unwrap() >= 1);
    let m = c.get("/v1/metrics").unwrap();
    assert_eq!(m.status, 200);
    let doc = m.json().unwrap();
    let cfg_a = doc.get("configs").unwrap().get("cfg_a").unwrap().clone();
    assert_eq!(cfg_a.get("requests").unwrap().as_i64().unwrap(), 1);
    assert_eq!(doc.get("engine").unwrap().get("name").unwrap().as_str().unwrap(), "native");
    let net_stats = doc.get("net").unwrap().clone();
    assert!(net_stats.get("requests").unwrap().as_i64().unwrap() >= 2);
    assert!(net_stats.get("bytes_in").unwrap().as_i64().unwrap() > 0);

    // everything above rode one keep-alive connection
    assert_eq!(net.metrics().accepted, 1, "keep-alive must reuse the connection");

    // unknown config → typed 404; unknown route → 404; bad method → 405
    let r = c.post_json("/v1/infer", &wire::infer_body("ghost", &[0, 0, 0])).unwrap();
    assert_eq!(r.status, 404);
    assert!(r.body.contains("unknown_config"), "{}", r.body);
    assert_eq!(c.get("/nope").unwrap().status, 404);
    assert_eq!(c.request("GET", "/v1/infer", None).unwrap().status, 405);
    // bad JSON / wrong shapes → 400
    let r = c.request("POST", "/v1/infer", Some("{not json".to_string())).unwrap();
    assert_eq!(r.status, 400);
    let r = c.request("POST", "/v1/infer", Some("{\"config\":\"cfg_a\"}".to_string())).unwrap();
    assert_eq!(r.status, 400, "missing features/batch: {}", r.body);
    drop(c);
    net.shutdown().unwrap();
}

#[test]
fn oversized_bodies_are_rejected_with_413() {
    let net = native_net_server(tiny_models(), NetOpts { body_limit: 128, ..Default::default() });
    let mut c = HttpClient::new(net.addr().to_string());
    let big: Vec<i32> = vec![1; 1000];
    let r = c.post_json("/v1/infer", &wire::infer_body("cfg_a", &big)).unwrap();
    assert_eq!(r.status, 413, "{}", r.body);
    // normal-sized requests still work on a fresh connection
    let r = c.post_json("/v1/infer", &wire::infer_body("cfg_a", &[1, 2, 3])).unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    drop(c);
    net.shutdown().unwrap();
}

// ----------------------------------------------------------- shutdown

#[test]
fn shutdown_stops_the_listener_and_coordinator() {
    for front in fronts() {
        let net = mock_net_server_on(front, MockEngine::new(), 1024, 64);
        let addr = net.addr().to_string();
        let mut c = HttpClient::new(&addr);
        let r = c.post_json("/v1/infer", &wire::infer_body("m", &[2, 0])).unwrap();
        assert_eq!(r.status, 200, "{front}: {}", r.body);
        drop(c); // release the keep-alive connection
        net.shutdown().unwrap();
        // nothing listens there anymore
        let opts = HttpClientOpts {
            connect_attempts: 1,
            backoff: Duration::from_millis(1),
            ..Default::default()
        };
        let mut c2 = HttpClient::with_opts(&addr, opts);
        assert!(c2.get("/healthz").is_err(), "{front}: listener must be gone after shutdown");
    }
}

#[test]
fn dispatcher_panic_surfaces_through_net_shutdown() {
    for front in fronts() {
        let engine = MockEngine::new().panic_when_first_feature_is(7);
        let net = mock_net_server_on(front, engine, 1024, 64);
        let mut c = HttpClient::new(net.addr().to_string());
        let r = c.post_json("/v1/infer", &wire::infer_body("m", &[7, 0])).unwrap();
        // the dispatcher died mid-batch: the request is answered `dropped`
        assert_eq!(r.status, 500, "{front}: {}", r.body);
        assert!(r.body.contains("dropped"), "{front}: {}", r.body);
        drop(c);
        let err = net.shutdown().unwrap_err();
        assert!(err.to_string().contains("scripted panic"), "{front}: {err:#}");
    }
}

// ------------------------------------------- slow-read guard + streaming

#[test]
fn slow_read_connections_are_killed_counted_and_exported() {
    use std::io::{Read, Write};
    for front in fronts() {
        let net = native_net_server(
            tiny_models(),
            NetOpts { front, read_deadline: Duration::from_millis(150), ..Default::default() },
        );
        let addr = net.addr().to_string();
        // a slowloris peer: half a request's head, then silence — the
        // idle keep-alive timeout must NOT apply (bytes did arrive);
        // the read deadline must
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(b"POST /v1/infer HTTP/1.1\r\nContent-Length: 40\r\n").unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut buf = [0u8; 64];
        // the server kills the connection without an answer: EOF (or a
        // reset, depending on how the close races the read)
        let t0 = Instant::now();
        let died = matches!(s.read(&mut buf), Ok(0) | Err(_));
        assert!(died, "{front}: stalled connection must be closed, not answered");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "{front}: the kill must come from the 150ms read deadline, not keep-alive"
        );
        // the kill lands in the counters (the close can race our EOF
        // observation, so poll briefly)
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let m = net.metrics();
            if m.timed_out >= 1 && m.closed >= 1 {
                break;
            }
            assert!(Instant::now() < deadline, "{front}: slow-read kill not counted: {m:?}");
            std::thread::sleep(Duration::from_millis(10));
        }
        // the server stays healthy, and the connection lifecycle +
        // gauges are exported through the Prometheus endpoint
        let mut c = HttpClient::new(&addr);
        assert_eq!(c.get("/healthz").unwrap().status, 200, "{front}");
        let p = c.get("/metrics").unwrap();
        for name in [
            "flexsvm_net_connections_timed_out_total 1",
            "flexsvm_net_connections_accepted_total",
            "flexsvm_net_connections_open",
            "flexsvm_net_connections_reading",
            "flexsvm_net_connections_writing",
            "flexsvm_net_connections_idle",
        ] {
            assert!(p.body.contains(name), "{front}: missing {name}:\n{}", p.body);
        }
        drop(c);
        net.shutdown().unwrap();
    }
}

#[test]
fn streaming_sessions_hold_keep_alive_and_stay_bit_exact() {
    let models = tiny_models();
    for front in fronts() {
        let net = native_net_server(
            models.clone(),
            NetOpts { front, workers: 32, ..Default::default() },
        );
        // 24 devices x 3 rounds (first = connect/warm, 2 timed) over
        // long-lived sessions, every answer checked against the native
        // spec inside drive_streaming
        let s = Streaming::new(24, models.len(), 4, 0x57a7);
        let r = drive_streaming(&net.addr().to_string(), &s, &models, 3, 4).unwrap();
        assert_eq!(r.devices, 24, "{front}");
        assert_eq!(r.native_mismatch, 0, "{front}: wire answers must be bit-exact");
        assert_eq!(r.stalled, 0, "{front}: no device session may starve: {r:?}");
        assert_eq!(r.shed, 0, "{front}: nothing sheds at this scale: {r:?}");
        assert_eq!(r.served, 48, "{front}: every timed window answered: {r:?}");
        assert!(
            r.connections_reused >= 48,
            "{front}: sessions must ride keep-alive, not reconnect: {r:?}"
        );
        net.shutdown().unwrap();
    }
}

// ------------------------------------- profiler / flight recorder / SLO

#[test]
fn profiler_slo_and_logs_surface_over_http() {
    use flexsvm::coordinator::Backend;
    use flexsvm::farm::FarmOpts;
    use flexsvm::obs::ObsOpts;
    use flexsvm::serv::TimingConfig;

    // accel farm with the continuous profiler on every simulated
    // request, the analytic fast path auditing every 2nd request (so
    // the log gets a fastpath_on event and the profiler still sees
    // SoC runs), and generous SLO targets that stay healthy
    let models = vec![("prof_lin".to_string(), gen::tiny_model("prof_lin", false))];
    let server = Server::builder()
        .models(models.clone())
        .backend(Backend::Accel)
        .linger(Duration::from_micros(200))
        .obs_opts(ObsOpts {
            slo: Some("p99=10s,avail=50".parse().unwrap()),
            ..Default::default()
        })
        .farm(FarmOpts {
            shards: 1,
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            fastpath: true,
            audit_rate: 2,
            profile_rate: 1,
            ..Default::default()
        })
        .start()
        .unwrap();
    let net = NetServer::bind(server, "127.0.0.1:0", NetOpts::default()).unwrap();
    let mut c = HttpClient::new(net.addr().to_string());

    let model = &models[0].1;
    let mut rng = Pcg32::seeded(0x0b5);
    for _ in 0..16 {
        let x = gen::features(&mut rng, model.n_features);
        let r = c.post_json("/v1/infer", &wire::infer_body("prof_lin", &x)).unwrap();
        assert_eq!(r.status, 200, "{}", r.body);
        let pred = r.json().unwrap().get("pred").unwrap().as_i32().unwrap();
        assert_eq!(pred, infer::predict(model, &x), "profiled serving stays bit-exact");
    }

    // /v1/profile: per-config hot regions from the sampled runs
    let p = c.get("/v1/profile").unwrap();
    assert_eq!(p.status, 200, "{}", p.body);
    let doc = p.json().unwrap();
    let cfg = doc.get("configs").unwrap().get("prof_lin").unwrap().clone();
    assert!(cfg.get("sampled_runs").unwrap().as_i64().unwrap() >= 1, "{}", p.body);
    assert!(cfg.get("total_cycles").unwrap().as_i64().unwrap() > 0, "{}", p.body);
    let regions: Vec<String> = cfg
        .get("hot")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|h| h.get("region").unwrap().as_str().unwrap().to_string())
        .collect();
    assert!(regions.iter().any(|r| r == "dot_loop"), "named hot region: {regions:?}");

    // collapsed-stack text is flamegraph input
    let fl = c.get("/v1/profile?collapsed=1").unwrap();
    assert_eq!(fl.status, 200);
    assert!(fl.body.contains("flexsvm;prof_lin;dot_loop "), "{}", fl.body);
    assert_eq!(c.get("/v1/profile?n=0").unwrap().status, 400);

    // /v1/logs: the flight recorder saw this farm's fastpath promotion
    let l = c.get("/v1/logs?n=512").unwrap();
    assert_eq!(l.status, 200, "{}", l.body);
    let events = l.json().unwrap().get("events").unwrap().as_arr().unwrap().to_vec();
    assert!(
        events.iter().any(|e| {
            e.get("event").unwrap().as_str().unwrap() == "fastpath_on"
                && e.opt("config").is_some_and(|c| c.as_str().unwrap() == "prof_lin")
        }),
        "fastpath_on event for prof_lin in: {}",
        l.body
    );
    assert_eq!(c.get("/v1/logs?level=bogus").unwrap().status, 400);
    assert_eq!(c.get("/v1/logs?n=abc").unwrap().status, 400);

    // /healthz folds the SLO verdict in; generous targets stay ok
    let h = c.get("/healthz").unwrap().json().unwrap();
    assert_eq!(h.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(h.get("slo").unwrap().as_str().unwrap(), "ok");

    // /metrics carries build info, uptime, and the SLO gauges
    let m = c.get("/metrics").unwrap();
    for name in [
        "flexsvm_build_info",
        "flexsvm_uptime_seconds",
        "flexsvm_slo_target_p99_us",
        "flexsvm_slo_target_availability",
        "flexsvm_slo_burn_rate",
        "flexsvm_slo_degraded",
    ] {
        assert!(m.body.contains(name), "missing {name}:\n{}", m.body);
    }
    drop(c);
    net.shutdown().unwrap();
}

#[test]
fn fleet_profiles_merge_across_nodes_and_tolerate_profile_less_peers() {
    use flexsvm::coordinator::Backend;
    use flexsvm::farm::FarmOpts;
    use flexsvm::serv::TimingConfig;

    // node A: accel farm, always-on profiler — its metrics document
    // carries a "profiles" section
    let accel = Server::builder()
        .models(vec![("m".to_string(), gen::tiny_model("m", false))])
        .backend(Backend::Accel)
        .linger(Duration::from_micros(200))
        .farm(FarmOpts {
            shards: 1,
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            profile_rate: 1,
            ..Default::default()
        })
        .start()
        .unwrap();
    let net_a = NetServer::bind(accel, "127.0.0.1:0", NetOpts::default()).unwrap();
    // node B: MockEngine — its document has NO "profiles" key, exactly
    // the shape a pre-profiler peer emits
    let net_b = mock_net_server(MockEngine::new(), 1024, 64);

    let mut re =
        RemoteEngine::new([net_a.addr().to_string(), net_b.addr().to_string()]).unwrap();
    re.warm(&ModelSource::None, &["m".to_string()]).unwrap();
    let xs: Vec<Vec<i32>> = (0..8).map(|i| vec![i as i32 % 8, 1, 2]).collect();
    let out = re.run_batch("m", &xs);
    assert_eq!(out.iter().filter(|r| r.is_ok()).count(), 8, "{out:?}");

    // the fleet snapshot merges node A's profile and shrugs off node
    // B's profile-less document
    let em = re.snapshot();
    let p = em.profiles.get("m").expect("fleet-merged profile for m");
    assert_eq!(p.sampled_runs, 4, "node A simulated (and profiled) its 4-sample chunk");
    assert!(p.regions.contains_key("dot_loop"), "{:?}", p.regions);
    assert!(p.total_cycles > 0);

    drop(re);
    net_a.shutdown().unwrap();
    net_b.shutdown().unwrap();
}
