//! Accelerator-model and PJRT micro-benchmarks: PE passes, full
//! accelerator op streams, and compiled-graph execution by batch size.
//!
//!     cargo bench --bench bench_accel

use flexsvm::accel::svm::SvmAccel;
use flexsvm::accel::{pe, Cfu};
use flexsvm::isa::svm_ops;
use flexsvm::svm::pack;
use flexsvm::util::benchkit::{manifest_or_skip, write_report, Bench};
use flexsvm::util::Pcg32;

fn main() -> anyhow::Result<()> {
    let mut rng = Pcg32::seeded(0xbe);

    // --- PE datapath ---
    let mut b = Bench::new("PE datapath (nibble-decomposed MAC)");
    for mode in [pe::Mode::W4, pe::Mode::W8, pe::Mode::W16] {
        let qmax = (1i32 << (mode.bits() - 1)) - 1;
        let pairs: Vec<(u32, u32)> = (0..1024)
            .map(|_| {
                let xs: Vec<u32> = (0..mode.lanes()).map(|_| rng.below(16)).collect();
                let ws: Vec<i32> =
                    (0..mode.lanes()).map(|_| rng.range_i32(-qmax, qmax)).collect();
                (pe::pack_features(&xs, mode), pe::pack_weights(&ws, mode))
            })
            .collect();
        let mut sink = 0i64;
        let s = b.case(&format!("pe::compute x1024 ({mode:?})"), 10, 200, || {
            sink = pairs.iter().map(|&(a, w)| pe::compute(a, w, mode)).sum();
        });
        std::hint::black_box(sink);
        b.metric(
            &format!("{mode:?} PE passes"),
            1024.0 / s.median.as_secs_f64() / 1e6,
            "Mpasses/s",
        );
    }

    // --- full accelerator instruction stream ---
    let mut b2 = Bench::new("SvmAccel op stream (calc4 x 8 + res4)");
    let mut accel = SvmAccel::new();
    let ops: Vec<(u32, u32)> = (0..8)
        .map(|_| {
            let xs: Vec<u32> = (0..8).map(|_| rng.below(16)).collect();
            let ws: Vec<i32> = (0..8).map(|_| rng.range_i32(-7, 7)).collect();
            (pe::pack_features(&xs, pe::Mode::W4), pe::pack_weights(&ws, pe::Mode::W4))
        })
        .collect();
    let s = b2.case("classifier pass (9 ops)", 100, 1000, || {
        accel.execute(svm_ops::CREATE_ENV, 0, 0).unwrap();
        for &(a, w) in &ops {
            accel.execute(svm_ops::SV_CALC4, a, w).unwrap();
        }
        accel.execute(svm_ops::SV_RES4, 0, 0).unwrap();
    });
    b2.metric("accelerator ops", 10.0 / s.median.as_secs_f64() / 1e6, "Mops/s");

    // --- packing ---
    let Some(manifest) = manifest_or_skip("bench_accel packing/PJRT sections") else {
        let path = write_report("accel", &[&b, &b2])?;
        println!("\nwrote {}", path.display());
        return Ok(());
    };
    let mut b3 = Bench::new("operand packing (host side)");
    let entry = manifest.config("derm_ovo_w16")?;
    let model = manifest.model(entry)?;
    let test = manifest.test_set("derm")?;
    b3.case("feature_words derm w16", 10, 1000, || {
        std::hint::black_box(pack::feature_words(&test.x_q[0], 16));
    });
    b3.case("all_weight_words derm ovo w16", 2, 50, || {
        std::hint::black_box(pack::all_weight_words(&model));
    });

    // --- PJRT compiled-graph execution (pjrt feature only) ---
    #[cfg(feature = "pjrt")]
    let path = {
        let mut b4 = Bench::new("PJRT execution (AOT HLO on CPU client)");
        let mut engine = flexsvm::runtime::Engine::new()?;
        for key in ["iris_ovr_w4", "derm_ovo_w16"] {
            let entry = manifest.config(key)?;
            let test = manifest.test_set(&entry.dataset)?;
            for batch in [1usize, 64] {
                engine.load(&manifest, entry, batch)?;
                let cfg = engine.get(key, batch)?;
                let mut flat = Vec::new();
                for i in 0..batch {
                    flat.extend_from_slice(&test.x_q[i % test.len()]);
                }
                let s = b4.case(&format!("{key} b{batch}"), 5, 100, || {
                    std::hint::black_box(cfg.execute(&flat).unwrap());
                });
                b4.metric(
                    &format!("{key} b{batch} throughput"),
                    batch as f64 / s.median.as_secs_f64(),
                    "inf/s",
                );
            }
        }
        write_report("accel", &[&b, &b2, &b3, &b4])?
    };
    #[cfg(not(feature = "pjrt"))]
    let path = {
        println!("\n(PJRT section skipped: built without the `pjrt` feature)");
        write_report("accel", &[&b, &b2, &b3])?
    };
    println!("\nwrote {}", path.display());
    Ok(())
}
