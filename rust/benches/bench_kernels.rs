//! Kernel-family benchmarks (ISSUE 8): the KSVM accelerator op stream
//! per kernel, a cross-layer differential counter over random RBF/poly
//! machines, and kernel configs through the serving farm with every
//! request audited against the analytic bill.
//!
//! Works without artifacts — models are the deterministic testing
//! fixtures — and emits `BENCH_kernels.json` for the perf-smoke gate:
//! the `kernel_cross_layer_mismatches` and `kernel_audit_mismatches`
//! metrics must be zero.
//!
//!     cargo bench --bench bench_kernels

use flexsvm::farm::{Farm, FarmOpts};
use flexsvm::kernel::Kernel;
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::svm::infer;
use flexsvm::testing::{gen, ksvm_emulate_scores};
use flexsvm::util::benchkit::{write_report, Bench};
use flexsvm::util::Pcg32;

fn main() -> anyhow::Result<()> {
    let mut rng = Pcg32::seeded(0x6b65);

    // --- KSVM accelerator op stream per family ---
    let mut b = Bench::new("KSVM op stream (full classifier sweep)");
    for kernel in [Kernel::Rbf, Kernel::Poly] {
        let m = gen::tiny_kernel_model("bench", kernel);
        let xs: Vec<Vec<i32>> =
            (0..64).map(|_| gen::features(&mut rng, m.n_features)).collect();
        let mut sink = 0i64;
        let s = b.case(&format!("{kernel} op-stream sweep x64"), 10, 200, || {
            sink = xs.iter().map(|x| ksvm_emulate_scores(&m, x).unwrap()[0]).sum();
        });
        std::hint::black_box(sink);
        b.metric(
            &format!("{kernel} op-stream sweeps"),
            64.0 / s.median.as_secs_f64() / 1e3,
            "ksweeps/s",
        );
    }

    // --- cross-layer differential: spec == op stream == SERV sim ---
    let mut b2 = Bench::new("kernel cross-layer differential (random models)");
    let mut checked = 0u64;
    let mut mismatches = 0u64;
    for _ in 0..12 {
        let m = gen::kernel_model(&mut rng);
        let mut acc =
            ProgramRunner::accelerated(&m, TimingConfig::ideal_mem(), ProgramOpts::default())?;
        for _ in 0..4 {
            let x = gen::features(&mut rng, m.n_features);
            let native = infer::scores(&m, &x);
            let emu = ksvm_emulate_scores(&m, &x)?;
            let (pred, _) = acc.run_sample(&x)?;
            checked += 1;
            if emu != native || pred != infer::predict(&m, &x) {
                mismatches += 1;
            }
        }
    }
    b2.metric("kernel cross-layer checks", checked as f64, "samples");
    b2.metric("kernel_cross_layer_mismatches", mismatches as f64, "mismatches");

    // --- kernel configs through the farm, every request audited ---
    let mut b3 = Bench::new("kernel serving farm (fastpath, audit_rate 1)");
    let models = vec![
        ("rbf".to_string(), gen::tiny_kernel_model("rbf", Kernel::Rbf)),
        ("poly".to_string(), gen::tiny_kernel_model("poly", Kernel::Poly)),
    ];
    let farm = Farm::start(
        models.clone(),
        FarmOpts {
            shards: 1,
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            fastpath: true,
            audit_rate: 1,
            ..Default::default()
        },
    )?;
    for (key, m) in &models {
        let xs: Vec<Vec<i32>> =
            (0..32).map(|_| gen::features(&mut rng, m.n_features)).collect();
        let s = b3.case(&format!("{key} predict x32 (sim + analytic audit)"), 2, 20, || {
            for x in &xs {
                std::hint::black_box(farm.predict(key, x).unwrap());
            }
        });
        b3.metric(&format!("{key} audited throughput"), 32.0 / s.median.as_secs_f64(), "inf/s");
    }
    let f = farm.metrics().fast;
    b3.metric("kernel_fastpath_configs", f.fastpath_configs as f64, "configs");
    b3.metric("kernel_audit_mismatches", f.mismatches as f64, "mismatches");

    let path = write_report("kernels", &[&b, &b2, &b3])?;
    println!("\nwrote {}", path.display());
    Ok(())
}
