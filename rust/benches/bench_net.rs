//! Wire-front serving benchmark: the `farm::scenario` steady / bursty /
//! multi-tenant streams replayed over loopback sockets against
//! `net::server` (coordinator + accel farm behind it).
//!
//! Arrivals are paced open-loop to the scenario's schedule (transport
//! concurrency is bounded by the client worker pool); every request is
//! a real HTTP `POST /v1/infer`, so the numbers include JSON
//! serialization, socket hops and the net layer's admission control.
//! Recorded per scenario: throughput, client-observed p50/p99 wall
//! latency, and shed rate; energy/request comes from
//! `report::serving` over the farm's sim accounting.  Results land in
//! `BENCH_net.json` through benchkit.
//!
//!     cargo bench --bench bench_net [n_requests]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use flexsvm::coordinator::metrics::Histogram;
use flexsvm::coordinator::{Backend, Server};
use flexsvm::farm::scenario::{self, Traffic};
use flexsvm::farm::FarmOpts;
use flexsvm::net::{wire, HttpClient, NetOpts, NetServer};
use flexsvm::power::FlexicModel;
use flexsvm::report::serving;
use flexsvm::serv::TimingConfig;
use flexsvm::svm::QuantModel;
use flexsvm::testing::gen;
use flexsvm::util::benchkit::{quick, write_report, Bench};
use flexsvm::util::Table;

const WORKERS: usize = 8;

/// Four tiny synthetic configs: the bench needs no artifacts, and tiny
/// models keep the simulated farm fast enough to stress the wire.
fn build_models() -> Vec<(String, QuantModel)> {
    ["syn_a", "syn_b", "syn_c", "syn_d"]
        .iter()
        .enumerate()
        .map(|(i, k)| (k.to_string(), gen::tiny_model(k, i % 2 == 1)))
        .collect()
}

/// Replay one scenario over HTTP (paced by `Scenario::replay`, one
/// keep-alive client per worker); returns (wall, served, shed,
/// client-side latency histogram).
fn replay_http(
    addr: &str,
    s: &scenario::Scenario,
    xs: &[Vec<i32>],
    models: &[(String, QuantModel)],
) -> (Duration, u64, u64, Histogram) {
    let served = AtomicU64::new(0);
    let shed = AtomicU64::new(0);
    let hist = Mutex::new(Histogram::new());
    let wall = s.replay(
        WORKERS,
        |_| HttpClient::new(addr),
        |client, i, a| {
            let t0 = Instant::now();
            let body = wire::infer_body(&models[a.config].0, &xs[i]);
            match client.post_json("/v1/infer", &body) {
                Ok(resp) if resp.status == 200 => {
                    hist.lock().unwrap().record(t0.elapsed());
                    served.fetch_add(1, Ordering::Relaxed);
                }
                Ok(resp) if resp.status == 503 => {
                    shed.fetch_add(1, Ordering::Relaxed);
                }
                Ok(resp) => panic!("unexpected status {}: {}", resp.status, resp.body),
                Err(e) => panic!("wire error: {e}"),
            }
        },
    );
    (wall, served.load(Ordering::Relaxed), shed.load(Ordering::Relaxed), hist.into_inner().unwrap())
}

fn main() -> anyhow::Result<()> {
    let default_n = if quick() { 200 } else { 1_500 };
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(default_n);
    let mut report = Bench::new("net serving (wire front over loopback)");
    let models = build_models();
    let n_cfg = models.len();

    let server = Server::builder()
        .models(models.clone())
        .backend(Backend::Accel)
        .queue_cap(512)
        .linger(Duration::from_micros(500))
        .farm(FarmOpts {
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            ..Default::default()
        })
        .start()?;
    let net = NetServer::bind(server, "127.0.0.1:0", NetOpts { workers: WORKERS, ..Default::default() })?;
    let addr = net.addr().to_string();
    let client = net.client();
    println!("### wire front on {addr}: {n} paced requests/scenario, {WORKERS} HTTP clients");

    // single-request wire round trip (serialization + socket + farm)
    let mut rtt_client = HttpClient::new(addr.clone());
    report.case("wire rtt single infer", 20, 200, || {
        let r = rtt_client.post_json("/v1/infer", &wire::infer_body(&models[0].0, &[1, 2, 3])).unwrap();
        assert_eq!(r.status, 200);
    });
    drop(rtt_client);

    let scenarios = [
        scenario::generate(Traffic::Steady { rps: 2_000.0 }, n_cfg, n, 0xb1),
        scenario::generate(Traffic::Bursty { rps: 2_000.0, burst: 32 }, n_cfg, n, 0xb2),
        scenario::generate(Traffic::MultiTenant { rps: 2_000.0, skew: 1.2 }, n_cfg, n, 0xb3),
    ];
    let nf: Vec<usize> = models.iter().map(|(_, m)| m.n_features).collect();
    let mut t = Table::new(["scenario", "req/s", "served", "shed", "shed %", "p50 (us)", "p99 (us)"]);
    let t_all = Instant::now();
    for s in &scenarios {
        let xs = gen::arrival_features(0xcafe, &nf, s);
        let (wall, served, shed, hist) = replay_http(&addr, s, &xs, &models);
        let total = served + shed;
        let rate = total as f64 / wall.as_secs_f64();
        let shed_pct = 100.0 * shed as f64 / total.max(1) as f64;
        t.row([
            s.traffic.name().to_string(),
            format!("{rate:.0}"),
            served.to_string(),
            shed.to_string(),
            format!("{shed_pct:.1}"),
            hist.quantile_us(0.50).to_string(),
            hist.quantile_us(0.99).to_string(),
        ]);
        report.metric(&format!("{} req/s", s.traffic.name()), rate, "req/s");
        report.metric(&format!("{} p50 latency", s.traffic.name()), hist.quantile_us(0.50) as f64, "us");
        report.metric(&format!("{} p99 latency", s.traffic.name()), hist.quantile_us(0.99) as f64, "us");
        report.metric(&format!("{} shed rate", s.traffic.name()), shed_pct, "%");
    }
    print!("{}", t.render());

    // energy/request + sim-vs-wall from the farm behind the socket,
    // with the server-side per-stage waterfall
    let metrics = client.metrics()?;
    let farm = client.engine_metrics()?.farm;
    let stages = client.obs().stage_snapshot();
    print!(
        "{}",
        serving::render(
            &metrics,
            t_all.elapsed(),
            farm.as_ref(),
            &FlexicModel::paper(),
            Some(&stages),
            None,
            None,
        )
    );
    if let Some(fm) = farm.as_ref() {
        report.metric("farm sim Mcyc over the wire", fm.total_sim_cycles() as f64 / 1e6, "Mcyc");
    }
    // server-side stage quantiles, aggregated across configs, into
    // BENCH_net.json (client-observed latency is recorded above; this
    // is where the time went inside the server)
    let mut agg = flexsvm::obs::StageMetrics::default();
    for sm in stages.values() {
        agg.merge(sm);
    }
    for (stage, h) in agg.iter() {
        report.metric(&format!("stage {} p50", stage.name()), h.quantile_us(0.50) as f64, "us");
        report.metric(&format!("stage {} p99", stage.name()), h.quantile_us(0.99) as f64, "us");
    }
    let nm = net.metrics();
    report.metric("net accepted connections", nm.accepted as f64, "conns");
    report.metric("net requests", nm.requests as f64, "reqs");
    report.metric("net bytes out", nm.bytes_out as f64, "bytes");
    net.shutdown()?;

    let path = write_report("net", &[&report])?;
    println!("wrote {}", path.display());
    Ok(())
}
