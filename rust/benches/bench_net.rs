//! Wire-front serving benchmark: paced scenario replay plus the
//! device-scale streaming sweep.
//!
//! **Part A** replays the `farm::scenario` steady / bursty /
//! multi-tenant streams over loopback sockets against `net::server`
//! (coordinator + accel farm behind it).  Arrivals are paced open-loop
//! to the scenario's schedule; every request is a real HTTP
//! `POST /v1/infer`, so the numbers include JSON serialization, socket
//! hops and the net layer's admission control.
//!
//! **Part B** is the event-driven front's reason to exist: a sweep of
//! concurrent keep-alive device sessions (`farm::scenario::Streaming` +
//! `net::drive_streaming`) run against **both** fronts at shared
//! concurrency points, plus an epoll-only point at 10k devices — a
//! scale the pool front cannot hold by construction.  Each point
//! reports steady-state throughput, client p50/p99, shed/stall rates,
//! keep-alive reuse, and the peak of the server's open-connection
//! gauge (sampled live, proving the sessions really were concurrent).
//! Predictions are checked bit-exact against `svm::infer::predict`
//! throughout.  Results land in `BENCH_net.json` through benchkit; CI
//! gates on zero epoll shed at smoke concurrency and epoll throughput
//! >= pool at every shared point.
//!
//!     cargo bench --bench bench_net [n_requests]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use flexsvm::coordinator::metrics::Histogram;
use flexsvm::coordinator::{Backend, Server};
use flexsvm::farm::scenario::{self, Traffic};
use flexsvm::farm::FarmOpts;
use flexsvm::net::{drive_streaming, raise_nofile, wire, HttpClient, NetFront, NetOpts, NetServer};
use flexsvm::power::FlexicModel;
use flexsvm::report::serving;
use flexsvm::serv::TimingConfig;
use flexsvm::svm::QuantModel;
use flexsvm::testing::gen;
use flexsvm::util::benchkit::{quick, write_report, Bench};
use flexsvm::util::Table;

const WORKERS: usize = 8;

/// Four tiny synthetic configs: the bench needs no artifacts, and tiny
/// models keep the simulated farm fast enough to stress the wire.
fn build_models() -> Vec<(String, QuantModel)> {
    ["syn_a", "syn_b", "syn_c", "syn_d"]
        .iter()
        .enumerate()
        .map(|(i, k)| (k.to_string(), gen::tiny_model(k, i % 2 == 1)))
        .collect()
}

/// Replay one scenario over HTTP (paced by `Scenario::replay`, one
/// keep-alive client per worker); returns (wall, served, shed,
/// client-side latency histogram).
fn replay_http(
    addr: &str,
    s: &scenario::Scenario,
    xs: &[Vec<i32>],
    models: &[(String, QuantModel)],
) -> (Duration, u64, u64, Histogram) {
    let served = AtomicU64::new(0);
    let shed = AtomicU64::new(0);
    let hist = Mutex::new(Histogram::new());
    let wall = s.replay(
        WORKERS,
        |_| HttpClient::new(addr),
        |client, i, a| {
            let t0 = Instant::now();
            let body = wire::infer_body(&models[a.config].0, &xs[i]);
            match client.post_json("/v1/infer", &body) {
                Ok(resp) if resp.status == 200 => {
                    hist.lock().unwrap().record(t0.elapsed());
                    served.fetch_add(1, Ordering::Relaxed);
                }
                Ok(resp) if resp.status == 503 => {
                    shed.fetch_add(1, Ordering::Relaxed);
                }
                Ok(resp) => panic!("unexpected status {}: {}", resp.status, resp.body),
                Err(e) => panic!("wire error: {e}"),
            }
        },
    );
    (wall, served.load(Ordering::Relaxed), shed.load(Ordering::Relaxed), hist.into_inner().unwrap())
}

/// One streaming sweep point, measured against a fresh server.
struct StreamPoint {
    front: NetFront,
    devices: usize,
    rps: f64,
    p50_us: u64,
    p99_us: u64,
    shed: u64,
    stalled: u64,
    reused: u64,
    mismatches: u64,
    /// Peak of the server's live open-connection gauge during the
    /// drive — the proof the sessions were actually concurrent.
    peak_open: u64,
}

/// Stand up a fresh coordinator + wire front, hold `devices` keep-alive
/// sessions open against it, and measure the steady-state rounds.  An
/// open-connection sampler rides along to capture the concurrency peak.
fn stream_point(
    front: NetFront,
    devices: usize,
    rounds: usize,
    models: &[(String, QuantModel)],
) -> anyhow::Result<StreamPoint> {
    let server = Server::builder()
        .models(models.to_vec())
        .backend(Backend::Accel)
        .queue_cap(1024)
        .linger(Duration::from_micros(200))
        .farm(FarmOpts {
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            // analytic fast path keeps 10k-device rounds quick while
            // the differential audit still exercises the full SoC
            fastpath: true,
            audit_rate: 64,
            ..Default::default()
        })
        .start()?;
    let opts = NetOpts {
        front,
        // the pool front's honest best at device scale: a big pool and
        // a small backlog, so starvation sheds fast instead of parking
        workers: 64,
        conn_backlog: 4,
        // devices report on long-lived sessions: idle between rounds
        // must not count as abandonment
        keep_alive: Duration::from_secs(30),
        ..Default::default()
    };
    let net = NetServer::bind(server, "127.0.0.1:0", opts)?;
    let addr = net.addr().to_string();
    let s = scenario::Streaming::new(devices, models.len(), 8, 0xd1ce ^ devices as u64);
    let threads = devices.clamp(1, 16);

    let stop = AtomicBool::new(false);
    let peak = AtomicU64::new(0);
    let r = std::thread::scope(|sc| {
        let sampler = sc.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                peak.fetch_max(net.metrics().active, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        let r = drive_streaming(&addr, &s, models, rounds, threads);
        stop.store(true, Ordering::Relaxed);
        sampler.join().expect("open-conns sampler panicked");
        r
    })?;
    net.shutdown()?;

    Ok(StreamPoint {
        front,
        devices,
        rps: r.served as f64 / r.wall.as_secs_f64().max(1e-9),
        p50_us: r.latency.quantile_us(0.50),
        p99_us: r.latency.quantile_us(0.99),
        shed: r.shed,
        stalled: r.stalled,
        reused: r.connections_reused,
        mismatches: r.native_mismatch,
        peak_open: peak.load(Ordering::Relaxed),
    })
}

fn main() -> anyhow::Result<()> {
    let default_n = if quick() { 200 } else { 1_500 };
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(default_n);
    let mut report = Bench::new("net serving (wire front over loopback)");
    let models = build_models();
    let n_cfg = models.len();

    let server = Server::builder()
        .models(models.clone())
        .backend(Backend::Accel)
        .queue_cap(512)
        .linger(Duration::from_micros(500))
        // generous objectives: the verdict lands in BENCH_net.json so a
        // regression that tanks availability or p99 flips it to degraded
        .obs_opts(flexsvm::obs::ObsOpts {
            slo: Some("p99=2s,avail=50".parse().expect("static SLO spec")),
            ..Default::default()
        })
        .farm(FarmOpts {
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            ..Default::default()
        })
        .start()?;
    let net = NetServer::bind(
        server,
        "127.0.0.1:0",
        NetOpts { workers: WORKERS, ..Default::default() },
    )?;
    let addr = net.addr().to_string();
    let client = net.client();
    println!(
        "### wire front on {addr} ({} front): {n} paced requests/scenario, {WORKERS} HTTP clients",
        net.front()
    );

    // single-request wire round trip (serialization + socket + farm)
    let mut rtt_client = HttpClient::new(addr.clone());
    report.case("wire rtt single infer", 20, 200, || {
        let r = rtt_client.post_json("/v1/infer", &wire::infer_body(&models[0].0, &[1, 2, 3])).unwrap();
        assert_eq!(r.status, 200);
    });
    drop(rtt_client);

    let scenarios = [
        scenario::generate(Traffic::Steady { rps: 2_000.0 }, n_cfg, n, 0xb1),
        scenario::generate(Traffic::Bursty { rps: 2_000.0, burst: 32 }, n_cfg, n, 0xb2),
        scenario::generate(Traffic::MultiTenant { rps: 2_000.0, skew: 1.2 }, n_cfg, n, 0xb3),
    ];
    let nf: Vec<usize> = models.iter().map(|(_, m)| m.n_features).collect();
    let mut t = Table::new(["scenario", "req/s", "served", "shed", "shed %", "p50 (us)", "p99 (us)"]);
    let t_all = Instant::now();
    for s in &scenarios {
        let xs = gen::arrival_features(0xcafe, &nf, s);
        let (wall, served, shed, hist) = replay_http(&addr, s, &xs, &models);
        let total = served + shed;
        let rate = total as f64 / wall.as_secs_f64();
        let shed_pct = 100.0 * shed as f64 / total.max(1) as f64;
        t.row([
            s.traffic.name().to_string(),
            format!("{rate:.0}"),
            served.to_string(),
            shed.to_string(),
            format!("{shed_pct:.1}"),
            hist.quantile_us(0.50).to_string(),
            hist.quantile_us(0.99).to_string(),
        ]);
        report.metric(&format!("{} req/s", s.traffic.name()), rate, "req/s");
        report.metric(&format!("{} p50 latency", s.traffic.name()), hist.quantile_us(0.50) as f64, "us");
        report.metric(&format!("{} p99 latency", s.traffic.name()), hist.quantile_us(0.99) as f64, "us");
        report.metric(&format!("{} shed rate", s.traffic.name()), shed_pct, "%");
    }
    print!("{}", t.render());

    // energy/request + sim-vs-wall from the farm behind the socket,
    // with the server-side per-stage waterfall and the net gauges
    let metrics = client.metrics()?;
    let farm = client.engine_metrics()?.farm;
    let stages = client.obs().stage_snapshot();
    let nm = net.metrics();
    let slo = client.obs().slo_snapshot();
    print!(
        "{}",
        serving::render(
            &metrics,
            t_all.elapsed(),
            farm.as_ref(),
            &FlexicModel::paper(),
            Some(&stages),
            None,
            None,
            Some(&nm),
            slo.as_ref(),
        )
    );
    if let Some(fm) = farm.as_ref() {
        report.metric("farm sim Mcyc over the wire", fm.total_sim_cycles() as f64 / 1e6, "Mcyc");
    }
    // server-side stage quantiles, aggregated across configs, into
    // BENCH_net.json (client-observed latency is recorded above; this
    // is where the time went inside the server)
    let mut agg = flexsvm::obs::StageMetrics::default();
    for sm in stages.values() {
        agg.merge(sm);
    }
    for (stage, h) in agg.iter() {
        report.metric(&format!("stage {} p50", stage.name()), h.quantile_us(0.50) as f64, "us");
        report.metric(&format!("stage {} p99", stage.name()), h.quantile_us(0.99) as f64, "us");
    }
    report.metric("net accepted connections", nm.accepted as f64, "conns");
    report.metric("net requests", nm.requests as f64, "reqs");
    report.metric("net bytes out", nm.bytes_out as f64, "bytes");
    if let Some(s) = &slo {
        report.metric("slo healthy", s.healthy() as u64 as f64, "bool");
        let worst =
            s.configs.iter().map(|c| c.burn_long).fold(0.0f64, f64::max);
        report.metric("slo worst long-window burn", worst, "x");
        println!("SLO verdict: {}", s.verdict());
    }
    net.shutdown()?;

    // ---- Part B: device-scale streaming, pool vs epoll -------------
    let mut streaming = Bench::new("streaming (concurrent keep-alive device sessions)");
    // shared concurrency points run on both fronts; the 10k point is
    // epoll-only (the pool cannot hold it by construction)
    let (shared, epoll_only, rounds): (&[usize], &[usize], usize) = if quick() {
        (&[64, 256], &[], 3)
    } else {
        (&[256, 2_048], &[10_000], 4)
    };
    let max_devices = shared.iter().chain(epoll_only).copied().max().unwrap_or(0);
    // client + server sockets live in this one process: ~2 fds/device
    let nofile = raise_nofile((4 * max_devices + 256) as u64);
    streaming.metric("nofile soft limit", nofile as f64, "fds");
    let fronts: &[NetFront] = if cfg!(target_os = "linux") {
        &[NetFront::Pool, NetFront::Epoll]
    } else {
        &[NetFront::Pool]
    };
    let mut points: Vec<StreamPoint> = Vec::new();
    for &devices in shared {
        for &front in fronts {
            points.push(stream_point(front, devices, rounds, &models)?);
        }
    }
    for &devices in epoll_only {
        if nofile < (2 * devices + 256) as u64 {
            println!("skipping {devices}-device point: nofile limit {nofile} too low");
            streaming.metric("epoll 10k point skipped (nofile)", 1.0, "flag");
            continue;
        }
        points.push(stream_point(NetFront::Epoll, devices, rounds, &models)?);
    }
    let mut st = Table::new([
        "front", "devices", "req/s", "p50 (us)", "p99 (us)", "shed", "stalled", "reused",
        "peak open",
    ]);
    let mut total_mismatches = 0u64;
    for p in &points {
        st.row([
            p.front.to_string(),
            p.devices.to_string(),
            format!("{:.0}", p.rps),
            p.p50_us.to_string(),
            p.p99_us.to_string(),
            p.shed.to_string(),
            p.stalled.to_string(),
            p.reused.to_string(),
            p.peak_open.to_string(),
        ]);
        let tag = format!("streaming {} {}dev", p.front, p.devices);
        streaming.metric(&format!("{tag} req/s"), p.rps, "req/s");
        streaming.metric(&format!("{tag} p50 latency"), p.p50_us as f64, "us");
        streaming.metric(&format!("{tag} p99 latency"), p.p99_us as f64, "us");
        streaming.metric(&format!("{tag} shed"), p.shed as f64, "reqs");
        streaming.metric(&format!("{tag} stalled"), p.stalled as f64, "reqs");
        streaming.metric(&format!("{tag} reused"), p.reused as f64, "reqs");
        streaming.metric(&format!("{tag} peak open conns"), p.peak_open as f64, "conns");
        total_mismatches += p.mismatches;
    }
    println!("\n### streaming sweep ({rounds} rounds, first = connect/warm, excluded)");
    print!("{}", st.render());
    streaming.metric("streaming native mismatches", total_mismatches as f64, "preds");
    assert_eq!(total_mismatches, 0, "wire answers must be bit-identical to svm::infer");

    let path = write_report("net", &[&report, &streaming])?;
    println!("wrote {}", path.display());
    Ok(())
}
