//! Bench target for Table I (experiment T1 in DESIGN.md §4): regenerates
//! every row of the paper's evaluation on the cycle-accurate SoC and
//! prints paper-vs-measured speedups.
//!
//!     cargo bench --bench bench_table1

use flexsvm::report::{run_table1, table1, Table1Opts};
use flexsvm::svm::model::{artifacts_root, Manifest};
use flexsvm::util::Table;

/// Paper Table I speedups, keyed like our configs (for shape comparison).
const PAPER_SPEEDUP: &[(&str, f64)] = &[
    ("bs_ovr_w4", 31.3), ("bs_ovr_w8", 23.5), ("bs_ovr_w16", 16.5),
    ("bs_ovo_w4", 15.7), ("bs_ovo_w8", 13.5), ("bs_ovo_w16", 11.0),
    ("derm_ovr_w4", 4.9), ("derm_ovr_w8", 2.3), ("derm_ovr_w16", 1.6),
    ("derm_ovo_w4", 3.1), ("derm_ovo_w8", 1.9), ("derm_ovo_w16", 1.5),
    ("iris_ovr_w4", 36.2), ("iris_ovr_w8", 27.7), ("iris_ovr_w16", 19.7),
    ("iris_ovo_w4", 32.6), ("iris_ovo_w8", 28.2), ("iris_ovo_w16", 22.7),
    ("seeds_ovr_w4", 33.7), ("seeds_ovr_w8", 25.0), ("seeds_ovr_w16", 14.0),
    ("seeds_ovo_w4", 36.4), ("seeds_ovo_w8", 30.4), ("seeds_ovo_w16", 14.4),
    ("v3_ovr_w4", 48.6), ("v3_ovr_w8", 36.5), ("v3_ovr_w16", 23.6),
    ("v3_ovo_w4", 39.5), ("v3_ovo_w8", 33.5), ("v3_ovo_w16", 16.4),
];

fn paper_speedup(key: &str) -> Option<f64> {
    PAPER_SPEEDUP.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_root())?;
    let t0 = std::time::Instant::now();
    let rows = run_table1(&manifest, &Table1Opts::default())?;
    let wall = t0.elapsed();

    println!("=== Table I (measured on the cycle-accurate SERV SoC) ===");
    print!("{}", table1::render(&rows, true));

    println!("=== paper-vs-measured speedup shape ===");
    let mut t = Table::new(["config", "paper (x)", "ours (x)", "ratio"]);
    let mut same_direction = 0usize;
    for r in &rows {
        if let Some(p) = paper_speedup(&r.key) {
            t.row([
                r.key.clone(),
                format!("{p:.1}"),
                format!("{:.1}", r.speedup),
                format!("{:.2}", r.speedup / p),
            ]);
            if r.speedup > 1.0 {
                same_direction += 1;
            }
        }
    }
    print!("{}", t.render());
    println!(
        "\naccelerator wins in {}/{} configs (paper: 30/30); total bench wall time {:.1}s",
        same_direction,
        rows.len(),
        wall.as_secs_f64()
    );

    // machine-readable output for EXPERIMENTS.md
    std::fs::write("artifacts/table1_measured.json", table1::to_json(&rows).to_string())?;
    println!("wrote artifacts/table1_measured.json");
    Ok(())
}
