//! Observability overhead benchmark: what the guest-cycle continuous
//! profiler costs on the block-compiled SoC hot path, and what a
//! flight-recorder emit costs per event.
//!
//!     cargo bench --bench bench_obs
//!
//! Writes `BENCH_obs.json` (CI perf smoke gates the 1-in-64 sampled
//! profiler at <= 10% overhead over the unprofiled runner).  Every
//! profiled run is also checked for the conservation contract —
//! attributed per-block cycles must equal `CycleStats::total()`
//! bit-exactly — so the overhead number can never come from dropping
//! accounting work.

use flexsvm::obs::{log as evlog, BlockProfiler, ConfigProfile};
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::testing::gen;
use flexsvm::util::benchkit::{quick, write_report, Bench};

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("observability overhead (profiler + event log)");
    let iters = if quick() { 40 } else { 400 };

    for (key, model) in [
        ("syn_a", gen::tiny_model("syn_a", false)),
        ("syn_rbf", gen::tiny_kernel_model("syn_rbf", flexsvm::kernel::Kernel::Rbf)),
    ] {
        let x: Vec<i32> = (0..model.n_features as i32).map(|i| (i * 7) % 16).collect();
        let mut runner =
            ProgramRunner::accelerated(&model, TimingConfig::flexic(), ProgramOpts::default())?;
        let (pred_ref, stats_ref) = runner.run_sample(&x)?;

        // baseline: the unprofiled hot path the farm runs by default
        let s_off = b.case(&format!("{key} profiler off"), 2, iters, || {
            let (p, s) = runner.run_sample(&x).unwrap();
            assert_eq!((p, s.total()), (pred_ref, stats_ref.total()));
        });

        // 1-in-64 sampling: the production cadence CI gates on
        let mut tick = 0u64;
        let mut profile = ConfigProfile::new();
        let regions = runner.program().regions.clone();
        let s_sampled = b.case(&format!("{key} profiler 1-in-64"), 2, iters, || {
            tick += 1;
            if tick % 64 == 0 {
                let mut prof = BlockProfiler::new();
                let (p, s) = runner.run_sample_profiled(&x, &mut prof).unwrap();
                assert_eq!((p, s.total()), (pred_ref, stats_ref.total()));
                assert_eq!(prof.attributed(), s.total(), "conservation");
                profile.absorb(&prof, &regions);
            } else {
                let (p, _) = runner.run_sample(&x).unwrap();
                assert_eq!(p, pred_ref);
            }
        });

        // always-on: the worst case (what `--profile-rate 1` costs)
        let s_always = b.case(&format!("{key} profiler always-on"), 2, iters, || {
            let mut prof = BlockProfiler::new();
            let (p, s) = runner.run_sample_profiled(&x, &mut prof).unwrap();
            assert_eq!(p, pred_ref);
            assert_eq!(prof.attributed(), s.total(), "conservation");
        });

        let ns_off = s_off.median.as_secs_f64();
        b.metric(
            &format!("{key} profiler off"),
            stats_ref.total() as f64 / ns_off / 1e6,
            "Mcyc/s",
        );
        b.metric(
            &format!("{key} overhead 1-in-64"),
            s_sampled.median.as_secs_f64() / ns_off,
            "x",
        );
        b.metric(
            &format!("{key} overhead always-on"),
            s_always.median.as_secs_f64() / ns_off,
            "x",
        );
    }

    // flight recorder: cost of one suppressed emit (below threshold —
    // the common case on the hot path) vs one recorded emit
    evlog::set_level(evlog::Level::Info);
    let n_emit = if quick() { 10_000 } else { 100_000 };
    let s_sup = b.case("log emit suppressed (debug under info)", 2, 20, || {
        for i in 0..n_emit {
            evlog::emit_fmt(evlog::Level::Debug, "bench_suppressed", || format!("event {i}"));
        }
    });
    let s_rec = b.case("log emit recorded (info)", 2, 20, || {
        for i in 0..n_emit {
            evlog::emit_fmt(evlog::Level::Info, "bench_recorded", || format!("event {i}"));
        }
    });
    b.metric("log suppressed emit", s_sup.median.as_secs_f64() / n_emit as f64 * 1e9, "ns");
    b.metric("log recorded emit", s_rec.median.as_secs_f64() / n_emit as f64 * 1e9, "ns");

    let path = write_report("obs", &[&b])?;
    println!("wrote {}", path.display());
    Ok(())
}
