//! Design-choice ablations (DESIGN.md §4, ABL-1/ABL-2):
//!
//!  * ABL-1 — PE multiplier count: fewer 4×4 multipliers serialise each
//!    calc pass (ceil(8/lanes) accelerator cycles instead of 1), but cut
//!    accelerator gates/power.  Latency-energy-area trade-off table.
//!  * ABL-2 — memory-latency sensitivity: sweep the FE memory model
//!    (read/write/overhead) and report how the headline speedup moves —
//!    the paper's Dermatology observation ("execution latency is mainly
//!    dominated by memory access delays") quantified.
//!  * ABL-3 — program shape: unrolled vs looped accelerated program.
//!
//!     cargo bench --bench bench_ablation

use flexsvm::power::FlexicModel;
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::svm::model::{artifacts_root, Manifest};
use flexsvm::util::Table;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_root())?;
    let power = FlexicModel::paper();

    // ---- ABL-1: PE lane count --------------------------------------------
    println!("### ABL-1: PE multiplier count (iris_ovr_w4, one inference)");
    let entry = manifest.config("iris_ovr_w4")?;
    let model = manifest.model(entry)?;
    let test = manifest.test_set("iris")?;
    let x = &test.x_q[0];
    let mut runner =
        ProgramRunner::accelerated(&model, TimingConfig::flexic(), ProgramOpts::default())?;
    let (_, stats) = runner.run_sample(x)?;
    // calc ops = cfu_ops - create_env - K res ops
    let k = model.weights.len() as u64;
    let calc_ops = stats.cfu_ops - 1 - k;
    let mut t = Table::new(["PE lanes", "accel cyc/inf", "accel gates", "accel mW", "energy/inf (mJ)", "rel. latency"]);
    let base_total = stats.total();
    for lanes in [8u64, 4, 2, 1] {
        // each calc pass serialises to ceil(8/lanes) accelerator cycles
        let extra = calc_ops * (8 / lanes - 1);
        let total = base_total + extra;
        // gate model: multipliers scale, the rest of the accelerator stays
        let full_gates = 2000u64;
        let mult_gates = 8 * 90;
        let gates = full_gates - mult_gates + lanes * 90;
        let accel_mw = power.accel_mw_scaled(gates);
        let energy = (power.serv_mw + accel_mw) * (total as f64 / power.clock_hz);
        t.row([
            lanes.to_string(),
            total.to_string(),
            gates.to_string(),
            format!("{accel_mw:.3}"),
            format!("{energy:.3}"),
            format!("{:.3}", total as f64 / base_total as f64),
        ]);
    }
    print!("{}", t.render());
    println!("(8 lanes = the paper's design point; 1 lane ~ a bespoke serial MAC)\n");

    // ---- ABL-2: memory latency sweep --------------------------------------
    println!("### ABL-2: memory-latency sensitivity (speedup of accel vs baseline)");
    let mut t2 = Table::new(["mem model (rd/wr/ovh)", "iris_ovr_w4", "derm_ovo_w16"]);
    let sweeps: &[(&str, u64, u64, u64)] = &[
        ("ideal (1/1/0)", 1, 1, 0),
        ("half paper (23/24/32)", 23, 24, 32),
        ("paper (46/47/64)", 46, 47, 64),
        ("2x paper (92/94/128)", 92, 94, 128),
        ("4x paper (184/188/256)", 184, 188, 256),
    ];
    for &(name, r, w, o) in sweeps {
        let timing = TimingConfig { mem_read: r, mem_write: w, mem_overhead: o, ..TimingConfig::flexic() };
        let mut cells = vec![name.to_string()];
        for key in ["iris_ovr_w4", "derm_ovo_w16"] {
            let entry = manifest.config(key)?;
            let model = manifest.model(entry)?;
            let test = manifest.test_set(&entry.dataset)?;
            let x = &test.x_q[0];
            let bc = ProgramRunner::baseline(&model, timing)?.run_sample(x)?.1.total();
            let ac = ProgramRunner::accelerated(&model, timing, ProgramOpts::default())?
                .run_sample(x)?
                .1
                .total();
            cells.push(format!("{:.1}x", bc as f64 / ac as f64));
        }
        t2.row(cells);
    }
    print!("{}", t2.render());
    println!("(speedup shrinks as memory dominates — the paper's Dermatology effect)\n");

    // ---- ABL-3: unrolled vs looped accelerated program --------------------
    println!("### ABL-3: program shape (accel cycles/inference)");
    let mut t3 = Table::new(["config", "unrolled", "looped", "unroll gain"]);
    for key in ["iris_ovr_w4", "bs_ovo_w8", "derm_ovr_w4", "derm_ovo_w16"] {
        let entry = manifest.config(key)?;
        let model = manifest.model(entry)?;
        let test = manifest.test_set(&entry.dataset)?;
        let x = &test.x_q[0];
        let un = ProgramRunner::accelerated(
            &model,
            TimingConfig::flexic(),
            ProgramOpts { unroll_limit: usize::MAX },
        )?
        .run_sample(x)?
        .1
        .total();
        let lo = ProgramRunner::accelerated(
            &model,
            TimingConfig::flexic(),
            ProgramOpts { unroll_limit: 0 },
        )?
        .run_sample(x)?
        .1
        .total();
        t3.row([
            key.to_string(),
            un.to_string(),
            lo.to_string(),
            format!("{:.2}x", lo as f64 / un as f64),
        ]);
    }
    print!("{}", t3.render());
    Ok(())
}
