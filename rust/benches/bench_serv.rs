//! SERV simulator performance (the L3 hot path of every Table-I run):
//! simulated cycles/s and instructions/s over representative programs.
//!
//!     cargo bench --bench bench_serv

use flexsvm::isa::reg::*;
use flexsvm::isa::Asm;
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::soc::Soc;
use flexsvm::util::benchkit::{manifest_or_skip, Bench};

/// A compute-heavy loop: N iterations of add/xor/shift/branch.
fn alu_loop(n: i32) -> Asm {
    let mut a = Asm::new(0);
    a.li(T0, n);
    a.li(T1, 0);
    a.label("loop");
    a.add(T1, T1, T0);
    a.xori(T1, T1, 0x5a);
    a.slli(T2, T1, 3);
    a.srli(T2, T2, 3);
    a.addi(T0, T0, -1);
    a.bne(T0, ZERO, "loop");
    a.mv(A0, T1);
    a.ecall();
    a
}

/// A memory-heavy loop: load/store ping-pong.
fn mem_loop(n: i32) -> Asm {
    let mut a = Asm::new(0);
    a.la(S0, "buf");
    a.li(T0, n);
    a.label("loop");
    a.lw(T1, S0, 0);
    a.addi(T1, T1, 3);
    a.sw(S0, T1, 0);
    a.lw(T1, S0, 4);
    a.sw(S0, T1, 4);
    a.addi(T0, T0, -1);
    a.bne(T0, ZERO, "loop");
    a.lw(A0, S0, 0);
    a.ecall();
    a.label("buf");
    a.zeros(2);
    a
}

fn main() -> anyhow::Result<()> {
    let b = Bench::new("SERV simulator throughput");

    for (name, asm) in [("alu_loop_5k", alu_loop(5000)), ("mem_loop_5k", mem_loop(5000))] {
        let image = asm.assemble_bytes()?;
        let mut cycles = 0u64;
        let mut instrs = 0u64;
        let s = b.case(name, 2, 10, || {
            let mut soc = Soc::new(&image, TimingConfig::flexic());
            let r = soc.run(100_000_000).unwrap();
            cycles = r.stats.total();
            instrs = r.stats.instret;
        });
        b.metric(
            &format!("{name} simulated"),
            cycles as f64 / s.median.as_secs_f64() / 1e6,
            "Mcyc/s",
        );
        b.metric(
            &format!("{name} retired"),
            instrs as f64 / s.median.as_secs_f64() / 1e6,
            "Minstr/s",
        );
    }

    // end-to-end inference programs (what bench_table1 spends time in)
    let Some(manifest) = manifest_or_skip("bench_serv inference section") else {
        return Ok(());
    };
    let b2 = Bench::new("inference program simulation");
    for key in ["iris_ovr_w4", "derm_ovo_w16"] {
        let entry = manifest.config(key)?;
        let model = manifest.model(entry)?;
        let test = manifest.test_set(&entry.dataset)?;
        let x = &test.x_q[0];

        let mut base = ProgramRunner::baseline(&model, TimingConfig::flexic())?;
        let mut cyc = 0u64;
        let s = b2.case(&format!("{key} baseline 1 inf"), 1, 10, || {
            cyc = base.run_sample(x).unwrap().1.total();
        });
        b2.metric(&format!("{key} baseline"), cyc as f64 / s.median.as_secs_f64() / 1e6, "Mcyc/s");

        let mut acc = ProgramRunner::accelerated(&model, TimingConfig::flexic(), ProgramOpts::default())?;
        b2.case(&format!("{key} accel 1 inf"), 1, 50, || {
            acc.run_sample(x).unwrap();
        });
    }
    Ok(())
}
