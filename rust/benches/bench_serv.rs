//! SERV simulator performance (the L3 hot path of every Table-I run
//! and of the farm's serving path): simulated cycles/s over
//! representative programs, block-compiled engine vs step interpreter.
//!
//!     cargo bench --bench bench_serv
//!
//! Writes `BENCH_serv.json` at the repo root (cases, ns, Mcyc/s,
//! block-vs-step speedups).  `FLEXSVM_BENCH_QUICK=1` runs a reduced
//! iteration count (CI perf smoke).

use flexsvm::isa::reg::*;
use flexsvm::isa::Asm;
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::soc::Soc;
use flexsvm::util::benchkit::{manifest_or_skip, write_report, Bench};

/// A compute-heavy loop: N iterations of add/xor/shift/branch.
fn alu_loop(n: i32) -> Asm {
    let mut a = Asm::new(0);
    a.li(T0, n);
    a.li(T1, 0);
    a.label("loop");
    a.add(T1, T1, T0);
    a.xori(T1, T1, 0x5a);
    a.slli(T2, T1, 3);
    a.srli(T2, T2, 3);
    a.addi(T0, T0, -1);
    a.bne(T0, ZERO, "loop");
    a.mv(A0, T1);
    a.ecall();
    a
}

/// A memory-heavy loop: load/store ping-pong.
fn mem_loop(n: i32) -> Asm {
    let mut a = Asm::new(0);
    a.la(S0, "buf");
    a.li(T0, n);
    a.label("loop");
    a.lw(T1, S0, 0);
    a.addi(T1, T1, 3);
    a.sw(S0, T1, 0);
    a.lw(T1, S0, 4);
    a.sw(S0, T1, 4);
    a.addi(T0, T0, -1);
    a.bne(T0, ZERO, "loop");
    a.lw(A0, S0, 0);
    a.ecall();
    a.label("buf");
    a.zeros(2);
    a
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("SERV simulator throughput: block engine vs step interpreter");

    for (name, asm) in [("alu_loop_5k", alu_loop(5000)), ("mem_loop_5k", mem_loop(5000))] {
        let image = asm.assemble_bytes()?;

        // block-compiled engine: the translation is built once and
        // survives rearm() — exactly the farm's warm-runner hot path
        let mut blk = Soc::new(&image, TimingConfig::flexic());
        let mut cycles = 0u64;
        let mut instrs = 0u64;
        let s_blk = b.case(&format!("{name} block"), 2, 10, || {
            blk.rearm();
            let r = blk.run(100_000_000).unwrap();
            cycles = r.stats.total();
            instrs = r.stats.instret;
        });

        // step interpreter (the traced path) on an identical SoC
        let mut stp = Soc::new(&image, TimingConfig::flexic());
        let mut cycles_step = 0u64;
        let s_stp = b.case(&format!("{name} step"), 2, 10, || {
            stp.rearm();
            let r = stp.run_traced(100_000_000, None).unwrap();
            cycles_step = r.stats.total();
        });
        assert_eq!(cycles, cycles_step, "{name}: engines must account identical cycles");

        let mcyc_blk = cycles as f64 / s_blk.median.as_secs_f64() / 1e6;
        let mcyc_stp = cycles_step as f64 / s_stp.median.as_secs_f64() / 1e6;
        b.metric(&format!("{name} block"), mcyc_blk, "Mcyc/s");
        b.metric(&format!("{name} step"), mcyc_stp, "Mcyc/s");
        b.metric(
            &format!("{name} retired (block)"),
            instrs as f64 / s_blk.median.as_secs_f64() / 1e6,
            "Minstr/s",
        );
        b.metric(&format!("{name} block/step speedup"), mcyc_blk / mcyc_stp, "x");
    }

    // end-to-end inference programs (what bench_table1 spends time in)
    let Some(manifest) = manifest_or_skip("bench_serv inference section") else {
        let path = write_report("serv", &[&b])?;
        println!("\nwrote {}", path.display());
        return Ok(());
    };
    let mut b2 = Bench::new("inference program simulation");
    for key in ["iris_ovr_w4", "derm_ovo_w16"] {
        let entry = manifest.config(key)?;
        let model = manifest.model(entry)?;
        let test = manifest.test_set(&entry.dataset)?;
        let x = &test.x_q[0];

        let mut base = ProgramRunner::baseline(&model, TimingConfig::flexic())?;
        let mut cyc = 0u64;
        let s = b2.case(&format!("{key} baseline 1 inf"), 1, 10, || {
            cyc = base.run_sample(x).unwrap().1.total();
        });
        b2.metric(&format!("{key} baseline"), cyc as f64 / s.median.as_secs_f64() / 1e6, "Mcyc/s");

        let mut acc = ProgramRunner::accelerated(&model, TimingConfig::flexic(), ProgramOpts::default())?;
        b2.case(&format!("{key} accel 1 inf"), 1, 50, || {
            acc.run_sample(x).unwrap();
        });
    }
    let path = write_report("serv", &[&b, &b2])?;
    println!("\nwrote {}", path.display());
    Ok(())
}
