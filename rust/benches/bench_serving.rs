//! Coordinator serving benchmark: throughput and tail latency vs batch
//! policy and backend (experiment E2E support data).  The client loop
//! lives in `util::benchkit::drive_clients`, shared with
//! `examples/serve_inference.rs` and the farm bench.
//!
//! Runs against the real Table-I artifacts when present, otherwise
//! against the synthetic tiny models — either way it emits
//! `BENCH_serving.json` (CI uploads it), including the serving-level
//! `fastpath_speedup` of the analytic fast path over full simulation
//! on the Accel backend.
//!
//!     cargo bench --bench bench_serving

use std::time::Duration;

use flexsvm::coordinator::{Backend, Server};
use flexsvm::farm::FarmOpts;
use flexsvm::obs::{ObsOpts, SloSnapshot, StageMetrics};
use flexsvm::svm::infer;
use flexsvm::svm::model::artifacts_root;
use flexsvm::svm::{QuantModel, TestSet};
use flexsvm::testing::gen;
use flexsvm::util::benchkit::{
    drive_clients, latency_summary, load_testsets, manifest_or_skip, quick, write_report, Bench,
};
use flexsvm::util::{Pcg32, Table};

const WORKERS: usize = 8;

fn requests() -> usize {
    if quick() {
        800
    } else {
        8_000
    }
}

/// Deterministic in-memory models + natively-labelled feature streams
/// (the artifact-free fallback, mirroring `serve --synthetic`); the
/// mix includes one config per kernel family so the batch-policy and
/// fastpath numbers cover the RBF/poly machines too.
fn synthetic_setup() -> (Vec<(String, QuantModel)>, Vec<(String, TestSet)>) {
    let models = vec![
        ("syn_a".to_string(), gen::tiny_model("syn_a", false)),
        ("syn_b".to_string(), gen::tiny_model("syn_b", true)),
        ("syn_rbf".to_string(), gen::tiny_kernel_model("syn_rbf", flexsvm::kernel::Kernel::Rbf)),
        (
            "syn_poly".to_string(),
            gen::tiny_kernel_model("syn_poly", flexsvm::kernel::Kernel::Poly),
        ),
    ];
    let mut rng = Pcg32::seeded(0x5e1f);
    let testsets = models
        .iter()
        .map(|(key, model)| {
            let x_q: Vec<Vec<i32>> =
                (0..64).map(|_| gen::features(&mut rng, model.n_features)).collect();
            let y: Vec<i32> = x_q.iter().map(|x| infer::predict(model, x)).collect();
            let t = TestSet {
                name: key.clone(),
                n_classes: model.n_classes,
                n_features: model.n_features,
                x_q,
                y,
            };
            (key.clone(), t)
        })
        .collect();
    (models, testsets)
}

fn drive(
    testsets: &[(String, TestSet)],
    models: Option<&[(String, QuantModel)]>,
    backend: Backend,
    farm: FarmOpts,
    batch_max: usize,
    linger_us: u64,
    eager: bool,
) -> anyhow::Result<(f64, u64, u64, f64, StageMetrics, Option<SloSnapshot>)> {
    let keys: Vec<String> = testsets.iter().map(|(k, _)| k.clone()).collect();
    let builder = Server::builder()
        .backend(backend)
        .batch_max(batch_max)
        .compiled_batch(64)
        .linger(Duration::from_micros(linger_us))
        .queue_cap(4096)
        .eager_flush(eager)
        // generous objectives: the verdict rides into BENCH_serving.json
        // so a regression that tanks tail latency flips it to degraded
        .obs_opts(ObsOpts {
            slo: Some("p99=2s,avail=50".parse().expect("static SLO spec")),
            ..Default::default()
        })
        .farm(farm);
    let builder = match models {
        Some(ms) => builder.models(ms.to_vec()),
        None => builder.artifacts(artifacts_root(), keys),
    };
    let server = builder.start()?;
    let client = server.client();
    let r = drive_clients(&client, testsets, requests(), WORKERS, None)?;
    let s = latency_summary(&client.metrics()?);
    // stage histograms aggregated across configs (where the time went
    // inside the coordinator, to pair with the end-to-end quantiles)
    let mut stages = StageMetrics::default();
    for sm in client.obs().stage_snapshot().values() {
        stages.merge(sm);
    }
    let slo = client.obs().slo_snapshot();
    Ok((r.served as f64 / r.wall.as_secs_f64(), s.p50_us, s.p99_us, s.mean_batch, stages, slo))
}

fn main() -> anyhow::Result<()> {
    // real Table-I testsets when artifacts exist, synthetic otherwise —
    // the bench must always produce its artifact for CI
    let (models, testsets) = match manifest_or_skip("bench_serving: real Table-I configs") {
        Some(manifest) => {
            // one linear OvR, one linear OvO, one kernel machine
            // (kernel keys require artifacts rebuilt since ISSUE 8)
            let keys = vec![
                "iris_ovr_w4".to_string(),
                "seeds_ovo_w4".to_string(),
                "iris_rbf_ovr_w4".to_string(),
            ];
            (None, load_testsets(&manifest, &keys)?)
        }
        None => {
            println!("bench_serving: using synthetic models instead");
            let (m, t) = synthetic_setup();
            (Some(m), t)
        }
    };
    let models_ref = models.as_deref();
    println!("### coordinator serving: {} requests, {WORKERS} client threads", requests());
    let mut report = Bench::new("coordinator serving (batch policy x backend)");
    #[cfg(feature = "pjrt")]
    let backends = [Backend::Pjrt, Backend::Native];
    #[cfg(not(feature = "pjrt"))]
    let backends = [Backend::Native];
    let mut t = Table::new(["backend", "batch_max", "linger", "eager", "req/s", "p50 (us)", "p99 (us)", "mean batch"]);
    for backend in backends {
        for (batch_max, linger_us, eager) in
            [(1usize, 0u64, false), (8, 200, false), (64, 500, false), (64, 2000, false), (64, 500, true)]
        {
            let (rps, p50, p99, mb, _, _) = drive(
                &testsets,
                models_ref,
                backend,
                FarmOpts::default(),
                batch_max,
                linger_us,
                eager,
            )?;
            report.metric(
                &format!("{backend} batch_max={batch_max} linger={linger_us}us eager={eager}"),
                rps,
                "req/s",
            );
            t.row([
                backend.to_string(),
                batch_max.to_string(),
                format!("{linger_us}us"),
                eager.to_string(),
                format!("{rps:.0}"),
                p50.to_string(),
                p99.to_string(),
                format!("{mb:.1}"),
            ]);
        }
    }

    // Accel backend: full simulation vs the analytic fast path on the
    // same requests (identical batch policy), end to end through the
    // coordinator — the serving-level view of bench_farm's raw number
    let farm_base = FarmOpts { shards: 4, calibrate_baseline: false, ..Default::default() };
    let farm_fast = FarmOpts { fastpath: true, audit_rate: 32, ..farm_base };
    let (rps_sim, p50s, p99s, mbs, stages_sim, slo_sim) =
        drive(&testsets, models_ref, Backend::Accel, farm_base, 8, 200, false)?;
    let (rps_fast, p50f, p99f, mbf, _, _) =
        drive(&testsets, models_ref, Backend::Accel, farm_fast, 8, 200, false)?;
    t.row([
        "accel (full sim)".to_string(),
        "8".to_string(),
        "200us".to_string(),
        "false".to_string(),
        format!("{rps_sim:.0}"),
        p50s.to_string(),
        p99s.to_string(),
        format!("{mbs:.1}"),
    ]);
    t.row([
        "accel (fastpath)".to_string(),
        "8".to_string(),
        "200us".to_string(),
        "false".to_string(),
        format!("{rps_fast:.0}"),
        p50f.to_string(),
        p99f.to_string(),
        format!("{mbf:.1}"),
    ]);
    report.metric("accel full-sim req/s", rps_sim, "req/s");
    report.metric("accel fastpath req/s", rps_fast, "req/s");
    report.metric("fastpath_speedup", rps_fast / rps_sim.max(1e-9), "x");
    // per-stage waterfall of the full-sim accel run (obs/ telemetry)
    for (stage, h) in stages_sim.iter() {
        report.metric(&format!("stage {} p50", stage.name()), h.quantile_us(0.50) as f64, "us");
        report.metric(&format!("stage {} p99", stage.name()), h.quantile_us(0.99) as f64, "us");
    }
    // SLO verdict of the full-sim accel run
    if let Some(s) = &slo_sim {
        report.metric("slo healthy", s.healthy() as u64 as f64, "bool");
        let worst = s.configs.iter().map(|c| c.burn_long).fold(0.0f64, f64::max);
        report.metric("slo worst long-window burn", worst, "x");
        println!("SLO verdict (accel full sim): {}", s.verdict());
    }

    print!("{}", t.render());
    println!("\n(batch_max=1 is the no-batching baseline; PJRT gains come from batch formation.");
    println!(" Raw-farm fastpath numbers live in: cargo bench --bench bench_farm)");
    let path = write_report("serving", &[&report])?;
    println!("wrote {}", path.display());
    Ok(())
}
