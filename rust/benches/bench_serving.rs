//! Coordinator serving benchmark: throughput and tail latency vs batch
//! policy and backend (experiment E2E support data).  The client loop
//! lives in `util::benchkit::drive_clients`, shared with
//! `examples/serve_inference.rs` and the farm bench.
//!
//!     cargo bench --bench bench_serving

use std::time::Duration;

use flexsvm::coordinator::{Backend, Server};
use flexsvm::svm::model::artifacts_root;
use flexsvm::svm::TestSet;
use flexsvm::util::benchkit::{
    drive_clients, latency_summary, load_testsets, manifest_or_skip, quick, write_report, Bench,
};
use flexsvm::util::Table;

const WORKERS: usize = 8;

fn requests() -> usize {
    if quick() {
        800
    } else {
        8_000
    }
}

fn drive(
    testsets: &[(String, TestSet)],
    backend: Backend,
    batch_max: usize,
    linger_us: u64,
    eager: bool,
) -> anyhow::Result<(f64, u64, u64, f64)> {
    let keys: Vec<String> = testsets.iter().map(|(k, _)| k.clone()).collect();
    let server = Server::builder()
        .artifacts(artifacts_root(), keys)
        .backend(backend)
        .batch_max(batch_max)
        .compiled_batch(64)
        .linger(Duration::from_micros(linger_us))
        .queue_cap(4096)
        .eager_flush(eager)
        .start()?;
    let client = server.client();
    let r = drive_clients(&client, testsets, requests(), WORKERS, None)?;
    let s = latency_summary(&client.metrics()?);
    Ok((r.served as f64 / r.wall.as_secs_f64(), s.p50_us, s.p99_us, s.mean_batch))
}

fn main() -> anyhow::Result<()> {
    let Some(manifest) = manifest_or_skip("bench_serving") else {
        return Ok(());
    };
    let keys = vec!["iris_ovr_w4".to_string(), "seeds_ovo_w4".to_string()];
    let testsets = load_testsets(&manifest, &keys)?;
    println!("### coordinator serving: {} requests, {WORKERS} client threads", requests());
    let mut report = Bench::new("coordinator serving (batch policy x backend)");
    #[cfg(feature = "pjrt")]
    let backends = [Backend::Pjrt, Backend::Native];
    #[cfg(not(feature = "pjrt"))]
    let backends = [Backend::Native];
    let mut t = Table::new(["backend", "batch_max", "linger", "eager", "req/s", "p50 (us)", "p99 (us)", "mean batch"]);
    for backend in backends {
        for (batch_max, linger_us, eager) in
            [(1usize, 0u64, false), (8, 200, false), (64, 500, false), (64, 2000, false), (64, 500, true)]
        {
            let (rps, p50, p99, mb) = drive(&testsets, backend, batch_max, linger_us, eager)?;
            report.metric(
                &format!("{backend} batch_max={batch_max} linger={linger_us}us eager={eager}"),
                rps,
                "req/s",
            );
            t.row([
                backend.to_string(),
                batch_max.to_string(),
                format!("{linger_us}us"),
                eager.to_string(),
                format!("{rps:.0}"),
                p50.to_string(),
                p99.to_string(),
                format!("{mb:.1}"),
            ]);
        }
    }
    print!("{}", t.render());
    println!("\n(batch_max=1 is the no-batching baseline; PJRT gains come from batch formation.");
    println!(" The Accel backend has its own bench: cargo bench --bench bench_farm)");
    let path = write_report("serving", &[&report])?;
    println!("wrote {}", path.display());
    Ok(())
}
