//! Coordinator serving benchmark: throughput and tail latency vs batch
//! policy and backend (experiment E2E support data).
//!
//!     cargo bench --bench bench_serving

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use flexsvm::coordinator::{Backend, Server, ServerOpts};
use flexsvm::svm::model::{artifacts_root, Manifest};
use flexsvm::util::Table;

const REQUESTS: usize = 8_000;
const WORKERS: usize = 8;

fn drive(backend: Backend, batch_max: usize, linger_us: u64, eager: bool) -> anyhow::Result<(f64, u64, u64, f64)> {
    let keys = vec!["iris_ovr_w4".to_string(), "seeds_ovo_w4".to_string()];
    let manifest = Manifest::load(&artifacts_root())?;
    let server = Server::start(
        artifacts_root(),
        keys.clone(),
        ServerOpts {
            backend,
            batch_max,
            compiled_batch: 64,
            linger: Duration::from_micros(linger_us),
            queue_cap: 4096,
            eager_flush: eager,
        },
    )?;
    let client = server.client();
    let mut testsets = Vec::new();
    for k in &keys {
        let entry = manifest.config(k)?;
        testsets.push((k.clone(), manifest.test_set(&entry.dataset)?));
    }
    let done = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| -> anyhow::Result<()> {
        let mut hs = Vec::new();
        for w in 0..WORKERS {
            let client = client.clone();
            let testsets = &testsets;
            let done = &done;
            hs.push(scope.spawn(move || -> anyhow::Result<()> {
                for i in 0..REQUESTS / WORKERS {
                    let (key, test) = &testsets[(w + i) % testsets.len()];
                    let idx = (w * 131 + i) % test.len();
                    client.infer(key, &test.x_q[idx])?;
                    done.fetch_add(1, Ordering::Relaxed);
                }
                Ok(())
            }));
        }
        for h in hs {
            h.join().unwrap()?;
        }
        Ok(())
    })?;
    let dt = t0.elapsed().as_secs_f64();
    let metrics = client.metrics()?;
    let mut p50 = 0u64;
    let mut p99 = 0u64;
    let mut mean_batch = 0.0;
    let mut n = 0.0;
    for m in metrics.values() {
        let h = m.latency.as_ref().unwrap();
        p50 = p50.max(h.quantile_us(0.50));
        p99 = p99.max(h.quantile_us(0.99));
        mean_batch += m.mean_batch();
        n += 1.0;
    }
    Ok((done.load(Ordering::Relaxed) as f64 / dt, p50, p99, mean_batch / n))
}

fn main() -> anyhow::Result<()> {
    println!("### coordinator serving: {REQUESTS} requests, {WORKERS} client threads");
    let mut t = Table::new(["backend", "batch_max", "linger", "eager", "req/s", "p50 (us)", "p99 (us)", "mean batch"]);
    for backend in [Backend::Pjrt, Backend::Native] {
        for (batch_max, linger_us, eager) in
            [(1usize, 0u64, false), (8, 200, false), (64, 500, false), (64, 2000, false), (64, 500, true)]
        {
            let (rps, p50, p99, mb) = drive(backend, batch_max, linger_us, eager)?;
            t.row([
                format!("{backend:?}"),
                batch_max.to_string(),
                format!("{linger_us}us"),
                eager.to_string(),
                format!("{rps:.0}"),
                p50.to_string(),
                p99.to_string(),
                format!("{mb:.1}"),
            ]);
        }
    }
    print!("{}", t.render());
    println!("\n(batch_max=1 is the no-batching baseline; PJRT gains come from batch formation)");
    Ok(())
}
