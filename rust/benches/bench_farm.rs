//! Accelerator-farm serving benchmark: the sharded cycle-level SoC
//! pool under steady / bursty / multi-tenant traffic.
//!
//! Part A drives the raw [`Farm`] (scheduler + shard balance + spill
//! behaviour, paced by the scenario generator's arrival times).
//! Part B races the analytic fast path against full simulation on the
//! same steady-scenario requests, unpaced, and emits the
//! `fastpath_speedup` metric CI gates on (audits must stay clean).
//! Part C serves the same traffic through the coordinator
//! (`Backend::Accel`) and prints the serving energy report.
//!
//! Runs against the real Table-I artifacts when present, otherwise
//! against synthetic quantized models — the farm needs no artifacts.
//!
//!     cargo bench --bench bench_farm [n_requests]

use std::sync::atomic::{AtomicU64, Ordering};

use flexsvm::coordinator::{Backend, Server};
use flexsvm::farm::scenario::{self, Traffic};
use flexsvm::farm::{Farm, FarmOpts};
use flexsvm::power::FlexicModel;
use flexsvm::report::serving;
use flexsvm::svm::QuantModel;
use flexsvm::testing::gen;
use flexsvm::util::benchkit::{manifest_or_skip, quick, write_report, Bench};
use flexsvm::util::{Pcg32, Table};

const WORKERS: usize = 8;

/// Table-I configs when artifacts exist, synthetic models otherwise.
fn build_models() -> Vec<(String, QuantModel)> {
    if let Some(manifest) = manifest_or_skip("bench_farm: real Table-I configs") {
        let keys = ["iris_ovr_w4", "seeds_ovo_w4", "bs_ovr_w8", "v3_ovo_w4"];
        return keys
            .iter()
            .map(|k| {
                let entry = manifest.config(k).unwrap();
                (k.to_string(), manifest.model(entry).unwrap())
            })
            .collect();
    }
    println!("bench_farm: using synthetic quantized models instead");
    let mut rng = Pcg32::seeded(0xfa12);
    (0..4)
        .map(|i| {
            let m = gen::quant_model(&mut rng);
            (format!("syn{i}_{}", m.config_key()), m)
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let default_n = if quick() { 200 } else { 1_200 };
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(default_n);
    let mut report = Bench::new("farm serving (scenario x shard sweep)");
    let models = build_models();
    let n_cfg = models.len();
    // feature widths per config, for the shared arrival pre-draw
    let nf: Vec<usize> = models.iter().map(|(_, m)| m.n_features).collect();
    let scenarios = [
        scenario::generate(Traffic::Steady { rps: 2_000.0 }, n_cfg, n, 0xa1),
        scenario::generate(Traffic::Bursty { rps: 2_000.0, burst: 32 }, n_cfg, n, 0xa2),
        scenario::generate(Traffic::MultiTenant { rps: 2_000.0, skew: 1.2 }, n_cfg, n, 0xa3),
    ];

    // ---- part A: raw farm, shard-count sweep -------------------------------
    println!("### farm scheduler: {n} paced requests, {WORKERS} client threads");
    let mut t = Table::new([
        "scenario", "shards", "req/s", "sim Mcyc", "spills", "max/min shard jobs", "lazy loads",
    ]);
    for s in &scenarios {
        let xs = gen::arrival_features(0xfeed, &nf, s);
        for shards in [1usize, 2, 4] {
            let farm = Farm::start(
                models.clone(),
                FarmOpts { shards, calibrate_baseline: false, ..Default::default() },
            )?;
            let errors = AtomicU64::new(0);
            let wall = s.replay(WORKERS, |_| (), |_, i, a| {
                if farm.predict(&models[a.config].0, &xs[i]).is_err() {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert_eq!(errors.load(Ordering::Relaxed), 0, "farm must answer every request");
            let m = farm.metrics();
            let jobs: Vec<u64> = m.shards.iter().map(|sh| sh.jobs).collect();
            // every config is warm-loaded once on its home shard; the
            // rest are lazy spill loads (reload churn)
            let lazy = m.shards.iter().map(|sh| sh.model_loads).sum::<u64>() - models.len() as u64;
            t.row([
                s.traffic.name().to_string(),
                shards.to_string(),
                format!("{:.0}", n as f64 / wall.as_secs_f64()),
                format!("{:.2}", m.total_sim_cycles() as f64 / 1e6),
                m.spills.to_string(),
                format!("{}/{}", jobs.iter().max().unwrap(), jobs.iter().min().unwrap()),
                lazy.to_string(),
            ]);
            report.metric(
                &format!("{} shards={shards} req/s", s.traffic.name()),
                n as f64 / wall.as_secs_f64(),
                "req/s",
            );
            report.metric(
                &format!("{} shards={shards} sim throughput", s.traffic.name()),
                m.total_sim_cycles() as f64 / wall.as_secs_f64() / 1e6,
                "Mcyc/s",
            );
        }
    }
    print!("{}", t.render());

    // ---- part B: analytic fast path vs full simulation ---------------------
    // same steady-scenario requests, driven UNPACED (the replay pacer
    // would hide any engine speedup behind arrival waits)
    println!("\n### analytic fast path vs full simulation (steady scenario, unpaced)");
    {
        let s = &scenarios[0];
        let xs = gen::arrival_features(0xfa57, &nf, s);
        let drive = |farm: &Farm| {
            let errors = AtomicU64::new(0);
            let t0 = std::time::Instant::now();
            std::thread::scope(|scope| {
                for w in 0..WORKERS {
                    let errors = &errors;
                    let xs = &xs;
                    let models = &models;
                    scope.spawn(move || {
                        for (i, a) in s.arrivals.iter().enumerate() {
                            if i % WORKERS != w {
                                continue;
                            }
                            if farm.predict(&models[a.config].0, &xs[i]).is_err() {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    });
                }
            });
            assert_eq!(errors.load(Ordering::Relaxed), 0, "farm must answer every request");
            t0.elapsed()
        };
        let sim_farm = Farm::start(
            models.clone(),
            FarmOpts { shards: 4, calibrate_baseline: false, ..Default::default() },
        )?;
        let wall_sim = drive(&sim_farm);
        let fast_farm = Farm::start(
            models.clone(),
            FarmOpts {
                shards: 4,
                calibrate_baseline: false,
                fastpath: true,
                audit_rate: 32,
                ..Default::default()
            },
        )?;
        let wall_fast = drive(&fast_farm);
        let fm = fast_farm.metrics();
        assert_eq!(fm.fast.mismatches, 0, "differential audit must stay clean");
        assert_eq!(
            fm.fast.fastpath_configs as usize,
            models.len(),
            "every accelerated config must derive an analytic model"
        );
        let speedup = wall_sim.as_secs_f64() / wall_fast.as_secs_f64().max(1e-9);
        println!(
            "full-sim {:.3}s vs fastpath {:.3}s -> {speedup:.1}x \
             ({} analytic answers, {} audits, {} mismatches)",
            wall_sim.as_secs_f64(),
            wall_fast.as_secs_f64(),
            fm.fast.fast_jobs,
            fm.fast.audits,
            fm.fast.mismatches,
        );
        report.metric("fastpath_speedup", speedup, "x");
        report.metric("fastpath_audit_mismatches", fm.fast.mismatches as f64, "count");
        report.metric("fastpath_audits", fm.fast.audits as f64, "count");
    }

    // ---- part C: behind the coordinator, with energy accounting ------------
    println!("\n### coordinator Backend::Accel (multi-tenant scenario)");
    let s = &scenarios[2];
    let xs = gen::arrival_features(0xbeef, &nf, s);
    let server = Server::builder()
        .models(models.clone())
        .backend(Backend::Accel)
        .farm(FarmOpts { calibrate_baseline: true, ..Default::default() })
        .start()?;
    let client = server.client();
    let errors = AtomicU64::new(0);
    let wall = s.replay(WORKERS, |_| (), |_, i, a| {
        if client.infer(&models[a.config].0, &xs[i]).is_err() {
            errors.fetch_add(1, Ordering::Relaxed);
        }
    });
    assert_eq!(errors.load(Ordering::Relaxed), 0);
    println!("served {n} requests in {:.2}s = {:.0} req/s", wall.as_secs_f64(), n as f64 / wall.as_secs_f64());
    report.metric("coordinator accel req/s", n as f64 / wall.as_secs_f64(), "req/s");
    let farm_metrics = client.engine_metrics()?.farm;
    if let Some(fm) = farm_metrics.as_ref() {
        report.metric("coordinator accel sim Mcyc", fm.total_sim_cycles() as f64 / 1e6, "Mcyc");
    }
    let stages = client.obs().stage_snapshot();
    print!(
        "{}",
        serving::render(
            &client.metrics()?,
            wall,
            farm_metrics.as_ref(),
            &FlexicModel::paper(),
            Some(&stages),
            None,
            None,
            None,
            None,
        )
    );
    server.shutdown()?;
    let path = write_report("farm", &[&report])?;
    println!("wrote {}", path.display());
    Ok(())
}
