//! Table I regeneration: per (dataset, strategy, bits) — accuracy,
//! cycles/inference with and without the accelerator (measured on the
//! cycle-accurate SERV SoC), energy via the FlexIC model, speedup and
//! energy reduction.

use anyhow::Result;

use crate::power::FlexicModel;
use crate::program::run::ProgramRunner;
use crate::program::ProgramOpts;
use crate::serv::TimingConfig;
use crate::svm::model::Manifest;
use crate::util::{json, Json, Table};

/// One Table-I row (paper columns + our cycle-attribution extras).
/// Kernel-machine rows (`kernel` = `"rbf"`/`"poly"`) have no software
/// baseline program, so their `base_*`/`speedup`/`energy_red_pct`
/// fields are 0 and render as dashes — never a fabricated ratio.
#[derive(Debug, Clone)]
pub struct RowResult {
    pub key: String,
    pub dataset: String,
    pub strategy: String,
    pub kernel: String,
    pub bits: u8,
    pub accuracy: f64,
    pub n_samples: usize,
    pub base_cycles: f64,
    pub base_energy_mj: f64,
    pub accel_cycles: f64,
    pub accel_energy_mj: f64,
    pub speedup: f64,
    pub energy_red_pct: f64,
    /// data-memory share of total cycles (MEM experiment)
    pub base_mem_share: f64,
    pub accel_mem_share: f64,
}

/// Options for the Table-I run.
#[derive(Debug, Clone)]
pub struct Table1Opts {
    /// Datasets to include (short names); empty = all.
    pub datasets: Vec<String>,
    /// Max test samples per config (None = full test set).
    pub limit: Option<usize>,
    pub timing: TimingConfig,
    pub program: ProgramOpts,
    /// Cross-check SoC predictions against build-time accuracy.
    pub verify_accuracy: bool,
}

impl Default for Table1Opts {
    fn default() -> Self {
        Table1Opts {
            datasets: vec![],
            limit: None,
            timing: TimingConfig::flexic(),
            program: ProgramOpts::default(),
            verify_accuracy: true,
        }
    }
}

/// Run the full sweep — configs are independent, so they run on a
/// scoped thread pool (one thread per config, each owning its SoCs;
/// EXPERIMENTS.md §Perf iteration 4).
pub fn run_table1(manifest: &Manifest, opts: &Table1Opts) -> Result<Vec<RowResult>> {
    let entries: Vec<_> = manifest
        .configs
        .iter()
        .filter(|e| opts.datasets.is_empty() || opts.datasets.contains(&e.dataset))
        .collect();
    let mut rows = Vec::with_capacity(entries.len());
    std::thread::scope(|scope| -> Result<()> {
        let handles: Vec<_> = entries
            .iter()
            .map(|entry| scope.spawn(move || run_one(manifest, entry, opts)))
            .collect();
        for h in handles {
            rows.push(h.join().expect("table1 worker panicked")?);
        }
        Ok(())
    })?;
    // paper row order: dataset, linear before the kernel families,
    // OvR before OvO, bits ascending
    let ds_rank = |d: &str| ["bs", "derm", "iris", "seeds", "v3"].iter().position(|x| *x == d).unwrap_or(99);
    let k_rank = |k: &str| ["linear", "rbf", "poly"].iter().position(|x| *x == k).unwrap_or(99);
    let st_rank = |s: &str| if s == "ovr" { 0 } else { 1 };
    rows.sort_by_key(|r| (ds_rank(&r.dataset), k_rank(&r.kernel), st_rank(&r.strategy), r.bits));
    Ok(rows)
}

fn run_one(
    manifest: &Manifest,
    entry: &crate::svm::model::ConfigEntry,
    opts: &Table1Opts,
) -> Result<RowResult> {
    let power = FlexicModel::paper();
    {
        let model = manifest.model(entry)?;
        let test = manifest.test_set(&entry.dataset)?;

        let mut acc = ProgramRunner::accelerated(&model, opts.timing, opts.program)?;
        let acc_res = acc.run_test_set(&test.x_q, &test.y, opts.limit)?;

        // kernel machines have no software-only baseline program
        // (`program::baseline` refuses them): their rows report the
        // accelerated side only, baseline columns render as dashes
        let base_res = if model.is_kernel() {
            None
        } else {
            let mut base = ProgramRunner::baseline(&model, opts.timing)?;
            let r = base.run_test_set(&test.x_q, &test.y, opts.limit)?;
            // both SoC variants must classify identically (same integer math)
            anyhow::ensure!(
                (r.accuracy - acc_res.accuracy).abs() < 1e-12,
                "{}: baseline and accelerated SoC disagree on accuracy",
                entry.key
            );
            Some(r)
        };
        if opts.verify_accuracy && opts.limit.is_none() {
            anyhow::ensure!(
                (acc_res.accuracy - entry.accuracy).abs() < 1e-9,
                "{}: SoC accuracy {} != build-time accuracy {}",
                entry.key,
                acc_res.accuracy,
                entry.accuracy
            );
        }

        let base_cycles = base_res.as_ref().map(|r| r.cycles_per_inference).unwrap_or(0.0);
        let accel_cycles = acc_res.cycles_per_inference;
        Ok(RowResult {
            key: entry.key.clone(),
            dataset: entry.dataset.clone(),
            strategy: entry.strategy.to_string(),
            kernel: entry.kernel.to_string(),
            bits: entry.bits,
            accuracy: acc_res.accuracy,
            n_samples: acc_res.n_samples,
            base_cycles,
            base_energy_mj: if base_cycles > 0.0 { power.energy_mj(base_cycles) } else { 0.0 },
            accel_cycles,
            accel_energy_mj: power.energy_mj(accel_cycles),
            speedup: if base_cycles > 0.0 { base_cycles / accel_cycles } else { 0.0 },
            energy_red_pct: if base_cycles > 0.0 {
                power.energy_reduction_pct(base_cycles, accel_cycles)
            } else {
                0.0
            },
            base_mem_share: base_res.as_ref().map(|r| r.agg.data_mem_share()).unwrap_or(0.0),
            accel_mem_share: acc_res.agg.data_mem_share(),
        })
    }
}

/// Render in the paper's column layout.
pub fn render(rows: &[RowResult], with_attr: bool) -> String {
    let mut header = vec![
        "Dataset", "Kernel", "Strategy", "Bits", "Acc(%)", "base Mcyc", "base mJ/inf",
        "accel Mcyc", "accel mJ/inf", "Speedup(x)", "EnRed(%)",
    ];
    if with_attr {
        header.push("base dmem%");
        header.push("accel dmem%");
    }
    let mut t = Table::new(header);
    for r in rows {
        let has_base = r.base_cycles > 0.0;
        let or_dash = |s: String| if has_base { s } else { "-".to_string() };
        let mut cells = vec![
            r.dataset.clone(),
            r.kernel.clone(),
            r.strategy.to_uppercase(),
            r.bits.to_string(),
            format!("{:.1}", r.accuracy * 100.0),
            or_dash(format!("{:.3}", r.base_cycles / 1e6)),
            or_dash(format!("{:.1}", r.base_energy_mj)),
            format!("{:.4}", r.accel_cycles / 1e6),
            format!("{:.2}", r.accel_energy_mj),
            or_dash(format!("{:.1}", r.speedup)),
            or_dash(format!("{:.1}", r.energy_red_pct)),
        ];
        if with_attr {
            cells.push(or_dash(format!("{:.1}", r.base_mem_share * 100.0)));
            cells.push(format!("{:.1}", r.accel_mem_share * 100.0));
        }
        t.row(cells);
    }
    let mut out = t.render();
    out.push_str(&summary(rows));
    out
}

/// Headline means (the paper's "21× improvement ... on average").
/// Speedup/energy-reduction means cover the linear rows only — kernel
/// rows have no baseline to be "faster than"; they get their own
/// per-family accuracy/energy lines instead.
pub fn summary(rows: &[RowResult]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let linear: Vec<&RowResult> = rows.iter().filter(|r| r.base_cycles > 0.0).collect();
    let mean_of = |rs: &[&RowResult], f: &dyn Fn(&RowResult) -> f64| {
        if rs.is_empty() {
            0.0
        } else {
            rs.iter().map(|r| f(r)).sum::<f64>() / rs.len() as f64
        }
    };
    let ovr: Vec<&RowResult> = linear.iter().copied().filter(|r| r.strategy == "ovr").collect();
    let ovo: Vec<&RowResult> = linear.iter().copied().filter(|r| r.strategy == "ovo").collect();
    let mut out = format!(
        "\nmean speedup {:.1}x (OvR {:.1}x, OvO {:.1}x) | mean energy reduction {:.1}% | paper: 21x avg, OvR 23x, OvO 19.8x\n",
        mean_of(&linear, &|r| r.speedup),
        mean_of(&ovr, &|r| r.speedup),
        mean_of(&ovo, &|r| r.speedup),
        mean_of(&linear, &|r| r.energy_red_pct),
    );
    for family in ["rbf", "poly"] {
        let fam: Vec<&RowResult> = rows.iter().filter(|r| r.kernel == family).collect();
        if !fam.is_empty() {
            out.push_str(&format!(
                "{family}: {} config(s), mean acc {:.1}%, mean {:.2} mJ/inf on the KSVM accelerator (no software baseline)\n",
                fam.len(),
                100.0 * mean_of(&fam, &|r| r.accuracy),
                mean_of(&fam, &|r| r.accel_energy_mj),
            ));
        }
    }
    out
}

/// JSON export for EXPERIMENTS.md bookkeeping.
pub fn to_json(rows: &[RowResult]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                json::obj([
                    ("key", r.key.as_str().into()),
                    ("kernel", r.kernel.as_str().into()),
                    ("accuracy", r.accuracy.into()),
                    ("base_cycles", r.base_cycles.into()),
                    ("accel_cycles", r.accel_cycles.into()),
                    ("base_energy_mj", r.base_energy_mj.into()),
                    ("accel_energy_mj", r.accel_energy_mj.into()),
                    ("speedup", r.speedup.into()),
                    ("energy_red_pct", r.energy_red_pct.into()),
                    ("base_mem_share", r.base_mem_share.into()),
                    ("accel_mem_share", r.accel_mem_share.into()),
                    ("n_samples", (r.n_samples as i32).into()),
                ])
            })
            .collect(),
    )
}
