//! Experiment regeneration: Table I, the §V-B area/power paragraph,
//! cycle-attribution reports, and the serving energy report
//! (DESIGN.md §4 experiment index).

pub mod area_power;
pub mod serving;
pub mod table1;

pub use table1::{run_table1, RowResult, Table1Opts};
