//! Experiment regeneration: Table I, the §V-B area/power paragraph,
//! and cycle-attribution reports (DESIGN.md §4 experiment index).

pub mod area_power;
pub mod table1;

pub use table1::{run_table1, RowResult, Table1Opts};
