//! The §V-B area/power paragraph: component power and area, the Gen3
//! gate-budget check, and per-CFU NAND2 estimates.

use crate::accel::mac::MacAccel;
use crate::accel::popcount::PopcountAccel;
use crate::accel::svm::SvmAccel;
use crate::accel::Cfu;
use crate::power::FlexicModel;
use crate::util::Table;

pub fn render() -> String {
    let m = FlexicModel::paper();
    let mut out = String::new();
    out.push_str("FlexIC Gen3 @ 52 kHz (paper §V-B reference figures)\n\n");
    let mut t = Table::new(["Component", "Power (mW)", "Area (mm2)", "NAND2-eq"]);
    let svm = SvmAccel::new();
    let mac = MacAccel::new();
    let pop = PopcountAccel::new();
    t.row([
        "SERV core".to_string(),
        format!("{:.3}", m.serv_mw),
        format!("{:.2}", m.serv_area_mm2),
        "~5500".to_string(),
    ]);
    t.row([
        "SVM accelerator".to_string(),
        format!("{:.3}", m.accel_mw),
        format!("{:.2}", m.accel_area_mm2),
        format!("{}", svm.nand2_equivalents()),
    ]);
    t.row([
        "(demo) mac32 CFU".to_string(),
        format!("{:.3}", m.accel_mw_scaled(mac.nand2_equivalents())),
        format!("{:.2}", m.accel_area_scaled(mac.nand2_equivalents())),
        format!("{}", mac.nand2_equivalents()),
    ]);
    t.row([
        "(demo) popcount CFU".to_string(),
        format!("{:.3}", m.accel_mw_scaled(pop.nand2_equivalents())),
        format!("{:.2}", m.accel_area_scaled(pop.nand2_equivalents())),
        format!("{}", pop.nand2_equivalents()),
    ]);
    t.row([
        "Total (SERV + SVM)".to_string(),
        format!("{:.3}", m.total_mw()),
        format!("{:.2}", m.serv_area_mm2 + m.accel_area_mm2),
        format!("{}", 5500 + svm.nand2_equivalents()),
    ]);
    out.push_str(&t.render());
    out.push_str(&format!(
        "\nGen3 integration budget: {} NAND2-eq — SERV + SVM accel fits: {}\n",
        m.gate_budget,
        m.fits_budget(svm.nand2_equivalents())
    ));
    out.push_str(&format!(
        "battery life on a 1000 mWh coin pack at continuous inference: {:.0} h\n",
        m.battery_life_h(1000.0)
    ));
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_all_components() {
        let s = super::render();
        for needle in ["SERV core", "SVM accelerator", "mac32", "popcount", "0.224", "18.47"] {
            assert!(s.contains(needle), "missing {needle} in:\n{s}");
        }
    }
}
