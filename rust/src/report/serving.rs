//! Serving report: Table I extended to streaming workloads.
//!
//! Renders what the paper's per-sample evaluation cannot show — the
//! accelerator's speed/energy story *under load*:
//!
//!  * energy/request (FlexIC model, per config and in aggregate),
//!  * simulated-hardware vs wall-clock throughput (how far the
//!    cycle-level simulation is from real-time 52 kHz silicon),
//!  * the accel-vs-baseline cycle ratio measured on the serving path
//!    (Table I's speedup column, re-derived from live traffic),
//!  * per-kernel-family aggregates (linear vs RBF vs polynomial
//!    energy/request — and live accuracy when the driver labelled its
//!    traffic), now that configs carry their kernel id end to end,
//!  * per-shard farm balance (jobs, simulated cycles, reload churn).

use std::collections::{BTreeMap, HashMap};
use std::time::Duration;

use crate::coordinator::metrics::ConfigMetrics;
use crate::farm::FarmMetrics;
use crate::net::NetMetricsSnapshot;
use crate::obs::{SloSnapshot, StageMetrics};
use crate::power::FlexicModel;
use crate::util::Table;

/// Render the serving section from a coordinator metrics snapshot.
/// `farm` adds the per-shard table; `wall` is the driving run's
/// wall-clock span; `stages` (an [`crate::obs::Obs`] stage snapshot)
/// adds the per-stage waterfall; `fleet` (merged per-node metrics from
/// `RemoteEngine::snapshot`) adds fleet-wide quantiles computed from
/// merged histogram buckets; `accuracy` maps config key →
/// `(label-correct, answered)` counts observed by a labelled driver
/// (the serving path itself never sees labels), enabling the
/// per-kernel live-accuracy column; `net` (a [`NetMetricsSnapshot`]
/// from the wire front) adds the connection-lifecycle line — live
/// gauges (open/reading/writing/idle), accept/close/timeout totals,
/// shed count, and wire bytes; `slo` (an [`crate::obs::Obs`] SLO
/// snapshot) adds the objective scorecard — per-config burn rates over
/// both windows and the overall verdict.
#[allow(clippy::too_many_arguments)]
pub fn render(
    per_config: &HashMap<String, ConfigMetrics>,
    wall: Duration,
    farm: Option<&FarmMetrics>,
    power: &FlexicModel,
    stages: Option<&BTreeMap<String, StageMetrics>>,
    fleet: Option<&HashMap<String, ConfigMetrics>>,
    accuracy: Option<&HashMap<String, (u64, u64)>>,
    net: Option<&NetMetricsSnapshot>,
    slo: Option<&SloSnapshot>,
) -> String {
    let mut out = String::from("\n=== serving energy report (Table I under load) ===\n");
    let mut keys: Vec<&String> = per_config.keys().collect();
    keys.sort();

    let mut t = Table::new([
        "config", "kernel", "reqs", "mJ/req", "kcyc/req", "accel-vs-base (x)",
        "hw req/s (1 SoC)", "p50 (us)", "p99 (us)",
    ]);
    let mut total_reqs = 0u64;
    let mut total_energy = 0.0f64;
    let mut total_cycles = 0u64;
    for key in keys {
        let m = &per_config[key];
        total_reqs += m.requests;
        total_energy += m.energy_mj;
        total_cycles += m.sim_cycles;
        let (p50, p99) = m
            .latency
            .as_ref()
            .map(|h| (h.quantile_us(0.50), h.quantile_us(0.99)))
            .unwrap_or((0, 0));
        let speedup = m.accel_speedup();
        let hw_rps = if m.mean_sim_cycles() > 0.0 { power.clock_hz / m.mean_sim_cycles() } else { 0.0 };
        t.row([
            key.clone(),
            if m.kernel.is_empty() { "?".to_string() } else { m.kernel.clone() },
            m.requests.to_string(),
            format!("{:.3}", m.mean_energy_mj()),
            format!("{:.1}", m.mean_sim_cycles() / 1e3),
            if speedup > 0.0 { format!("{speedup:.1}") } else { "-".to_string() },
            format!("{hw_rps:.2}"),
            p50.to_string(),
            p99.to_string(),
        ]);
    }
    out.push_str(&t.render());

    // per-kernel-family rollup: the mixed-kernel ablation as observed
    // on the serving path.  Rendered once any config knows its kernel
    // id; the accuracy column needs a labelled driver (`accuracy`).
    #[derive(Default)]
    struct Family {
        reqs: u64,
        sim_samples: u64,
        energy_mj: f64,
        sim_cycles: u64,
        correct: u64,
        answered: u64,
    }
    let mut families: BTreeMap<&str, Family> = BTreeMap::new();
    for (key, m) in per_config {
        if m.kernel.is_empty() {
            continue;
        }
        let fam = families.entry(m.kernel.as_str()).or_default();
        fam.reqs += m.requests;
        fam.sim_samples += m.sim_samples;
        fam.energy_mj += m.energy_mj;
        fam.sim_cycles += m.sim_cycles;
        if let Some(&(correct, answered)) = accuracy.and_then(|a| a.get(key)) {
            fam.correct += correct;
            fam.answered += answered;
        }
    }
    if !families.is_empty() {
        let mut kt = Table::new(["kernel", "reqs", "mJ/req", "kcyc/req", "live acc"]);
        for (kernel, f) in &families {
            let per = |v: f64| {
                if f.sim_samples > 0 { format!("{:.3}", v / f.sim_samples as f64) } else { "-".into() }
            };
            kt.row([
                kernel.to_string(),
                f.reqs.to_string(),
                per(f.energy_mj),
                per(f.sim_cycles as f64 / 1e3),
                if f.answered > 0 {
                    format!("{:.1}%", 100.0 * f.correct as f64 / f.answered as f64)
                } else {
                    "-".to_string()
                },
            ]);
        }
        out.push_str("\nper kernel family (from live traffic):\n");
        out.push_str(&kt.render());
    }

    // aggregate: simulated hardware time vs the wall clock that served it
    let n_socs = farm.map(|f| f.shards.len().max(1)).unwrap_or(1);
    let sim_s = total_cycles as f64 / power.clock_hz;
    let wall_s = wall.as_secs_f64();
    out.push_str(&format!(
        "\ntotal: {total_reqs} reqs | {total_energy:.1} mJ simulated energy | \
         {:.2} Mcyc simulated ({sim_s:.1} s of 52 kHz FlexIC time across {n_socs} SoC shard(s))\n",
        total_cycles as f64 / 1e6,
    ));
    if wall_s > 0.0 && total_cycles > 0 {
        // >1 means the farm serves faster than the modelled silicon would
        out.push_str(&format!(
            "simulated-vs-wall: {:.2} s hw-time per SoC vs {wall_s:.2} s wall -> sim speed {:.2}x real time\n",
            sim_s / n_socs as f64,
            sim_s / n_socs as f64 / wall_s,
        ));
    }

    if let Some(f) = farm {
        let mut st = Table::new(["shard", "jobs", "sim Mcyc", "model loads"]);
        for (i, s) in f.shards.iter().enumerate() {
            st.row([
                i.to_string(),
                s.jobs.to_string(),
                format!("{:.2}", s.sim_cycles as f64 / 1e6),
                s.model_loads.to_string(),
            ]);
        }
        out.push_str(&format!("\nfarm shards ({} spill(s) off the home shard):\n", f.spills));
        out.push_str(&st.render());
        // the differential-audit story: how much traffic the analytic
        // fast path absorbed and whether it ever diverged from the SoC
        if f.fast.fastpath_configs > 0 || f.fast.fast_jobs > 0 || f.fast.poisoned_configs > 0 {
            out.push_str(&format!(
                "fast path: {} analytic answer(s), {:.2} Mcyc billed | {} audit(s), {} mismatch(es) | \
                 {} config(s) analytic, {} demoted to full sim\n",
                f.fast.fast_jobs,
                f.fast.fast_cycles as f64 / 1e6,
                f.fast.audits,
                f.fast.mismatches,
                f.fast.fastpath_configs,
                f.fast.poisoned_configs,
            ));
        }
    }

    // where a request's time actually goes, stage by stage
    if let Some(stages) = stages {
        let mut any = false;
        let mut wt = Table::new(["config", "stage", "p50 (us)", "p99 (us)", "mean (us)", "count"]);
        for (cfg, sm) in stages {
            for (stage, h) in sm.iter() {
                any = true;
                wt.row([
                    cfg.clone(),
                    stage.name().to_string(),
                    h.quantile_us(0.50).to_string(),
                    h.quantile_us(0.99).to_string(),
                    format!("{:.1}", h.mean_us()),
                    h.count().to_string(),
                ]);
            }
        }
        if any {
            out.push_str("\nper-stage waterfall:\n");
            out.push_str(&wt.render());
        }
    }

    // fleet view: quantiles from bucket counts merged across nodes,
    // not a max over per-node summaries
    if let Some(fleet) = fleet {
        let mut keys: Vec<&String> = fleet.keys().collect();
        keys.sort();
        let mut ft = Table::new(["config", "reqs", "mJ/req", "p50 (us)", "p99 (us)", "max (us)"]);
        for key in keys {
            let m = &fleet[key];
            let (p50, p99, max) = m
                .latency
                .as_ref()
                .map(|h| (h.quantile_us(0.50), h.quantile_us(0.99), h.max_us()))
                .unwrap_or((0, 0, 0));
            ft.row([
                key.clone(),
                m.requests.to_string(),
                format!("{:.3}", m.mean_energy_mj()),
                p50.to_string(),
                p99.to_string(),
                max.to_string(),
            ]);
        }
        out.push_str("\nfleet (merged per-node histograms):\n");
        out.push_str(&ft.render());
    }

    // the wire front's connection lifecycle: how many sessions are
    // open right now (and what they're doing), how many ever came and
    // went, and what admission control or the timeout guards shed
    if let Some(n) = net {
        out.push_str(&format!(
            "\nnet front: {} open ({} reading / {} writing / {} idle) | \
             {} accepted, {} closed ({} timed out) | {} shed | \
             {} reqs, {:.2} MiB in / {:.2} MiB out\n",
            n.active,
            n.reading,
            n.writing,
            n.idle,
            n.accepted,
            n.closed,
            n.timed_out,
            n.shed,
            n.requests,
            n.bytes_in as f64 / (1024.0 * 1024.0),
            n.bytes_out as f64 / (1024.0 * 1024.0),
        ));
    }

    // the SLO scorecard: what each config promised vs what the rolling
    // windows observed, and whether the error budget is burning
    if let Some(s) = slo {
        out.push_str(&format!(
            "\nSLO (p99 <= {} us, availability >= {}%): {}\n",
            s.targets.p99_us,
            s.targets.avail,
            s.verdict()
        ));
        let mut st = Table::new([
            "config", "good/total (60s)", "avail %", "burn 10s", "burn 60s", "state",
        ]);
        for c in &s.configs {
            let (good, total) = c.long;
            st.row([
                c.config.clone(),
                format!("{good}/{total}"),
                if total > 0 {
                    format!("{:.2}", 100.0 * good as f64 / total as f64)
                } else {
                    "-".to_string()
                },
                format!("{:.2}", c.burn_short),
                format!("{:.2}", c.burn_long),
                if c.degraded { "DEGRADED".to_string() } else { "ok".to_string() },
            ]);
        }
        out.push_str(&st.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::farm::{FastPathMetrics, ShardMetrics};

    fn fake_metrics() -> HashMap<String, ConfigMetrics> {
        let mut m = ConfigMetrics::new();
        m.requests = 10;
        m.batches = 5;
        m.batched_samples = 10;
        m.sim_samples = 10;
        m.sim_cycles = 600_000; // 60 kcyc/req
        m.energy_mj = 13.4;
        m.baseline_cycles_per_inf = 2_100_000.0; // 35x
        let mut map = HashMap::new();
        map.insert("iris_ovr_w4".to_string(), m);
        map
    }

    #[test]
    fn render_contains_energy_and_ratio() {
        let farm = FarmMetrics {
            shards: vec![
                ShardMetrics { jobs: 6, sim_cycles: 360_000, model_loads: 1 },
                ShardMetrics { jobs: 4, sim_cycles: 240_000, model_loads: 1 },
            ],
            spills: 2,
            fast: FastPathMetrics {
                fast_jobs: 90,
                fast_cycles: 5_400_000,
                audits: 10,
                mismatches: 0,
                fastpath_configs: 1,
                poisoned_configs: 0,
            },
        };
        let s = render(
            &fake_metrics(),
            Duration::from_secs(2),
            Some(&farm),
            &FlexicModel::paper(),
            None,
            None,
            None,
            None,
            None,
        );
        assert!(s.contains("iris_ovr_w4"), "{s}");
        assert!(s.contains("1.340"), "mean mJ/req: {s}");
        assert!(s.contains("35.0"), "speedup column: {s}");
        assert!(s.contains("2 spill(s)"), "{s}");
        assert!(s.contains("simulated-vs-wall"), "{s}");
        assert!(s.contains("90 analytic answer(s)"), "{s}");
        assert!(s.contains("10 audit(s), 0 mismatch(es)"), "{s}");
        assert!(!s.contains("per-stage waterfall"), "no stages given: {s}");
        assert!(!s.contains("fleet ("), "no fleet given: {s}");
    }

    #[test]
    fn waterfall_and_fleet_sections_render() {
        use crate::obs::{Obs, ObsOpts, Stage, StageSet};
        let obs = Obs::new(ObsOpts::default());
        let mut st = StageSet::new();
        st.set(Stage::QueueWait, 15);
        st.set(Stage::Execute, 480);
        obs.observe("iris_ovr_w4", &st, Duration::from_micros(520));
        let stages = obs.stage_snapshot();

        let mut fleet = HashMap::new();
        let mut fm = ConfigMetrics::new();
        fm.requests = 20;
        fm.sim_samples = 20;
        fm.energy_mj = 10.0;
        for us in [100u64, 200, 40_000] {
            fm.latency.as_mut().unwrap().record_us(us);
        }
        fleet.insert("iris_ovr_w4".to_string(), fm);

        let s = render(
            &fake_metrics(),
            Duration::from_secs(1),
            None,
            &FlexicModel::paper(),
            Some(&stages),
            Some(&fleet),
            None,
            None,
            None,
        );
        assert!(s.contains("per-stage waterfall"), "{s}");
        assert!(s.contains("queue_wait"), "{s}");
        assert!(s.contains("execute"), "{s}");
        assert!(s.contains("fleet (merged per-node histograms)"), "{s}");
        // the fleet p99 comes from real buckets: the 40ms sample pulls
        // it to the 50ms bound, far above the p50 bucket
        assert!(s.contains("50000"), "fleet p99 from merged buckets: {s}");
    }

    #[test]
    fn fast_path_line_hidden_when_inactive() {
        let farm = FarmMetrics {
            shards: vec![ShardMetrics { jobs: 6, sim_cycles: 360_000, model_loads: 1 }],
            spills: 0,
            fast: FastPathMetrics::default(),
        };
        let s = render(
            &fake_metrics(),
            Duration::from_secs(1),
            Some(&farm),
            &FlexicModel::paper(),
            None,
            None,
            None,
            None,
            None,
        );
        assert!(s.contains("farm shards"), "{s}");
        assert!(!s.contains("fast path:"), "{s}");
    }

    #[test]
    fn net_front_line_renders_gauges_and_lifecycle() {
        let net = NetMetricsSnapshot {
            accepted: 10_000,
            active: 9_998,
            closed: 2,
            timed_out: 1,
            reading: 3,
            writing: 5,
            idle: 9_990,
            shed: 7,
            requests: 123_456,
            bytes_in: 3 * 1024 * 1024,
            bytes_out: 6 * 1024 * 1024,
        };
        let s = render(
            &fake_metrics(),
            Duration::from_secs(1),
            None,
            &FlexicModel::paper(),
            None,
            None,
            None,
            Some(&net),
            None,
        );
        assert!(s.contains("net front: 9998 open (3 reading / 5 writing / 9990 idle)"), "{s}");
        assert!(s.contains("10000 accepted, 2 closed (1 timed out)"), "{s}");
        assert!(s.contains("7 shed"), "{s}");
        assert!(s.contains("3.00 MiB in / 6.00 MiB out"), "{s}");
    }

    #[test]
    fn per_kernel_rollup_renders_with_live_accuracy() {
        let mut map = fake_metrics();
        map.get_mut("iris_ovr_w4").unwrap().kernel = "linear".into();
        let mut m = ConfigMetrics::new();
        m.requests = 4;
        m.sim_samples = 4;
        m.sim_cycles = 400_000;
        m.energy_mj = 2.0;
        m.kernel = "rbf".into();
        m.bits = 8;
        map.insert("syn_rbf".to_string(), m);
        let mut acc = HashMap::new();
        acc.insert("syn_rbf".to_string(), (3u64, 4u64));
        let s = render(
            &map,
            Duration::from_secs(1),
            None,
            &FlexicModel::paper(),
            None,
            None,
            Some(&acc),
            None,
            None,
        );
        assert!(s.contains("per kernel family"), "{s}");
        assert!(s.contains("rbf"), "{s}");
        assert!(s.contains("linear"), "{s}");
        assert!(s.contains("75.0%"), "rbf live accuracy from the labelled drive: {s}");
        // the linear family had no labelled traffic: dash, not a fake 0%
        assert!(s.contains('-'), "{s}");
    }

    #[test]
    fn kernel_rollup_hidden_when_no_config_knows_its_family() {
        let s = render(
            &fake_metrics(),
            Duration::from_secs(1),
            None,
            &FlexicModel::paper(),
            None,
            None,
            None,
            None,
            None,
        );
        assert!(!s.contains("per kernel family"), "{s}");
    }

    #[test]
    fn slo_scorecard_renders_verdict_and_burn() {
        use crate::obs::slo::{ConfigSlo, SloTargets};
        let snap = SloSnapshot {
            targets: "p99=20ms,avail=99.9".parse::<SloTargets>().unwrap(),
            configs: vec![
                ConfigSlo {
                    config: "iris_ovr_w4".into(),
                    short: (10, 10),
                    long: (59, 60),
                    burn_short: 0.0,
                    burn_long: 16.67,
                    degraded: false,
                },
                ConfigSlo {
                    config: "syn_rbf".into(),
                    short: (0, 10),
                    long: (0, 60),
                    burn_short: 1000.0,
                    burn_long: 1000.0,
                    degraded: true,
                },
            ],
        };
        let s = render(
            &fake_metrics(),
            Duration::from_secs(1),
            None,
            &FlexicModel::paper(),
            None,
            None,
            None,
            None,
            Some(&snap),
        );
        assert!(s.contains("SLO (p99 <= 20000 us, availability >= 99.9%)"), "{s}");
        assert!(s.contains("degraded(syn_rbf: burn"), "{s}");
        assert!(s.contains("59/60"), "{s}");
        assert!(s.contains("DEGRADED"), "{s}");
        assert!(s.contains("98.33"), "observed availability column: {s}");
    }

    #[test]
    fn render_without_farm_or_sim_samples() {
        let mut map = fake_metrics();
        let m = map.get_mut("iris_ovr_w4").unwrap();
        m.sim_samples = 0;
        m.sim_cycles = 0;
        m.energy_mj = 0.0;
        m.baseline_cycles_per_inf = 0.0;
        let s = render(
            &map,
            Duration::from_secs(1),
            None,
            &FlexicModel::paper(),
            None,
            None,
            None,
            None,
            None,
        );
        assert!(s.contains("iris_ovr_w4"));
        assert!(s.contains('-'), "uncalibrated ratio renders as dash");
        assert!(!s.contains("farm shards"));
    }
}
