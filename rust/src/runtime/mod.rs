//! PJRT runtime: load the AOT-compiled inference graphs (HLO text
//! emitted by `python/compile/aot.py`) and execute them from Rust.
//!
//! Python never runs on this path — the artifacts are compiled once by
//! `make artifacts`, and this module turns each into a resident
//! `PjRtLoadedExecutable` on the CPU PJRT client (the same flow a TPU
//! deployment would use with a TPU plugin; see /opt/xla-example/README
//! for why the interchange format is HLO *text*, not serialized proto:
//! xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit instruction ids).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::svm::model::{ConfigEntry, Manifest};

/// One compiled inference graph: predicts `batch` samples of
/// `n_features` 4-bit features in a single execution.
pub struct LoadedConfig {
    exe: xla::PjRtLoadedExecutable,
    pub key: String,
    pub batch: usize,
    pub n_features: usize,
    pub n_classifiers: usize,
}

/// Batch inference output.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Predicted class per sample.
    pub preds: Vec<i32>,
    /// Raw integer classifier scores, row-major [batch][n_classifiers].
    pub scores: Vec<i32>,
}

impl LoadedConfig {
    /// Execute on exactly `batch` samples (callers pad; see `Engine`).
    pub fn execute(&self, x_q: &[i32]) -> Result<BatchOutput> {
        if x_q.len() != self.batch * self.n_features {
            bail!(
                "expected {}x{} features, got {}",
                self.batch,
                self.n_features,
                x_q.len()
            );
        }
        let input = xla::Literal::vec1(x_q).reshape(&[self.batch as i64, self.n_features as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (pred [B], scores [B,K])
        let (pred_lit, scores_lit) = result.to_tuple2()?;
        Ok(BatchOutput { preds: pred_lit.to_vec::<i32>()?, scores: scores_lit.to_vec::<i32>()? })
    }
}

/// The PJRT engine: one CPU client + a cache of compiled configs.
pub struct Engine {
    client: xla::PjRtClient,
    loaded: HashMap<(String, usize), LoadedConfig>,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, loaded: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO text file.
    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
    }

    /// Load (compile + cache) a (config, batch) pair from the manifest.
    pub fn load(&mut self, manifest: &Manifest, entry: &ConfigEntry, batch: usize) -> Result<()> {
        let cache_key = (entry.key.clone(), batch);
        if self.loaded.contains_key(&cache_key) {
            return Ok(());
        }
        let path = manifest.hlo_path(entry, batch)?;
        let exe = self.compile(&path)?;
        self.loaded.insert(
            cache_key,
            LoadedConfig {
                exe,
                key: entry.key.clone(),
                batch,
                n_features: entry.n_features,
                n_classifiers: entry.n_classifiers,
            },
        );
        Ok(())
    }

    pub fn get(&self, key: &str, batch: usize) -> Result<&LoadedConfig> {
        self.loaded
            .get(&(key.to_string(), batch))
            .with_context(|| format!("config {key:?} batch {batch} not loaded"))
    }

    pub fn loaded_keys(&self) -> Vec<(String, usize)> {
        self.loaded.keys().cloned().collect()
    }

    /// Predict an arbitrary number of samples by padding to the loaded
    /// batch size and slicing the tail off (row-major x_q, n×F).
    pub fn predict(&self, key: &str, batch: usize, x_q: &[Vec<i32>]) -> Result<Vec<i32>> {
        let cfg = self.get(key, batch)?;
        let mut preds = Vec::with_capacity(x_q.len());
        for chunk in x_q.chunks(cfg.batch) {
            let mut flat = Vec::with_capacity(cfg.batch * cfg.n_features);
            for row in chunk {
                if row.len() != cfg.n_features {
                    bail!("feature arity mismatch");
                }
                flat.extend_from_slice(row);
            }
            flat.resize(cfg.batch * cfg.n_features, 0); // pad with zeros
            let out = cfg.execute(&flat)?;
            preds.extend_from_slice(&out.preds[..chunk.len()]);
        }
        Ok(preds)
    }
}

// NOTE: integration tests in rust/tests/runtime_pjrt.rs exercise this
// module against the real artifacts (golden vectors + accuracy); no
// unit tests here because the PJRT client needs the artifacts on disk.
