//! Disassembler — used by execution traces and the cycle_sim example.

use super::reg::NAMES;
use super::{AluOp, BranchOp, Instr, LoadOp, StoreOp, CFU_FUNCT7_SVM};

fn r(i: u8) -> &'static str {
    NAMES[i as usize]
}

fn alu_name(op: AluOp) -> &'static str {
    match op {
        AluOp::Add => "add",
        AluOp::Sub => "sub",
        AluOp::Sll => "sll",
        AluOp::Slt => "slt",
        AluOp::Sltu => "sltu",
        AluOp::Xor => "xor",
        AluOp::Srl => "srl",
        AluOp::Sra => "sra",
        AluOp::Or => "or",
        AluOp::And => "and",
    }
}

/// SVM accelerator mnemonic for a funct3 value (paper Fig. 8).
pub fn svm_mnemonic(funct3: u8) -> &'static str {
    match funct3 {
        0b000 => "sv.calc4",
        0b001 => "sv.res4",
        0b010 => "sv.calc8",
        0b100 => "sv.res8",
        0b101 => "sv.calc16",
        0b110 => "sv.res16",
        0b111 => "sv.create_env",
        _ => "sv.unknown",
    }
}

/// Render an instruction in GNU-style assembly syntax.
pub fn disasm(i: Instr) -> String {
    match i {
        Instr::Lui { rd, imm } => format!("lui {}, {:#x}", r(rd), (imm as u32) >> 12),
        Instr::Auipc { rd, imm } => format!("auipc {}, {:#x}", r(rd), (imm as u32) >> 12),
        Instr::Jal { rd, offset } => format!("jal {}, {offset:+}", r(rd)),
        Instr::Jalr { rd, rs1, offset } => format!("jalr {}, {offset}({})", r(rd), r(rs1)),
        Instr::Branch { op, rs1, rs2, offset } => {
            let name = match op {
                BranchOp::Beq => "beq",
                BranchOp::Bne => "bne",
                BranchOp::Blt => "blt",
                BranchOp::Bge => "bge",
                BranchOp::Bltu => "bltu",
                BranchOp::Bgeu => "bgeu",
            };
            format!("{name} {}, {}, {offset:+}", r(rs1), r(rs2))
        }
        Instr::Load { op, rd, rs1, offset } => {
            let name = match op {
                LoadOp::Lb => "lb",
                LoadOp::Lh => "lh",
                LoadOp::Lw => "lw",
                LoadOp::Lbu => "lbu",
                LoadOp::Lhu => "lhu",
            };
            format!("{name} {}, {offset}({})", r(rd), r(rs1))
        }
        Instr::Store { op, rs1, rs2, offset } => {
            let name = match op {
                StoreOp::Sb => "sb",
                StoreOp::Sh => "sh",
                StoreOp::Sw => "sw",
            };
            format!("{name} {}, {offset}({})", r(rs2), r(rs1))
        }
        Instr::OpImm { op, rd, rs1, imm } => match op {
            AluOp::Sll | AluOp::Srl | AluOp::Sra => {
                format!("{}i {}, {}, {imm}", alu_name(op), r(rd), r(rs1))
            }
            _ => format!("{}i {}, {}, {imm}", alu_name(op), r(rd), r(rs1)),
        },
        Instr::Op { op, rd, rs1, rs2 } => {
            format!("{} {}, {}, {}", alu_name(op), r(rd), r(rs1), r(rs2))
        }
        Instr::Custom { funct7, funct3, rd, rs1, rs2 } => {
            if funct7 == CFU_FUNCT7_SVM {
                format!("{} {}, {}, {}", svm_mnemonic(funct3), r(rd), r(rs1), r(rs2))
            } else {
                format!("cfu{funct7}.op{funct3} {}, {}, {}", r(rd), r(rs1), r(rs2))
            }
        }
        Instr::Fence => "fence".to_string(),
        Instr::Ecall => "ecall".to_string(),
        Instr::Ebreak => "ebreak".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::reg::*;
    use super::*;

    #[test]
    fn renders() {
        assert_eq!(
            disasm(Instr::OpImm { op: AluOp::Add, rd: A0, rs1: ZERO, imm: 5 }),
            "addi a0, zero, 5"
        );
        assert_eq!(
            disasm(Instr::Custom { funct7: 1, funct3: 0, rd: ZERO, rs1: A1, rs2: A2 }),
            "sv.calc4 zero, a1, a2"
        );
        assert_eq!(
            disasm(Instr::Custom { funct7: 1, funct3: 7, rd: ZERO, rs1: ZERO, rs2: ZERO }),
            "sv.create_env zero, zero, zero"
        );
        assert_eq!(
            disasm(Instr::Load { op: LoadOp::Lw, rd: T0, rs1: SP, offset: 8 }),
            "lw t0, 8(sp)"
        );
    }
}
