//! RV32I (+ custom CFU) instruction decoder.
//!
//! This is the software twin of the paper's *modified SERV decoder*
//! (Fig. 4): a standard R-type word whose funct7 is neither 0x00 nor
//! 0x20 is dispatched as a `Custom` (accelerator) instruction — the
//! hardware asserts `acc_op` and forwards `funct3` to the CFU.

use anyhow::{bail, Result};

use super::{AluOp, BranchOp, Instr, LoadOp, StoreOp};

#[inline]
fn bits(w: u32, hi: u32, lo: u32) -> u32 {
    (w >> lo) & ((1 << (hi - lo + 1)) - 1)
}

#[inline]
fn sign_extend(v: u32, width: u32) -> i32 {
    let shift = 32 - width;
    ((v << shift) as i32) >> shift
}

/// Decode a 32-bit instruction word.
pub fn decode(w: u32) -> Result<Instr> {
    let opcode = bits(w, 6, 0);
    let rd = bits(w, 11, 7) as u8;
    let funct3 = bits(w, 14, 12) as u8;
    let rs1 = bits(w, 19, 15) as u8;
    let rs2 = bits(w, 24, 20) as u8;
    let funct7 = bits(w, 31, 25) as u8;

    let imm_i = sign_extend(bits(w, 31, 20), 12);
    let imm_s = sign_extend((bits(w, 31, 25) << 5) | bits(w, 11, 7), 12);
    let imm_b = sign_extend(
        (bits(w, 31, 31) << 12) | (bits(w, 7, 7) << 11) | (bits(w, 30, 25) << 5) | (bits(w, 11, 8) << 1),
        13,
    );
    let imm_u = (w & 0xffff_f000) as i32;
    let imm_j = sign_extend(
        (bits(w, 31, 31) << 20) | (bits(w, 19, 12) << 12) | (bits(w, 20, 20) << 11) | (bits(w, 30, 21) << 1),
        21,
    );

    Ok(match opcode {
        0b0110111 => Instr::Lui { rd, imm: imm_u },
        0b0010111 => Instr::Auipc { rd, imm: imm_u },
        0b1101111 => Instr::Jal { rd, offset: imm_j },
        0b1100111 => {
            if funct3 != 0 {
                bail!("bad JALR funct3 {funct3}");
            }
            Instr::Jalr { rd, rs1, offset: imm_i }
        }
        0b1100011 => {
            let op = match funct3 {
                0b000 => BranchOp::Beq,
                0b001 => BranchOp::Bne,
                0b100 => BranchOp::Blt,
                0b101 => BranchOp::Bge,
                0b110 => BranchOp::Bltu,
                0b111 => BranchOp::Bgeu,
                _ => bail!("bad branch funct3 {funct3}"),
            };
            Instr::Branch { op, rs1, rs2, offset: imm_b }
        }
        0b0000011 => {
            let op = match funct3 {
                0b000 => LoadOp::Lb,
                0b001 => LoadOp::Lh,
                0b010 => LoadOp::Lw,
                0b100 => LoadOp::Lbu,
                0b101 => LoadOp::Lhu,
                _ => bail!("bad load funct3 {funct3}"),
            };
            Instr::Load { op, rd, rs1, offset: imm_i }
        }
        0b0100011 => {
            let op = match funct3 {
                0b000 => StoreOp::Sb,
                0b001 => StoreOp::Sh,
                0b010 => StoreOp::Sw,
                _ => bail!("bad store funct3 {funct3}"),
            };
            Instr::Store { op, rs1, rs2, offset: imm_s }
        }
        0b0010011 => {
            let op = match funct3 {
                0b000 => AluOp::Add,
                0b001 => {
                    if funct7 != 0 {
                        bail!("bad SLLI funct7 {funct7:#x}");
                    }
                    AluOp::Sll
                }
                0b010 => AluOp::Slt,
                0b011 => AluOp::Sltu,
                0b100 => AluOp::Xor,
                0b101 => match funct7 {
                    0x00 => AluOp::Srl,
                    0x20 => AluOp::Sra,
                    _ => bail!("bad shift funct7 {funct7:#x}"),
                },
                0b110 => AluOp::Or,
                0b111 => AluOp::And,
                _ => unreachable!(),
            };
            let imm = match op {
                AluOp::Sll | AluOp::Srl | AluOp::Sra => (rs2 as i32) & 0x1f,
                _ => imm_i,
            };
            Instr::OpImm { op, rd, rs1, imm }
        }
        0b0110011 => {
            // The modified decoder (Fig. 4): funct7 ∉ {0x00, 0x20} → acc_op.
            match funct7 {
                0x00 => {
                    let op = match funct3 {
                        0b000 => AluOp::Add,
                        0b001 => AluOp::Sll,
                        0b010 => AluOp::Slt,
                        0b011 => AluOp::Sltu,
                        0b100 => AluOp::Xor,
                        0b101 => AluOp::Srl,
                        0b110 => AluOp::Or,
                        0b111 => AluOp::And,
                        _ => unreachable!(),
                    };
                    Instr::Op { op, rd, rs1, rs2 }
                }
                0x20 => {
                    let op = match funct3 {
                        0b000 => AluOp::Sub,
                        0b101 => AluOp::Sra,
                        _ => bail!("bad OP funct3 {funct3} with funct7=0x20"),
                    };
                    Instr::Op { op, rd, rs1, rs2 }
                }
                f7 => Instr::Custom { funct7: f7, funct3, rd, rs1, rs2 },
            }
        }
        0b0001111 => Instr::Fence,
        0b1110011 => match bits(w, 31, 20) {
            0 => Instr::Ecall,
            1 => Instr::Ebreak,
            sys => bail!("unsupported SYSTEM instruction (imm={sys:#x}); CSRs are not implemented in SERV"),
        },
        _ => bail!("unknown opcode {opcode:#09b} (word {w:#010x})"),
    })
}

#[cfg(test)]
mod tests {
    use super::super::encode::encode;
    use super::super::reg::*;
    use super::*;

    /// encode -> decode must be the identity on every instruction form.
    #[test]
    fn roundtrip_all_forms() {
        let cases = vec![
            Instr::Lui { rd: T0, imm: 0x7ffff << 12 },
            Instr::Auipc { rd: A0, imm: -4096 },
            Instr::Jal { rd: RA, offset: -2048 },
            Instr::Jalr { rd: ZERO, rs1: RA, offset: 0 },
            Instr::Branch { op: BranchOp::Bgeu, rs1: T0, rs2: T1, offset: 4094 },
            Instr::Branch { op: BranchOp::Blt, rs1: S0, rs2: S1, offset: -4096 },
            Instr::Load { op: LoadOp::Lbu, rd: A1, rs1: SP, offset: -1 },
            Instr::Load { op: LoadOp::Lw, rd: A1, rs1: SP, offset: 2047 },
            Instr::Store { op: StoreOp::Sh, rs1: SP, rs2: A2, offset: -2048 },
            Instr::OpImm { op: AluOp::Xor, rd: T2, rs1: T3, imm: -1 },
            Instr::OpImm { op: AluOp::Sra, rd: T2, rs1: T3, imm: 31 },
            Instr::OpImm { op: AluOp::Sll, rd: T2, rs1: T3, imm: 1 },
            Instr::Op { op: AluOp::Sub, rd: S2, rs1: S3, rs2: S4 },
            Instr::Op { op: AluOp::Sltu, rd: S2, rs1: S3, rs2: S4 },
            Instr::Custom { funct7: 1, funct3: 7, rd: A0, rs1: A1, rs2: A2 },
            Instr::Custom { funct7: 3, funct3: 0, rd: ZERO, rs1: A1, rs2: A2 },
            Instr::Fence,
            Instr::Ecall,
            Instr::Ebreak,
        ];
        for i in cases {
            let w = encode(i);
            let d = decode(w).unwrap_or_else(|e| panic!("decode {i:?}: {e}"));
            assert_eq!(d, i, "word {w:#010x}");
        }
    }

    #[test]
    fn custom_funct7_routing() {
        // funct7=1 with OP opcode is the SVM accelerator, not ADD
        let w = encode(Instr::Custom { funct7: 1, funct3: 0, rd: A0, rs1: A1, rs2: A2 });
        match decode(w).unwrap() {
            Instr::Custom { funct7: 1, .. } => {}
            other => panic!("expected Custom, got {other:?}"),
        }
        // funct7=0 stays a regular ADD
        let w = encode(Instr::Op { op: AluOp::Add, rd: A0, rs1: A1, rs2: A2 });
        assert!(matches!(decode(w).unwrap(), Instr::Op { op: AluOp::Add, .. }));
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(0xffff_ffff).is_err());
        assert!(decode(0x0000_0000).is_err()); // opcode 0
    }
}
