//! Text assembler front-end: parse GNU-style RV32I assembly (the same
//! syntax `disasm` emits, plus labels and `.word` directives) into an
//! `Asm` program.  Round-trip property: `parse(disasm(i)) == i`.
//!
//! Supported grammar per line (comments start with `#` or `//`):
//!   label:
//!   mnemonic rd, rs1, rs2
//!   mnemonic rd, rs1, imm
//!   load/store:  lw rd, off(rs1)   sw rs2, off(rs1)
//!   branches:    beq rs1, rs2, <label|offset>
//!   jumps:       jal rd, <label|offset>    j <label>
//!   pseudo:      li, mv, nop, ret, call
//!   custom:      sv.calc4 rd, rs1, rs2   cfu<f7>.op<f3> rd, rs1, rs2
//!   data:        .word 0x1234  |  .zero N

use anyhow::{anyhow, bail, Context, Result};

use super::reg::NAMES;
use super::{svm_ops, Asm, BranchOp, CFU_FUNCT7_SVM};

fn parse_reg(tok: &str) -> Result<u8> {
    let t = tok.trim();
    if let Some(i) = NAMES.iter().position(|n| *n == t) {
        return Ok(i as u8);
    }
    if let Some(n) = t.strip_prefix('x') {
        let i: u8 = n.parse().context("bad xN register")?;
        if i < 32 {
            return Ok(i);
        }
    }
    bail!("unknown register {t:?}")
}

fn parse_imm(tok: &str) -> Result<i32> {
    let t = tok.trim();
    let (neg, t) = match t.strip_prefix('-') {
        Some(rest) => (true, rest),
        None => (false, t.strip_prefix('+').unwrap_or(t)),
    };
    let v = if let Some(hex) = t.strip_prefix("0x") {
        i64::from_str_radix(hex, 16)?
    } else if let Some(bin) = t.strip_prefix("0b") {
        i64::from_str_radix(bin, 2)?
    } else {
        t.parse::<i64>().with_context(|| format!("bad immediate {t:?}"))?
    };
    let v = if neg { -v } else { v };
    i32::try_from(v).map_err(|_| anyhow!("immediate {v} out of 32-bit range"))
}

/// split "off(reg)" -> (off, reg)
fn parse_mem_operand(tok: &str) -> Result<(i32, u8)> {
    let t = tok.trim();
    let open = t.find('(').ok_or_else(|| anyhow!("expected off(reg), got {t:?}"))?;
    let close = t.rfind(')').ok_or_else(|| anyhow!("missing ')' in {t:?}"))?;
    let off = if open == 0 { 0 } else { parse_imm(&t[..open])? };
    let reg = parse_reg(&t[open + 1..close])?;
    Ok((off, reg))
}

fn svm_funct3(mnemonic: &str) -> Option<u8> {
    Some(match mnemonic {
        "sv.calc4" => svm_ops::SV_CALC4,
        "sv.res4" => svm_ops::SV_RES4,
        "sv.calc8" => svm_ops::SV_CALC8,
        "sv.res8" => svm_ops::SV_RES8,
        "sv.calc16" => svm_ops::SV_CALC16,
        "sv.res16" => svm_ops::SV_RES16,
        "sv.create_env" => svm_ops::CREATE_ENV,
        _ => return None,
    })
}

/// Parse a full program into an `Asm` (base address 0 unless set).
pub fn parse_program(text: &str) -> Result<Asm> {
    let mut a = Asm::new(0);
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap().split("//").next().unwrap().trim();
        if line.is_empty() {
            continue;
        }
        parse_line(&mut a, line)
            .with_context(|| format!("line {}: {raw:?}", lineno + 1))?;
    }
    Ok(a)
}

fn parse_line(a: &mut Asm, line: &str) -> Result<()> {
    if let Some(label) = line.strip_suffix(':') {
        let label = label.trim();
        if label.is_empty() || label.contains(char::is_whitespace) {
            bail!("bad label {label:?}");
        }
        a.label(label);
        return Ok(());
    }
    let (mnemonic, rest) = match line.find(char::is_whitespace) {
        Some(i) => (&line[..i], line[i..].trim()),
        None => (line, ""),
    };
    let ops: Vec<&str> = if rest.is_empty() {
        vec![]
    } else {
        rest.split(',').map(|s| s.trim()).collect()
    };
    let n = ops.len();
    let rrr = |a: &mut Asm, f: fn(&mut Asm, u8, u8, u8) -> &mut Asm| -> Result<()> {
        anyhow::ensure!(n == 3, "{mnemonic} needs 3 operands");
        f(a, parse_reg(ops[0])?, parse_reg(ops[1])?, parse_reg(ops[2])?);
        Ok(())
    };
    let rri = |a: &mut Asm, f: fn(&mut Asm, u8, u8, i32) -> &mut Asm| -> Result<()> {
        anyhow::ensure!(n == 3, "{mnemonic} needs 3 operands");
        f(a, parse_reg(ops[0])?, parse_reg(ops[1])?, parse_imm(ops[2])?);
        Ok(())
    };
    let branch = |a: &mut Asm, op: BranchOp| -> Result<()> {
        anyhow::ensure!(n == 3, "{mnemonic} needs 3 operands");
        a.branch(op, parse_reg(ops[0])?, parse_reg(ops[1])?, ops[2]);
        Ok(())
    };
    let load = |a: &mut Asm, f: fn(&mut Asm, u8, u8, i32) -> &mut Asm| -> Result<()> {
        anyhow::ensure!(n == 2, "{mnemonic} needs rd, off(rs1)");
        let rd = parse_reg(ops[0])?;
        let (off, rs1) = parse_mem_operand(ops[1])?;
        f(a, rd, rs1, off);
        Ok(())
    };
    let store = |a: &mut Asm, f: fn(&mut Asm, u8, u8, i32) -> &mut Asm| -> Result<()> {
        anyhow::ensure!(n == 2, "{mnemonic} needs rs2, off(rs1)");
        let rs2 = parse_reg(ops[0])?;
        let (off, rs1) = parse_mem_operand(ops[1])?;
        f(a, rs1, rs2, off);
        Ok(())
    };

    match mnemonic {
        "add" => rrr(a, |a, d, s1, s2| a.add(d, s1, s2))?,
        "sub" => rrr(a, |a, d, s1, s2| a.sub(d, s1, s2))?,
        "and" => rrr(a, |a, d, s1, s2| a.and(d, s1, s2))?,
        "or" => rrr(a, |a, d, s1, s2| a.or(d, s1, s2))?,
        "xor" => rrr(a, |a, d, s1, s2| a.xor(d, s1, s2))?,
        "sll" => rrr(a, |a, d, s1, s2| a.sll(d, s1, s2))?,
        "srl" => rrr(a, |a, d, s1, s2| a.srl(d, s1, s2))?,
        "sra" => rrr(a, |a, d, s1, s2| a.sra(d, s1, s2))?,
        "slt" => rrr(a, |a, d, s1, s2| a.slt(d, s1, s2))?,
        "sltu" => rrr(a, |a, d, s1, s2| a.sltu(d, s1, s2))?,
        "addi" => rri(a, |a, d, s, i| a.addi(d, s, i))?,
        "andi" => rri(a, |a, d, s, i| a.andi(d, s, i))?,
        "ori" => rri(a, |a, d, s, i| a.ori(d, s, i))?,
        "xori" => rri(a, |a, d, s, i| a.xori(d, s, i))?,
        "slti" => rri(a, |a, d, s, i| a.slti(d, s, i))?,
        "slli" => rri(a, |a, d, s, i| a.slli(d, s, i))?,
        "srli" => rri(a, |a, d, s, i| a.srli(d, s, i))?,
        "srai" => rri(a, |a, d, s, i| a.srai(d, s, i))?,
        "li" => rri_2(a, ops, |a, d, i| {
            a.li(d, i);
        })?,
        "lui" => rri_2(a, ops, |a, d, i| {
            a.lui(d, i << 12);
        })?,
        "auipc" => rri_2(a, ops, |a, d, i| {
            a.auipc(d, i << 12);
        })?,
        "mv" => {
            anyhow::ensure!(n == 2, "mv needs 2 operands");
            a.mv(parse_reg(ops[0])?, parse_reg(ops[1])?);
        }
        "lw" => load(a, |a, d, s, o| a.lw(d, s, o))?,
        "lb" => load(a, |a, d, s, o| a.lb(d, s, o))?,
        "lbu" => load(a, |a, d, s, o| a.lbu(d, s, o))?,
        "lh" => load(a, |a, d, s, o| a.lh(d, s, o))?,
        "lhu" => load(a, |a, d, s, o| a.lhu(d, s, o))?,
        "sw" => store(a, |a, s1, s2, o| a.sw(s1, s2, o))?,
        "sb" => store(a, |a, s1, s2, o| a.sb(s1, s2, o))?,
        "sh" => store(a, |a, s1, s2, o| a.sh(s1, s2, o))?,
        "beq" => branch(a, BranchOp::Beq)?,
        "bne" => branch(a, BranchOp::Bne)?,
        "blt" => branch(a, BranchOp::Blt)?,
        "bge" => branch(a, BranchOp::Bge)?,
        "bltu" => branch(a, BranchOp::Bltu)?,
        "bgeu" => branch(a, BranchOp::Bgeu)?,
        "jal" => {
            anyhow::ensure!(n == 2, "jal needs rd, target");
            a.jal(parse_reg(ops[0])?, ops[1]);
        }
        "jalr" => {
            anyhow::ensure!(n == 2, "jalr needs rd, off(rs1)");
            let rd = parse_reg(ops[0])?;
            let (off, rs1) = parse_mem_operand(ops[1])?;
            a.jalr(rd, rs1, off);
        }
        "j" => {
            anyhow::ensure!(n == 1, "j needs a target");
            a.j(ops[0]);
        }
        "call" => {
            anyhow::ensure!(n == 1, "call needs a target");
            a.call(ops[0]);
        }
        "la" => {
            anyhow::ensure!(n == 2, "la needs rd, label");
            a.la(parse_reg(ops[0])?, ops[1]);
        }
        "ret" => {
            a.ret();
        }
        "nop" => {
            a.nop();
        }
        "ecall" => {
            a.ecall();
        }
        "ebreak" => {
            a.ebreak();
        }
        "fence" => {
            a.word(super::encode::encode(super::Instr::Fence));
        }
        ".word" => {
            anyhow::ensure!(n == 1, ".word needs one value");
            a.word(parse_imm(ops[0])? as u32);
        }
        ".zero" => {
            anyhow::ensure!(n == 1, ".zero needs a count");
            a.zeros(parse_imm(ops[0])? as usize);
        }
        m => {
            // custom CFU forms: sv.* or cfu<f7>.op<f3>
            if let Some(f3) = svm_funct3(m) {
                anyhow::ensure!(n == 3, "{m} needs 3 operands");
                a.cfu(CFU_FUNCT7_SVM, f3, parse_reg(ops[0])?, parse_reg(ops[1])?, parse_reg(ops[2])?);
            } else if let Some(rest) = m.strip_prefix("cfu") {
                let (f7s, f3s) = rest
                    .split_once(".op")
                    .ok_or_else(|| anyhow!("bad custom mnemonic {m:?}"))?;
                let f7: u8 = f7s.parse()?;
                let f3: u8 = f3s.parse()?;
                anyhow::ensure!(n == 3, "{m} needs 3 operands");
                a.cfu(f7, f3, parse_reg(ops[0])?, parse_reg(ops[1])?, parse_reg(ops[2])?);
            } else {
                bail!("unknown mnemonic {m:?}");
            }
        }
    }
    Ok(())
}

fn rri_2(a: &mut Asm, ops: Vec<&str>, f: impl FnOnce(&mut Asm, u8, i32)) -> Result<()> {
    anyhow::ensure!(ops.len() == 2, "needs 2 operands");
    f(a, parse_reg(ops[0])?, parse_imm(ops[1])?);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{decode, disasm};
    use super::*;
    use crate::serv::TimingConfig;
    use crate::soc::Soc;

    #[test]
    fn parse_and_run_program() {
        let src = r#"
            # sum 1..10 through memory
                la   s0, buf
                li   t0, 10
                li   t1, 0
            loop:
                add  t1, t1, t0
                sw   t1, 0(s0)
                lw   t1, 0(s0)
                addi t0, t0, -1
                bne  t0, zero, loop
                mv   a0, t1
                ecall
            buf:
                .zero 1
        "#;
        let a = parse_program(src).unwrap();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::ideal_mem());
        assert_eq!(soc.run(10_000_000).unwrap().value(), 55);
    }

    #[test]
    fn parse_custom_instructions() {
        let src = "sv.create_env zero, zero, zero\nsv.calc4 zero, a1, a2\ncfu3.op1 a0, a1, a2\necall\n";
        let a = parse_program(src).unwrap();
        let words = a.assemble().unwrap();
        match decode(words[0]).unwrap() {
            super::super::Instr::Custom { funct7: 1, funct3: 7, .. } => {}
            other => panic!("{other:?}"),
        }
        match decode(words[2]).unwrap() {
            super::super::Instr::Custom { funct7: 3, funct3: 1, .. } => {}
            other => panic!("{other:?}"),
        }
    }

    /// disasm -> parse -> encode is the identity for register/imm forms.
    #[test]
    fn disasm_parse_roundtrip() {
        use crate::testing::check;
        check("disasm-parse", 0x77, 500, |rng| {
            use super::super::{AluOp, Instr, LoadOp, StoreOp};
            let rd = rng.below(32) as u8;
            let rs1 = rng.below(32) as u8;
            let rs2 = rng.below(32) as u8;
            let instr = match rng.below(5) {
                0 => Instr::Op { op: *rng.choose(&[AluOp::Add, AluOp::Sub, AluOp::Xor, AluOp::Sltu]), rd, rs1, rs2 },
                1 => Instr::OpImm { op: AluOp::Add, rd, rs1, imm: rng.range_i32(-2048, 2047) },
                2 => Instr::Load { op: *rng.choose(&[LoadOp::Lw, LoadOp::Lbu, LoadOp::Lh]), rd, rs1, offset: rng.range_i32(-2048, 2047) },
                3 => Instr::Store { op: *rng.choose(&[StoreOp::Sw, StoreOp::Sb]), rs1, rs2, offset: rng.range_i32(-2048, 2047) },
                _ => Instr::Custom { funct7: CFU_FUNCT7_SVM, funct3: *rng.choose(&[0u8, 1, 2, 4, 5, 6, 7]), rd, rs1, rs2 },
            };
            let text = disasm(instr);
            let a = parse_program(&text).unwrap_or_else(|e| panic!("parse {text:?}: {e}"));
            let words = a.assemble().unwrap();
            assert_eq!(decode(words[0]).unwrap(), instr, "text was {text:?}");
        });
    }

    #[test]
    fn hex_and_binary_immediates() {
        let a = parse_program("li a0, 0x10\nli a1, -0x10\nli a2, 0b101\necall").unwrap();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::ideal_mem());
        let r = soc.run(100_000).unwrap();
        match r.exit {
            crate::serv::Exit::Ecall { a0, a1 } => {
                assert_eq!(a0, 16);
                assert_eq!(a1 as i32, -16);
            }
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_program("nop\nbogus a0, a1\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }
}
