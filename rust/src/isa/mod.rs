//! RV32I instruction-set substrate: encode, decode, disassemble, and a
//! programmatic macro-assembler.
//!
//! This replaces the bare-metal GCC toolchain the paper uses: both the
//! software-only baseline and the accelerated SVM routine are generated
//! directly as machine code (rust/src/program/), so the exact instruction
//! stream the SERV simulator executes is auditable.
//!
//! The custom ML-accelerator instructions (paper Fig. 3/8) reuse the
//! standard R-type OP opcode (0b0110011) with `funct7 = 1`; `funct3`
//! selects the accelerator operation.  SERV itself only uses funct7
//! values 0x00 and 0x20, so funct7 = 1..=0x1f (≠0x20) are free for CFUs;
//! we follow the paper and route funct7 = 1 to the SVM accelerator, and
//! demonstrate extensibility with funct7 = 2, 3 demo CFUs.

pub mod asm;
pub mod decode;
pub mod disasm;
pub mod encode;
pub mod parse;

pub use asm::Asm;
pub use decode::decode;
pub use disasm::disasm;

/// ABI register indices (x0..x31).
pub mod reg {
    pub const ZERO: u8 = 0;
    pub const RA: u8 = 1;
    pub const SP: u8 = 2;
    pub const GP: u8 = 3;
    pub const TP: u8 = 4;
    pub const T0: u8 = 5;
    pub const T1: u8 = 6;
    pub const T2: u8 = 7;
    pub const S0: u8 = 8;
    pub const S1: u8 = 9;
    pub const A0: u8 = 10;
    pub const A1: u8 = 11;
    pub const A2: u8 = 12;
    pub const A3: u8 = 13;
    pub const A4: u8 = 14;
    pub const A5: u8 = 15;
    pub const A6: u8 = 16;
    pub const A7: u8 = 17;
    pub const S2: u8 = 18;
    pub const S3: u8 = 19;
    pub const S4: u8 = 20;
    pub const S5: u8 = 21;
    pub const S6: u8 = 22;
    pub const S7: u8 = 23;
    pub const S8: u8 = 24;
    pub const S9: u8 = 25;
    pub const S10: u8 = 26;
    pub const S11: u8 = 27;
    pub const T3: u8 = 28;
    pub const T4: u8 = 29;
    pub const T5: u8 = 30;
    pub const T6: u8 = 31;

    pub const NAMES: [&str; 32] = [
        "zero", "ra", "sp", "gp", "tp", "t0", "t1", "t2", "s0", "s1", "a0", "a1", "a2", "a3",
        "a4", "a5", "a6", "a7", "s2", "s3", "s4", "s5", "s6", "s7", "s8", "s9", "s10", "s11",
        "t3", "t4", "t5", "t6",
    ];
}

/// The funct7 value that routes an R-type instruction to the paper's SVM
/// accelerator (Fig. 3: funct7 = 0000001).
pub const CFU_FUNCT7_SVM: u8 = 1;

/// SVM accelerator funct3 encodings (paper Fig. 8).
pub mod svm_ops {
    pub const SV_CALC4: u8 = 0b000;
    pub const SV_RES4: u8 = 0b001;
    pub const SV_CALC8: u8 = 0b010;
    pub const SV_RES8: u8 = 0b100;
    pub const SV_CALC16: u8 = 0b101;
    pub const SV_RES16: u8 = 0b110;
    pub const CREATE_ENV: u8 = 0b111;
}

/// The funct7 value that routes to the kernel-SVM accelerator (ISSUE 8:
/// RBF/poly feature-map evaluation + dual accumulate).
pub const CFU_FUNCT7_KSVM: u8 = 4;

/// Kernel-SVM accelerator funct3 encodings.
///
/// A kernel pass per support vector is: repeated `K_ACC` over the
/// packed 4-bit lanes (squared distance for RBF, dot product for poly),
/// one `K_EVAL` with the dual coefficient (evaluates phi from the
/// accumulator and folds `alpha * phi` into the score), and per
/// classifier one `K_RES` with the bias (finalizes `+ KSCALE * b` and
/// updates the argmax registers exactly like `SV_RES*`).
pub mod ksvm_ops {
    /// rs1 = value, rs2 = config register index (see `kcfg`).
    pub const K_CFG: u8 = 0b000;
    /// rs1 = 8x4-bit input lanes, rs2 = 8x4-bit support-vector lanes.
    pub const K_ACC: u8 = 0b001;
    /// rs1 = signed dual coefficient alpha.
    pub const K_EVAL: u8 = 0b010;
    /// rs1 = signed bias; returns sign|max_id like the linear RES ops.
    pub const K_RES: u8 = 0b011;
    /// Full reset, config registers included.
    pub const K_ENV: u8 = 0b111;

    /// `K_CFG` register indices (rs2 operand).
    pub mod kcfg {
        /// 1 = rbf, 2 = poly.
        pub const KIND: u32 = 0;
        /// rbf `g2_q` / poly `gamma_q`.
        pub const GAMMA: u32 = 1;
        pub const COEF0: u32 = 2;
        pub const DEGREE: u32 = 3;
    }

    /// `kcfg::KIND` values.
    pub const KIND_RBF: u32 = 1;
    pub const KIND_POLY: u32 = 2;
}

/// A decoded RV32I (+ custom CFU) instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    Lui { rd: u8, imm: i32 },
    Auipc { rd: u8, imm: i32 },
    Jal { rd: u8, offset: i32 },
    Jalr { rd: u8, rs1: u8, offset: i32 },
    Branch { op: BranchOp, rs1: u8, rs2: u8, offset: i32 },
    Load { op: LoadOp, rd: u8, rs1: u8, offset: i32 },
    Store { op: StoreOp, rs1: u8, rs2: u8, offset: i32 },
    OpImm { op: AluOp, rd: u8, rs1: u8, imm: i32 },
    Op { op: AluOp, rd: u8, rs1: u8, rs2: u8 },
    /// Custom CFU dispatch: R-type with non-standard funct7 (paper Fig. 3).
    Custom { funct7: u8, funct3: u8, rd: u8, rs1: u8, rs2: u8 },
    Fence,
    Ecall,
    Ebreak,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchOp {
    Beq,
    Bne,
    Blt,
    Bge,
    Bltu,
    Bgeu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadOp {
    Lb,
    Lh,
    Lw,
    Lbu,
    Lhu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreOp {
    Sb,
    Sh,
    Sw,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Slt,
    Sltu,
    Xor,
    Srl,
    Sra,
    Or,
    And,
}

impl Instr {
    /// Does this instruction write a destination register?
    pub fn writes_rd(&self) -> Option<u8> {
        match *self {
            Instr::Lui { rd, .. }
            | Instr::Auipc { rd, .. }
            | Instr::Jal { rd, .. }
            | Instr::Jalr { rd, .. }
            | Instr::Load { rd, .. }
            | Instr::OpImm { rd, .. }
            | Instr::Op { rd, .. }
            | Instr::Custom { rd, .. } => {
                if rd != 0 {
                    Some(rd)
                } else {
                    None
                }
            }
            _ => None,
        }
    }
}
