//! Programmatic RV32I macro-assembler with labels and pseudo-instructions.
//!
//! Replaces the bare-metal GCC toolchain of the paper's flow: the program
//! generators (rust/src/program/) build the baseline and accelerated SVM
//! inference routines through this API, and the SERV simulator executes
//! the assembled image directly.
//!
//! Supported pseudo-instructions: `li` (1–2 words), `la` (2 words,
//! label-relocated), `mv`, `j`, `call`, `ret`, `nop`.  Branch and jump
//! targets may be forward references; they are patched in `assemble()`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::encode::encode;
use super::reg::{RA, ZERO};
use super::{AluOp, BranchOp, Instr, LoadOp, StoreOp};

#[derive(Debug, Clone)]
enum Item {
    /// A fully-resolved instruction.
    Fixed(Instr),
    /// Branch to a label (offset patched at assembly).
    Branch { op: BranchOp, rs1: u8, rs2: u8, target: String },
    /// jal rd, label
    Jal { rd: u8, target: String },
    /// First word of `la rd, label` (lui); second word is the paired addi.
    LaHi { rd: u8, target: String },
    LaLo { rd: u8, target: String },
    /// Raw data word.
    Word(u32),
}

/// Assembler state.  All addresses are byte addresses relative to `base`.
#[derive(Debug)]
pub struct Asm {
    base: u32,
    items: Vec<Item>,
    labels: BTreeMap<String, u32>, // label -> byte offset from base
}

impl Asm {
    pub fn new(base: u32) -> Self {
        Asm { base, items: Vec::new(), labels: BTreeMap::new() }
    }

    /// Current location counter (absolute address).
    pub fn here(&self) -> u32 {
        self.base + (self.items.len() as u32) * 4
    }

    pub fn label(&mut self, name: &str) {
        let off = (self.items.len() as u32) * 4;
        assert!(
            self.labels.insert(name.to_string(), off).is_none(),
            "duplicate label {name:?}"
        );
    }

    pub fn lookup(&self, name: &str) -> Option<u32> {
        self.labels.get(name).map(|off| self.base + off)
    }

    fn push(&mut self, i: Instr) -> &mut Self {
        self.items.push(Item::Fixed(i));
        self
    }

    // -- raw instructions ---------------------------------------------------

    pub fn lui(&mut self, rd: u8, imm_hi20: i32) -> &mut Self {
        self.push(Instr::Lui { rd, imm: imm_hi20 })
    }
    pub fn auipc(&mut self, rd: u8, imm: i32) -> &mut Self {
        self.push(Instr::Auipc { rd, imm })
    }
    pub fn addi(&mut self, rd: u8, rs1: u8, imm: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::Add, rd, rs1, imm })
    }
    pub fn andi(&mut self, rd: u8, rs1: u8, imm: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::And, rd, rs1, imm })
    }
    pub fn ori(&mut self, rd: u8, rs1: u8, imm: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::Or, rd, rs1, imm })
    }
    pub fn xori(&mut self, rd: u8, rs1: u8, imm: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::Xor, rd, rs1, imm })
    }
    pub fn slti(&mut self, rd: u8, rs1: u8, imm: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::Slt, rd, rs1, imm })
    }
    pub fn slli(&mut self, rd: u8, rs1: u8, shamt: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::Sll, rd, rs1, imm: shamt })
    }
    pub fn srli(&mut self, rd: u8, rs1: u8, shamt: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::Srl, rd, rs1, imm: shamt })
    }
    pub fn srai(&mut self, rd: u8, rs1: u8, shamt: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::Sra, rd, rs1, imm: shamt })
    }
    pub fn add(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Add, rd, rs1, rs2 })
    }
    pub fn sub(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Sub, rd, rs1, rs2 })
    }
    pub fn and(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::And, rd, rs1, rs2 })
    }
    pub fn or(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Or, rd, rs1, rs2 })
    }
    pub fn xor(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Xor, rd, rs1, rs2 })
    }
    pub fn sll(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Sll, rd, rs1, rs2 })
    }
    pub fn srl(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Srl, rd, rs1, rs2 })
    }
    pub fn sra(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Sra, rd, rs1, rs2 })
    }
    pub fn slt(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Slt, rd, rs1, rs2 })
    }
    pub fn sltu(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Sltu, rd, rs1, rs2 })
    }
    pub fn lw(&mut self, rd: u8, rs1: u8, offset: i32) -> &mut Self {
        self.push(Instr::Load { op: LoadOp::Lw, rd, rs1, offset })
    }
    pub fn lb(&mut self, rd: u8, rs1: u8, offset: i32) -> &mut Self {
        self.push(Instr::Load { op: LoadOp::Lb, rd, rs1, offset })
    }
    pub fn lbu(&mut self, rd: u8, rs1: u8, offset: i32) -> &mut Self {
        self.push(Instr::Load { op: LoadOp::Lbu, rd, rs1, offset })
    }
    pub fn lh(&mut self, rd: u8, rs1: u8, offset: i32) -> &mut Self {
        self.push(Instr::Load { op: LoadOp::Lh, rd, rs1, offset })
    }
    pub fn lhu(&mut self, rd: u8, rs1: u8, offset: i32) -> &mut Self {
        self.push(Instr::Load { op: LoadOp::Lhu, rd, rs1, offset })
    }
    pub fn sw(&mut self, rs1: u8, rs2: u8, offset: i32) -> &mut Self {
        self.push(Instr::Store { op: StoreOp::Sw, rs1, rs2, offset })
    }
    pub fn sb(&mut self, rs1: u8, rs2: u8, offset: i32) -> &mut Self {
        self.push(Instr::Store { op: StoreOp::Sb, rs1, rs2, offset })
    }
    pub fn sh(&mut self, rs1: u8, rs2: u8, offset: i32) -> &mut Self {
        self.push(Instr::Store { op: StoreOp::Sh, rs1, rs2, offset })
    }
    pub fn jalr(&mut self, rd: u8, rs1: u8, offset: i32) -> &mut Self {
        self.push(Instr::Jalr { rd, rs1, offset })
    }
    pub fn ecall(&mut self) -> &mut Self {
        self.push(Instr::Ecall)
    }
    pub fn ebreak(&mut self) -> &mut Self {
        self.push(Instr::Ebreak)
    }
    /// Custom CFU instruction (paper Fig. 3): funct7 selects the CFU,
    /// funct3 the operation.
    pub fn cfu(&mut self, funct7: u8, funct3: u8, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.push(Instr::Custom { funct7, funct3, rd, rs1, rs2 })
    }

    // -- label-targeted control flow ----------------------------------------

    pub fn branch(&mut self, op: BranchOp, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.items.push(Item::Branch { op, rs1, rs2, target: target.to_string() });
        self
    }
    pub fn beq(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.branch(BranchOp::Beq, rs1, rs2, target)
    }
    pub fn bne(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.branch(BranchOp::Bne, rs1, rs2, target)
    }
    pub fn blt(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.branch(BranchOp::Blt, rs1, rs2, target)
    }
    pub fn bge(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.branch(BranchOp::Bge, rs1, rs2, target)
    }
    pub fn bltu(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.branch(BranchOp::Bltu, rs1, rs2, target)
    }
    pub fn bgeu(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.branch(BranchOp::Bgeu, rs1, rs2, target)
    }
    pub fn jal(&mut self, rd: u8, target: &str) -> &mut Self {
        self.items.push(Item::Jal { rd, target: target.to_string() });
        self
    }

    // -- pseudo-instructions --------------------------------------------------

    pub fn nop(&mut self) -> &mut Self {
        self.addi(ZERO, ZERO, 0)
    }
    pub fn mv(&mut self, rd: u8, rs: u8) -> &mut Self {
        self.addi(rd, rs, 0)
    }
    pub fn j(&mut self, target: &str) -> &mut Self {
        self.jal(ZERO, target)
    }
    pub fn call(&mut self, target: &str) -> &mut Self {
        self.jal(RA, target)
    }
    pub fn ret(&mut self) -> &mut Self {
        self.jalr(ZERO, RA, 0)
    }

    /// Load a 32-bit immediate (expands to addi, lui, or lui+addi).
    pub fn li(&mut self, rd: u8, value: i32) -> &mut Self {
        if (-2048..=2047).contains(&value) {
            return self.addi(rd, ZERO, value);
        }
        // split into hi20/lo12 with the standard rounding trick: the addi
        // immediate is sign-extended, so bias the upper part by bit 11.
        let lo = (value << 20) >> 20; // sign-extended low 12 bits
        let hi = value.wrapping_sub(lo) as u32; // multiple of 0x1000
        self.lui(rd, hi as i32);
        if lo != 0 {
            self.addi(rd, rd, lo);
        }
        self
    }

    /// Load the absolute address of a label (always 2 words: lui+addi).
    pub fn la(&mut self, rd: u8, target: &str) -> &mut Self {
        self.items.push(Item::LaHi { rd, target: target.to_string() });
        self.items.push(Item::LaLo { rd, target: target.to_string() });
        self
    }

    // -- data -----------------------------------------------------------------

    pub fn word(&mut self, w: u32) -> &mut Self {
        self.items.push(Item::Word(w));
        self
    }

    pub fn words(&mut self, ws: &[u32]) -> &mut Self {
        for &w in ws {
            self.word(w);
        }
        self
    }

    pub fn words_i32(&mut self, ws: &[i32]) -> &mut Self {
        for &w in ws {
            self.word(w as u32);
        }
        self
    }

    /// Reserve `n` zero words.
    pub fn zeros(&mut self, n: usize) -> &mut Self {
        for _ in 0..n {
            self.word(0);
        }
        self
    }

    // -- assembly ---------------------------------------------------------------

    fn resolve(&self, target: &str) -> Result<u32> {
        self.lookup(target).ok_or_else(|| anyhow!("undefined label {target:?}"))
    }

    /// Resolve labels and produce the memory image (one u32 per word).
    pub fn assemble(&self) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(self.items.len());
        for (idx, item) in self.items.iter().enumerate() {
            let pc = self.base + (idx as u32) * 4;
            let word = match item {
                Item::Fixed(i) => encode(*i),
                Item::Word(w) => *w,
                Item::Branch { op, rs1, rs2, target } => {
                    let dest = self.resolve(target)?;
                    let offset = dest.wrapping_sub(pc) as i32;
                    if !(-4096..=4094).contains(&offset) {
                        bail!("branch to {target:?} out of range ({offset})");
                    }
                    encode(Instr::Branch { op: *op, rs1: *rs1, rs2: *rs2, offset })
                }
                Item::Jal { rd, target } => {
                    let dest = self.resolve(target)?;
                    let offset = dest.wrapping_sub(pc) as i32;
                    encode(Instr::Jal { rd: *rd, offset })
                }
                Item::LaHi { rd, target } => {
                    let addr = self.resolve(target)? as i32;
                    let lo = (addr << 20) >> 20;
                    let hi = addr.wrapping_sub(lo);
                    encode(Instr::Lui { rd: *rd, imm: hi })
                }
                Item::LaLo { rd, target } => {
                    let addr = self.resolve(target)? as i32;
                    let lo = (addr << 20) >> 20;
                    encode(Instr::OpImm { op: AluOp::Add, rd: *rd, rs1: *rd, imm: lo })
                }
            };
            out.push(word);
        }
        Ok(out)
    }

    /// Assemble to a little-endian byte image.
    pub fn assemble_bytes(&self) -> Result<Vec<u8>> {
        Ok(self.assemble()?.iter().flat_map(|w| w.to_le_bytes()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::decode::decode;
    use super::super::reg::*;
    use super::*;

    #[test]
    fn forward_and_backward_branches() {
        let mut a = Asm::new(0);
        a.li(T0, 3);
        a.label("loop");
        a.addi(T0, T0, -1);
        a.bne(T0, ZERO, "loop");
        a.j("end");
        a.nop();
        a.label("end");
        a.ecall();
        let img = a.assemble().unwrap();
        // bne at word 2 targets word 1: offset -4
        match decode(img[2]).unwrap() {
            Instr::Branch { offset, .. } => assert_eq!(offset, -4),
            other => panic!("{other:?}"),
        }
        // j at word 3 targets word 5: offset +8
        match decode(img[3]).unwrap() {
            Instr::Jal { offset, .. } => assert_eq!(offset, 8),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn li_expansions() {
        let mut a = Asm::new(0);
        a.li(A0, 5); // 1 word
        a.li(A1, 0x12345678); // 2 words
        a.li(A2, -1); // 1 word
        a.li(A3, 0x7ffff800); // lui only? lo12 = 0x800 sign-extends to -2048
        let img = a.assemble().unwrap();
        assert!(img.len() >= 5);
        // verify by simulating the li semantics
        let check = |words: &[u32], expect: i32| {
            let mut v: i32 = 0;
            for &w in words {
                match decode(w).unwrap() {
                    Instr::Lui { imm, .. } => v = imm,
                    Instr::OpImm { imm, .. } => v = v.wrapping_add(imm),
                    other => panic!("{other:?}"),
                }
            }
            assert_eq!(v, expect);
        };
        check(&img[0..1], 5);
        check(&img[1..3], 0x12345678);
        check(&img[3..4], -1);
        check(&img[4..6], 0x7ffff800);
    }

    #[test]
    fn la_resolves_address() {
        let mut a = Asm::new(0x1000);
        a.la(A0, "data");
        a.ecall();
        a.label("data");
        a.words(&[0xdead_beef]);
        let img = a.assemble().unwrap();
        // data is at 0x1000 + 3*4 = 0x100c
        let mut v: i32 = 0;
        for &w in &img[0..2] {
            match decode(w).unwrap() {
                Instr::Lui { imm, .. } => v = imm,
                Instr::OpImm { imm, .. } => v = v.wrapping_add(imm),
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(v as u32, 0x100c);
        assert_eq!(img[3], 0xdead_beef);
    }

    #[test]
    fn undefined_label_errors() {
        let mut a = Asm::new(0);
        a.j("nowhere");
        assert!(a.assemble().is_err());
    }

    #[test]
    #[should_panic(expected = "duplicate label")]
    fn duplicate_label_panics() {
        let mut a = Asm::new(0);
        a.label("x");
        a.label("x");
    }
}
