//! RV32I instruction encoders (bit-exact with the RISC-V unprivileged spec).

use super::{AluOp, BranchOp, Instr, LoadOp, StoreOp};

const OP_LUI: u32 = 0b0110111;
const OP_AUIPC: u32 = 0b0010111;
const OP_JAL: u32 = 0b1101111;
const OP_JALR: u32 = 0b1100111;
const OP_BRANCH: u32 = 0b1100011;
const OP_LOAD: u32 = 0b0000011;
const OP_STORE: u32 = 0b0100011;
const OP_IMM: u32 = 0b0010011;
const OP_OP: u32 = 0b0110011;
const OP_MISC_MEM: u32 = 0b0001111;
const OP_SYSTEM: u32 = 0b1110011;

pub fn enc_r(funct7: u8, rs2: u8, rs1: u8, funct3: u8, rd: u8, opcode: u32) -> u32 {
    ((funct7 as u32) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | ((funct3 as u32) << 12)
        | ((rd as u32) << 7)
        | opcode
}

pub fn enc_i(imm: i32, rs1: u8, funct3: u8, rd: u8, opcode: u32) -> u32 {
    debug_assert!((-2048..=2047).contains(&imm), "I-imm out of range: {imm}");
    (((imm as u32) & 0xfff) << 20)
        | ((rs1 as u32) << 15)
        | ((funct3 as u32) << 12)
        | ((rd as u32) << 7)
        | opcode
}

pub fn enc_s(imm: i32, rs2: u8, rs1: u8, funct3: u8, opcode: u32) -> u32 {
    debug_assert!((-2048..=2047).contains(&imm), "S-imm out of range: {imm}");
    let imm = imm as u32;
    (((imm >> 5) & 0x7f) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | ((funct3 as u32) << 12)
        | ((imm & 0x1f) << 7)
        | opcode
}

pub fn enc_b(offset: i32, rs2: u8, rs1: u8, funct3: u8, opcode: u32) -> u32 {
    debug_assert!(offset % 2 == 0, "B-offset must be even");
    debug_assert!((-4096..=4094).contains(&offset), "B-offset out of range: {offset}");
    let imm = offset as u32;
    (((imm >> 12) & 1) << 31)
        | (((imm >> 5) & 0x3f) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | ((funct3 as u32) << 12)
        | (((imm >> 1) & 0xf) << 8)
        | (((imm >> 11) & 1) << 7)
        | opcode
}

pub fn enc_u(imm: i32, rd: u8, opcode: u32) -> u32 {
    ((imm as u32) & 0xffff_f000) | ((rd as u32) << 7) | opcode
}

pub fn enc_j(offset: i32, rd: u8, opcode: u32) -> u32 {
    debug_assert!(offset % 2 == 0, "J-offset must be even");
    debug_assert!((-(1 << 20)..(1 << 20)).contains(&offset), "J-offset out of range: {offset}");
    let imm = offset as u32;
    (((imm >> 20) & 1) << 31)
        | (((imm >> 1) & 0x3ff) << 21)
        | (((imm >> 11) & 1) << 20)
        | (((imm >> 12) & 0xff) << 12)
        | ((rd as u32) << 7)
        | opcode
}

fn alu_funct(op: AluOp) -> (u8, u8) {
    // (funct3, funct7)
    match op {
        AluOp::Add => (0b000, 0x00),
        AluOp::Sub => (0b000, 0x20),
        AluOp::Sll => (0b001, 0x00),
        AluOp::Slt => (0b010, 0x00),
        AluOp::Sltu => (0b011, 0x00),
        AluOp::Xor => (0b100, 0x00),
        AluOp::Srl => (0b101, 0x00),
        AluOp::Sra => (0b101, 0x20),
        AluOp::Or => (0b110, 0x00),
        AluOp::And => (0b111, 0x00),
    }
}

fn branch_funct(op: BranchOp) -> u8 {
    match op {
        BranchOp::Beq => 0b000,
        BranchOp::Bne => 0b001,
        BranchOp::Blt => 0b100,
        BranchOp::Bge => 0b101,
        BranchOp::Bltu => 0b110,
        BranchOp::Bgeu => 0b111,
    }
}

fn load_funct(op: LoadOp) -> u8 {
    match op {
        LoadOp::Lb => 0b000,
        LoadOp::Lh => 0b001,
        LoadOp::Lw => 0b010,
        LoadOp::Lbu => 0b100,
        LoadOp::Lhu => 0b101,
    }
}

fn store_funct(op: StoreOp) -> u8 {
    match op {
        StoreOp::Sb => 0b000,
        StoreOp::Sh => 0b001,
        StoreOp::Sw => 0b010,
    }
}

/// Encode any `Instr` to its 32-bit machine word.
pub fn encode(i: Instr) -> u32 {
    match i {
        Instr::Lui { rd, imm } => enc_u(imm, rd, OP_LUI),
        Instr::Auipc { rd, imm } => enc_u(imm, rd, OP_AUIPC),
        Instr::Jal { rd, offset } => enc_j(offset, rd, OP_JAL),
        Instr::Jalr { rd, rs1, offset } => enc_i(offset, rs1, 0b000, rd, OP_JALR),
        Instr::Branch { op, rs1, rs2, offset } => {
            enc_b(offset, rs2, rs1, branch_funct(op), OP_BRANCH)
        }
        Instr::Load { op, rd, rs1, offset } => enc_i(offset, rs1, load_funct(op), rd, OP_LOAD),
        Instr::Store { op, rs1, rs2, offset } => {
            enc_s(offset, rs2, rs1, store_funct(op), OP_STORE)
        }
        Instr::OpImm { op, rd, rs1, imm } => {
            let (f3, f7) = alu_funct(op);
            match op {
                AluOp::Sll | AluOp::Srl | AluOp::Sra => {
                    debug_assert!((0..32).contains(&imm), "shamt out of range");
                    enc_r(f7, imm as u8, rs1, f3, rd, OP_IMM)
                }
                AluOp::Sub => panic!("subi does not exist; use addi with negated imm"),
                _ => enc_i(imm, rs1, f3, rd, OP_IMM),
            }
        }
        Instr::Op { op, rd, rs1, rs2 } => {
            let (f3, f7) = alu_funct(op);
            enc_r(f7, rs2, rs1, f3, rd, OP_OP)
        }
        Instr::Custom { funct7, funct3, rd, rs1, rs2 } => {
            enc_r(funct7, rs2, rs1, funct3, rd, OP_OP)
        }
        Instr::Fence => enc_i(0, 0, 0b000, 0, OP_MISC_MEM),
        Instr::Ecall => enc_i(0, 0, 0b000, 0, OP_SYSTEM),
        Instr::Ebreak => enc_i(1, 0, 0b000, 0, OP_SYSTEM),
    }
}

#[cfg(test)]
mod tests {
    use super::super::reg::*;
    use super::*;

    // Reference encodings cross-checked against the RISC-V spec / GNU as.
    #[test]
    fn known_encodings() {
        // addi x1, x0, 5  -> 0x00500093
        assert_eq!(
            encode(Instr::OpImm { op: AluOp::Add, rd: RA, rs1: ZERO, imm: 5 }),
            0x0050_0093
        );
        // add x3, x1, x2 -> 0x002081b3
        assert_eq!(
            encode(Instr::Op { op: AluOp::Add, rd: GP, rs1: RA, rs2: SP }),
            0x0020_81b3
        );
        // sub x3, x1, x2 -> 0x402081b3
        assert_eq!(
            encode(Instr::Op { op: AluOp::Sub, rd: GP, rs1: RA, rs2: SP }),
            0x4020_81b3
        );
        // lw x5, 8(x2) -> 0x00812283
        assert_eq!(
            encode(Instr::Load { op: LoadOp::Lw, rd: T0, rs1: SP, offset: 8 }),
            0x0081_2283
        );
        // sw x5, 12(x2) -> 0x00512623
        assert_eq!(
            encode(Instr::Store { op: StoreOp::Sw, rs1: SP, rs2: T0, offset: 12 }),
            0x0051_2623
        );
        // beq x1, x2, +8 -> 0x00208463
        assert_eq!(
            encode(Instr::Branch { op: BranchOp::Beq, rs1: RA, rs2: SP, offset: 8 }),
            0x0020_8463
        );
        // jal x1, +16 -> 0x010000ef
        assert_eq!(encode(Instr::Jal { rd: RA, offset: 16 }), 0x0100_00ef);
        // lui x7, 0x12345 -> 0x123453b7
        assert_eq!(encode(Instr::Lui { rd: T2, imm: 0x12345 << 12 }), 0x1234_53b7);
        // ecall -> 0x00000073
        assert_eq!(encode(Instr::Ecall), 0x0000_0073);
        // srai x6, x5, 3 -> 0x4032d313
        assert_eq!(
            encode(Instr::OpImm { op: AluOp::Sra, rd: T1, rs1: T0, imm: 3 }),
            0x4032_d313
        );
    }

    #[test]
    fn custom_cfu_encoding_matches_fig3() {
        // Fig. 3: funct7=0000001, opcode=0110011 (OP)
        let w = encode(Instr::Custom { funct7: 1, funct3: 0, rd: A0, rs1: A1, rs2: A2 });
        assert_eq!(w >> 25, 1, "funct7");
        assert_eq!(w & 0x7f, 0b0110011, "opcode");
        assert_eq!((w >> 12) & 7, 0, "funct3");
        assert_eq!((w >> 7) & 0x1f, A0 as u32);
        assert_eq!((w >> 15) & 0x1f, A1 as u32);
        assert_eq!((w >> 20) & 0x1f, A2 as u32);
    }

    #[test]
    fn negative_immediates() {
        // addi x1, x1, -1 -> 0xfff08093
        assert_eq!(
            encode(Instr::OpImm { op: AluOp::Add, rd: RA, rs1: RA, imm: -1 }),
            0xfff0_8093
        );
        // beq backwards
        let w = encode(Instr::Branch { op: BranchOp::Bne, rs1: T0, rs2: ZERO, offset: -8 });
        assert_eq!(w, 0xfe02_9ce3);
    }
}
