//! Deterministic mock [`Engine`] for coordinator tests: exercises
//! batching, linger/eager flush, backpressure and per-sample failure
//! isolation with no artifacts, no SoC simulation and no XLA
//! toolchain.
//!
//! The mock owns its "models" (any key warms successfully), predicts
//! `pred = x[0]`, and is scripted through the builder methods:
//! per-batch latencies ([`MockEngine::with_delays`]), per-sample
//! failures keyed on the first feature value
//! ([`MockEngine::fail_when_first_feature_is`]), dispatcher-death
//! injection ([`MockEngine::panic_when_first_feature_is`]) and a fixed
//! [`SimCost`] per answer ([`MockEngine::with_sim`]).  Executed batch
//! sizes are recorded in order through the handle returned by
//! [`MockEngine::batch_log`].

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::engine::{Engine, ModelSource, Sample, ServeError, SimCost};

/// Scripted, artifact-free serving engine.
#[derive(Default)]
pub struct MockEngine {
    /// Batch `i` sleeps `delays[i % len]` before answering (empty =
    /// answer immediately).
    delays: Vec<Duration>,
    /// Samples whose first feature equals this value fail alone.
    fail_on: Option<i32>,
    /// A batch containing this first-feature value panics the caller
    /// (the dispatcher thread) — for `Server::shutdown` tests.
    panic_on: Option<i32>,
    /// Fixed simulated cost attached to every successful answer.
    sim: Option<SimCost>,
    /// Executed batch sizes, in execution order.
    batches: Arc<Mutex<Vec<usize>>>,
}

impl MockEngine {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_delays(mut self, delays: Vec<Duration>) -> Self {
        self.delays = delays;
        self
    }

    pub fn fail_when_first_feature_is(mut self, v: i32) -> Self {
        self.fail_on = Some(v);
        self
    }

    pub fn panic_when_first_feature_is(mut self, v: i32) -> Self {
        self.panic_on = Some(v);
        self
    }

    pub fn with_sim(mut self, sim: SimCost) -> Self {
        self.sim = Some(sim);
        self
    }

    /// Shared handle to the executed-batch-size log; clone it before
    /// boxing the engine into the server.
    pub fn batch_log(&self) -> Arc<Mutex<Vec<usize>>> {
        Arc::clone(&self.batches)
    }
}

impl Engine for MockEngine {
    fn name(&self) -> &str {
        "mock"
    }

    fn warm(&mut self, _source: &ModelSource, _keys: &[String]) -> Result<()> {
        Ok(())
    }

    fn run_batch(&self, _key: &str, xs: &[Vec<i32>]) -> Vec<Result<Sample, ServeError>> {
        if let Some(v) = self.panic_on {
            if xs.iter().any(|x| x.first() == Some(&v)) {
                panic!("mock engine: scripted panic");
            }
        }
        let batch_idx = {
            let mut log = self.batches.lock().unwrap();
            log.push(xs.len());
            log.len() - 1
        };
        if !self.delays.is_empty() {
            let d = self.delays[batch_idx % self.delays.len()];
            if !d.is_zero() {
                std::thread::sleep(d);
            }
        }
        xs.iter()
            .map(|x| {
                let first = x.first().copied().unwrap_or(0);
                if self.fail_on == Some(first) {
                    Err(ServeError::Engine("mock engine: scripted failure".into()))
                } else {
                    Ok(Sample::new(first, self.sim))
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_predicts_first_feature_and_logs_batches() {
        let e = MockEngine::new();
        let log = e.batch_log();
        let out = e.run_batch("any", &[vec![4, 0], vec![9, 1]]);
        assert_eq!(out[0].as_ref().unwrap().pred, 4);
        assert_eq!(out[1].as_ref().unwrap().pred, 9);
        assert_eq!(*log.lock().unwrap(), vec![2]);
    }

    #[test]
    fn scripted_failure_hits_only_marked_samples() {
        let e = MockEngine::new().fail_when_first_feature_is(13);
        let out = e.run_batch("any", &[vec![1], vec![13], vec![2]]);
        assert!(out[0].is_ok() && out[2].is_ok());
        assert!(matches!(&out[1], Err(ServeError::Engine(_))));
    }

    #[test]
    fn sim_cost_attached_when_scripted() {
        let e = MockEngine::new().with_sim(SimCost { cycles: 42, energy_mj: 0.5 });
        let out = e.run_batch("any", &[vec![0]]);
        let sim = out[0].as_ref().unwrap().sim.unwrap();
        assert_eq!(sim.cycles, 42);
    }
}
