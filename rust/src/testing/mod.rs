//! Minimal property-testing harness (proptest is not in the offline
//! vendor set; DESIGN.md §3 documents the substitution).
//!
//! A property runs `cases` times against values drawn from generator
//! closures over a seeded [`Pcg32`].  On failure the harness reports the
//! case index and re-runnable seed, then panics with the property's own
//! assertion message.  No shrinking — generators here draw from small,
//! structured domains where the raw counterexample is already readable.
//!
//! [`mock`] additionally hosts the scripted [`MockEngine`] the
//! coordinator tests plug into the serving loop.

pub mod mock;

pub use crate::util::Pcg32;
pub use mock::MockEngine;

use crate::svm::model::{artifacts_root, Manifest, QuantModel};

/// Load the artifact manifest, or skip the calling test with a note
/// when the artifacts are not on disk (tier-1 runs on machines without
/// an XLA/JAX toolchain; artifact-backed tests degrade to no-ops there
/// instead of failing).
pub fn artifacts_or_skip(test: &str) -> Option<Manifest> {
    match Manifest::load(&artifacts_root()) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping {test}: artifacts not present (run `make artifacts` first)");
            None
        }
    }
}

/// Bind the artifact manifest, or return from the calling test with a
/// skip note when artifacts are absent (shared by the integration-test
/// crates).
#[macro_export]
macro_rules! manifest_or_return {
    ($test:literal) => {
        match $crate::testing::artifacts_or_skip($test) {
            Some(m) => m,
            None => return,
        }
    };
}

/// Drive the KSVM accelerator's raw op stream — the same sequence the
/// `program::accel` codegen emits — and return every classifier score,
/// reading `cur_sum + KSCALE·b` before `K_RES` folds the bias in and
/// advances the argmax.  Shared by `golden-check`, the cross-layer
/// integration tests, and the differential proptests: an independent
/// path to the same integers as `infer::scores`.
pub fn ksvm_emulate_scores(m: &QuantModel, x: &[i32]) -> anyhow::Result<Vec<i64>> {
    use crate::accel::kernel::KernelAccel;
    use crate::accel::Cfu;
    use crate::isa::ksvm_ops::{self, kcfg};
    use crate::kernel::{Kernel, KSCALE};
    use crate::svm::pack;

    let mut a = KernelAccel::new();
    a.execute(ksvm_ops::K_ENV, 0, 0)?;
    let (kind, gamma) = match m.kernel {
        Kernel::Rbf => (ksvm_ops::KIND_RBF, m.kparams.g2_q),
        _ => (ksvm_ops::KIND_POLY, m.kparams.gamma_q),
    };
    a.execute(ksvm_ops::K_CFG, kind, kcfg::KIND)?;
    a.execute(ksvm_ops::K_CFG, gamma as u32, kcfg::GAMMA)?;
    a.execute(ksvm_ops::K_CFG, m.kparams.coef0_q as u32, kcfg::COEF0)?;
    a.execute(ksvm_ops::K_CFG, m.kparams.degree, kcfg::DEGREE)?;
    let fw = pack::kernel_feature_words(x);
    let mut scores = Vec::with_capacity(m.weights.len());
    for k in 0..m.weights.len() {
        for s in 0..m.support.len() {
            let sw = pack::kernel_sv_words(m, s);
            for (&xw, &vw) in fw.iter().zip(&sw) {
                a.execute(ksvm_ops::K_ACC, xw, vw)?;
            }
            a.execute(ksvm_ops::K_EVAL, m.weights[k][s] as u32, 0)?;
        }
        scores.push(a.registers().1 + KSCALE * m.biases[k] as i64);
        a.execute(ksvm_ops::K_RES, m.biases[k] as u32, 0)?;
    }
    Ok(scores)
}

/// Run a property `cases` times with a deterministic base seed.
pub fn check<F: FnMut(&mut Pcg32)>(name: &str, seed: u64, cases: u32, mut prop: F) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Pcg32::seeded(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property {name:?} failed at case {case} (seed {case_seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Generators over the domains this repo cares about.
pub mod gen {
    use super::Pcg32;
    use crate::kernel::{Kernel, KernelParams};
    use crate::svm::model::{QuantModel, Strategy};

    /// A 4-bit unsigned feature vector.
    pub fn features(rng: &mut Pcg32, n: usize) -> Vec<i32> {
        (0..n).map(|_| rng.below(16) as i32).collect()
    }

    /// One pre-drawn feature vector per scenario arrival (outside the
    /// timed region of a replay); `n_features[cfg]` is each config's
    /// input width.  Shared by the scenario-driven benches.
    pub fn arrival_features(
        seed: u64,
        n_features: &[usize],
        s: &crate::farm::scenario::Scenario,
    ) -> Vec<Vec<i32>> {
        let mut rng = Pcg32::seeded(seed);
        s.arrivals.iter().map(|a| features(&mut rng, n_features[a.config])).collect()
    }

    /// A deterministic 2-class, 3-feature toy model (shared fixture of
    /// the farm/coordinator tests; `flip` mirrors the decision plane so
    /// two distinct configs can be served side by side).
    pub fn tiny_model(dataset: &str, flip: bool) -> QuantModel {
        let (a, b) = if flip { (-7, 7) } else { (7, -7) };
        QuantModel {
            dataset: dataset.into(),
            strategy: Strategy::Ovr,
            bits: 4,
            n_classes: 2,
            n_features: 3,
            weights: vec![vec![a, b, 1], vec![b, a, -1]],
            biases: vec![0, 1],
            pairs: vec![(0, 0), (1, 1)],
            scale: 1.0,
            kernel: Kernel::Linear,
            support: Vec::new(),
            kparams: KernelParams::default(),
        }
    }

    /// A deterministic 2-class, 3-feature kernel-machine fixture: two
    /// support vectors at opposite corners, nearest-support wins
    /// (serving-layer twin of `tiny_model` for kernel configs).
    pub fn tiny_kernel_model(dataset: &str, kernel: Kernel) -> QuantModel {
        QuantModel {
            dataset: dataset.into(),
            strategy: Strategy::Ovr,
            bits: 4,
            n_classes: 2,
            n_features: 3,
            // dual rows over the S=2 support set
            weights: vec![vec![7, -1], vec![-1, 7]],
            biases: vec![0, 0],
            pairs: vec![(0, 0), (1, 1)],
            scale: 1.0,
            kernel,
            support: vec![vec![0, 0, 0], vec![15, 15, 15]],
            kparams: match kernel {
                Kernel::Rbf => KernelParams { g2_q: 91, ..Default::default() },
                _ => KernelParams { gamma_q: 777, coef0_q: 256, degree: 2, ..Default::default() },
            },
        }
    }

    /// A random well-formed kernel machine over a random support set.
    pub fn kernel_model(rng: &mut Pcg32) -> QuantModel {
        let mut m = quant_model(rng);
        let kernel = if rng.below(2) == 0 { Kernel::Rbf } else { Kernel::Poly };
        let s = 1 + rng.below(8) as usize; // 1..=8 support vectors
        let qmax = (1i32 << (m.bits - 1)) - 1;
        let k = m.pairs.len();
        m.weights =
            (0..k).map(|_| (0..s).map(|_| rng.range_i32(-qmax, qmax)).collect()).collect();
        m.support = (0..s).map(|_| features(rng, m.n_features)).collect();
        m.kernel = kernel;
        // constants in the ranges quantize_kernel_constants produces
        m.kparams = match kernel {
            Kernel::Rbf => {
                KernelParams { g2_q: 1 + rng.below(4000) as i32, ..Default::default() }
            }
            _ => KernelParams {
                gamma_q: 1 + rng.below(8000) as i32,
                coef0_q: rng.range_i32(-1024, 1024),
                degree: 1 + rng.below(4),
                ..Default::default()
            },
        };
        m
    }

    /// A random well-formed quantized model.
    pub fn quant_model(rng: &mut Pcg32) -> QuantModel {
        let bits = *rng.choose(&[4u8, 8, 16]);
        let strategy = if rng.below(2) == 0 { Strategy::Ovr } else { Strategy::Ovo };
        let c = 2 + rng.below(4) as usize; // 2..=5 classes
        let f = 1 + rng.below(12) as usize; // 1..=12 features
        let qmax = (1i32 << (bits - 1)) - 1;
        let pairs: Vec<(usize, usize)> = match strategy {
            Strategy::Ovr => (0..c).map(|i| (i, i)).collect(),
            Strategy::Ovo => {
                let mut p = vec![];
                for i in 0..c {
                    for j in i + 1..c {
                        p.push((i, j));
                    }
                }
                p
            }
        };
        let k = pairs.len();
        QuantModel {
            dataset: "prop".into(),
            strategy,
            bits,
            n_classes: c,
            n_features: f,
            weights: (0..k)
                .map(|_| (0..f).map(|_| rng.range_i32(-qmax, qmax)).collect())
                .collect(),
            biases: (0..k).map(|_| rng.range_i32(-qmax, qmax)).collect(),
            pairs,
            scale: 1.0,
            kernel: Kernel::Linear,
            support: Vec::new(),
            kparams: KernelParams::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counts", 1, 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failures() {
        check("fails", 2, 10, |rng| assert!(rng.below(10) < 5));
    }

    #[test]
    fn generators_produce_valid_domains() {
        check("gen-domains", 3, 50, |rng| {
            let m = gen::quant_model(rng);
            let qmax = (1i32 << (m.bits - 1)) - 1;
            assert!(m.weights.iter().flatten().all(|w| w.abs() <= qmax));
            assert_eq!(m.weights.len(), m.pairs.len());
            let x = gen::features(rng, m.n_features);
            assert!(x.iter().all(|&v| (0..16).contains(&v)));
        });
    }

    #[test]
    fn kernel_generator_produces_valid_models() {
        check("gen-kernel-domains", 4, 50, |rng| {
            let m = gen::kernel_model(rng);
            assert!(m.is_kernel());
            m.validate().expect("generated kernel model must validate");
            assert_eq!(m.weights[0].len(), m.n_support());
        });
    }
}
