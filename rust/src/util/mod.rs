//! From-scratch utility substrates (nothing beyond `xla`/`anyhow` is
//! available in the offline vendor set): JSON, RNG, CLI, text tables.

pub mod cli;
pub mod json;
pub mod rng;
pub mod benchkit;
pub mod table;

pub use cli::Args;
pub use json::Json;
pub use rng::Pcg32;
pub use table::Table;
