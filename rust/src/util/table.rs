//! Plain-text table rendering for reports (Table I, ablations, metrics).

/// A simple column-aligned text table with a header row.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: impl IntoIterator<Item = S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: impl IntoIterator<Item = S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                // right-align numeric-looking cells, left-align text
                let numeric = cell.chars().next().map(|c| c.is_ascii_digit() || c == '-').unwrap_or(false);
                if numeric {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                } else {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `d` decimals, trimming to a compact form.
pub fn f(v: f64, d: usize) -> String {
    format!("{v:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "val"]);
        t.row(["alpha", "1.0"]);
        t.row(["b", "123.45"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].contains("alpha"));
        // numeric column right-aligned: both value cells end at same column
        assert_eq!(lines[2].len(), lines[2].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }
}
