//! Tiny command-line parser (clap is not in the offline vendor set).
//!
//! Grammar: `prog <subcommand> [positional...] [--key value] [--flag]`.
//! Unknown options are errors; `--help` is handled by the caller.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand if it
    /// does not start with `-`).
    pub fn parse_tokens<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                bail!("short options not supported: {tok}");
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse_tokens(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt_str(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    /// Comma-separated list option, e.g. `--datasets bs,iris`.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.opt_str(name) {
            Some(s) => s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_tokens(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["table1", "x", "y"]);
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.positional, vec!["x", "y"]);
    }

    #[test]
    fn options_and_flags() {
        let a = parse(&["sim", "--bits", "8", "--trace", "--out=res.json"]);
        assert_eq!(a.opt_str("bits"), Some("8"));
        assert!(a.flag("trace"));
        assert_eq!(a.opt_str("out"), Some("res.json"));
        assert_eq!(a.usize_or("bits", 4).unwrap(), 8);
        assert_eq!(a.usize_or("missing", 4).unwrap(), 4);
    }

    #[test]
    fn flag_before_value_option() {
        // --trace is a flag because the next token starts with --
        let a = parse(&["run", "--trace", "--bits", "16"]);
        assert!(a.flag("trace"));
        assert_eq!(a.opt_str("bits"), Some("16"));
    }

    #[test]
    fn list_option() {
        let a = parse(&["t", "--datasets", "bs, iris"]);
        assert_eq!(a.list_or("datasets", &[]), vec!["bs", "iris"]);
        assert_eq!(a.list_or("other", &["all"]), vec!["all"]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--bits", "4"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.opt_str("bits"), Some("4"));
    }

    #[test]
    fn short_options_rejected() {
        assert!(Args::parse_tokens(vec!["-x".to_string()]).is_err());
    }
}
