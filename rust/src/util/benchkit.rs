//! Tiny benchmark harness (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets use [`Bench`] for warmup, repeated timing and
//! simple robust statistics.  Times are wall-clock; results print in a
//! fixed tabular format so bench_output.txt diffs cleanly.

use std::time::{Duration, Instant};

/// Results of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub iters: u32,
}

/// Run `f` repeatedly: `warmup` unmeasured runs, then `iters` measured.
pub fn measure<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Sample {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let sum: Duration = times.iter().sum();
    Sample {
        mean: sum / iters,
        median: times[times.len() / 2],
        min: times[0],
        iters,
    }
}

/// Formatting helper: a benchmark section with aligned case rows.
pub struct Bench {
    section: String,
}

impl Bench {
    pub fn new(section: &str) -> Self {
        println!("\n### {section}");
        println!("{:<44} {:>12} {:>12} {:>12} {:>8}", "case", "mean", "median", "min", "iters");
        Bench { section: section.to_string() }
    }

    pub fn case<F: FnMut()>(&self, name: &str, warmup: u32, iters: u32, f: F) -> Sample {
        let s = measure(warmup, iters, f);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>8}",
            name,
            fmt_dur(s.mean),
            fmt_dur(s.median),
            fmt_dur(s.min),
            s.iters
        );
        s
    }

    /// Report a derived throughput-style metric on its own row.
    pub fn metric(&self, name: &str, value: f64, unit: &str) {
        println!("{:<44} {value:>12.2} {unit}", format!("  -> {name}"));
    }

    pub fn section(&self) -> &str {
        &self.section
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0u32;
        let s = measure(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.mean * 3);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }
}
