//! Tiny benchmark harness (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets use [`Bench`] for warmup, repeated timing and
//! simple robust statistics.  Times are wall-clock; results print in a
//! fixed tabular format so bench_output.txt diffs cleanly.
//!
//! The serving-side helpers ([`manifest_or_skip`], [`load_testsets`],
//! [`drive_clients`], [`latency_summary`]) are the harness shared by
//! the serving benches, `examples/serve_inference.rs` and the CLI's
//! `serve` subcommand — one implementation of the multi-threaded
//! client loop instead of a copy per driver.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::metrics::ConfigMetrics;
use crate::coordinator::Client;
use crate::svm::infer;
use crate::svm::model::{Manifest, QuantModel, TestSet};

/// Results of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub iters: u32,
}

/// Run `f` repeatedly: `warmup` unmeasured runs, then `iters` measured.
pub fn measure<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Sample {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let sum: Duration = times.iter().sum();
    Sample {
        mean: sum / iters,
        median: times[times.len() / 2],
        min: times[0],
        iters,
    }
}

/// Formatting helper: a benchmark section with aligned case rows.
pub struct Bench {
    section: String,
}

impl Bench {
    pub fn new(section: &str) -> Self {
        println!("\n### {section}");
        println!("{:<44} {:>12} {:>12} {:>12} {:>8}", "case", "mean", "median", "min", "iters");
        Bench { section: section.to_string() }
    }

    pub fn case<F: FnMut()>(&self, name: &str, warmup: u32, iters: u32, f: F) -> Sample {
        let s = measure(warmup, iters, f);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>8}",
            name,
            fmt_dur(s.mean),
            fmt_dur(s.median),
            fmt_dur(s.min),
            s.iters
        );
        s
    }

    /// Report a derived throughput-style metric on its own row.
    pub fn metric(&self, name: &str, value: f64, unit: &str) {
        println!("{:<44} {value:>12.2} {unit}", format!("  -> {name}"));
    }

    pub fn section(&self) -> &str {
        &self.section
    }
}

/// Load the artifact manifest, or print a skip note and return None
/// (benches degrade gracefully on machines without `make artifacts`;
/// same policy as the test suites).
pub fn manifest_or_skip(context: &str) -> Option<Manifest> {
    crate::testing::artifacts_or_skip(context)
}

/// Resolve `(key, TestSet)` pairs for a set of config keys.
pub fn load_testsets(manifest: &Manifest, keys: &[String]) -> Result<Vec<(String, TestSet)>> {
    keys.iter()
        .map(|k| {
            let entry = manifest.config(k)?;
            Ok((k.clone(), manifest.test_set(&entry.dataset)?))
        })
        .collect()
}

/// Outcome of one multi-threaded client drive.
#[derive(Debug, Clone, Copy)]
pub struct DriveResult {
    /// Requests answered (workers × per-worker share).
    pub served: u64,
    /// Answers equal to the test-set label.
    pub label_correct: u64,
    /// Answers that diverged from `svm::infer::predict` (only counted
    /// when reference models are supplied; must be 0).
    pub native_mismatch: u64,
    pub wall: Duration,
}

/// Drive a serving client from `workers` threads over real test
/// vectors, round-robining configs.  Backend-agnostic: whatever
/// engine the server was built with, answers come back through the
/// same `Client::infer` path (typed `ServeError`s convert into the
/// worker's `anyhow` result).  When `check_models` is given, every
/// answer is additionally compared against the native integer spec
/// (differential serving check).
pub fn drive_clients(
    client: &Client,
    testsets: &[(String, TestSet)],
    n_requests: usize,
    workers: usize,
    check_models: Option<&HashMap<String, QuantModel>>,
) -> Result<DriveResult> {
    assert!(workers > 0 && !testsets.is_empty());
    let correct = AtomicU64::new(0);
    let mismatch = AtomicU64::new(0);
    let done = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for w in 0..workers {
            let client = client.clone();
            let (correct, mismatch, done) = (&correct, &mismatch, &done);
            handles.push(scope.spawn(move || -> Result<()> {
                for i in 0..n_requests / workers {
                    let (key, test) = &testsets[(w + i) % testsets.len()];
                    let idx = (w * 7919 + i * 31) % test.len();
                    let resp = client.infer(key, &test.x_q[idx])?;
                    if resp.pred == test.y[idx] {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                    if let Some(models) = check_models {
                        if resp.pred != infer::predict(&models[key], &test.x_q[idx]) {
                            mismatch.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    done.fetch_add(1, Ordering::Relaxed);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("client worker panicked")?;
        }
        Ok(())
    })?;
    Ok(DriveResult {
        served: done.load(Ordering::Relaxed),
        label_correct: correct.load(Ordering::Relaxed),
        native_mismatch: mismatch.load(Ordering::Relaxed),
        wall: t0.elapsed(),
    })
}

/// Worst-case latency quantiles + mean batch size across configs.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_batch: f64,
}

pub fn latency_summary(metrics: &HashMap<String, ConfigMetrics>) -> LatencySummary {
    let mut s = LatencySummary::default();
    let mut n = 0.0;
    for m in metrics.values() {
        if let Some(h) = m.latency.as_ref() {
            s.p50_us = s.p50_us.max(h.quantile_us(0.50));
            s.p99_us = s.p99_us.max(h.quantile_us(0.99));
        }
        s.mean_batch += m.mean_batch();
        n += 1.0;
    }
    if n > 0.0 {
        s.mean_batch /= n;
    }
    s
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0u32;
        let s = measure(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.mean * 3);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }

    #[test]
    fn latency_summary_takes_worst_quantiles() {
        let mut a = ConfigMetrics::new();
        a.batches = 2;
        a.batched_samples = 8; // mean 4
        a.latency.as_mut().unwrap().record(Duration::from_micros(10));
        let mut b = ConfigMetrics::new();
        b.batches = 1;
        b.batched_samples = 2; // mean 2
        b.latency.as_mut().unwrap().record(Duration::from_micros(900));
        let mut m = HashMap::new();
        m.insert("a".to_string(), a);
        m.insert("b".to_string(), b);
        let s = latency_summary(&m);
        assert!(s.p99_us >= 900, "{s:?}");
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
    }
}
