//! Tiny benchmark harness (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets use [`Bench`] for warmup, repeated timing and
//! simple robust statistics.  Times are wall-clock; results print in a
//! fixed tabular format so bench_output.txt diffs cleanly.  Every case
//! and derived metric is also recorded, and [`write_report`] emits them
//! as `BENCH_<name>.json` at the repo root so the perf trajectory is
//! machine-readable across PRs.  Setting `FLEXSVM_BENCH_QUICK=1` cuts
//! warmup/iteration counts for CI smoke runs ([`quick`]).
//!
//! The serving-side helpers ([`manifest_or_skip`], [`load_testsets`],
//! [`drive_clients`], [`latency_summary`]) are the harness shared by
//! the serving benches, `examples/serve_inference.rs` and the CLI's
//! `serve` subcommand — one implementation of the multi-threaded
//! client loop instead of a copy per driver.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::metrics::ConfigMetrics;
use crate::coordinator::Client;
use crate::svm::infer;
use crate::svm::model::{Manifest, QuantModel, TestSet};

/// Results of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub iters: u32,
}

/// Run `f` repeatedly: `warmup` unmeasured runs, then `iters` measured.
pub fn measure<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Sample {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let sum: Duration = times.iter().sum();
    Sample {
        mean: sum / iters,
        median: times[times.len() / 2],
        min: times[0],
        iters,
    }
}

/// One recorded timing case (for the JSON report).
#[derive(Debug, Clone)]
pub struct CaseRow {
    pub name: String,
    pub mean_ns: u64,
    pub median_ns: u64,
    pub min_ns: u64,
    pub iters: u32,
}

/// One recorded derived metric (for the JSON report).
#[derive(Debug, Clone)]
pub struct MetricRow {
    pub name: String,
    pub value: f64,
    pub unit: String,
}

/// Quick mode for CI perf-smoke jobs: `FLEXSVM_BENCH_QUICK=1` reduces
/// warmup/iteration counts (results still get recorded and reported).
pub fn quick() -> bool {
    std::env::var_os("FLEXSVM_BENCH_QUICK").is_some()
}

fn scaled(warmup: u32, iters: u32) -> (u32, u32) {
    if quick() {
        (warmup.min(1), iters.clamp(1, 3))
    } else {
        (warmup, iters)
    }
}

/// A benchmark section: prints aligned case rows and records every
/// case/metric for [`write_report`].
pub struct Bench {
    section: String,
    cases: Vec<CaseRow>,
    metrics: Vec<MetricRow>,
}

impl Bench {
    pub fn new(section: &str) -> Self {
        println!("\n### {section}");
        println!("{:<44} {:>12} {:>12} {:>12} {:>8}", "case", "mean", "median", "min", "iters");
        Bench { section: section.to_string(), cases: Vec::new(), metrics: Vec::new() }
    }

    pub fn case<F: FnMut()>(&mut self, name: &str, warmup: u32, iters: u32, f: F) -> Sample {
        let (warmup, iters) = scaled(warmup, iters);
        let s = measure(warmup, iters, f);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>8}",
            name,
            fmt_dur(s.mean),
            fmt_dur(s.median),
            fmt_dur(s.min),
            s.iters
        );
        self.cases.push(CaseRow {
            name: name.to_string(),
            mean_ns: s.mean.as_nanos() as u64,
            median_ns: s.median.as_nanos() as u64,
            min_ns: s.min.as_nanos() as u64,
            iters: s.iters,
        });
        s
    }

    /// Report a derived throughput-style metric on its own row.
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        println!("{:<44} {value:>12.2} {unit}", format!("  -> {name}"));
        self.metrics.push(MetricRow { name: name.to_string(), value, unit: unit.to_string() });
    }

    pub fn section(&self) -> &str {
        &self.section
    }

    pub fn cases(&self) -> &[CaseRow] {
        &self.cases
    }

    pub fn metrics(&self) -> &[MetricRow] {
        &self.metrics
    }
}

/// Serialise bench sections to `BENCH_<name>.json` at the repo root
/// (next to the workspace `Cargo.toml`), so the perf trajectory is
/// tracked across PRs; returns the written path.
pub fn write_report(name: &str, sections: &[&Bench]) -> Result<std::path::PathBuf> {
    let path = repo_root().join(format!("BENCH_{name}.json"));
    std::fs::write(&path, report_json(name, sections).to_string())?;
    Ok(path)
}

/// The report document (separated from the file write for testing).
fn report_json(name: &str, sections: &[&Bench]) -> crate::util::json::Json {
    use crate::util::json::{obj, Json};
    let sections_json: Vec<Json> = sections
        .iter()
        .map(|b| {
            obj([
                ("section", b.section.as_str().into()),
                (
                    "cases",
                    Json::Arr(
                        b.cases
                            .iter()
                            .map(|c| {
                                obj([
                                    ("name", c.name.as_str().into()),
                                    ("mean_ns", c.mean_ns.into()),
                                    ("median_ns", c.median_ns.into()),
                                    ("min_ns", c.min_ns.into()),
                                    ("iters", Json::Num(c.iters as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "metrics",
                    Json::Arr(
                        b.metrics
                            .iter()
                            .map(|m| {
                                obj([
                                    ("name", m.name.as_str().into()),
                                    ("value", Json::Num(m.value)),
                                    ("unit", m.unit.as_str().into()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    obj([
        ("bench", name.into()),
        ("quick", Json::Bool(quick())),
        ("sections", Json::Arr(sections_json)),
    ])
}

/// The workspace root: the `rust/` crate directory's parent.
fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."))
}

/// Load the artifact manifest, or print a skip note and return None
/// (benches degrade gracefully on machines without `make artifacts`;
/// same policy as the test suites).
pub fn manifest_or_skip(context: &str) -> Option<Manifest> {
    crate::testing::artifacts_or_skip(context)
}

/// Resolve `(key, TestSet)` pairs for a set of config keys.
pub fn load_testsets(manifest: &Manifest, keys: &[String]) -> Result<Vec<(String, TestSet)>> {
    keys.iter()
        .map(|k| {
            let entry = manifest.config(k)?;
            Ok((k.clone(), manifest.test_set(&entry.dataset)?))
        })
        .collect()
}

/// Outcome of one multi-threaded client drive.
#[derive(Debug, Clone)]
pub struct DriveResult {
    /// Requests answered (workers × per-worker share).
    pub served: u64,
    /// Answers equal to the test-set label.
    pub label_correct: u64,
    /// Answers that diverged from `svm::infer::predict` (only counted
    /// when reference models are supplied; must be 0).
    pub native_mismatch: u64,
    pub wall: Duration,
    /// Per-config `(label-correct, answered)` counts — the live
    /// accuracy feed for `report::serving`'s per-kernel rollup.
    pub per_config: HashMap<String, (u64, u64)>,
}

/// Drive a serving client from `workers` threads over real test
/// vectors, round-robining configs.  Backend-agnostic: whatever
/// engine the server was built with, answers come back through the
/// same `Client::infer` path (typed `ServeError`s convert into the
/// worker's `anyhow` result).  When `check_models` is given, every
/// answer is additionally compared against the native integer spec
/// (differential serving check).
pub fn drive_clients(
    client: &Client,
    testsets: &[(String, TestSet)],
    n_requests: usize,
    workers: usize,
    check_models: Option<&HashMap<String, QuantModel>>,
) -> Result<DriveResult> {
    assert!(workers > 0 && !testsets.is_empty());
    let correct = AtomicU64::new(0);
    let mismatch = AtomicU64::new(0);
    let done = AtomicU64::new(0);
    // one (correct, answered) slot per testset, indexed like the
    // round-robin so workers touch disjoint atomics, no lock
    let per_cfg: Vec<(AtomicU64, AtomicU64)> =
        testsets.iter().map(|_| (AtomicU64::new(0), AtomicU64::new(0))).collect();
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for w in 0..workers {
            let client = client.clone();
            let (correct, mismatch, done) = (&correct, &mismatch, &done);
            let per_cfg = &per_cfg;
            handles.push(scope.spawn(move || -> Result<()> {
                for i in 0..n_requests / workers {
                    let slot = (w + i) % testsets.len();
                    let (key, test) = &testsets[slot];
                    let idx = (w * 7919 + i * 31) % test.len();
                    let resp = client.infer(key, &test.x_q[idx])?;
                    per_cfg[slot].1.fetch_add(1, Ordering::Relaxed);
                    if resp.pred == test.y[idx] {
                        correct.fetch_add(1, Ordering::Relaxed);
                        per_cfg[slot].0.fetch_add(1, Ordering::Relaxed);
                    }
                    if let Some(models) = check_models {
                        if resp.pred != infer::predict(&models[key], &test.x_q[idx]) {
                            mismatch.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    done.fetch_add(1, Ordering::Relaxed);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("client worker panicked")?;
        }
        Ok(())
    })?;
    let per_config = testsets
        .iter()
        .zip(&per_cfg)
        .map(|((key, _), (c, n))| {
            (key.clone(), (c.load(Ordering::Relaxed), n.load(Ordering::Relaxed)))
        })
        .collect();
    Ok(DriveResult {
        served: done.load(Ordering::Relaxed),
        label_correct: correct.load(Ordering::Relaxed),
        native_mismatch: mismatch.load(Ordering::Relaxed),
        wall: t0.elapsed(),
        per_config,
    })
}

/// Worst-case latency quantiles + mean batch size across configs.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_batch: f64,
}

pub fn latency_summary(metrics: &HashMap<String, ConfigMetrics>) -> LatencySummary {
    let mut s = LatencySummary::default();
    let mut n = 0.0;
    for m in metrics.values() {
        if let Some(h) = m.latency.as_ref() {
            s.p50_us = s.p50_us.max(h.quantile_us(0.50));
            s.p99_us = s.p99_us.max(h.quantile_us(0.99));
        }
        s.mean_batch += m.mean_batch();
        n += 1.0;
    }
    if n > 0.0 {
        s.mean_batch /= n;
    }
    s
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0u32;
        let s = measure(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.mean * 3);
    }

    #[test]
    fn bench_records_cases_and_metrics_for_the_report() {
        let mut b = Bench::new("unit section");
        b.case("c1", 0, 3, || {});
        b.metric("m1", 12.5, "Mcyc/s");
        assert_eq!(b.cases().len(), 1);
        assert_eq!(b.cases()[0].iters, 3);
        assert_eq!(b.metrics()[0].unit, "Mcyc/s");
        let doc = report_json("unit", &[&b]);
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "unit");
        let sections = doc.get("sections").unwrap().as_arr().unwrap();
        assert_eq!(sections.len(), 1);
        let cases = sections[0].get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases[0].get("name").unwrap().as_str().unwrap(), "c1");
        assert!(cases[0].get("median_ns").unwrap().as_f64().unwrap() >= 0.0);
        let metrics = sections[0].get("metrics").unwrap().as_arr().unwrap();
        assert_eq!(metrics[0].get("value").unwrap().as_f64().unwrap(), 12.5);
        // round-trips through the parser
        assert!(crate::util::json::Json::parse(&doc.to_string()).is_ok());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }

    #[test]
    fn latency_summary_takes_worst_quantiles() {
        let mut a = ConfigMetrics::new();
        a.batches = 2;
        a.batched_samples = 8; // mean 4
        a.latency.as_mut().unwrap().record(Duration::from_micros(10));
        let mut b = ConfigMetrics::new();
        b.batches = 1;
        b.batched_samples = 2; // mean 2
        b.latency.as_mut().unwrap().record(Duration::from_micros(900));
        let mut m = HashMap::new();
        m.insert("a".to_string(), a);
        m.insert("b".to_string(), b);
        let s = latency_summary(&m);
        assert!(s.p99_us >= 900, "{s:?}");
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
    }
}
