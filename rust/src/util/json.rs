//! Minimal JSON parser/writer (serde is not in the offline vendor set).
//!
//! Supports the full JSON grammar we exchange with the Python build path:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Numbers are stored as f64 — all our integers (quantized weights,
//! 4-bit inputs, int32 scores) are ≤ 2^31, far inside f64's exact range.
//!
//! Since the wire front (`net/`) parses untrusted request bodies with
//! this module, parsing is guarded: [`Json::parse_limited`] enforces an
//! explicit byte budget + nesting-depth bound, and even the plain
//! [`Json::parse`] bounds depth so no input can overflow the stack.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse guards for wire duty: a byte budget (a malicious request body
/// must not balloon memory) and a nesting-depth bound (deep `[[[[...`
/// must not overflow the parser's stack).
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    /// Maximum input size in bytes (checked before parsing).
    pub max_bytes: usize,
    /// Maximum object/array nesting depth.
    pub max_depth: usize,
}

impl Default for Limits {
    /// Wire defaults: 1 MiB bodies, 64 nesting levels.
    fn default() -> Self {
        Limits { max_bytes: 1 << 20, max_depth: 64 }
    }
}

/// Depth bound applied by the plain [`Json::parse`] (generous — trusted
/// local files — but still finite so no input can overflow the stack).
const DEFAULT_MAX_DEPTH: usize = 512;

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        Self::parse_with_depth(text, DEFAULT_MAX_DEPTH)
    }

    /// Parse untrusted input under explicit [`Limits`] (the wire front
    /// runs every request body through this).
    pub fn parse_limited(text: &str, limits: &Limits) -> Result<Json> {
        if text.len() > limits.max_bytes {
            bail!("input of {} bytes exceeds the {}-byte budget", text.len(), limits.max_bytes);
        }
        Self::parse_with_depth(text, limits.max_depth)
    }

    fn parse_with_depth(text: &str, max_depth: usize) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0, max_depth };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n.abs() > 2f64.powi(53) {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_i32(&self) -> Result<i32> {
        Ok(i32::try_from(self.as_i64()?)?)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(usize::try_from(self.as_i64()?)?)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// `[1,2,3]` -> `Vec<i32>`.
    pub fn as_vec_i32(&self) -> Result<Vec<i32>> {
        self.as_arr()?.iter().map(|v| v.as_i32()).collect()
    }

    /// `[[..],[..]]` -> row-major `Vec<Vec<i32>>`.
    pub fn as_mat_i32(&self) -> Result<Vec<Vec<i32>>> {
        self.as_arr()?.iter().map(|r| r.as_vec_i32()).collect()
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // NaN/inf have no JSON spelling; `write!("{n}")`
                    // would emit invalid output the parser rejects
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<i32> for Json {
    fn from(n: i32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Builder for JSON objects: `obj([("a", 1.into()), ...])`.
pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            c @ (b'{' | b'[') => {
                if self.depth >= self.max_depth {
                    bail!("nesting exceeds {} levels at offset {}", self.max_depth, self.i);
                }
                self.depth += 1;
                let v = if c == b'{' { self.object() } else { self.array() };
                self.depth -= 1;
                v
            }
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs are not produced by our writers;
                            // map lone surrogates to U+FFFD
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text.parse().with_context(|| format!("bad number {text:?}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for t in ["null", "true", "false", "1", "-42", "3.5"] {
            let v = Json::parse(t).unwrap();
            assert_eq!(v.to_string(), t);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
        assert_eq!(*v.get("c").unwrap(), Json::Null);
    }

    #[test]
    fn parse_matrix() {
        let v = Json::parse("[[1,-2],[3,4]]").unwrap();
        assert_eq!(v.as_mat_i32().unwrap(), vec![vec![1, -2], vec![3, 4]]);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
        // writer escapes back
        let w = Json::Str("a\nb\"".into()).to_string();
        assert_eq!(w, r#""a\nb\"""#);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse(r#""αβγ — ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "αβγ — ok");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn obj_builder() {
        let v = obj([("x", 1.into()), ("y", "z".into())]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn integer_precision() {
        let v = Json::parse("2147483647").unwrap();
        assert_eq!(v.as_i32().unwrap(), i32::MAX);
        assert!(Json::parse("3.5").unwrap().as_i64().is_err());
    }

    // ---- wire-duty hardening (net/ serves untrusted bodies) ----------

    /// A string drawn from the hostile-ish pool: quotes, backslashes,
    /// every escaped control char, multibyte UTF-8 and an astral char.
    fn gen_string(rng: &mut crate::util::Pcg32) -> String {
        const POOL: &[char] = &[
            'a', 'Z', '0', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{8}', '\u{c}', '\u{1}',
            '\u{1f}', '\u{7f}', 'α', 'ß', '—', '\u{1F600}',
        ];
        (0..rng.below(12)).map(|_| *rng.choose(POOL)).collect()
    }

    /// Numbers across the exact-integer envelope and float fractions.
    fn gen_num(rng: &mut crate::util::Pcg32) -> Json {
        let max_exact = (1i64 << 53) - 1;
        match rng.below(4) {
            0 => Json::Num(max_exact as f64 * if rng.below(2) == 0 { 1.0 } else { -1.0 }),
            1 => Json::Num(rng.range_i32(i32::MIN + 1, i32::MAX) as f64),
            2 => Json::Num(rng.f64() * 1e6 - 5e5),
            _ => Json::Num(rng.below(100) as f64 / 8.0),
        }
    }

    fn gen_value(rng: &mut crate::util::Pcg32, depth: usize) -> Json {
        let arms = if depth >= 5 { 4 } else { 6 };
        match rng.below(arms) {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => gen_num(rng),
            3 => Json::Str(gen_string(rng)),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen_value(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4)).map(|_| (gen_string(rng), gen_value(rng, depth + 1))).collect(),
            ),
        }
    }

    #[test]
    fn roundtrip_property_over_random_documents() {
        crate::testing::check("json-roundtrip", 0x9e1, 300, |rng| {
            let v = gen_value(rng, 0);
            let text = v.to_string();
            let back = Json::parse(&text).unwrap_or_else(|e| panic!("{e:#} parsing {text:?}"));
            assert_eq!(back, v, "parse(write(v)) != v for {text:?}");
            // serialization is a fixed point of the round trip
            assert_eq!(back.to_string(), text);
        });
    }

    #[test]
    fn parse_limited_enforces_byte_budget() {
        let limits = Limits { max_bytes: 16, max_depth: 8 };
        assert!(Json::parse_limited("[1,2,3]", &limits).is_ok());
        let err = Json::parse_limited("[1,2,3,4,5,6,7,8,9]", &limits).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
    }

    #[test]
    fn parse_limited_bounds_nesting_depth() {
        let deep = "[[[[[0]]]]]"; // 5 levels
        assert!(Json::parse_limited(deep, &Limits { max_bytes: 1024, max_depth: 4 }).is_err());
        assert!(Json::parse_limited(deep, &Limits { max_bytes: 1024, max_depth: 5 }).is_ok());
    }

    #[test]
    fn deep_nesting_attack_is_an_error_not_a_stack_overflow() {
        let attack = format!("{}0{}", "[".repeat(100_000), "]".repeat(100_000));
        let err = Json::parse(&attack).unwrap_err();
        assert!(err.to_string().contains("nesting"), "{err}");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // `write!("{n}")` would emit `NaN`/`inf`, which no JSON parser
        // (including this one) accepts back
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        let doc = Json::Arr(vec![Json::Num(f64::NEG_INFINITY), Json::Num(1.0)]);
        assert_eq!(Json::parse(&doc.to_string()).unwrap(), Json::Arr(vec![Json::Null, Json::Num(1.0)]));
    }

    #[test]
    fn huge_integers_round_trip_exactly() {
        for n in [(1i64 << 53) - 1, -(1i64 << 53) + 1] {
            let v = Json::parse(&n.to_string()).unwrap();
            assert_eq!(v.as_i64().unwrap(), n);
            assert_eq!(v.to_string(), n.to_string());
        }
    }
}
