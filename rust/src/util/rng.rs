//! PCG32 pseudo-random generator (the `rand` crate is not vendored).
//!
//! Deterministic, seedable, statistically solid for test-vector and
//! workload generation (O'Neill's PCG-XSH-RR 64/32).

#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire reduction).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo <= hi);
        let span = (hi as i64 - lo as i64 + 1) as u32;
        lo.wrapping_add(self.below(span) as i32)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg32::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut rng = Pcg32::seeded(4);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = rng.range_i32(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg32::seeded(5);
        let mean: f64 = (0..10_000).map(|_| rng.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg32::seeded(6);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
