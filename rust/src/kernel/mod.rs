//! Fixed-point kernel evaluation — the Rust twin of the kernel spec in
//! `python/compile/quantize.py` (ISSUE 8).
//!
//! A kernel machine is a linear machine over the integer feature map
//! `phi`: per support vector `s`, `phi[s] = K(x_q, sv_q[s])` evaluated
//! entirely in integers, then the dual coefficients ride the existing
//! linear accumulate with the bias as an (input = `KSCALE`, weight =
//! `b_q`) pair.  Every constant and every shift here has a textual twin
//! in the Python spec; `exp2_lut_pins_formula` is the tripwire for
//! editing one side only.
//!
//! Shared by `svm::infer` (native scores), `accel::kernel` (the KSVM
//! CFU), and — through those — the SERV programs and the wire front.

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, Error};

/// Fractional bits of the kernel feature map phi.
pub const KFRAC: u32 = 8;
/// Phi full scale; also the kernel bias "input".
pub const KSCALE: i64 = 1 << KFRAC;
/// Fractional bits of the quantized gamma constants.
pub const GSHIFT: u32 = 12;
/// log2(EXP2_LUT entries).
pub const LUTB: u32 = 5;
/// Poly feature-map clamp: keeps every product inside i32.
pub const KCLAMP: i64 = 1 << 10;

/// `EXP2_LUT[i] = round(KSCALE * 2^(-i/32))` — one 2^-x period in
/// KFRAC fixed point.  Hardcoded (not computed) so the Python twin is
/// textually identical.
pub const EXP2_LUT: [i64; 32] = [
    256, 251, 245, 240, 235, 230, 225, 220, 215, 211, 206, 202, 197, 193, 189, 185, 181, 177,
    173, 170, 166, 162, 159, 156, 152, 149, 146, 143, 140, 137, 134, 131,
];

/// Which kernel a quantized model evaluates (per-config selection).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Kernel {
    #[default]
    Linear,
    Rbf,
    Poly,
}

impl FromStr for Kernel {
    type Err = Error;

    fn from_str(s: &str) -> Result<Kernel, Error> {
        match s {
            "linear" => Ok(Kernel::Linear),
            "rbf" => Ok(Kernel::Rbf),
            "poly" => Ok(Kernel::Poly),
            _ => bail!("unknown kernel {s:?} (want linear|rbf|poly)"),
        }
    }
}

impl fmt::Display for Kernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Kernel::Linear => "linear",
            Kernel::Rbf => "rbf",
            Kernel::Poly => "poly",
        })
    }
}

/// Quantized kernel hyper-parameters (all zero for linear models).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KernelParams {
    /// rbf: `round(gamma * log2(e) * 2^GSHIFT / 225)`.
    pub g2_q: i32,
    /// poly: `round(gamma * 2^(KFRAC+GSHIFT) / 225)`.
    pub gamma_q: i32,
    /// poly: `round(coef0 * KSCALE)`.
    pub coef0_q: i32,
    /// poly: exponent, >= 1.
    pub degree: u32,
}

/// RBF feature value from a squared distance: LUT'd `2^-x` with the
/// exponent in GSHIFT fixed point.  `d2 * g2_q` must fit i32 (the
/// quantizer validates `g2_q * F * 225 < 2^31`).
pub fn rbf_phi_of_d2(d2: i64, g2_q: i32) -> i64 {
    let z = g2_q as i64 * d2;
    let zi = z >> GSHIFT;
    let zf = (z >> (GSHIFT - LUTB)) & ((1 << LUTB) - 1);
    if zi >= 31 {
        0
    } else {
        EXP2_LUT[zf as usize] >> zi.min(62)
    }
}

/// Poly feature value from a dot product: clamped affine map raised to
/// `degree` by a KFRAC fixed-point multiply ladder.  The ±KCLAMP clamp
/// is part of the feature-map definition (training sees it).
pub fn poly_phi_of_dot(d: i64, p: &KernelParams) -> i64 {
    let t = ((p.gamma_q as i64 * d) >> GSHIFT) + p.coef0_q as i64;
    let t = t.clamp(-KCLAMP, KCLAMP);
    let mut acc = t;
    for _ in 1..p.degree {
        acc = ((acc * t) >> KFRAC).clamp(-KCLAMP, KCLAMP);
    }
    acc
}

/// Squared distance between a 4-bit input and a 4-bit support vector.
pub fn sq_dist(x_q: &[i32], sv: &[i32]) -> i64 {
    x_q.iter().zip(sv).map(|(&x, &s)| ((x - s) as i64).pow(2)).sum()
}

/// Dot product between a 4-bit input and a 4-bit support vector.
pub fn dot(x_q: &[i32], sv: &[i32]) -> i64 {
    x_q.iter().zip(sv).map(|(&x, &s)| x as i64 * s as i64).sum()
}

/// The integer feature value of one support vector.
pub fn phi(kernel: Kernel, params: &KernelParams, x_q: &[i32], sv: &[i32]) -> i64 {
    debug_assert_eq!(x_q.len(), sv.len(), "feature arity");
    match kernel {
        Kernel::Linear => panic!("phi is for kernel machines, not linear"),
        Kernel::Rbf => rbf_phi_of_d2(sq_dist(x_q, sv), params.g2_q),
        Kernel::Poly => poly_phi_of_dot(dot(x_q, sv), params),
    }
}

/// The full feature map `[phi(x, sv_s)]_s` of one sample.
pub fn feature_map(
    kernel: Kernel,
    params: &KernelParams,
    support: &[Vec<i32>],
    x_q: &[i32],
) -> Vec<i64> {
    support.iter().map(|sv| phi(kernel, params, x_q, sv)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp2_lut_pins_formula() {
        for (i, &v) in EXP2_LUT.iter().enumerate() {
            let want = (KSCALE as f64 * 2f64.powf(-(i as f64) / 32.0)).round() as i64;
            assert_eq!(v, want, "EXP2_LUT[{i}]");
        }
    }

    #[test]
    fn kernel_round_trips_strings() {
        for k in [Kernel::Linear, Kernel::Rbf, Kernel::Poly] {
            assert_eq!(k.to_string().parse::<Kernel>().unwrap(), k);
        }
        assert!("sigmoid".parse::<Kernel>().is_err());
    }

    #[test]
    fn rbf_full_scale_at_zero_distance() {
        assert_eq!(rbf_phi_of_d2(0, 1000), KSCALE);
    }

    #[test]
    fn rbf_monotone_in_distance() {
        let g2_q = 137;
        let mut prev = i64::MAX;
        for d2 in 0..4000 {
            let v = rbf_phi_of_d2(d2, g2_q);
            assert!(v <= prev, "phi must not grow with distance (d2={d2})");
            assert!((0..=KSCALE).contains(&v));
            prev = v;
        }
    }

    #[test]
    fn rbf_underflows_to_zero() {
        // zi >= 31 -> exact zero, and huge exponents don't shift-overflow
        assert_eq!(rbf_phi_of_d2(1 << 24, 1 << 12), 0);
    }

    #[test]
    fn poly_degree_one_is_clamped_affine() {
        let p = KernelParams { gamma_q: 801, coef0_q: -300, degree: 1, ..Default::default() };
        let d = 187;
        assert_eq!(poly_phi_of_dot(d, &p), ((801 * d) >> GSHIFT) - 300);
        // saturation
        let hot = KernelParams { gamma_q: 4999, coef0_q: 1024, degree: 1, ..Default::default() };
        assert_eq!(poly_phi_of_dot(35 * 225, &hot), KCLAMP);
    }

    #[test]
    fn poly_ladder_clamps_every_step() {
        let p = KernelParams { gamma_q: 4999, coef0_q: -1024, degree: 4, ..Default::default() };
        let v = poly_phi_of_dot(35 * 225, &p);
        assert!((-KCLAMP..=KCLAMP).contains(&v));
    }

    #[test]
    fn distance_and_dot_agree_with_naive() {
        let x = [0, 7, 15, 3];
        let sv = [15, 7, 0, 4];
        assert_eq!(sq_dist(&x, &sv), 225 + 0 + 225 + 1);
        assert_eq!(dot(&x, &sv), 0 + 49 + 0 + 12);
    }
}
