//! Flat little-endian memory with bounds/alignment checking.
//!
//! Latency is charged by the core from `TimingConfig` (the paper's
//! 46/47-cycle transactions + 64-cycle overhead); this module is purely
//! the data side, plus access counters for the MEM attribution report.

use anyhow::{bail, Result};

use crate::serv::Bus;

/// Default memory map used by the program generators.
pub const TEXT_BASE: u32 = 0x0000_0000;
pub const STACK_TOP: u32 = 0x000f_fff0;
pub const DEFAULT_SIZE: usize = 0x10_0000; // 1 MiB

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemCounters {
    pub ifetches: u64,
    pub reads: u64,
    pub writes: u64,
}

pub struct Memory {
    bytes: Vec<u8>,
    pub counters: MemCounters,
}

impl Memory {
    pub fn new(size: usize) -> Self {
        Memory { bytes: vec![0; size], counters: MemCounters::default() }
    }

    pub fn with_image(image: &[u8], size: usize) -> Self {
        let mut m = Memory::new(size.max(image.len()));
        m.bytes[..image.len()].copy_from_slice(image);
        m
    }

    pub fn size(&self) -> usize {
        self.bytes.len()
    }

    /// Raw (latency-free, uncounted) access for test harnesses and the
    /// program loader.
    pub fn poke32(&mut self, addr: u32, value: u32) {
        let a = addr as usize;
        self.bytes[a..a + 4].copy_from_slice(&value.to_le_bytes());
    }

    pub fn peek32(&self, addr: u32) -> u32 {
        let a = addr as usize;
        u32::from_le_bytes(self.bytes[a..a + 4].try_into().unwrap())
    }

    pub fn poke_words(&mut self, addr: u32, words: &[u32]) {
        for (i, &w) in words.iter().enumerate() {
            self.poke32(addr + (i as u32) * 4, w);
        }
    }

    fn check(&self, addr: u32, size: u8) -> Result<usize> {
        let a = addr as usize;
        if a + size as usize > self.bytes.len() {
            bail!("memory access out of range: {addr:#010x} (+{size})");
        }
        if addr % size as u32 != 0 {
            bail!("misaligned {size}-byte access at {addr:#010x}");
        }
        Ok(a)
    }
}

impl Bus for Memory {
    fn fetch(&mut self, addr: u32) -> Result<u32> {
        let a = self.check(addr, 4)?;
        self.counters.ifetches += 1;
        Ok(u32::from_le_bytes(self.bytes[a..a + 4].try_into().unwrap()))
    }

    fn load(&mut self, addr: u32, size: u8) -> Result<u32> {
        let a = self.check(addr, size)?;
        self.counters.reads += 1;
        let mut v = 0u32;
        for i in 0..size as usize {
            v |= (self.bytes[a + i] as u32) << (8 * i);
        }
        Ok(v)
    }

    fn store(&mut self, addr: u32, value: u32, size: u8) -> Result<()> {
        let a = self.check(addr, size)?;
        self.counters.writes += 1;
        for i in 0..size as usize {
            self.bytes[a + i] = (value >> (8 * i)) as u8;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn little_endian_roundtrip() {
        let mut m = Memory::new(64);
        m.store(8, 0x1234_5678, 4).unwrap();
        assert_eq!(m.load(8, 4).unwrap(), 0x1234_5678);
        assert_eq!(m.load(8, 1).unwrap(), 0x78);
        assert_eq!(m.load(9, 1).unwrap(), 0x56);
        assert_eq!(m.load(8, 2).unwrap(), 0x5678);
    }

    #[test]
    fn bounds_and_alignment() {
        let mut m = Memory::new(16);
        assert!(m.load(16, 4).is_err());
        assert!(m.load(13, 4).is_err()); // misaligned
        assert!(m.store(15, 0, 2).is_err());
        assert!(m.load(14, 2).is_ok());
    }

    #[test]
    fn counters_track_accesses() {
        let mut m = Memory::new(64);
        m.fetch(0).unwrap();
        m.load(4, 4).unwrap();
        m.store(8, 1, 4).unwrap();
        m.store(12, 2, 4).unwrap();
        assert_eq!(m.counters, MemCounters { ifetches: 1, reads: 1, writes: 2 });
    }
}
