//! Block-compiled SERV execution engine (EXPERIMENTS.md §Perf, L3
//! iteration 6).
//!
//! The step interpreter ([`crate::serv::ServCore::step`]) pays a fetch
//! bus transaction, a decode-cache probe, a full `StepInfo` and several
//! `CycleStats` field updates for *every* retired instruction.  None of
//! that work depends on run-time values: the instruction stream of a
//! loaded image is fixed, and on the bit-serial SERV almost every cycle
//! cost is static — the fetch transaction, the 32-cycle serial ALU
//! passes, the load/store memory latencies and the shift-in cost are
//! all known per instruction at translation time.
//!
//! So the image is translated **once** into a [`DecodedProgram`]: a
//! dense `Vec` of pre-decoded micro-ops indexed by `pc/4`, partitioned
//! into basic blocks (maximal straight-line runs cut after control
//! flow and before undecodable words), with the static cycle cost of
//! every block suffix precomputed.  Execution then runs block-at-a-time
//! in a tight loop: one `CycleStats` update per block, no fetch calls,
//! no `StepInfo`.  Only genuinely dynamic costs are accounted at run
//! time: taken-branch PC updates, register-count shifts (`sll/srl/sra`
//! with the amount in rs2), and the CFU handshake + accelerator compute.
//! The accounting is **bit-identical** to the step interpreter —
//! `rust/tests/proptests.rs` pins exit value, registers and the full
//! `CycleStats` on random programs and random quantized models.
//!
//! The `DecodedProgram` is immutable and lives in an `Arc`, so the farm
//! shares one translation across all shards and `Soc::rearm` keeps it
//! across runs.  Per-SoC mutable state lives in [`BlockCtx`]:
//!
//!  * **Self-modifying code.**  A store into a slot covered by a
//!    translated block ends the current block (its unexecuted suffix is
//!    discounted), marks the slot dirty, and drops the overlay cache.
//!    Blocks intersecting dirty slots are re-translated from memory
//!    into per-SoC owned blocks, so patched instructions execute with
//!    their new semantics and costs — exactly like the interpreter's
//!    raw-word-keyed decode cache, at block granularity.
//!  * **Untranslated regions.**  Entry at an undecodable slot or past
//!    the image falls back to the step interpreter one instruction at a
//!    time (its decode cache re-validates against the raw word, so code
//!    written into data regions at run time stays correct).
//!
//! Host-side `mem.poke*` writes bypass the simulated store path, so
//! they must only touch data (feature buffers), never executed text —
//! the same contract the generators already follow.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::accel::CfuBank;
use crate::isa::{self, AluOp, BranchOp, Instr, LoadOp, StoreOp};
use crate::obs::{log as evlog, BlockProfiler};
use crate::serv::{CycleStats, Exit, ServCore, TimingConfig};

use super::mem::Memory;
use super::RunResult;

/// Pre-decoded micro-op: the run-time-relevant fields of an
/// instruction with everything PC-relative folded in at translation
/// time (AUIPC values, JAL/branch targets, link addresses).
#[derive(Debug, Clone, Copy)]
enum UOp {
    Lui { rd: u8, imm: u32 },
    /// AUIPC with `pc + imm` precomputed.
    Auipc { rd: u8, value: u32 },
    Jal { rd: u8, link: u32, target: u32 },
    Jalr { rd: u8, rs1: u8, link: u32, offset: u32 },
    Branch { op: BranchOp, rs1: u8, rs2: u8, target: u32 },
    Load { op: LoadOp, rd: u8, rs1: u8, offset: u32 },
    Store { size: u8, rs1: u8, rs2: u8, offset: u32 },
    AluImm { op: AluOp, rd: u8, rs1: u8, imm: u32 },
    AluReg { op: AluOp, rd: u8, rs1: u8, rs2: u8 },
    Cfu { funct7: u8, funct3: u8, rd: u8, rs1: u8, rs2: u8 },
    Fence,
    Ecall,
    Ebreak,
    /// Word that does not decode (data, or garbage): never part of a
    /// block; entering here falls back to the step interpreter.
    Invalid,
}

fn lower(instr: Instr, pc: u32) -> UOp {
    match instr {
        Instr::Lui { rd, imm } => UOp::Lui { rd, imm: imm as u32 },
        Instr::Auipc { rd, imm } => UOp::Auipc { rd, value: pc.wrapping_add(imm as u32) },
        Instr::Jal { rd, offset } => {
            UOp::Jal { rd, link: pc.wrapping_add(4), target: pc.wrapping_add(offset as u32) }
        }
        Instr::Jalr { rd, rs1, offset } => {
            UOp::Jalr { rd, rs1, link: pc.wrapping_add(4), offset: offset as u32 }
        }
        Instr::Branch { op, rs1, rs2, offset } => {
            UOp::Branch { op, rs1, rs2, target: pc.wrapping_add(offset as u32) }
        }
        Instr::Load { op, rd, rs1, offset } => UOp::Load { op, rd, rs1, offset: offset as u32 },
        Instr::Store { op, rs1, rs2, offset } => {
            let size = match op {
                StoreOp::Sb => 1,
                StoreOp::Sh => 2,
                StoreOp::Sw => 4,
            };
            UOp::Store { size, rs1, rs2, offset: offset as u32 }
        }
        Instr::OpImm { op, rd, rs1, imm } => UOp::AluImm { op, rd, rs1, imm: imm as u32 },
        Instr::Op { op, rd, rs1, rs2 } => UOp::AluReg { op, rd, rs1, rs2 },
        Instr::Custom { funct7, funct3, rd, rs1, rs2 } => {
            UOp::Cfu { funct7, funct3, rd, rs1, rs2 }
        }
        Instr::Fence => UOp::Fence,
        Instr::Ecall => UOp::Ecall,
        Instr::Ebreak => UOp::Ebreak,
    }
}

/// Control flow ends a basic block.
fn is_terminator(u: UOp) -> bool {
    matches!(
        u,
        UOp::Jal { .. } | UOp::Jalr { .. } | UOp::Branch { .. } | UOp::Ecall | UOp::Ebreak
    )
}

/// Timing-independent static cost of a block suffix, aggregated at
/// translation time.  [`charge`](StaticCost::charge) turns it into the
/// same `CycleStats` the step interpreter would have accumulated
/// (dynamic costs — taken branches, register-count shifts, CFU — are
/// added separately at run time).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct StaticCost {
    /// Retired instructions (also the number of fetch transactions).
    n: u32,
    /// Serial execute cycles excluding the per-load shift-in cost.
    exec: u32,
    loads: u32,
    stores: u32,
}

impl StaticCost {
    fn of(u: UOp) -> StaticCost {
        let mut c = StaticCost { n: 1, exec: 32, loads: 0, stores: 0 };
        match u {
            UOp::Load { .. } => c.loads = 1,
            UOp::Store { .. } => c.stores = 1,
            UOp::AluImm { op: AluOp::Sll | AluOp::Srl | AluOp::Sra, imm, .. } => {
                // immediate shift amount is known at translation time
                c.exec += imm & 0x1f;
            }
            // CFU cost is entirely dynamic (handshake + compute)
            UOp::Cfu { .. } => c.exec = 0,
            _ => {}
        }
        c
    }

    fn add(&mut self, o: StaticCost) {
        self.n += o.n;
        self.exec += o.exec;
        self.loads += o.loads;
        self.stores += o.stores;
    }

    fn minus(self, o: StaticCost) -> StaticCost {
        StaticCost {
            n: self.n - o.n,
            exec: self.exec - o.exec,
            loads: self.loads - o.loads,
            stores: self.stores - o.stores,
        }
    }

    fn charge(self, t: &TimingConfig, stats: &mut CycleStats) {
        let (n, loads, stores) = (self.n as u64, self.loads as u64, self.stores as u64);
        stats.fetch += n * t.fetch_cost();
        stats.exec += self.exec as u64 + loads * t.load_shift_in;
        stats.data_mem += loads * t.load_cost() + stores * t.store_cost();
        stats.loads += loads;
        stats.stores += stores;
        stats.instret += n;
    }
}

/// An image translated once: per-slot (`pc/4`) micro-ops, basic-block
/// partition, and precomputed static cycle cost for every block suffix.
/// Immutable — share it with `Arc` across SoCs/shards and across
/// `Soc::rearm` calls.
pub struct DecodedProgram {
    image: Vec<u8>,
    uops: Vec<UOp>,
    /// Static cost from each slot to the end of its basic block
    /// (inclusive); zero for `Invalid` slots.
    suffix: Vec<StaticCost>,
    /// Inclusive last slot of the basic block containing each slot.
    block_end: Vec<u32>,
}

impl DecodedProgram {
    /// Decode and block-partition a program image.  Words that do not
    /// decode (data sections, padding) become `Invalid` boundary
    /// markers; they are never part of a block.
    pub fn translate(image: &[u8]) -> DecodedProgram {
        let n = image.len() / 4;
        let mut uops = Vec::with_capacity(n);
        for s in 0..n {
            let word = u32::from_le_bytes(image[s * 4..s * 4 + 4].try_into().unwrap());
            let pc = (s as u32) * 4;
            uops.push(match isa::decode(word) {
                Ok(i) => lower(i, pc),
                Err(_) => UOp::Invalid,
            });
        }
        let mut suffix = vec![StaticCost::default(); n];
        let mut block_end = vec![0u32; n];
        for s in (0..n).rev() {
            block_end[s] = s as u32;
            let u = uops[s];
            if matches!(u, UOp::Invalid) {
                continue; // zero suffix, own (degenerate) block
            }
            let mut c = StaticCost::of(u);
            if !is_terminator(u) && s + 1 < n && !matches!(uops[s + 1], UOp::Invalid) {
                c.add(suffix[s + 1]);
                block_end[s] = block_end[s + 1];
            }
            suffix[s] = c;
        }
        DecodedProgram { image: image.to_vec(), uops, suffix, block_end }
    }

    /// The original image bytes (memory initialisation).
    pub fn image(&self) -> &[u8] {
        &self.image
    }

    /// Number of translated word slots.
    pub fn n_slots(&self) -> usize {
        self.uops.len()
    }

    /// Number of basic blocks (excluding `Invalid` boundary slots).
    pub fn n_blocks(&self) -> usize {
        let mut n = 0;
        let mut s = 0;
        while s < self.uops.len() {
            if !matches!(self.uops[s], UOp::Invalid) {
                n += 1;
            }
            s = self.block_end[s] as usize + 1;
        }
        n
    }

    /// The translation-time static cost of the block suffix starting at
    /// `slot`, rendered as the `CycleStats` the block engine charges
    /// for executing it end to end.  Dynamic terms (taken branches,
    /// register-count shifts, CFU handshakes) are *not* included —
    /// they are exactly what [`crate::soc::cost`]'s analytic models add
    /// back in closed form.  Zero for `Invalid` / out-of-range slots.
    pub fn static_suffix_cost(&self, slot: usize, t: &TimingConfig) -> CycleStats {
        let mut stats = CycleStats::default();
        if slot < self.suffix.len() {
            self.suffix[slot].charge(t, &mut stats);
        }
        stats
    }
}

/// A block re-translated from *memory* after self-modifying code
/// diverged it from the baked image (per-SoC, not shared).
struct OwnedBlock {
    uops: Vec<UOp>,
    suffix: Vec<StaticCost>,
}

fn translate_owned(mem: &Memory, start: usize, limit: usize) -> OwnedBlock {
    let mut uops = Vec::new();
    for s in start..limit {
        let word = mem.peek32((s as u32) * 4);
        let Ok(instr) = isa::decode(word) else { break };
        let u = lower(instr, (s as u32) * 4);
        uops.push(u);
        if is_terminator(u) {
            break;
        }
    }
    let mut suffix = vec![StaticCost::default(); uops.len()];
    for k in (0..uops.len()).rev() {
        let mut c = StaticCost::of(uops[k]);
        if k + 1 < uops.len() {
            c.add(suffix[k + 1]);
        }
        suffix[k] = c;
    }
    OwnedBlock { uops, suffix }
}

/// Per-SoC mutable execution state for the block engine: which slots
/// are covered by a translation (so stores there must invalidate),
/// which slots have diverged from the baked image, and the re-translated
/// overlay blocks for diverged regions.
pub(crate) struct BlockCtx {
    covered: Vec<u64>,
    dirty: HashSet<u32>,
    overlay: HashMap<u32, OwnedBlock>,
}

fn bit(v: &[u64], s: usize) -> bool {
    s / 64 < v.len() && (v[s / 64] >> (s % 64)) & 1 == 1
}

fn set_bit(v: &mut [u64], s: usize) {
    if s / 64 < v.len() {
        v[s / 64] |= 1 << (s % 64);
    }
}

impl BlockCtx {
    pub(crate) fn new(prog: &DecodedProgram) -> BlockCtx {
        let n = prog.n_slots();
        let mut covered = vec![0u64; n.div_ceil(64)];
        for (s, u) in prog.uops.iter().enumerate() {
            if !matches!(u, UOp::Invalid) {
                set_bit(&mut covered, s);
            }
        }
        BlockCtx { covered, dirty: HashSet::new(), overlay: HashMap::new() }
    }
}

/// How a block finished.
enum BlockExit {
    /// Control transfer (or fall-through) to this PC.
    Jump(u32),
    /// Program exit; PC after the exiting instruction.
    Done(Exit, u32),
    /// A store hit a translated slot: block ended early (unexecuted
    /// suffix discounted), caller must invalidate and resume.
    Smc { next_pc: u32, slot: u32 },
}

#[inline]
fn r(regs: &[u32; 32], i: u8) -> u32 {
    regs[(i & 31) as usize]
}

#[inline]
fn w(regs: &mut [u32; 32], rd: u8, value: u32) {
    if rd != 0 {
        regs[(rd & 31) as usize] = value;
    }
}

#[inline]
fn alu_value(op: AluOp, a: u32, b: u32) -> u32 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::And => a & b,
        AluOp::Or => a | b,
        AluOp::Xor => a ^ b,
        AluOp::Slt => ((a as i32) < (b as i32)) as u32,
        AluOp::Sltu => (a < b) as u32,
        AluOp::Sll => a << (b & 0x1f),
        AluOp::Srl => a >> (b & 0x1f),
        AluOp::Sra => ((a as i32) >> (b & 0x1f)) as u32,
    }
}

/// Execute one basic block entered at `base_slot`.  Charges the static
/// suffix cost (minus any unexecuted remainder on an SMC abort) plus
/// the dynamic costs in a single `stats` update.
#[allow(clippy::too_many_arguments)]
fn exec_block(
    base_slot: usize,
    uops: &[UOp],
    suffix: &[StaticCost],
    covered: &[u64],
    regs: &mut [u32; 32],
    mem: &mut Memory,
    cfus: &mut CfuBank,
    t: &TimingConfig,
    stats: &mut CycleStats,
) -> Result<BlockExit> {
    let mut charged = suffix[0];
    let mut dyn_exec = 0u64;
    let mut cfu_cyc = 0u64;
    let mut cfu_n = 0u64;
    let mut ended = None;
    for (k, uop) in uops.iter().enumerate() {
        let pc = ((base_slot + k) as u32) << 2;
        match *uop {
            UOp::Lui { rd, imm } => w(regs, rd, imm),
            UOp::Auipc { rd, value } => w(regs, rd, value),
            UOp::AluImm { op, rd, rs1, imm } => {
                let v = alu_value(op, r(regs, rs1), imm);
                w(regs, rd, v);
            }
            UOp::AluReg { op, rd, rs1, rs2 } => {
                let b = r(regs, rs2);
                if matches!(op, AluOp::Sll | AluOp::Srl | AluOp::Sra) {
                    // register-count shift: circulation cycles are dynamic
                    dyn_exec += (b & 0x1f) as u64;
                }
                let v = alu_value(op, r(regs, rs1), b);
                w(regs, rd, v);
            }
            UOp::Load { op, rd, rs1, offset } => {
                let addr = r(regs, rs1).wrapping_add(offset);
                let (size, signed) = match op {
                    LoadOp::Lb => (1, true),
                    LoadOp::Lbu => (1, false),
                    LoadOp::Lh => (2, true),
                    LoadOp::Lhu => (2, false),
                    LoadOp::Lw => (4, false),
                };
                let raw = crate::serv::Bus::load(mem, addr, size)?;
                let value = if signed {
                    match size {
                        1 => raw as u8 as i8 as i32 as u32,
                        2 => raw as u16 as i16 as i32 as u32,
                        _ => raw,
                    }
                } else {
                    raw
                };
                w(regs, rd, value);
            }
            UOp::Store { size, rs1, rs2, offset } => {
                let addr = r(regs, rs1).wrapping_add(offset);
                let slot = (addr >> 2) as usize;
                // raw-word-keyed like the step decode cache: only a
                // store that actually CHANGES a translated word
                // invalidates (covered slots are always in peek range)
                let watched = bit(covered, slot);
                let before = if watched { mem.peek32(addr & !3) } else { 0 };
                crate::serv::Bus::store(mem, addr, r(regs, rs2), size)?;
                if watched && mem.peek32(addr & !3) != before {
                    // self-modifying code: stop before the (now stale)
                    // rest of this block and let the caller re-translate
                    if k + 1 < uops.len() {
                        charged = charged.minus(suffix[k + 1]);
                    }
                    ended =
                        Some(BlockExit::Smc { next_pc: pc.wrapping_add(4), slot: slot as u32 });
                    break;
                }
            }
            UOp::Jal { rd, link, target } => {
                w(regs, rd, link);
                ended = Some(BlockExit::Jump(target));
                break;
            }
            UOp::Jalr { rd, rs1, link, offset } => {
                let target = r(regs, rs1).wrapping_add(offset) & !1;
                w(regs, rd, link);
                ended = Some(BlockExit::Jump(target));
                break;
            }
            UOp::Branch { op, rs1, rs2, target } => {
                let a = r(regs, rs1);
                let b = r(regs, rs2);
                let taken = match op {
                    BranchOp::Beq => a == b,
                    BranchOp::Bne => a != b,
                    BranchOp::Blt => (a as i32) < (b as i32),
                    BranchOp::Bge => (a as i32) >= (b as i32),
                    BranchOp::Bltu => a < b,
                    BranchOp::Bgeu => a >= b,
                };
                let next = if taken {
                    dyn_exec += t.branch_taken_extra;
                    target
                } else {
                    pc.wrapping_add(4)
                };
                ended = Some(BlockExit::Jump(next));
                break;
            }
            UOp::Cfu { funct7, funct3, rd, rs1, rs2 } => {
                let a = r(regs, rs1);
                let b = r(regs, rs2);
                let cfu = cfus.get_mut(funct7).ok_or_else(|| {
                    anyhow!("no CFU registered for funct7={funct7} at pc {pc:#010x}")
                })?;
                let out = cfu.execute(funct3, a, b)?;
                let mut c = t.cfu_setup + t.cfu_tx + out.compute_cycles;
                if rd != 0 {
                    c += t.cfu_wb;
                    w(regs, rd, out.value);
                }
                cfu_cyc += c;
                cfu_n += 1;
            }
            UOp::Fence => {}
            UOp::Ecall => {
                ended = Some(BlockExit::Done(
                    Exit::Ecall { a0: r(regs, 10), a1: r(regs, 11) },
                    pc.wrapping_add(4),
                ));
                break;
            }
            UOp::Ebreak => {
                ended = Some(BlockExit::Done(Exit::Ebreak, pc.wrapping_add(4)));
                break;
            }
            UOp::Invalid => {
                // blocks are cut before undecodable words at translation
                bail!("block engine entered an untranslated word at pc {pc:#010x}");
            }
        }
    }
    // fall-through off the end of the block (next slot starts a new one)
    let ended =
        ended.unwrap_or_else(|| BlockExit::Jump(((base_slot + uops.len()) as u32) << 2));
    charged.charge(t, stats);
    stats.exec += dyn_exec;
    stats.cfu += cfu_cyc;
    stats.cfu_ops += cfu_n;
    mem.counters.ifetches += charged.n as u64;
    Ok(ended)
}

/// Drive a program to completion block-at-a-time; bit-identical
/// `CycleStats`, registers and exit value to the step interpreter.
///
/// When `prof` is supplied (sampled requests), every loop iteration's
/// cycle delta is attributed to the entered slot (CFU cycles kept
/// apart), including the exiting block and step-interpreter fallbacks —
/// so `prof.attributed() == stats.total()` bit-exactly on return (the
/// obs::profile conservation contract, DESIGN.md §5).  The profiler
/// costs one `BTreeMap` bump per *block*, and nothing at all on
/// unsampled requests (the `Option` is `None`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_blocks(
    prog: &DecodedProgram,
    ctx: &mut BlockCtx,
    core: &mut ServCore,
    mem: &mut Memory,
    cfus: &mut CfuBank,
    t: &TimingConfig,
    max_cycles: u64,
    mut prof: Option<&mut BlockProfiler>,
) -> Result<RunResult> {
    let mut stats = CycleStats::default();
    loop {
        let pc = core.pc;
        if pc % 4 != 0 {
            bail!("misaligned PC {pc:#010x}");
        }
        let slot = (pc / 4) as usize;
        let (cyc_before, cfu_before) = (stats.total(), stats.cfu);
        let translated = slot < prog.n_slots() && !matches!(prog.uops[slot], UOp::Invalid);
        let mut ended = None;
        if translated {
            let end = prog.block_end[slot] as usize;
            let needs_overlay = !ctx.dirty.is_empty()
                && ctx.dirty.iter().any(|&d| slot as u32 <= d && d <= end as u32);
            if needs_overlay {
                if !ctx.overlay.contains_key(&(slot as u32)) {
                    let ob = translate_owned(mem, slot, prog.n_slots());
                    for s in slot..slot + ob.uops.len() {
                        set_bit(&mut ctx.covered, s);
                    }
                    ctx.overlay.insert(slot as u32, ob);
                }
                let ob = &ctx.overlay[&(slot as u32)];
                if !ob.uops.is_empty() {
                    ended = Some(exec_block(
                        slot,
                        &ob.uops,
                        &ob.suffix,
                        &ctx.covered,
                        &mut core.regs,
                        mem,
                        cfus,
                        t,
                        &mut stats,
                    )?);
                }
            } else {
                ended = Some(exec_block(
                    slot,
                    &prog.uops[slot..=end],
                    &prog.suffix[slot..=end],
                    &ctx.covered,
                    &mut core.regs,
                    mem,
                    cfus,
                    t,
                    &mut stats,
                )?);
            }
        }
        let mut finished: Option<Exit> = None;
        match ended {
            Some(BlockExit::Jump(next)) => core.pc = next,
            Some(BlockExit::Smc { next_pc, slot }) => {
                core.pc = next_pc;
                ctx.dirty.insert(slot);
                ctx.overlay.clear();
                evlog::emit_fmt(evlog::Level::Warn, "smc_retranslate", || {
                    format!(
                        "store dirtied translated slot {slot}; overlay dropped, \
                         affected blocks re-translate from memory"
                    )
                });
            }
            Some(BlockExit::Done(exit, next_pc)) => {
                core.pc = next_pc;
                finished = Some(exit);
            }
            None => {
                // untranslated (data word / past the image / patched to
                // garbage): interpret one instruction — the step
                // decoder re-validates against the raw memory word
                let info = core.step(mem, cfus, t, &mut stats)?;
                // interpreted stores can also self-modify translated
                // text; stores don't write rd, so the EA is still
                // computable from the post-step registers
                if let Instr::Store { rs1, offset, .. } = info.instr {
                    let s =
                        (core.regs[rs1 as usize].wrapping_add(offset as u32) >> 2) as usize;
                    if bit(&ctx.covered, s) {
                        ctx.dirty.insert(s as u32);
                        ctx.overlay.clear();
                    }
                }
                if let Some(exit) = info.exit {
                    finished = Some(exit);
                }
            }
        }
        if let Some(p) = prof.as_deref_mut() {
            let cfu_delta = stats.cfu - cfu_before;
            p.record(slot as u32, stats.total() - cyc_before - cfu_delta, cfu_delta);
        }
        if let Some(exit) = finished {
            return Ok(RunResult { exit, stats });
        }
        if stats.total() > max_cycles {
            bail!(
                "cycle budget exceeded ({max_cycles}) at pc {:#010x} after {} instructions",
                core.pc,
                stats.instret
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;
    use crate::isa::Asm;

    #[test]
    fn translate_partitions_blocks() {
        let mut a = Asm::new(0);
        a.li(T0, 3); // addi
        a.label("loop");
        a.addi(T0, T0, -1);
        a.bne(T0, ZERO, "loop"); // terminator
        a.ecall(); // terminator
        a.label("data");
        a.zeros(2); // invalid words
        let p = DecodedProgram::translate(&a.assemble_bytes().unwrap());
        assert_eq!(p.n_slots(), 6);
        // blocks: [li addi bne] [ecall]; two zero words are boundaries
        assert_eq!(p.n_blocks(), 2);
        assert_eq!(p.block_end[0], 2);
        assert_eq!(p.block_end[1], 2);
        assert_eq!(p.block_end[3], 3);
        // suffix cost of the whole first block: 3 instrs, 3x32 exec
        assert_eq!(p.suffix[0], StaticCost { n: 3, exec: 96, loads: 0, stores: 0 });
        // mid-block entry (the loop back-edge target) covers 2 instrs
        assert_eq!(p.suffix[1], StaticCost { n: 2, exec: 64, loads: 0, stores: 0 });
        // invalid slots carry no cost
        assert_eq!(p.suffix[4], StaticCost::default());
    }

    #[test]
    fn static_cost_knows_imm_shift_amounts() {
        let mut a = Asm::new(0);
        a.slli(T0, T0, 9);
        a.ecall();
        let p = DecodedProgram::translate(&a.assemble_bytes().unwrap());
        assert_eq!(p.suffix[0].exec, 32 + 9 + 32, "slli 9 + ecall");
    }

    #[test]
    fn charge_matches_timing_components() {
        let t = TimingConfig::flexic();
        let c = StaticCost { n: 3, exec: 96, loads: 1, stores: 1 };
        let mut stats = CycleStats::default();
        c.charge(&t, &mut stats);
        assert_eq!(stats.fetch, 3 * t.fetch_cost());
        assert_eq!(stats.exec, 96 + t.load_shift_in);
        assert_eq!(stats.data_mem, t.load_cost() + t.store_cost());
        assert_eq!(stats.instret, 3);
        assert_eq!((stats.loads, stats.stores), (1, 1));
    }
}
