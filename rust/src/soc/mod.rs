//! System-on-chip: SERV core + memory + CFU bank, wired per Fig. 1/5.
//!
//! `Soc::run` drives the core to completion and returns the exit value
//! with full cycle attribution.  An optional tracer receives one event
//! per retired instruction — `examples/cycle_sim.rs` uses it to render
//! the Fig. 2 handshake life-cycle.

pub mod mem;
pub mod vcd;

use anyhow::{bail, Result};

use crate::accel::CfuBank;
use crate::isa::disasm;
use crate::serv::{CfuEvent, CycleStats, Exit, ServCore, StepInfo, TimingConfig};

pub use mem::{Memory, DEFAULT_SIZE, STACK_TOP, TEXT_BASE};

/// Outcome of a completed program run.
#[derive(Debug, Clone, Copy)]
pub struct RunResult {
    pub exit: Exit,
    pub stats: CycleStats,
}

impl RunResult {
    /// The program's result value (a0 at ecall).
    pub fn value(&self) -> u32 {
        match self.exit {
            Exit::Ecall { a0, .. } => a0,
            Exit::Ebreak => 0,
        }
    }
}

/// A trace callback: called once per retired instruction.
pub type Tracer<'a> = &'a mut dyn FnMut(&StepInfo);

pub struct Soc {
    pub core: ServCore,
    pub mem: Memory,
    pub cfus: CfuBank,
    pub timing: TimingConfig,
}

impl Soc {
    /// Build an SoC with the program image loaded at `TEXT_BASE`, the
    /// stack pointer initialised to `STACK_TOP`, and PC at the entry.
    pub fn new(image: &[u8], timing: TimingConfig) -> Self {
        let mem = Memory::with_image(image, DEFAULT_SIZE);
        let mut core = ServCore::new(TEXT_BASE);
        core.regs[2] = STACK_TOP; // sp
        Soc { core, mem, cfus: CfuBank::new(), timing }
    }

    pub fn register_cfu(&mut self, funct7: u8, cfu: Box<dyn crate::accel::Cfu>) -> Result<()> {
        self.cfus.register(funct7, cfu)
    }

    /// Run to `ecall`/`ebreak` or the cycle budget.
    pub fn run(&mut self, max_cycles: u64) -> Result<RunResult> {
        self.run_traced(max_cycles, None)
    }

    pub fn run_traced(&mut self, max_cycles: u64, mut tracer: Option<Tracer>) -> Result<RunResult> {
        let mut stats = CycleStats::default();
        loop {
            let info = self.core.step(&mut self.mem, &mut self.cfus, &self.timing, &mut stats)?;
            if let Some(t) = tracer.as_deref_mut() {
                t(&info);
            }
            if let Some(exit) = info.exit {
                return Ok(RunResult { exit, stats });
            }
            if stats.total() > max_cycles {
                bail!(
                    "cycle budget exceeded ({max_cycles}) at pc {:#010x} after {} instructions",
                    self.core.pc,
                    stats.instret
                );
            }
        }
    }

    /// Re-arm the SoC for another run of the same image: reset PC/regs
    /// (but NOT memory — programs may carry state between runs; reload
    /// the image if isolation is needed).
    pub fn rearm(&mut self) {
        self.core = ServCore::new(TEXT_BASE);
        self.core.regs[2] = STACK_TOP;
        self.cfus.reset_all();
    }
}

/// Render one trace line; CFU instructions show the Fig. 2 phases.
pub fn format_trace_line(info: &StepInfo, timing: &TimingConfig) -> String {
    let base = format!("{:#010x}  {:<28}", info.pc, disasm(info.instr));
    match info.cfu {
        Some(CfuEvent { funct3, rs1, rs2, result, compute_cycles, wrote_rd, .. }) => {
            let wb = if wrote_rd {
                format!(" | rf-writeback {} cyc", timing.cfu_wb)
            } else {
                " | no writeback (rd=x0)".to_string()
            };
            format!(
                "{base} [init {} cyc | operand-tx {} cyc (rs1={rs1:#010x} rs2={rs2:#010x}) | \
                 accel_valid -> compute {compute_cycles} cyc -> accel_ready (res={result:#010x} f3={funct3}){wb}] total {} cyc",
                timing.cfu_setup, timing.cfu_tx, info.cycles
            )
        }
        None => format!("{base} {} cyc", info.cycles),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;
    use crate::isa::Asm;

    #[test]
    fn run_simple_program() {
        let mut a = Asm::new(0);
        a.li(A0, 1234);
        a.ecall();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        let r = soc.run(1_000_000).unwrap();
        assert_eq!(r.value(), 1234);
        // flexic timing: every instruction pays 110-cycle fetch
        assert_eq!(r.stats.fetch, r.stats.instret * 110);
    }

    #[test]
    fn stack_pointer_initialised() {
        let mut a = Asm::new(0);
        a.mv(A0, SP);
        a.ecall();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        let r = soc.run(100_000).unwrap();
        assert_eq!(r.value(), STACK_TOP);
    }

    #[test]
    fn cycle_budget_enforced() {
        let mut a = Asm::new(0);
        a.label("spin");
        a.j("spin");
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        assert!(soc.run(10_000).is_err());
    }

    #[test]
    fn tracer_sees_every_instruction() {
        let mut a = Asm::new(0);
        a.li(T0, 2);
        a.label("l");
        a.addi(T0, T0, -1);
        a.bne(T0, ZERO, "l");
        a.ecall();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        let mut n = 0u64;
        let mut cb = |_: &StepInfo| n += 1;
        let r = soc.run_traced(1_000_000, Some(&mut cb)).unwrap();
        assert_eq!(n, r.stats.instret);
        assert_eq!(n, 6); // li, addi, bne(taken), addi, bne, ecall
    }

    #[test]
    fn rearm_resets_core_state() {
        let mut a = Asm::new(0);
        a.addi(A0, A0, 1);
        a.ecall();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        assert_eq!(soc.run(100_000).unwrap().value(), 1);
        soc.rearm();
        assert_eq!(soc.run(100_000).unwrap().value(), 1, "a0 must reset");
    }
}
