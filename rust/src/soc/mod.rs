//! System-on-chip: SERV core + memory + CFU bank, wired per Fig. 1/5.
//!
//! `Soc::run` drives the core to completion and returns the exit value
//! with full cycle attribution.  Untraced runs execute on the
//! [`block`]-compiled engine (pre-decoded basic blocks, one stats
//! update per block); `Soc::run_traced` keeps the per-instruction step
//! interpreter — an optional tracer receives one event per retired
//! instruction, and `examples/cycle_sim.rs` uses it to render the
//! Fig. 2 handshake life-cycle.  Both paths produce bit-identical
//! `CycleStats` (pinned by `rust/tests/proptests.rs`).

pub mod block;
pub mod cost;
pub mod mem;
pub mod vcd;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::accel::CfuBank;
use crate::isa::disasm;
use crate::serv::{CfuEvent, CycleStats, Exit, ServCore, StepInfo, TimingConfig};

pub use block::DecodedProgram;
pub use mem::{Memory, DEFAULT_SIZE, STACK_TOP, TEXT_BASE};

/// Outcome of a completed program run.
#[derive(Debug, Clone, Copy)]
pub struct RunResult {
    pub exit: Exit,
    pub stats: CycleStats,
}

impl RunResult {
    /// The program's result value (a0 at ecall).
    pub fn value(&self) -> u32 {
        match self.exit {
            Exit::Ecall { a0, .. } => a0,
            Exit::Ebreak => 0,
        }
    }
}

/// A trace callback: called once per retired instruction.
pub type Tracer<'a> = &'a mut dyn FnMut(&StepInfo);

pub struct Soc {
    pub core: ServCore,
    pub mem: Memory,
    pub cfus: CfuBank,
    pub timing: TimingConfig,
    /// Shared block translation of the loaded image (see [`block`]);
    /// survives `rearm` and is shared across SoCs built from the same
    /// `Arc` (the farm's shards).
    program: Arc<DecodedProgram>,
    /// Per-SoC block-engine state (SMC invalidation, overlay blocks).
    blocks: block::BlockCtx,
}

impl Soc {
    /// Build an SoC with the program image loaded at `TEXT_BASE`, the
    /// stack pointer initialised to `STACK_TOP`, and PC at the entry.
    /// The image is block-translated once, here.
    pub fn new(image: &[u8], timing: TimingConfig) -> Self {
        Self::with_program(Arc::new(DecodedProgram::translate(image)), timing)
    }

    /// Build an SoC around an already-translated program — shards of a
    /// farm share one `Arc<DecodedProgram>` instead of re-decoding the
    /// image per SoC.
    pub fn with_program(program: Arc<DecodedProgram>, timing: TimingConfig) -> Self {
        let mem = Memory::with_image(program.image(), DEFAULT_SIZE);
        let mut core = ServCore::new(TEXT_BASE);
        core.regs[2] = STACK_TOP; // sp
        let blocks = block::BlockCtx::new(&program);
        Soc { core, mem, cfus: CfuBank::new(), timing, program, blocks }
    }

    /// The shared block translation this SoC executes.
    pub fn program(&self) -> &Arc<DecodedProgram> {
        &self.program
    }

    pub fn register_cfu(&mut self, funct7: u8, cfu: Box<dyn crate::accel::Cfu>) -> Result<()> {
        self.cfus.register(funct7, cfu)
    }

    /// Run to `ecall`/`ebreak` or the cycle budget on the
    /// block-compiled engine (bit-identical accounting to
    /// [`run_traced`](Self::run_traced), measurably faster).
    ///
    /// The budget is a runaway guard and is enforced at *block*
    /// granularity: a run may overshoot `max_cycles` by up to one
    /// basic block's cost before bailing (and completes successfully
    /// if it exits within that block), where the step interpreter
    /// checks after every instruction.  Successful runs under budget
    /// are unaffected.
    pub fn run(&mut self, max_cycles: u64) -> Result<RunResult> {
        let program = Arc::clone(&self.program);
        block::run_blocks(
            &program,
            &mut self.blocks,
            &mut self.core,
            &mut self.mem,
            &mut self.cfus,
            &self.timing,
            max_cycles,
            None,
        )
    }

    /// [`run`](Self::run) with per-block cycle attribution into `prof`
    /// (the sampled continuous profiler, `obs::profile`).  Same block
    /// engine, same bit-identical accounting; on success
    /// `prof.attributed()` equals the run's `stats.total()` bit-exactly.
    pub fn run_profiled(
        &mut self,
        max_cycles: u64,
        prof: &mut crate::obs::BlockProfiler,
    ) -> Result<RunResult> {
        let program = Arc::clone(&self.program);
        block::run_blocks(
            &program,
            &mut self.blocks,
            &mut self.core,
            &mut self.mem,
            &mut self.cfus,
            &self.timing,
            max_cycles,
            Some(prof),
        )
    }

    /// Step-interpreted run: one event per retired instruction for the
    /// tracer.  Also the differential reference the block engine is
    /// pinned against.
    pub fn run_traced(&mut self, max_cycles: u64, mut tracer: Option<Tracer>) -> Result<RunResult> {
        let mut stats = CycleStats::default();
        loop {
            let info = self.core.step(&mut self.mem, &mut self.cfus, &self.timing, &mut stats)?;
            if let Some(t) = tracer.as_deref_mut() {
                t(&info);
            }
            if let Some(exit) = info.exit {
                return Ok(RunResult { exit, stats });
            }
            if stats.total() > max_cycles {
                bail!(
                    "cycle budget exceeded ({max_cycles}) at pc {:#010x} after {} instructions",
                    self.core.pc,
                    stats.instret
                );
            }
        }
    }

    /// Re-arm the SoC for another run of the same image: reset PC/regs
    /// (but NOT memory — programs may carry state between runs; reload
    /// the image if isolation is needed).  Decoded/translated state is
    /// kept: the block translation, its SMC overlay, and the step
    /// interpreter's decode cache all survive, so warm re-runs skip
    /// re-decoding entirely.
    pub fn rearm(&mut self) {
        self.core.reset(TEXT_BASE);
        self.core.regs[2] = STACK_TOP;
        self.cfus.reset_all();
    }
}

/// Render one trace line; CFU instructions show the Fig. 2 phases.
pub fn format_trace_line(info: &StepInfo, timing: &TimingConfig) -> String {
    let base = format!("{:#010x}  {:<28}", info.pc, disasm(info.instr));
    match info.cfu {
        Some(CfuEvent { funct3, rs1, rs2, result, compute_cycles, wrote_rd, .. }) => {
            let wb = if wrote_rd {
                format!(" | rf-writeback {} cyc", timing.cfu_wb)
            } else {
                " | no writeback (rd=x0)".to_string()
            };
            format!(
                "{base} [init {} cyc | operand-tx {} cyc (rs1={rs1:#010x} rs2={rs2:#010x}) | \
                 accel_valid -> compute {compute_cycles} cyc -> accel_ready (res={result:#010x} f3={funct3}){wb}] total {} cyc",
                timing.cfu_setup, timing.cfu_tx, info.cycles
            )
        }
        None => format!("{base} {} cyc", info.cycles),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;
    use crate::isa::Asm;

    #[test]
    fn run_simple_program() {
        let mut a = Asm::new(0);
        a.li(A0, 1234);
        a.ecall();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        let r = soc.run(1_000_000).unwrap();
        assert_eq!(r.value(), 1234);
        // flexic timing: every instruction pays 110-cycle fetch
        assert_eq!(r.stats.fetch, r.stats.instret * 110);
    }

    #[test]
    fn stack_pointer_initialised() {
        let mut a = Asm::new(0);
        a.mv(A0, SP);
        a.ecall();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        let r = soc.run(100_000).unwrap();
        assert_eq!(r.value(), STACK_TOP);
    }

    #[test]
    fn cycle_budget_enforced() {
        let mut a = Asm::new(0);
        a.label("spin");
        a.j("spin");
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        assert!(soc.run(10_000).is_err());
    }

    #[test]
    fn tracer_sees_every_instruction() {
        let mut a = Asm::new(0);
        a.li(T0, 2);
        a.label("l");
        a.addi(T0, T0, -1);
        a.bne(T0, ZERO, "l");
        a.ecall();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        let mut n = 0u64;
        let mut cb = |_: &StepInfo| n += 1;
        let r = soc.run_traced(1_000_000, Some(&mut cb)).unwrap();
        assert_eq!(n, r.stats.instret);
        assert_eq!(n, 6); // li, addi, bne(taken), addi, bne, ecall
    }

    #[test]
    fn rearm_resets_core_state() {
        let mut a = Asm::new(0);
        a.addi(A0, A0, 1);
        a.ecall();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        assert_eq!(soc.run(100_000).unwrap().value(), 1);
        soc.rearm();
        assert_eq!(soc.run(100_000).unwrap().value(), 1, "a0 must reset");
    }

    #[test]
    fn rearm_keeps_decoded_state() {
        let mut a = Asm::new(0);
        a.li(T0, 2);
        a.label("l");
        a.addi(T0, T0, -1);
        a.bne(T0, ZERO, "l");
        a.li(A0, 9);
        a.ecall();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), TimingConfig::flexic());
        // step path fills the decode cache; rearm must not discard it
        assert_eq!(soc.run_traced(100_000, None).unwrap().value(), 9);
        let warm = soc.core.decode_cache_entries();
        assert!(warm > 0);
        soc.rearm();
        assert_eq!(soc.core.decode_cache_entries(), warm, "decode cache survives rearm");
        // and the shared block translation survives too
        let prog = Arc::clone(soc.program());
        soc.rearm();
        assert!(Arc::ptr_eq(&prog, soc.program()));
        assert_eq!(soc.run(100_000).unwrap().value(), 9);
    }

    #[test]
    fn socs_can_share_one_translation() {
        let mut a = Asm::new(0);
        a.li(A0, 5);
        a.ecall();
        let prog = Arc::new(DecodedProgram::translate(&a.assemble_bytes().unwrap()));
        let mut s1 = Soc::with_program(Arc::clone(&prog), TimingConfig::flexic());
        let mut s2 = Soc::with_program(Arc::clone(&prog), TimingConfig::flexic());
        assert!(Arc::ptr_eq(s1.program(), s2.program()));
        let r1 = s1.run(100_000).unwrap();
        let r2 = s2.run(100_000).unwrap();
        assert_eq!(r1.value(), 5);
        assert_eq!(r1.stats, r2.stats);
    }

    #[test]
    fn block_and_step_agree_on_a_looping_program() {
        let mut a = Asm::new(0);
        a.la(S0, "buf");
        a.li(T0, 25);
        a.li(T1, 0);
        a.label("loop");
        a.add(T1, T1, T0);
        a.sw(S0, T1, 0);
        a.lw(T1, S0, 0);
        a.slli(T2, T1, 3);
        a.sll(T2, T2, T0); // register-count shift: dynamic cycles
        a.addi(T0, T0, -1);
        a.bne(T0, ZERO, "loop");
        a.mv(A0, T1);
        a.ecall();
        a.label("buf");
        a.zeros(1);
        let image = a.assemble_bytes().unwrap();
        let mut blk = Soc::new(&image, TimingConfig::flexic());
        let mut stp = Soc::new(&image, TimingConfig::flexic());
        let rb = blk.run(100_000_000).unwrap();
        let rs = stp.run_traced(100_000_000, None).unwrap();
        assert_eq!(rb.exit, rs.exit);
        assert_eq!(rb.stats, rs.stats, "cycle accounting must be bit-identical");
        assert_eq!(blk.core.regs, stp.core.regs);
        assert_eq!(blk.core.pc, stp.core.pc);
        assert_eq!(blk.mem.counters, stp.mem.counters);
    }
}
