//! Cycle-vector algebra for analytic cost models (ISSUE 6 tentpole).
//!
//! The block engine already separates every program's cycle bill into
//! a translation-time **static** part (per-block suffix costs, see
//! [`super::block`]) and a small **dynamic** remainder (taken-branch
//! PC updates, register-count shifts, CFU handshakes).  An analytic
//! cost model exploits that split: measure the full bill once on a
//! probe input, then express the data-dependent remainder as a linear
//! combination of a few closed-form delta vectors.
//!
//! [`CostVec`] is the signed vector space those models compute in —
//! one `i64` lane per [`CycleStats`] field, so deltas may be negative
//! (e.g. the not-taken side of a branch retiring one *more*
//! instruction than the taken side while skipping the
//! `branch_taken_extra` cycles).  A finished prediction converts back
//! to `CycleStats` via [`CostVec::to_stats`], which refuses negative
//! lanes rather than wrapping.

use crate::serv::CycleStats;

/// A signed cycle vector: `CycleStats` lifted to `i64` lanes so cost
/// models can subtract and scale.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CostVec {
    pub fetch: i64,
    pub exec: i64,
    pub data_mem: i64,
    pub cfu: i64,
    pub instret: i64,
    pub loads: i64,
    pub stores: i64,
    pub cfu_ops: i64,
}

impl CostVec {
    /// Lift measured stats into the signed vector space.
    pub fn from_stats(s: &CycleStats) -> CostVec {
        CostVec {
            fetch: s.fetch as i64,
            exec: s.exec as i64,
            data_mem: s.data_mem as i64,
            cfu: s.cfu as i64,
            instret: s.instret as i64,
            loads: s.loads as i64,
            stores: s.stores as i64,
            cfu_ops: s.cfu_ops as i64,
        }
    }

    /// Lower back to `CycleStats`; `None` if any lane went negative
    /// (an ill-formed model must surface, not wrap around).
    pub fn to_stats(&self) -> Option<CycleStats> {
        let lanes = [
            self.fetch,
            self.exec,
            self.data_mem,
            self.cfu,
            self.instret,
            self.loads,
            self.stores,
            self.cfu_ops,
        ];
        if lanes.iter().any(|&v| v < 0) {
            return None;
        }
        Some(CycleStats {
            fetch: self.fetch as u64,
            exec: self.exec as u64,
            data_mem: self.data_mem as u64,
            cfu: self.cfu as u64,
            instret: self.instret as u64,
            loads: self.loads as u64,
            stores: self.stores as u64,
            cfu_ops: self.cfu_ops as u64,
        })
    }

    pub fn add(self, o: CostVec) -> CostVec {
        CostVec {
            fetch: self.fetch + o.fetch,
            exec: self.exec + o.exec,
            data_mem: self.data_mem + o.data_mem,
            cfu: self.cfu + o.cfu,
            instret: self.instret + o.instret,
            loads: self.loads + o.loads,
            stores: self.stores + o.stores,
            cfu_ops: self.cfu_ops + o.cfu_ops,
        }
    }

    pub fn sub(self, o: CostVec) -> CostVec {
        CostVec {
            fetch: self.fetch - o.fetch,
            exec: self.exec - o.exec,
            data_mem: self.data_mem - o.data_mem,
            cfu: self.cfu - o.cfu,
            instret: self.instret - o.instret,
            loads: self.loads - o.loads,
            stores: self.stores - o.stores,
            cfu_ops: self.cfu_ops - o.cfu_ops,
        }
    }

    pub fn scaled(self, n: i64) -> CostVec {
        CostVec {
            fetch: self.fetch * n,
            exec: self.exec * n,
            data_mem: self.data_mem * n,
            cfu: self.cfu * n,
            instret: self.instret * n,
            loads: self.loads * n,
            stores: self.stores * n,
            cfu_ops: self.cfu_ops * n,
        }
    }

    /// Total cycles of the vector (the `CycleStats::total` analogue).
    pub fn total(&self) -> i64 {
        self.fetch + self.exec + self.data_mem + self.cfu
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;
    use crate::isa::Asm;
    use crate::serv::TimingConfig;
    use crate::soc::DecodedProgram;

    #[test]
    fn stats_round_trip() {
        let s = CycleStats {
            fetch: 110,
            exec: 64,
            data_mem: 221,
            cfu: 68,
            instret: 3,
            loads: 1,
            stores: 1,
            cfu_ops: 1,
        };
        let v = CostVec::from_stats(&s);
        assert_eq!(v.to_stats(), Some(s));
        assert_eq!(v.total(), s.total() as i64);
    }

    #[test]
    fn negative_lane_refuses_to_lower() {
        let s = CycleStats { exec: 5, ..Default::default() };
        let v = CostVec::from_stats(&s).sub(CostVec { exec: 6, ..Default::default() });
        assert_eq!(v.exec, -1, "signed lanes hold intermediate deltas");
        assert_eq!(v.to_stats(), None, "ill-formed model must surface");
    }

    #[test]
    fn algebra_is_affine() {
        let base = CostVec { fetch: 100, exec: 50, instret: 4, ..Default::default() };
        let delta = CostVec { fetch: 110, exec: 32, instret: 1, ..Default::default() };
        let v = base.add(delta.scaled(3));
        assert_eq!(v.fetch, 430);
        assert_eq!(v.instret, 7);
        assert_eq!(v.sub(delta.scaled(3)), base);
        assert_eq!(delta.scaled(0), CostVec::default());
    }

    #[test]
    fn static_suffix_cost_matches_block_translation() {
        // the public accessor mirrors what the block engine charges
        // statically: n·fetch, 32/instr exec (+imm shift amounts),
        // load/store transactions + load shift-in
        let mut a = Asm::new(0);
        a.lw(T0, A0, 0);
        a.slli(T0, T0, 9);
        a.sw(A0, T0, 0);
        a.ecall();
        let p = DecodedProgram::translate(&a.assemble_bytes().unwrap());
        let t = TimingConfig::flexic();
        let s = p.static_suffix_cost(0, &t);
        assert_eq!(s.instret, 4);
        assert_eq!(s.fetch, 4 * t.fetch_cost());
        assert_eq!(s.exec, 4 * 32 + 9 + t.load_shift_in);
        assert_eq!(s.data_mem, t.load_cost() + t.store_cost());
        assert_eq!((s.loads, s.stores), (1, 1));
        // mid-block entry covers the remaining suffix only
        let s2 = p.static_suffix_cost(2, &t);
        assert_eq!(s2.instret, 2);
        assert_eq!(s2.stores, 1);
        // out-of-range and data slots cost nothing
        assert_eq!(p.static_suffix_cost(99, &t), CycleStats::default());
    }
}
