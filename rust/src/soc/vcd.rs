//! VCD (Value Change Dump) waveform writer for the SERV ⇄ accelerator
//! handshake — the software twin of watching the Fig. 1/2 signals in a
//! waveform viewer during FPGA bring-up (paper §III-D).
//!
//! One VCD record per retired instruction, expanded into the handshake
//! phases of Fig. 2 for CFU instructions: `init`, `cnt_en`, `cnt_done`,
//! `accel_valid`, `accel_ready`, plus the 32-bit operand/result buses.
//! Output loads in GTKWave/Surfer.

use std::fmt::Write as _;

use crate::serv::{CfuEvent, StepInfo, TimingConfig};

/// Signal ids (VCD identifier characters).
const SIG_INIT: char = 'a';
const SIG_CNT_EN: char = 'b';
const SIG_CNT_DONE: char = 'c';
const SIG_VALID: char = 'd';
const SIG_READY: char = 'e';
const SIG_RS1: char = 'f';
const SIG_RS2: char = 'g';
const SIG_RES: char = 'h';
const SIG_PC: char = 'i';

/// Streaming VCD builder driven by the SoC tracer.
pub struct VcdWriter {
    body: String,
    t: u64,
    timing: TimingConfig,
}

impl VcdWriter {
    pub fn new(timing: TimingConfig) -> Self {
        VcdWriter { body: String::new(), t: 0, timing }
    }

    fn change_bit(&mut self, sig: char, v: bool) {
        let _ = writeln!(self.body, "{}{}", if v { '1' } else { '0' }, sig);
    }

    fn change_bus(&mut self, sig: char, v: u32) {
        let _ = writeln!(self.body, "b{:b} {}", v, sig);
    }

    fn at(&mut self, t: u64) {
        let _ = writeln!(self.body, "#{t}");
    }

    /// Record one retired instruction (SoC tracer callback).
    pub fn record(&mut self, info: &StepInfo) {
        let start = self.t;
        self.at(start);
        self.change_bus(SIG_PC, info.pc);
        if let Some(CfuEvent { rs1, rs2, result, compute_cycles, wrote_rd, .. }) = info.cfu {
            let t = self.timing;
            // Fig. 2 phase timeline within this instruction
            let fetch_end = start + t.fetch_cost();
            self.at(fetch_end);
            self.change_bit(SIG_INIT, true);
            let tx_start = fetch_end + t.cfu_setup;
            self.at(tx_start);
            self.change_bit(SIG_CNT_EN, true);
            self.change_bus(SIG_RS1, rs1);
            self.change_bus(SIG_RS2, rs2);
            let tx_end = tx_start + t.cfu_tx;
            self.at(tx_end - 1);
            self.change_bit(SIG_CNT_DONE, true);
            self.at(tx_end);
            self.change_bit(SIG_CNT_EN, false);
            self.change_bit(SIG_CNT_DONE, false);
            self.change_bit(SIG_INIT, false);
            self.change_bit(SIG_VALID, true);
            let ready_at = tx_end + compute_cycles;
            self.at(ready_at);
            self.change_bit(SIG_VALID, false);
            self.change_bit(SIG_READY, true);
            self.change_bus(SIG_RES, result);
            let wb_end = if wrote_rd { ready_at + t.cfu_wb } else { ready_at };
            self.at(wb_end);
            self.change_bit(SIG_READY, false);
        }
        self.t = start + info.cycles;
    }

    /// Finish and render the complete VCD document.
    pub fn finish(mut self) -> String {
        let end = self.t;
        self.at(end);
        let mut out = String::new();
        out.push_str("$date flexsvm cycle-accurate simulation $end\n");
        out.push_str("$version flexsvm 0.1.0 $end\n");
        out.push_str("$timescale 1us $end\n"); // 1 cycle ~ 19us at 52 kHz; symbolic
        out.push_str("$scope module bendable_riscv $end\n");
        for (sig, name, width) in [
            (SIG_INIT, "init", 1usize),
            (SIG_CNT_EN, "cnt_en", 1),
            (SIG_CNT_DONE, "cnt_done", 1),
            (SIG_VALID, "accel_valid", 1),
            (SIG_READY, "accel_ready", 1),
            (SIG_RS1, "rs1", 32),
            (SIG_RS2, "rs2", 32),
            (SIG_RES, "accel_result", 32),
            (SIG_PC, "pc", 32),
        ] {
            let kind = if width == 1 { "wire" } else { "reg" };
            let _ = writeln!(out, "$var {kind} {width} {sig} {name} $end");
        }
        out.push_str("$upscope $end\n$enddefinitions $end\n");
        // initial values
        out.push_str("$dumpvars\n");
        for sig in [SIG_INIT, SIG_CNT_EN, SIG_CNT_DONE, SIG_VALID, SIG_READY] {
            let _ = writeln!(out, "0{sig}");
        }
        for sig in [SIG_RS1, SIG_RS2, SIG_RES, SIG_PC] {
            let _ = writeln!(out, "b0 {sig}");
        }
        out.push_str("$end\n");
        out.push_str(&self.body);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::svm::SvmAccel;
    use crate::isa::reg::*;
    use crate::isa::{svm_ops, Asm, CFU_FUNCT7_SVM};
    use crate::soc::Soc;

    fn trace_program() -> String {
        let mut a = Asm::new(0);
        a.cfu(CFU_FUNCT7_SVM, svm_ops::CREATE_ENV, ZERO, ZERO, ZERO);
        a.li(A1, 0x35);
        a.li(A2, 0x21);
        a.cfu(CFU_FUNCT7_SVM, svm_ops::SV_CALC4, ZERO, A1, A2);
        a.cfu(CFU_FUNCT7_SVM, svm_ops::SV_RES4, A0, ZERO, ZERO);
        a.ecall();
        let timing = TimingConfig::flexic();
        let mut soc = Soc::new(&a.assemble_bytes().unwrap(), timing);
        soc.register_cfu(CFU_FUNCT7_SVM, Box::new(SvmAccel::new())).unwrap();
        let mut vcd = VcdWriter::new(timing);
        let mut cb = |info: &StepInfo| vcd.record(info);
        soc.run_traced(1_000_000, Some(&mut cb)).unwrap();
        vcd.finish()
    }

    #[test]
    fn vcd_structure_valid() {
        let vcd = trace_program();
        assert!(vcd.starts_with("$date"));
        assert!(vcd.contains("$enddefinitions $end"));
        for name in ["init", "cnt_en", "cnt_done", "accel_valid", "accel_ready", "rs1"] {
            assert!(vcd.contains(name), "missing signal {name}");
        }
        // handshake edges appear for each of the 3 CFU instructions
        assert_eq!(vcd.matches("1d").count(), 3, "accel_valid rising edges");
        assert_eq!(vcd.matches("1e").count(), 3, "accel_ready rising edges");
        // operand bus carries the packed value
        assert!(vcd.contains(&format!("b{:b} f", 0x35)));
    }

    #[test]
    fn timestamps_monotone() {
        let vcd = trace_program();
        let mut last = 0u64;
        for line in vcd.lines() {
            if let Some(ts) = line.strip_prefix('#') {
                let t: u64 = ts.parse().unwrap();
                assert!(t >= last, "timestamps must not go backwards: {t} < {last}");
                last = t;
            }
        }
        assert!(last > 0);
    }
}
