//! Cycle-accurate bit-serial SERV core model (paper §II-B).
//!
//! SERV executes instructions one bit at a time: a 1-bit ALU with a
//! carry flip-flop, shift-register operand access, and an FSM that
//! sequences 32-cycle serial passes.  This module reproduces that
//! execution discipline in software:
//!
//!  * [`alu`] — the bit-serial ALU: results are computed bit by bit, and
//!    every pass reports the serial cycles it consumed.
//!  * [`core`] — the instruction FSM: fetch (charged at the paper's FE
//!    memory latency), decode (the *modified decoder* that raises
//!    `acc_op` for funct7 ∉ {0x00, 0x20} — implemented in
//!    `crate::isa::decode`), serial execute, and the CFU handshake of
//!    Fig. 2 (32-cycle operand transmission, accelerator compute,
//!    32-cycle result write-back).
//!  * [`timing`] — all latency parameters (memory, handshake, shifts)
//!    plus per-category cycle attribution.
//!
//! SERV has no M extension: multiplication is emulated in software by
//! the baseline programs (rust/src/program/baseline.rs), which is
//! exactly the bottleneck the paper's SVM accelerator removes.

pub mod alu;
pub mod core;
pub mod timing;

pub use core::{Bus, CfuEvent, Exit, ServCore, StepInfo};
pub use timing::{CycleStats, TimingConfig};
