//! Timing configuration and cycle accounting for the SERV SoC.
//!
//! The paper's evaluation injects realistic FE memory delays: "each
//! memory read takes 46 cycles, each write takes 47 cycles, and every
//! memory access involves an additional 64-cycle overhead" (§V-B).
//! Those delays apply to both instruction fetch and data accesses; the
//! bit-serial execution cost comes from the serial ALU (serv/alu.rs).
//!
//! Everything is a parameter so the ablation benches can sweep the
//! memory latency (ABL-2 in DESIGN.md §4) or model an ideal memory.

/// SoC timing parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingConfig {
    /// Cycles for a memory read transaction (paper: 46).
    pub mem_read: u64,
    /// Cycles for a memory write transaction (paper: 47).
    pub mem_write: u64,
    /// Fixed overhead added to every memory access (paper: 64).
    pub mem_overhead: u64,
    /// Extra cycles a taken branch spends serially updating the PC.
    pub branch_taken_extra: u64,
    /// Extra cycles a load spends shifting the fetched word into rd.
    pub load_shift_in: u64,
    /// CFU handshake: operand transmission cycles (Fig. 2: 32-cycle
    /// serial transfer of rs1/rs2).
    pub cfu_tx: u64,
    /// CFU handshake: result write-back cycles (Fig. 2: 32 cycles,
    /// skipped when rd = x0 — the SV_Calc* instructions).
    pub cfu_wb: u64,
    /// CFU handshake setup: init + i_rf_ready + accel_valid edges.
    pub cfu_setup: u64,
}

impl TimingConfig {
    /// The paper's FE memory model on the bit-serial SERV.
    pub fn flexic() -> Self {
        TimingConfig {
            mem_read: 46,
            mem_write: 47,
            mem_overhead: 64,
            branch_taken_extra: 32,
            load_shift_in: 32,
            cfu_tx: 32,
            cfu_wb: 32,
            cfu_setup: 3,
        }
    }

    /// Ideal single-cycle memory (used by ablations and unit tests to
    /// isolate the bit-serial execution cost).
    pub fn ideal_mem() -> Self {
        TimingConfig { mem_read: 1, mem_write: 1, mem_overhead: 0, ..Self::flexic() }
    }

    #[inline]
    pub fn fetch_cost(&self) -> u64 {
        self.mem_read + self.mem_overhead
    }

    #[inline]
    pub fn load_cost(&self) -> u64 {
        self.mem_read + self.mem_overhead
    }

    #[inline]
    pub fn store_cost(&self) -> u64 {
        self.mem_write + self.mem_overhead
    }
}

impl Default for TimingConfig {
    fn default() -> Self {
        Self::flexic()
    }
}

/// Cycle attribution by category (the MEM experiment in DESIGN.md §4
/// reports the data-memory share, mirroring the paper's 8/12/16 %).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CycleStats {
    /// Instruction-fetch cycles (memory transaction per instruction).
    pub fetch: u64,
    /// Bit-serial execution cycles (ALU/shift/branch/PC).
    pub exec: u64,
    /// Data-memory transaction cycles (loads + stores).
    pub data_mem: u64,
    /// Cycles spent inside CFU handshakes + accelerator compute.
    pub cfu: u64,
    /// Retired instruction count.
    pub instret: u64,
    /// Retired loads / stores / CFU ops.
    pub loads: u64,
    pub stores: u64,
    pub cfu_ops: u64,
}

impl CycleStats {
    pub fn total(&self) -> u64 {
        self.fetch + self.exec + self.data_mem + self.cfu
    }

    pub fn merge(&mut self, o: &CycleStats) {
        self.fetch += o.fetch;
        self.exec += o.exec;
        self.data_mem += o.data_mem;
        self.cfu += o.cfu;
        self.instret += o.instret;
        self.loads += o.loads;
        self.stores += o.stores;
        self.cfu_ops += o.cfu_ops;
    }

    /// Fraction of cycles spent on data-memory transactions.
    pub fn data_mem_share(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.data_mem as f64 / self.total() as f64
        }
    }

    /// Cycles per retired instruction.
    pub fn cpi(&self) -> f64 {
        if self.instret == 0 {
            0.0
        } else {
            self.total() as f64 / self.instret as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flexic_matches_paper() {
        let t = TimingConfig::flexic();
        assert_eq!(t.mem_read, 46);
        assert_eq!(t.mem_write, 47);
        assert_eq!(t.mem_overhead, 64);
        assert_eq!(t.fetch_cost(), 110);
        assert_eq!(t.store_cost(), 111);
    }

    #[test]
    fn stats_merge_and_shares() {
        let mut a = CycleStats { fetch: 100, exec: 50, data_mem: 30, cfu: 20, instret: 10, ..Default::default() };
        let b = CycleStats { fetch: 10, exec: 5, data_mem: 70, cfu: 0, instret: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.total(), 285);
        assert_eq!(a.instret, 12);
        assert!((a.data_mem_share() - 100.0 / 285.0).abs() < 1e-12);
        assert!(a.cpi() > 0.0);
    }
}
