//! Bit-serial ALU model — the heart of SERV's area efficiency (§II-B).
//!
//! SERV processes one bit per clock: a 1-bit full adder with a carry
//! flip-flop, 1-bit logic gates, and serial comparison logic.  Every
//! operation here reports the serial cycles that datapath would consume
//! (one per bit, plus circulation cycles for shifts).
//!
//! Implementation note (EXPERIMENTS.md §Perf, L3 iteration 2): the
//! simulator originally computed each result with an explicit
//! 32-iteration bit loop.  That loop was the simulator's hottest code,
//! so the public functions now compute word-parallel results with
//! identical outputs *and identical cycle accounting*; the bit-by-bit
//! datapath lives on in [`bit_ref`] and a property test pins the two
//! implementations together on random operands.  The simulated machine
//! is unchanged — only the simulator got faster (~1.9x end to end).

/// Word width — one serial cycle per bit.
pub const BITS: u32 = 32;

/// Result of a serial ALU pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SerialResult {
    pub value: u32,
    /// Carry flip-flop state after the last bit (add/sub).
    pub carry: bool,
    /// Sign bit of the result (latched at bit 31).
    pub sign: bool,
    /// Serial cycles consumed.
    pub cycles: u32,
}

impl SerialResult {
    #[inline]
    fn word(value: u32, carry: bool) -> Self {
        SerialResult { value, carry, sign: value >> 31 == 1, cycles: BITS }
    }
}

/// Serial add with carry-in; `cin = true` + inverted `b` gives subtract,
/// exactly like SERV's single adder does both.
#[inline]
pub fn serial_add(a: u32, b: u32, cin: bool) -> SerialResult {
    let wide = a as u64 + b as u64 + cin as u64;
    SerialResult::word(wide as u32, wide >> 32 == 1)
}

/// a + b.
#[inline]
pub fn add(a: u32, b: u32) -> SerialResult {
    serial_add(a, b, false)
}

/// a - b  (add of !b with carry-in 1).
#[inline]
pub fn sub(a: u32, b: u32) -> SerialResult {
    serial_add(a, !b, true)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BitOp {
    And,
    Or,
    Xor,
}

/// Bitwise ops, one bit per cycle through the 1-bit logic unit.
#[inline]
pub fn bitwise(op: BitOp, a: u32, b: u32) -> SerialResult {
    let value = match op {
        BitOp::And => a & b,
        BitOp::Or => a | b,
        BitOp::Xor => a ^ b,
    };
    SerialResult::word(value, false)
}

/// Signed less-than via serial subtraction: lt = sign(a-b) XOR overflow,
/// both latched during the same 32-cycle pass.
#[inline]
pub fn slt(a: u32, b: u32) -> SerialResult {
    let r = sub(a, b);
    SerialResult { value: ((a as i32) < (b as i32)) as u32, carry: r.carry, sign: false, cycles: BITS }
}

/// Unsigned less-than: !carry after serial subtract.
#[inline]
pub fn sltu(a: u32, b: u32) -> SerialResult {
    let r = sub(a, b);
    SerialResult { value: (a < b) as u32, carry: r.carry, sign: false, cycles: BITS }
}

/// Serial equality: OR-reduction of per-bit XOR, one bit per cycle.
#[inline]
pub fn eq(a: u32, b: u32) -> SerialResult {
    SerialResult { value: (a == b) as u32, carry: false, sign: false, cycles: BITS }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShiftOp {
    Sll,
    Srl,
    Sra,
}

/// Serial shift: SERV circulates the value through the shift register;
/// a shift by `n` costs a full load pass plus `n` extra circulation
/// cycles (`BITS + n`).
#[inline]
pub fn shift(op: ShiftOp, a: u32, shamt: u32) -> SerialResult {
    let n = shamt & 0x1f;
    let value = match op {
        ShiftOp::Sll => a << n,
        ShiftOp::Srl => a >> n,
        ShiftOp::Sra => ((a as i32) >> n) as u32,
    };
    SerialResult { value, carry: false, sign: value >> 31 == 1, cycles: BITS + n }
}

/// The explicit bit-by-bit datapath — SERV's actual hardware structure,
/// kept as the reference the fast implementation is verified against
/// (and as documentation of what the cycle counts correspond to).
pub mod bit_ref {
    use super::{BitOp, SerialResult, ShiftOp, BITS};

    pub fn serial_add(a: u32, b: u32, cin: bool) -> SerialResult {
        let mut carry = cin;
        let mut value: u32 = 0;
        for i in 0..BITS {
            let ab = (a >> i) & 1 == 1;
            let bb = (b >> i) & 1 == 1;
            let sum = ab ^ bb ^ carry;
            carry = (ab && bb) || (ab && carry) || (bb && carry);
            if sum {
                value |= 1 << i;
            }
        }
        SerialResult { value, carry, sign: value >> 31 == 1, cycles: BITS }
    }

    pub fn bitwise(op: BitOp, a: u32, b: u32) -> SerialResult {
        let mut value = 0u32;
        for i in 0..BITS {
            let ab = (a >> i) & 1;
            let bb = (b >> i) & 1;
            let r = match op {
                BitOp::And => ab & bb,
                BitOp::Or => ab | bb,
                BitOp::Xor => ab ^ bb,
            };
            value |= r << i;
        }
        SerialResult { value, carry: false, sign: value >> 31 == 1, cycles: BITS }
    }

    pub fn slt(a: u32, b: u32) -> SerialResult {
        let r = serial_add(a, !b, true);
        let sa = a >> 31 == 1;
        let sb = b >> 31 == 1;
        let sr = r.value >> 31 == 1;
        let overflow = (sa != sb) && (sr != sa);
        let lt = sr != overflow;
        SerialResult { value: lt as u32, carry: r.carry, sign: false, cycles: BITS }
    }

    pub fn sltu(a: u32, b: u32) -> SerialResult {
        let r = serial_add(a, !b, true);
        SerialResult { value: (!r.carry) as u32, carry: r.carry, sign: false, cycles: BITS }
    }

    pub fn eq(a: u32, b: u32) -> SerialResult {
        let mut any_diff = false;
        for i in 0..BITS {
            any_diff |= ((a >> i) ^ (b >> i)) & 1 == 1;
        }
        SerialResult { value: (!any_diff) as u32, carry: false, sign: false, cycles: BITS }
    }

    pub fn shift(op: ShiftOp, a: u32, shamt: u32) -> SerialResult {
        let n = shamt & 0x1f;
        let mut value = a;
        for _ in 0..n {
            value = match op {
                ShiftOp::Sll => value << 1,
                ShiftOp::Srl => value >> 1,
                ShiftOp::Sra => ((value as i32) >> 1) as u32,
            };
        }
        SerialResult { value, carry: false, sign: value >> 31 == 1, cycles: BITS + n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    /// The fast word-parallel implementation must agree with the
    /// bit-by-bit reference datapath on every field of every op.
    #[test]
    fn fast_matches_bit_reference() {
        let mut rng = Pcg32::seeded(0xa1);
        for _ in 0..3000 {
            let a = rng.next_u32();
            let b = rng.next_u32();
            let cin = rng.below(2) == 1;
            assert_eq!(serial_add(a, b, cin), bit_ref::serial_add(a, b, cin));
            for op in [BitOp::And, BitOp::Or, BitOp::Xor] {
                assert_eq!(bitwise(op, a, b), bit_ref::bitwise(op, a, b));
            }
            assert_eq!(slt(a, b), bit_ref::slt(a, b));
            assert_eq!(sltu(a, b), bit_ref::sltu(a, b));
            assert_eq!(eq(a, b), bit_ref::eq(a, b));
            let s = rng.below(32);
            for op in [ShiftOp::Sll, ShiftOp::Srl, ShiftOp::Sra] {
                assert_eq!(shift(op, a, s), bit_ref::shift(op, a, s));
            }
        }
    }

    /// And both must agree with plain word arithmetic.
    #[test]
    fn serial_matches_parallel() {
        let mut rng = Pcg32::seeded(0xa2);
        for _ in 0..2000 {
            let a = rng.next_u32();
            let b = rng.next_u32();
            assert_eq!(add(a, b).value, a.wrapping_add(b));
            assert_eq!(sub(a, b).value, a.wrapping_sub(b));
            assert_eq!(bitwise(BitOp::And, a, b).value, a & b);
            assert_eq!(bitwise(BitOp::Or, a, b).value, a | b);
            assert_eq!(bitwise(BitOp::Xor, a, b).value, a ^ b);
            assert_eq!(slt(a, b).value, ((a as i32) < (b as i32)) as u32);
            assert_eq!(sltu(a, b).value, (a < b) as u32);
            assert_eq!(eq(a, b).value, (a == b) as u32);
            let s = rng.below(32);
            assert_eq!(shift(ShiftOp::Sll, a, s).value, a << s);
            assert_eq!(shift(ShiftOp::Srl, a, s).value, a >> s);
            assert_eq!(shift(ShiftOp::Sra, a, s).value, ((a as i32) >> s) as u32);
        }
    }

    #[test]
    fn carry_chain_edges() {
        assert_eq!(add(u32::MAX, 1).value, 0);
        assert!(add(u32::MAX, 1).carry);
        assert_eq!(sub(0, 1).value, u32::MAX);
        assert!(!sub(0, 1).carry); // borrow
        assert!(sub(5, 5).carry); // no borrow
    }

    #[test]
    fn slt_overflow_cases() {
        assert_eq!(slt(i32::MIN as u32, i32::MAX as u32).value, 1);
        assert_eq!(slt(i32::MAX as u32, i32::MIN as u32).value, 0);
        assert_eq!(slt(0xffff_ffff, 0).value, 1); // -1 < 0
        assert_eq!(sltu(0xffff_ffff, 0).value, 0);
    }

    #[test]
    fn cycle_counts() {
        assert_eq!(add(1, 2).cycles, 32);
        assert_eq!(shift(ShiftOp::Sll, 1, 0).cycles, 32);
        assert_eq!(shift(ShiftOp::Srl, 1, 31).cycles, 63);
        assert_eq!(bit_ref::shift(ShiftOp::Sra, 1, 31).cycles, 63);
    }
}
