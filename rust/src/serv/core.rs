//! The SERV instruction FSM: fetch → (modified) decode → serial execute.

use anyhow::{anyhow, bail, Result};

use crate::accel::CfuBank;
use crate::isa::{self, AluOp, BranchOp, Instr, LoadOp, StoreOp};

use super::alu::{self, BitOp, ShiftOp};
use super::timing::{CycleStats, TimingConfig};

/// Memory-side interface of the core (implemented by `soc::Memory`).
/// Latency is charged by the core from `TimingConfig`; the bus only
/// moves data and validates addresses.
pub trait Bus {
    fn fetch(&mut self, addr: u32) -> Result<u32>;
    /// size in {1, 2, 4}; returns zero-extended raw bits.
    fn load(&mut self, addr: u32, size: u8) -> Result<u32>;
    fn store(&mut self, addr: u32, value: u32, size: u8) -> Result<()>;
}

/// Program termination, signalled by `ecall`/`ebreak`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Exit {
    /// `ecall`: a0 carries the program's result value, a1 an optional
    /// auxiliary value (our bare-metal convention).
    Ecall { a0: u32, a1: u32 },
    Ebreak,
}

/// CFU handshake record for one accelerator instruction — enough to
/// render the Fig. 2 life-cycle trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CfuEvent {
    pub funct7: u8,
    pub funct3: u8,
    pub rs1: u32,
    pub rs2: u32,
    pub result: u32,
    pub compute_cycles: u64,
    pub wrote_rd: bool,
}

/// Per-step report.
#[derive(Debug, Clone, Copy)]
pub struct StepInfo {
    pub pc: u32,
    pub instr: Instr,
    pub cycles: u64,
    pub exit: Option<Exit>,
    pub cfu: Option<CfuEvent>,
}

/// Architectural state: 32 registers + PC.  (In RTL these are shift
/// registers; their serial access cost is what the 32-cycle execute
/// phases account for.)
///
/// `decode_cache` is a pure simulator optimisation (EXPERIMENTS.md
/// §Perf): decoding is memoised per PC, keyed by the raw fetched word,
/// so a hit is only valid while the instruction memory at that PC is
/// unchanged — self-modifying images degrade gracefully to re-decoding.
#[derive(Debug, Clone)]
pub struct ServCore {
    pub regs: [u32; 32],
    pub pc: u32,
    decode_cache: Vec<(u32, Instr)>,
}

/// Cache entries are (raw_word, decoded); this raw word never decodes
/// successfully, so it marks an empty slot.
const CACHE_EMPTY: u32 = 0xffff_ffff;

impl ServCore {
    pub fn new(pc: u32) -> Self {
        ServCore { regs: [0; 32], pc, decode_cache: Vec::new() }
    }

    /// Reset architectural state (registers + PC) for another run of
    /// the same image.  The decode cache is *kept*: entries are
    /// memoised against the raw fetched word, so they stay valid
    /// across runs and a re-armed core does not re-decode the image.
    pub fn reset(&mut self, pc: u32) {
        self.regs = [0; 32];
        self.pc = pc;
    }

    /// Decode-cache occupancy (tests pin that `reset` keeps it).
    #[cfg(test)]
    pub(crate) fn decode_cache_entries(&self) -> usize {
        self.decode_cache.iter().filter(|(raw, _)| *raw != CACHE_EMPTY).count()
    }

    #[inline]
    fn rd_write(&mut self, rd: u8, value: u32) {
        if rd != 0 {
            self.regs[rd as usize] = value;
        }
    }

    #[inline]
    fn r(&self, i: u8) -> u32 {
        self.regs[i as usize]
    }

    /// Execute one instruction; charge cycles into `stats`.
    pub fn step(
        &mut self,
        bus: &mut (impl Bus + ?Sized),
        cfus: &mut CfuBank,
        t: &TimingConfig,
        stats: &mut CycleStats,
    ) -> Result<StepInfo> {
        let pc = self.pc;
        if pc % 4 != 0 {
            bail!("misaligned PC {pc:#010x}");
        }
        // ---- fetch: one memory transaction per instruction ----
        let word = bus.fetch(pc)?;
        stats.fetch += t.fetch_cost();
        let slot = (pc / 4) as usize;
        let instr = match self.decode_cache.get(slot) {
            Some(&(raw, cached)) if raw == word => cached,
            _ => {
                let decoded = isa::decode(word)
                    .map_err(|e| anyhow!("at pc {pc:#010x} (word {word:#010x}): {e}"))?;
                if self.decode_cache.len() <= slot {
                    self.decode_cache.resize(slot + 1, (CACHE_EMPTY, Instr::Fence));
                }
                self.decode_cache[slot] = (word, decoded);
                decoded
            }
        };

        let mut cycles = t.fetch_cost();
        let mut exit = None;
        let mut cfu_event = None;
        let mut next_pc = pc.wrapping_add(4);

        macro_rules! exec {
            ($n:expr) => {{
                stats.exec += $n as u64;
                cycles += $n as u64;
            }};
        }

        match instr {
            Instr::Lui { rd, imm } => {
                // serial pass shifting the immediate into rd
                exec!(alu::BITS);
                self.rd_write(rd, imm as u32);
            }
            Instr::Auipc { rd, imm } => {
                let r = alu::add(pc, imm as u32);
                exec!(r.cycles);
                self.rd_write(rd, r.value);
            }
            Instr::Jal { rd, offset } => {
                let link = pc.wrapping_add(4);
                let r = alu::add(pc, offset as u32);
                exec!(r.cycles);
                self.rd_write(rd, link);
                next_pc = r.value;
            }
            Instr::Jalr { rd, rs1, offset } => {
                let link = pc.wrapping_add(4);
                let r = alu::add(self.r(rs1), offset as u32);
                exec!(r.cycles);
                self.rd_write(rd, link);
                next_pc = r.value & !1;
            }
            Instr::Branch { op, rs1, rs2, offset } => {
                let a = self.r(rs1);
                let b = self.r(rs2);
                let (taken, c) = match op {
                    BranchOp::Beq => {
                        let r = alu::eq(a, b);
                        (r.value == 1, r.cycles)
                    }
                    BranchOp::Bne => {
                        let r = alu::eq(a, b);
                        (r.value == 0, r.cycles)
                    }
                    BranchOp::Blt => {
                        let r = alu::slt(a, b);
                        (r.value == 1, r.cycles)
                    }
                    BranchOp::Bge => {
                        let r = alu::slt(a, b);
                        (r.value == 0, r.cycles)
                    }
                    BranchOp::Bltu => {
                        let r = alu::sltu(a, b);
                        (r.value == 1, r.cycles)
                    }
                    BranchOp::Bgeu => {
                        let r = alu::sltu(a, b);
                        (r.value == 0, r.cycles)
                    }
                };
                exec!(c);
                if taken {
                    // serial PC update pass
                    exec!(t.branch_taken_extra);
                    next_pc = pc.wrapping_add(offset as u32);
                }
            }
            Instr::Load { op, rd, rs1, offset } => {
                let a = alu::add(self.r(rs1), offset as u32); // serial EA calc
                exec!(a.cycles);
                let addr = a.value;
                let (size, signed) = match op {
                    LoadOp::Lb => (1, true),
                    LoadOp::Lbu => (1, false),
                    LoadOp::Lh => (2, true),
                    LoadOp::Lhu => (2, false),
                    LoadOp::Lw => (4, false),
                };
                let raw = bus.load(addr, size)?;
                stats.data_mem += t.load_cost();
                cycles += t.load_cost();
                stats.loads += 1;
                let value = if signed {
                    match size {
                        1 => raw as u8 as i8 as i32 as u32,
                        2 => raw as u16 as i16 as i32 as u32,
                        _ => raw,
                    }
                } else {
                    raw
                };
                // serial shift of the fetched word into rd
                exec!(t.load_shift_in);
                self.rd_write(rd, value);
            }
            Instr::Store { op, rs1, rs2, offset } => {
                let a = alu::add(self.r(rs1), offset as u32);
                exec!(a.cycles);
                let size = match op {
                    StoreOp::Sb => 1,
                    StoreOp::Sh => 2,
                    StoreOp::Sw => 4,
                };
                bus.store(a.value, self.r(rs2), size)?;
                stats.data_mem += t.store_cost();
                cycles += t.store_cost();
                stats.stores += 1;
            }
            Instr::OpImm { op, rd, rs1, imm } => {
                let r = self.alu_exec(op, self.r(rs1), imm as u32);
                exec!(r.cycles);
                self.rd_write(rd, r.value);
            }
            Instr::Op { op, rd, rs1, rs2 } => {
                let r = self.alu_exec(op, self.r(rs1), self.r(rs2));
                exec!(r.cycles);
                self.rd_write(rd, r.value);
            }
            Instr::Custom { funct7, funct3, rd, rs1, rs2 } => {
                // Fig. 2 handshake: init/rf_ready/valid edges, 32-cycle
                // serial operand transmission, accelerator compute,
                // 32-cycle result write-back (skipped for rd = x0).
                let a = self.r(rs1);
                let b = self.r(rs2);
                let cfu = cfus
                    .get_mut(funct7)
                    .ok_or_else(|| anyhow!("no CFU registered for funct7={funct7} at pc {pc:#010x}"))?;
                let out = cfu.execute(funct3, a, b)?;
                let wrote_rd = rd != 0;
                let mut c = t.cfu_setup + t.cfu_tx + out.compute_cycles;
                if wrote_rd {
                    c += t.cfu_wb;
                    self.rd_write(rd, out.value);
                }
                stats.cfu += c;
                cycles += c;
                stats.cfu_ops += 1;
                cfu_event = Some(CfuEvent {
                    funct7,
                    funct3,
                    rs1: a,
                    rs2: b,
                    result: out.value,
                    compute_cycles: out.compute_cycles,
                    wrote_rd,
                });
            }
            Instr::Fence => {
                exec!(alu::BITS);
            }
            Instr::Ecall => {
                exec!(alu::BITS);
                exit = Some(Exit::Ecall { a0: self.r(10), a1: self.r(11) });
            }
            Instr::Ebreak => {
                exec!(alu::BITS);
                exit = Some(Exit::Ebreak);
            }
        }

        self.pc = next_pc;
        stats.instret += 1;
        Ok(StepInfo { pc, instr, cycles, exit, cfu: cfu_event })
    }

    fn alu_exec(&self, op: AluOp, a: u32, b: u32) -> alu::SerialResult {
        match op {
            AluOp::Add => alu::add(a, b),
            AluOp::Sub => alu::sub(a, b),
            AluOp::And => alu::bitwise(BitOp::And, a, b),
            AluOp::Or => alu::bitwise(BitOp::Or, a, b),
            AluOp::Xor => alu::bitwise(BitOp::Xor, a, b),
            AluOp::Slt => alu::slt(a, b),
            AluOp::Sltu => alu::sltu(a, b),
            AluOp::Sll => alu::shift(ShiftOp::Sll, a, b & 0x1f),
            AluOp::Srl => alu::shift(ShiftOp::Srl, a, b & 0x1f),
            AluOp::Sra => alu::shift(ShiftOp::Sra, a, b & 0x1f),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::reg::*;
    use crate::isa::Asm;

    /// Trivial RAM-backed bus for core unit tests.
    pub struct TestRam(pub Vec<u8>);

    impl Bus for TestRam {
        fn fetch(&mut self, addr: u32) -> Result<u32> {
            self.load(addr, 4)
        }
        fn load(&mut self, addr: u32, size: u8) -> Result<u32> {
            let a = addr as usize;
            if a + size as usize > self.0.len() {
                bail!("load out of range {addr:#x}");
            }
            let mut v = 0u32;
            for i in 0..size as usize {
                v |= (self.0[a + i] as u32) << (8 * i);
            }
            Ok(v)
        }
        fn store(&mut self, addr: u32, value: u32, size: u8) -> Result<()> {
            let a = addr as usize;
            if a + size as usize > self.0.len() {
                bail!("store out of range {addr:#x}");
            }
            for i in 0..size as usize {
                self.0[a + i] = (value >> (8 * i)) as u8;
            }
            Ok(())
        }
    }

    fn run(asm: &Asm) -> (ServCore, CycleStats, Exit) {
        let mut img = asm.assemble_bytes().unwrap();
        img.resize(img.len() + 4096, 0);
        let mut ram = TestRam(img);
        let mut core = ServCore::new(0);
        let mut cfus = CfuBank::new();
        let t = TimingConfig::ideal_mem();
        let mut stats = CycleStats::default();
        for _ in 0..100_000 {
            let info = core.step(&mut ram, &mut cfus, &t, &mut stats).unwrap();
            if let Some(e) = info.exit {
                return (core, stats, e);
            }
        }
        panic!("program did not terminate");
    }

    #[test]
    fn arithmetic_program() {
        let mut a = Asm::new(0);
        a.li(A0, 21);
        a.li(A1, 2);
        a.add(A0, A0, A1); // 23
        a.slli(A0, A0, 4); // 368
        a.addi(A0, A0, -68); // 300
        a.ecall();
        let (_, stats, e) = run(&a);
        assert_eq!(e, Exit::Ecall { a0: 300, a1: 2 });
        assert!(stats.instret >= 6);
        // every retired instruction paid a fetch and ≥32 exec cycles
        assert!(stats.exec >= stats.instret * 32);
    }

    #[test]
    fn memory_roundtrip_and_loop() {
        let mut a = Asm::new(0);
        // sum = 1+2+...+5 stored/reloaded through memory each iteration
        a.la(S0, "buf");
        a.li(T0, 5);
        a.li(T1, 0);
        a.label("loop");
        a.add(T1, T1, T0);
        a.sw(S0, T1, 0);
        a.lw(T1, S0, 0);
        a.addi(T0, T0, -1);
        a.bne(T0, ZERO, "loop");
        a.mv(A0, T1);
        a.ecall();
        a.label("buf");
        a.zeros(1);
        let (_, stats, e) = run(&a);
        assert_eq!(e, Exit::Ecall { a0: 15, a1: 0 });
        assert_eq!(stats.loads, 5);
        assert_eq!(stats.stores, 5);
    }

    #[test]
    fn byte_halfword_sign_extension() {
        let mut a = Asm::new(0);
        a.la(S0, "buf");
        a.li(T0, 0xFF);
        a.sb(S0, T0, 0);
        a.lb(A0, S0, 0); // sign-extended -1
        a.lbu(A1, S0, 0); // 255
        a.ecall();
        a.label("buf");
        a.zeros(1);
        let (_, _, e) = run(&a);
        assert_eq!(e, Exit::Ecall { a0: 0xffff_ffff, a1: 255 });
    }

    #[test]
    fn function_call_and_return() {
        let mut a = Asm::new(0);
        a.li(A0, 7);
        a.call("double");
        a.call("double");
        a.ecall(); // 28
        a.label("double");
        a.add(A0, A0, A0);
        a.ret();
        let (_, _, e) = run(&a);
        assert_eq!(e, Exit::Ecall { a0: 28, a1: 0 });
    }

    #[test]
    fn branch_taken_costs_more() {
        let t = TimingConfig::ideal_mem();
        // taken branch
        let mut a1 = Asm::new(0);
        a1.beq(ZERO, ZERO, "t");
        a1.label("t");
        a1.ecall();
        // not-taken branch
        let mut a2 = Asm::new(0);
        a2.bne(ZERO, ZERO, "t");
        a2.label("t");
        a2.ecall();
        let run1 = |a: &Asm| {
            let mut img = a.assemble_bytes().unwrap();
            img.resize(1024, 0);
            let mut ram = TestRam(img);
            let mut core = ServCore::new(0);
            let mut cfus = CfuBank::new();
            let mut stats = CycleStats::default();
            core.step(&mut ram, &mut cfus, &t, &mut stats).unwrap();
            stats.total()
        };
        assert_eq!(run1(&a1), run1(&a2) + t.branch_taken_extra);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let mut a = Asm::new(0);
        a.li(T0, 99);
        a.add(ZERO, T0, T0);
        a.mv(A0, ZERO);
        a.ecall();
        let (_, _, e) = run(&a);
        assert_eq!(e, Exit::Ecall { a0: 0, a1: 0 });
    }

    #[test]
    fn unknown_cfu_errors() {
        let mut a = Asm::new(0);
        a.cfu(5, 0, A0, A1, A2);
        let img = {
            let mut b = a.assemble_bytes().unwrap();
            b.resize(64, 0);
            b
        };
        let mut ram = TestRam(img);
        let mut core = ServCore::new(0);
        let mut cfus = CfuBank::new();
        let mut stats = CycleStats::default();
        let err = core
            .step(&mut ram, &mut cfus, &TimingConfig::ideal_mem(), &mut stats)
            .unwrap_err();
        assert!(err.to_string().contains("no CFU registered"));
    }

    #[test]
    fn srai_on_negative() {
        let mut a = Asm::new(0);
        a.li(A0, -64);
        a.srai(A0, A0, 3);
        a.ecall();
        let (_, _, e) = run(&a);
        assert_eq!(e, Exit::Ecall { a0: (-8i32) as u32, a1: 0 });
    }
}
