//! Inference coordinator: request router + dynamic batcher + serving
//! loop over pluggable backends (Python is never on this path).
//!
//! Shape (vLLM-router-like, scaled to this paper's workload): client
//! threads submit `(config, features)` requests through a bounded
//! channel; the dispatcher thread routes them into per-config queues,
//! flushes a queue when it reaches `batch_max` or its oldest request
//! exceeds `linger`, executes the batch on the engine, and answers
//! each request through its response channel.
//!
//! The serving loop is backend-agnostic: execution, simulated-hardware
//! accounting, baseline calibration and engine statistics all flow
//! through [`crate::engine::Engine`] (see that module for the in-tree
//! `native`/`accel`/`pjrt` engines), and per-sample failure isolation
//! is universal — a bad request fails alone instead of poisoning its
//! batchmates.  Servers are built with [`Server::builder`]:
//!
//! ```no_run
//! use flexsvm::coordinator::{Backend, Server};
//! # fn main() -> anyhow::Result<()> {
//! let server = Server::builder()
//!     .artifacts(flexsvm::svm::model::artifacts_root(), ["iris_ovr_w4"])
//!     .backend(Backend::Accel)
//!     .batch_max(32)
//!     .linger(std::time::Duration::from_micros(500))
//!     .start()?;
//! let client = server.client();
//! let resp = client.infer("iris_ovr_w4", &[5, 1, 3, 0])?;
//! println!("pred {} (sim {:?})", resp.pred, resp.sim);
//! server.shutdown()?;
//! # Ok(())
//! # }
//! ```

pub mod metrics;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::{batch_error, BatchCtx, Engine, FarmEngine, ModelSource, NativeEngine};
use crate::farm::FarmOpts;
use crate::obs::{Obs, ObsOpts, Span, Stage, TraceId};
use crate::svm::model::Manifest;
use crate::svm::QuantModel;

pub use crate::engine::{Backend, EngineMetrics, ServeError, SimCost};

use metrics::ConfigMetrics;

/// Identity of one served config: the key plus the model-family facts
/// the wire front reports per config in `/healthz` (ISSUE 8).  Fields
/// are empty/zero when the model source doesn't know them (keys-only
/// engines, e.g. remote shards that own their models).
#[derive(Debug, Clone)]
pub struct ServedConfig {
    pub key: String,
    /// `"linear"` / `"rbf"` / `"poly"`; empty when unknown.
    pub kernel: String,
    /// Weight bit-width; 0 when unknown.
    pub bits: u8,
}

/// A single inference answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub pred: i32,
    /// The request's trace id — minted at ingress, or carried in from
    /// the wire ([`Client::submit_traced`]).
    pub trace: TraceId,
    /// Queue + execute time observed by the server.
    pub latency: Duration,
    /// How many samples shared the executed batch.
    pub batch_size: usize,
    /// Simulated cycles + energy (None on wall-clock-only engines).
    pub sim: Option<SimCost>,
    /// Full span tree with per-stage timings.  Populated only for
    /// explicitly-traced requests (`submit_traced`); plain traffic pays
    /// no span-assembly cost on the response path.
    pub span: Option<Box<Span>>,
}

struct Request {
    key: String,
    features: Vec<i32>,
    enqueued: Instant,
    /// When the dispatcher routed the request into its per-config
    /// queue (`queue_wait` ends, `batch_linger` begins).
    routed: Option<Instant>,
    trace: TraceId,
    /// Wire-carried trace: the caller wants the span tree back.
    explicit: bool,
    resp: mpsc::SyncSender<Result<Response, ServeError>>,
}

fn make_request(
    key: &str,
    features: &[i32],
    trace: TraceId,
    explicit: bool,
) -> (Request, mpsc::Receiver<Result<Response, ServeError>>) {
    let (tx, rx) = mpsc::sync_channel(1);
    let req = Request {
        key: key.to_string(),
        features: features.to_vec(),
        enqueued: Instant::now(),
        routed: None,
        trace,
        explicit,
        resp: tx,
    };
    (req, rx)
}

enum Msg {
    Req(Request),
    Snapshot(mpsc::SyncSender<HashMap<String, ConfigMetrics>>),
    EngineSnapshot(mpsc::SyncSender<EngineMetrics>),
    Shutdown,
}

/// An in-flight request handle from [`Client::submit`]; redeem it with
/// [`Pending::wait`] (or poll with [`Pending::try_wait`]).
///
/// The answer is delivered at most once: after `try_wait` returns
/// `Some`, the handle is spent — later `try_wait` calls return `None`
/// and `wait` reports the request as dropped.
pub struct Pending {
    rx: mpsc::Receiver<Result<Response, ServeError>>,
    taken: bool,
}

impl Pending {
    /// Block until the answer arrives.
    pub fn wait(self) -> Result<Response, ServeError> {
        if self.taken {
            return Err(ServeError::Dropped);
        }
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::Dropped),
        }
    }

    /// Non-blocking poll: `None` while the answer is still in flight
    /// (or after it was already taken).
    pub fn try_wait(&mut self) -> Option<Result<Response, ServeError>> {
        if self.taken {
            return None;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.taken = true;
                Some(r)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.taken = true;
                Some(Err(ServeError::Dropped))
            }
        }
    }
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::SyncSender<Msg>,
    obs: Arc<Obs>,
}

impl Client {
    /// Non-blocking submit: enqueue the request (subject to ingress
    /// backpressure) and return a [`Pending`] handle for the answer.
    pub fn submit(&self, key: &str, features: &[i32]) -> Result<Pending, ServeError> {
        let (req, rx) = make_request(key, features, self.obs.next_trace(), false);
        self.tx.send(Msg::Req(req)).map_err(|_| ServeError::ServerDown)?;
        Ok(Pending { rx, taken: false })
    }

    /// Submit under a caller-supplied trace id (one carried in from
    /// the wire).  The answer's [`Response::span`] holds the full span
    /// tree, so a remote coordinator can graft it into its own trace.
    pub fn submit_traced(
        &self,
        key: &str,
        features: &[i32],
        trace: TraceId,
    ) -> Result<Pending, ServeError> {
        let (req, rx) = make_request(key, features, trace, true);
        self.tx.send(Msg::Req(req)).map_err(|_| ServeError::ServerDown)?;
        Ok(Pending { rx, taken: false })
    }

    /// Admission-controlled submit: like [`submit`](Self::submit), but
    /// when the bounded ingress queue is full the request is shed with
    /// [`ServeError::Overloaded`] instead of blocking the caller.  The
    /// wire front (`net::server`) uses this to answer
    /// `503 + Retry-After` under saturation rather than stalling the
    /// socket.
    pub fn try_submit(&self, key: &str, features: &[i32]) -> Result<Pending, ServeError> {
        let (req, rx) = make_request(key, features, self.obs.next_trace(), false);
        self.tx.try_send(Msg::Req(req)).map_err(try_send_error)?;
        Ok(Pending { rx, taken: false })
    }

    /// [`try_submit`](Self::try_submit) under a caller-supplied trace
    /// id — the admission-controlled twin of
    /// [`submit_traced`](Self::submit_traced).
    pub fn try_submit_traced(
        &self,
        key: &str,
        features: &[i32],
        trace: TraceId,
    ) -> Result<Pending, ServeError> {
        let (req, rx) = make_request(key, features, trace, true);
        self.tx.try_send(Msg::Req(req)).map_err(try_send_error)?;
        Ok(Pending { rx, taken: false })
    }

    /// The observability store behind this server (trace ring +
    /// per-stage histograms).
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// Blocking single inference.
    pub fn infer(&self, key: &str, features: &[i32]) -> Result<Response, ServeError> {
        self.submit(key, features)?.wait()
    }

    /// Submit a whole batch for one config, then wait for every
    /// answer; per-sample results come back in input order.
    pub fn infer_many(
        &self,
        key: &str,
        xs: &[Vec<i32>],
    ) -> Result<Vec<Result<Response, ServeError>>, ServeError> {
        let handles: Vec<Pending> =
            xs.iter().map(|x| self.submit(key, x)).collect::<Result<_, _>>()?;
        Ok(handles.into_iter().map(Pending::wait).collect())
    }

    /// Per-config serving metrics snapshot.
    pub fn metrics(&self) -> Result<HashMap<String, ConfigMetrics>, ServeError> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx.send(Msg::Snapshot(tx)).map_err(|_| ServeError::ServerDown)?;
        rx.recv().map_err(|_| ServeError::Dropped)
    }

    /// Non-blocking [`metrics`](Self::metrics): sheds with
    /// [`ServeError::Overloaded`] when the bounded ingress is full, and
    /// again when the answer does not arrive within [`PROBE_TIMEOUT`]
    /// (deep backlog ahead of the probe) — so the wire front's
    /// `/v1/metrics` and `/healthz` never park a socket worker behind
    /// the serving queue.
    pub fn try_metrics(&self) -> Result<HashMap<String, ConfigMetrics>, ServeError> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx.try_send(Msg::Snapshot(tx)).map_err(try_send_error)?;
        recv_probe(&rx)
    }

    /// Engine statistics snapshot ([`Engine::snapshot`]).
    pub fn engine_metrics(&self) -> Result<EngineMetrics, ServeError> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx.send(Msg::EngineSnapshot(tx)).map_err(|_| ServeError::ServerDown)?;
        rx.recv().map_err(|_| ServeError::Dropped)
    }

    /// Non-blocking [`engine_metrics`](Self::engine_metrics) — same
    /// shedding contract as [`try_metrics`](Self::try_metrics).
    pub fn try_engine_metrics(&self) -> Result<EngineMetrics, ServeError> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx.try_send(Msg::EngineSnapshot(tx)).map_err(try_send_error)?;
        recv_probe(&rx)
    }
}

/// How long a `try_*` probe waits for its answer before shedding.
const PROBE_TIMEOUT: Duration = Duration::from_secs(1);

fn recv_probe<T>(rx: &mpsc::Receiver<T>) -> Result<T, ServeError> {
    match rx.recv_timeout(PROBE_TIMEOUT) {
        Ok(v) => Ok(v),
        // the probe is queued behind a deep backlog: shed it (the
        // dispatcher's late answer lands in a dropped channel, which
        // it tolerates)
        Err(mpsc::RecvTimeoutError::Timeout) => Err(ServeError::Overloaded),
        Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServeError::Dropped),
    }
}

fn try_send_error(e: mpsc::TrySendError<Msg>) -> ServeError {
    match e {
        mpsc::TrySendError::Full(_) => ServeError::Overloaded,
        mpsc::TrySendError::Disconnected(_) => ServeError::ServerDown,
    }
}

/// Running server handle.  Prefer an explicit [`Server::shutdown`] —
/// it surfaces a dispatcher panic as an error; plain `drop` only logs
/// it to stderr.
pub struct Server {
    tx: mpsc::SyncSender<Msg>,
    keys: Vec<String>,
    served: Vec<ServedConfig>,
    obs: Arc<Obs>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Fluent construction — see [`ServerBuilder`].
    pub fn builder() -> ServerBuilder {
        ServerBuilder::default()
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone(), obs: Arc::clone(&self.obs) }
    }

    /// The observability store (trace ring + per-stage histograms)
    /// every request through this server reports into.
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// The config keys this server was started with (the served set).
    pub fn keys(&self) -> &[String] {
        &self.keys
    }

    /// The served set with model identity: kernel family + bit-width
    /// per config (what `/healthz` reports on the wire).
    pub fn served_configs(&self) -> &[ServedConfig] {
        &self.served
    }

    /// Drain queued work, stop the dispatcher and join it.  A
    /// dispatcher panic (engine bug, poisoned lock, ...) is returned
    /// here with its payload instead of vanishing.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        join_dispatcher(&mut self.join)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Err(e) = join_dispatcher(&mut self.join) {
            eprintln!("flexsvm coordinator: {e:#} (use Server::shutdown() to handle this)");
        }
    }
}

fn join_dispatcher(join: &mut Option<std::thread::JoinHandle<()>>) -> Result<()> {
    match join.take() {
        None => Ok(()),
        Some(j) => match j.join() {
            Ok(()) => Ok(()),
            Err(payload) => Err(anyhow!("dispatcher thread panicked: {}", panic_message(&payload))),
        },
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

// ------------------------------------------------------------ builder

enum Source {
    Unset,
    Artifacts { root: PathBuf, keys: Vec<String> },
    Models(Vec<(String, QuantModel)>),
    Keys(Vec<String>),
}

/// Fluent server construction: pick a model source
/// ([`artifacts`](Self::artifacts), [`models`](Self::models), or bare
/// [`keys`](Self::keys) for engines that own their models), pick an
/// engine ([`backend`](Self::backend) for the in-tree kinds or
/// [`engine`](Self::engine) for anything implementing
/// [`crate::engine::Engine`]), tune the batcher, then
/// [`start`](Self::start).
pub struct ServerBuilder {
    source: Source,
    engine: Option<Box<dyn Engine>>,
    backend: Backend,
    batch_max: usize,
    compiled_batch: usize,
    linger: Duration,
    queue_cap: usize,
    eager_flush: bool,
    farm: FarmOpts,
    obs: ObsOpts,
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder {
            source: Source::Unset,
            engine: None,
            backend: Backend::Native,
            batch_max: 64,
            compiled_batch: 64,
            linger: Duration::from_millis(2),
            queue_cap: 1024,
            eager_flush: true,
            farm: FarmOpts::default(),
            obs: ObsOpts::default(),
        }
    }
}

impl ServerBuilder {
    /// Serve the given config keys of an on-disk artifact tree.
    pub fn artifacts<I, S>(mut self, root: impl Into<PathBuf>, keys: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.source = Source::Artifacts {
            root: root.into(),
            keys: keys.into_iter().map(Into::into).collect(),
        };
        self
    }

    /// Serve in-memory models (no artifacts on disk required).
    pub fn models(mut self, models: Vec<(String, QuantModel)>) -> Self {
        self.source = Source::Models(models);
        self
    }

    /// Serve bare config keys — for engines that own their models
    /// (mocks, remote shards).
    pub fn keys<I, S>(mut self, keys: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.source = Source::Keys(keys.into_iter().map(Into::into).collect());
        self
    }

    /// Pick an in-tree engine kind (ignored when [`engine`](Self::engine)
    /// supplies a custom one).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Plug in a custom engine.
    pub fn engine(mut self, engine: Box<dyn Engine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Max samples per flushed batch (≤ the compiled batch size).
    pub fn batch_max(mut self, n: usize) -> Self {
        self.batch_max = n;
        self
    }

    /// Compiled batch size to load (PJRT: from the manifest's batch set).
    pub fn compiled_batch(mut self, n: usize) -> Self {
        self.compiled_batch = n;
        self
    }

    /// How long a request may wait for batchmates.
    pub fn linger(mut self, d: Duration) -> Self {
        self.linger = d;
        self
    }

    /// Bound of the ingress queue (backpressure).
    pub fn queue_cap(mut self, n: usize) -> Self {
        self.queue_cap = n;
        self
    }

    /// Flush as soon as the ingress channel drains (EXPERIMENTS.md
    /// §Perf, L3 iteration 5): whatever arrived together is batched
    /// together, and nobody waits out the linger against an idle
    /// channel.  The linger then only bounds worst-case wait under
    /// sustained load.
    pub fn eager_flush(mut self, on: bool) -> Self {
        self.eager_flush = on;
        self
    }

    /// Farm knobs (`Backend::Accel` only).
    pub fn farm(mut self, opts: FarmOpts) -> Self {
        self.farm = opts;
        self
    }

    /// Observability knobs: trace sampling rate and retention-ring
    /// capacity (see [`ObsOpts`]).
    pub fn obs_opts(mut self, opts: ObsOpts) -> Self {
        self.obs = opts;
        self
    }

    /// Validate, spawn the dispatcher, warm the engine, and return the
    /// running server.  Fails fast — bad configs, an unloadable
    /// manifest or an engine warm-up error all surface here, before
    /// any traffic is accepted.
    pub fn start(self) -> Result<Server> {
        if self.batch_max == 0 {
            bail!("batch_max must be >= 1");
        }
        let (source, keys) = match self.source {
            Source::Unset => bail!("ServerBuilder needs .artifacts(..), .models(..) or .keys(..)"),
            Source::Artifacts { root, keys } => {
                // fail fast on bad configs before spawning
                let manifest = Manifest::load(&root)?;
                for k in &keys {
                    manifest.config(k)?;
                }
                (ModelSource::Artifacts(manifest), keys)
            }
            Source::Models(models) => {
                if models.is_empty() {
                    bail!("no models to serve");
                }
                let keys: Vec<String> = models.iter().map(|(k, _)| k.clone()).collect();
                let mut map = HashMap::new();
                for (k, m) in models {
                    if map.insert(k.clone(), m).is_some() {
                        bail!("duplicate config key {k:?}");
                    }
                }
                (ModelSource::Inline(map), keys)
            }
            Source::Keys(keys) => {
                if keys.is_empty() {
                    bail!("no config keys to serve");
                }
                (ModelSource::None, keys)
            }
        };
        let engine: Box<dyn Engine> = match self.engine {
            Some(e) => e,
            None => match self.backend {
                Backend::Native => Box::new(NativeEngine::new()),
                Backend::Accel => Box::new(FarmEngine::new(self.farm)),
                #[cfg(feature = "pjrt")]
                Backend::Pjrt => {
                    // PJRT-specific constraint, checked where the
                    // compiled batch actually matters
                    if self.batch_max > self.compiled_batch {
                        bail!("batch_max must be <= compiled_batch for the pjrt backend");
                    }
                    Box::new(crate::engine::PjrtEngine::new(self.compiled_batch))
                }
                #[cfg(not(feature = "pjrt"))]
                Backend::Pjrt => bail!("Backend::Pjrt requires building with `--features pjrt`"),
            },
        };
        let tuning = Tuning {
            batch_max: self.batch_max,
            linger: self.linger,
            eager_flush: self.eager_flush,
        };
        // model identity (kernel family + bit-width) per served config,
        // resolved while the source is still on this side — the
        // dispatcher stamps it into ConfigMetrics, /healthz reports it
        let served: Vec<ServedConfig> = keys
            .iter()
            .map(|k| {
                let (kernel, bits) = match &source {
                    ModelSource::Artifacts(man) => man
                        .config(k)
                        .map(|c| (c.kernel.to_string(), c.bits))
                        .unwrap_or_default(),
                    ModelSource::Inline(map) => {
                        map.get(k).map(|m| (m.kernel.to_string(), m.bits)).unwrap_or_default()
                    }
                    ModelSource::None => Default::default(),
                };
                ServedConfig { key: k.clone(), kernel, bits }
            })
            .collect();
        let meta: HashMap<String, (String, u8)> = served
            .iter()
            .filter(|s| !s.kernel.is_empty())
            .map(|s| (s.key.clone(), (s.kernel.clone(), s.bits)))
            .collect();
        let (tx, rx) = mpsc::sync_channel::<Msg>(self.queue_cap);
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let served_keys = keys.clone();
        let obs = Arc::new(Obs::new(self.obs));
        let obs_dispatch = Arc::clone(&obs);
        let join = std::thread::Builder::new()
            .name("flexsvm-dispatcher".into())
            .spawn(move || dispatcher(engine, source, keys, meta, tuning, obs_dispatch, rx, ready_tx))?;
        ready_rx.recv().context("dispatcher died during init")??;
        Ok(Server { tx, keys: served_keys, served, obs, join: Some(join) })
    }
}

// ---------------------------------------------------------- dispatcher

#[derive(Clone, Copy)]
struct Tuning {
    batch_max: usize,
    linger: Duration,
    eager_flush: bool,
}

/// Receive timeout while no request is queued (nothing to linger on).
const IDLE_POLL: Duration = Duration::from_millis(50);

/// The metrics slot for a config, stamped with its model identity
/// (kernel family + bit-width) on first touch.
fn stat_entry<'a>(
    stats: &'a mut HashMap<String, ConfigMetrics>,
    key: &str,
    meta: &HashMap<String, (String, u8)>,
) -> &'a mut ConfigMetrics {
    let m = stats.entry(key.to_string()).or_insert_with(ConfigMetrics::new);
    if m.kernel.is_empty() {
        if let Some((kernel, bits)) = meta.get(key) {
            m.kernel = kernel.clone();
            m.bits = *bits;
        }
    }
    m
}

/// Execute one queued batch on the engine and answer every request.
/// Per-sample isolation is universal: a failed sample answers its own
/// request with the engine's error while its batchmates succeed.
///
/// Stage accounting: every measured stage is a disjoint sub-interval
/// of `[enqueued, answered]` — `queue_wait` (ingress channel), then
/// `batch_linger` (per-config queue), then whatever the engine
/// reported ([`crate::engine::Sample::stages`]) — and `dispatch` is
/// the residual, so the stage sum never exceeds the end-to-end
/// latency.
fn flush(
    engine: &dyn Engine,
    key: &str,
    q: &mut Vec<Request>,
    stats: &mut HashMap<String, ConfigMetrics>,
    meta: &HashMap<String, (String, u8)>,
    obs: &Obs,
) {
    if q.is_empty() {
        return;
    }
    let pending: Vec<Request> = std::mem::take(q);
    let xs: Vec<Vec<i32>> = pending.iter().map(|r| r.features.clone()).collect();
    let traces: Vec<TraceId> = pending.iter().map(|r| r.trace).collect();
    let t_exec = Instant::now();
    let mut answers = engine.run_batch_ctx(key, &xs, &BatchCtx { traces: &traces });
    let exec_us = t_exec.elapsed().as_micros() as u64;
    if answers.len() != pending.len() {
        // a misbehaving engine must not leave requests unanswered —
        // and a wrong-length reply makes every answer's attribution
        // suspect, so the whole batch fails
        let msg = format!("engine answered {} samples for a batch of {}", answers.len(), pending.len());
        answers = batch_error(pending.len(), ServeError::Engine(msg));
    }
    let m = stat_entry(stats, key, meta);
    m.batches += 1;
    m.batched_samples += pending.len() as u64;
    if let Some(b) = engine.baseline_cycles(key) {
        m.baseline_cycles_per_inf = b;
    }
    for (req, answer) in pending.into_iter().zip(answers) {
        let latency = req.enqueued.elapsed();
        match answer {
            Ok(s) => {
                if let Some(sim) = s.sim {
                    m.sim_samples += 1;
                    m.sim_cycles += sim.cycles;
                    m.energy_mj += sim.energy_mj;
                }
                if let Some(h) = m.latency.as_mut() {
                    h.record(latency);
                }
                let total_us = latency.as_micros() as u64;
                let routed = req.routed.unwrap_or(req.enqueued);
                let mut stages = s.stages;
                if stages.is_empty() {
                    // no engine-side breakdown (native/pjrt/mock):
                    // charge the whole engine call to `execute`
                    stages.set(Stage::Execute, exec_us);
                }
                stages.set(
                    Stage::QueueWait,
                    routed.saturating_duration_since(req.enqueued).as_micros() as u64,
                );
                stages.set(
                    Stage::BatchLinger,
                    t_exec.saturating_duration_since(routed).as_micros() as u64,
                );
                stages.set(Stage::Dispatch, total_us.saturating_sub(stages.sum_us()));
                obs.slo_record(key, true, latency);
                let sampled = obs.observe(key, &stages, latency);
                let span = if sampled || req.explicit {
                    let mut sp = Span::new(req.trace, key);
                    sp.total_us = total_us;
                    sp.stages = stages;
                    sp.mode = s.mode.map(str::to_string);
                    if let Some(sim) = s.sim {
                        sp.cycles = Some(sim.cycles);
                        sp.energy_mj = Some(sim.energy_mj);
                    }
                    if let Some(child) = s.child {
                        sp.children.push(*child);
                    }
                    Some(sp)
                } else {
                    None
                };
                if sampled {
                    obs.keep(span.clone().expect("sampled implies span"));
                }
                let _ = req.resp.send(Ok(Response {
                    pred: s.pred,
                    trace: req.trace,
                    latency,
                    batch_size: xs.len(),
                    sim: s.sim,
                    span: if req.explicit { span.map(Box::new) } else { None },
                }));
            }
            Err(e) => {
                obs.slo_record(key, false, latency);
                let _ = req.resp.send(Err(e));
            }
        }
    }
}

fn dispatcher(
    mut engine: Box<dyn Engine>,
    source: ModelSource,
    keys: Vec<String>,
    meta: HashMap<String, (String, u8)>,
    tuning: Tuning,
    obs: Arc<Obs>,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    // AOT: compile/load everything up front — no first-request jank
    match engine.warm(&source, &keys) {
        Ok(()) => {
            let _ = ready.send(Ok(()));
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    }
    drop(source); // models are resident in the engine now
    let engine: &dyn Engine = engine.as_ref();

    let mut queues: HashMap<String, Vec<Request>> = HashMap::new();
    let mut stats: HashMap<String, ConfigMetrics> = HashMap::new();

    loop {
        // deadline of the oldest pending request across queues
        let now = Instant::now();
        let next_deadline = queues
            .values()
            .filter_map(|q| q.first())
            .map(|r| r.enqueued + tuning.linger)
            .min();
        let timeout =
            next_deadline.map(|d| d.saturating_duration_since(now)).unwrap_or(IDLE_POLL);

        match rx.recv_timeout(timeout) {
            Ok(Msg::Req(req)) => {
                // drain everything already in flight so co-arriving
                // requests land in the same batch
                let mut pending = vec![Msg::Req(req)];
                while let Ok(m) = rx.try_recv() {
                    pending.push(m);
                }
                let mut shutdown = false;
                for msg in pending {
                    match msg {
                        Msg::Req(mut req) => {
                            if !queues.contains_key(&req.key) && !keys.iter().any(|k| *k == req.key) {
                                let _ = req
                                    .resp
                                    .send(Err(ServeError::UnknownConfig(req.key.clone())));
                                continue;
                            }
                            req.routed = Some(Instant::now());
                            let m = stat_entry(&mut stats, &req.key, &meta);
                            m.requests += 1;
                            let q = queues.entry(req.key.clone()).or_default();
                            q.push(req);
                            if q.len() >= tuning.batch_max {
                                let key = q[0].key.clone();
                                let mut taken = std::mem::take(queues.get_mut(&key).unwrap());
                                flush(engine, &key, &mut taken, &mut stats, &meta, &obs);
                            }
                        }
                        Msg::Snapshot(tx) => {
                            let _ = tx.send(stats.clone());
                        }
                        Msg::EngineSnapshot(tx) => {
                            let _ = tx.send(engine.snapshot());
                        }
                        Msg::Shutdown => shutdown = true,
                    }
                }
                if tuning.eager_flush {
                    // channel is drained: everything queued goes out now
                    let due: Vec<String> =
                        queues.iter().filter(|(_, q)| !q.is_empty()).map(|(k, _)| k.clone()).collect();
                    for key in due {
                        let mut taken = std::mem::take(queues.get_mut(&key).unwrap());
                        flush(engine, &key, &mut taken, &mut stats, &meta, &obs);
                    }
                }
                if shutdown {
                    for (key, mut q) in std::mem::take(&mut queues) {
                        flush(engine, &key, &mut q, &mut stats, &meta, &obs);
                    }
                    return;
                }
            }
            Ok(Msg::Snapshot(tx)) => {
                let _ = tx.send(stats.clone());
            }
            Ok(Msg::EngineSnapshot(tx)) => {
                let _ = tx.send(engine.snapshot());
            }
            Ok(Msg::Shutdown) => {
                for (key, mut q) in std::mem::take(&mut queues) {
                    flush(engine, &key, &mut q, &mut stats, &meta, &obs);
                }
                return;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // flush queues whose oldest request exceeded the linger
                let now = Instant::now();
                let due: Vec<String> = queues
                    .iter()
                    .filter(|(_, q)| {
                        q.first().map(|r| now >= r.enqueued + tuning.linger).unwrap_or(false)
                    })
                    .map(|(k, _)| k.clone())
                    .collect();
                for key in due {
                    let mut taken = std::mem::take(queues.get_mut(&key).unwrap());
                    flush(engine, &key, &mut taken, &mut stats, &meta, &obs);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for (key, mut q) in std::mem::take(&mut queues) {
                    flush(engine, &key, &mut q, &mut stats, &meta, &obs);
                }
                return;
            }
        }
    }
}

// Integration tests live in rust/tests/coordinator.rs: MockEngine
// covers batching/linger/backpressure/failure-isolation with no
// artifacts, Native/Accel run against in-memory models, and the PJRT
// and artifact-backed paths skip gracefully when artifacts are absent.
