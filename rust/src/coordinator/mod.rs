//! Inference coordinator: request router + dynamic batcher + serving
//! loop over three interchangeable backends (Python is never on this
//! path).
//!
//! Shape (vLLM-router-like, scaled to this paper's workload): client
//! threads submit `(config, features)` requests through a bounded
//! channel; the dispatcher thread routes them into per-config queues,
//! flushes a queue when it reaches `batch_max` or its oldest request
//! exceeds `linger`, executes the batch on the backend, and answers
//! each request through its response channel.
//!
//! Backends:
//!
//!  * [`Backend::Pjrt`] — AOT-compiled HLO on the PJRT CPU client
//!    (`pjrt` cargo feature).  The client is not `Send`, so the engine
//!    lives on the dispatcher thread — batching, not parallel
//!    dispatch, is where CPU-PJRT throughput comes from.
//!  * [`Backend::Native`] — pure-Rust integer inference (differential
//!    testing / baseline).
//!  * [`Backend::Accel`] — the cycle-level SoC farm
//!    ([`crate::farm::Farm`]): batches fan out across warm SERV+CFU
//!    shard threads, and every response carries simulated cycles and
//!    FlexIC energy, aggregated into [`ConfigMetrics`] for the
//!    serving report (`report::serving`).

pub mod metrics;

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::farm::{AccelOutput, Farm, FarmMetrics, FarmOpts};
use crate::svm::model::Manifest;
use crate::svm::{infer, QuantModel};

use metrics::ConfigMetrics;

/// Which compute backend serves the batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled HLO on the PJRT CPU client (needs the `pjrt`
    /// feature and on-disk artifacts).
    Pjrt,
    /// Native Rust integer inference (differential testing / baseline).
    Native,
    /// Sharded cycle-level SoC farm with per-request energy accounting.
    Accel,
}

/// Server tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerOpts {
    pub backend: Backend,
    /// Max samples per flushed batch (≤ the compiled batch size).
    pub batch_max: usize,
    /// Compiled batch size to load (from the manifest's batch set).
    pub compiled_batch: usize,
    /// How long a request may wait for batchmates.
    pub linger: Duration,
    /// Bound of the ingress queue (backpressure).
    pub queue_cap: usize,
    /// Flush as soon as the ingress channel drains (EXPERIMENTS.md §Perf,
    /// L3 iteration 5): whatever arrived together is batched together,
    /// and nobody waits out the linger against an idle channel.  The
    /// linger then only bounds worst-case wait under sustained load.
    pub eager_flush: bool,
    /// Farm knobs (Backend::Accel only).
    pub farm: FarmOpts,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            backend: Backend::Native,
            batch_max: 64,
            compiled_batch: 64,
            linger: Duration::from_millis(2),
            queue_cap: 1024,
            eager_flush: true,
            farm: FarmOpts::default(),
        }
    }
}

/// Simulated-hardware accounting attached to `Backend::Accel` answers.
#[derive(Debug, Clone, Copy)]
pub struct SimCost {
    /// SoC cycles the inference took on the simulated FlexIC hardware.
    pub cycles: u64,
    /// FlexIC energy for the inference in mJ.
    pub energy_mj: f64,
}

/// A single inference answer.
#[derive(Debug, Clone, Copy)]
pub struct Response {
    pub pred: i32,
    /// Queue + execute time observed by the server.
    pub latency: Duration,
    /// How many samples shared the executed batch.
    pub batch_size: usize,
    /// Simulated cycles + energy (None on Pjrt/Native backends).
    pub sim: Option<SimCost>,
}

struct Request {
    key: String,
    features: Vec<i32>,
    enqueued: Instant,
    resp: mpsc::SyncSender<Result<Response>>,
}

enum Msg {
    Req(Request),
    Snapshot(mpsc::SyncSender<HashMap<String, ConfigMetrics>>),
    FarmSnapshot(mpsc::SyncSender<Option<FarmMetrics>>),
    Shutdown,
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::SyncSender<Msg>,
}

impl Client {
    /// Blocking single inference.
    pub fn infer(&self, key: &str, features: &[i32]) -> Result<Response> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Msg::Req(Request {
                key: key.to_string(),
                features: features.to_vec(),
                enqueued: Instant::now(),
                resp: tx,
            }))
            .map_err(|_| anyhow!("server is down"))?;
        rx.recv().context("server dropped the request")?
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> Result<HashMap<String, ConfigMetrics>> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx.send(Msg::Snapshot(tx)).map_err(|_| anyhow!("server is down"))?;
        rx.recv().context("server dropped the snapshot request")
    }

    /// Shard-level farm statistics (None on non-Accel backends).
    pub fn farm_metrics(&self) -> Result<Option<FarmMetrics>> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx.send(Msg::FarmSnapshot(tx)).map_err(|_| anyhow!("server is down"))?;
        rx.recv().context("server dropped the snapshot request")
    }
}

/// Running server; dropping the handle shuts the dispatcher down.
pub struct Server {
    tx: mpsc::SyncSender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Where the dispatcher gets its models from.
enum ModelSource {
    /// On-disk artifact tree (all backends).
    Artifacts(Manifest),
    /// In-memory models (Native/Accel — lets tests and benches serve
    /// synthetic models with no artifacts on disk).
    Inline(HashMap<String, QuantModel>),
}

impl ModelSource {
    fn model(&self, key: &str) -> Result<QuantModel> {
        match self {
            ModelSource::Artifacts(m) => {
                let entry = m.config(key)?;
                m.model(entry)
            }
            ModelSource::Inline(map) => {
                map.get(key).cloned().with_context(|| format!("config {key:?} not provided"))
            }
        }
    }
}

impl Server {
    /// Start a server for the given config keys of an artifact tree.
    pub fn start(artifacts_root: std::path::PathBuf, keys: Vec<String>, opts: ServerOpts) -> Result<Server> {
        // fail fast on bad configs before spawning
        let manifest = Manifest::load(&artifacts_root)?;
        for k in &keys {
            manifest.config(k)?;
        }
        Self::spawn(ModelSource::Artifacts(manifest), keys, opts)
    }

    /// Start a server over in-memory models (Native/Accel backends;
    /// no artifacts on disk required).
    pub fn start_with_models(models: Vec<(String, QuantModel)>, opts: ServerOpts) -> Result<Server> {
        if opts.backend == Backend::Pjrt {
            bail!("start_with_models serves Native/Accel only — Pjrt needs on-disk artifacts");
        }
        if models.is_empty() {
            bail!("no models to serve");
        }
        let keys: Vec<String> = models.iter().map(|(k, _)| k.clone()).collect();
        let mut map = HashMap::new();
        for (k, m) in models {
            if map.insert(k.clone(), m).is_some() {
                bail!("duplicate config key {k:?}");
            }
        }
        Self::spawn(ModelSource::Inline(map), keys, opts)
    }

    fn spawn(source: ModelSource, keys: Vec<String>, opts: ServerOpts) -> Result<Server> {
        if opts.batch_max == 0 || opts.batch_max > opts.compiled_batch {
            bail!("batch_max must be in 1..=compiled_batch");
        }
        let (tx, rx) = mpsc::sync_channel::<Msg>(opts.queue_cap);
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let join = std::thread::Builder::new()
            .name("flexsvm-dispatcher".into())
            .spawn(move || dispatcher(source, keys, opts, rx, ready_tx))?;
        ready_rx.recv().context("dispatcher died during init")??;
        Ok(Server { tx, join: Some(join) })
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

enum Exec {
    #[cfg(feature = "pjrt")]
    Pjrt(crate::runtime::Engine, usize),
    Native(HashMap<String, QuantModel>),
    Accel(Farm),
}

/// One executed batch.  Pjrt/Native batches succeed or fail as a unit
/// (execution cannot fail on input values); the farm answers per
/// sample, so a bad request fails alone instead of poisoning its
/// batchmates.
enum BatchAnswer {
    Uniform(Vec<i32>),
    PerSample(Vec<Result<AccelOutput>>),
}

impl Exec {
    fn run(&self, key: &str, xs: &[Vec<i32>]) -> Result<BatchAnswer> {
        match self {
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(engine, batch) => Ok(BatchAnswer::Uniform(engine.predict(key, *batch, xs)?)),
            Exec::Native(models) => {
                let m = models.get(key).ok_or_else(|| anyhow!("no model {key}"))?;
                Ok(BatchAnswer::Uniform(xs.iter().map(|x| infer::predict(m, x)).collect()))
            }
            Exec::Accel(farm) => Ok(BatchAnswer::PerSample(farm.predict_batch(key, xs)?)),
        }
    }

    fn baseline_cycles(&self, key: &str) -> Option<f64> {
        match self {
            Exec::Accel(farm) => farm.baseline_cycles(key),
            _ => None,
        }
    }

    fn farm_metrics(&self) -> Option<FarmMetrics> {
        match self {
            Exec::Accel(farm) => Some(farm.metrics()),
            _ => None,
        }
    }
}

/// Init: compile/load everything up front (AOT — no first-request jank).
fn init_exec(source: &ModelSource, keys: &[String], opts: &ServerOpts) -> Result<Exec> {
    if opts.backend == Backend::Pjrt {
        #[cfg(feature = "pjrt")]
        {
            let ModelSource::Artifacts(manifest) = source else {
                bail!("the PJRT backend serves on-disk artifacts only");
            };
            let mut engine = crate::runtime::Engine::new()?;
            for k in keys {
                let entry = manifest.config(k)?;
                engine.load(manifest, entry, opts.compiled_batch)?;
            }
            return Ok(Exec::Pjrt(engine, opts.compiled_batch));
        }
        #[cfg(not(feature = "pjrt"))]
        bail!("Backend::Pjrt requires building with `--features pjrt`");
    }
    let mut models = HashMap::new();
    for k in keys {
        models.insert(k.clone(), source.model(k)?);
    }
    match opts.backend {
        Backend::Native => Ok(Exec::Native(models)),
        Backend::Accel => {
            let list: Vec<(String, QuantModel)> =
                keys.iter().map(|k| (k.clone(), models.remove(k).expect("loaded above"))).collect();
            Ok(Exec::Accel(Farm::start(list, opts.farm)?))
        }
        Backend::Pjrt => unreachable!("handled above"),
    }
}

fn dispatcher(
    source: ModelSource,
    keys: Vec<String>,
    opts: ServerOpts,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    let exec = match init_exec(&source, &keys, &opts) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mut queues: HashMap<String, Vec<Request>> = HashMap::new();
    let mut stats: HashMap<String, ConfigMetrics> = HashMap::new();

    let flush = |key: &str, q: &mut Vec<Request>, stats: &mut HashMap<String, ConfigMetrics>| {
        if q.is_empty() {
            return;
        }
        let pending: Vec<Request> = std::mem::take(q);
        let xs: Vec<Vec<i32>> = pending.iter().map(|r| r.features.clone()).collect();
        let result = exec.run(key, &xs);
        let m = stats.entry(key.to_string()).or_insert_with(ConfigMetrics::new);
        m.batches += 1;
        m.batched_samples += pending.len() as u64;
        match result {
            Ok(BatchAnswer::Uniform(preds)) => {
                for (req, pred) in pending.into_iter().zip(preds) {
                    let latency = req.enqueued.elapsed();
                    if let Some(h) = m.latency.as_mut() {
                        h.record(latency);
                    }
                    let _ =
                        req.resp.send(Ok(Response { pred, latency, batch_size: xs.len(), sim: None }));
                }
            }
            Ok(BatchAnswer::PerSample(outs)) => {
                if let Some(b) = exec.baseline_cycles(key) {
                    m.baseline_cycles_per_inf = b;
                }
                for (req, out) in pending.into_iter().zip(outs) {
                    let latency = req.enqueued.elapsed();
                    match out {
                        Ok(o) => {
                            m.sim_samples += 1;
                            m.sim_cycles += o.cycles;
                            m.energy_mj += o.energy_mj;
                            if let Some(h) = m.latency.as_mut() {
                                h.record(latency);
                            }
                            let _ = req.resp.send(Ok(Response {
                                pred: o.pred,
                                latency,
                                batch_size: xs.len(),
                                sim: Some(SimCost { cycles: o.cycles, energy_mj: o.energy_mj }),
                            }));
                        }
                        Err(e) => {
                            let _ = req.resp.send(Err(anyhow!("inference failed: {e:#}")));
                        }
                    }
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                for req in pending {
                    let _ = req.resp.send(Err(anyhow!(msg.clone())));
                }
            }
        }
    };

    loop {
        // deadline of the oldest pending request across queues
        let now = Instant::now();
        let next_deadline = queues
            .values()
            .filter_map(|q| q.first())
            .map(|r| r.enqueued + opts.linger)
            .min();
        let timeout = next_deadline
            .map(|d| d.saturating_duration_since(now))
            .unwrap_or(Duration::from_millis(50));

        match rx.recv_timeout(timeout) {
            Ok(Msg::Req(req)) => {
                // drain everything already in flight so co-arriving
                // requests land in the same batch
                let mut pending = vec![Msg::Req(req)];
                while let Ok(m) = rx.try_recv() {
                    pending.push(m);
                }
                let mut shutdown = false;
                for msg in pending {
                    match msg {
                        Msg::Req(req) => {
                            if !queues.contains_key(&req.key) && !keys.iter().any(|k| *k == req.key) {
                                let _ =
                                    req.resp.send(Err(anyhow!("config {:?} not served", req.key)));
                                continue;
                            }
                            let m =
                                stats.entry(req.key.clone()).or_insert_with(ConfigMetrics::new);
                            m.requests += 1;
                            let q = queues.entry(req.key.clone()).or_default();
                            q.push(req);
                            if q.len() >= opts.batch_max {
                                let key = q[0].key.clone();
                                let mut taken = std::mem::take(queues.get_mut(&key).unwrap());
                                flush(&key, &mut taken, &mut stats);
                            }
                        }
                        Msg::Snapshot(tx) => {
                            let _ = tx.send(stats.clone());
                        }
                        Msg::FarmSnapshot(tx) => {
                            let _ = tx.send(exec.farm_metrics());
                        }
                        Msg::Shutdown => shutdown = true,
                    }
                }
                if opts.eager_flush {
                    // channel is drained: everything queued goes out now
                    let due: Vec<String> =
                        queues.iter().filter(|(_, q)| !q.is_empty()).map(|(k, _)| k.clone()).collect();
                    for key in due {
                        let mut taken = std::mem::take(queues.get_mut(&key).unwrap());
                        flush(&key, &mut taken, &mut stats);
                    }
                }
                if shutdown {
                    for (key, mut q) in std::mem::take(&mut queues) {
                        flush(&key, &mut q, &mut stats);
                    }
                    return;
                }
            }
            Ok(Msg::Snapshot(tx)) => {
                let _ = tx.send(stats.clone());
            }
            Ok(Msg::FarmSnapshot(tx)) => {
                let _ = tx.send(exec.farm_metrics());
            }
            Ok(Msg::Shutdown) => {
                for (key, mut q) in std::mem::take(&mut queues) {
                    flush(&key, &mut q, &mut stats);
                }
                return;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // flush queues whose oldest request exceeded the linger
                let now = Instant::now();
                let due: Vec<String> = queues
                    .iter()
                    .filter(|(_, q)| {
                        q.first().map(|r| now >= r.enqueued + opts.linger).unwrap_or(false)
                    })
                    .map(|(k, _)| k.clone())
                    .collect();
                for key in due {
                    let mut taken = std::mem::take(queues.get_mut(&key).unwrap());
                    flush(&key, &mut taken, &mut stats);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for (key, mut q) in std::mem::take(&mut queues) {
                    flush(&key, &mut q, &mut stats);
                }
                return;
            }
        }
    }
}

// Integration tests live in rust/tests/coordinator.rs: Native/Accel
// run against in-memory models (no artifacts needed); the PJRT and
// artifact-backed paths skip gracefully when artifacts are absent.
