//! Serving metrics: latency histogram + per-config counters
//! (hand-rolled; no external metrics crates offline).

use std::time::Duration;

/// Bucket upper bounds in µs: 1, 2, 5, 10, 20, 50, ... up to 500 s.
/// A sample lands in bucket `i` iff `BOUNDS[i-1] <= us < BOUNDS[i]`
/// (bucket 0: `us < 1`); the overflow bucket holds `us >= 500 s`.
/// Precomputed once — `record` sits on the hot path of every request
/// and must not allocate.
const BOUNDS: [u64; 27] = [
    1,
    2,
    5,
    10,
    20,
    50,
    100,
    200,
    500,
    1_000,
    2_000,
    5_000,
    10_000,
    20_000,
    50_000,
    100_000,
    200_000,
    500_000,
    1_000_000,
    2_000_000,
    5_000_000,
    10_000_000,
    20_000_000,
    50_000_000,
    100_000_000,
    200_000_000,
    500_000_000,
];

const N_BUCKETS: usize = BOUNDS.len() + 1; // + overflow

/// Log-scaled latency histogram, microsecond resolution.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i counts samples with value < BOUNDS[i]; the last slot
    /// is the overflow bucket.
    counts: Vec<u64>,
    total: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; N_BUCKETS], total: 0, sum_us: 0, max_us: 0 }
    }

    /// The shared bucket-bound table (µs) — every histogram in the
    /// fleet uses the same bounds, which is what makes raw bucket
    /// counts mergeable across the wire.
    pub fn bucket_bounds() -> &'static [u64] {
        &BOUNDS
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn record_us(&mut self, us: u64) {
        // first bucket whose bound exceeds the sample; all bounds
        // <= us sit to the left (partition_point = binary search)
        let idx = BOUNDS.partition_point(|&b| b <= us);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Fold another histogram in (fleet aggregation: both sides use
    /// the shared `bucket_bounds`, so counts add bucket-wise).
    pub fn merge(&mut self, other: &Histogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Raw bucket counts (len = `bucket_bounds().len() + 1`; the last
    /// slot is the overflow bucket).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Rebuild a histogram from wire-carried parts.  Errors if the
    /// bucket count does not match this build's bound table.
    pub fn from_parts(counts: Vec<u64>, sum_us: u64, max_us: u64) -> anyhow::Result<Histogram> {
        if counts.len() != N_BUCKETS {
            anyhow::bail!("histogram bucket count {} != expected {}", counts.len(), N_BUCKETS);
        }
        let total = counts.iter().sum();
        Ok(Histogram { counts, total, sum_us, max_us })
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.total as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate quantile (upper bound of the bucket containing it).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return BOUNDS.get(i).copied().unwrap_or(self.max_us);
            }
        }
        self.max_us
    }
}

/// Per-config serving counters.
#[derive(Debug, Clone, Default)]
pub struct ConfigMetrics {
    pub requests: u64,
    pub batches: u64,
    pub batched_samples: u64,
    pub latency: Option<Histogram>,
    /// Samples answered by the simulated SoC farm (Backend::Accel).
    pub sim_samples: u64,
    /// Total simulated SoC cycles across those samples.
    pub sim_cycles: u64,
    /// Total FlexIC energy across those samples, mJ.
    pub energy_mj: f64,
    /// Calibrated software-only baseline cycles/inference for the
    /// accel-vs-baseline ratio (0.0 when unknown / non-Accel).
    pub baseline_cycles_per_inf: f64,
    /// Kernel family of the served model (`"linear"`/`"rbf"`/`"poly"`;
    /// empty when unknown — e.g. a keys-only engine or an old peer).
    pub kernel: String,
    /// Weight bit-width of the served model (0 when unknown).
    pub bits: u8,
}

impl ConfigMetrics {
    pub fn new() -> Self {
        ConfigMetrics { latency: Some(Histogram::new()), ..Default::default() }
    }

    /// Fold another node's counters for this config into ours (fleet
    /// view).  Latency histograms merge bucket-wise when both sides
    /// carry one, so fleet quantiles come from real counts rather
    /// than a max over per-node summaries.
    pub fn merge(&mut self, other: &ConfigMetrics) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.batched_samples += other.batched_samples;
        self.sim_samples += other.sim_samples;
        self.sim_cycles += other.sim_cycles;
        self.energy_mj += other.energy_mj;
        if self.baseline_cycles_per_inf == 0.0 {
            self.baseline_cycles_per_inf = other.baseline_cycles_per_inf;
        }
        // model identity: fill in what we don't know (tolerates peers
        // that predate the kernel/bits fields)
        if self.kernel.is_empty() {
            self.kernel = other.kernel.clone();
        }
        if self.bits == 0 {
            self.bits = other.bits;
        }
        match (&mut self.latency, &other.latency) {
            (Some(mine), Some(theirs)) => mine.merge(theirs),
            (None, Some(theirs)) => self.latency = Some(theirs.clone()),
            _ => {}
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_samples as f64 / self.batches as f64
        }
    }

    /// Mean simulated cycles per inference (0 with no sim samples).
    pub fn mean_sim_cycles(&self) -> f64 {
        if self.sim_samples == 0 {
            0.0
        } else {
            self.sim_cycles as f64 / self.sim_samples as f64
        }
    }

    /// Mean FlexIC energy per request in mJ.
    pub fn mean_energy_mj(&self) -> f64 {
        if self.sim_samples == 0 {
            0.0
        } else {
            self.energy_mj / self.sim_samples as f64
        }
    }

    /// Accel-vs-baseline cycle ratio under load (Table I's speedup
    /// column measured on the serving path; 0 when uncalibrated).
    pub fn accel_speedup(&self) -> f64 {
        let accel = self.mean_sim_cycles();
        if accel == 0.0 || self.baseline_cycles_per_inf == 0.0 {
            0.0
        } else {
            self.baseline_cycles_per_inf / accel
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_bounds_match_the_generated_sequence() {
        // the table replaced a per-record generator loop; pin equality
        let mut gen = Vec::new();
        let mut base = 1u64;
        while base <= 100_000_000 {
            for m in [1, 2, 5] {
                gen.push(base * m);
            }
            base *= 10;
        }
        assert_eq!(Histogram::bucket_bounds(), &gen[..]);
        assert!(BOUNDS.windows(2).all(|w| w[0] < w[1]), "bounds strictly increasing");
    }

    #[test]
    fn record_buckets_by_binary_search() {
        let mut h = Histogram::new();
        // bucket edges are half-open [prev, bound): 1us lands in the
        // bucket bounded by 2, not the one bounded by 1
        for us in [0u64, 1, 2, 4, 5, 999_999_999_999] {
            h.record_us(us);
        }
        assert_eq!(h.counts()[0], 1, "us=0 < bound 1");
        assert_eq!(h.counts()[1], 1, "us=1 in [1,2)");
        assert_eq!(h.counts()[2], 2, "us=2,4 in [2,5)");
        assert_eq!(h.counts()[3], 1, "us=5 in [5,10)");
        assert_eq!(*h.counts().last().unwrap(), 1, "overflow bucket");
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for us in [3u64, 7, 12, 40, 90, 900, 15_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99, "p50 {p50} p99 {p99}");
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 15_000);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn merge_adds_bucketwise_and_quantiles_follow() {
        let (mut a, mut b) = (Histogram::new(), Histogram::new());
        for us in [10u64, 20, 30] {
            a.record_us(us);
        }
        for us in [40_000u64, 50_000, 60_000] {
            b.record_us(us);
        }
        a.merge(&b);
        assert_eq!(a.count(), 6);
        assert_eq!(a.max_us(), 60_000);
        assert_eq!(a.sum_us(), 10 + 20 + 30 + 40_000 + 50_000 + 60_000);
        // fleet p99 reflects the slow node's samples, not a summary max
        assert!(a.quantile_us(0.99) >= 50_000, "p99 {}", a.quantile_us(0.99));
        assert!(a.quantile_us(0.25) <= 50, "p25 {}", a.quantile_us(0.25));
    }

    #[test]
    fn from_parts_round_trips_and_rejects_bad_shapes() {
        let mut h = Histogram::new();
        for us in [5u64, 500, 50_000] {
            h.record_us(us);
        }
        let back = Histogram::from_parts(h.counts().to_vec(), h.sum_us(), h.max_us()).unwrap();
        assert_eq!(back.count(), h.count());
        assert_eq!(back.quantile_us(0.5), h.quantile_us(0.5));
        assert!(Histogram::from_parts(vec![0; 3], 0, 0).is_err());
    }

    #[test]
    fn mean_batch_size() {
        let mut m = ConfigMetrics::new();
        m.batches = 4;
        m.batched_samples = 10;
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn config_metrics_merge_folds_counters_and_latency() {
        let mut a = ConfigMetrics::new();
        a.requests = 3;
        a.batches = 2;
        a.batched_samples = 3;
        a.sim_samples = 3;
        a.sim_cycles = 300;
        a.energy_mj = 1.5;
        a.latency.as_mut().unwrap().record_us(100);
        let mut b = ConfigMetrics::new();
        b.requests = 1;
        b.batches = 1;
        b.batched_samples = 1;
        b.baseline_cycles_per_inf = 777.0;
        b.kernel = "rbf".into();
        b.bits = 8;
        b.latency.as_mut().unwrap().record_us(9_000);
        a.merge(&b);
        assert_eq!(a.requests, 4);
        assert_eq!(a.batches, 3);
        assert_eq!(a.sim_cycles, 300);
        assert_eq!(a.baseline_cycles_per_inf, 777.0);
        assert_eq!(a.kernel, "rbf", "unknown kernel fills from the peer");
        assert_eq!(a.bits, 8);
        let mut c = ConfigMetrics::new();
        c.kernel = "linear".into();
        c.bits = 4;
        a.merge(&c);
        assert_eq!(a.kernel, "rbf", "known kernel is never overwritten");
        assert_eq!(a.bits, 8);
        let h = a.latency.as_ref().unwrap();
        assert_eq!(h.count(), 2);
        assert_eq!(h.max_us(), 9_000);
    }

    #[test]
    fn sim_accounting_means() {
        let mut m = ConfigMetrics::new();
        assert_eq!(m.mean_sim_cycles(), 0.0);
        assert_eq!(m.mean_energy_mj(), 0.0);
        assert_eq!(m.accel_speedup(), 0.0);
        m.sim_samples = 4;
        m.sim_cycles = 400_000;
        m.energy_mj = 8.0;
        m.baseline_cycles_per_inf = 2_000_000.0;
        assert!((m.mean_sim_cycles() - 100_000.0).abs() < 1e-9);
        assert!((m.mean_energy_mj() - 2.0).abs() < 1e-12);
        assert!((m.accel_speedup() - 20.0).abs() < 1e-9);
    }
}
