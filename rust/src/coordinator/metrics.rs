//! Serving metrics: latency histogram + per-config counters
//! (hand-rolled; no external metrics crates offline).

use std::time::Duration;

/// Log-scaled latency histogram, microsecond resolution.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i counts samples with value < BOUNDS[i].
    counts: Vec<u64>,
    total: u64,
    sum_us: u64,
    max_us: u64,
}

/// Bucket upper bounds in µs: 1, 2, 5, 10, 20, 50, ... up to ~100 s.
fn bounds() -> Vec<u64> {
    let mut b = Vec::new();
    let mut base = 1u64;
    while base <= 100_000_000 {
        for m in [1, 2, 5] {
            b.push(base * m);
        }
        base *= 10;
    }
    b
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; bounds().len() + 1], total: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = bounds().iter().position(|&b| us < b).unwrap_or(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.total as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate quantile (upper bound of the bucket containing it).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        let bs = bounds();
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bs.get(i).copied().unwrap_or(self.max_us);
            }
        }
        self.max_us
    }
}

/// Per-config serving counters.
#[derive(Debug, Clone, Default)]
pub struct ConfigMetrics {
    pub requests: u64,
    pub batches: u64,
    pub batched_samples: u64,
    pub latency: Option<Histogram>,
    /// Samples answered by the simulated SoC farm (Backend::Accel).
    pub sim_samples: u64,
    /// Total simulated SoC cycles across those samples.
    pub sim_cycles: u64,
    /// Total FlexIC energy across those samples, mJ.
    pub energy_mj: f64,
    /// Calibrated software-only baseline cycles/inference for the
    /// accel-vs-baseline ratio (0.0 when unknown / non-Accel).
    pub baseline_cycles_per_inf: f64,
}

impl ConfigMetrics {
    pub fn new() -> Self {
        ConfigMetrics { latency: Some(Histogram::new()), ..Default::default() }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_samples as f64 / self.batches as f64
        }
    }

    /// Mean simulated cycles per inference (0 with no sim samples).
    pub fn mean_sim_cycles(&self) -> f64 {
        if self.sim_samples == 0 {
            0.0
        } else {
            self.sim_cycles as f64 / self.sim_samples as f64
        }
    }

    /// Mean FlexIC energy per request in mJ.
    pub fn mean_energy_mj(&self) -> f64 {
        if self.sim_samples == 0 {
            0.0
        } else {
            self.energy_mj / self.sim_samples as f64
        }
    }

    /// Accel-vs-baseline cycle ratio under load (Table I's speedup
    /// column measured on the serving path; 0 when uncalibrated).
    pub fn accel_speedup(&self) -> f64 {
        let accel = self.mean_sim_cycles();
        if accel == 0.0 || self.baseline_cycles_per_inf == 0.0 {
            0.0
        } else {
            self.baseline_cycles_per_inf / accel
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for us in [3u64, 7, 12, 40, 90, 900, 15_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99, "p50 {p50} p99 {p99}");
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 15_000);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn mean_batch_size() {
        let mut m = ConfigMetrics::new();
        m.batches = 4;
        m.batched_samples = 10;
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn sim_accounting_means() {
        let mut m = ConfigMetrics::new();
        assert_eq!(m.mean_sim_cycles(), 0.0);
        assert_eq!(m.mean_energy_mj(), 0.0);
        assert_eq!(m.accel_speedup(), 0.0);
        m.sim_samples = 4;
        m.sim_cycles = 400_000;
        m.energy_mj = 8.0;
        m.baseline_cycles_per_inf = 2_000_000.0;
        assert!((m.mean_sim_cycles() - 100_000.0).abs() < 1e-9);
        assert!((m.mean_energy_mj() - 2.0).abs() < 1e-12);
        assert!((m.accel_speedup() - 20.0).abs() < 1e-9);
    }
}
