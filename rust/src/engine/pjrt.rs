//! PJRT engine (`pjrt` cargo feature): AOT-compiled HLO on the PJRT
//! CPU client ([`crate::runtime`]) behind the [`Engine`] contract.
//! Serves on-disk artifacts only; batching, not parallel dispatch, is
//! where CPU-PJRT throughput comes from.

use anyhow::{bail, Result};

use super::{batch_error, Engine, ModelSource, Sample, ServeError};

/// Compiled-HLO serving engine.  The PJRT client is created lazily in
/// `warm` so the un-warmed struct is plain data and can be moved onto
/// the dispatcher thread.
///
/// Not constructible outside the crate: the only way to obtain one is
/// `Server::builder().backend(Backend::Pjrt)`, which never hands the
/// engine out — see the `Send` safety argument below.
pub struct PjrtEngine {
    /// Compiled batch size to load (from the manifest's batch set).
    batch: usize,
    runtime: Option<crate::runtime::Engine>,
}

impl PjrtEngine {
    pub(crate) fn new(compiled_batch: usize) -> Self {
        PjrtEngine { batch: compiled_batch, runtime: None }
    }
}

// SAFETY: the PJRT client inside `crate::runtime::Engine` is not
// `Send`.  This impl is sound because safe code outside the crate can
// never move a *warmed* engine across threads: `PjrtEngine::new` is
// `pub(crate)`, and the single construction site
// (`ServerBuilder::start`) moves the engine onto the dispatcher
// thread while `runtime` is still `None` (plain data).  `warm` then
// creates the client on the dispatcher thread, and every later call
// (`run_batch`, drop) stays on that thread for the engine's whole
// life.  Any new crate-internal construction site must preserve this
// move-before-warm invariant.
unsafe impl Send for PjrtEngine {}

impl Engine for PjrtEngine {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn warm(&mut self, source: &ModelSource, keys: &[String]) -> Result<()> {
        let Some(manifest) = source.manifest() else {
            bail!("the PJRT backend serves on-disk artifacts only");
        };
        let mut rt = crate::runtime::Engine::new()?;
        for k in keys {
            let entry = manifest.config(k)?;
            rt.load(manifest, entry, self.batch)?;
        }
        self.runtime = Some(rt);
        Ok(())
    }

    fn run_batch(&self, key: &str, xs: &[Vec<i32>]) -> Vec<Result<Sample, ServeError>> {
        let Some(rt) = self.runtime.as_ref() else {
            return batch_error(xs.len(), ServeError::Engine("pjrt engine not warmed".into()));
        };
        match rt.predict(key, self.batch, xs) {
            Ok(preds) => preds.into_iter().map(|pred| Ok(Sample::new(pred, None))).collect(),
            Err(e) => batch_error(xs.len(), ServeError::Engine(format!("batch execution failed: {e:#}"))),
        }
    }
}
