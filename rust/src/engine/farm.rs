//! Farm engine: the sharded cycle-level SoC pool ([`crate::farm`])
//! behind the [`Engine`] contract.  Every answer carries cycles +
//! FlexIC energy (simulated, or analytic under `FarmOpts::fastpath` —
//! kept bit-identical by the farm's differential audit), and
//! `snapshot` exposes per-shard balance plus the fast-path/audit
//! counters.  `baseline_cycles` is `Some` for every served config the
//! moment `warm` returns: the farm seeds the accel-vs-baseline ratio
//! from the closed-form static estimate and upgrades it in place once
//! background calibration lands.  Shards execute on the block-compiled
//! SERV engine over one shared `Arc`'d translation per config (`warm`
//! compiles each program exactly once), so requests never re-generate
//! or re-decode anything.

use anyhow::Result;

use crate::farm::{Farm, FarmOpts};
use crate::svm::QuantModel;

use super::{batch_error, Engine, EngineMetrics, ModelSource, Sample, ServeError, SimCost};

/// Cycle-level SoC farm as a serving engine.  The farm itself starts
/// in `warm` (shard spin-up + program builds + optional baseline
/// calibration happen before the server reports ready).
pub struct FarmEngine {
    opts: FarmOpts,
    farm: Option<Farm>,
}

impl FarmEngine {
    pub fn new(opts: FarmOpts) -> Self {
        FarmEngine { opts, farm: None }
    }
}

impl Engine for FarmEngine {
    fn name(&self) -> &str {
        "accel"
    }

    fn warm(&mut self, source: &ModelSource, keys: &[String]) -> Result<()> {
        if self.farm.is_some() {
            return Ok(()); // idempotent: already warmed
        }
        let models: Vec<(String, QuantModel)> =
            keys.iter().map(|k| Ok((k.clone(), source.model(k)?))).collect::<Result<_>>()?;
        self.farm = Some(Farm::start(models, self.opts)?);
        Ok(())
    }

    fn run_batch(&self, key: &str, xs: &[Vec<i32>]) -> Vec<Result<Sample, ServeError>> {
        let Some(farm) = self.farm.as_ref() else {
            return batch_error(xs.len(), ServeError::Engine("farm engine not warmed".into()));
        };
        match farm.predict_batch(key, xs) {
            Ok(outs) => outs
                .into_iter()
                .map(|r| {
                    r.map(|o| {
                        let mut s = Sample::new(
                            o.pred,
                            Some(SimCost { cycles: o.cycles, energy_mj: o.energy_mj }),
                        );
                        s.stages = o.stages;
                        s.mode = Some(o.mode.name());
                        s
                    })
                    .map_err(|e| ServeError::Engine(format!("inference failed: {e:#}")))
                })
                .collect(),
            Err(e) => batch_error(xs.len(), ServeError::Engine(format!("batch execution failed: {e:#}"))),
        }
    }

    fn baseline_cycles(&self, key: &str) -> Option<f64> {
        self.farm.as_ref()?.baseline_cycles(key)
    }

    fn snapshot(&self) -> EngineMetrics {
        EngineMetrics {
            engine: self.name().to_string(),
            farm: self.farm.as_ref().map(|f| f.metrics()),
            profiles: self.farm.as_ref().map(|f| f.profiles()).unwrap_or_default(),
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serv::TimingConfig;
    use crate::svm::infer;
    use crate::testing::gen;
    use std::collections::HashMap;

    fn warm_engine() -> (FarmEngine, QuantModel) {
        let model = gen::tiny_model("f", false);
        let mut src = HashMap::new();
        src.insert("f".to_string(), model.clone());
        let mut e = FarmEngine::new(FarmOpts {
            shards: 1,
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            ..Default::default()
        });
        e.warm(&ModelSource::Inline(src), &["f".to_string()]).unwrap();
        (e, model)
    }

    #[test]
    fn farm_engine_answers_with_sim_cost() {
        let (e, model) = warm_engine();
        let xs = vec![vec![3, 4, 5], vec![9, 1, 0]];
        for (x, r) in xs.iter().zip(e.run_batch("f", &xs)) {
            let s = r.unwrap();
            assert_eq!(s.pred, infer::predict(&model, x));
            let sim = s.sim.expect("farm answers carry sim cost");
            assert!(sim.cycles > 0 && sim.energy_mj > 0.0);
        }
        let m = e.snapshot();
        assert_eq!(m.engine, "accel");
        assert_eq!(m.farm.expect("farm metrics").total_jobs(), 2);
    }

    #[test]
    fn bad_sample_fails_alone() {
        let (e, _) = warm_engine();
        let out = e.run_batch("f", &[vec![1, 2, 3], vec![99, 0, 0]]);
        assert!(out[0].is_ok());
        assert!(matches!(&out[1], Err(ServeError::Engine(_))));
    }

    #[test]
    fn unwarmed_engine_reports_cleanly() {
        let e = FarmEngine::new(FarmOpts::default());
        assert!(e.run_batch("f", &[vec![1]])[0].is_err());
        assert!(e.baseline_cycles("f").is_none());
        assert!(e.snapshot().farm.is_none());
    }

    #[test]
    fn baseline_ratio_available_from_warm() {
        // calibration is off in the fixture: the estimate must serve
        // ratios anyway, from the very first request
        let (e, _) = warm_engine();
        let base = e.baseline_cycles("f").expect("estimate-seeded baseline");
        assert!(base > 0.0);
        assert!(e.baseline_cycles("nope").is_none());
    }

    #[test]
    fn fastpath_engine_snapshot_carries_audit_counters() {
        let model = gen::tiny_model("f", false);
        let mut src = HashMap::new();
        src.insert("f".to_string(), model.clone());
        let mut e = FarmEngine::new(FarmOpts {
            shards: 1,
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            fastpath: true,
            audit_rate: 2,
            ..Default::default()
        });
        e.warm(&ModelSource::Inline(src), &["f".to_string()]).unwrap();
        let xs = vec![vec![3, 4, 5], vec![9, 1, 0], vec![0, 2, 4], vec![7, 7, 7]];
        for (x, r) in xs.iter().zip(e.run_batch("f", &xs)) {
            assert_eq!(r.unwrap().pred, infer::predict(&model, x));
        }
        let farm = e.snapshot().farm.expect("farm metrics");
        assert_eq!(farm.total_jobs(), 4);
        assert_eq!(farm.fast.fast_jobs, 2, "requests 1 and 3 analytic");
        assert_eq!(farm.fast.audits, 2, "requests 0 and 2 audited");
        assert_eq!(farm.fast.mismatches, 0);
    }
}
