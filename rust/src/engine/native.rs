//! Native engine: pure-Rust integer inference ([`crate::svm::infer`]),
//! the Rust twin of `quantize.py` that every other backend must agree
//! with.  No simulated-hardware accounting — answers carry `sim: None`.

use std::collections::HashMap;

use anyhow::Result;

use crate::svm::{infer, QuantModel};

use super::{batch_error, Engine, ModelSource, Sample, ServeError};

/// The baseline backend: model lookup + `infer::predict` per sample.
#[derive(Default)]
pub struct NativeEngine {
    models: HashMap<String, QuantModel>,
}

impl NativeEngine {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &str {
        "native"
    }

    fn warm(&mut self, source: &ModelSource, keys: &[String]) -> Result<()> {
        for k in keys {
            if !self.models.contains_key(k) {
                self.models.insert(k.clone(), source.model(k)?);
            }
        }
        Ok(())
    }

    fn run_batch(&self, key: &str, xs: &[Vec<i32>]) -> Vec<Result<Sample, ServeError>> {
        let Some(m) = self.models.get(key) else {
            return batch_error(xs.len(), ServeError::UnknownConfig(key.to_string()));
        };
        xs.iter().map(|x| Ok(Sample::new(infer::predict(m, x), None))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen;

    #[test]
    fn warm_then_run_matches_infer() {
        let model = gen::tiny_model("t", false);
        let mut src = HashMap::new();
        src.insert("t".to_string(), model.clone());
        let mut e = NativeEngine::new();
        e.warm(&ModelSource::Inline(src), &["t".to_string()]).unwrap();
        let xs = vec![vec![15, 0, 3], vec![0, 15, 9]];
        let out = e.run_batch("t", &xs);
        assert_eq!(out.len(), 2);
        for (x, r) in xs.iter().zip(out) {
            let s = r.unwrap();
            assert_eq!(s.pred, infer::predict(&model, x));
            assert!(s.sim.is_none());
        }
    }

    #[test]
    fn unknown_key_fails_every_slot() {
        let e = NativeEngine::new();
        let out = e.run_batch("nope", &[vec![1, 2, 3]]);
        assert!(matches!(&out[0], Err(ServeError::UnknownConfig(k)) if k == "nope"));
    }

    #[test]
    fn warm_fails_on_missing_model() {
        let mut e = NativeEngine::new();
        assert!(e.warm(&ModelSource::Inline(HashMap::new()), &["absent".to_string()]).is_err());
        assert!(e.warm(&ModelSource::None, &["absent".to_string()]).is_err());
    }
}
