//! Pluggable serving engines: the backend contract behind the
//! coordinator.
//!
//! The coordinator's serving loop (routing, batching, linger/eager
//! flush, metrics) is backend-agnostic: everything a backend does —
//! model residency, batch execution, simulated-hardware accounting,
//! baseline calibration, statistics — flows through [`Engine`].  The
//! three in-tree engines mirror the paper's evaluation stack:
//!
//!  * [`NativeEngine`] — pure-Rust integer inference (differential
//!    testing / baseline);
//!  * [`FarmEngine`] — the sharded cycle-level SoC farm
//!    ([`crate::farm::Farm`]) with per-request cycle + FlexIC energy
//!    accounting;
//!  * `PjrtEngine` (`pjrt` cargo feature) — AOT-compiled HLO on the
//!    PJRT CPU client.
//!
//! Out-of-tree engines (mocks, mixed-kernel accelerator variants,
//! remote shards) plug in through
//! [`ServerBuilder::engine`](crate::coordinator::ServerBuilder::engine);
//! [`crate::testing::mock::MockEngine`] is the reference
//! implementation used by the coordinator tests.

mod farm;
mod native;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use farm::FarmEngine;
pub use native::NativeEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

use std::collections::HashMap;
use std::fmt;

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::ConfigMetrics;
use crate::farm::FarmMetrics;
use crate::obs::{ConfigProfile, Span, StageSet, TraceId};
use crate::svm::model::Manifest;
use crate::svm::QuantModel;

/// Which in-tree engine serves the batches (the backend *kind*; custom
/// engines bypass this via `ServerBuilder::engine`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled HLO on the PJRT CPU client (needs the `pjrt`
    /// feature and on-disk artifacts).
    Pjrt,
    /// Native Rust integer inference (differential testing / baseline).
    Native,
    /// Sharded cycle-level SoC farm with per-request energy accounting.
    Accel,
}

impl Backend {
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Pjrt => "pjrt",
            Backend::Native => "native",
            Backend::Accel => "accel",
        }
    }

    /// Default backend for this build: `pjrt` when the feature is
    /// compiled in, `native` otherwise.
    pub fn default_for_build() -> Backend {
        if cfg!(feature = "pjrt") {
            Backend::Pjrt
        } else {
            Backend::Native
        }
    }
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Backend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "pjrt" => Ok(Backend::Pjrt),
            "native" => Ok(Backend::Native),
            "accel" => Ok(Backend::Accel),
            other => bail!("unknown backend {other:?} (valid: pjrt, native, accel)"),
        }
    }
}

/// Typed request-path error.  Everything a client can see from
/// `infer`/`submit`/`infer_many` is one of these (init-time problems
/// stay `anyhow` on `ServerBuilder::start`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The requested config key is not in the served set.
    UnknownConfig(String),
    /// The server (dispatcher thread) is gone.
    ServerDown,
    /// The dispatcher dropped the request without answering — e.g. it
    /// panicked mid-batch (see `Server::shutdown` for the payload).
    Dropped,
    /// The server's bounded ingress is saturated; the request was shed
    /// instead of queued (`Client::try_submit`, mapped to
    /// `503 + Retry-After` by the wire front).  Retry after backing off.
    Overloaded,
    /// The engine failed this sample or batch.
    Engine(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownConfig(key) => write!(f, "config {key:?} not served"),
            ServeError::ServerDown => f.write_str("server is down"),
            ServeError::Dropped => f.write_str("server dropped the request"),
            ServeError::Overloaded => f.write_str("server overloaded; retry later"),
            ServeError::Engine(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for ServeError {}

/// Simulated-hardware accounting attached to answers from cycle-level
/// engines (the farm); wall-clock-only engines leave it `None`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimCost {
    /// SoC cycles the inference took on the simulated FlexIC hardware.
    pub cycles: u64,
    /// FlexIC energy for the inference in mJ.
    pub energy_mj: f64,
}

/// One answered sample of an executed batch.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Predicted class id.
    pub pred: i32,
    /// Simulated cycles + energy (engines without a hardware model
    /// report `None`).
    pub sim: Option<SimCost>,
    /// Engine-side stage timings for this sample (the farm records
    /// `shard_wait` / `execute` / `audit`; engines that don't measure
    /// stages leave this empty and the coordinator attributes the
    /// whole engine call to `execute`).
    pub stages: StageSet,
    /// Execution-mode label when the engine distinguishes one
    /// (`"sim"` / `"fast"` / `"audited"` from the farm's `ExecMode`).
    pub mode: Option<&'static str>,
    /// Child span from a remote hop (`RemoteEngine` fan-out): the
    /// executing node's own span for this sample's chunk.
    pub child: Option<Box<Span>>,
}

impl Sample {
    /// A plain answer with no stage breakdown (what most engines
    /// return; the tracing fields start empty).
    pub fn new(pred: i32, sim: Option<SimCost>) -> Sample {
        Sample { pred, sim, stages: StageSet::new(), mode: None, child: None }
    }
}

/// Point-in-time engine statistics, snapshotted through the dispatcher.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    /// Engine label (`Engine::name`).
    pub engine: String,
    /// Shard-level statistics for sharded engines (the farm); `None`
    /// for single-executor engines.
    pub farm: Option<FarmMetrics>,
    /// Fleet-wide per-config serving metrics for fan-out engines
    /// (`RemoteEngine` merges every node's `ConfigMetrics` — full
    /// histogram buckets, so fleet quantiles are real quantiles).
    pub fleet: Option<HashMap<String, ConfigMetrics>>,
    /// Per-config guest-cycle profiles from the sampled continuous
    /// profiler (the farm aggregates across shards; `RemoteEngine`
    /// merges across nodes).  Empty when profiling is off.
    pub profiles: HashMap<String, ConfigProfile>,
}

/// Where an engine's `warm` gets host-side models from.
pub enum ModelSource {
    /// On-disk artifact tree (all backends).
    Artifacts(Manifest),
    /// In-memory models (lets tests and benches serve synthetic models
    /// with no artifacts on disk).
    Inline(HashMap<String, QuantModel>),
    /// No host-side models: the engine brings its own (mocks, remote
    /// shards).
    None,
}

impl ModelSource {
    /// Load one model by config key.
    pub fn model(&self, key: &str) -> Result<QuantModel> {
        match self {
            ModelSource::Artifacts(m) => {
                let entry = m.config(key)?;
                m.model(entry)
            }
            ModelSource::Inline(map) => {
                map.get(key).cloned().with_context(|| format!("config {key:?} not provided"))
            }
            ModelSource::None => bail!("no model source: the engine must own its models"),
        }
    }

    /// The artifact manifest, for engines that serve on-disk artifacts
    /// only (PJRT).
    pub fn manifest(&self) -> Option<&Manifest> {
        match self {
            ModelSource::Artifacts(m) => Some(m),
            _ => None,
        }
    }
}

/// The whole backend contract.  The coordinator moves the boxed engine
/// onto its dispatcher thread, calls [`warm`](Engine::warm) once before
/// accepting traffic, then drives batches through
/// [`run_batch`](Engine::run_batch); per-sample failure isolation is
/// universal — a bad request fails alone instead of poisoning its
/// batchmates.
pub trait Engine: Send {
    /// Short engine label (shows up in reports and metrics).
    fn name(&self) -> &str;

    /// Load/compile everything for `keys` up front — AOT residency, no
    /// first-request jank.  Runs on the dispatcher thread before the
    /// server reports ready; an error here fails `start()`.
    fn warm(&mut self, source: &ModelSource, keys: &[String]) -> Result<()>;

    /// Execute one batch; one answer per input sample, in input order.
    fn run_batch(&self, key: &str, xs: &[Vec<i32>]) -> Vec<Result<Sample, ServeError>>;

    /// Execute one batch with tracing context (per-sample trace ids,
    /// parallel to `xs`).  Engines that propagate traces downstream
    /// (`RemoteEngine` puts them on the wire) override this; the
    /// default ignores the context, so existing engines keep working
    /// unchanged.
    fn run_batch_ctx(
        &self,
        key: &str,
        xs: &[Vec<i32>],
        _ctx: &BatchCtx<'_>,
    ) -> Vec<Result<Sample, ServeError>> {
        self.run_batch(key, xs)
    }

    /// Calibrated software-only cycles/inference for the
    /// accel-vs-baseline ratio (`None` for engines without a baseline
    /// story).
    fn baseline_cycles(&self, _key: &str) -> Option<f64> {
        None
    }

    /// Point-in-time engine statistics.
    fn snapshot(&self) -> EngineMetrics {
        EngineMetrics { engine: self.name().to_string(), ..Default::default() }
    }
}

/// Tracing context for one engine batch: per-sample trace ids,
/// parallel to the batch's `xs`.  Empty when the caller traces
/// nothing (benches, plain `run_batch` paths).
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchCtx<'a> {
    pub traces: &'a [TraceId],
}

/// Replicate one batch-level failure across every sample slot (for
/// engines whose execution succeeds or fails as a unit).
pub fn batch_error(n: usize, err: ServeError) -> Vec<Result<Sample, ServeError>> {
    (0..n).map(|_| Err(err.clone())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_round_trips_through_str() {
        for b in [Backend::Pjrt, Backend::Native, Backend::Accel] {
            let parsed: Backend = b.as_str().parse().unwrap();
            assert_eq!(parsed, b);
            assert_eq!(b.to_string(), b.as_str());
        }
    }

    #[test]
    fn backend_parse_error_lists_valid_values() {
        let err = "tpu".parse::<Backend>().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pjrt") && msg.contains("native") && msg.contains("accel"), "{msg}");
    }

    #[test]
    fn serve_error_messages() {
        assert_eq!(ServeError::ServerDown.to_string(), "server is down");
        assert!(ServeError::UnknownConfig("k".into()).to_string().contains("not served"));
        assert!(ServeError::Overloaded.to_string().contains("overloaded"));
        assert_eq!(ServeError::Engine("boom".into()).to_string(), "boom");
    }

    #[test]
    fn batch_error_fills_every_slot() {
        let v = batch_error(3, ServeError::Engine("x".into()));
        assert_eq!(v.len(), 3);
        assert!(v.iter().all(|r| r.is_err()));
    }

    #[test]
    fn empty_model_source_refuses_lookups() {
        assert!(ModelSource::None.model("k").is_err());
        assert!(ModelSource::None.manifest().is_none());
    }
}
