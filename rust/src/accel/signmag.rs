//! 2's-complement → sign-magnitude conversion module (paper Fig. 6).
//!
//! The PE multiplies 4-bit *unsigned* quantities, so each signed weight
//! is decomposed into an unsigned magnitude (split into 4-bit nibbles
//! that map onto the 4×4 multipliers) plus a sign flag that selects
//! add-or-subtract at the accumulator.

/// Sign flag + unsigned magnitude of a two's-complement value of the
/// given width.  `bits` ∈ {4, 8, 16}; `w` must fit the width.
pub fn to_sign_magnitude(w: i32, bits: u8) -> (bool, u32) {
    debug_assert!(matches!(bits, 4 | 8 | 16));
    let min = -(1i32 << (bits - 1));
    let max = (1i32 << (bits - 1)) - 1;
    debug_assert!(
        (min..=max).contains(&w),
        "weight {w} does not fit {bits}-bit two's complement"
    );
    (w < 0, w.unsigned_abs())
}

/// Split a magnitude into `n` 4-bit nibbles, least-significant first —
/// one per 4×4 multiplier lane.
pub fn nibbles(mag: u32, n: usize) -> impl Iterator<Item = u32> {
    (0..n).map(move |k| (mag >> (4 * k)) & 0xf)
}

/// Sign-extend the low `bits` of a raw field to i32 (unpacking side).
pub fn sign_extend(raw: u32, bits: u8) -> i32 {
    let shift = 32 - bits as u32;
    ((raw << shift) as i32) >> shift
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn decomposition_reconstructs_value() {
        let mut rng = Pcg32::seeded(11);
        for bits in [4u8, 8, 16] {
            let qmax = (1i32 << (bits - 1)) - 1;
            for _ in 0..500 {
                let w = rng.range_i32(-qmax, qmax);
                let (neg, mag) = to_sign_magnitude(w, bits);
                let n = (bits / 4) as usize;
                let rebuilt: u32 =
                    nibbles(mag, n).enumerate().map(|(k, nib)| nib << (4 * k)).sum();
                let signed = if neg { -(rebuilt as i64) } else { rebuilt as i64 };
                assert_eq!(signed, w as i64, "bits={bits} w={w}");
            }
        }
    }

    #[test]
    fn nibble_bounds() {
        let (_, mag) = to_sign_magnitude(-128 + 1, 8); // 127
        assert!(nibbles(mag, 2).all(|n| n <= 0xf));
        let (neg, mag) = to_sign_magnitude(-32767, 16);
        assert!(neg);
        assert_eq!(mag, 32767);
        assert_eq!(nibbles(mag, 4).collect::<Vec<_>>(), vec![0xf, 0xf, 0xf, 0x7]);
    }

    #[test]
    fn sign_extend_fields() {
        assert_eq!(sign_extend(0xf, 4), -1);
        assert_eq!(sign_extend(0x7, 4), 7);
        assert_eq!(sign_extend(0x80, 8), -128);
        assert_eq!(sign_extend(0xffff, 16), -1);
        assert_eq!(sign_extend(0x7fff, 16), 32767);
    }
}
