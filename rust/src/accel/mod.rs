//! The ML-accelerator framework (paper contribution 1, §III).
//!
//! The paper's framework lets any developer attach a custom co-processor
//! to the SERV core by implementing a small RTL interface template: a
//! `accel_valid`/`accel_ready` handshake carrying `rs1`, `rs2` and the
//! `funct3` operation id (Fig. 1).  This module is the software twin of
//! that template:
//!
//!  * [`Cfu`] is the interface a co-processor implements — the analogue
//!    of the RTL template the framework ships.
//!  * [`CfuBank`] is the decoder-side routing: R-type instructions with
//!    funct7 ∉ {0x00, 0x20} are dispatched to the CFU registered under
//!    that funct7 value (Fig. 4 — SERV only uses 0x00/0x20 internally,
//!    so funct7 = 1, 2, 3, … are free; each CFU gets up to 8 operations
//!    via funct3).
//!
//! The paper's SVM accelerator ([`svm::SvmAccel`], funct7 = 1) is one
//! instance; [`mac::MacAccel`] (funct7 = 2) and [`popcount::PopcountAccel`]
//! (funct7 = 3) demonstrate the claimed extensibility.

pub mod kernel;
pub mod mac;
pub mod pe;
pub mod popcount;
pub mod rtl_template;
pub mod signmag;
pub mod svm;

use anyhow::{bail, Result};

/// Result of one CFU operation — what the handshake returns to SERV.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CfuOutput {
    /// Value forwarded to `rd` (ignored when the instruction's rd = x0,
    /// e.g. the SV_Calc* family in Fig. 8).
    pub value: u32,
    /// Accelerator-internal compute cycles between `accel_valid` and
    /// `accel_ready` (the 32-cycle operand/result transfers are charged
    /// by the SoC handshake, not here).
    pub compute_cycles: u64,
}

/// The co-processor interface template (paper Fig. 1).
///
/// Implementations must be deterministic: the cycle-accurate SoC replays
/// operations when tracing.
pub trait Cfu: Send {
    /// Human-readable name (reports/traces).
    fn name(&self) -> &'static str;

    /// Reset all internal registers (power-on or explicit re-init).
    fn reset(&mut self);

    /// Execute one operation.  `funct3` selects among up to 8 ops;
    /// `rs1`/`rs2` are the 32-bit operands serially received from SERV.
    fn execute(&mut self, funct3: u8, rs1: u32, rs2: u32) -> Result<CfuOutput>;

    /// Combinational gate-count estimate (NAND2-equivalents) for the
    /// FlexIC area model; 0 if unknown.
    fn nand2_equivalents(&self) -> u64 {
        0
    }
}

/// Decoder-side CFU routing by funct7 (1..=31, excluding 0x20).
pub struct CfuBank {
    slots: Vec<(u8, Box<dyn Cfu>)>,
}

impl Default for CfuBank {
    fn default() -> Self {
        Self::new()
    }
}

impl CfuBank {
    pub fn new() -> Self {
        CfuBank { slots: Vec::new() }
    }

    /// Register a CFU under a funct7 value.  funct7 0x00 and 0x20 are
    /// SERV's own ALU encodings and are rejected (paper §III-C).
    pub fn register(&mut self, funct7: u8, cfu: Box<dyn Cfu>) -> Result<()> {
        if funct7 == 0x00 || funct7 == 0x20 || funct7 > 0x7f {
            bail!("funct7 {funct7:#x} is reserved by SERV or out of range");
        }
        if self.slots.iter().any(|(f, _)| *f == funct7) {
            bail!("funct7 {funct7:#x} already registered");
        }
        self.slots.push((funct7, cfu));
        Ok(())
    }

    pub fn get_mut(&mut self, funct7: u8) -> Option<&mut dyn Cfu> {
        self.slots
            .iter_mut()
            .find(|(f, _)| *f == funct7)
            .map(|(_, c)| c.as_mut() as &mut dyn Cfu)
    }

    pub fn get(&self, funct7: u8) -> Option<&dyn Cfu> {
        self.slots.iter().find(|(f, _)| *f == funct7).map(|(_, c)| c.as_ref() as &dyn Cfu)
    }

    pub fn reset_all(&mut self) {
        for (_, c) in &mut self.slots {
            c.reset();
        }
    }

    pub fn registered(&self) -> Vec<(u8, &'static str)> {
        self.slots.iter().map(|(f, c)| (*f, c.name())).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Cfu for Echo {
        fn name(&self) -> &'static str {
            "echo"
        }
        fn reset(&mut self) {}
        fn execute(&mut self, funct3: u8, rs1: u32, rs2: u32) -> Result<CfuOutput> {
            Ok(CfuOutput { value: rs1 ^ rs2 ^ funct3 as u32, compute_cycles: 1 })
        }
    }

    #[test]
    fn register_and_dispatch() {
        let mut bank = CfuBank::new();
        bank.register(1, Box::new(Echo)).unwrap();
        let out = bank.get_mut(1).unwrap().execute(3, 0xf0, 0x0f).unwrap();
        assert_eq!(out.value, 0xf0 ^ 0x0f ^ 3);
        assert!(bank.get_mut(2).is_none());
    }

    #[test]
    fn reserved_funct7_rejected() {
        let mut bank = CfuBank::new();
        assert!(bank.register(0x00, Box::new(Echo)).is_err());
        assert!(bank.register(0x20, Box::new(Echo)).is_err());
        bank.register(1, Box::new(Echo)).unwrap();
        assert!(bank.register(1, Box::new(Echo)).is_err(), "double registration");
    }
}
