//! The Processing Engine (paper Fig. 7): eight parallel 4×4-bit unsigned
//! multipliers with a shift-mux recombination stage.
//!
//! One `SV_Calc*` instruction delivers two packed 32-bit operands:
//!
//! | mode | rs1 (features, 4-bit unsigned each) | rs2 (weights, signed)    | pairs/instr |
//! |------|-------------------------------------|--------------------------|-------------|
//! | W4   | 8 features in nibbles 0..7          | 8 × 4-bit                | 8           |
//! | W8   | 4 features in nibbles 0..3          | 4 × 8-bit                | 4           |
//! | W16  | 2 features in nibbles 0..1          | 2 × 16-bit               | 2           |
//!
//! In every mode all eight multipliers are busy (8 = pairs × nibbles),
//! so the PE pass costs one accelerator cycle.  Each weight is converted
//! to sign-magnitude; nibble products are shifted by the mux stage
//! (<< 0/4/8/12) and added to or subtracted from the running sum.

use super::signmag::{nibbles, sign_extend, to_sign_magnitude};

/// Weight-precision mode, selected by funct3 (paper Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    W4,
    W8,
    W16,
}

impl Mode {
    pub fn bits(self) -> u8 {
        match self {
            Mode::W4 => 4,
            Mode::W8 => 8,
            Mode::W16 => 16,
        }
    }

    /// Feature/weight pairs consumed per instruction.
    pub fn lanes(self) -> usize {
        match self {
            Mode::W4 => 8,
            Mode::W8 => 4,
            Mode::W16 => 2,
        }
    }

    /// Magnitude nibbles per weight (= multipliers per lane).
    pub fn nibbles_per_weight(self) -> usize {
        (self.bits() / 4) as usize
    }
}

/// Number of physical 4×4 multipliers in the PE (Fig. 7).
pub const NUM_MULTIPLIERS: usize = 8;

/// Unpack the packed feature word: lane `l` is the 4-bit unsigned value
/// in nibble `l`.
pub fn unpack_features(rs1: u32, mode: Mode) -> Vec<u32> {
    (0..mode.lanes()).map(|l| (rs1 >> (4 * l)) & 0xf).collect()
}

/// Unpack the packed weight word: lane `l` is the `bits`-wide signed
/// field at offset `l * bits`.
pub fn unpack_weights(rs2: u32, mode: Mode) -> Vec<i32> {
    let bits = mode.bits() as u32;
    (0..mode.lanes())
        .map(|l| sign_extend((rs2 >> (bits * l as u32)) & ((1u64 << bits) - 1) as u32, mode.bits()))
        .collect()
}

/// Pack features (values 0..15) into an rs1 word for the given mode.
pub fn pack_features(xs: &[u32], mode: Mode) -> u32 {
    assert!(xs.len() <= mode.lanes(), "too many features for one word");
    xs.iter().enumerate().fold(0u32, |acc, (l, &x)| {
        assert!(x <= 0xf, "feature {x} exceeds 4 bits");
        acc | (x << (4 * l))
    })
}

/// Pack signed weights into an rs2 word for the given mode.
pub fn pack_weights(ws: &[i32], mode: Mode) -> u32 {
    assert!(ws.len() <= mode.lanes(), "too many weights for one word");
    let bits = mode.bits() as u32;
    let mask = ((1u64 << bits) - 1) as u32;
    ws.iter().enumerate().fold(0u32, |acc, (l, &w)| {
        let min = -(1i32 << (bits - 1));
        let max = (1i32 << (bits - 1)) - 1;
        assert!((min..=max).contains(&w), "weight {w} does not fit {bits} bits");
        acc | (((w as u32) & mask) << (bits * l as u32))
    })
}

/// One PE pass: the multiply-accumulate contribution of a packed
/// operand pair.  This is the bit-exact model of the Fig. 7 datapath.
pub fn compute(rs1: u32, rs2: u32, mode: Mode) -> i64 {
    let xs = unpack_features(rs1, mode);
    let ws = unpack_weights(rs2, mode);
    let npw = mode.nibbles_per_weight();
    let mut sum: i64 = 0;
    let mut multipliers_used = 0;
    for (x, w) in xs.iter().zip(ws.iter()) {
        let (neg, mag) = to_sign_magnitude(*w, mode.bits());
        for (k, nib) in nibbles(mag, npw).enumerate() {
            // a 4×4 unsigned multiplier lane + the shift-mux stage
            let product = (x * nib) as i64; // ≤ 15*15 = 225
            let shifted = product << (4 * k);
            sum += if neg { -shifted } else { shifted };
            multipliers_used += 1;
        }
    }
    debug_assert!(multipliers_used <= NUM_MULTIPLIERS);
    sum
}

/// Accelerator-internal cycles for one PE pass: every mode fills all
/// eight multipliers exactly once.
pub fn compute_cycles(_mode: Mode) -> u64 {
    1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    /// The nibble-decomposed PE must equal the direct dot product.
    #[test]
    fn pe_matches_dot_product() {
        let mut rng = Pcg32::seeded(0xbeef);
        for mode in [Mode::W4, Mode::W8, Mode::W16] {
            let qmax = (1i32 << (mode.bits() - 1)) - 1;
            for _ in 0..1000 {
                let lanes = mode.lanes();
                let xs: Vec<u32> = (0..lanes).map(|_| rng.below(16)).collect();
                let ws: Vec<i32> = (0..lanes).map(|_| rng.range_i32(-qmax, qmax)).collect();
                let rs1 = pack_features(&xs, mode);
                let rs2 = pack_weights(&ws, mode);
                let expect: i64 =
                    xs.iter().zip(ws.iter()).map(|(&x, &w)| x as i64 * w as i64).sum();
                assert_eq!(compute(rs1, rs2, mode), expect, "mode {mode:?}");
            }
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Pcg32::seeded(2);
        for mode in [Mode::W4, Mode::W8, Mode::W16] {
            let qmax = (1i32 << (mode.bits() - 1)) - 1;
            for _ in 0..200 {
                let lanes = mode.lanes();
                let xs: Vec<u32> = (0..lanes).map(|_| rng.below(16)).collect();
                let ws: Vec<i32> = (0..lanes).map(|_| rng.range_i32(-qmax, qmax)).collect();
                assert_eq!(unpack_features(pack_features(&xs, mode), mode), xs);
                assert_eq!(unpack_weights(pack_weights(&ws, mode), mode), ws);
            }
        }
    }

    #[test]
    fn partial_words_zero_padded() {
        // fewer pairs than lanes: remaining lanes multiply by 0
        let rs1 = pack_features(&[3, 5], Mode::W4);
        let rs2 = pack_weights(&[2, -1], Mode::W4);
        assert_eq!(compute(rs1, rs2, Mode::W4), 3 * 2 - 5);
    }

    #[test]
    fn extreme_weights() {
        // most-negative representable weights still decompose correctly
        for (mode, w) in [(Mode::W8, -127), (Mode::W16, -32767)] {
            let rs1 = pack_features(&[15], mode);
            let rs2 = pack_weights(&[w], mode);
            assert_eq!(compute(rs1, rs2, mode), 15 * w as i64);
        }
    }

    #[test]
    fn single_cycle_all_modes() {
        for mode in [Mode::W4, Mode::W8, Mode::W16] {
            assert_eq!(compute_cycles(mode), 1);
        }
    }
}
