//! The kernel-SVM co-processor (ISSUE 8): RBF/polynomial feature-map
//! evaluation + dual accumulate, behind the [`Cfu`] framework interface
//! at `funct7 = CFU_FUNCT7_KSVM`.
//!
//! Structure mirrors [`super::svm::SvmAccel`] with one extra stage: an
//! inner-product accumulator `acc` fed by `K_ACC` (squared distance for
//! RBF, dot product for poly — both reuse the eight 4×4 multipliers,
//! since inputs *and* support vectors are 4-bit unsigned), a fixed-point
//! kernel evaluator (`kernel::rbf_phi_of_d2` / `poly_phi_of_dot`)
//! triggered by `K_EVAL`, and the same `cur_sum`/`max_sum`/`max_id`
//! argmax registers finalized by `K_RES` with the bias riding as an
//! (input = KSCALE, weight = b_q) pair.
//!
//! All compute-cycle counts are data-independent (2 for the RBF
//! LUT+shift, `degree` for the poly multiply ladder), which is what
//! lets `program/cost.rs` derive an analytic bill for kernel programs.

use anyhow::{bail, Result};

use crate::isa::ksvm_ops::{self, kcfg};
use crate::kernel::{poly_phi_of_dot, rbf_phi_of_d2, Kernel, KernelParams, KSCALE};

use super::{Cfu, CfuOutput};

/// 4-bit lanes per `K_ACC` word (inputs and support vectors alike).
pub const KLANES: usize = 8;

#[derive(Debug, Clone, Default)]
pub struct KernelAccel {
    /// Configured kernel (None until `K_CFG kind` arrives).
    kind: Option<Kernel>,
    params: KernelParams,
    /// Inner-product accumulator of the support vector in flight.
    acc: i64,
    cur_sum: i64,
    cur_id: u32,
    max_sum: i64,
    max_id: u32,
    max_valid: bool,
    /// lifetime op counter (reports)
    pub ops: u64,
}

impl KernelAccel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Observable register state (tests and the cycle trace).
    pub fn registers(&self) -> (i64, i64, u32, i64, u32) {
        (self.acc, self.cur_sum, self.cur_id, self.max_sum, self.max_id)
    }

    fn cfg(&mut self, rs1: u32, rs2: u32) -> Result<CfuOutput> {
        match rs2 {
            kcfg::KIND => {
                self.kind = Some(match rs1 {
                    ksvm_ops::KIND_RBF => Kernel::Rbf,
                    ksvm_ops::KIND_POLY => Kernel::Poly,
                    other => bail!("ksvm: unknown kernel kind {other}"),
                })
            }
            kcfg::GAMMA => match self.kind {
                Some(Kernel::Rbf) => self.params.g2_q = rs1 as i32,
                _ => self.params.gamma_q = rs1 as i32,
            },
            kcfg::COEF0 => self.params.coef0_q = rs1 as i32,
            kcfg::DEGREE => self.params.degree = rs1,
            other => bail!("ksvm: unknown config register {other}"),
        }
        Ok(CfuOutput { value: 0, compute_cycles: 1 })
    }

    /// One pass of the eight-multiplier array: 8 input lanes against 8
    /// support-vector lanes.  Zero-padded tail lanes contribute 0 in
    /// both kernels ((0-0)² = 0·0 = 0).
    fn acc_step(&mut self, rs1: u32, rs2: u32) -> Result<CfuOutput> {
        let kind = match self.kind {
            Some(k) => k,
            None => bail!("ksvm: K_ACC before K_CFG kind"),
        };
        for lane in 0..KLANES {
            let x = ((rs1 >> (4 * lane)) & 0xf) as i64;
            let s = ((rs2 >> (4 * lane)) & 0xf) as i64;
            self.acc += match kind {
                Kernel::Rbf => (x - s) * (x - s),
                _ => x * s,
            };
        }
        debug_assert!(self.acc < 1 << 31, "acc overflowed the 32-bit accumulator");
        Ok(CfuOutput { value: 0, compute_cycles: 1 })
    }

    /// Evaluate phi from the accumulator, fold `alpha * phi` into the
    /// classifier score, and clear the accumulator for the next support
    /// vector.
    fn eval(&mut self, rs1: u32) -> Result<CfuOutput> {
        let alpha = rs1 as i32 as i64;
        let (phi, cycles) = match self.kind {
            Some(Kernel::Rbf) => (rbf_phi_of_d2(self.acc, self.params.g2_q), 2),
            Some(Kernel::Poly) => {
                (poly_phi_of_dot(self.acc, &self.params), self.params.degree.max(1) as u64)
            }
            _ => bail!("ksvm: K_EVAL before K_CFG kind"),
        };
        self.cur_sum += alpha * phi;
        debug_assert!(
            self.cur_sum.abs() < (1 << 31),
            "cur_sum overflowed the 32-bit accumulator"
        );
        self.acc = 0;
        Ok(CfuOutput { value: 0, compute_cycles: cycles })
    }

    /// Finalize a classifier: `+ KSCALE * b_q`, then the identical
    /// strictly-greater argmax update and sign|max_id result word as
    /// the linear accelerator's `SV_Res*`.
    fn res(&mut self, rs1: u32) -> CfuOutput {
        let b = rs1 as i32 as i64;
        self.cur_sum += KSCALE * b;
        let score = self.cur_sum;
        if !self.max_valid || score > self.max_sum {
            self.max_sum = score;
            self.max_id = self.cur_id;
            self.max_valid = true;
        }
        let sign_bit = if score < 0 { 1u32 << 31 } else { 0 };
        let value = sign_bit | (self.max_id & 0xff);
        self.cur_sum = 0;
        self.acc = 0;
        self.cur_id = self.cur_id.wrapping_add(1);
        CfuOutput { value, compute_cycles: 1 }
    }
}

impl Cfu for KernelAccel {
    fn name(&self) -> &'static str {
        "kernel-svm-accelerator"
    }

    fn reset(&mut self) {
        // full reset, config registers included — programs re-issue
        // K_CFG in their prologue (the SoC rearm re-executes from the
        // start, so configuration is always re-established)
        *self = KernelAccel { ops: self.ops, ..KernelAccel::default() };
    }

    fn execute(&mut self, funct3: u8, rs1: u32, rs2: u32) -> Result<CfuOutput> {
        self.ops += 1;
        match funct3 {
            ksvm_ops::K_CFG => self.cfg(rs1, rs2),
            ksvm_ops::K_ACC => self.acc_step(rs1, rs2),
            ksvm_ops::K_EVAL => self.eval(rs1),
            ksvm_ops::K_RES => Ok(self.res(rs1)),
            ksvm_ops::K_ENV => {
                self.reset();
                Ok(CfuOutput { value: 0, compute_cycles: 1 })
            }
            other => bail!("ksvm accelerator: unknown funct3 {other}"),
        }
    }

    /// NAND2-equivalent estimate: the eight 4×4 multipliers are shared
    /// with the subtract stage (RBF distance), plus the 32-entry × 9-bit
    /// 2^-x LUT ROM, a barrel shifter, the poly clamp/multiply ladder
    /// reusing one 16×16 multiplier, and the argmax register file.
    fn nand2_equivalents(&self) -> u64 {
        let multipliers = 8 * 90;
        let sub_stage = 8 * 18; // 4-bit subtract + abs before squaring
        let lut_rom = 32 * 9; // ~1 NAND2 per ROM bit
        let shifter = 32 * 12; // barrel shift for the 2^-zi scaling
        let poly_ladder = 16 * 16; // shared multiplier + clamp compare
        let accumulator = 2 * 32 * 9; // acc + cur_sum adders
        let registers = 6 * 32 * 4 + 32 * 6;
        multipliers + sub_stage + lut_rom + shifter + poly_ladder + accumulator + registers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::ksvm_ops::*;
    use crate::kernel;

    fn pack4(vals: &[i32]) -> u32 {
        vals.iter().enumerate().fold(0u32, |w, (i, &v)| {
            assert!((0..=15).contains(&v));
            w | ((v as u32) << (4 * i))
        })
    }

    fn configure(a: &mut KernelAccel, kind: u32, p: &KernelParams) {
        a.execute(K_ENV, 0, 0).unwrap();
        a.execute(K_CFG, kind, kcfg::KIND).unwrap();
        let gamma = if kind == KIND_RBF { p.g2_q } else { p.gamma_q };
        a.execute(K_CFG, gamma as u32, kcfg::GAMMA).unwrap();
        a.execute(K_CFG, p.coef0_q as u32, kcfg::COEF0).unwrap();
        a.execute(K_CFG, p.degree, kcfg::DEGREE).unwrap();
    }

    #[test]
    fn rbf_op_stream_matches_spec() {
        let p = KernelParams { g2_q: 137, ..Default::default() };
        let mut a = KernelAccel::new();
        configure(&mut a, KIND_RBF, &p);
        let x = [3, 15, 0, 7, 9];
        let sv = [0, 15, 15, 1, 9];
        a.execute(K_ACC, pack4(&x), pack4(&sv)).unwrap();
        let alpha = -5i32;
        a.execute(K_EVAL, alpha as u32, 0).unwrap();
        let want = alpha as i64 * kernel::phi(Kernel::Rbf, &p, &x, &sv);
        assert_eq!(a.registers().1, want);
        assert_eq!(a.registers().0, 0, "K_EVAL must clear the accumulator");
    }

    #[test]
    fn poly_op_stream_matches_spec() {
        let p = KernelParams { gamma_q: 801, coef0_q: -300, degree: 3, ..Default::default() };
        let mut a = KernelAccel::new();
        configure(&mut a, KIND_POLY, &p);
        // 9 features: two K_ACC words, tail lanes zero-padded
        let x = [3, 15, 0, 7, 9, 1, 2, 3, 4];
        let sv = [0, 15, 15, 1, 9, 5, 6, 7, 8];
        a.execute(K_ACC, pack4(&x[..8]), pack4(&sv[..8])).unwrap();
        a.execute(K_ACC, pack4(&x[8..]), pack4(&sv[8..])).unwrap();
        a.execute(K_EVAL, 7, 0).unwrap();
        let want = 7 * kernel::phi(Kernel::Poly, &p, &x, &sv);
        assert_eq!(a.registers().1, want);
    }

    #[test]
    fn res_adds_kscale_bias_and_tracks_argmax() {
        let p = KernelParams { g2_q: 137, ..Default::default() };
        let mut a = KernelAccel::new();
        configure(&mut a, KIND_RBF, &p);
        // classifier 0: zero-distance support (phi = KSCALE), alpha 2
        a.execute(K_ACC, pack4(&[5]), pack4(&[5])).unwrap();
        a.execute(K_EVAL, 2, 0).unwrap();
        let r0 = a.execute(K_RES, 1, 0).unwrap().value;
        assert_eq!(r0 & 0xff, 0);
        assert_eq!(a.registers().3, 2 * KSCALE + KSCALE);
        // classifier 1: negative score -> sign bit, argmax stays 0
        a.execute(K_ACC, pack4(&[5]), pack4(&[5])).unwrap();
        a.execute(K_EVAL, (-3i32) as u32, 0).unwrap();
        let r1 = a.execute(K_RES, 0, 0).unwrap().value;
        assert_eq!(r1 >> 31, 1);
        assert_eq!(r1 & 0xff, 0);
    }

    #[test]
    fn zero_padded_lanes_contribute_nothing() {
        let p = KernelParams { g2_q: 137, ..Default::default() };
        let mut a = KernelAccel::new();
        configure(&mut a, KIND_RBF, &p);
        a.execute(K_ACC, pack4(&[7]), pack4(&[2])).unwrap();
        assert_eq!(a.registers().0, 25);
    }

    #[test]
    fn unconfigured_ops_rejected() {
        let mut a = KernelAccel::new();
        assert!(a.execute(K_ACC, 0, 0).is_err());
        assert!(a.execute(K_EVAL, 0, 0).is_err());
        assert!(a.execute(K_CFG, 9, kcfg::KIND).is_err(), "bad kind value");
        assert!(a.execute(0b110, 0, 0).is_err(), "unknown funct3");
    }

    #[test]
    fn env_resets_config_too() {
        let p = KernelParams { g2_q: 137, ..Default::default() };
        let mut a = KernelAccel::new();
        configure(&mut a, KIND_RBF, &p);
        a.execute(K_ENV, 0, 0).unwrap();
        assert!(a.execute(K_ACC, 0, 0).is_err(), "kind cleared by K_ENV");
    }
}
