//! Demo CFU #2 (funct7 = 2): a 32×32 multiply-accumulate unit.
//!
//! Demonstrates the framework's extensibility claim (paper §III-C:
//! "other non-conflicting values (e.g., funct7 = 2, 3, etc.) could be
//! assigned to additional custom accelerators").  This is the generic
//! MAC SERV lacks (no M extension): op 0 accumulates rs1*rs2, op 1
//! reads the accumulator, op 2 clears it.

use anyhow::{bail, Result};

use super::{Cfu, CfuOutput};

pub const OP_MAC: u8 = 0;
pub const OP_READ: u8 = 1;
pub const OP_CLEAR: u8 = 2;

/// Compute cycles for one 32×32 multiply on the iterative (shift-add)
/// hardware multiplier this CFU models: one partial product per cycle.
const MUL_CYCLES: u64 = 32;

#[derive(Debug, Default)]
pub struct MacAccel {
    acc: u32,
    pub ops: u64,
}

impl MacAccel {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Cfu for MacAccel {
    fn name(&self) -> &'static str {
        "mac32"
    }

    fn reset(&mut self) {
        self.acc = 0;
    }

    fn execute(&mut self, funct3: u8, rs1: u32, rs2: u32) -> Result<CfuOutput> {
        self.ops += 1;
        Ok(match funct3 {
            OP_MAC => {
                // low 32 bits of the product are sign-agnostic
                self.acc = self.acc.wrapping_add(rs1.wrapping_mul(rs2));
                CfuOutput { value: 0, compute_cycles: MUL_CYCLES }
            }
            OP_READ => CfuOutput { value: self.acc, compute_cycles: 1 },
            OP_CLEAR => {
                self.acc = 0;
                CfuOutput { value: 0, compute_cycles: 1 }
            }
            other => bail!("mac32: unknown funct3 {other}"),
        })
    }

    fn nand2_equivalents(&self) -> u64 {
        // iterative multiplier (32-bit adder + control) + accumulator
        32 * 9 + 32 * 4 + 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_accumulates_signed_products() {
        let mut m = MacAccel::new();
        m.execute(OP_MAC, 7, (-3i32) as u32, ).unwrap();
        m.execute(OP_MAC, 2, 10).unwrap();
        let v = m.execute(OP_READ, 0, 0).unwrap().value;
        assert_eq!(v as i32, -21 + 20);
        m.execute(OP_CLEAR, 0, 0).unwrap();
        assert_eq!(m.execute(OP_READ, 0, 0).unwrap().value, 0);
    }

    #[test]
    fn wrapping_behaviour() {
        let mut m = MacAccel::new();
        m.execute(OP_MAC, u32::MAX, 2).unwrap();
        assert_eq!(m.execute(OP_READ, 0, 0).unwrap().value, u32::MAX - 1);
    }
}
