//! Demo CFU #3 (funct7 = 3): single-cycle popcount — the primitive a
//! binary-neural-network classifier needs (paper ref [4] deploys BNNs
//! on flexible substrates).  op 0: popcount(rs1) + rs2 (fused
//! accumulate form, so a BNN inner loop is one instruction per word).

use anyhow::{bail, Result};

use super::{Cfu, CfuOutput};

pub const OP_POPCNT_ACC: u8 = 0;
pub const OP_XNOR_POPCNT: u8 = 1;

#[derive(Debug, Default)]
pub struct PopcountAccel;

impl PopcountAccel {
    pub fn new() -> Self {
        Self
    }
}

impl Cfu for PopcountAccel {
    fn name(&self) -> &'static str {
        "popcount"
    }

    fn reset(&mut self) {}

    fn execute(&mut self, funct3: u8, rs1: u32, rs2: u32) -> Result<CfuOutput> {
        Ok(match funct3 {
            OP_POPCNT_ACC => {
                CfuOutput { value: rs1.count_ones() + rs2, compute_cycles: 1 }
            }
            OP_XNOR_POPCNT => {
                // BNN dot product: popcount(xnor(a, b))
                CfuOutput { value: (!(rs1 ^ rs2)).count_ones(), compute_cycles: 1 }
            }
            other => bail!("popcount: unknown funct3 {other}"),
        })
    }

    fn nand2_equivalents(&self) -> u64 {
        // adder tree of 32 inputs
        32 * 5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn popcount_accumulate() {
        let mut p = PopcountAccel::new();
        assert_eq!(p.execute(OP_POPCNT_ACC, 0xff, 10).unwrap().value, 18);
        assert_eq!(p.execute(OP_POPCNT_ACC, 0, 0).unwrap().value, 0);
    }

    #[test]
    fn xnor_popcount() {
        let mut p = PopcountAccel::new();
        assert_eq!(p.execute(OP_XNOR_POPCNT, 0xffff_ffff, 0xffff_ffff).unwrap().value, 32);
        assert_eq!(p.execute(OP_XNOR_POPCNT, 0, 0xffff_ffff).unwrap().value, 0);
    }
}
