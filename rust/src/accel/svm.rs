//! The paper's SVM co-processor (Fig. 6): PE + control registers +
//! the Fig. 8 instruction set, behind the [`Cfu`] framework interface.
//!
//! Internal registers (paper §IV-A):
//!   * `cur_sum` — running weighted sum of the classifier in flight;
//!   * `cur_id`  — id of that classifier;
//!   * `max_sum`/`max_id` — running argmax across classifiers (OvR),
//!     updated concurrently with the PE;
//!   * `max_valid` — one flip-flop marking whether any classifier has
//!     finalised since `Create_Env` (a minor micro-architectural
//!     refinement over the paper, which resets `max_sum` to zero: the
//!     flag makes the first `SV_Res*` unconditionally seed the maximum,
//!     so the argmax is exact even when every score is negative; the
//!     paper itself notes "minor deviations in ... design choices may
//!     exist", §III).
//!
//! The `SV_Res*` result word (paper §IV-A): bit 31 = sign of the
//! classifier's `cur_sum` (what OvO consumes), bits 7..0 = `max_id`
//! (what OvR consumes after the final classifier).

use anyhow::{bail, Result};

use crate::isa::svm_ops;

use super::pe::{self, Mode};
use super::{Cfu, CfuOutput};

/// Accumulator width guard: features ≤ 15, |weights| < 2^15, F ≤ 34 + bias
/// keeps |score| < 2^24, far inside i32 — checked at runtime anyway.
#[derive(Debug, Clone, Default)]
pub struct SvmAccel {
    cur_sum: i64,
    cur_id: u32,
    max_sum: i64,
    max_id: u32,
    max_valid: bool,
    /// lifetime op counter (reports)
    pub ops: u64,
}

impl SvmAccel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Observable register state (used by tests and the cycle trace).
    pub fn registers(&self) -> (i64, u32, i64, u32) {
        (self.cur_sum, self.cur_id, self.max_sum, self.max_id)
    }

    fn calc(&mut self, rs1: u32, rs2: u32, mode: Mode) -> CfuOutput {
        self.cur_sum += pe::compute(rs1, rs2, mode);
        debug_assert!(
            self.cur_sum.abs() < (1 << 31),
            "cur_sum overflowed the 32-bit accumulator"
        );
        CfuOutput { value: 0, compute_cycles: pe::compute_cycles(mode) }
    }

    fn res(&mut self) -> CfuOutput {
        let score = self.cur_sum;
        // concurrent argmax update (strictly-greater => first max wins)
        if !self.max_valid || score > self.max_sum {
            self.max_sum = score;
            self.max_id = self.cur_id;
            self.max_valid = true;
        }
        // unified 32-bit result: sign in MSB, class id in low 8 bits
        let sign_bit = if score < 0 { 1u32 << 31 } else { 0 };
        let value = sign_bit | (self.max_id & 0xff);
        self.cur_sum = 0;
        self.cur_id = self.cur_id.wrapping_add(1);
        CfuOutput { value, compute_cycles: 1 }
    }
}

impl Cfu for SvmAccel {
    fn name(&self) -> &'static str {
        "svm-accelerator"
    }

    fn reset(&mut self) {
        self.cur_sum = 0;
        self.cur_id = 0;
        self.max_sum = 0;
        self.max_id = 0;
        self.max_valid = false;
    }

    fn execute(&mut self, funct3: u8, rs1: u32, rs2: u32) -> Result<CfuOutput> {
        self.ops += 1;
        Ok(match funct3 {
            svm_ops::SV_CALC4 => self.calc(rs1, rs2, Mode::W4),
            svm_ops::SV_CALC8 => self.calc(rs1, rs2, Mode::W8),
            svm_ops::SV_CALC16 => self.calc(rs1, rs2, Mode::W16),
            svm_ops::SV_RES4 | svm_ops::SV_RES8 | svm_ops::SV_RES16 => self.res(),
            svm_ops::CREATE_ENV => {
                self.reset();
                CfuOutput { value: 0, compute_cycles: 1 }
            }
            other => bail!("svm accelerator: unknown funct3 {other}"),
        })
    }

    /// NAND2-equivalent estimate for the FlexIC area model: eight 4×4
    /// multipliers (~90 gates each), the sign-magnitude converters,
    /// shift-mux stage, a 32-bit adder/subtractor and four registers
    /// with compare logic — calibrated so the total is consistent with
    /// the paper's 5.82 mm² at Gen3 FlexIC density (see power/).
    fn nand2_equivalents(&self) -> u64 {
        let multipliers = 8 * 90;
        let signmag = 4 * 40;
        let shift_mux = 8 * 24;
        let accumulator = 32 * 9; // adder + sub select
        let registers = 4 * 32 * 4 + 32 * 6; // 4 regs + comparator
        multipliers + signmag + shift_mux + accumulator + registers
    }
}

/// Extract the OvO sign from an `SV_Res*` result word (bit 31 set =
/// negative score = vote for class j of the pair).
pub fn result_sign_negative(result: u32) -> bool {
    result >> 31 == 1
}

/// Extract the OvR running-argmax class id from an `SV_Res*` result.
pub fn result_class_id(result: u32) -> u32 {
    result & 0xff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::svm_ops::*;

    fn calc4(a: &mut SvmAccel, xs: &[u32], ws: &[i32]) {
        let rs1 = pe::pack_features(xs, Mode::W4);
        let rs2 = pe::pack_weights(ws, Mode::W4);
        a.execute(SV_CALC4, rs1, rs2).unwrap();
    }

    #[test]
    fn ovr_argmax_sequence() {
        let mut a = SvmAccel::new();
        a.execute(CREATE_ENV, 0, 0).unwrap();
        // classifier 0: score 10
        calc4(&mut a, &[5], &[2]);
        let r0 = a.execute(SV_RES4, 0, 0).unwrap().value;
        assert_eq!(result_class_id(r0), 0);
        assert!(!result_sign_negative(r0));
        // classifier 1: score 30 -> takes over
        calc4(&mut a, &[10], &[3]);
        let r1 = a.execute(SV_RES4, 0, 0).unwrap().value;
        assert_eq!(result_class_id(r1), 1);
        // classifier 2: score 20 -> max stays 1
        calc4(&mut a, &[10], &[2]);
        let r2 = a.execute(SV_RES4, 0, 0).unwrap().value;
        assert_eq!(result_class_id(r2), 1);
    }

    #[test]
    fn all_negative_scores_argmax_exact() {
        // the max_valid flag: argmax of [-10, -3, -7] must be 1
        let mut a = SvmAccel::new();
        a.execute(CREATE_ENV, 0, 0).unwrap();
        for (i, s) in [(-10i32, 0usize), (-3, 1), (-7, 2)].iter().zip(0..) {
            let _ = s;
            calc4(&mut a, &[1], &[i.0.clamp(-7, 7)]);
            // use multiple calcs to reach scores beyond 4-bit range
            while a.registers().0 != i.0 as i64 {
                let remaining = i.0 as i64 - a.registers().0;
                let step = remaining.clamp(-7, 7) as i32;
                calc4(&mut a, &[1], &[step]);
            }
            a.execute(SV_RES4, 0, 0).unwrap();
        }
        let (_, _, max_sum, max_id) = a.registers();
        assert_eq!(max_sum, -3);
        assert_eq!(max_id, 1);
    }

    #[test]
    fn ovo_sign_extraction() {
        let mut a = SvmAccel::new();
        a.execute(CREATE_ENV, 0, 0).unwrap();
        calc4(&mut a, &[3], &[-5]); // score -15
        let r = a.execute(SV_RES4, 0, 0).unwrap().value;
        assert!(result_sign_negative(r));
        calc4(&mut a, &[3], &[5]); // score +15
        let r = a.execute(SV_RES4, 0, 0).unwrap().value;
        assert!(!result_sign_negative(r));
        // zero counts as non-negative (votes class i)
        let r = a.execute(SV_RES4, 0, 0).unwrap().value;
        assert!(!result_sign_negative(r));
    }

    #[test]
    fn res_resets_cur_sum_and_increments_id() {
        let mut a = SvmAccel::new();
        a.execute(CREATE_ENV, 0, 0).unwrap();
        calc4(&mut a, &[7, 2], &[1, 1]);
        assert_eq!(a.registers().0, 9);
        a.execute(SV_RES4, 0, 0).unwrap();
        let (cur_sum, cur_id, _, _) = a.registers();
        assert_eq!(cur_sum, 0);
        assert_eq!(cur_id, 1);
    }

    #[test]
    fn create_env_resets_everything() {
        let mut a = SvmAccel::new();
        calc4(&mut a, &[7], &[7]);
        a.execute(SV_RES4, 0, 0).unwrap();
        a.execute(CREATE_ENV, 0, 0).unwrap();
        assert_eq!(a.registers(), (0, 0, 0, 0));
    }

    #[test]
    fn multi_precision_accumulation() {
        let mut a = SvmAccel::new();
        a.execute(CREATE_ENV, 0, 0).unwrap();
        let rs1 = pe::pack_features(&[9, 4], Mode::W16);
        let rs2 = pe::pack_weights(&[1000, -2000], Mode::W16);
        a.execute(SV_CALC16, rs1, rs2).unwrap();
        assert_eq!(a.registers().0, 9 * 1000 - 4 * 2000);
        let rs1 = pe::pack_features(&[1, 1, 1, 1], Mode::W8);
        let rs2 = pe::pack_weights(&[100, 100, -50, 0], Mode::W8);
        a.execute(SV_CALC8, rs1, rs2).unwrap();
        assert_eq!(a.registers().0, 1000 + 150);
    }

    #[test]
    fn unknown_funct3_rejected() {
        let mut a = SvmAccel::new();
        assert!(a.execute(0b011, 0, 0).is_err());
    }
}
