//! Serving-workload scenario generator: deterministic request streams
//! for the farm and coordinator benches.
//!
//! Three traffic shapes cover the serving stories the paper's far-edge
//! deployment implies:
//!
//!  * [`Traffic::Steady`] — Poisson arrivals at a target rate with a
//!    uniform config mix (the sustained-monitoring workload).
//!  * [`Traffic::Bursty`] — back-to-back bursts separated by idle gaps
//!    (event-driven sensors); the mean rate still equals `rps`.
//!  * [`Traffic::MultiTenant`] — Poisson arrivals with a Zipf-skewed
//!    config mix (many tenants, a few hot models) probing shard
//!    affinity and spill behaviour.
//!
//! A [`Scenario`] is a pure data object (seeded PCG32, no wall clock),
//! so benches replay identical streams across backends and shard
//! counts; [`Scenario::replay`] is the shared multi-threaded paced
//! replayer those benches drive (`bench_farm`, `bench_net`).
//!
//! [`Streaming`] models the device-scale workload the wearable
//! co-processor line of work implies (PAPERS.md, arxiv 2511.05985):
//! thousands of cheap sensors each holding one long-lived session,
//! aggregating a window of raw ticks into a 4-bit feature vector per
//! request, every device pinned to its config (per-device affinity).
//! Unlike [`Scenario`] it is not a materialised arrival list — with
//! 10k devices × many windows the stream is generated on the fly, one
//! deterministic feature vector per `(device, window)`.

use std::time::{Duration, Instant};

use crate::util::Pcg32;

/// Traffic shape; rates are requests/second of simulated arrival time.
#[derive(Debug, Clone, Copy)]
pub enum Traffic {
    /// Poisson arrivals, uniform config mix.
    Steady { rps: f64 },
    /// Bursts of `burst` simultaneous requests; exponential idle gaps
    /// sized so the long-run rate is `rps`.
    Bursty { rps: f64, burst: usize },
    /// Poisson arrivals; config `i` drawn with weight `1/(i+1)^skew`.
    MultiTenant { rps: f64, skew: f64 },
}

impl Traffic {
    pub fn name(&self) -> &'static str {
        match self {
            Traffic::Steady { .. } => "steady",
            Traffic::Bursty { .. } => "bursty",
            Traffic::MultiTenant { .. } => "multi_tenant",
        }
    }
}

/// One request arrival: offset from stream start + config index.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    pub at: Duration,
    pub config: usize,
}

/// A fully materialised request stream.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub traffic: Traffic,
    pub arrivals: Vec<Arrival>,
}

impl Scenario {
    /// Arrival-time span of the stream.
    pub fn duration(&self) -> Duration {
        self.arrivals.last().map(|a| a.at).unwrap_or(Duration::ZERO)
    }

    /// Requests per config (mix inspection).
    pub fn mix(&self, n_configs: usize) -> Vec<usize> {
        let mut counts = vec![0usize; n_configs];
        for a in &self.arrivals {
            counts[a.config] += 1;
        }
        counts
    }

    /// Replay the stream paced to its arrival times from `workers`
    /// threads (round-robin partition): `init(w)` builds per-worker
    /// state (an HTTP connection, nothing, ...), `f(state, i, arrival)`
    /// issues request `i`.  Returns the wall-clock span.  Shared by
    /// `bench_farm` and `bench_net` so the pacing logic lives once.
    pub fn replay<S, I, F>(&self, workers: usize, init: I, f: F) -> Duration
    where
        S: Send,
        I: Fn(usize) -> S + Sync,
        F: Fn(&mut S, usize, &Arrival) + Sync,
    {
        assert!(workers > 0);
        let start = Instant::now();
        std::thread::scope(|scope| {
            for w in 0..workers {
                let (init, f) = (&init, &f);
                scope.spawn(move || {
                    let mut state = init(w);
                    for (i, a) in self.arrivals.iter().enumerate().skip(w).step_by(workers) {
                        let target = start + a.at;
                        let now = Instant::now();
                        if target > now {
                            std::thread::sleep(target - now);
                        }
                        f(&mut state, i, a);
                    }
                });
            }
        });
        start.elapsed()
    }
}

/// Device-scale streaming workload: `n_devices` long-lived sessions,
/// each emitting one windowed feature vector per round to its affine
/// config.  Pure data — `net::drive_streaming` turns it into sockets.
#[derive(Debug, Clone, Copy)]
pub struct Streaming {
    /// Concurrent device sessions.
    pub n_devices: usize,
    /// Configs the device population is pinned across.
    pub n_configs: usize,
    /// Raw sensor ticks aggregated into each window's feature vector.
    pub samples_per_window: usize,
    seed: u64,
}

/// SplitMix64 finalizer: the stable hash behind device affinity and
/// per-window seeding.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Streaming {
    pub fn new(n_devices: usize, n_configs: usize, samples_per_window: usize, seed: u64) -> Streaming {
        assert!(n_devices > 0 && n_configs > 0 && samples_per_window > 0);
        Streaming { n_devices, n_configs, samples_per_window, seed }
    }

    /// The config this device's session is pinned to — stable across
    /// windows and runs (the affinity the farm's shard scheduler sees).
    pub fn config_of(&self, device: usize) -> usize {
        (mix64(self.seed ^ (device as u64).wrapping_mul(0xd134_2543_de82_ef95))
            % self.n_configs as u64) as usize
    }

    /// Windowed feature extraction for `(device, window)`: the device
    /// aggregates `samples_per_window` raw ticks of a noisy per-channel
    /// sensor around its own baseline into one mean, clamped to the
    /// 4-bit feature range the quantized models consume.  Deterministic
    /// per `(seed, device, window)` — both ends of a wire check can
    /// regenerate the exact vector.
    pub fn window_features(&self, device: usize, window: u64, n_features: usize) -> Vec<i32> {
        let mut rng = Pcg32::seeded(mix64(
            self.seed ^ mix64(device as u64) ^ window.wrapping_mul(0x2545_f491_4f6c_dd1d),
        ));
        (0..n_features)
            .map(|c| {
                // per-(device, channel) baseline: devices genuinely
                // differ, so the config's decision surface is exercised
                let baseline = (mix64(self.seed ^ ((device * 131 + c) as u64)) % 16) as i64;
                let sum: i64 = (0..self.samples_per_window)
                    .map(|_| baseline + rng.below(7) as i64 - 3)
                    .sum();
                (sum / self.samples_per_window as i64).clamp(0, 15) as i32
            })
            .collect()
    }
}

/// Exponential inter-arrival sample with the given rate (events/s).
fn exp_gap(rng: &mut Pcg32, rate: f64) -> f64 {
    // f64() is in [0, 1), so 1-u is in (0, 1] and ln() is finite
    -(1.0 - rng.f64()).ln() / rate
}

/// Generate `n` arrivals over `n_configs` configs.
pub fn generate(traffic: Traffic, n_configs: usize, n: usize, seed: u64) -> Scenario {
    assert!(n_configs > 0, "need at least one config");
    let mut rng = Pcg32::seeded(seed);
    let mut arrivals = Vec::with_capacity(n);
    let mut t = 0.0f64;
    match traffic {
        Traffic::Steady { rps } => {
            for _ in 0..n {
                t += exp_gap(&mut rng, rps);
                arrivals.push(Arrival { at: Duration::from_secs_f64(t), config: rng.below(n_configs as u32) as usize });
            }
        }
        Traffic::Bursty { rps, burst } => {
            let burst = burst.max(1);
            while arrivals.len() < n {
                // gap carries the whole burst's worth of mean spacing
                t += exp_gap(&mut rng, rps / burst as f64);
                let at = Duration::from_secs_f64(t);
                for _ in 0..burst.min(n - arrivals.len()) {
                    arrivals.push(Arrival { at, config: rng.below(n_configs as u32) as usize });
                }
            }
        }
        Traffic::MultiTenant { rps, skew } => {
            // cumulative Zipf weights over the config list
            let weights: Vec<f64> = (0..n_configs).map(|i| 1.0 / ((i + 1) as f64).powf(skew)).collect();
            let total: f64 = weights.iter().sum();
            let mut cdf = Vec::with_capacity(n_configs);
            let mut acc = 0.0;
            for w in &weights {
                acc += w / total;
                cdf.push(acc);
            }
            for _ in 0..n {
                t += exp_gap(&mut rng, rps);
                let u = rng.f64();
                let config = cdf.iter().position(|&c| u < c).unwrap_or(n_configs - 1);
                arrivals.push(Arrival { at: Duration::from_secs_f64(t), config });
            }
        }
    }
    Scenario { traffic, arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a = generate(Traffic::Steady { rps: 100.0 }, 3, 50, 7);
        let b = generate(Traffic::Steady { rps: 100.0 }, 3, 50, 7);
        assert_eq!(a.arrivals.len(), 50);
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.config, y.config);
        }
        let c = generate(Traffic::Steady { rps: 100.0 }, 3, 50, 8);
        assert!(a.arrivals.iter().zip(&c.arrivals).any(|(x, y)| x.at != y.at));
    }

    #[test]
    fn steady_rate_approximates_target() {
        let s = generate(Traffic::Steady { rps: 1000.0 }, 2, 4000, 1);
        let rate = s.arrivals.len() as f64 / s.duration().as_secs_f64();
        assert!((rate - 1000.0).abs() < 150.0, "observed rate {rate}");
        assert!(s.mix(2).iter().all(|&c| c > 0));
    }

    #[test]
    fn bursty_groups_share_timestamps_and_keep_rate() {
        let s = generate(Traffic::Bursty { rps: 1000.0, burst: 8 }, 2, 800, 2);
        assert_eq!(s.arrivals.len(), 800);
        // first burst: 8 identical timestamps
        let t0 = s.arrivals[0].at;
        assert!(s.arrivals[..8].iter().all(|a| a.at == t0));
        assert!(s.arrivals[8].at > t0);
        let rate = s.arrivals.len() as f64 / s.duration().as_secs_f64();
        assert!((rate - 1000.0).abs() < 250.0, "observed rate {rate}");
    }

    #[test]
    fn multi_tenant_skews_toward_first_config() {
        let s = generate(Traffic::MultiTenant { rps: 500.0, skew: 1.2 }, 4, 2000, 3);
        let mix = s.mix(4);
        assert_eq!(mix.iter().sum::<usize>(), 2000);
        assert!(mix[0] > mix[3] * 2, "mix {mix:?} should be Zipf-skewed");
    }

    #[test]
    fn replay_visits_every_arrival_once_with_per_worker_state() {
        let s = generate(Traffic::Steady { rps: 1e6 }, 2, 40, 9);
        let hits = std::sync::Mutex::new(vec![0u32; 40]);
        let wall = s.replay(
            4,
            |w| w,
            |w, i, a| {
                assert!(a.config < 2);
                assert_eq!(i % 4, *w, "round-robin partition");
                hits.lock().unwrap()[i] += 1;
            },
        );
        assert!(hits.lock().unwrap().iter().all(|&h| h == 1), "every arrival replayed once");
        assert!(wall >= s.duration(), "pacing must wait out the schedule");
    }

    #[test]
    fn streaming_features_are_deterministic_4bit_and_device_specific() {
        let s = Streaming::new(100, 4, 8, 0xfeed);
        let a = s.window_features(7, 3, 6);
        let b = s.window_features(7, 3, 6);
        assert_eq!(a, b, "same (device, window) regenerates bit-identically");
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&v| (0..16).contains(&v)), "4-bit features: {a:?}");
        // windows and devices actually vary (not a constant stream)
        let windows: Vec<_> = (0..16).map(|w| s.window_features(7, w, 6)).collect();
        assert!(windows.windows(2).any(|p| p[0] != p[1]), "windows never vary");
        let devices: Vec<_> = (0..16).map(|d| s.window_features(d, 0, 6)).collect();
        assert!(devices.windows(2).any(|p| p[0] != p[1]), "devices never vary");
    }

    #[test]
    fn streaming_affinity_is_stable_and_covers_configs() {
        let s = Streaming::new(1000, 4, 8, 0xabcd);
        let mut mix = vec![0usize; 4];
        for d in 0..s.n_devices {
            let c = s.config_of(d);
            assert_eq!(c, s.config_of(d), "affinity must be stable");
            mix[c] += 1;
        }
        assert!(mix.iter().all(|&c| c > 100), "affinity mix too skewed: {mix:?}");
    }

    #[test]
    fn arrivals_are_time_ordered() {
        for traffic in [
            Traffic::Steady { rps: 200.0 },
            Traffic::Bursty { rps: 200.0, burst: 4 },
            Traffic::MultiTenant { rps: 200.0, skew: 1.0 },
        ] {
            let s = generate(traffic, 3, 300, 4);
            assert!(s.arrivals.windows(2).all(|w| w[0].at <= w[1].at), "{}", traffic.name());
        }
    }
}
