//! Accelerator farm: a sharded pool of cycle-level SoCs (SERV core +
//! SVM CFU) that serves classification requests as the coordinator's
//! third backend (`Backend::Accel`).
//!
//! Unlike the PJRT client, [`crate::program::run::ProgramRunner`] is
//! `Send` (the whole SoC is plain data and `Cfu: Send`), so the farm
//! runs N warm, model-loaded shards on OS threads:
//!
//!  * **Shards** — each shard thread owns one `ProgramRunner` per
//!    config it has served, kept warm across requests (no program
//!    regeneration or SoC rebuild on the hot path).  The generated
//!    program is compiled (block-translated) **once per config** at
//!    farm start — shards instantiate runners from the shared
//!    `Arc<CompiledProgram>`, so neither warm-up nor spill loads
//!    re-generate or re-decode anything, and `Soc::rearm` keeps the
//!    translation across requests.
//!  * **Affinity + least-loaded spill** — every config has a *home*
//!    shard (round-robin at startup); jobs go home unless the home
//!    queue is deeper than `spill_threshold`, in which case the
//!    least-loaded shard takes the job and lazily builds the runner
//!    (counted as a `model_loads` reload-churn event).
//!  * **Backpressure** — per-shard job queues are bounded
//!    (`queue_cap`); submission blocks when a queue is full, mirroring
//!    the coordinator's bounded-ingress contract
//!    (`ServerBuilder::queue_cap`).
//!  * **Graceful shutdown** — dropping the [`Farm`] enqueues a
//!    shutdown marker behind any queued work; shards finish in-flight
//!    jobs, answer them, and join.
//!
//! Every answer carries the simulated cycle count and FlexIC energy
//! (`power::FlexicModel`), so the serving layer can extend Table I's
//! speed/energy story to streaming workloads.  When
//! `calibrate_baseline` is set, the farm also runs the software-only
//! baseline program once per config (in the background, after the
//! shards are up) and exposes the calibrated cycles/inference for
//! accel-vs-baseline ratios under load; until that lands — and from
//! the very first request — the ratio is seeded from the closed-form
//! static estimate ([`crate::program::cost::baseline_estimate`]).
//!
//! **Fast path** (`FarmOpts::fastpath`, ISSUE 6 tentpole): at startup
//! the farm derives an [`AnalyticModel`] per config — prediction by
//! `svm::infer` at native speed, cycle/energy bill from the affine
//! cost law validated bit-exactly against the block-compiled SoC.
//! Requests then skip the shards entirely, except that every
//! `audit_rate`-th request per config still rides a shard and its
//! `CycleStats` must equal the analytic bill **bit-for-bit** (the
//! continuous differential audit).  Any mismatch — or a config whose
//! derivation failed — permanently demotes that config to full
//! simulation and surfaces in [`FastPathMetrics`].
//!
//! [`scenario`] generates the steady / bursty / multi-tenant request
//! streams the farm benches replay.

pub mod scenario;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::log as evlog;
use crate::obs::{BlockProfiler, ConfigProfile, Stage, StageSet};
use crate::power::FlexicModel;
use crate::program::cost::{baseline_estimate_cycles, AnalyticModel};
use crate::program::run::{CompiledProgram, ProgramRunner};
use crate::program::ProgramOpts;
use crate::serv::{CycleStats, TimingConfig};
use crate::svm::QuantModel;

/// Farm tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct FarmOpts {
    /// Number of shard threads (0 = one per available core, capped at 8).
    pub shards: usize,
    /// Bound of each shard's job queue; a full queue blocks submission
    /// (backpressure).
    pub queue_cap: usize,
    /// Home-shard queue depth above which a job spills to the
    /// least-loaded shard instead.
    pub spill_threshold: usize,
    /// SoC timing of the simulated hardware (paper: FE memory model).
    pub timing: TimingConfig,
    /// Program-generation options for the accelerated programs.
    pub program: ProgramOpts,
    /// Power model used for per-request energy accounting.
    pub power: FlexicModel,
    /// Run the software-only baseline program once per config so
    /// responses can be reported against the paper's "w/o accel"
    /// cycle count.  The (slow) calibration simulations run on a
    /// background thread after the shards are up; until each lands,
    /// [`Farm::baseline_cycles`] serves the closed-form static
    /// estimate.
    pub calibrate_baseline: bool,
    /// Serve requests from the analytic cost model
    /// ([`crate::program::cost::AnalyticModel`]) instead of simulating
    /// every one.  Configs whose model fails probe validation — or a
    /// later differential audit — transparently stay on full
    /// simulation.
    pub fastpath: bool,
    /// With `fastpath`, still simulate every Nth request per config
    /// and require the analytic bill to match the SoC's `CycleStats`
    /// bit-for-bit (0 disables auditing).  The first request per
    /// config is always audited.
    pub audit_rate: u64,
    /// Continuous profiler sampling: profile every Nth simulated job
    /// per config (0 disables).  A profiled job runs the exact same
    /// block-compiled simulation — the profiler only reads the cycle
    /// counters already maintained per step, so sampled answers stay
    /// bit-identical to unsampled ones.
    pub profile_rate: u64,
}

impl Default for FarmOpts {
    fn default() -> Self {
        FarmOpts {
            shards: 0,
            queue_cap: 256,
            spill_threshold: 4,
            timing: TimingConfig::flexic(),
            program: ProgramOpts::default(),
            power: FlexicModel::paper(),
            calibrate_baseline: true,
            fastpath: false,
            audit_rate: 16,
            profile_rate: 0,
        }
    }
}

/// Resolve a requested shard count (0 = auto) the same way
/// [`Farm::start`] does — exposed so reports can label runs.
pub fn resolve_shards(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
    }
}

/// How an answer was produced (the audit story in every response).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Full cycle-level simulation on a shard SoC.
    Sim,
    /// Analytic fast path: native prediction, closed-form cycle bill.
    Fast,
    /// Fast path, *and* this request was simulated too — the answer is
    /// the SoC's, checked bit-for-bit against the analytic bill.
    Audited,
}

impl ExecMode {
    /// Stable wire/trace label.
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Sim => "sim",
            ExecMode::Fast => "fast",
            ExecMode::Audited => "audited",
        }
    }

    pub fn from_name(s: &str) -> Option<ExecMode> {
        match s {
            "sim" => Some(ExecMode::Sim),
            "fast" => Some(ExecMode::Fast),
            "audited" => Some(ExecMode::Audited),
            _ => None,
        }
    }
}

/// One inference answer.
#[derive(Debug, Clone, Copy)]
pub struct AccelOutput {
    /// Predicted class id.
    pub pred: i32,
    /// SoC cycles for this inference (simulated or analytic — the
    /// differential audit keeps them bit-identical).
    pub cycles: u64,
    /// FlexIC energy for this inference in mJ (`cycles × T_clk × P`).
    pub energy_mj: f64,
    /// Which path produced this answer.
    pub mode: ExecMode,
    /// Wall-clock stage timings for this answer: `shard_wait` /
    /// `execute` for simulated jobs, `execute` alone for analytic
    /// ones, plus `audit` (the extra simulation) on audited requests.
    pub stages: StageSet,
}

/// Per-config fast-path state (lock-free; shared with nobody — the
/// shards never see it, only the routing front).
#[derive(Default)]
struct FastState {
    /// Requests routed so far (drives the 1-in-N audit cadence).
    seq: AtomicU64,
    /// Answers served analytically (audited requests count as shard
    /// jobs instead — the two never double-count).
    fast_jobs: AtomicU64,
    /// Cycles billed analytically.
    fast_cycles: AtomicU64,
    audits: AtomicU64,
    mismatches: AtomicU64,
    /// A failed audit poisons the config: all later requests simulate.
    poisoned: AtomicBool,
    /// Fault injection: extra exec cycles added to every analytic bill
    /// (tests use this to prove the audit trips the fallback).
    skew: AtomicU64,
}

struct FarmConfig {
    key: String,
    /// The served model (the fast path predicts with it natively).
    model: QuantModel,
    /// The accelerated program, generated and block-translated once;
    /// every shard's runner executes this shared compilation.
    program: Arc<CompiledProgram>,
    /// Home shard index (affinity: avoids reload churn).
    home: usize,
    /// Probe-validated analytic cost model (None: full sim only).
    analytic: Option<AnalyticModel>,
    /// Closed-form static estimate of the software-only baseline
    /// cycles — available from request one.
    baseline_est: f64,
    /// Measured baseline cycles, set by the background calibration
    /// thread when `calibrate_baseline` is on.
    baseline_cal: OnceLock<f64>,
    fast: FastState,
    /// Aggregated region profile (shards fold sampled runs in; the
    /// lock is off the hot path — only every `profile_rate`-th job
    /// touches it).
    profile: Mutex<ConfigProfile>,
    /// Simulated jobs seen for this config (drives the 1-in-N
    /// profiling cadence across all shards).
    profile_tick: AtomicU64,
}

/// What a shard answers with: the prediction plus the full simulated
/// stats vector, so audits can compare every lane — not just totals.
struct SimAnswer {
    pred: i32,
    stats: CycleStats,
    /// Wall-clock µs the job sat in the shard queue before execution.
    wait_us: u64,
    /// Wall-clock µs the simulation itself took.
    exec_us: u64,
}

struct Job {
    cfg: usize,
    features: Vec<i32>,
    /// When the job was submitted (drives the `shard_wait` stage).
    submitted: Instant,
    resp: mpsc::SyncSender<Result<SimAnswer>>,
}

enum ShardMsg {
    Job(Job),
    Shutdown,
}

/// Monotonic per-shard counters (lock-free snapshots).
#[derive(Default)]
struct ShardCounters {
    jobs: AtomicU64,
    sim_cycles: AtomicU64,
    model_loads: AtomicU64,
}

struct Shard {
    tx: mpsc::SyncSender<ShardMsg>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Queued + running jobs on this shard (scheduler load signal).
    depth: Arc<AtomicUsize>,
    counters: Arc<ShardCounters>,
}

/// Point-in-time farm statistics.
#[derive(Debug, Clone)]
pub struct FarmMetrics {
    pub shards: Vec<ShardMetrics>,
    /// Jobs routed away from their home shard by the load spill rule.
    pub spills: u64,
    /// Analytic fast-path counters (all zero with `fastpath` off).
    pub fast: FastPathMetrics,
}

#[derive(Debug, Clone)]
pub struct ShardMetrics {
    pub jobs: u64,
    pub sim_cycles: u64,
    /// Accelerated-program builds on this shard (home warm-up loads +
    /// lazy spill loads).
    pub model_loads: u64,
}

/// Aggregated fast-path/audit counters across a farm's configs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FastPathMetrics {
    /// Answers served from the analytic model (no simulation).
    pub fast_jobs: u64,
    /// Cycles billed analytically.
    pub fast_cycles: u64,
    /// Requests simulated *in addition* to the analytic bill for the
    /// differential audit (these count as shard jobs, not fast jobs).
    pub audits: u64,
    /// Audits where the SoC's answer diverged from the analytic bill.
    pub mismatches: u64,
    /// Configs serving on the fast path.
    pub fastpath_configs: u64,
    /// Configs demoted to full simulation by a failed audit.
    pub poisoned_configs: u64,
}

impl FastPathMetrics {
    /// Fold another snapshot in (multi-node aggregation).
    pub fn merge(&mut self, o: &FastPathMetrics) {
        self.fast_jobs += o.fast_jobs;
        self.fast_cycles += o.fast_cycles;
        self.audits += o.audits;
        self.mismatches += o.mismatches;
        self.fastpath_configs += o.fastpath_configs;
        self.poisoned_configs += o.poisoned_configs;
    }
}

impl FarmMetrics {
    /// All answered requests: simulated (shard) jobs + analytic ones.
    pub fn total_jobs(&self) -> u64 {
        self.shards.iter().map(|s| s.jobs).sum::<u64>() + self.fast.fast_jobs
    }

    pub fn total_sim_cycles(&self) -> u64 {
        self.shards.iter().map(|s| s.sim_cycles).sum()
    }
}

/// The shard pool.  Dropping the farm drains queued work and joins
/// every shard thread (and the background calibration thread).
pub struct Farm {
    configs: Arc<Vec<FarmConfig>>,
    index: HashMap<String, usize>,
    shards: Vec<Shard>,
    spills: AtomicU64,
    spill_threshold: usize,
    power: FlexicModel,
    audit_rate: u64,
    cal_join: Option<std::thread::JoinHandle<()>>,
}

impl Farm {
    /// Start a farm serving the given models.  Every config's home
    /// shard builds its accelerated program up front (warm start) and,
    /// with `fastpath` on, derives + probe-validates its analytic cost
    /// model; baseline calibration (when enabled) runs on a background
    /// thread so startup never waits on the slow software-only sims.
    pub fn start(models: Vec<(String, QuantModel)>, opts: FarmOpts) -> Result<Farm> {
        if models.is_empty() {
            bail!("farm needs at least one model");
        }
        let n_shards = resolve_shards(opts.shards);
        let mut index = HashMap::new();
        for (i, (key, _)) in models.iter().enumerate() {
            if index.insert(key.clone(), i).is_some() {
                bail!("duplicate config key {key:?}");
            }
        }

        // generate + block-translate each accelerated program exactly
        // once (in parallel across configs); shards share the
        // compilation through the Arc
        let compiled: Vec<Result<Arc<CompiledProgram>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = models
                .iter()
                .map(|(_, m)| scope.spawn(move || CompiledProgram::accelerated(m, opts.program)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("program compile panicked")).collect()
        });
        let mut configs: Vec<FarmConfig> = models
            .into_iter()
            .zip(compiled)
            .enumerate()
            .map(|(i, ((key, model), program))| -> Result<FarmConfig> {
                let program =
                    program.with_context(|| format!("compiling program for config {key:?}"))?;
                let baseline_est = baseline_estimate_cycles(&model, &opts.timing);
                Ok(FarmConfig {
                    key,
                    model,
                    program,
                    home: i % n_shards,
                    analytic: None,
                    baseline_est,
                    baseline_cal: OnceLock::new(),
                    fast: FastState::default(),
                    profile: Mutex::new(ConfigProfile::new()),
                    profile_tick: AtomicU64::new(0),
                })
            })
            .collect::<Result<_>>()?;

        // fast path: derive + probe-validate the analytic model per
        // config (in parallel — each derivation runs a few probe sims);
        // a config whose validation fails simply stays on full sim
        if opts.fastpath {
            let analytics: Vec<Option<AnalyticModel>> = std::thread::scope(|scope| {
                let handles: Vec<_> = configs
                    .iter()
                    .map(|c| {
                        scope.spawn(move || AnalyticModel::derive(&c.model, &c.program, opts.timing))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("cost derivation panicked")).collect()
            });
            for (c, a) in configs.iter_mut().zip(analytics) {
                c.analytic = a;
                if c.analytic.is_some() {
                    evlog::emit_cfg(evlog::Level::Info, "fastpath_on", &c.key, || {
                        "analytic cost model probe-validated; serving from the fast path".into()
                    });
                } else {
                    evlog::emit_cfg(evlog::Level::Warn, "fastpath_unavailable", &c.key, || {
                        "analytic cost model failed probe validation; full simulation".into()
                    });
                }
            }
        }
        let configs = Arc::new(configs);

        // Baseline calibration: one software-only inference per config
        // on a mid-scale input (the shift-add mul32 cost is dominated
        // by model shape, not operand values).  Runs in the background
        // — the static estimate serves ratios until each sim lands; a
        // sim failure just leaves the estimate in place.
        let cal_join = if opts.calibrate_baseline {
            let cfgs = Arc::clone(&configs);
            Some(
                std::thread::Builder::new().name("flexsvm-calibrate".into()).spawn(move || {
                    for c in cfgs.iter() {
                        if let Ok(cycles) = baseline_cycles_for(&c.model, opts.timing) {
                            let _ = c.baseline_cal.set(cycles);
                        }
                    }
                })?,
            )
        } else {
            None
        };

        let mut shards = Vec::with_capacity(n_shards);
        let mut readies = Vec::with_capacity(n_shards);
        for shard_idx in 0..n_shards {
            let (tx, rx) = mpsc::sync_channel::<ShardMsg>(opts.queue_cap.max(1));
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
            let depth = Arc::new(AtomicUsize::new(0));
            let counters = Arc::new(ShardCounters::default());
            let join = std::thread::Builder::new()
                .name(format!("flexsvm-shard-{shard_idx}"))
                .spawn({
                    let configs = Arc::clone(&configs);
                    let depth = Arc::clone(&depth);
                    let counters = Arc::clone(&counters);
                    move || shard_main(shard_idx, configs, opts, rx, depth, counters, ready_tx)
                })?;
            shards.push(Shard { tx, join: Some(join), depth, counters });
            readies.push(ready_rx);
        }
        for (i, ready) in readies.into_iter().enumerate() {
            ready.recv().with_context(|| format!("shard {i} died during warm-up"))??;
        }
        Ok(Farm {
            configs,
            index,
            shards,
            spills: AtomicU64::new(0),
            spill_threshold: opts.spill_threshold,
            power: opts.power,
            audit_rate: opts.audit_rate,
            cal_join,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Config keys this farm serves, in registration order.
    pub fn keys(&self) -> Vec<String> {
        self.configs.iter().map(|c| c.key.clone()).collect()
    }

    /// Software-only cycles/inference for a config: the measured
    /// calibration value once the background sim lands, the
    /// closed-form static estimate before that (so speedup ratios are
    /// available from request one).  None only for unknown keys.
    pub fn baseline_cycles(&self, key: &str) -> Option<f64> {
        self.index.get(key).map(|&i| {
            let c = &self.configs[i];
            c.baseline_cal.get().copied().unwrap_or(c.baseline_est)
        })
    }

    /// The power model the farm charges energy with.
    pub fn power(&self) -> &FlexicModel {
        &self.power
    }

    /// The compiled (generated + block-translated) program a config is
    /// served with — one per config, shared by every shard's runner.
    pub fn compiled(&self, key: &str) -> Option<Arc<CompiledProgram>> {
        self.index.get(key).map(|&i| Arc::clone(&self.configs[i].program))
    }

    pub fn metrics(&self) -> FarmMetrics {
        let mut fast = FastPathMetrics::default();
        for c in self.configs.iter() {
            fast.fast_jobs += c.fast.fast_jobs.load(Ordering::Relaxed);
            fast.fast_cycles += c.fast.fast_cycles.load(Ordering::Relaxed);
            fast.audits += c.fast.audits.load(Ordering::Relaxed);
            fast.mismatches += c.fast.mismatches.load(Ordering::Relaxed);
            let poisoned = c.fast.poisoned.load(Ordering::Relaxed);
            if c.analytic.is_some() && !poisoned {
                fast.fastpath_configs += 1;
            }
            if poisoned {
                fast.poisoned_configs += 1;
            }
        }
        FarmMetrics {
            shards: self
                .shards
                .iter()
                .map(|s| ShardMetrics {
                    jobs: s.counters.jobs.load(Ordering::Relaxed),
                    sim_cycles: s.counters.sim_cycles.load(Ordering::Relaxed),
                    model_loads: s.counters.model_loads.load(Ordering::Relaxed),
                })
                .collect(),
            spills: self.spills.load(Ordering::Relaxed),
            fast,
        }
    }

    /// Per-config guest-cycle profiles from the sampled continuous
    /// profiler (empty map with `profile_rate` 0 or before the first
    /// sampled job).  Configs with no samples yet are omitted.
    pub fn profiles(&self) -> HashMap<String, ConfigProfile> {
        self.configs
            .iter()
            .filter_map(|c| {
                let p = c.profile.lock().unwrap();
                if p.is_empty() {
                    None
                } else {
                    Some((c.key.clone(), p.clone()))
                }
            })
            .collect()
    }

    /// Affinity-with-spill scheduling: home shard unless its queue is
    /// deeper than the spill threshold, else the least-loaded shard.
    fn pick_shard(&self, home: usize, spill_threshold: usize) -> usize {
        let home_depth = self.shards[home].depth.load(Ordering::Relaxed);
        if home_depth <= spill_threshold {
            return home;
        }
        let mut best = home;
        let mut best_depth = home_depth;
        for (i, s) in self.shards.iter().enumerate() {
            let d = s.depth.load(Ordering::Relaxed);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        if best != home {
            self.spills.fetch_add(1, Ordering::Relaxed);
            evlog::emit_fmt(evlog::Level::Debug, "shard_spill", || {
                format!("home shard {home} depth {home_depth} > {spill_threshold}; spilled to {best}")
            });
        }
        best
    }

    /// Submit one job to a shard; returns the response receiver.
    /// Blocks when the chosen shard's queue is full (backpressure).
    fn submit(&self, cfg: usize, features: Vec<i32>) -> Result<mpsc::Receiver<Result<SimAnswer>>> {
        let shard = self.pick_shard(self.configs[cfg].home, self.spill_threshold);
        let (tx, rx) = mpsc::sync_channel(1);
        self.shards[shard].depth.fetch_add(1, Ordering::Relaxed);
        let job = Job { cfg, features, submitted: Instant::now(), resp: tx };
        if self.shards[shard].tx.send(ShardMsg::Job(job)).is_err() {
            self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
            bail!("shard {shard} is down");
        }
        Ok(rx)
    }

    fn output(&self, pred: i32, cycles: u64, mode: ExecMode, stages: StageSet) -> AccelOutput {
        AccelOutput { pred, cycles, energy_mj: self.power.energy_mj(cycles as f64), mode, stages }
    }

    /// Route one request: analytic fast path when the config has a
    /// live cost model (resolving inline, no shard round-trip), full
    /// simulation otherwise — and on the audit cadence, *both*.
    fn route(&self, cfg: usize, features: Vec<i32>) -> Result<Pending> {
        let c = &self.configs[cfg];
        if let Some(am) = &c.analytic {
            if !c.fast.poisoned.load(Ordering::Relaxed) {
                let n = c.fast.seq.fetch_add(1, Ordering::Relaxed);
                let audited = self.audit_rate > 0 && n % self.audit_rate == 0;
                let t0 = Instant::now();
                let answer = am.predict(&features);
                let fast_us = t0.elapsed().as_micros() as u64;
                return match answer {
                    // the analytic path rejects exactly what the sim
                    // path would (same validation) — answer inline
                    Err(e) => Ok(Pending::Ready(Err(e))),
                    Ok((pred, mut stats)) => {
                        stats.exec += c.fast.skew.load(Ordering::Relaxed);
                        if audited {
                            let rx = self.submit(cfg, features)?;
                            Ok(Pending::Audit { cfg, rx, pred, stats, fast_us })
                        } else {
                            c.fast.fast_jobs.fetch_add(1, Ordering::Relaxed);
                            c.fast.fast_cycles.fetch_add(stats.total(), Ordering::Relaxed);
                            let mut st = StageSet::new();
                            st.set(Stage::Execute, fast_us);
                            Ok(Pending::Ready(Ok(self.output(
                                pred,
                                stats.total(),
                                ExecMode::Fast,
                                st,
                            ))))
                        }
                    }
                };
            }
        }
        Ok(Pending::Sim(self.submit(cfg, features)?))
    }

    /// Wait out a routed request.  Outer error = transport failure;
    /// inner = the per-sample answer.  Audited requests compare the
    /// SoC's `CycleStats` to the analytic bill **bit-for-bit**; any
    /// divergence counts a mismatch and poisons the config (all later
    /// requests simulate) — the simulator's answer is returned either
    /// way, as ground truth.
    fn resolve(&self, p: Pending) -> Result<Result<AccelOutput>> {
        match p {
            Pending::Ready(r) => Ok(r),
            Pending::Sim(rx) => {
                let r = rx.recv().context("farm shard dropped the job")?;
                Ok(r.map(|a| {
                    let mut st = StageSet::new();
                    st.set(Stage::ShardWait, a.wait_us);
                    st.set(Stage::Execute, a.exec_us);
                    self.output(a.pred, a.stats.total(), ExecMode::Sim, st)
                }))
            }
            Pending::Audit { cfg, rx, pred, stats, fast_us } => {
                let c = &self.configs[cfg];
                c.fast.audits.fetch_add(1, Ordering::Relaxed);
                let r = rx.recv().context("farm shard dropped the job")?;
                Ok(match r {
                    Ok(a) => {
                        if a.pred != pred || a.stats != stats {
                            c.fast.mismatches.fetch_add(1, Ordering::Relaxed);
                            c.fast.poisoned.store(true, Ordering::Relaxed);
                            evlog::emit_cfg(evlog::Level::Error, "config_poisoned", &c.key, || {
                                format!(
                                    "differential audit mismatch: analytic pred={pred} \
                                     cycles={} vs SoC pred={} cycles={}; demoted to full sim",
                                    stats.total(),
                                    a.pred,
                                    a.stats.total()
                                )
                            });
                        }
                        // the analytic predict is the `execute` stage;
                        // the extra simulation is attributed to `audit`
                        let mut st = StageSet::new();
                        st.set(Stage::Execute, fast_us);
                        st.set(Stage::ShardWait, a.wait_us);
                        st.set(Stage::Audit, a.exec_us);
                        Ok(self.output(a.pred, a.stats.total(), ExecMode::Audited, st))
                    }
                    Err(e) => {
                        // the analytic model accepted what the SoC
                        // rejected: that is itself an audit failure
                        c.fast.mismatches.fetch_add(1, Ordering::Relaxed);
                        c.fast.poisoned.store(true, Ordering::Relaxed);
                        evlog::emit_cfg(evlog::Level::Error, "config_poisoned", &c.key, || {
                            format!(
                                "differential audit: SoC rejected a sample the analytic \
                                 model accepted ({e:#}); demoted to full sim"
                            )
                        });
                        Err(e)
                    }
                })
            }
        }
    }

    fn lookup(&self, key: &str) -> Result<usize> {
        self.index.get(key).copied().ok_or_else(|| anyhow!("config {key:?} not served"))
    }

    /// Classify one sample.
    pub fn predict(&self, key: &str, x: &[i32]) -> Result<AccelOutput> {
        let cfg = self.lookup(key)?;
        let p = self.route(cfg, x.to_vec())?;
        self.resolve(p)?
    }

    /// Classify a batch: fast-path samples answer inline, simulated
    /// ones fan out across shards; results come back in input order,
    /// **per sample** — one bad request (e.g. out-of-range features)
    /// fails alone instead of poisoning its batchmates.  The outer
    /// error covers submission/transport failures only.  Submission
    /// applies backpressure; collection never blocks a shard (per-job
    /// channels have room for the single answer).
    pub fn predict_batch(&self, key: &str, xs: &[Vec<i32>]) -> Result<Vec<Result<AccelOutput>>> {
        let cfg = self.lookup(key)?;
        let mut pending = Vec::with_capacity(xs.len());
        for x in xs {
            pending.push(self.route(cfg, x.clone())?);
        }
        let mut out = Vec::with_capacity(xs.len());
        for p in pending {
            out.push(self.resolve(p)?);
        }
        Ok(out)
    }

    /// Fault injection for tests and drills: add `extra_exec` cycles
    /// to every analytic bill of `key`, guaranteeing the next audit
    /// mismatches and demotes the config to full simulation.
    pub fn inject_analytic_skew(&self, key: &str, extra_exec: u64) -> Result<()> {
        let cfg = self.lookup(key)?;
        self.configs[cfg].fast.skew.store(extra_exec, Ordering::Relaxed);
        Ok(())
    }
}

/// A routed-but-unresolved request (fast answers carry no receiver).
enum Pending {
    Ready(Result<AccelOutput>),
    Sim(mpsc::Receiver<Result<SimAnswer>>),
    Audit {
        cfg: usize,
        rx: mpsc::Receiver<Result<SimAnswer>>,
        pred: i32,
        stats: CycleStats,
        /// Wall-clock µs of the analytic predict (the `execute` stage).
        fast_us: u64,
    },
}

impl Drop for Farm {
    fn drop(&mut self) {
        // the shutdown marker queues *behind* outstanding work, so
        // in-flight jobs are answered before the shard exits.
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
        if let Some(j) = self.cal_join.take() {
            let _ = j.join();
        }
    }
}

fn baseline_cycles_for(m: &QuantModel, timing: TimingConfig) -> Result<f64> {
    let mut runner = ProgramRunner::baseline(m, timing)?;
    let x = vec![7i32; m.n_features];
    let (_, stats) = runner.run_sample(&x)?;
    Ok(stats.total() as f64)
}

fn shard_main(
    shard_idx: usize,
    configs: Arc<Vec<FarmConfig>>,
    opts: FarmOpts,
    rx: mpsc::Receiver<ShardMsg>,
    depth: Arc<AtomicUsize>,
    counters: Arc<ShardCounters>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    // warm start: instantiate a runner over the shared compiled
    // program for every home config before reporting ready (no
    // first-request jank; no per-shard generation or re-decoding)
    let mut runners: HashMap<usize, ProgramRunner> = HashMap::new();
    let warm = (|| -> Result<()> {
        for (ci, c) in configs.iter().enumerate() {
            if c.home == shard_idx {
                counters.model_loads.fetch_add(1, Ordering::Relaxed);
                runners.insert(ci, ProgramRunner::from_compiled(&c.program, opts.timing)?);
            }
        }
        Ok(())
    })();
    let ok = warm.is_ok();
    let _ = ready.send(warm);
    if !ok {
        return;
    }

    while let Ok(msg) = rx.recv() {
        let job = match msg {
            ShardMsg::Job(j) => j,
            ShardMsg::Shutdown => break,
        };
        let picked = Instant::now();
        let wait_us = picked.saturating_duration_since(job.submitted).as_micros() as u64;
        let result = (|| -> Result<SimAnswer> {
            let runner = match runners.entry(job.cfg) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(v) => {
                    // spill load: this shard was not the config's home
                    // (still no re-compilation — the translation is shared)
                    counters.model_loads.fetch_add(1, Ordering::Relaxed);
                    let c = &configs[job.cfg];
                    v.insert(ProgramRunner::from_compiled(&c.program, opts.timing)?)
                }
            };
            let c = &configs[job.cfg];
            let sampled = opts.profile_rate > 0
                && c.profile_tick.fetch_add(1, Ordering::Relaxed) % opts.profile_rate == 0;
            let (pred, stats) = if sampled {
                // profiled run: identical simulation, plus per-block
                // cycle attribution folded into the config's profile
                let mut prof = BlockProfiler::new();
                let out = runner.run_sample_profiled(&job.features, &mut prof)?;
                c.profile.lock().unwrap().absorb(&prof, &c.program.built().regions);
                out
            } else {
                runner.run_sample(&job.features)?
            };
            counters.jobs.fetch_add(1, Ordering::Relaxed);
            counters.sim_cycles.fetch_add(stats.total(), Ordering::Relaxed);
            let exec_us = picked.elapsed().as_micros() as u64;
            Ok(SimAnswer { pred, stats, wait_us, exec_us })
        })();
        depth.fetch_sub(1, Ordering::Relaxed);
        let _ = job.resp.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::infer;
    use crate::testing::gen;

    fn tiny(key: &str, flip: bool) -> (String, QuantModel) {
        (key.to_string(), gen::tiny_model(key, flip))
    }

    fn fast_opts() -> FarmOpts {
        FarmOpts {
            shards: 2,
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            ..Default::default()
        }
    }

    #[test]
    fn farm_predicts_like_native() {
        let models = vec![tiny("a", false), tiny("b", true)];
        let farm = Farm::start(models.clone(), fast_opts()).unwrap();
        let xs: Vec<Vec<i32>> = vec![vec![15, 0, 3], vec![0, 15, 9], vec![9, 3, 7], vec![2, 11, 0]];
        for (key, m) in &models {
            let outs = farm.predict_batch(key, &xs).unwrap();
            for (x, o) in xs.iter().zip(outs) {
                let o = o.unwrap();
                assert_eq!(o.pred, infer::predict(m, x), "{key} {x:?}");
                assert!(o.cycles > 0);
                assert!(o.energy_mj > 0.0);
            }
        }
        let m = farm.metrics();
        assert_eq!(m.total_jobs(), 8);
        assert!(m.total_sim_cycles() > 0);
    }

    #[test]
    fn unknown_key_rejected() {
        let farm = Farm::start(vec![tiny("a", false)], fast_opts()).unwrap();
        assert!(farm.predict("nope", &[0, 0, 0]).is_err());
    }

    #[test]
    fn bad_features_answered_with_error_not_hang() {
        let farm = Farm::start(vec![tiny("a", false)], fast_opts()).unwrap();
        assert!(farm.predict("a", &[99, 0, 0]).is_err(), "out-of-range feature");
        assert!(farm.predict("a", &[1]).is_err(), "wrong arity");
        // shard still healthy afterwards
        assert!(farm.predict("a", &[1, 2, 3]).is_ok());
    }

    #[test]
    fn bad_sample_fails_alone_inside_a_batch() {
        let farm = Farm::start(vec![tiny("a", false)], fast_opts()).unwrap();
        let xs = vec![vec![3, 4, 5], vec![99, 0, 0], vec![5, 6, 7]];
        let outs = farm.predict_batch("a", &xs).unwrap();
        assert!(outs[0].is_ok());
        assert!(outs[1].is_err(), "only the invalid sample errors");
        assert!(outs[2].is_ok());
    }

    #[test]
    fn baseline_calibration_exposed() {
        let opts = FarmOpts { calibrate_baseline: true, ..fast_opts() };
        let farm = Farm::start(vec![tiny("a", false)], opts).unwrap();
        let base = farm.baseline_cycles("a").unwrap();
        let accel = farm.predict("a", &[8, 8, 8]).unwrap().cycles as f64;
        assert!(base > 0.0);
        // the software mul32 loop makes the baseline strictly slower
        assert!(base > accel, "baseline {base} vs accel {accel}");
        assert!(farm.baseline_cycles("nope").is_none());
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(Farm::start(vec![tiny("a", false), tiny("a", true)], fast_opts()).is_err());
    }

    #[test]
    fn shutdown_joins_cleanly_with_queued_work() {
        let farm = Farm::start(vec![tiny("a", false)], FarmOpts { queue_cap: 4, ..fast_opts() }).unwrap();
        // leave answered-but-uncollected receivers around, then drop
        let rx1 = farm.submit(0, vec![1, 2, 3]).unwrap();
        let rx2 = farm.submit(0, vec![3, 4, 5]).unwrap();
        drop(farm); // must drain both jobs, then join
        assert!(rx1.recv().unwrap().is_ok());
        assert!(rx2.recv().unwrap().is_ok());
    }

    #[test]
    fn translation_shared_and_no_per_request_reloads() {
        let farm = Farm::start(vec![tiny("a", false)], FarmOpts { shards: 1, ..fast_opts() }).unwrap();
        for _ in 0..24 {
            farm.predict("a", &[1, 2, 3]).unwrap();
        }
        let m = farm.metrics();
        assert_eq!(m.total_jobs(), 24);
        let loads: u64 = m.shards.iter().map(|s| s.model_loads).sum();
        assert_eq!(loads, 1, "one warm load; requests must not re-load or re-decode");
        // the shard's runner executes the farm's shared translation
        let c = farm.compiled("a").expect("served config has a compiled program");
        assert!(
            Arc::strong_count(c.decoded()) >= 2,
            "decoded program shared: the compiled program + the shard runner's SoC"
        );
        assert!(farm.compiled("nope").is_none());
    }

    #[test]
    fn exec_mode_names_round_trip() {
        for m in [ExecMode::Sim, ExecMode::Fast, ExecMode::Audited] {
            assert_eq!(ExecMode::from_name(m.name()), Some(m));
        }
        assert_eq!(ExecMode::from_name("warp"), None);
    }

    #[test]
    fn resolve_shards_auto_positive() {
        assert!(resolve_shards(0) >= 1);
        assert_eq!(resolve_shards(3), 3);
    }

    fn fastpath_opts(audit_rate: u64) -> FarmOpts {
        FarmOpts { fastpath: true, audit_rate, ..fast_opts() }
    }

    #[test]
    fn fastpath_predicts_and_bills_like_the_simulator() {
        let models = vec![tiny("a", false), tiny("b", true)];
        let fast = Farm::start(models.clone(), fastpath_opts(4)).unwrap();
        let slow = Farm::start(models.clone(), fast_opts()).unwrap();
        let mut rng = crate::util::Pcg32::seeded(0xfa51);
        for (key, m) in &models {
            for i in 0..8 {
                let x: Vec<i32> = (0..3).map(|_| rng.below(16) as i32).collect();
                let f = fast.predict(key, &x).unwrap();
                let s = slow.predict(key, &x).unwrap();
                assert_eq!(f.pred, infer::predict(m, &x), "{key} {x:?}");
                assert_eq!(f.cycles, s.cycles, "analytic bill == simulated bill ({key} {x:?})");
                let want = if i % 4 == 0 { ExecMode::Audited } else { ExecMode::Fast };
                assert_eq!(f.mode, want, "{key} request {i}");
                assert_eq!(s.mode, ExecMode::Sim);
            }
        }
        let m = fast.metrics();
        assert_eq!(m.fast.fast_jobs, 12, "6 of 8 per config served analytically");
        assert_eq!(m.fast.audits, 4, "requests 0 and 4 of each config audited");
        assert_eq!(m.fast.mismatches, 0);
        assert_eq!(m.fast.fastpath_configs, 2);
        assert_eq!(m.fast.poisoned_configs, 0);
        assert!(m.fast.fast_cycles > 0);
        assert_eq!(m.total_jobs(), 16, "fast answers count as jobs too");
    }

    #[test]
    fn audit_failure_poisons_config_and_surfaces() {
        let farm = Farm::start(vec![tiny("a", false)], fastpath_opts(2)).unwrap();
        farm.inject_analytic_skew("a", 7).unwrap();
        // request 0 is audited: the skewed bill diverges from the SoC
        // → mismatch, but the caller still gets the simulator's answer
        let o = farm.predict("a", &[1, 2, 3]).unwrap();
        assert_eq!(o.mode, ExecMode::Audited);
        assert_eq!(o.pred, infer::predict(&gen::tiny_model("a", false), &[1, 2, 3]));
        // ...and the poisoned config simulates from then on
        for _ in 0..3 {
            assert_eq!(farm.predict("a", &[1, 2, 3]).unwrap().mode, ExecMode::Sim);
        }
        let m = farm.metrics();
        assert_eq!(m.fast.audits, 1);
        assert_eq!(m.fast.mismatches, 1);
        assert_eq!(m.fast.poisoned_configs, 1);
        assert_eq!(m.fast.fastpath_configs, 0, "a poisoned config is not serving fast");
        assert_eq!(m.fast.fast_jobs, 0);
        assert_eq!(m.total_jobs(), 4, "audit + 3 fallback sims");
    }

    #[test]
    fn fastpath_validates_features_like_the_simulator() {
        // audit_rate 0: pure fast path, no simulation in the loop
        let farm = Farm::start(vec![tiny("a", false)], fastpath_opts(0)).unwrap();
        assert!(farm.predict("a", &[99, 0, 0]).is_err(), "out-of-range feature");
        assert!(farm.predict("a", &[1]).is_err(), "wrong arity");
        assert_eq!(farm.predict("a", &[1, 2, 3]).unwrap().mode, ExecMode::Fast);
        let m = farm.metrics();
        assert_eq!(m.fast.audits, 0);
        assert_eq!(m.total_jobs(), 1, "rejected requests are not jobs");
    }

    #[test]
    fn bad_sample_fails_alone_on_the_fast_path() {
        let farm = Farm::start(vec![tiny("a", false)], fastpath_opts(0)).unwrap();
        let xs = vec![vec![3, 4, 5], vec![99, 0, 0], vec![5, 6, 7]];
        let outs = farm.predict_batch("a", &xs).unwrap();
        assert!(outs[0].is_ok());
        assert!(outs[1].is_err(), "only the invalid sample errors");
        assert!(outs[2].is_ok());
    }

    #[test]
    fn outputs_carry_stage_timings() {
        let farm = Farm::start(vec![tiny("a", false)], fast_opts()).unwrap();
        let o = farm.predict("a", &[1, 2, 3]).unwrap();
        assert_eq!(o.mode, ExecMode::Sim);
        assert!(o.stages.get(Stage::ShardWait).is_some(), "sim jobs time the queue");
        assert!(o.stages.get(Stage::Execute).is_some(), "sim jobs time the simulation");
        assert!(o.stages.get(Stage::Audit).is_none());

        let ff = Farm::start(vec![tiny("a", false)], fastpath_opts(2)).unwrap();
        let o0 = ff.predict("a", &[1, 2, 3]).unwrap();
        assert_eq!(o0.mode, ExecMode::Audited, "first request always audited");
        assert!(o0.stages.get(Stage::Audit).is_some(), "the extra sim is the audit stage");
        let o1 = ff.predict("a", &[1, 2, 3]).unwrap();
        assert_eq!(o1.mode, ExecMode::Fast);
        assert!(o1.stages.get(Stage::Execute).is_some());
        assert!(o1.stages.get(Stage::ShardWait).is_none(), "no shard round trip on the fast path");
    }

    /// Kernel configs (ISSUE 8) serve end-to-end: analytic fast path
    /// derives, the differential audit never mismatches, calibration
    /// tolerates the missing software baseline (0 = unknown, never a
    /// fabricated ratio).
    #[test]
    fn kernel_configs_serve_on_the_fast_path_without_mismatches() {
        let models = vec![
            ("rbf".to_string(), gen::tiny_kernel_model("rbf", crate::kernel::Kernel::Rbf)),
            ("poly".to_string(), gen::tiny_kernel_model("poly", crate::kernel::Kernel::Poly)),
        ];
        let opts = FarmOpts { calibrate_baseline: true, ..fastpath_opts(4) };
        let farm = Farm::start(models.clone(), opts).unwrap();
        let mut rng = crate::util::Pcg32::seeded(0x4e53);
        for (key, m) in &models {
            for _ in 0..8 {
                let x: Vec<i32> = (0..3).map(|_| rng.below(16) as i32).collect();
                let o = farm.predict(key, &x).unwrap();
                assert_eq!(o.pred, infer::predict(m, &x), "{key} {x:?}");
                assert!(o.cycles > 0);
                assert!(o.energy_mj > 0.0);
            }
            assert_eq!(farm.baseline_cycles(key), Some(0.0), "no baseline program exists");
        }
        let m = farm.metrics();
        assert_eq!(m.fast.mismatches, 0, "kernel fast path must stay bit-exact");
        assert_eq!(m.fast.poisoned_configs, 0);
        assert_eq!(m.fast.fastpath_configs, 2);
        assert!(m.fast.fast_jobs > 0, "kernel configs must actually ride the fast path");
    }

    #[test]
    fn profiler_samples_and_aggregates_per_config() {
        let opts = FarmOpts { shards: 1, profile_rate: 2, ..fast_opts() };
        let farm = Farm::start(vec![tiny("a", false)], opts).unwrap();
        let off = Farm::start(vec![tiny("a", false)], FarmOpts { shards: 1, ..fast_opts() }).unwrap();
        for _ in 0..8 {
            let p = farm.predict("a", &[1, 2, 3]).unwrap();
            let q = off.predict("a", &[1, 2, 3]).unwrap();
            // sampling must not perturb answers or bills
            assert_eq!((p.pred, p.cycles), (q.pred, q.cycles));
        }
        let profs = farm.profiles();
        let p = profs.get("a").expect("sampled config has a profile");
        assert_eq!(p.sampled_runs, 4, "1-in-2 of 8 jobs sampled");
        assert!(p.total_cycles > 0);
        assert!(p.regions.contains_key("dot_loop"), "{:?}", p.regions);
        assert!(off.profiles().is_empty(), "profiling off: no profiles");
    }

    #[test]
    fn baseline_ratio_available_from_request_one() {
        // calibration off: the closed-form static estimate still
        // seeds the accel-vs-baseline ratio
        let farm = Farm::start(vec![tiny("a", false)], fast_opts()).unwrap();
        let est = farm.baseline_cycles("a").expect("estimate available immediately");
        let accel = farm.predict("a", &[8, 8, 8]).unwrap().cycles as f64;
        assert!(est > accel, "estimate {est} vs accel {accel}");
    }
}
