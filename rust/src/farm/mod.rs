//! Accelerator farm: a sharded pool of cycle-level SoCs (SERV core +
//! SVM CFU) that serves classification requests as the coordinator's
//! third backend (`Backend::Accel`).
//!
//! Unlike the PJRT client, [`crate::program::run::ProgramRunner`] is
//! `Send` (the whole SoC is plain data and `Cfu: Send`), so the farm
//! runs N warm, model-loaded shards on OS threads:
//!
//!  * **Shards** — each shard thread owns one `ProgramRunner` per
//!    config it has served, kept warm across requests (no program
//!    regeneration or SoC rebuild on the hot path).  The generated
//!    program is compiled (block-translated) **once per config** at
//!    farm start — shards instantiate runners from the shared
//!    `Arc<CompiledProgram>`, so neither warm-up nor spill loads
//!    re-generate or re-decode anything, and `Soc::rearm` keeps the
//!    translation across requests.
//!  * **Affinity + least-loaded spill** — every config has a *home*
//!    shard (round-robin at startup); jobs go home unless the home
//!    queue is deeper than `spill_threshold`, in which case the
//!    least-loaded shard takes the job and lazily builds the runner
//!    (counted as a `model_loads` reload-churn event).
//!  * **Backpressure** — per-shard job queues are bounded
//!    (`queue_cap`); submission blocks when a queue is full, mirroring
//!    the coordinator's bounded-ingress contract
//!    (`ServerBuilder::queue_cap`).
//!  * **Graceful shutdown** — dropping the [`Farm`] enqueues a
//!    shutdown marker behind any queued work; shards finish in-flight
//!    jobs, answer them, and join.
//!
//! Every answer carries the simulated cycle count and FlexIC energy
//! (`power::FlexicModel`), so the serving layer can extend Table I's
//! speed/energy story to streaming workloads.  When
//! `calibrate_baseline` is set, the farm also runs the software-only
//! baseline program once per config at startup (in parallel) and
//! exposes the calibrated cycles/inference for accel-vs-baseline
//! ratios under load.
//!
//! [`scenario`] generates the steady / bursty / multi-tenant request
//! streams the farm benches replay.

pub mod scenario;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, bail, Context, Result};

use crate::power::FlexicModel;
use crate::program::run::{CompiledProgram, ProgramRunner};
use crate::program::ProgramOpts;
use crate::serv::TimingConfig;
use crate::svm::QuantModel;

/// Farm tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct FarmOpts {
    /// Number of shard threads (0 = one per available core, capped at 8).
    pub shards: usize,
    /// Bound of each shard's job queue; a full queue blocks submission
    /// (backpressure).
    pub queue_cap: usize,
    /// Home-shard queue depth above which a job spills to the
    /// least-loaded shard instead.
    pub spill_threshold: usize,
    /// SoC timing of the simulated hardware (paper: FE memory model).
    pub timing: TimingConfig,
    /// Program-generation options for the accelerated programs.
    pub program: ProgramOpts,
    /// Power model used for per-request energy accounting.
    pub power: FlexicModel,
    /// Run the software-only baseline program once per config at
    /// startup so responses can be reported against the paper's
    /// "w/o accel" cycle count.  Costs one (slow) baseline simulation
    /// per config, run in parallel across configs.
    pub calibrate_baseline: bool,
}

impl Default for FarmOpts {
    fn default() -> Self {
        FarmOpts {
            shards: 0,
            queue_cap: 256,
            spill_threshold: 4,
            timing: TimingConfig::flexic(),
            program: ProgramOpts::default(),
            power: FlexicModel::paper(),
            calibrate_baseline: true,
        }
    }
}

/// Resolve a requested shard count (0 = auto) the same way
/// [`Farm::start`] does — exposed so reports can label runs.
pub fn resolve_shards(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
    }
}

/// One simulated inference answer.
#[derive(Debug, Clone, Copy)]
pub struct AccelOutput {
    /// Predicted class id.
    pub pred: i32,
    /// Simulated SoC cycles for this inference.
    pub cycles: u64,
    /// FlexIC energy for this inference in mJ (`cycles × T_clk × P`).
    pub energy_mj: f64,
}

struct FarmConfig {
    key: String,
    /// The accelerated program, generated and block-translated once;
    /// every shard's runner executes this shared compilation.
    program: Arc<CompiledProgram>,
    /// Home shard index (affinity: avoids reload churn).
    home: usize,
    /// Calibrated software-only cycles/inference (None when
    /// calibration is disabled).
    baseline_cycles: Option<f64>,
}

struct Job {
    cfg: usize,
    features: Vec<i32>,
    resp: mpsc::SyncSender<Result<AccelOutput>>,
}

enum ShardMsg {
    Job(Job),
    Shutdown,
}

/// Monotonic per-shard counters (lock-free snapshots).
#[derive(Default)]
struct ShardCounters {
    jobs: AtomicU64,
    sim_cycles: AtomicU64,
    model_loads: AtomicU64,
}

struct Shard {
    tx: mpsc::SyncSender<ShardMsg>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Queued + running jobs on this shard (scheduler load signal).
    depth: Arc<AtomicUsize>,
    counters: Arc<ShardCounters>,
}

/// Point-in-time farm statistics.
#[derive(Debug, Clone)]
pub struct FarmMetrics {
    pub shards: Vec<ShardMetrics>,
    /// Jobs routed away from their home shard by the load spill rule.
    pub spills: u64,
}

#[derive(Debug, Clone)]
pub struct ShardMetrics {
    pub jobs: u64,
    pub sim_cycles: u64,
    /// Accelerated-program builds on this shard (home warm-up loads +
    /// lazy spill loads).
    pub model_loads: u64,
}

impl FarmMetrics {
    pub fn total_jobs(&self) -> u64 {
        self.shards.iter().map(|s| s.jobs).sum()
    }

    pub fn total_sim_cycles(&self) -> u64 {
        self.shards.iter().map(|s| s.sim_cycles).sum()
    }
}

/// The shard pool.  Dropping the farm drains queued work and joins
/// every shard thread.
pub struct Farm {
    configs: Arc<Vec<FarmConfig>>,
    index: HashMap<String, usize>,
    shards: Vec<Shard>,
    spills: AtomicU64,
    spill_threshold: usize,
    power: FlexicModel,
}

impl Farm {
    /// Start a farm serving the given models.  Every config's home
    /// shard builds its accelerated program up front (warm start);
    /// baseline calibration (when enabled) runs in parallel across
    /// configs before the shards spin up.
    pub fn start(models: Vec<(String, QuantModel)>, opts: FarmOpts) -> Result<Farm> {
        if models.is_empty() {
            bail!("farm needs at least one model");
        }
        let n_shards = resolve_shards(opts.shards);
        let mut index = HashMap::new();
        for (i, (key, _)) in models.iter().enumerate() {
            if index.insert(key.clone(), i).is_some() {
                bail!("duplicate config key {key:?}");
            }
        }

        // Baseline calibration: one software-only inference per config
        // on a mid-scale input (the shift-add mul32 cost is dominated
        // by model shape, not operand values).  Parallel across
        // configs — each runner is independent.
        let mut baselines: Vec<Option<f64>> = vec![None; models.len()];
        if opts.calibrate_baseline {
            let results: Vec<Result<f64>> = std::thread::scope(|scope| {
                let handles: Vec<_> = models
                    .iter()
                    .map(|(_, m)| scope.spawn(move || baseline_cycles_for(m, opts.timing)))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("calibration panicked")).collect()
            });
            for (slot, r) in baselines.iter_mut().zip(results) {
                *slot = Some(r?);
            }
        }

        // generate + block-translate each accelerated program exactly
        // once (in parallel across configs, like calibration); shards
        // share the compilation through the Arc
        let compiled: Vec<Result<Arc<CompiledProgram>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = models
                .iter()
                .map(|(_, m)| scope.spawn(move || CompiledProgram::accelerated(m, opts.program)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("program compile panicked")).collect()
        });
        let configs: Vec<FarmConfig> = models
            .into_iter()
            .zip(baselines)
            .zip(compiled)
            .enumerate()
            .map(|(i, (((key, _), baseline_cycles), program))| -> Result<FarmConfig> {
                let program =
                    program.with_context(|| format!("compiling program for config {key:?}"))?;
                Ok(FarmConfig { key, program, home: i % n_shards, baseline_cycles })
            })
            .collect::<Result<_>>()?;
        let configs = Arc::new(configs);

        let mut shards = Vec::with_capacity(n_shards);
        let mut readies = Vec::with_capacity(n_shards);
        for shard_idx in 0..n_shards {
            let (tx, rx) = mpsc::sync_channel::<ShardMsg>(opts.queue_cap.max(1));
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
            let depth = Arc::new(AtomicUsize::new(0));
            let counters = Arc::new(ShardCounters::default());
            let join = std::thread::Builder::new()
                .name(format!("flexsvm-shard-{shard_idx}"))
                .spawn({
                    let configs = Arc::clone(&configs);
                    let depth = Arc::clone(&depth);
                    let counters = Arc::clone(&counters);
                    move || shard_main(shard_idx, configs, opts, rx, depth, counters, ready_tx)
                })?;
            shards.push(Shard { tx, join: Some(join), depth, counters });
            readies.push(ready_rx);
        }
        for (i, ready) in readies.into_iter().enumerate() {
            ready.recv().with_context(|| format!("shard {i} died during warm-up"))??;
        }
        Ok(Farm {
            configs,
            index,
            shards,
            spills: AtomicU64::new(0),
            spill_threshold: opts.spill_threshold,
            power: opts.power,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Config keys this farm serves, in registration order.
    pub fn keys(&self) -> Vec<String> {
        self.configs.iter().map(|c| c.key.clone()).collect()
    }

    /// Calibrated software-only cycles/inference for a config (None
    /// when calibration was disabled or the key is unknown).
    pub fn baseline_cycles(&self, key: &str) -> Option<f64> {
        self.index.get(key).and_then(|&i| self.configs[i].baseline_cycles)
    }

    /// The power model the farm charges energy with.
    pub fn power(&self) -> &FlexicModel {
        &self.power
    }

    /// The compiled (generated + block-translated) program a config is
    /// served with — one per config, shared by every shard's runner.
    pub fn compiled(&self, key: &str) -> Option<Arc<CompiledProgram>> {
        self.index.get(key).map(|&i| Arc::clone(&self.configs[i].program))
    }

    pub fn metrics(&self) -> FarmMetrics {
        FarmMetrics {
            shards: self
                .shards
                .iter()
                .map(|s| ShardMetrics {
                    jobs: s.counters.jobs.load(Ordering::Relaxed),
                    sim_cycles: s.counters.sim_cycles.load(Ordering::Relaxed),
                    model_loads: s.counters.model_loads.load(Ordering::Relaxed),
                })
                .collect(),
            spills: self.spills.load(Ordering::Relaxed),
        }
    }

    /// Affinity-with-spill scheduling: home shard unless its queue is
    /// deeper than the spill threshold, else the least-loaded shard.
    fn pick_shard(&self, home: usize, spill_threshold: usize) -> usize {
        let home_depth = self.shards[home].depth.load(Ordering::Relaxed);
        if home_depth <= spill_threshold {
            return home;
        }
        let mut best = home;
        let mut best_depth = home_depth;
        for (i, s) in self.shards.iter().enumerate() {
            let d = s.depth.load(Ordering::Relaxed);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        if best != home {
            self.spills.fetch_add(1, Ordering::Relaxed);
        }
        best
    }

    /// Submit one job; returns the response receiver.  Blocks when the
    /// chosen shard's queue is full (backpressure).
    fn submit(&self, cfg: usize, features: Vec<i32>) -> Result<mpsc::Receiver<Result<AccelOutput>>> {
        let shard = self.pick_shard(self.configs[cfg].home, self.spill_threshold);
        let (tx, rx) = mpsc::sync_channel(1);
        self.shards[shard].depth.fetch_add(1, Ordering::Relaxed);
        if self.shards[shard].tx.send(ShardMsg::Job(Job { cfg, features, resp: tx })).is_err() {
            self.shards[shard].depth.fetch_sub(1, Ordering::Relaxed);
            bail!("shard {shard} is down");
        }
        Ok(rx)
    }

    /// Classify one sample.
    pub fn predict(&self, key: &str, x: &[i32]) -> Result<AccelOutput> {
        let cfg = *self.index.get(key).ok_or_else(|| anyhow!("config {key:?} not served"))?;
        let rx = self.submit(cfg, x.to_vec())?;
        rx.recv().context("farm shard dropped the job")?
    }

    /// Classify a batch: samples fan out across shards and the results
    /// come back in input order, **per sample** — one bad request (e.g.
    /// out-of-range features) fails alone instead of poisoning its
    /// batchmates.  The outer error covers submission/transport
    /// failures only.  Submission applies backpressure; collection
    /// never blocks a shard (per-job channels have room for the single
    /// answer).
    pub fn predict_batch(&self, key: &str, xs: &[Vec<i32>]) -> Result<Vec<Result<AccelOutput>>> {
        let cfg = *self.index.get(key).ok_or_else(|| anyhow!("config {key:?} not served"))?;
        let mut pending = Vec::with_capacity(xs.len());
        for x in xs {
            pending.push(self.submit(cfg, x.clone())?);
        }
        let mut out = Vec::with_capacity(xs.len());
        for rx in pending {
            out.push(rx.recv().context("farm shard dropped the job")?);
        }
        Ok(out)
    }
}

impl Drop for Farm {
    fn drop(&mut self) {
        // the shutdown marker queues *behind* outstanding work, so
        // in-flight jobs are answered before the shard exits.
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }
}

fn baseline_cycles_for(m: &QuantModel, timing: TimingConfig) -> Result<f64> {
    let mut runner = ProgramRunner::baseline(m, timing)?;
    let x = vec![7i32; m.n_features];
    let (_, stats) = runner.run_sample(&x)?;
    Ok(stats.total() as f64)
}

fn shard_main(
    shard_idx: usize,
    configs: Arc<Vec<FarmConfig>>,
    opts: FarmOpts,
    rx: mpsc::Receiver<ShardMsg>,
    depth: Arc<AtomicUsize>,
    counters: Arc<ShardCounters>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    // warm start: instantiate a runner over the shared compiled
    // program for every home config before reporting ready (no
    // first-request jank; no per-shard generation or re-decoding)
    let mut runners: HashMap<usize, ProgramRunner> = HashMap::new();
    let warm = (|| -> Result<()> {
        for (ci, c) in configs.iter().enumerate() {
            if c.home == shard_idx {
                counters.model_loads.fetch_add(1, Ordering::Relaxed);
                runners.insert(ci, ProgramRunner::from_compiled(&c.program, opts.timing)?);
            }
        }
        Ok(())
    })();
    let ok = warm.is_ok();
    let _ = ready.send(warm);
    if !ok {
        return;
    }

    while let Ok(msg) = rx.recv() {
        let job = match msg {
            ShardMsg::Job(j) => j,
            ShardMsg::Shutdown => break,
        };
        let result = (|| -> Result<AccelOutput> {
            let runner = match runners.entry(job.cfg) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(v) => {
                    // spill load: this shard was not the config's home
                    // (still no re-compilation — the translation is shared)
                    counters.model_loads.fetch_add(1, Ordering::Relaxed);
                    let c = &configs[job.cfg];
                    v.insert(ProgramRunner::from_compiled(&c.program, opts.timing)?)
                }
            };
            let (pred, stats) = runner.run_sample(&job.features)?;
            let cycles = stats.total();
            counters.jobs.fetch_add(1, Ordering::Relaxed);
            counters.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
            Ok(AccelOutput { pred, cycles, energy_mj: opts.power.energy_mj(cycles as f64) })
        })();
        depth.fetch_sub(1, Ordering::Relaxed);
        let _ = job.resp.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::infer;
    use crate::testing::gen;

    fn tiny(key: &str, flip: bool) -> (String, QuantModel) {
        (key.to_string(), gen::tiny_model(key, flip))
    }

    fn fast_opts() -> FarmOpts {
        FarmOpts {
            shards: 2,
            timing: TimingConfig::ideal_mem(),
            calibrate_baseline: false,
            ..Default::default()
        }
    }

    #[test]
    fn farm_predicts_like_native() {
        let models = vec![tiny("a", false), tiny("b", true)];
        let farm = Farm::start(models.clone(), fast_opts()).unwrap();
        let xs: Vec<Vec<i32>> = vec![vec![15, 0, 3], vec![0, 15, 9], vec![9, 3, 7], vec![2, 11, 0]];
        for (key, m) in &models {
            let outs = farm.predict_batch(key, &xs).unwrap();
            for (x, o) in xs.iter().zip(outs) {
                let o = o.unwrap();
                assert_eq!(o.pred, infer::predict(m, x), "{key} {x:?}");
                assert!(o.cycles > 0);
                assert!(o.energy_mj > 0.0);
            }
        }
        let m = farm.metrics();
        assert_eq!(m.total_jobs(), 8);
        assert!(m.total_sim_cycles() > 0);
    }

    #[test]
    fn unknown_key_rejected() {
        let farm = Farm::start(vec![tiny("a", false)], fast_opts()).unwrap();
        assert!(farm.predict("nope", &[0, 0, 0]).is_err());
    }

    #[test]
    fn bad_features_answered_with_error_not_hang() {
        let farm = Farm::start(vec![tiny("a", false)], fast_opts()).unwrap();
        assert!(farm.predict("a", &[99, 0, 0]).is_err(), "out-of-range feature");
        assert!(farm.predict("a", &[1]).is_err(), "wrong arity");
        // shard still healthy afterwards
        assert!(farm.predict("a", &[1, 2, 3]).is_ok());
    }

    #[test]
    fn bad_sample_fails_alone_inside_a_batch() {
        let farm = Farm::start(vec![tiny("a", false)], fast_opts()).unwrap();
        let xs = vec![vec![3, 4, 5], vec![99, 0, 0], vec![5, 6, 7]];
        let outs = farm.predict_batch("a", &xs).unwrap();
        assert!(outs[0].is_ok());
        assert!(outs[1].is_err(), "only the invalid sample errors");
        assert!(outs[2].is_ok());
    }

    #[test]
    fn baseline_calibration_exposed() {
        let opts = FarmOpts { calibrate_baseline: true, ..fast_opts() };
        let farm = Farm::start(vec![tiny("a", false)], opts).unwrap();
        let base = farm.baseline_cycles("a").unwrap();
        let accel = farm.predict("a", &[8, 8, 8]).unwrap().cycles as f64;
        assert!(base > 0.0);
        // the software mul32 loop makes the baseline strictly slower
        assert!(base > accel, "baseline {base} vs accel {accel}");
        assert!(farm.baseline_cycles("nope").is_none());
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(Farm::start(vec![tiny("a", false), tiny("a", true)], fast_opts()).is_err());
    }

    #[test]
    fn shutdown_joins_cleanly_with_queued_work() {
        let farm = Farm::start(vec![tiny("a", false)], FarmOpts { queue_cap: 4, ..fast_opts() }).unwrap();
        // leave answered-but-uncollected receivers around, then drop
        let rx1 = farm.submit(0, vec![1, 2, 3]).unwrap();
        let rx2 = farm.submit(0, vec![3, 4, 5]).unwrap();
        drop(farm); // must drain both jobs, then join
        assert!(rx1.recv().unwrap().is_ok());
        assert!(rx2.recv().unwrap().is_ok());
    }

    #[test]
    fn translation_shared_and_no_per_request_reloads() {
        let farm = Farm::start(vec![tiny("a", false)], FarmOpts { shards: 1, ..fast_opts() }).unwrap();
        for _ in 0..24 {
            farm.predict("a", &[1, 2, 3]).unwrap();
        }
        let m = farm.metrics();
        assert_eq!(m.total_jobs(), 24);
        let loads: u64 = m.shards.iter().map(|s| s.model_loads).sum();
        assert_eq!(loads, 1, "one warm load; requests must not re-load or re-decode");
        // the shard's runner executes the farm's shared translation
        let c = farm.compiled("a").expect("served config has a compiled program");
        assert!(
            Arc::strong_count(c.decoded()) >= 2,
            "decoded program shared: the compiled program + the shard runner's SoC"
        );
        assert!(farm.compiled("nope").is_none());
    }

    #[test]
    fn resolve_shards_auto_positive() {
        assert!(resolve_shards(0) >= 1);
        assert_eq!(resolve_shards(3), 3);
    }
}
