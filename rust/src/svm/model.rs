//! Quantized SVM model, dataset and golden-vector loading from the
//! build-time artifacts emitted by `python/compile/aot.py`.

use std::fmt;
use std::path::{Path, PathBuf};
use std::str::FromStr;

use anyhow::{bail, Context, Error, Result};

use crate::kernel::{Kernel, KernelParams, KCLAMP, KSCALE};
use crate::util::Json;

/// Multi-class decomposition strategy (paper §IV-A).
///
/// Parsed/rendered via `FromStr`/`Display` like `engine::Backend` and
/// `kernel::Kernel` — one spelling for CLI flags, artifact JSON, and
/// config keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Ovr,
    Ovo,
}

impl FromStr for Strategy {
    type Err = Error;

    fn from_str(s: &str) -> Result<Strategy> {
        match s {
            "ovr" => Ok(Strategy::Ovr),
            "ovo" => Ok(Strategy::Ovo),
            _ => bail!("unknown strategy {s:?} (want ovr|ovo)"),
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Strategy::Ovr => "ovr",
            Strategy::Ovo => "ovo",
        })
    }
}

/// A quantized multi-class SVM — the bit-exact twin of
/// `python/compile/quantize.QuantModel`.
///
/// `kernel == Linear`: `weights` is [K][F] over the raw features.
/// `kernel == Rbf | Poly`: the model is a *kernel machine* — `support`
/// holds S quantized support vectors [S][F], `weights` is [K][S] (dual
/// coefficients over the integer feature map `kernel::phi`), and the
/// bias rides as `KSCALE * b_q`.
#[derive(Debug, Clone)]
pub struct QuantModel {
    pub dataset: String,
    pub strategy: Strategy,
    pub bits: u8,
    pub n_classes: usize,
    pub n_features: usize,
    /// linear: [K][F]; kernel: [K][S] — signed, |w| ≤ 2^(bits-1)-1.
    pub weights: Vec<Vec<i32>>,
    /// [K]
    pub biases: Vec<i32>,
    /// [K] (i, j) — for OvR, (k, k).
    pub pairs: Vec<(usize, usize)>,
    pub scale: f64,
    pub kernel: Kernel,
    /// [S][F] values 0..15 — empty for linear models.
    pub support: Vec<Vec<i32>>,
    pub kparams: KernelParams,
}

impl QuantModel {
    pub fn n_classifiers(&self) -> usize {
        self.weights.len()
    }

    pub fn n_support(&self) -> usize {
        self.support.len()
    }

    pub fn is_kernel(&self) -> bool {
        self.kernel != Kernel::Linear
    }

    pub fn config_key(&self) -> String {
        match self.kernel {
            Kernel::Linear => format!("{}_{}_w{}", self.dataset, self.strategy, self.bits),
            k => format!("{}_{}_{}_w{}", self.dataset, k, self.strategy, self.bits),
        }
    }

    pub fn from_json(j: &Json) -> Result<QuantModel> {
        let weights = j.get("weights")?.as_mat_i32()?;
        let biases = j.get("biases")?.as_vec_i32()?;
        let pairs: Vec<(usize, usize)> = j
            .get("pairs")?
            .as_arr()?
            .iter()
            .map(|p| {
                let p = p.as_arr()?;
                Ok((p[0].as_usize()?, p[1].as_usize()?))
            })
            .collect::<Result<_>>()?;
        // kernel fields are optional: pre-kernel artifacts stay loadable
        let kernel = match j.get("kernel") {
            Ok(k) => k.as_str()?.parse()?,
            Err(_) => Kernel::Linear,
        };
        let (support, kparams) = if kernel == Kernel::Linear {
            (Vec::new(), KernelParams::default())
        } else {
            let geti = |key: &str| -> Result<i32> { Ok(j.get(key)?.as_i64()? as i32) };
            (
                j.get("support")?.as_mat_i32()?,
                KernelParams {
                    g2_q: geti("g2_q")?,
                    gamma_q: geti("gamma_q")?,
                    coef0_q: geti("coef0_q")?,
                    degree: geti("degree")? as u32,
                },
            )
        };
        let m = QuantModel {
            dataset: j.get("dataset")?.as_str()?.to_string(),
            strategy: j.get("strategy")?.as_str()?.parse()?,
            bits: j.get("bits")?.as_i64()? as u8,
            n_classes: j.get("n_classes")?.as_usize()?,
            n_features: j.get("n_features")?.as_usize()?,
            weights,
            biases,
            pairs,
            scale: j.get("scale")?.as_f64()?,
            kernel,
            support,
            kparams,
        };
        m.validate()?;
        Ok(m)
    }

    pub fn load(path: &Path) -> Result<QuantModel> {
        Self::from_json(&Json::parse_file(path)?)
    }

    fn validate(&self) -> Result<()> {
        if !matches!(self.bits, 4 | 8 | 16) {
            bail!("bad bits {}", self.bits);
        }
        let k = self.weights.len();
        if self.biases.len() != k || self.pairs.len() != k {
            bail!("inconsistent classifier count");
        }
        // kernel machines: weight rows span the support set, not features
        let row_len = if self.is_kernel() { self.n_support() } else { self.n_features };
        let qmax = (1i32 << (self.bits - 1)) - 1;
        for row in &self.weights {
            if row.len() != row_len {
                bail!("weight row length {} != {}", row.len(), row_len);
            }
            if row.iter().any(|w| w.abs() > qmax) {
                bail!("weight exceeds {}-bit range", self.bits);
            }
        }
        if self.biases.iter().any(|b| b.abs() > qmax) {
            bail!("bias exceeds {}-bit range", self.bits);
        }
        for &(i, j) in &self.pairs {
            if i >= self.n_classes || j >= self.n_classes {
                bail!("pair ({i},{j}) out of class range");
            }
        }
        if self.is_kernel() {
            if self.support.is_empty() {
                bail!("kernel model without support vectors");
            }
            for sv in &self.support {
                if sv.len() != self.n_features {
                    bail!("support row length {} != n_features {}", sv.len(), self.n_features);
                }
                if sv.iter().any(|&v| !(0..=15).contains(&v)) {
                    bail!("support values must be 4-bit unsigned");
                }
            }
            // i32 headroom of the score accumulator (quantizer contract)
            let s = self.n_support() as i64;
            if s * qmax as i64 * KCLAMP + KSCALE * qmax as i64 >= 1 << 31 {
                bail!("S={} at {}-bit overflows the i32 score accumulator", s, self.bits);
            }
            match self.kernel {
                Kernel::Rbf if self.kparams.g2_q <= 0 => bail!("rbf model needs g2_q > 0"),
                Kernel::Poly if self.kparams.gamma_q <= 0 || self.kparams.degree == 0 => {
                    bail!("poly model needs gamma_q > 0 and degree >= 1")
                }
                _ => {}
            }
        }
        Ok(())
    }
}

/// The 4-bit-quantized held-out test set of a dataset.
#[derive(Debug, Clone)]
pub struct TestSet {
    pub name: String,
    pub n_classes: usize,
    pub n_features: usize,
    pub x_q: Vec<Vec<i32>>, // values 0..15
    pub y: Vec<i32>,
}

impl TestSet {
    pub fn from_json(j: &Json) -> Result<TestSet> {
        let t = TestSet {
            name: j.get("name")?.as_str()?.to_string(),
            n_classes: j.get("n_classes")?.as_usize()?,
            n_features: j.get("n_features")?.as_usize()?,
            x_q: j.get("x_q_test")?.as_mat_i32()?,
            y: j.get("y_test")?.as_vec_i32()?,
        };
        if t.x_q.len() != t.y.len() {
            bail!("x/y length mismatch");
        }
        if t.x_q.iter().flatten().any(|&v| !(0..=15).contains(&v)) {
            bail!("test features must be 4-bit unsigned");
        }
        Ok(t)
    }

    pub fn load(path: &Path) -> Result<TestSet> {
        Self::from_json(&Json::parse_file(path)?)
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// Golden cross-layer vectors (first N test samples with the integer
/// scores and predictions computed by the Python spec).
#[derive(Debug, Clone)]
pub struct Golden {
    pub config: String,
    pub x_q: Vec<Vec<i32>>,
    pub scores: Vec<Vec<i64>>,
    pub pred: Vec<i32>,
}

impl Golden {
    pub fn from_json(j: &Json) -> Result<Golden> {
        let scores = j
            .get("scores")?
            .as_arr()?
            .iter()
            .map(|r| r.as_arr()?.iter().map(|v| v.as_i64()).collect::<Result<Vec<_>>>())
            .collect::<Result<Vec<_>>>()?;
        Ok(Golden {
            config: j.get("config")?.as_str()?.to_string(),
            x_q: j.get("x_q")?.as_mat_i32()?,
            scores,
            pred: j.get("pred")?.as_vec_i32()?,
        })
    }

    pub fn load(path: &Path) -> Result<Golden> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

/// One (dataset, strategy, bits) entry of the artifact manifest.
#[derive(Debug, Clone)]
pub struct ConfigEntry {
    pub key: String,
    pub dataset: String,
    pub strategy: Strategy,
    pub kernel: Kernel,
    pub bits: u8,
    pub n_classes: usize,
    pub n_features: usize,
    pub n_classifiers: usize,
    pub weights_path: String,
    pub golden_path: String,
    /// batch size -> HLO text path
    pub hlo: Vec<(usize, String)>,
    pub accuracy: f64,
}

/// Artifact index (`artifacts/manifest.json`).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub configs: Vec<ConfigEntry>,
    pub datasets: Vec<(String, String)>, // name -> file
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&root.join("manifest.json"))
            .context("loading artifacts/manifest.json — run `make artifacts` first")?;
        let mut configs = Vec::new();
        for (key, c) in j.get("configs")?.as_obj()? {
            let mut hlo = Vec::new();
            for (b, p) in c.get("hlo")?.as_obj()? {
                hlo.push((b.parse::<usize>()?, p.as_str()?.to_string()));
            }
            hlo.sort();
            configs.push(ConfigEntry {
                key: key.clone(),
                dataset: c.get("dataset")?.as_str()?.to_string(),
                strategy: c.get("strategy")?.as_str()?.parse()?,
                // optional: manifests predating the kernel subsystem
                kernel: match c.get("kernel") {
                    Ok(k) => k.as_str()?.parse()?,
                    Err(_) => Kernel::Linear,
                },
                bits: c.get("bits")?.as_i64()? as u8,
                n_classes: c.get("n_classes")?.as_usize()?,
                n_features: c.get("n_features")?.as_usize()?,
                n_classifiers: c.get("n_classifiers")?.as_usize()?,
                weights_path: c.get("weights")?.as_str()?.to_string(),
                golden_path: c.get("golden")?.as_str()?.to_string(),
                hlo,
                accuracy: c.get("accuracy")?.as_f64()?,
            });
        }
        configs.sort_by(|a, b| a.key.cmp(&b.key));
        let mut datasets = Vec::new();
        for (name, d) in j.get("datasets")?.as_obj()? {
            datasets.push((name.clone(), d.get("file")?.as_str()?.to_string()));
        }
        Ok(Manifest { root: root.to_path_buf(), configs, datasets })
    }

    pub fn config(&self, key: &str) -> Result<&ConfigEntry> {
        self.configs
            .iter()
            .find(|c| c.key == key)
            .with_context(|| format!("config {key:?} not in manifest"))
    }

    pub fn model(&self, entry: &ConfigEntry) -> Result<QuantModel> {
        QuantModel::load(&self.root.join(&entry.weights_path))
    }

    pub fn golden(&self, entry: &ConfigEntry) -> Result<Golden> {
        Golden::load(&self.root.join(&entry.golden_path))
    }

    pub fn test_set(&self, dataset: &str) -> Result<TestSet> {
        let file = self
            .datasets
            .iter()
            .find(|(n, _)| n == dataset)
            .with_context(|| format!("dataset {dataset:?} not in manifest"))?;
        TestSet::load(&self.root.join(&file.1))
    }

    pub fn hlo_path(&self, entry: &ConfigEntry, batch: usize) -> Result<PathBuf> {
        let rel = entry
            .hlo
            .iter()
            .find(|(b, _)| *b == batch)
            .with_context(|| format!("no HLO artifact for batch {batch} in {}", entry.key))?;
        Ok(self.root.join(&rel.1))
    }
}

/// Default artifact root: `$FLEXSVM_ARTIFACTS` or `./artifacts`.
pub fn artifacts_root() -> PathBuf {
    std::env::var_os("FLEXSVM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_json() -> Json {
        Json::parse(
            r#"{"dataset":"toy","strategy":"ovo","bits":4,"n_classes":3,
                "n_features":2,"n_classifiers":3,
                "weights":[[1,-2],[3,4],[-5,6]],"biases":[0,-1,2],
                "pairs":[[0,1],[0,2],[1,2]],"scale":3.5}"#,
        )
        .unwrap()
    }

    fn kernel_model_json() -> Json {
        Json::parse(
            r#"{"dataset":"toy","strategy":"ovr","bits":4,"n_classes":2,
                "n_features":2,"n_classifiers":2,"kernel":"rbf",
                "weights":[[1,-2,3],[3,4,-1]],"biases":[0,-1],
                "pairs":[[0,0],[1,1]],"scale":3.5,
                "support":[[0,15],[7,7],[15,0]],
                "g2_q":137,"gamma_q":0,"coef0_q":0,"degree":0}"#,
        )
        .unwrap()
    }

    #[test]
    fn model_from_json() {
        let m = QuantModel::from_json(&model_json()).unwrap();
        assert_eq!(m.n_classifiers(), 3);
        assert_eq!(m.strategy, Strategy::Ovo);
        assert_eq!(m.config_key(), "toy_ovo_w4");
        assert_eq!(m.weights[2], vec![-5, 6]);
        // missing "kernel" key == pre-kernel artifact == linear
        assert_eq!(m.kernel, Kernel::Linear);
        assert!(!m.is_kernel());
    }

    #[test]
    fn kernel_model_from_json() {
        let m = QuantModel::from_json(&kernel_model_json()).unwrap();
        assert_eq!(m.kernel, Kernel::Rbf);
        assert_eq!(m.n_support(), 3);
        assert_eq!(m.kparams.g2_q, 137);
        assert_eq!(m.config_key(), "toy_rbf_ovr_w4");
    }

    #[test]
    fn kernel_model_validation() {
        // support values must be 4-bit unsigned
        let mut j = kernel_model_json();
        if let Json::Obj(m) = &mut j {
            m.insert("support".into(), Json::parse("[[0,16],[7,7],[15,0]]").unwrap());
        }
        assert!(QuantModel::from_json(&j).is_err());
        // rbf needs a positive exponent constant
        let mut j = kernel_model_json();
        if let Json::Obj(m) = &mut j {
            m.insert("g2_q".into(), Json::parse("0").unwrap());
        }
        assert!(QuantModel::from_json(&j).is_err());
    }

    #[test]
    fn strategy_round_trips_strings() {
        for s in [Strategy::Ovr, Strategy::Ovo] {
            assert_eq!(s.to_string().parse::<Strategy>().unwrap(), s);
        }
        assert!("ova".parse::<Strategy>().is_err());
    }

    #[test]
    fn model_validation_rejects_out_of_range() {
        let mut j = model_json();
        if let Json::Obj(m) = &mut j {
            m.insert("weights".into(), Json::parse("[[9,0],[0,0],[0,0]]").unwrap());
        }
        assert!(QuantModel::from_json(&j).is_err(), "9 exceeds 4-bit qmax 7");
    }

    #[test]
    fn testset_bounds_checked() {
        let j = Json::parse(
            r#"{"name":"t","n_classes":2,"n_features":1,
                "x_q_test":[[16]],"y_test":[0]}"#,
        )
        .unwrap();
        assert!(TestSet::from_json(&j).is_err());
    }
}
