//! Native quantized-SVM library: artifact loading, bit-exact integer
//! inference (the Rust twin of the Python spec), and operand packing
//! shared with the accelerated program generator.

pub mod infer;
pub mod model;
pub mod pack;

pub use model::{ConfigEntry, Golden, Manifest, QuantModel, Strategy, TestSet};
