//! Native integer SVM inference — the Rust twin of the Python spec
//! (`python/compile/quantize.py`): every layer (Pallas kernel, PJRT
//! graph, accelerator model, SERV program) must agree with this.

use crate::kernel::{self, KSCALE};

use super::model::{QuantModel, Strategy, TestSet};

/// The bias rides the PE as an (input = 15, weight = b_q) pair.
pub const XMAX: i64 = 15;

/// Integer classifier scores for one sample.
///
/// Linear: `x·w_k + 15*b_k`.  Kernel machines: the same accumulate over
/// the integer feature map — `phi·w_k + KSCALE*b_k` with `phi[s] =
/// K(x, sv_s)` (see `kernel::phi`); argmax/vote logic is shared.
pub fn scores(m: &QuantModel, x_q: &[i32]) -> Vec<i64> {
    assert_eq!(x_q.len(), m.n_features, "feature arity");
    if m.is_kernel() {
        let phi = kernel::feature_map(m.kernel, &m.kparams, &m.support, x_q);
        return m
            .weights
            .iter()
            .zip(&m.biases)
            .map(|(row, &b)| {
                row.iter().zip(&phi).map(|(&w, &p)| w as i64 * p).sum::<i64>()
                    + KSCALE * b as i64
            })
            .collect();
    }
    m.weights
        .iter()
        .zip(&m.biases)
        .map(|(row, &b)| {
            row.iter().zip(x_q).map(|(&w, &x)| w as i64 * x as i64).sum::<i64>() + XMAX * b as i64
        })
        .collect()
}

/// First-maximum argmax (ties -> lowest index), matching both
/// `jnp.argmax` and the accelerator's strictly-greater max_sum update.
pub fn argmax_first(vals: &[i64]) -> usize {
    let mut best = 0;
    for (i, &v) in vals.iter().enumerate().skip(1) {
        if v > vals[best] {
            best = i;
        }
    }
    best
}

/// OvO vote tally: classifier k for pair (i, j): score ≥ 0 votes i.
pub fn ovo_votes(m: &QuantModel, s: &[i64]) -> Vec<i64> {
    let mut votes = vec![0i64; m.n_classes];
    for (k, &(i, j)) in m.pairs.iter().enumerate() {
        if s[k] >= 0 {
            votes[i] += 1;
        } else {
            votes[j] += 1;
        }
    }
    votes
}

/// Predict the class of one quantized sample.
pub fn predict(m: &QuantModel, x_q: &[i32]) -> i32 {
    let s = scores(m, x_q);
    match m.strategy {
        Strategy::Ovr => argmax_first(&s) as i32,
        Strategy::Ovo => argmax_first(&ovo_votes(m, &s)) as i32,
    }
}

/// Accuracy over a test set.
pub fn accuracy(m: &QuantModel, t: &TestSet) -> f64 {
    let correct = t
        .x_q
        .iter()
        .zip(&t.y)
        .filter(|(x, &y)| predict(m, x) == y)
        .count();
    correct as f64 / t.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::model::Strategy;

    use crate::kernel::{Kernel, KernelParams};

    fn toy(strategy: Strategy) -> QuantModel {
        QuantModel {
            dataset: "toy".into(),
            strategy,
            bits: 4,
            n_classes: 3,
            n_features: 2,
            weights: vec![vec![7, 0], vec![0, 7], vec![-3, -3]],
            biases: vec![0, 0, 5],
            pairs: match strategy {
                Strategy::Ovr => vec![(0, 0), (1, 1), (2, 2)],
                Strategy::Ovo => vec![(0, 1), (0, 2), (1, 2)],
            },
            scale: 1.0,
            kernel: Kernel::Linear,
            support: Vec::new(),
            kparams: KernelParams::default(),
        }
    }

    #[test]
    fn scores_include_bias_times_xmax() {
        let m = toy(Strategy::Ovr);
        let s = scores(&m, &[2, 3]);
        assert_eq!(s, vec![14, 21, -15 + 75]);
    }

    #[test]
    fn ovr_argmax() {
        let m = toy(Strategy::Ovr);
        assert_eq!(predict(&m, &[15, 0]), 0); // scores 105, 0, 30
        assert_eq!(predict(&m, &[0, 15]), 1);
        assert_eq!(predict(&m, &[0, 0]), 2); // 0, 0, 75
    }

    #[test]
    fn argmax_tie_breaks_to_first() {
        assert_eq!(argmax_first(&[5, 5, 5]), 0);
        assert_eq!(argmax_first(&[1, 7, 7]), 1);
        assert_eq!(argmax_first(&[-3]), 0);
    }

    #[test]
    fn ovo_vote_path() {
        let m = toy(Strategy::Ovo);
        // x = [15, 0]: s = [105, 75+(-45)=30... recompute:
        // k0 (0 vs 1): 7*15=105 >= 0 -> vote 0
        // k1 (0 vs 2): 0*?; weights[1] = [0,7] -> 0 -> vote 0
        // k2 (1 vs 2): [-3,-3]·[15,0] + 75 = 30 -> vote 1
        let v = ovo_votes(&m, &scores(&m, &[15, 0]));
        assert_eq!(v, vec![2, 1, 0]);
        assert_eq!(predict(&m, &[15, 0]), 0);
    }

    #[test]
    fn ovo_zero_score_votes_first_of_pair() {
        let m = toy(Strategy::Ovo);
        let v = ovo_votes(&m, &[0, -1, -1]);
        // k0 zero -> vote 0; k1 neg -> vote 2; k2 neg -> vote 2
        assert_eq!(v, vec![1, 0, 2]);
    }

    fn toy_rbf() -> QuantModel {
        QuantModel {
            dataset: "toy".into(),
            strategy: Strategy::Ovr,
            bits: 4,
            n_classes: 2,
            n_features: 2,
            // duals over S=2 supports; nearest-support wins
            weights: vec![vec![7, 0], vec![0, 7]],
            biases: vec![0, 0],
            pairs: vec![(0, 0), (1, 1)],
            scale: 1.0,
            kernel: Kernel::Rbf,
            support: vec![vec![0, 0], vec![15, 15]],
            kparams: KernelParams { g2_q: 137, ..Default::default() },
        }
    }

    #[test]
    fn kernel_scores_follow_the_feature_map() {
        let m = toy_rbf();
        let phi = crate::kernel::feature_map(m.kernel, &m.kparams, &m.support, &[1, 1]);
        let s = scores(&m, &[1, 1]);
        assert_eq!(s, vec![7 * phi[0], 7 * phi[1]]);
        // a point at support 0 classifies as class 0, and vice versa
        assert_eq!(predict(&m, &[0, 0]), 0);
        assert_eq!(predict(&m, &[15, 15]), 1);
    }

    #[test]
    fn kernel_bias_rides_at_kscale() {
        let mut m = toy_rbf();
        m.weights = vec![vec![0, 0], vec![0, 0]];
        m.biases = vec![3, -2];
        assert_eq!(scores(&m, &[4, 9]), vec![3 * KSCALE, -2 * KSCALE]);
    }
}
