//! Operand packing for the accelerator's calc instructions.
//!
//! The feature stream of one classifier pass is `x[0..F]` followed by
//! the bias input `15`; the weight stream is `w_k[0..F]` followed by
//! `b_k`.  Both are chunked into `Mode::lanes()`-wide groups and packed
//! into 32-bit words (zero-padding the tail — zero lanes contribute
//! nothing).  The same packing is used by the accelerated SERV program
//! generator (data section) and the host-side emulation tests, so a
//! mismatch between program and accelerator is structurally impossible.

use crate::accel::pe::{pack_features, pack_weights, Mode};

use super::infer::XMAX;
use super::model::QuantModel;

/// The PE mode for a weight bit-width.
pub fn mode_for_bits(bits: u8) -> Mode {
    match bits {
        4 => Mode::W4,
        8 => Mode::W8,
        16 => Mode::W16,
        _ => panic!("unsupported bits {bits}"),
    }
}

/// Packed feature words for one sample (shared by all classifiers):
/// `x[0..F] ++ [15]`, chunked by mode lane count.
pub fn feature_words(x_q: &[i32], bits: u8) -> Vec<u32> {
    let mode = mode_for_bits(bits);
    let stream: Vec<u32> = x_q.iter().map(|&v| v as u32).chain([XMAX as u32]).collect();
    stream.chunks(mode.lanes()).map(|c| pack_features(c, mode)).collect()
}

/// Packed weight words for classifier `k`: `w_k[0..F] ++ [b_k]`.
pub fn weight_words(m: &QuantModel, k: usize) -> Vec<u32> {
    let mode = mode_for_bits(m.bits);
    let stream: Vec<i32> = m.weights[k].iter().copied().chain([m.biases[k]]).collect();
    stream.chunks(mode.lanes()).map(|c| pack_weights(c, mode)).collect()
}

/// Words per classifier pass = ceil((F + 1) / lanes).
pub fn words_per_classifier(n_features: usize, bits: u8) -> usize {
    let lanes = mode_for_bits(bits).lanes();
    (n_features + 1).div_ceil(lanes)
}

/// Flattened weight words for all K classifiers (row-major), as laid
/// out in the accelerated program's data section.
pub fn all_weight_words(m: &QuantModel) -> Vec<u32> {
    (0..m.n_classifiers()).flat_map(|k| weight_words(m, k)).collect()
}

// --- kernel-machine packing (KSVM CFU, ISSUE 8) -------------------------
//
// The K_ACC op always takes eight 4-bit lanes per word regardless of the
// model's weight bit-width (both operands are 4-bit unsigned), and there
// is no bias lane — the bias rides K_RES.  Dual coefficients travel as
// raw i32 data words, not packed lanes.

/// 4-bit lanes per `K_ACC` word.
pub const KERNEL_LANES: usize = 8;

fn pack_nibbles(vals: &[i32]) -> u32 {
    debug_assert!(vals.len() <= KERNEL_LANES);
    vals.iter().enumerate().fold(0u32, |w, (i, &v)| {
        debug_assert!((0..=15).contains(&v), "kernel lanes are 4-bit unsigned");
        w | ((v as u32) << (4 * i))
    })
}

/// Packed feature words of one sample for the kernel accelerator:
/// `x[0..F]` chunked 8 lanes per word, zero-padded tail.
pub fn kernel_feature_words(x_q: &[i32]) -> Vec<u32> {
    x_q.chunks(KERNEL_LANES).map(pack_nibbles).collect()
}

/// Packed words of support vector `s` — same layout as the features so
/// the two streams align lane for lane.
pub fn kernel_sv_words(m: &QuantModel, s: usize) -> Vec<u32> {
    m.support[s].chunks(KERNEL_LANES).map(pack_nibbles).collect()
}

/// Words per support vector = ceil(F / 8).
pub fn kernel_words_per_sv(n_features: usize) -> usize {
    n_features.div_ceil(KERNEL_LANES)
}

/// Flattened support-vector words (row-major), as laid out in the
/// kernel program's data section.
pub fn all_kernel_sv_words(m: &QuantModel) -> Vec<u32> {
    (0..m.n_support()).flat_map(|s| kernel_sv_words(m, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::pe;
    use crate::svm::model::Strategy;
    use crate::util::Pcg32;

    fn random_model(rng: &mut Pcg32, bits: u8, k: usize, f: usize) -> QuantModel {
        let qmax = (1i32 << (bits - 1)) - 1;
        QuantModel {
            dataset: "rand".into(),
            strategy: Strategy::Ovr,
            bits,
            n_classes: k,
            n_features: f,
            weights: (0..k)
                .map(|_| (0..f).map(|_| rng.range_i32(-qmax, qmax)).collect())
                .collect(),
            biases: (0..k).map(|_| rng.range_i32(-qmax, qmax)).collect(),
            pairs: (0..k).map(|i| (i, i)).collect(),
            scale: 1.0,
            kernel: crate::kernel::Kernel::Linear,
            support: Vec::new(),
            kparams: crate::kernel::KernelParams::default(),
        }
    }

    /// Property: streaming the packed words through the PE reproduces
    /// the integer score for every classifier — the packing and the PE
    /// datapath compose to the spec (`infer::scores`).
    #[test]
    fn packed_stream_through_pe_equals_scores() {
        let mut rng = Pcg32::seeded(77);
        for bits in [4u8, 8, 16] {
            for f in [1usize, 2, 4, 7, 8, 15, 34] {
                let m = random_model(&mut rng, bits, 3, f);
                let x: Vec<i32> = (0..f).map(|_| rng.below(16) as i32).collect();
                let fw = feature_words(&x, bits);
                assert_eq!(fw.len(), words_per_classifier(f, bits));
                let spec = crate::svm::infer::scores(&m, &x);
                let mode = mode_for_bits(bits);
                for k in 0..3 {
                    let ww = weight_words(&m, k);
                    assert_eq!(ww.len(), fw.len());
                    let sum: i64 =
                        fw.iter().zip(&ww).map(|(&a, &b)| pe::compute(a, b, mode)).sum();
                    assert_eq!(sum, spec[k], "bits={bits} f={f} k={k}");
                }
            }
        }
    }

    #[test]
    fn word_counts() {
        // iris: F=4, 4-bit -> (4+1)/8 -> 1 word; derm: F=34, 16-bit -> 18
        assert_eq!(words_per_classifier(4, 4), 1);
        assert_eq!(words_per_classifier(34, 4), 5);
        assert_eq!(words_per_classifier(34, 8), 9);
        assert_eq!(words_per_classifier(34, 16), 18);
        assert_eq!(words_per_classifier(7, 4), 1);
    }

    #[test]
    fn all_weight_words_layout() {
        let mut rng = Pcg32::seeded(5);
        let m = random_model(&mut rng, 8, 4, 6);
        let all = all_weight_words(&m);
        let per = words_per_classifier(6, 8);
        assert_eq!(all.len(), 4 * per);
        assert_eq!(&all[per..2 * per], weight_words(&m, 1).as_slice());
    }

    #[test]
    fn kernel_words_pack_eight_lanes_no_bias() {
        // 9 features -> 2 words, second word only lane 0 populated
        let x: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 8, 9];
        let fw = kernel_feature_words(&x);
        assert_eq!(fw.len(), kernel_words_per_sv(9));
        assert_eq!(fw.len(), 2);
        assert_eq!(fw[0], 0x87654321);
        assert_eq!(fw[1], 0x9);
        // exact multiple: no padding word
        assert_eq!(kernel_feature_words(&x[..8]).len(), 1);
    }

    #[test]
    fn kernel_sv_words_align_with_features() {
        let mut rng = Pcg32::seeded(9);
        let mut m = random_model(&mut rng, 8, 2, 11);
        m.kernel = crate::kernel::Kernel::Rbf;
        m.support = (0..3)
            .map(|_| (0..11).map(|_| rng.below(16) as i32).collect())
            .collect();
        let all = all_kernel_sv_words(&m);
        let per = kernel_words_per_sv(11);
        assert_eq!(all.len(), 3 * per);
        assert_eq!(&all[per..2 * per], kernel_sv_words(&m, 1).as_slice());
        // unpack round-trips lane by lane against the raw support vector
        for (lane, &v) in m.support[1].iter().enumerate() {
            let word = all[per + lane / KERNEL_LANES];
            assert_eq!(((word >> (4 * (lane % KERNEL_LANES))) & 0xf) as i32, v);
        }
    }
}
