//! The serving front: a `coordinator::Server` behind a `TcpListener`.
//!
//! Two interchangeable fronts speak the same HTTP/1.1 + routing stack
//! (selected by [`NetOpts::front`], CLI `--net-front pool|epoll`):
//!
//!  * **pool** — one acceptor thread pushes accepted connections into
//!    a bounded queue drained by a fixed worker pool; each worker
//!    blocks on its connection.  Concurrency is capped at the pool
//!    size; the fallback and the non-Linux default.
//!  * **epoll** (`net::evloop`, Linux) — a handful of event threads
//!    hold tens of thousands of non-blocking keep-alive sockets in an
//!    epoll readiness loop, feeding bytes to the incremental
//!    `net::http::Parser` and polling in-flight coordinator work via
//!    `Pending::try_wait`.  The device-scale streaming front.
//!
//! Both fronts share `route()`: a request either resolves immediately
//! ([`Routed::Ready`]) or becomes an [`InflightInfer`] — submitted
//! slots the pool front waits on and the event loop polls.  Admission
//! control is two-stage and never blocks the socket:
//!
//!  * a full connection queue (pool) or connection cap (epoll) sheds
//!    the connection itself with a one-shot `503 + Retry-After`;
//!  * a saturated coordinator ingress sheds the *request* the same way
//!    (`Client::try_submit` → [`ServeError::Overloaded`] →
//!    `503 + Retry-After`) while accepted batchmates still complete.
//!
//! Timeout contract (both fronts): an idle keep-alive peer is closed
//! after [`NetOpts::keep_alive`]; a peer mid-message — however slowly
//! it trickles bytes — is killed after [`NetOpts::read_deadline`] and
//! counted in `timed_out` (the slowloris guard).  Request bodies are
//! bounded by [`NetOpts::body_limit`] (raw read and JSON parse).
//! [`NetServer::shutdown`] stops accepting, drains in-flight
//! connections, then shuts the coordinator down — surfacing dispatcher
//! panics like `Server::shutdown` does.

use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::coordinator::{Client, Pending, ServedConfig, Server};
use crate::engine::ServeError;
use crate::obs::log as evlog;
use crate::obs::{Span, Stage, TraceId};
use crate::util::json::{obj, Json, Limits};

use super::http::{Conn, HttpError, Message};
use super::wire;

/// Which serving front holds the sockets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetFront {
    /// Blocking worker pool: one thread per in-flight connection.
    Pool,
    /// Epoll readiness loop (Linux): a few event threads hold all
    /// connections.  Falls back to `Pool` with a warning elsewhere.
    Epoll,
}

impl NetFront {
    /// `Epoll` where the readiness loop exists (Linux), `Pool`
    /// elsewhere.
    pub fn default_for_platform() -> NetFront {
        if cfg!(target_os = "linux") {
            NetFront::Epoll
        } else {
            NetFront::Pool
        }
    }
}

impl std::str::FromStr for NetFront {
    type Err = String;
    fn from_str(s: &str) -> Result<NetFront, String> {
        match s {
            "pool" => Ok(NetFront::Pool),
            "epoll" => Ok(NetFront::Epoll),
            _ => Err(format!("unknown net front {s:?} (expected pool|epoll)")),
        }
    }
}

impl std::fmt::Display for NetFront {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            NetFront::Pool => "pool",
            NetFront::Epoll => "epoll",
        })
    }
}

/// Net-layer knobs.
#[derive(Debug, Clone)]
pub struct NetOpts {
    /// Which front holds the sockets.
    pub front: NetFront,
    /// Pool front: connection-handling worker threads (= max
    /// concurrent connections being served).
    pub workers: usize,
    /// Epoll front: event-loop threads (`0` = auto: `min(4, cores)`).
    pub event_threads: usize,
    /// Epoll front: cap on concurrently open connections; overflow is
    /// shed with `503` at accept time.
    pub max_conns: usize,
    /// Pool front: bound of the accepted-connection queue; overflow is
    /// shed with `503`.
    pub conn_backlog: usize,
    /// Request-body cap in bytes (raw read and JSON parse).
    pub body_limit: usize,
    /// Idle keep-alive timeout: how long a peer may sit between
    /// requests before the connection is closed.
    pub keep_alive: Duration,
    /// Slow-read (slowloris) deadline: max wall time one message may
    /// take to arrive, however slowly the peer trickles bytes.
    pub read_deadline: Duration,
    /// Value of the `Retry-After` header on shed requests.
    pub retry_after: Duration,
}

impl Default for NetOpts {
    fn default() -> Self {
        NetOpts {
            front: NetFront::default_for_platform(),
            workers: 8,
            event_threads: 0,
            max_conns: 16 * 1024,
            conn_backlog: 64,
            body_limit: 1 << 20,
            keep_alive: Duration::from_secs(2),
            read_deadline: Duration::from_secs(5),
            retry_after: Duration::from_secs(1),
        }
    }
}

/// Point-in-time net-layer counters (`/v1/metrics` → `"net"`).
#[derive(Debug, Clone, Copy, Default)]
pub struct NetMetricsSnapshot {
    /// Connections accepted off the listener.
    pub accepted: u64,
    /// Connections currently open (the `open` gauge).
    pub active: u64,
    /// Connections that have ended (any reason, sheds included).
    pub closed: u64,
    /// Connections killed by the idle or slow-read timeout.
    pub timed_out: u64,
    /// Open connections with a partial request buffered.
    pub reading: u64,
    /// Open connections with an answer being produced or written.
    pub writing: u64,
    /// Open connections idle between keep-alive requests.
    pub idle: u64,
    /// Requests (and overflow connections) shed with `503`.
    pub shed: u64,
    /// HTTP requests parsed.
    pub requests: u64,
    /// Bytes read off completed requests.
    pub bytes_in: u64,
    /// Bytes written in answers.
    pub bytes_out: u64,
}

/// Which live gauge a connection currently occupies.  The epoll front
/// tracks these exactly; the pool front approximates (a worker blocked
/// in read counts as `idle` until bytes arrive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Gauge {
    Reading,
    Writing,
    Idle,
}

#[derive(Default)]
pub(crate) struct Counters {
    pub(crate) accepted: AtomicU64,
    pub(crate) active: AtomicU64,
    pub(crate) closed: AtomicU64,
    pub(crate) timed_out: AtomicU64,
    pub(crate) reading: AtomicU64,
    pub(crate) writing: AtomicU64,
    pub(crate) idle: AtomicU64,
    pub(crate) shed: AtomicU64,
    pub(crate) requests: AtomicU64,
    pub(crate) bytes_in: AtomicU64,
    pub(crate) bytes_out: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> NetMetricsSnapshot {
        NetMetricsSnapshot {
            accepted: self.accepted.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            closed: self.closed.load(Ordering::Relaxed),
            timed_out: self.timed_out.load(Ordering::Relaxed),
            reading: self.reading.load(Ordering::Relaxed),
            writing: self.writing.load(Ordering::Relaxed),
            idle: self.idle.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }

    fn gauge(&self, g: Gauge) -> &AtomicU64 {
        match g {
            Gauge::Reading => &self.reading,
            Gauge::Writing => &self.writing,
            Gauge::Idle => &self.idle,
        }
    }

    /// Move one connection between live gauges (`None` = not counted,
    /// used at open/close).
    pub(crate) fn move_gauge(&self, from: Option<Gauge>, to: Option<Gauge>) {
        if from == to {
            return;
        }
        if let Some(g) = from {
            self.gauge(g).fetch_sub(1, Ordering::Relaxed);
        }
        if let Some(g) = to {
            self.gauge(g).fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Shared state between the acceptor and the connection handlers.
pub(crate) struct Ctx {
    pub(crate) client: Client,
    pub(crate) served: Vec<ServedConfig>,
    pub(crate) counters: Counters,
    pub(crate) stop: AtomicBool,
    pub(crate) opts: NetOpts,
}

/// The running front's threads.
enum FrontImpl {
    Pool { acceptor: Option<JoinHandle<()>>, workers: Vec<JoinHandle<()>> },
    #[cfg(target_os = "linux")]
    Epoll(Option<super::evloop::EvLoop>),
}

/// Running wire front.  Owns the wrapped coordinator server; prefer an
/// explicit [`shutdown`](Self::shutdown) (drains in-flight requests and
/// surfaces dispatcher panics) over plain drop.
pub struct NetServer {
    addr: SocketAddr,
    ctx: Arc<Ctx>,
    front: FrontImpl,
    coordinator: Option<Server>,
}

impl NetServer {
    /// Put `server` on a socket.  `listen` is `host:port`; port `0`
    /// picks a free port — read it back from [`addr`](Self::addr).
    pub fn bind(server: Server, listen: &str, opts: NetOpts) -> Result<NetServer> {
        crate::obs::mark_start(); // anchor flexsvm_uptime_seconds
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr()?;
        let mut opts = opts;
        if opts.front == NetFront::Epoll && !cfg!(target_os = "linux") {
            eprintln!("flexsvm net: epoll front unavailable on this platform, using pool");
            opts.front = NetFront::Pool;
        }
        let ctx = Arc::new(Ctx {
            client: server.client(),
            served: server.served_configs().to_vec(),
            counters: Counters::default(),
            stop: AtomicBool::new(false),
            opts: opts.clone(),
        });
        let front = match opts.front {
            NetFront::Pool => start_pool(listener, &ctx, &opts)?,
            #[cfg(target_os = "linux")]
            NetFront::Epoll => {
                FrontImpl::Epoll(Some(super::evloop::EvLoop::start(listener, Arc::clone(&ctx))?))
            }
            #[cfg(not(target_os = "linux"))]
            NetFront::Epoll => unreachable!("epoll front rewritten to pool above"),
        };
        Ok(NetServer { addr, ctx, front, coordinator: Some(server) })
    }

    /// The bound address (resolves `:0` to the picked port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// In-process handle to the wrapped coordinator (metrics, local
    /// traffic next to the socket).
    pub fn client(&self) -> Client {
        self.ctx.client.clone()
    }

    /// Net-layer counters.
    pub fn metrics(&self) -> NetMetricsSnapshot {
        self.ctx.counters.snapshot()
    }

    /// The front actually serving (after platform fallback).
    pub fn front(&self) -> NetFront {
        self.ctx.opts.front
    }

    /// Stop accepting, drain in-flight connections, then shut the
    /// coordinator down (dispatcher panics surface here).
    pub fn shutdown(mut self) -> Result<()> {
        self.stop_net();
        match self.coordinator.take() {
            Some(server) => server.shutdown(),
            None => Ok(()),
        }
    }

    /// Idempotent net-side teardown (shared by `shutdown` and `Drop`).
    fn stop_net(&mut self) {
        let first = !self.ctx.stop.swap(true, Ordering::SeqCst);
        if first {
            evlog::emit_fmt(evlog::Level::Info, "drain_start", || {
                format!("stopped accepting on {}; draining in-flight connections", self.addr)
            });
        }
        wake_accept(self.addr);
        match &mut self.front {
            FrontImpl::Pool { acceptor, workers } => {
                if let Some(a) = acceptor.take() {
                    let _ = a.join();
                }
                for w in workers.drain(..) {
                    let _ = w.join();
                }
            }
            #[cfg(target_os = "linux")]
            FrontImpl::Epoll(ev) => {
                if let Some(ev) = ev.take() {
                    ev.stop();
                }
            }
        }
        if first {
            evlog::emit_fmt(evlog::Level::Info, "drain_end", || {
                "net front drained; all connection threads joined".into()
            });
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_net();
        // the coordinator Server's own Drop handles dispatcher
        // teardown (panics are logged, not surfaced — use
        // NetServer::shutdown to handle them)
    }
}

/// Wake a blocking `accept` with a throwaway connection; an
/// unspecified bind address (0.0.0.0 / [::]) is not self-connectable
/// on every platform, so aim at its loopback equivalent, and never
/// hang the teardown on the connect.
fn wake_accept(addr: SocketAddr) {
    let mut wake = addr;
    if wake.ip().is_unspecified() {
        wake.set_ip(match wake.ip() {
            IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        });
    }
    let _ = TcpStream::connect_timeout(&wake, Duration::from_millis(500));
}

fn start_pool(listener: TcpListener, ctx: &Arc<Ctx>, opts: &NetOpts) -> Result<FrontImpl> {
    let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(opts.conn_backlog.max(1));
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let mut workers = Vec::with_capacity(opts.workers.max(1));
    for i in 0..opts.workers.max(1) {
        let rx = Arc::clone(&conn_rx);
        let wctx = Arc::clone(ctx);
        workers.push(
            std::thread::Builder::new()
                .name(format!("flexsvm-net-{i}"))
                .spawn(move || worker_loop(rx, wctx))?,
        );
    }
    let actx = Arc::clone(ctx);
    let acceptor = std::thread::Builder::new()
        .name("flexsvm-net-accept".into())
        .spawn(move || acceptor_loop(listener, conn_tx, actx))?;
    Ok(FrontImpl::Pool { acceptor: Some(acceptor), workers })
}

fn acceptor_loop(listener: TcpListener, conn_tx: mpsc::SyncSender<TcpStream>, ctx: Arc<Ctx>) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if ctx.stop.load(Ordering::SeqCst) {
                    return; // the shutdown wake-up
                }
                ctx.counters.accepted.fetch_add(1, Ordering::Relaxed);
                match conn_tx.try_send(stream) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(stream)) => {
                        // every worker busy and the backlog full: shed
                        // the connection instead of letting it queue
                        // unboundedly behind the socket
                        ctx.counters.shed.fetch_add(1, Ordering::Relaxed);
                        evlog::emit_fmt(evlog::Level::Warn, "admission_shed", || {
                            "connection backlog full; connection shed with 503".into()
                        });
                        shed_connection(stream, &ctx);
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => return,
                }
            }
            Err(_) => {
                if ctx.stop.load(Ordering::SeqCst) {
                    return;
                }
                // transient accept error (EMFILE, aborted handshake):
                // back off briefly instead of spinning a core while
                // the condition persists
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Best-effort one-shot `503` on a connection we cannot serve.
pub(crate) fn shed_connection(stream: TcpStream, ctx: &Ctx) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    let mut conn = Conn::new(stream);
    let _ = conn.write_message(
        "HTTP/1.1 503 Service Unavailable",
        &[
            ("Content-Type", "application/json".to_string()),
            ("Retry-After", ctx.opts.retry_after.as_secs().max(1).to_string()),
            ("Connection", "close".to_string()),
        ],
        wire::error_body(&ServeError::Overloaded).to_string().as_bytes(),
    );
    ctx.counters.closed.fetch_add(1, Ordering::Relaxed);
}

fn worker_loop(rx: Arc<Mutex<mpsc::Receiver<TcpStream>>>, ctx: Arc<Ctx>) {
    loop {
        // holding the lock while blocked in `recv` is the shared-
        // consumer idiom: whoever holds it takes the next connection,
        // then releases the lock for the next idle worker
        let stream = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match stream {
            Ok(s) => handle_connection(s, &ctx),
            // acceptor gone and queue drained: clean exit
            Err(_) => return,
        }
    }
}

fn handle_connection(stream: TcpStream, ctx: &Ctx) {
    let _ = stream.set_read_timeout(Some(ctx.opts.keep_alive));
    let _ = stream.set_nodelay(true);
    ctx.counters.active.fetch_add(1, Ordering::SeqCst);
    let mut gauge = Some(Gauge::Idle);
    ctx.counters.move_gauge(None, gauge);
    let mut conn = Conn::new(stream);
    conn.set_read_deadline(Some(ctx.opts.read_deadline));
    let (mut folded_in, mut folded_out) = (0u64, 0u64);
    loop {
        match conn.read_message(ctx.opts.body_limit) {
            Ok(msg) => {
                ctx.counters.move_gauge(gauge, Some(Gauge::Writing));
                gauge = Some(Gauge::Writing);
                ctx.counters.requests.fetch_add(1, Ordering::Relaxed);
                let close_requested = msg
                    .header("Connection")
                    .map(|v| v.eq_ignore_ascii_case("close"))
                    .unwrap_or(false);
                let answer = match route(ctx, &msg) {
                    Routed::Ready(a) => a,
                    // the pool front simply parks its worker on the
                    // in-flight slots; the event loop polls instead
                    Routed::Infer(inflight) => inflight.finish(ctx),
                };
                let keep = !close_requested && !ctx.stop.load(Ordering::SeqCst);
                let t_enc = Instant::now();
                let write_ok = write_answer(&mut conn, &answer, keep, &ctx.opts).is_ok();
                if let Some(cfg) = &answer.encode_cfg {
                    // the encode stage (serialization + socket write)
                    // happens after the span is sealed, so it reports
                    // into the stage histograms directly
                    ctx.client.obs().record_stage(
                        cfg,
                        Stage::Encode,
                        t_enc.elapsed().as_micros() as u64,
                    );
                }
                ctx.counters.bytes_in.fetch_add(conn.bytes_in() - folded_in, Ordering::Relaxed);
                ctx.counters.bytes_out.fetch_add(conn.bytes_out() - folded_out, Ordering::Relaxed);
                folded_in = conn.bytes_in();
                folded_out = conn.bytes_out();
                if !write_ok || !keep {
                    break;
                }
                ctx.counters.move_gauge(gauge, Some(Gauge::Idle));
                gauge = Some(Gauge::Idle);
            }
            Err(HttpError::TooLarge(what)) => {
                let a = Answer::plain(413, "Payload Too Large", &format!("request {what} too large"));
                let _ = write_answer(&mut conn, &a, false, &ctx.opts);
                break;
            }
            Err(HttpError::Malformed(m)) => {
                let a = Answer::plain(400, "Bad Request", &m);
                let _ = write_answer(&mut conn, &a, false, &ctx.opts);
                break;
            }
            Err(HttpError::Timeout) => {
                // idle keep-alive expiry is a clean close; a timeout
                // with a partial message buffered is the slow-read
                // guard firing
                if conn.mid_message() {
                    ctx.counters.timed_out.fetch_add(1, Ordering::Relaxed);
                }
                break;
            }
            // clean close or transport error
            Err(HttpError::Closed | HttpError::Io(_)) => break,
        }
    }
    // fold whatever the in-loop folds missed (error answers, partial
    // requests) so the byte counters cover every exit path
    ctx.counters.bytes_in.fetch_add(conn.bytes_in() - folded_in, Ordering::Relaxed);
    ctx.counters.bytes_out.fetch_add(conn.bytes_out() - folded_out, Ordering::Relaxed);
    ctx.counters.move_gauge(gauge, None);
    ctx.counters.active.fetch_sub(1, Ordering::SeqCst);
    ctx.counters.closed.fetch_add(1, Ordering::Relaxed);
}

/// Answer payload: JSON for the API routes, preformatted text for the
/// Prometheus scrape endpoint.
pub(crate) enum Body {
    Json(Json),
    Text(String),
}

/// One routed answer, ready to serialize.
pub(crate) struct Answer {
    pub(crate) status: u16,
    pub(crate) reason: &'static str,
    pub(crate) body: Body,
    pub(crate) retry_after: bool,
    /// Echoed back as `X-Trace-Id` (explicitly-traced requests).
    pub(crate) trace: Option<TraceId>,
    /// Config whose `encode` stage should be credited with this
    /// answer's serialization + socket-write time.
    pub(crate) encode_cfg: Option<String>,
}

impl Answer {
    fn ok(body: Json) -> Answer {
        Answer {
            status: 200,
            reason: "OK",
            body: Body::Json(body),
            retry_after: false,
            trace: None,
            encode_cfg: None,
        }
    }

    fn text(text: String) -> Answer {
        Answer {
            status: 200,
            reason: "OK",
            body: Body::Text(text),
            retry_after: false,
            trace: None,
            encode_cfg: None,
        }
    }

    pub(crate) fn plain(status: u16, reason: &'static str, message: &str) -> Answer {
        let body = obj([(
            "error",
            obj([("kind", reason_kind(status).into()), ("message", message.into())]),
        )]);
        Answer {
            status,
            reason,
            body: Body::Json(body),
            retry_after: false,
            trace: None,
            encode_cfg: None,
        }
    }

    fn from_serve_error(e: ServeError) -> Answer {
        let status = wire::status_for(&e);
        Answer {
            status,
            reason: reason_phrase(status),
            retry_after: matches!(e, ServeError::Overloaded),
            body: Body::Json(wire::error_body(&e)),
            trace: None,
            encode_cfg: None,
        }
    }
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

fn reason_kind(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        404 => "not_found",
        405 => "method_not_allowed",
        413 => "too_large",
        _ => "error",
    }
}

/// A routed request: either answered on the spot, or a set of
/// submitted coordinator slots still in flight.
pub(crate) enum Routed {
    Ready(Answer),
    Infer(InflightInfer),
}

/// One submitted inference slot: still pending at the coordinator, or
/// settled with its result.
enum Slot {
    Pending(Pending),
    Ready(Result<crate::coordinator::Response, ServeError>),
}

/// An infer request whose samples have been submitted (admission
/// already applied per sample) but not yet answered.  The pool front
/// blocks in [`finish`](Self::finish); the event loop calls
/// [`try_settle`](Self::try_settle) each tick and
/// [`finalize`](Self::finalize) once everything landed — both paths
/// assemble the identical answer.
pub(crate) struct InflightInfer {
    key: String,
    t0: Instant,
    trace: Option<TraceId>,
    per_sample_traced: bool,
    batch: bool,
    slots: Vec<Slot>,
}

impl InflightInfer {
    /// Poll every pending slot without blocking; true once all have
    /// settled and [`finalize`](Self::finalize) may run.
    pub(crate) fn try_settle(&mut self) -> bool {
        let mut all = true;
        for s in &mut self.slots {
            if let Slot::Pending(p) = s {
                match p.try_wait() {
                    Some(r) => *s = Slot::Ready(r),
                    None => all = false,
                }
            }
        }
        all
    }

    /// Block until every slot settles, then assemble the answer (the
    /// pool front's path).
    pub(crate) fn finish(mut self, ctx: &Ctx) -> Answer {
        self.slots = std::mem::take(&mut self.slots)
            .into_iter()
            .map(|s| match s {
                Slot::Pending(p) => Slot::Ready(p.wait()),
                ready => ready,
            })
            .collect();
        self.finalize(ctx)
    }

    /// Assemble the answer from settled slots (blocks on any stragglers
    /// for safety; call after [`try_settle`](Self::try_settle) returned
    /// true to stay non-blocking).
    pub(crate) fn finalize(self, ctx: &Ctx) -> Answer {
        let InflightInfer { key, t0, trace, per_sample_traced, batch, slots } = self;
        let settled: Vec<Result<crate::coordinator::Response, ServeError>> = slots
            .into_iter()
            .map(|s| match s {
                Slot::Ready(r) => r,
                Slot::Pending(p) => p.wait(),
            })
            .collect();
        if !batch {
            let r = settled.into_iter().next().expect("single infer has one slot");
            return match r {
                Ok(resp) => {
                    if let Some(s) = &resp.span {
                        ctx.client.obs().keep((**s).clone());
                    }
                    let mut a = Answer::ok(wire::response_json(&resp));
                    a.trace = trace;
                    a.encode_cfg = Some(key);
                    a
                }
                Err(e) => {
                    // engine-side failures are scored against the SLO
                    // inside the coordinator's flush; admission sheds
                    // never reach it, so score them here
                    if matches!(e, ServeError::Overloaded) {
                        ctx.client.obs().slo_record(&key, false, t0.elapsed());
                    }
                    shed_aware_error(ctx, e)
                }
            };
        }
        let mut any_shed = false;
        let mut spans: Vec<Span> = Vec::new();
        let results: Vec<Json> = settled
            .into_iter()
            .map(|r| match r {
                Ok(resp) => {
                    if let Some(s) = &resp.span {
                        spans.push((**s).clone());
                    }
                    wire::response_json(&resp)
                }
                Err(e) => {
                    if matches!(e, ServeError::Overloaded) {
                        any_shed = true;
                        ctx.counters.shed.fetch_add(1, Ordering::Relaxed);
                        ctx.client.obs().slo_record(&key, false, t0.elapsed());
                    }
                    wire::error_body(&e)
                }
            })
            .collect();
        // retain explicit spans so `/v1/traces?id=` can answer: one
        // batch-wide trace becomes one tree (per-sample children),
        // per-sample ids are retained individually
        match (trace, per_sample_traced) {
            (Some(t), _) if spans.len() > 1 => {
                let mut root = Span::new(t, &key);
                root.total_us = t0.elapsed().as_micros() as u64;
                root.children = spans;
                ctx.client.obs().keep(root);
            }
            (_, true) => {
                for s in spans {
                    ctx.client.obs().keep(s);
                }
            }
            _ => {}
        }
        let mut a = Answer::ok(obj([("results", Json::Arr(results))]));
        a.retry_after = any_shed;
        a.trace = trace;
        a.encode_cfg = Some(key);
        a
    }
}

pub(crate) fn route(ctx: &Ctx, msg: &Message) -> Routed {
    let mut parts = msg.start_line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m, p),
        _ => return Routed::Ready(Answer::plain(400, "Bad Request", "bad request line")),
    };
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    match (method, path) {
        ("GET", "/healthz") => Routed::Ready(healthz(ctx)),
        ("GET", "/v1/metrics") => Routed::Ready(metrics(ctx)),
        ("GET", "/metrics") => Routed::Ready(prom(ctx)),
        ("GET", "/v1/traces") => Routed::Ready(traces(ctx, query)),
        ("GET", "/v1/profile") => Routed::Ready(profile(ctx, query)),
        ("GET", "/v1/logs") => Routed::Ready(logs(query)),
        ("POST", "/v1/infer") => infer(ctx, msg),
        (
            _,
            "/healthz" | "/v1/metrics" | "/metrics" | "/v1/traces" | "/v1/profile" | "/v1/logs"
            | "/v1/infer",
        ) => {
            Routed::Ready(Answer::plain(
                405,
                "Method Not Allowed",
                &format!("{method} not allowed here"),
            ))
        }
        _ => Routed::Ready(Answer::plain(404, "Not Found", &format!("no route {path:?}"))),
    }
}

/// Typed error → answer, counting `Overloaded` sheds in the net stats.
fn shed_aware_error(ctx: &Ctx, e: ServeError) -> Answer {
    if matches!(e, ServeError::Overloaded) {
        ctx.counters.shed.fetch_add(1, Ordering::Relaxed);
        evlog::emit_fmt(evlog::Level::Warn, "admission_shed", || {
            "coordinator ingress saturated; request shed with 503 + Retry-After".into()
        });
    }
    Answer::from_serve_error(e)
}

fn healthz(ctx: &Ctx) -> Answer {
    // the round-trip through the dispatcher doubles as a liveness
    // probe; non-blocking so a saturated ingress sheds the probe with
    // 503 instead of parking this worker
    match ctx.client.try_engine_metrics() {
        Ok(em) => {
            // SLO verdict folds into liveness: a live server with a
            // burning error budget answers "degraded" + the reasons
            let slo = ctx.client.obs().slo_snapshot();
            let status = match &slo {
                Some(s) if !s.healthy() => "degraded",
                _ => "ok",
            };
            let mut body = obj([
            ("status", status.into()),
            ("engine", em.engine.as_str().into()),
            // each served config is an object carrying the model-family
            // facts (kernel + bit-width); peers that only want the keys
            // read the "key" field and ignore the rest
            (
                "configs",
                Json::Arr(
                    ctx.served
                        .iter()
                        .map(|s| {
                            obj([
                                ("key", s.key.as_str().into()),
                                ("kernel", s.kernel.as_str().into()),
                                ("bits", (s.bits as u64).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ]);
            if let Some(s) = &slo {
                let Json::Obj(map) = &mut body else { unreachable!() };
                map.insert("slo".to_string(), Json::Str(s.verdict()));
            }
            Answer::ok(body)
        }
        Err(e) => shed_aware_error(ctx, e),
    }
}

fn metrics(ctx: &Ctx) -> Answer {
    let configs = match ctx.client.try_metrics() {
        Ok(c) => c,
        Err(e) => return shed_aware_error(ctx, e),
    };
    let engine = match ctx.client.try_engine_metrics() {
        Ok(em) => em,
        Err(e) => return shed_aware_error(ctx, e),
    };
    Answer::ok(wire::metrics_body(&configs, &engine, &ctx.counters.snapshot()))
}

/// `GET /metrics`: the Prometheus text-format twin of `/v1/metrics` —
/// per-config counters + latency histograms, per-stage histograms,
/// net-layer and trace-retention counters.
fn prom(ctx: &Ctx) -> Answer {
    let configs = match ctx.client.try_metrics() {
        Ok(c) => c,
        Err(e) => return shed_aware_error(ctx, e),
    };
    let obs = ctx.client.obs();
    let net = ctx.counters.snapshot();
    let slo = obs.slo_snapshot();
    Answer::text(crate::obs::prom_render(
        &configs,
        &obs.stage_snapshot(),
        &[
            ("net_connections_accepted_total", net.accepted),
            ("net_connections_open", net.active),
            ("net_connections_closed_total", net.closed),
            ("net_connections_timed_out_total", net.timed_out),
            ("net_connections_reading", net.reading),
            ("net_connections_writing", net.writing),
            ("net_connections_idle", net.idle),
            ("net_requests_shed_total", net.shed),
            ("net_requests_total", net.requests),
            ("net_bytes_in_total", net.bytes_in),
            ("net_bytes_out_total", net.bytes_out),
            ("traces_retained", obs.retained() as u64),
            ("traces_observed_total", obs.observed()),
        ],
        slo.as_ref(),
    ))
}

/// `GET /v1/traces[?id=<hex>|n=<count>]`: retained span trees from the
/// ring — by trace id (404 when not retained), or the newest `n`.
fn traces(ctx: &Ctx, query: &str) -> Answer {
    let obs = ctx.client.obs();
    let mut id = None;
    let mut n = 32usize;
    for pair in query.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        match k {
            "id" => match TraceId::parse(v) {
                Some(t) => id = Some(t),
                None => return Answer::plain(400, "Bad Request", &format!("bad trace id {v:?}")),
            },
            "n" => match v.parse::<usize>() {
                Ok(v) if v >= 1 => n = v.min(1024),
                _ => return Answer::plain(400, "Bad Request", &format!("bad count {v:?}")),
            },
            _ => {} // tolerate unknown query params
        }
    }
    match id {
        Some(t) => match obs.get(t) {
            Some(span) => {
                let mut a = Answer::ok(span.to_json());
                a.trace = Some(t);
                a
            }
            None => {
                Answer::plain(404, "Not Found", &format!("no retained trace {}", t.to_hex()))
            }
        },
        None => Answer::ok(obj([
            ("observed", obs.observed().into()),
            ("retained", (obs.retained() as u64).into()),
            ("traces", Json::Arr(obs.recent(n).iter().map(Span::to_json).collect())),
        ])),
    }
}

/// `GET /v1/profile[?n=<count>&collapsed=1]`: the continuous
/// profiler's merged per-config region profile — top-`n` hot regions
/// as JSON, or the full collapsed-stack text (flamegraph input) with
/// `collapsed=1`.  Configs with zero sampled runs are omitted; remote
/// engines answer with the fleet-merged profile.
fn profile(ctx: &Ctx, query: &str) -> Answer {
    let em = match ctx.client.try_engine_metrics() {
        Ok(em) => em,
        Err(e) => return shed_aware_error(ctx, e),
    };
    let mut n = 10usize;
    let mut collapsed = false;
    for pair in query.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        match k {
            "n" => match v.parse::<usize>() {
                Ok(v) if v >= 1 => n = v.min(64),
                _ => return Answer::plain(400, "Bad Request", &format!("bad count {v:?}")),
            },
            "collapsed" => collapsed = v != "0",
            _ => {} // tolerate unknown query params
        }
    }
    if collapsed {
        let mut out = String::new();
        let mut keys: Vec<&String> = em.profiles.keys().collect();
        keys.sort();
        for k in keys {
            em.profiles[k].collapsed_stack(k, &mut out);
        }
        return Answer::text(out);
    }
    let mut cfgs = std::collections::BTreeMap::new();
    for (key, p) in &em.profiles {
        let hot: Vec<Json> = p
            .hot_regions(n)
            .into_iter()
            .map(|(name, cycles, pct)| {
                obj([
                    ("region", name.as_str().into()),
                    ("cycles", cycles.into()),
                    ("pct", pct.into()),
                ])
            })
            .collect();
        cfgs.insert(
            key.clone(),
            obj([
                ("sampled_runs", p.sampled_runs.into()),
                ("total_cycles", p.total_cycles.into()),
                ("hot", Json::Arr(hot)),
            ]),
        );
    }
    Answer::ok(obj([("configs", Json::Obj(cfgs))]))
}

/// `GET /v1/logs[?n=<count>&level=<min>&trace=<hex>]`: the newest
/// structured events from the flight-recorder ring, newest first.
fn logs(query: &str) -> Answer {
    let mut n = 100usize;
    let mut min_level = None;
    let mut trace: Option<String> = None;
    for pair in query.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        match k {
            "n" => match v.parse::<usize>() {
                Ok(v) if v >= 1 => n = v.min(1024),
                _ => return Answer::plain(400, "Bad Request", &format!("bad count {v:?}")),
            },
            "level" => match v.parse::<evlog::Level>() {
                Ok(l) => min_level = Some(l),
                Err(e) => return Answer::plain(400, "Bad Request", &format!("{e:#}")),
            },
            "trace" => trace = Some(v.to_string()),
            _ => {} // tolerate unknown query params
        }
    }
    let events = evlog::recent(n, min_level, trace.as_deref());
    Answer::ok(obj([
        ("recorded", evlog::recorded().into()),
        ("events", Json::Arr(events.iter().map(|e| e.to_json()).collect())),
    ]))
}

/// The request's explicit trace id, if any: the JSON `"trace"` field
/// wins over the `X-Trace-Id` header.
fn explicit_trace(doc: &Json, msg: &Message) -> Result<Option<TraceId>, String> {
    let from = |s: &str| TraceId::parse(s).ok_or_else(|| format!("bad trace id {s:?}"));
    if let Some(t) = doc.opt("trace") {
        let s = t.as_str().map_err(|e| format!("bad trace: {e:#}"))?;
        return from(s).map(Some);
    }
    match msg.header("X-Trace-Id") {
        Some(s) => from(s).map(Some),
        None => Ok(None),
    }
}

/// Parse + submit an infer request.  Validation failures answer
/// immediately; submitted work comes back as [`Routed::Infer`] so the
/// caller chooses blocking or polled completion.
fn infer(ctx: &Ctx, msg: &Message) -> Routed {
    let bad = |m: &str| Routed::Ready(Answer::plain(400, "Bad Request", m));
    let text = match std::str::from_utf8(&msg.body) {
        Ok(t) => t,
        Err(_) => return bad("body is not UTF-8"),
    };
    let limits = Limits { max_bytes: ctx.opts.body_limit, max_depth: 64 };
    let doc = match Json::parse_limited(text, &limits) {
        Ok(d) => d,
        Err(e) => return bad(&format!("bad JSON: {e:#}")),
    };
    let key = match doc.get("config").and_then(|c| c.as_str()) {
        Ok(k) => k.to_string(),
        Err(e) => return bad(&format!("{e:#}")),
    };
    let trace = match explicit_trace(&doc, msg) {
        Ok(t) => t,
        Err(e) => return bad(&e),
    };
    if let Some(batch) = doc.opt("batch") {
        let xs = match batch.as_mat_i32() {
            Ok(xs) => xs,
            Err(e) => return bad(&format!("bad batch: {e:#}")),
        };
        // per-sample trace ids (`"traces"`, a RemoteEngine fan-out
        // chunk) win over one batch-wide id (`"trace"` / header)
        let traces: Option<Vec<TraceId>> = match doc.opt("traces") {
            Some(tj) => {
                let parsed: Option<Vec<TraceId>> = tj
                    .as_arr()
                    .ok()
                    .map(|a| a.iter().filter_map(|t| TraceId::parse(t.as_str().ok()?)).collect());
                match parsed {
                    Some(ts) if ts.len() == xs.len() => Some(ts),
                    _ => return bad("\"traces\" must be hex ids, one per batch sample"),
                }
            }
            None => trace.map(|t| vec![t; xs.len()]),
        };
        let t0 = Instant::now();
        // admission is per sample: shed samples answer `overloaded` in
        // their slot while accepted batchmates still complete
        let slots: Vec<Slot> = match &traces {
            Some(ts) => xs
                .iter()
                .zip(ts)
                .map(|(x, &t)| match ctx.client.try_submit_traced(&key, x, t) {
                    Ok(p) => Slot::Pending(p),
                    Err(e) => Slot::Ready(Err(e)),
                })
                .collect(),
            None => xs
                .iter()
                .map(|x| match ctx.client.try_submit(&key, x) {
                    Ok(p) => Slot::Pending(p),
                    Err(e) => Slot::Ready(Err(e)),
                })
                .collect(),
        };
        Routed::Infer(InflightInfer {
            key,
            t0,
            trace,
            per_sample_traced: doc.opt("traces").is_some(),
            batch: true,
            slots,
        })
    } else if let Some(features) = doc.opt("features") {
        let x = match features.as_vec_i32() {
            Ok(x) => x,
            Err(e) => return bad(&format!("bad features: {e:#}")),
        };
        let slot = match trace {
            Some(t) => ctx.client.try_submit_traced(&key, &x, t),
            None => ctx.client.try_submit(&key, &x),
        };
        Routed::Infer(InflightInfer {
            key,
            t0: Instant::now(),
            trace,
            per_sample_traced: false,
            batch: false,
            slots: vec![match slot {
                Ok(p) => Slot::Pending(p),
                Err(e) => Slot::Ready(Err(e)),
            }],
        })
    } else {
        bad("need \"features\" or \"batch\"")
    }
}

/// Serialize one answer to wire bytes (start-line + headers + body) —
/// shared by the blocking writer and the event loop's write buffers.
pub(crate) fn answer_bytes(a: &Answer, keep: bool, opts: &NetOpts) -> Vec<u8> {
    let content_type = match &a.body {
        Body::Json(_) => "application/json",
        Body::Text(_) => "text/plain; version=0.0.4; charset=utf-8",
    };
    let mut headers: Vec<(&str, String)> = vec![
        ("Content-Type", content_type.to_string()),
        ("Connection", if keep { "keep-alive" } else { "close" }.to_string()),
    ];
    if a.retry_after {
        headers.push(("Retry-After", opts.retry_after.as_secs().max(1).to_string()));
    }
    if let Some(t) = a.trace {
        headers.push(("X-Trace-Id", t.to_hex()));
    }
    let payload = match &a.body {
        Body::Json(j) => j.to_string(),
        Body::Text(t) => t.clone(),
    };
    super::http::encode_message(
        &format!("HTTP/1.1 {} {}", a.status, a.reason),
        &headers,
        payload.as_bytes(),
    )
}

fn write_answer(
    conn: &mut Conn,
    a: &Answer,
    keep: bool,
    opts: &NetOpts,
) -> Result<(), HttpError> {
    conn.write_raw(&answer_bytes(a, keep, opts))
}
