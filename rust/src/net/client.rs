//! Wire client: a keep-alive HTTP/1.1 client over one `TcpStream`,
//! with connect/read timeouts and bounded reconnect.
//!
//! [`HttpClient`] is deliberately small: `get` / `post_json` against a
//! single `host:port`, reusing the connection across requests.  A
//! request against a dead cached connection is retried once on a fresh
//! connection (every endpoint this repo serves is idempotent —
//! inference is a pure function of the request).  Connection attempts
//! themselves are bounded by [`HttpClientOpts::connect_attempts`] with
//! a linear backoff, so a down peer fails fast instead of hanging.

use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::util::json::{Json, Limits};

use super::http::{Conn, HttpError};

/// Typed transport error — `net::remote` maps these onto `ServeError`.
#[derive(Debug)]
pub enum NetError {
    /// Could not establish a connection (after bounded retries).
    Connect(String),
    /// The peer did not answer within the I/O timeout.
    Timeout(String),
    /// The connection broke mid-request.
    Io(String),
    /// The peer answered bytes that are not valid HTTP/JSON.
    Protocol(String),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Connect(msg) => write!(f, "connect failed: {msg}"),
            NetError::Timeout(msg) => write!(f, "timeout: {msg}"),
            NetError::Io(msg) => write!(f, "transport: {msg}"),
            NetError::Protocol(msg) => write!(f, "protocol: {msg}"),
        }
    }
}

impl std::error::Error for NetError {}

/// Client knobs.
#[derive(Debug, Clone)]
pub struct HttpClientOpts {
    /// Per-attempt TCP connect timeout.
    pub connect_timeout: Duration,
    /// Read/write timeout on an established connection.
    pub io_timeout: Duration,
    /// Connection attempts before giving up (bounded reconnect).
    pub connect_attempts: u32,
    /// Sleep between connection attempts (linear backoff: attempt i
    /// waits `i * backoff`).
    pub backoff: Duration,
    /// Response body cap.
    pub max_response_bytes: usize,
}

impl Default for HttpClientOpts {
    fn default() -> Self {
        HttpClientOpts {
            connect_timeout: Duration::from_secs(1),
            io_timeout: Duration::from_secs(10),
            connect_attempts: 3,
            backoff: Duration::from_millis(50),
            max_response_bytes: 8 << 20,
        }
    }
}

/// One HTTP response.
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        super::http::header(&self.headers, name)
    }

    /// Parse the body as JSON (under the wire limits).
    pub fn json(&self) -> Result<Json, NetError> {
        Json::parse_limited(&self.body, &Limits { max_bytes: self.body.len(), max_depth: 64 })
            .map_err(|e| NetError::Protocol(format!("bad JSON body: {e:#}")))
    }
}

/// Keep-alive HTTP client against one `host:port`.
pub struct HttpClient {
    addr: String,
    opts: HttpClientOpts,
    conn: Option<Conn>,
    reused: u64,
}

impl HttpClient {
    pub fn new(addr: impl Into<String>) -> HttpClient {
        Self::with_opts(addr, HttpClientOpts::default())
    }

    pub fn with_opts(addr: impl Into<String>, opts: HttpClientOpts) -> HttpClient {
        HttpClient { addr: addr.into(), opts, conn: None, reused: 0 }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Requests that rode an already-established keep-alive connection
    /// (everything except the first request per connect).  A 10k-device
    /// drive watches this to prove sessions actually stay open instead
    /// of churning the ephemeral-port range.
    pub fn connections_reused(&self) -> u64 {
        self.reused
    }

    /// Drop the cached connection with an RST instead of a FIN
    /// (`SO_LINGER` 0 on Linux): no TIME_WAIT state survives, so mass
    /// teardowns don't strand client ports for 60s.
    pub fn close_abortive(&mut self) {
        if let Some(conn) = self.conn.take() {
            super::abortive_close(conn.stream());
        }
    }

    pub fn get(&mut self, path: &str) -> Result<HttpResponse, NetError> {
        self.request("GET", path, None)
    }

    pub fn post_json(&mut self, path: &str, body: &Json) -> Result<HttpResponse, NetError> {
        self.request("POST", path, Some(body.to_string()))
    }

    /// [`post_json`](Self::post_json) with extra request headers (e.g.
    /// `X-Trace-Id` for distributed trace propagation).
    pub fn post_json_with(
        &mut self,
        path: &str,
        body: &Json,
        extra: &[(String, String)],
    ) -> Result<HttpResponse, NetError> {
        self.request_with("POST", path, Some(body.to_string()), extra)
    }

    /// One request/response exchange.  A cached keep-alive connection
    /// that turns out dead is replaced once and the request retried.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<String>,
    ) -> Result<HttpResponse, NetError> {
        self.request_with(method, path, body, &[])
    }

    /// [`request`](Self::request) with extra request headers.
    pub fn request_with(
        &mut self,
        method: &str,
        path: &str,
        body: Option<String>,
        extra: &[(String, String)],
    ) -> Result<HttpResponse, NetError> {
        let had_cached = self.conn.is_some();
        match self.exchange(method, path, body.as_deref(), extra) {
            Ok(resp) => Ok(resp),
            Err(e) => {
                self.conn = None;
                // a dead cached connection is expected (server-side
                // keep-alive timeout) — retry once on a fresh one;
                // fresh-connection failures are real errors
                if had_cached && !matches!(e, NetError::Timeout(_)) {
                    let retried = self.exchange(method, path, body.as_deref(), extra);
                    if retried.is_err() {
                        self.conn = None;
                    }
                    retried
                } else {
                    Err(e)
                }
            }
        }
    }

    fn exchange(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        extra: &[(String, String)],
    ) -> Result<HttpResponse, NetError> {
        let body_cap = self.opts.max_response_bytes;
        let host = self.addr.clone();
        let reusing = self.conn.is_some();
        let conn = self.ensure_conn()?;
        let mut headers: Vec<(&str, String)> = vec![("Host", host), ("Connection", "keep-alive".into())];
        if body.is_some() {
            headers.push(("Content-Type", "application/json".into()));
        }
        for (k, v) in extra {
            headers.push((k.as_str(), v.clone()));
        }
        let payload = body.unwrap_or("").as_bytes();
        conn.write_message(&format!("{method} {path} HTTP/1.1"), &headers, payload)
            .map_err(http_to_net)?;
        let msg = conn.read_message(body_cap).map_err(http_to_net)?;
        let status = parse_status_line(&msg.start_line)?;
        let body = String::from_utf8(msg.body)
            .map_err(|_| NetError::Protocol("response body is not UTF-8".into()))?;
        let close = msg.header("Connection").is_some_and(|v| v.eq_ignore_ascii_case("close"));
        let headers = msg.headers;
        if close {
            self.conn = None;
        }
        if reusing {
            self.reused += 1;
        }
        Ok(HttpResponse { status, headers, body })
    }

    /// Cached connection, or a fresh one after bounded retries.
    fn ensure_conn(&mut self) -> Result<&mut Conn, NetError> {
        if self.conn.is_none() {
            let sock_addr = self
                .addr
                .to_socket_addrs()
                .map_err(|e| NetError::Connect(format!("{}: bad address: {e}", self.addr)))?
                .next()
                .ok_or_else(|| NetError::Connect(format!("{}: no address", self.addr)))?;
            let attempts = self.opts.connect_attempts.max(1);
            let mut last = String::new();
            for attempt in 0..attempts {
                if attempt > 0 {
                    std::thread::sleep(self.opts.backoff * attempt);
                }
                match TcpStream::connect_timeout(&sock_addr, self.opts.connect_timeout) {
                    Ok(stream) => {
                        let _ = stream.set_read_timeout(Some(self.opts.io_timeout));
                        let _ = stream.set_write_timeout(Some(self.opts.io_timeout));
                        let _ = stream.set_nodelay(true);
                        self.conn = Some(Conn::new(stream));
                        break;
                    }
                    Err(e) => last = e.to_string(),
                }
            }
            if self.conn.is_none() {
                return Err(NetError::Connect(format!(
                    "{}: {last} (after {attempts} attempts)",
                    self.addr
                )));
            }
        }
        Ok(self.conn.as_mut().unwrap())
    }
}

fn http_to_net(e: HttpError) -> NetError {
    match e {
        HttpError::Timeout => NetError::Timeout("peer did not answer in time".into()),
        HttpError::Closed => NetError::Io("connection closed by peer".into()),
        HttpError::Io(e) => NetError::Io(e.to_string()),
        HttpError::TooLarge(what) => NetError::Protocol(format!("response {what} too large")),
        HttpError::Malformed(msg) => NetError::Protocol(msg),
    }
}

fn parse_status_line(line: &str) -> Result<u16, NetError> {
    let mut parts = line.split_whitespace();
    match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => code
            .parse::<u16>()
            .map_err(|_| NetError::Protocol(format!("bad status line {line:?}"))),
        _ => Err(NetError::Protocol(format!("bad status line {line:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_line_parses() {
        assert_eq!(parse_status_line("HTTP/1.1 200 OK").unwrap(), 200);
        assert_eq!(parse_status_line("HTTP/1.1 503 Service Unavailable").unwrap(), 503);
        assert!(parse_status_line("ICY 200 OK").is_err());
        assert!(parse_status_line("HTTP/1.1").is_err());
    }

    #[test]
    fn connect_to_dead_port_fails_fast_and_bounded() {
        // a freshly bound-then-dropped port refuses connections
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let opts = HttpClientOpts {
            connect_attempts: 2,
            backoff: Duration::from_millis(1),
            ..Default::default()
        };
        let mut c = HttpClient::with_opts(addr, opts);
        let t0 = std::time::Instant::now();
        match c.get("/healthz") {
            Err(NetError::Connect(msg)) => assert!(msg.contains("2 attempts"), "{msg}"),
            other => panic!("expected Connect error, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "bounded reconnect must fail fast");
    }
}
