//! Minimal HTTP/1.1 message layer shared by the wire server and
//! client (no hyper/reqwest in the offline vendor set).
//!
//! One [`Conn`] wraps a `TcpStream` with a read buffer so keep-alive
//! connections can carry back-to-back (even pipelined) messages.
//! [`Conn::read_message`] returns the raw start-line, headers and body
//! of the next message — the server parses the start-line as a request
//! line, the client as a status line.  Bodies are `Content-Length`
//! framed only (chunked transfer encoding is rejected); head and body
//! sizes are capped so a hostile peer cannot balloon memory.

use std::io::{Read, Write};
use std::net::TcpStream;

/// Hard cap on the start-line + headers block.
pub const HEAD_LIMIT: usize = 16 * 1024;

/// What went wrong reading one HTTP message.
#[derive(Debug)]
pub enum HttpError {
    /// Clean EOF before any byte of the next message (keep-alive peer
    /// went away between requests).
    Closed,
    /// The socket read timed out.
    Timeout,
    /// Head or body exceeded its size cap (maps to `413`).
    TooLarge(&'static str),
    /// The bytes were not a valid HTTP/1.1 message (maps to `400`).
    Malformed(String),
    /// Transport error mid-message.
    Io(std::io::Error),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Closed => f.write_str("connection closed"),
            HttpError::Timeout => f.write_str("socket read timed out"),
            HttpError::TooLarge(what) => write!(f, "{what} too large"),
            HttpError::Malformed(msg) => write!(f, "malformed HTTP message: {msg}"),
            HttpError::Io(e) => write!(f, "transport error: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

fn io_error(e: std::io::Error) -> HttpError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => HttpError::Timeout,
        _ => HttpError::Io(e),
    }
}

/// One parsed message: start-line, headers, body.
#[derive(Debug)]
pub struct Message {
    pub start_line: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Message {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }
}

/// Case-insensitive header lookup over a parsed header list.
pub fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k.eq_ignore_ascii_case(name)).map(|(_, v)| v.as_str())
}

/// A TCP connection with a read buffer (leftover bytes between
/// keep-alive messages) and byte counters for the net-layer metrics.
pub struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
    bytes_in: u64,
    bytes_out: u64,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Conn {
        Conn { stream, buf: Vec::new(), bytes_in: 0, bytes_out: 0 }
    }

    pub fn bytes_in(&self) -> u64 {
        self.bytes_in
    }

    pub fn bytes_out(&self) -> u64 {
        self.bytes_out
    }

    /// Read the next message off the connection; `body_cap` bounds the
    /// accepted `Content-Length`.
    pub fn read_message(&mut self, body_cap: usize) -> Result<Message, HttpError> {
        let head_end = self.fill_until_head_end()?;
        // split head off the buffer; keep any body/pipelined bytes
        let head_bytes: Vec<u8> = self.buf.drain(..head_end + 4).collect();
        let head = std::str::from_utf8(&head_bytes[..head_end])
            .map_err(|_| HttpError::Malformed("head is not UTF-8".into()))?;
        let mut lines = head.split("\r\n");
        let start_line = lines
            .next()
            .filter(|l| !l.is_empty())
            .ok_or_else(|| HttpError::Malformed("empty start line".into()))?
            .to_string();
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once(':')
                .ok_or_else(|| HttpError::Malformed(format!("bad header line {line:?}")))?;
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
        if header(&headers, "Transfer-Encoding").is_some() {
            return Err(HttpError::Malformed("chunked transfer encoding not supported".into()));
        }
        let body_len = match header(&headers, "Content-Length") {
            None => 0usize,
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| HttpError::Malformed(format!("bad Content-Length {v:?}")))?,
        };
        if body_len > body_cap {
            return Err(HttpError::TooLarge("body"));
        }
        while self.buf.len() < body_len {
            self.fill_some()?;
        }
        let body: Vec<u8> = self.buf.drain(..body_len).collect();
        Ok(Message { start_line, headers, body })
    }

    /// Write one message; returns when the bytes are handed to the OS.
    pub fn write_message(
        &mut self,
        start_line: &str,
        headers: &[(&str, String)],
        body: &[u8],
    ) -> Result<(), HttpError> {
        let mut head = String::with_capacity(128);
        head.push_str(start_line);
        head.push_str("\r\n");
        for (k, v) in headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
        self.stream.write_all(head.as_bytes()).map_err(io_error)?;
        self.stream.write_all(body).map_err(io_error)?;
        self.stream.flush().map_err(io_error)?;
        self.bytes_out += (head.len() + body.len()) as u64;
        Ok(())
    }

    /// Grow the buffer until it contains the `\r\n\r\n` head terminator;
    /// returns its offset.
    fn fill_until_head_end(&mut self) -> Result<usize, HttpError> {
        loop {
            if let Some(pos) = find_head_end(&self.buf) {
                return Ok(pos);
            }
            if self.buf.len() > HEAD_LIMIT {
                return Err(HttpError::TooLarge("head"));
            }
            let was_empty = self.buf.is_empty();
            match self.fill_some() {
                Ok(()) => {}
                // EOF between messages is a clean keep-alive close;
                // EOF mid-head is a protocol error
                Err(HttpError::Closed) if !was_empty => {
                    return Err(HttpError::Malformed("EOF mid-head".into()))
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One `read` into the buffer; maps EOF to [`HttpError::Closed`].
    fn fill_some(&mut self) -> Result<(), HttpError> {
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk).map_err(io_error)?;
        if n == 0 {
            return Err(HttpError::Closed);
        }
        self.bytes_in += n as u64;
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(())
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Loopback pair for message-layer tests.
    fn pair() -> (Conn, Conn) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (Conn::new(a), Conn::new(b))
    }

    #[test]
    fn round_trips_messages_with_bodies_and_keepalive() {
        let (mut c, mut s) = pair();
        c.write_message("POST /v1/infer HTTP/1.1", &[("Host", "x".into())], b"{\"a\":1}").unwrap();
        c.write_message("GET /healthz HTTP/1.1", &[], b"").unwrap();
        let m1 = s.read_message(1024).unwrap();
        assert_eq!(m1.start_line, "POST /v1/infer HTTP/1.1");
        assert_eq!(m1.header("host"), Some("x"), "case-insensitive lookup");
        assert_eq!(m1.body, b"{\"a\":1}");
        // second (pipelined) message comes straight out of the buffer
        let m2 = s.read_message(1024).unwrap();
        assert_eq!(m2.start_line, "GET /healthz HTTP/1.1");
        assert!(m2.body.is_empty());
        assert!(s.bytes_in() > 0 && c.bytes_out() == s.bytes_in());
    }

    #[test]
    fn oversized_body_is_too_large() {
        let (mut c, mut s) = pair();
        c.write_message("POST /x HTTP/1.1", &[], &[b'a'; 64]).unwrap();
        match s.read_message(16) {
            Err(HttpError::TooLarge("body")) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn clean_eof_between_messages_is_closed() {
        let (c, mut s) = pair();
        drop(c);
        match s.read_message(16) {
            Err(HttpError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn garbage_head_is_malformed() {
        let (mut c, mut s) = pair();
        c.write_message("NOT A HEADER LINE", &[("broken", String::new())], b"").unwrap();
        // header "broken: " parses fine; inject a truly bad one manually
        let m = s.read_message(16).unwrap();
        assert_eq!(m.start_line, "NOT A HEADER LINE");
        drop(m);
        c.stream.write_all(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").unwrap();
        match s.read_message(16) {
            Err(HttpError::Malformed(_)) => {}
            other => panic!("expected Malformed, got {other:?}"),
        }
    }
}
