//! Minimal HTTP/1.1 message layer shared by the wire server and
//! client (no hyper/reqwest in the offline vendor set).
//!
//! The core is the **incremental** [`Parser`]: feed it bytes as they
//! arrive ([`Parser::feed`]) and pull complete messages out
//! ([`Parser::next_message`]) — the readiness-loop front
//! (`net::evloop`) feeds it from non-blocking reads, one parser per
//! connection, thousands of connections per thread.  [`Conn`] wraps a
//! `TcpStream` + parser for the blocking users (the pool front and the
//! wire client): keep-alive connections carry back-to-back (even
//! pipelined) messages, and [`Conn::read_message`] blocks until the
//! next one is complete.  Bodies are `Content-Length` framed only
//! (chunked transfer encoding is rejected); head and body sizes are
//! capped so a hostile peer cannot balloon memory.
//!
//! Slow-read (slowloris) guard: the parser stamps the arrival of the
//! first byte of every message ([`Parser::started`]).  A peer that
//! trickles a request byte-by-byte is bounded by the caller's read
//! deadline — [`Conn::set_read_deadline`] enforces it on the blocking
//! path (each arrival re-checks elapsed time since the message
//! started), the event loop's timer wheel enforces it on the
//! non-blocking path.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Hard cap on the start-line + headers block.
pub const HEAD_LIMIT: usize = 16 * 1024;

/// What went wrong reading one HTTP message.
#[derive(Debug)]
pub enum HttpError {
    /// Clean EOF before any byte of the next message (keep-alive peer
    /// went away between requests).
    Closed,
    /// The socket read timed out, or the message exceeded the read
    /// deadline (slow-read guard).
    Timeout,
    /// Head or body exceeded its size cap (maps to `413`).
    TooLarge(&'static str),
    /// The bytes were not a valid HTTP/1.1 message (maps to `400`).
    Malformed(String),
    /// Transport error mid-message.
    Io(std::io::Error),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Closed => f.write_str("connection closed"),
            HttpError::Timeout => f.write_str("socket read timed out"),
            HttpError::TooLarge(what) => write!(f, "{what} too large"),
            HttpError::Malformed(msg) => write!(f, "malformed HTTP message: {msg}"),
            HttpError::Io(e) => write!(f, "transport error: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

fn io_error(e: std::io::Error) -> HttpError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => HttpError::Timeout,
        _ => HttpError::Io(e),
    }
}

/// One parsed message: start-line, headers, body.
#[derive(Debug)]
pub struct Message {
    pub start_line: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Message {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }
}

/// Case-insensitive header lookup over a parsed header list.
pub fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k.eq_ignore_ascii_case(name)).map(|(_, v)| v.as_str())
}

/// Serialize one message (start-line + headers + `Content-Length`
/// framing) to bytes — shared by the blocking [`Conn::write_message`]
/// and the event loop's write buffers.
pub fn encode_message(start_line: &str, headers: &[(&str, String)], body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(128 + body.len());
    out.extend_from_slice(start_line.as_bytes());
    out.extend_from_slice(b"\r\n");
    for (k, v) in headers {
        out.extend_from_slice(k.as_bytes());
        out.extend_from_slice(b": ");
        out.extend_from_slice(v.as_bytes());
        out.extend_from_slice(b"\r\n");
    }
    out.extend_from_slice(format!("Content-Length: {}\r\n\r\n", body.len()).as_bytes());
    out.extend_from_slice(body);
    out
}

/// A head parsed out of the buffer, waiting for its body bytes.
struct PendingHead {
    start_line: String,
    headers: Vec<(String, String)>,
    body_len: usize,
}

/// Incremental HTTP/1.1 message parser: a byte buffer plus the state
/// of the message currently being assembled.  `feed` bytes in any
/// chunking, pull complete messages with `next_message`; leftover
/// bytes (pipelined requests) stay buffered for the next call.
#[derive(Default)]
pub struct Parser {
    buf: Vec<u8>,
    head: Option<PendingHead>,
    /// When the first byte of the in-progress message arrived (the
    /// slow-read guard clock); `None` between messages.
    started: Option<Instant>,
    bytes_in: u64,
}

impl Parser {
    pub fn new() -> Parser {
        Parser::default()
    }

    /// Append bytes read off the transport.
    pub fn feed(&mut self, bytes: &[u8]) {
        if bytes.is_empty() {
            return;
        }
        self.bytes_in += bytes.len() as u64;
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Total bytes ever fed (the net-layer `bytes_in` counter).
    pub fn bytes_in(&self) -> u64 {
        self.bytes_in
    }

    /// Bytes buffered but not yet consumed as a message.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// True while a partial message sits in the buffer — the state the
    /// slow-read deadline applies to.
    pub fn mid_message(&self) -> bool {
        self.started.is_some()
    }

    /// Arrival time of the in-progress message's first byte.
    pub fn started(&self) -> Option<Instant> {
        self.started
    }

    /// Try to complete the next message from the buffered bytes.
    /// `Ok(None)` means "need more bytes"; errors are terminal for the
    /// connection (size caps, protocol violations).
    pub fn next_message(&mut self, body_cap: usize) -> Result<Option<Message>, HttpError> {
        if self.head.is_none() {
            let Some(head_end) = find_head_end(&self.buf) else {
                if self.buf.len() > HEAD_LIMIT {
                    return Err(HttpError::TooLarge("head"));
                }
                return Ok(None);
            };
            let head_bytes: Vec<u8> = self.buf.drain(..head_end + 4).collect();
            let head = std::str::from_utf8(&head_bytes[..head_end])
                .map_err(|_| HttpError::Malformed("head is not UTF-8".into()))?;
            let mut lines = head.split("\r\n");
            let start_line = lines
                .next()
                .filter(|l| !l.is_empty())
                .ok_or_else(|| HttpError::Malformed("empty start line".into()))?
                .to_string();
            let mut headers = Vec::new();
            for line in lines {
                if line.is_empty() {
                    continue;
                }
                let (k, v) = line
                    .split_once(':')
                    .ok_or_else(|| HttpError::Malformed(format!("bad header line {line:?}")))?;
                headers.push((k.trim().to_string(), v.trim().to_string()));
            }
            if header(&headers, "Transfer-Encoding").is_some() {
                return Err(HttpError::Malformed("chunked transfer encoding not supported".into()));
            }
            let body_len = match header(&headers, "Content-Length") {
                None => 0usize,
                Some(v) => v
                    .parse::<usize>()
                    .map_err(|_| HttpError::Malformed(format!("bad Content-Length {v:?}")))?,
            };
            self.head = Some(PendingHead { start_line, headers, body_len });
        }
        let body_len = self.head.as_ref().unwrap().body_len;
        if body_len > body_cap {
            return Err(HttpError::TooLarge("body"));
        }
        if self.buf.len() < body_len {
            return Ok(None);
        }
        let PendingHead { start_line, headers, body_len } = self.head.take().unwrap();
        let body: Vec<u8> = self.buf.drain(..body_len).collect();
        // leftover bytes are the next (pipelined) message, already
        // partially arrived: its deadline clock starts now
        self.started = if self.buf.is_empty() { None } else { Some(Instant::now()) };
        Ok(Some(Message { start_line, headers, body }))
    }
}

/// A blocking TCP connection over the incremental parser, with byte
/// counters for the net-layer metrics.
pub struct Conn {
    stream: TcpStream,
    parser: Parser,
    bytes_out: u64,
    read_deadline: Option<Duration>,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Conn {
        Conn { stream, parser: Parser::new(), bytes_out: 0, read_deadline: None }
    }

    /// The underlying socket (timeouts, socket options).
    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    pub fn bytes_in(&self) -> u64 {
        self.parser.bytes_in()
    }

    pub fn bytes_out(&self) -> u64 {
        self.bytes_out
    }

    /// True while a partial message is buffered (used to tell a
    /// slow-read kill from an idle keep-alive timeout).
    pub fn mid_message(&self) -> bool {
        self.parser.mid_message()
    }

    /// Bound the wall time one message may take to arrive, however
    /// slowly the peer trickles it (slowloris guard).  Checked on
    /// every arrival, so the effective kill time is
    /// `deadline + socket read timeout` at worst.
    pub fn set_read_deadline(&mut self, deadline: Option<Duration>) {
        self.read_deadline = deadline;
    }

    /// Read the next message off the connection; `body_cap` bounds the
    /// accepted `Content-Length`.
    pub fn read_message(&mut self, body_cap: usize) -> Result<Message, HttpError> {
        loop {
            if let Some(msg) = self.parser.next_message(body_cap)? {
                return Ok(msg);
            }
            if let (Some(deadline), Some(t0)) = (self.read_deadline, self.parser.started()) {
                if t0.elapsed() > deadline {
                    return Err(HttpError::Timeout);
                }
            }
            let was_mid = self.parser.mid_message();
            match self.fill_some() {
                Ok(()) => {}
                // EOF between messages is a clean keep-alive close;
                // EOF mid-message is a protocol error
                Err(HttpError::Closed) if was_mid => {
                    return Err(HttpError::Malformed("EOF mid-message".into()))
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Write pre-encoded wire bytes (see [`encode_message`]).
    pub fn write_raw(&mut self, bytes: &[u8]) -> Result<(), HttpError> {
        self.stream.write_all(bytes).map_err(io_error)?;
        self.stream.flush().map_err(io_error)?;
        self.bytes_out += bytes.len() as u64;
        Ok(())
    }

    /// Write one message; returns when the bytes are handed to the OS.
    pub fn write_message(
        &mut self,
        start_line: &str,
        headers: &[(&str, String)],
        body: &[u8],
    ) -> Result<(), HttpError> {
        let bytes = encode_message(start_line, headers, body);
        // write_all already retries ErrorKind::Interrupted internally
        self.stream.write_all(&bytes).map_err(io_error)?;
        self.stream.flush().map_err(io_error)?;
        self.bytes_out += bytes.len() as u64;
        Ok(())
    }

    /// One `read` into the parser; maps EOF to [`HttpError::Closed`]
    /// and retries `EINTR`.
    fn fill_some(&mut self) -> Result<(), HttpError> {
        let mut chunk = [0u8; 4096];
        let n = loop {
            match self.stream.read(&mut chunk) {
                Ok(n) => break n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(io_error(e)),
            }
        };
        if n == 0 {
            return Err(HttpError::Closed);
        }
        self.parser.feed(&chunk[..n]);
        Ok(())
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Loopback pair for message-layer tests.
    fn pair() -> (Conn, Conn) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (Conn::new(a), Conn::new(b))
    }

    #[test]
    fn round_trips_messages_with_bodies_and_keepalive() {
        let (mut c, mut s) = pair();
        c.write_message("POST /v1/infer HTTP/1.1", &[("Host", "x".into())], b"{\"a\":1}").unwrap();
        c.write_message("GET /healthz HTTP/1.1", &[], b"").unwrap();
        let m1 = s.read_message(1024).unwrap();
        assert_eq!(m1.start_line, "POST /v1/infer HTTP/1.1");
        assert_eq!(m1.header("host"), Some("x"), "case-insensitive lookup");
        assert_eq!(m1.body, b"{\"a\":1}");
        // second (pipelined) message comes straight out of the buffer
        let m2 = s.read_message(1024).unwrap();
        assert_eq!(m2.start_line, "GET /healthz HTTP/1.1");
        assert!(m2.body.is_empty());
        assert!(s.bytes_in() > 0 && c.bytes_out() == s.bytes_in());
    }

    #[test]
    fn oversized_body_is_too_large() {
        let (mut c, mut s) = pair();
        c.write_message("POST /x HTTP/1.1", &[], &[b'a'; 64]).unwrap();
        match s.read_message(16) {
            Err(HttpError::TooLarge("body")) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn clean_eof_between_messages_is_closed() {
        let (c, mut s) = pair();
        drop(c);
        match s.read_message(16) {
            Err(HttpError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn garbage_head_is_malformed() {
        let (mut c, mut s) = pair();
        c.write_message("NOT A HEADER LINE", &[("broken", String::new())], b"").unwrap();
        // header "broken: " parses fine; inject a truly bad one manually
        let m = s.read_message(16).unwrap();
        assert_eq!(m.start_line, "NOT A HEADER LINE");
        drop(m);
        c.stream.write_all(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").unwrap();
        match s.read_message(16) {
            Err(HttpError::Malformed(_)) => {}
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn incremental_parser_assembles_messages_byte_by_byte() {
        let raw = b"POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"a\":1}";
        let mut p = Parser::new();
        assert!(!p.mid_message());
        for (i, b) in raw.iter().enumerate() {
            // no message until the very last byte lands
            assert!(p.next_message(1024).unwrap().is_none(), "early message at byte {i}");
            p.feed(std::slice::from_ref(b));
            assert!(p.mid_message());
        }
        let m = p.next_message(1024).unwrap().expect("complete after the last byte");
        assert_eq!(m.start_line, "POST /v1/infer HTTP/1.1");
        assert_eq!(m.body, b"{\"a\":1}");
        assert!(!p.mid_message(), "parser is idle between messages");
        assert_eq!(p.bytes_in(), raw.len() as u64);
    }

    #[test]
    fn incremental_parser_keeps_pipelined_leftovers() {
        let mut p = Parser::new();
        p.feed(b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\nGET /c");
        assert_eq!(p.next_message(64).unwrap().unwrap().start_line, "GET /a HTTP/1.1");
        assert_eq!(p.next_message(64).unwrap().unwrap().start_line, "GET /b HTTP/1.1");
        // the third message is partial: deadline clock restarted for it
        assert!(p.next_message(64).unwrap().is_none());
        assert!(p.mid_message());
        p.feed(b" HTTP/1.1\r\n\r\n");
        assert_eq!(p.next_message(64).unwrap().unwrap().start_line, "GET /c HTTP/1.1");
    }

    #[test]
    fn read_deadline_kills_a_trickling_message() {
        let (mut c, mut s) = pair();
        s.set_read_deadline(Some(Duration::from_millis(80)));
        let _ = s.stream.set_read_timeout(Some(Duration::from_millis(30)));
        // trickle a partial head slower than the deadline allows
        c.stream.write_all(b"POST /v1/infer HT").unwrap();
        let t0 = Instant::now();
        loop {
            match s.read_message(1024) {
                Err(HttpError::Timeout) if s.mid_message() => break,
                Err(HttpError::Timeout) => {
                    // socket-timeout tick before the deadline: keep going
                    assert!(t0.elapsed() < Duration::from_secs(2), "never hit the deadline");
                    c.stream.write_all(b"T").unwrap();
                }
                other => panic!("expected slow-read timeout, got {other:?}"),
            }
        }
        assert!(t0.elapsed() >= Duration::from_millis(80), "killed before the deadline");
    }

    #[test]
    fn encode_message_matches_conn_writes() {
        let bytes = encode_message("GET / HTTP/1.1", &[("Host", "x".into())], b"hi");
        let text = String::from_utf8(bytes).unwrap();
        assert_eq!(text, "GET / HTTP/1.1\r\nHost: x\r\nContent-Length: 2\r\n\r\nhi");
    }
}
