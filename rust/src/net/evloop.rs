//! Epoll readiness loop (Linux): the event-driven serving front.
//!
//! One blocking acceptor thread round-robins accepted sockets to a
//! small set of event threads.  Each event thread owns an epoll
//! instance and a `HashMap` of connection state machines: non-blocking
//! reads feed the incremental `http::Parser`, complete requests go
//! through the shared `server::route()`, and in-flight coordinator
//! work (`InflightInfer`) is polled with `Pending::try_wait` — so a
//! handful of threads hold tens of thousands of keep-alive sockets
//! where the pool front caps out at its worker count.
//!
//! Mechanics worth knowing:
//!
//!  * **FFI surface is three syscalls.** `epoll_create1/ctl/wait` are
//!    declared `extern "C"` against the libc std already links (the
//!    no-new-deps rule); sockets become non-blocking via std's
//!    `set_nonblocking`, and the cross-thread wake-up is a
//!    `UnixStream::pair`, not an eventfd.
//!  * **Level-triggered** with explicit interest management: `EPOLLIN`
//!    is dropped while a response is in flight and the parser already
//!    buffers [`PIPELINE_BUF_CAP`] bytes (pipelining backpressure),
//!    `EPOLLOUT` is raised only while the write buffer is non-empty.
//!  * **Timeouts ride a hashed timer wheel** with lazy re-check: each
//!    connection keeps exactly one wheel entry; when it fires, the
//!    real deadline (idle keep-alive, or the slow-read guard while a
//!    partial message is buffered) is recomputed and the entry either
//!    kills the connection or reschedules.
//!  * **Completion polling** runs with a zero epoll timeout plus a
//!    50µs sleep when nothing progressed — the latency floor for
//!    coordinator answers is microseconds, not the 1ms epoll tick.
//!  * **Graceful drain**: on stop, idle connections close immediately,
//!    in-flight requests finish and flush with `Connection: close`.

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::raw::c_int;
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use super::http::Parser;
use super::server::{
    answer_bytes, route, shed_connection, Answer, Ctx, Gauge, InflightInfer, Routed,
};
use crate::obs::Stage;

/// Stop reading from a connection whose parser already buffers this
/// many bytes while a response is in flight: bounds per-connection
/// memory and keeps a pipelining peer from busy-looping the level-
/// triggered readiness.
const PIPELINE_BUF_CAP: usize = 64 * 1024;

/// Force-close everything still open this long after a drain starts.
const DRAIN_LIMIT: Duration = Duration::from_secs(5);

// ---------------------------------------------------------------- FFI

// std already links libc on unix; declaring the three epoll calls (and
// rlimit/setsockopt for the bench helpers) here keeps the no-new-deps
// rule — same idiom as `signal` in main.rs.
extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn close(fd: c_int) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
    fn setsockopt(
        fd: c_int,
        level: c_int,
        name: c_int,
        value: *const Linger,
        len: u32,
    ) -> c_int;
}

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLRDHUP: u32 = 0x2000;

/// The kernel ABI struct: packed on x86_64 (the one arch where the
/// kernel's layout differs from natural C alignment).
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[repr(C)]
struct RLimit {
    cur: u64,
    max: u64,
}

#[repr(C)]
struct Linger {
    onoff: c_int,
    linger: c_int,
}

const RLIMIT_NOFILE: c_int = 7;
const SOL_SOCKET: c_int = 1;
const SO_LINGER: c_int = 13;

/// Raise the soft open-file limit toward `want` (capped at the hard
/// limit) and return the effective soft limit.  A 10k-device loopback
/// drive needs ~2× that many fds in one process; the default soft
/// limit is often 1024.
pub fn raise_nofile(want: u64) -> u64 {
    let mut lim = RLimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 0;
    }
    if lim.cur >= want {
        return lim.cur;
    }
    let new = RLimit { cur: want.min(lim.max), max: lim.max };
    if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
        new.cur
    } else {
        lim.cur
    }
}

/// Make dropping this socket send an RST instead of a FIN
/// (`SO_LINGER` 0): the close leaves no TIME_WAIT state behind, so a
/// bench sweep tearing down 10k client connections per point doesn't
/// strand the ephemeral-port range for 60s.
pub fn abortive_close(stream: &TcpStream) {
    let lg = Linger { onoff: 1, linger: 0 };
    let _ = unsafe {
        setsockopt(
            stream.as_raw_fd(),
            SOL_SOCKET,
            SO_LINGER,
            &lg,
            std::mem::size_of::<Linger>() as u32,
        )
    };
}

/// Thin owning wrapper over one epoll instance.
struct Epoll {
    fd: RawFd,
}

impl Epoll {
    fn new() -> Result<Epoll> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error()).context("epoll_create1");
        }
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> std::io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        if unsafe { epoll_ctl(self.fd, op, fd, &mut ev) } != 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    fn add(&self, fd: RawFd, events: u32, token: u64) -> std::io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    fn modify(&self, fd: RawFd, events: u32, token: u64) -> std::io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    /// Wait for readiness; `EINTR` counts as an empty wake-up.
    fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> usize {
        let n = unsafe {
            epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, timeout_ms)
        };
        if n < 0 {
            0 // EINTR or a transient error: treat as a timeout tick
        } else {
            n as usize
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

// ------------------------------------------------------- timer wheel

/// Hashed timer wheel with lazy re-check.  `schedule` drops a token
/// into the slot of its deadline tick (modulo the wheel, so far-out
/// deadlines fire early — the owner re-checks the real deadline and
/// reschedules).  Each connection keeps exactly one live entry; stale
/// entries for closed connections fall out on a failed lookup.
struct TimerWheel {
    slots: Vec<Vec<u64>>,
    granularity: Duration,
    epoch: Instant,
    cursor: u64,
}

impl TimerWheel {
    fn new(n_slots: usize, granularity: Duration) -> TimerWheel {
        TimerWheel {
            slots: (0..n_slots).map(|_| Vec::new()).collect(),
            granularity,
            epoch: Instant::now(),
            cursor: 0,
        }
    }

    fn tick_of(&self, t: Instant) -> u64 {
        (t.saturating_duration_since(self.epoch).as_nanos() / self.granularity.as_nanos().max(1))
            as u64
    }

    fn schedule(&mut self, token: u64, deadline: Instant) {
        // never behind the cursor, or the entry would wait a full lap
        let tick = self.tick_of(deadline).max(self.cursor);
        let idx = (tick % self.slots.len() as u64) as usize;
        self.slots[idx].push(token);
    }

    /// Drain every slot up to `now` into `due`.
    fn advance(&mut self, now: Instant, due: &mut Vec<u64>) {
        let now_tick = self.tick_of(now);
        // a long stall (> one lap) still visits each slot once
        let laps = self.slots.len() as u64;
        let end = now_tick.min(self.cursor + laps);
        while self.cursor <= end {
            let idx = (self.cursor % laps) as usize;
            due.append(&mut self.slots[idx]);
            self.cursor += 1;
        }
        self.cursor = self.cursor.max(now_tick + 1);
    }
}

// -------------------------------------------------- connection state

/// One non-blocking connection owned by an event thread.
struct ConnState {
    stream: TcpStream,
    fd: RawFd,
    parser: Parser,
    /// Response bytes not yet accepted by the socket.
    out: Vec<u8>,
    out_pos: usize,
    /// A routed request waiting on the coordinator, plus whether the
    /// connection stays open after its answer.
    inflight: Option<(InflightInfer, bool)>,
    close_after_write: bool,
    peer_closed: bool,
    last_activity: Instant,
    /// Events currently registered with epoll (interest cache).
    interest: u32,
    gauge: Option<Gauge>,
    /// Parser bytes already folded into the shared counters.
    folded_in: u64,
}

impl ConnState {
    fn wants_read(&self, draining: bool) -> bool {
        if self.peer_closed || self.close_after_write || draining {
            return false;
        }
        // backpressure: a pipelining peer stops being read once enough
        // of its next requests are buffered behind an in-flight answer
        !(self.inflight.is_some() && self.parser.buffered() >= PIPELINE_BUF_CAP)
    }

    fn wants_write(&self) -> bool {
        self.out_pos < self.out.len()
    }

    /// The live gauge this connection belongs in right now.
    fn gauge_now(&self) -> Gauge {
        if self.inflight.is_some() || self.wants_write() {
            Gauge::Writing
        } else if self.parser.mid_message() {
            Gauge::Reading
        } else {
            Gauge::Idle
        }
    }

    /// When this connection must next be inspected for a timeout.
    fn deadline(&self, opts: &super::server::NetOpts) -> Option<Instant> {
        if self.inflight.is_some() {
            None // bounded by the coordinator, not the wire
        } else if let Some(t0) = self.parser.started() {
            Some(t0 + opts.read_deadline) // slow-read guard
        } else if self.wants_write() {
            Some(self.last_activity + opts.read_deadline) // stuck writer
        } else {
            Some(self.last_activity + opts.keep_alive) // idle keep-alive
        }
    }
}

// --------------------------------------------------------- the front

/// Handle to the running epoll front: the acceptor, the event threads,
/// and their wake-up pipes.
pub(crate) struct EvLoop {
    acceptor: Option<JoinHandle<()>>,
    threads: Vec<JoinHandle<()>>,
    wakes: Vec<UnixStream>,
}

impl EvLoop {
    /// Spawn the acceptor and `opts.event_threads` event threads
    /// (0 = `min(4, cores)`).
    pub(crate) fn start(listener: TcpListener, ctx: Arc<Ctx>) -> Result<EvLoop> {
        let n = match ctx.opts.event_threads {
            0 => std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(4),
            n => n,
        };
        let mut threads = Vec::with_capacity(n);
        let mut wakes = Vec::with_capacity(n);
        let mut handoffs = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<TcpStream>();
            let (wake_r, wake_w) = UnixStream::pair().context("wake pipe")?;
            wake_r.set_nonblocking(true)?;
            wake_w.set_nonblocking(true)?;
            let tctx = Arc::clone(&ctx);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("flexsvm-ev-{i}"))
                    .spawn(move || EventThread::new(tctx, rx, wake_r).run())?,
            );
            let wake_accept_side = wake_w.try_clone()?;
            wakes.push(wake_w);
            handoffs.push((tx, wake_accept_side));
        }
        let actx = Arc::clone(&ctx);
        let acceptor = std::thread::Builder::new()
            .name("flexsvm-ev-accept".into())
            .spawn(move || accept_loop(listener, handoffs, actx))?;
        Ok(EvLoop { acceptor: Some(acceptor), threads, wakes })
    }

    /// Join everything down.  The caller has already set `ctx.stop`
    /// and poked the listener awake.
    pub(crate) fn stop(mut self) {
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // acceptor exit dropped the handoff senders; a wake byte makes
        // each event thread notice stop + disconnect immediately
        for w in &self.wakes {
            let _ = (&*w).write(&[1]);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    handoffs: Vec<(mpsc::Sender<TcpStream>, UnixStream)>,
    ctx: Arc<Ctx>,
) {
    let mut next = 0usize;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if ctx.stop.load(Ordering::SeqCst) {
                    return; // the shutdown wake-up
                }
                ctx.counters.accepted.fetch_add(1, Ordering::Relaxed);
                if ctx.counters.active.load(Ordering::SeqCst) >= ctx.opts.max_conns as u64 {
                    // connection cap: shed at the door, same contract
                    // as the pool front's full backlog
                    ctx.counters.shed.fetch_add(1, Ordering::Relaxed);
                    shed_connection(stream, &ctx);
                    continue;
                }
                let (tx, wake) = &handoffs[next % handoffs.len()];
                next += 1;
                if tx.send(stream).is_ok() {
                    let _ = (&*wake).write(&[1]);
                }
            }
            Err(_) => {
                if ctx.stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Per-thread readiness loop state.
struct EventThread {
    ctx: Arc<Ctx>,
    ep: Epoll,
    rx: mpsc::Receiver<TcpStream>,
    wake: UnixStream,
    conns: HashMap<u64, ConnState>,
    /// Tokens with an in-flight coordinator request to poll.
    inflight: HashSet<u64>,
    wheel: TimerWheel,
    next_token: u64,
    draining_since: Option<Instant>,
}

/// epoll token of the wake pipe (connection tokens start at 1).
const WAKE_TOKEN: u64 = 0;

impl EventThread {
    fn new(ctx: Arc<Ctx>, rx: mpsc::Receiver<TcpStream>, wake: UnixStream) -> EventThread {
        let ep = Epoll::new().expect("epoll_create1");
        ep.add(wake.as_raw_fd(), EPOLLIN, WAKE_TOKEN).expect("register wake pipe");
        EventThread {
            ctx,
            ep,
            rx,
            wake,
            conns: HashMap::new(),
            inflight: HashSet::new(),
            wheel: TimerWheel::new(128, Duration::from_millis(20)),
            next_token: 1,
            draining_since: None,
        }
    }

    fn run(mut self) {
        let mut events = vec![EpollEvent { events: 0, data: 0 }; 512];
        let mut due: Vec<u64> = Vec::new();
        loop {
            // zero timeout while coordinator answers are pending: their
            // latency floor is the poll cadence, not the epoll tick
            let timeout_ms: i32 = if self.inflight.is_empty() { 20 } else { 0 };
            let n = self.ep.wait(&mut events, timeout_ms);
            let mut progress = n > 0;
            for i in 0..n {
                let (token, evs) = (events[i].data, events[i].events);
                if token == WAKE_TOKEN {
                    let mut buf = [0u8; 64];
                    while matches!((&self.wake).read(&mut buf), Ok(n) if n > 0) {}
                    continue;
                }
                self.handle_io(token, evs);
            }

            // adopt newly accepted connections
            let mut disconnected = false;
            loop {
                match self.rx.try_recv() {
                    Ok(stream) => {
                        progress = true;
                        self.register(stream);
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }

            // poll in-flight coordinator work
            let settled: Vec<u64> = self
                .inflight
                .iter()
                .copied()
                .filter(|t| {
                    self.conns
                        .get_mut(t)
                        .and_then(|c| c.inflight.as_mut())
                        .is_some_and(|(f, _)| f.try_settle())
                })
                .collect();
            for token in settled {
                progress = true;
                self.complete(token);
            }

            // timer wheel sweep
            let now = Instant::now();
            self.wheel.advance(now, &mut due);
            for token in std::mem::take(&mut due) {
                self.check_deadline(token, now);
            }

            // graceful drain: close idle conns, let in-flight finish
            if self.ctx.stop.load(Ordering::SeqCst) {
                let t0 = *self.draining_since.get_or_insert(now);
                let force = now.duration_since(t0) > DRAIN_LIMIT;
                let doomed: Vec<u64> = self
                    .conns
                    .iter()
                    .filter(|(_, c)| force || (c.inflight.is_none() && !c.wants_write()))
                    .map(|(t, _)| *t)
                    .collect();
                for token in doomed {
                    self.close_conn(token, false);
                }
                if disconnected && self.conns.is_empty() {
                    return;
                }
            }

            if !self.inflight.is_empty() && !progress {
                // completions are near: poll at 50µs, not a full tick
                std::thread::sleep(Duration::from_micros(50));
            }
        }
    }

    fn register(&mut self, stream: TcpStream) {
        if self.ctx.stop.load(Ordering::SeqCst) {
            // accepted just before the drain began: drop it
            self.ctx.counters.closed.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            self.ctx.counters.closed.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let _ = stream.set_nodelay(true);
        let fd = stream.as_raw_fd();
        let token = self.next_token;
        self.next_token += 1;
        let interest = EPOLLIN | EPOLLRDHUP;
        if self.ep.add(fd, interest, token).is_err() {
            self.ctx.counters.closed.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let now = Instant::now();
        self.ctx.counters.active.fetch_add(1, Ordering::SeqCst);
        self.ctx.counters.move_gauge(None, Some(Gauge::Idle));
        self.wheel.schedule(token, now + self.ctx.opts.keep_alive);
        self.conns.insert(
            token,
            ConnState {
                stream,
                fd,
                parser: Parser::new(),
                out: Vec::new(),
                out_pos: 0,
                inflight: None,
                close_after_write: false,
                peer_closed: false,
                last_activity: now,
                interest,
                gauge: Some(Gauge::Idle),
                folded_in: 0,
            },
        );
    }

    /// Readiness on one connection: read what's there, parse + route,
    /// flush what's writable, then re-arm interest.
    fn handle_io(&mut self, token: u64, evs: u32) {
        let Some(conn) = self.conns.get_mut(&token) else {
            return; // closed earlier this tick
        };
        let mut fatal = evs & (EPOLLERR | EPOLLHUP) != 0;
        if !fatal && evs & (EPOLLIN | EPOLLRDHUP) != 0 {
            let draining = self.ctx.stop.load(Ordering::SeqCst);
            let mut chunk = [0u8; 16 * 1024];
            while conn.wants_read(draining) {
                match conn.stream.read(&mut chunk) {
                    Ok(0) => {
                        conn.peer_closed = true;
                        break;
                    }
                    Ok(n) => {
                        conn.parser.feed(&chunk[..n]);
                        conn.last_activity = Instant::now();
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        fatal = true;
                        break;
                    }
                }
            }
            let folded = conn.parser.bytes_in() - conn.folded_in;
            conn.folded_in = conn.parser.bytes_in();
            self.ctx.counters.bytes_in.fetch_add(folded, Ordering::Relaxed);
        }
        if fatal {
            self.close_conn(token, false);
            return;
        }
        self.pump(token);
    }

    /// Drive one connection forward: parse + route buffered requests,
    /// flush pending output, close or re-arm.
    fn pump(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        // parse and route while the answer pipeline is clear: HTTP/1.1
        // answers must go out in request order, so a request in flight
        // at the coordinator holds everything behind it
        let mut fatal = false;
        while conn.inflight.is_none() && !conn.close_after_write {
            match conn.parser.next_message(self.ctx.opts.body_limit) {
                Ok(Some(msg)) => {
                    self.ctx.counters.requests.fetch_add(1, Ordering::Relaxed);
                    let close_req = msg
                        .header("Connection")
                        .map(|v| v.eq_ignore_ascii_case("close"))
                        .unwrap_or(false);
                    let keep = !close_req && !self.ctx.stop.load(Ordering::SeqCst);
                    match route(&self.ctx, &msg) {
                        Routed::Ready(a) => {
                            enqueue_answer(&self.ctx, conn, &a, keep);
                        }
                        Routed::Infer(f) => {
                            conn.inflight = Some((f, keep));
                            self.inflight.insert(token);
                        }
                    }
                }
                Ok(None) => break,
                Err(super::http::HttpError::TooLarge(what)) => {
                    let a = Answer::plain(
                        413,
                        "Payload Too Large",
                        &format!("request {what} too large"),
                    );
                    enqueue_answer(&self.ctx, conn, &a, false);
                }
                Err(super::http::HttpError::Malformed(m)) => {
                    let a = Answer::plain(400, "Bad Request", &m);
                    enqueue_answer(&self.ctx, conn, &a, false);
                }
                // the parser itself never yields Closed/Timeout/Io
                Err(_) => {
                    fatal = true;
                    break;
                }
            }
        }
        if fatal || self.flush(token).is_err() {
            self.close_conn(token, false);
            return;
        }
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        let done_writing = !conn.wants_write();
        if done_writing && conn.inflight.is_none() && (conn.close_after_write || conn.peer_closed)
        {
            self.close_conn(token, false);
            return;
        }
        self.rearm(token);
    }

    /// A coordinator answer landed: assemble, enqueue, and pick up any
    /// pipelined request buffered behind it.
    fn complete(&mut self, token: u64) {
        self.inflight.remove(&token);
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        let Some((f, keep)) = conn.inflight.take() else {
            return;
        };
        let answer = f.finalize(&self.ctx);
        let keep = keep && !self.ctx.stop.load(Ordering::SeqCst);
        enqueue_answer(&self.ctx, conn, &answer, keep);
        self.pump(token);
    }

    /// Write buffered output until the socket stops accepting.
    fn flush(&mut self, token: u64) -> std::io::Result<()> {
        let Some(conn) = self.conns.get_mut(&token) else {
            return Ok(());
        };
        while conn.out_pos < conn.out.len() {
            match conn.stream.write(&conn.out[conn.out_pos..]) {
                Ok(0) => return Err(std::io::ErrorKind::WriteZero.into()),
                Ok(n) => {
                    conn.out_pos += n;
                    conn.last_activity = Instant::now();
                    self.ctx.counters.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if conn.out_pos >= conn.out.len() {
            conn.out.clear();
            conn.out_pos = 0;
        }
        Ok(())
    }

    /// Sync epoll interest and the live gauge with the state machine.
    fn rearm(&mut self, token: u64) {
        let draining = self.ctx.stop.load(Ordering::SeqCst);
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        let mut want = EPOLLRDHUP;
        if conn.wants_read(draining) {
            want |= EPOLLIN;
        }
        if conn.wants_write() {
            want |= EPOLLOUT;
        }
        let mut fatal = false;
        if want != conn.interest {
            if self.ep.modify(conn.fd, want, token).is_ok() {
                conn.interest = want;
            } else {
                fatal = true;
            }
        }
        let g = Some(conn.gauge_now());
        if g != conn.gauge {
            self.ctx.counters.move_gauge(conn.gauge, g);
            conn.gauge = g;
        }
        if fatal {
            self.close_conn(token, false);
        }
    }

    /// A wheel entry fired: recompute the real deadline; kill or
    /// reschedule.
    fn check_deadline(&mut self, token: u64, now: Instant) {
        let Some(conn) = self.conns.get_mut(&token) else {
            return; // stale entry of a closed connection
        };
        match conn.deadline(&self.ctx.opts) {
            Some(d) if d <= now => {
                // a partial message that ran out its deadline is the
                // slow-read guard firing; an idle expiry is routine
                let slow_read = conn.parser.mid_message();
                self.close_conn(token, slow_read);
            }
            Some(d) => self.wheel.schedule(token, d),
            // in flight at the coordinator: look again in a while
            None => self.wheel.schedule(token, now + self.ctx.opts.keep_alive),
        }
    }

    fn close_conn(&mut self, token: u64, timed_out: bool) {
        let Some(conn) = self.conns.remove(&token) else {
            return;
        };
        self.inflight.remove(&token);
        // dropping the stream closes the fd, which also removes it
        // from the epoll interest list — no EPOLL_CTL_DEL needed
        let folded = conn.parser.bytes_in() - conn.folded_in;
        self.ctx.counters.bytes_in.fetch_add(folded, Ordering::Relaxed);
        self.ctx.counters.move_gauge(conn.gauge, None);
        self.ctx.counters.active.fetch_sub(1, Ordering::SeqCst);
        self.ctx.counters.closed.fetch_add(1, Ordering::Relaxed);
        if timed_out {
            self.ctx.counters.timed_out.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Serialize an answer into the connection's write buffer and credit
/// the encode stage (serialization only — the socket write is async).
fn enqueue_answer(ctx: &Ctx, conn: &mut ConnState, a: &Answer, keep: bool) {
    let t_enc = Instant::now();
    let bytes = answer_bytes(a, keep, &ctx.opts);
    if let Some(cfg) = &a.encode_cfg {
        ctx.client.obs().record_stage(cfg, Stage::Encode, t_enc.elapsed().as_micros() as u64);
    }
    conn.out.extend_from_slice(&bytes);
    conn.last_activity = Instant::now();
    if !keep {
        conn.close_after_write = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_wheel_fires_in_order_and_reschedules() {
        let mut w = TimerWheel::new(8, Duration::from_millis(10));
        let t0 = w.epoch;
        w.schedule(1, t0 + Duration::from_millis(25));
        w.schedule(2, t0 + Duration::from_millis(5));
        let mut due = Vec::new();
        w.advance(t0 + Duration::from_millis(12), &mut due);
        assert_eq!(due, vec![2], "only the near deadline fires");
        due.clear();
        w.advance(t0 + Duration::from_millis(40), &mut due);
        assert_eq!(due, vec![1]);
        // far-out deadlines (> one lap) fire early and are simply
        // rescheduled by the owner — lazy re-check by design
        due.clear();
        w.schedule(3, t0 + Duration::from_secs(10));
        w.advance(t0 + Duration::from_millis(200), &mut due);
        assert_eq!(due, vec![3]);
    }

    #[test]
    fn raise_nofile_reports_a_sane_limit() {
        let got = raise_nofile(256);
        assert!(got >= 256, "soft nofile limit {got} below floor");
    }
}
