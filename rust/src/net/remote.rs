//! [`RemoteEngine`]: the coordinator's [`Engine`] contract executed on
//! remote flexsvm nodes over the wire protocol.
//!
//! This is the piece that takes the serving stack multi-node: a local
//! coordinator built with `Server::builder().keys(..).engine(..)` keeps
//! its whole batching/metrics/failure-isolation loop, while batches
//! execute on N remote `net::server` nodes.  Per batch, the sample
//! slice is split into contiguous chunks — one per node — and the
//! chunks are posted concurrently; each node's own coordinator then
//! re-batches and runs them on whatever engine *it* was built with
//! (native, the SoC farm, PJRT, or another `RemoteEngine` one hop
//! further out).
//!
//! Failure mapping is typed end to end: per-sample wire errors come
//! back as their original [`ServeError`] variants (per-sample isolation
//! crosses the machine boundary), connect failures after the client's
//! bounded reconnect map to [`ServeError::ServerDown`], timeouts and
//! transport drops to [`ServeError::Engine`] — a dead node fails its
//! chunk alone, not the whole batch.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context as _, Result};

use crate::coordinator::metrics::ConfigMetrics;
use crate::engine::{batch_error, BatchCtx, Engine, EngineMetrics, ModelSource, Sample, ServeError};
use crate::farm::FarmMetrics;
use crate::obs::log as evlog;
use crate::obs::{ConfigProfile, TraceId};
use crate::util::json::Json;

use super::client::{HttpClient, HttpClientOpts, NetError};
use super::wire;

/// Remote-node serving engine (see the module docs).
pub struct RemoteEngine {
    name: String,
    nodes: Vec<Mutex<HttpClient>>,
    /// Remote per-config software-baseline cycles, fetched at warm.
    baselines: HashMap<String, f64>,
    /// Rotating start node, so small (even single-sample) batches
    /// spread across the fleet instead of pinning node 0.
    next: AtomicUsize,
}

impl RemoteEngine {
    /// Fan out to the given `host:port` nodes with default client
    /// options.
    pub fn new<I, S>(addrs: I) -> Result<RemoteEngine>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        Self::with_opts(addrs, HttpClientOpts::default())
    }

    pub fn with_opts<I, S>(addrs: I, opts: HttpClientOpts) -> Result<RemoteEngine>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let addrs: Vec<String> = addrs.into_iter().map(Into::into).collect();
        if addrs.is_empty() {
            bail!("RemoteEngine needs at least one node address");
        }
        let name = format!("remote({})", addrs.join(","));
        let nodes = addrs
            .into_iter()
            .map(|a| Mutex::new(HttpClient::with_opts(a, opts.clone())))
            .collect();
        Ok(RemoteEngine { name, nodes, baselines: HashMap::new(), next: AtomicUsize::new(0) })
    }

    /// Node count (chunks per batch).
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Execute one contiguous chunk on one node.  When trace ids ride
    /// along (`traces.len() == xs.len()`), they travel in the wire
    /// body plus an `X-Trace-Id` header, and each answered sample's
    /// span comes back as a child span stamped with this node's
    /// address.
    fn run_chunk(
        &self,
        node: usize,
        key: &str,
        xs: &[Vec<i32>],
        traces: &[TraceId],
    ) -> Vec<Result<Sample, ServeError>> {
        let mut client = self.nodes[node].lock().unwrap();
        let addr = client.addr().to_string();
        let resp = if traces.len() == xs.len() && !traces.is_empty() {
            let body = wire::infer_batch_body_traced(key, xs, traces);
            let extra = [("X-Trace-Id".to_string(), traces[0].to_hex())];
            client.post_json_with("/v1/infer", &body, &extra)
        } else {
            client.post_json("/v1/infer", &wire::infer_batch_body(key, xs))
        };
        let resp = match resp {
            Ok(r) => r,
            Err(e) => {
                let err = net_to_serve(e);
                if err == ServeError::ServerDown {
                    evlog::emit_fmt(evlog::Level::Warn, "node_down", || {
                        format!("node {addr} unreachable after bounded reconnect; chunk failed alone")
                    });
                }
                return batch_error(xs.len(), err);
            }
        };
        if resp.status != 200 {
            return batch_error(xs.len(), status_to_serve(resp.status, &resp.body));
        }
        let doc = match resp.json() {
            Ok(d) => d,
            Err(e) => return batch_error(xs.len(), ServeError::Engine(e.to_string())),
        };
        let results = match doc.get("results").and_then(|r| r.as_arr().map(|a| a.to_vec())) {
            Ok(r) => r,
            Err(e) => {
                return batch_error(xs.len(), ServeError::Engine(format!("bad results: {e:#}")))
            }
        };
        if results.len() != xs.len() {
            let msg = format!("node answered {} samples for a chunk of {}", results.len(), xs.len());
            return batch_error(xs.len(), ServeError::Engine(msg));
        }
        results
            .iter()
            .map(|item| {
                if item.opt("error").is_some() {
                    Err(wire::error_from_json(item))
                } else {
                    wire::sample_from_json(item)
                        .map(|mut s| {
                            if let Some(child) = s.child.as_mut() {
                                if child.node.is_empty() {
                                    child.node = addr.clone();
                                }
                            }
                            s
                        })
                        .map_err(|e| ServeError::Engine(format!("bad sample: {e:#}")))
                }
            })
            .collect()
    }

    /// Split the batch into contiguous per-node chunks and post them
    /// concurrently (shared by the traced and untraced entry points).
    fn fan_out(
        &self,
        key: &str,
        xs: &[Vec<i32>],
        traces: &[TraceId],
    ) -> Vec<Result<Sample, ServeError>> {
        if xs.is_empty() {
            return Vec::new();
        }
        let n_nodes = self.nodes.len();
        // rotate the start node per batch: small batches (down to the
        // single-sample flushes of a lightly-loaded front) spread over
        // the fleet instead of pinning node 0
        let start = self.next.fetch_add(1, Ordering::Relaxed) % n_nodes;
        if n_nodes == 1 || xs.len() == 1 {
            return self.run_chunk(start, key, xs, traces);
        }
        // contiguous chunks, one per node, posted concurrently
        let chunk = xs.len().div_ceil(n_nodes);
        let chunks: Vec<&[Vec<i32>]> = xs.chunks(chunk).collect();
        let tchunks: Vec<&[TraceId]> = if traces.len() == xs.len() {
            traces.chunks(chunk).collect()
        } else {
            vec![&[]; chunks.len()]
        };
        let mut out = Vec::with_capacity(xs.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = chunks
                .iter()
                .zip(&tchunks)
                .enumerate()
                .map(|(i, (c, t))| {
                    scope.spawn(move || self.run_chunk((start + i) % n_nodes, key, c, t))
                })
                .collect();
            for (h, c) in handles.into_iter().zip(&chunks) {
                match h.join() {
                    Ok(answers) => out.extend(answers),
                    Err(_) => out.extend(batch_error(
                        c.len(),
                        ServeError::Engine("remote chunk worker panicked".into()),
                    )),
                }
            }
        });
        out
    }
}

fn net_to_serve(e: NetError) -> ServeError {
    match e {
        // the node is unreachable even after bounded reconnect
        NetError::Connect(_) => ServeError::ServerDown,
        NetError::Timeout(msg) => ServeError::Engine(format!("remote timeout: {msg}")),
        NetError::Io(msg) => ServeError::Engine(format!("remote transport: {msg}")),
        NetError::Protocol(msg) => ServeError::Engine(format!("remote protocol: {msg}")),
    }
}

fn status_to_serve(status: u16, body: &str) -> ServeError {
    if let Ok(doc) = Json::parse(body) {
        if doc.opt("error").is_some() {
            return wire::error_from_json(&doc);
        }
    }
    ServeError::Engine(format!("remote answered HTTP {status}"))
}

impl Engine for RemoteEngine {
    fn name(&self) -> &str {
        &self.name
    }

    /// Probe every node's `/healthz`, check it serves all requested
    /// keys, and fetch the remote baseline calibration.
    fn warm(&mut self, _source: &ModelSource, keys: &[String]) -> Result<()> {
        for node in &self.nodes {
            let mut client = node.lock().unwrap();
            let addr = client.addr().to_string();
            let resp = client
                .get("/healthz")
                .map_err(anyhow::Error::from)
                .with_context(|| format!("probing node {addr}"))?;
            if resp.status != 200 {
                bail!("node {addr} unhealthy: HTTP {} ({})", resp.status, resp.body);
            }
            let doc = resp.json().map_err(anyhow::Error::from)?;
            // two healthz generations are in the field: plain key
            // strings (pre-kernel nodes) and {"key","kernel","bits"}
            // objects — accept both
            let served: Vec<String> = doc
                .get("configs")?
                .as_arr()?
                .iter()
                .map(|k| match k {
                    Json::Str(s) => Ok(s.clone()),
                    obj => Ok(obj.get("key")?.as_str()?.to_string()),
                })
                .collect::<Result<_>>()?;
            for key in keys {
                if !served.iter().any(|s| s == key) {
                    bail!("node {addr} does not serve config {key:?} (serves {served:?})");
                }
            }
        }
        // baseline calibration travels from node 0's metrics (all nodes
        // serve the same configs; Table I's ratio needs one source)
        let mut client = self.nodes[0].lock().unwrap();
        if let Ok(resp) = client.get("/v1/metrics") {
            if resp.status == 200 {
                if let Ok(doc) = resp.json() {
                    if let Ok(configs) = doc.get("configs").and_then(|c| c.as_obj().cloned()) {
                        for (key, m) in &configs {
                            if let Some(b) =
                                m.opt("baseline_cycles_per_inf").and_then(|v| v.as_f64().ok())
                            {
                                if b > 0.0 {
                                    self.baselines.insert(key.clone(), b);
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn run_batch(&self, key: &str, xs: &[Vec<i32>]) -> Vec<Result<Sample, ServeError>> {
        self.fan_out(key, xs, &[])
    }

    /// Traced fan-out: every sample's trace id crosses the wire, so
    /// the remote nodes answer with child spans and the coordinator's
    /// span trees show the per-node breakdown.
    fn run_batch_ctx(
        &self,
        key: &str,
        xs: &[Vec<i32>],
        ctx: &BatchCtx<'_>,
    ) -> Vec<Result<Sample, ServeError>> {
        self.fan_out(key, xs, ctx.traces)
    }

    fn baseline_cycles(&self, key: &str) -> Option<f64> {
        self.baselines.get(key).copied()
    }

    /// Merge the nodes' farm shards into one view (jobs/cycles per
    /// remote shard, spills summed) and fold every node's per-config
    /// serving metrics — full latency bucket counts included — into
    /// the fleet map, so `report::serving` shows true fleet-wide
    /// quantiles rather than a max over per-node summaries.  Nodes are
    /// probed concurrently: this runs on the coordinator's dispatcher
    /// thread, so a dead node must cost one bounded reconnect, not one
    /// per node in series.
    fn snapshot(&self) -> EngineMetrics {
        type NodeView =
            (Option<FarmMetrics>, HashMap<String, ConfigMetrics>, HashMap<String, ConfigProfile>);
        let views: Vec<Option<NodeView>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .nodes
                .iter()
                .map(|node| {
                    scope.spawn(move || -> Option<NodeView> {
                        let mut client = node.lock().unwrap();
                        let resp = client.get("/v1/metrics").ok()?;
                        if resp.status != 200 {
                            return None;
                        }
                        let doc = resp.json().ok()?;
                        let farm = doc
                            .opt("engine")
                            .and_then(|e| e.opt("farm"))
                            .filter(|f| !matches!(f, Json::Null))
                            .and_then(|f| wire::farm_from_json(f).ok());
                        let mut configs = HashMap::new();
                        if let Some(Json::Obj(cfgs)) = doc.opt("configs") {
                            for (key, m) in cfgs {
                                if let Ok(cm) = wire::config_metrics_from_json(m) {
                                    configs.insert(key.clone(), cm);
                                }
                            }
                        }
                        // absent on pre-profiler nodes → empty map
                        let profiles = wire::profiles_from_json(
                            doc.opt("engine").and_then(|e| e.opt("profiles")),
                        );
                        Some((farm, configs, profiles))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap_or(None)).collect()
        });
        let mut merged: Option<FarmMetrics> = None;
        let mut fleet: HashMap<String, ConfigMetrics> = HashMap::new();
        let mut profiles: HashMap<String, ConfigProfile> = HashMap::new();
        for (farm, configs, node_profiles) in views.into_iter().flatten() {
            if let Some(f) = farm {
                match merged.as_mut() {
                    None => merged = Some(f),
                    Some(m) => {
                        m.spills += f.spills;
                        m.fast.merge(&f.fast);
                        m.shards.extend(f.shards);
                    }
                }
            }
            for (key, cm) in configs {
                match fleet.get_mut(&key) {
                    Some(existing) => existing.merge(&cm),
                    None => {
                        fleet.insert(key, cm);
                    }
                }
            }
            // fleet profile: plain counter adds, order-independent
            for (key, p) in node_profiles {
                profiles.entry(key).or_default().merge(&p);
            }
        }
        EngineMetrics {
            engine: self.name.clone(),
            farm: merged,
            fleet: (!fleet.is_empty()).then_some(fleet),
            profiles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_at_least_one_node() {
        assert!(RemoteEngine::new(Vec::<String>::new()).is_err());
        let e = RemoteEngine::new(["127.0.0.1:9", "127.0.0.1:10"]).unwrap();
        assert_eq!(e.n_nodes(), 2);
        assert_eq!(e.name(), "remote(127.0.0.1:9,127.0.0.1:10)");
    }

    #[test]
    fn unreachable_node_maps_to_server_down() {
        // port reserved then released: nothing listens there
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let opts = HttpClientOpts {
            connect_attempts: 1,
            backoff: std::time::Duration::from_millis(1),
            ..Default::default()
        };
        let engine = RemoteEngine::with_opts([addr], opts).unwrap();
        let out = engine.run_batch("k", &[vec![1], vec![2]]);
        assert_eq!(out.len(), 2);
        for r in &out {
            assert_eq!(r.as_ref().unwrap_err(), &ServeError::ServerDown);
        }
    }

    #[test]
    fn warm_fails_fast_against_a_dead_node() {
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let opts = HttpClientOpts {
            connect_attempts: 1,
            backoff: std::time::Duration::from_millis(1),
            ..Default::default()
        };
        let mut engine = RemoteEngine::with_opts([addr], opts).unwrap();
        let err = engine.warm(&ModelSource::None, &["k".to_string()]).unwrap_err();
        assert!(err.to_string().contains("probing node"), "{err:#}");
    }
}
