//! Wire-protocol serving front: the coordinator on a socket.
//!
//! Everything below `net/` is std-only (matching the repo's no-deps
//! substrate style in `util/`): a from-scratch incremental HTTP/1.1
//! layer ([`http`]), a serving front that puts a
//! [`crate::coordinator::Server`] behind a `TcpListener` ([`server`],
//! with two socket fronts — the blocking worker pool and the
//! [`evloop`] epoll readiness loop that holds 10k+ keep-alive device
//! sockets on a few threads), a keep-alive wire client ([`client`]),
//! and [`remote::RemoteEngine`] — an implementation of
//! [`crate::engine::Engine`] that executes batches on remote flexsvm
//! nodes, so one coordinator can fan out to N machines (the first
//! multi-node topology; see DESIGN.md §"The network front").
//!
//! Endpoints:
//!
//! | route             | method | body / answer |
//! |-------------------|--------|----------------|
//! | `/healthz`        | GET    | `{"status":"ok","engine":..,"configs":[{"key":..,"kernel":..,"bits":..},..]}` |
//! | `/v1/infer`       | POST   | `{"config":k,"features":[..]}` → one answer; `{"config":k,"batch":[[..],..]}` → `{"results":[..]}` with per-sample isolation.  An explicit trace (`"trace"`/`"traces"` field or `X-Trace-Id` header) makes the answer carry its span tree |
//! | `/v1/metrics`     | GET    | `ConfigMetrics` + `EngineMetrics` + net counters |
//! | `/metrics`        | GET    | Prometheus text format (counters + latency/stage histograms) |
//! | `/v1/traces`      | GET    | retained span trees; `?id=<hex>` looks one up, `?n=<count>` bounds the listing |
//!
//! Admission control: request bodies are parsed under
//! [`crate::util::json::Limits`], and submission uses the coordinator's
//! non-blocking [`crate::coordinator::Client::try_submit`] — when the
//! bounded ingress is saturated the request is shed with
//! `503 + Retry-After` instead of blocking the socket.  The [`wire`]
//! module pins the JSON encoding of answers and the typed
//! [`ServeError`](crate::engine::ServeError) ↔ status-code mapping that
//! both sides of the protocol share, which is what keeps served
//! predictions bit-identical across process boundaries (DESIGN.md §6).

pub mod client;
#[cfg(target_os = "linux")]
pub mod evloop;
pub mod http;
pub mod remote;
pub mod server;

pub use client::{HttpClient, HttpClientOpts, HttpResponse, NetError};
pub use remote::RemoteEngine;
pub use server::{NetFront, NetMetricsSnapshot, NetOpts, NetServer};

#[cfg(target_os = "linux")]
pub use evloop::{abortive_close, raise_nofile};

/// No-op stand-ins off Linux so bench/drive code stays portable.
#[cfg(not(target_os = "linux"))]
pub fn raise_nofile(_want: u64) -> u64 {
    u64::MAX
}
#[cfg(not(target_os = "linux"))]
pub fn abortive_close(_stream: &std::net::TcpStream) {}

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::Histogram;
use crate::svm::infer;
use crate::svm::model::{QuantModel, TestSet};

/// The JSON encoding both sides of the wire protocol share.
pub mod wire {
    use std::collections::HashMap;

    use anyhow::Result;

    use crate::coordinator::metrics::{ConfigMetrics, Histogram};
    use crate::coordinator::Response;
    use crate::engine::{EngineMetrics, Sample, ServeError, SimCost};
    use crate::farm::{ExecMode, FarmMetrics, FastPathMetrics, ShardMetrics};
    use crate::obs::{ConfigProfile, Span, TraceId};
    use crate::util::json::{obj, Json};

    pub fn features_json(x: &[i32]) -> Json {
        Json::Arr(x.iter().map(|&v| v.into()).collect())
    }

    pub fn mat_json(xs: &[Vec<i32>]) -> Json {
        Json::Arr(xs.iter().map(|x| features_json(x)).collect())
    }

    /// `POST /v1/infer` body for one sample.
    pub fn infer_body(config: &str, x: &[i32]) -> Json {
        obj([("config", config.into()), ("features", features_json(x))])
    }

    /// `POST /v1/infer` body for a batch.
    pub fn infer_batch_body(config: &str, xs: &[Vec<i32>]) -> Json {
        obj([("config", config.into()), ("batch", mat_json(xs))])
    }

    /// `POST /v1/infer` body for one sample under an explicit trace id
    /// (the wire twin of [`Client::submit_traced`]
    /// (crate::coordinator::Client::submit_traced)).
    pub fn infer_body_traced(config: &str, x: &[i32], trace: TraceId) -> Json {
        obj([
            ("config", config.into()),
            ("features", features_json(x)),
            ("trace", Json::Str(trace.to_hex())),
        ])
    }

    /// `POST /v1/infer` body for a batch with per-sample trace ids
    /// (`traces.len()` must equal `xs.len()`; the remote coordinator
    /// answers each sample with its span under that id).
    pub fn infer_batch_body_traced(config: &str, xs: &[Vec<i32>], traces: &[TraceId]) -> Json {
        obj([
            ("config", config.into()),
            ("batch", mat_json(xs)),
            ("traces", Json::Arr(traces.iter().map(|t| Json::Str(t.to_hex())).collect())),
        ])
    }

    pub fn sim_json(sim: Option<SimCost>) -> Json {
        match sim {
            None => Json::Null,
            Some(s) => obj([("cycles", s.cycles.into()), ("energy_mj", s.energy_mj.into())]),
        }
    }

    pub fn sim_from_json(v: &Json) -> Result<Option<SimCost>> {
        match v {
            Json::Null => Ok(None),
            v => Ok(Some(SimCost {
                cycles: v.get("cycles")?.as_i64()? as u64,
                energy_mj: v.get("energy_mj")?.as_f64()?,
            })),
        }
    }

    /// One successful coordinator answer.  The trace id always
    /// travels; the span tree travels only when the coordinator built
    /// one (explicitly-traced requests).
    pub fn response_json(r: &Response) -> Json {
        let mut o = obj([
            ("pred", r.pred.into()),
            ("batch_size", Json::Num(r.batch_size as f64)),
            ("latency_us", (r.latency.as_micros() as u64).into()),
            ("sim", sim_json(r.sim)),
            ("trace", Json::Str(r.trace.to_hex())),
        ]);
        if let Some(span) = &r.span {
            let Json::Obj(map) = &mut o else { unreachable!() };
            map.insert("span".to_string(), span.to_json());
        }
        o
    }

    /// Parse an answer object back into the engine-level [`Sample`].
    /// A `"span"` object becomes the sample's child span (the remote
    /// node's view of the request); its mode name is re-interned
    /// through [`ExecMode`] so `Sample::mode` stays `&'static`.
    pub fn sample_from_json(v: &Json) -> Result<Sample> {
        let mut s = Sample::new(
            v.get("pred")?.as_i32()?,
            sim_from_json(v.opt("sim").unwrap_or(&Json::Null))?,
        );
        if let Some(sj) = v.opt("span") {
            let span = Span::from_json(sj)?;
            s.mode = span.mode.as_deref().and_then(ExecMode::from_name).map(|m| m.name());
            s.child = Some(Box::new(span));
        }
        Ok(s)
    }

    /// HTTP status a typed request-path error maps to.
    pub fn status_for(e: &ServeError) -> u16 {
        match e {
            ServeError::UnknownConfig(_) => 404,
            ServeError::Overloaded => 503,
            ServeError::ServerDown => 503,
            ServeError::Dropped => 500,
            ServeError::Engine(_) => 500,
        }
    }

    fn kind_for(e: &ServeError) -> &'static str {
        match e {
            ServeError::UnknownConfig(_) => "unknown_config",
            ServeError::Overloaded => "overloaded",
            ServeError::ServerDown => "server_down",
            ServeError::Dropped => "dropped",
            ServeError::Engine(_) => "engine",
        }
    }

    /// `{"error":{"kind":..,"message":..}}` — the wire form of a typed
    /// error; [`error_from_json`] inverts it.
    pub fn error_body(e: &ServeError) -> Json {
        let mut pairs = vec![("kind", kind_for(e).into()), ("message", e.to_string().into())];
        if let ServeError::UnknownConfig(key) = e {
            pairs.push(("config", key.as_str().into()));
        }
        obj([("error", obj(pairs))])
    }

    /// Map a wire error body back to the typed error (tolerant: an
    /// unrecognised shape degrades to `ServeError::Engine`).
    pub fn error_from_json(body: &Json) -> ServeError {
        let Some(err) = body.opt("error") else {
            let raw = body.to_string();
            return ServeError::Engine(format!("unrecognised error body: {raw}"));
        };
        let kind = err.opt("kind").and_then(|k| k.as_str().ok()).unwrap_or("engine");
        let message = err
            .opt("message")
            .and_then(|m| m.as_str().ok())
            .unwrap_or("remote error")
            .to_string();
        match kind {
            "unknown_config" => ServeError::UnknownConfig(
                err.opt("config")
                    .and_then(|c| c.as_str().ok())
                    .unwrap_or("<unknown>")
                    .to_string(),
            ),
            "overloaded" => ServeError::Overloaded,
            "server_down" => ServeError::ServerDown,
            "dropped" => ServeError::Dropped,
            _ => ServeError::Engine(message),
        }
    }

    pub fn farm_json(f: &FarmMetrics) -> Json {
        obj([
            ("spills", f.spills.into()),
            (
                "shards",
                Json::Arr(
                    f.shards
                        .iter()
                        .map(|s| {
                            obj([
                                ("jobs", s.jobs.into()),
                                ("sim_cycles", s.sim_cycles.into()),
                                ("model_loads", s.model_loads.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "fast",
                obj([
                    ("fast_jobs", f.fast.fast_jobs.into()),
                    ("fast_cycles", f.fast.fast_cycles.into()),
                    ("audits", f.fast.audits.into()),
                    ("mismatches", f.fast.mismatches.into()),
                    ("fastpath_configs", f.fast.fastpath_configs.into()),
                    ("poisoned_configs", f.fast.poisoned_configs.into()),
                ]),
            ),
        ])
    }

    pub fn farm_from_json(v: &Json) -> Result<FarmMetrics> {
        let shards = v
            .get("shards")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(ShardMetrics {
                    jobs: s.get("jobs")?.as_i64()? as u64,
                    sim_cycles: s.get("sim_cycles")?.as_i64()? as u64,
                    model_loads: s.get("model_loads")?.as_i64()? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        // "fast" is absent from pre-fastpath servers: default to zeros
        // so mixed-version fleets keep aggregating
        let fast = match v.opt("fast") {
            Some(fj) => FastPathMetrics {
                fast_jobs: fj.get("fast_jobs")?.as_i64()? as u64,
                fast_cycles: fj.get("fast_cycles")?.as_i64()? as u64,
                audits: fj.get("audits")?.as_i64()? as u64,
                mismatches: fj.get("mismatches")?.as_i64()? as u64,
                fastpath_configs: fj.get("fastpath_configs")?.as_i64()? as u64,
                poisoned_configs: fj.get("poisoned_configs")?.as_i64()? as u64,
            },
            None => FastPathMetrics::default(),
        };
        Ok(FarmMetrics { shards, spills: v.get("spills")?.as_i64()? as u64, fast })
    }

    pub fn engine_metrics_json(em: &EngineMetrics) -> Json {
        let mut o = obj([
            ("name", em.engine.as_str().into()),
            ("farm", em.farm.as_ref().map(farm_json).unwrap_or(Json::Null)),
        ]);
        // profiles travel only when the profiler has samples, so
        // pre-profiler peers see exactly the document they always saw
        if !em.profiles.is_empty() {
            let Json::Obj(map) = &mut o else { unreachable!() };
            map.insert("profiles".to_string(), profiles_json(&em.profiles));
        }
        o
    }

    /// One config's aggregated guest-cycle profile.
    pub fn profile_json(p: &ConfigProfile) -> Json {
        let regions: std::collections::BTreeMap<String, Json> =
            p.regions.iter().map(|(k, &c)| (k.clone(), c.into())).collect();
        obj([
            ("sampled_runs", p.sampled_runs.into()),
            ("total_cycles", p.total_cycles.into()),
            ("regions", Json::Obj(regions)),
        ])
    }

    pub fn profile_from_json(v: &Json) -> Result<ConfigProfile> {
        let mut p = ConfigProfile::new();
        p.sampled_runs = v.get("sampled_runs")?.as_i64()?.max(0) as u64;
        p.total_cycles = v.get("total_cycles")?.as_i64()?.max(0) as u64;
        if let Some(regions) = v.opt("regions") {
            for (name, c) in regions.as_obj()? {
                p.regions.insert(name.clone(), c.as_i64()?.max(0) as u64);
            }
        }
        Ok(p)
    }

    /// The per-config profile map under `"profiles"` in the engine
    /// object of `/v1/metrics`.
    pub fn profiles_json(profiles: &HashMap<String, ConfigProfile>) -> Json {
        let mut o = std::collections::BTreeMap::new();
        for (k, p) in profiles {
            o.insert(k.clone(), profile_json(p));
        }
        Json::Obj(o)
    }

    /// Tolerant decode of the `"profiles"` map: absent on pre-profiler
    /// peers (→ empty map), and a malformed entry drops alone rather
    /// than failing the whole snapshot.
    pub fn profiles_from_json(v: Option<&Json>) -> HashMap<String, ConfigProfile> {
        let mut out = HashMap::new();
        if let Some(Json::Obj(map)) = v {
            for (k, pj) in map {
                if let Ok(p) = profile_from_json(pj) {
                    out.insert(k.clone(), p);
                }
            }
        }
        out
    }

    /// Full latency histogram: per-bucket counts + sum + max, enough
    /// to reconstruct true quantiles on the far side
    /// ([`Histogram::from_parts`]).
    pub fn histogram_json(h: &Histogram) -> Json {
        obj([
            ("counts", Json::Arr(h.counts().iter().map(|&c| c.into()).collect())),
            ("sum_us", h.sum_us().into()),
            ("max_us", h.max_us().into()),
        ])
    }

    pub fn histogram_from_json(v: &Json) -> Result<Histogram> {
        let counts = v
            .get("counts")?
            .as_arr()?
            .iter()
            .map(|c| Ok(c.as_i64()?.max(0) as u64))
            .collect::<Result<Vec<u64>>>()?;
        Histogram::from_parts(
            counts,
            v.get("sum_us")?.as_i64()?.max(0) as u64,
            v.get("max_us")?.as_i64()?.max(0) as u64,
        )
    }

    /// Per-config serving counters + latency.  The summary quantiles
    /// (`p50_us`/`p99_us`/..) stay for dashboards and old peers; the
    /// full bucket counts ride alongside under `"latency"` so a
    /// fan-out coordinator can merge true fleet-wide quantiles.
    pub fn config_metrics_json(m: &ConfigMetrics) -> Json {
        let (p50, p99, mean, max) = m
            .latency
            .as_ref()
            .map(|h| (h.quantile_us(0.50), h.quantile_us(0.99), h.mean_us(), h.max_us()))
            .unwrap_or((0, 0, 0.0, 0));
        let mut o = obj([
            ("requests", m.requests.into()),
            ("batches", m.batches.into()),
            ("batched_samples", m.batched_samples.into()),
            ("sim_samples", m.sim_samples.into()),
            ("sim_cycles", m.sim_cycles.into()),
            ("energy_mj", m.energy_mj.into()),
            ("baseline_cycles_per_inf", m.baseline_cycles_per_inf.into()),
            ("p50_us", p50.into()),
            ("p99_us", p99.into()),
            ("mean_us", mean.into()),
            ("max_us", max.into()),
        ]);
        if let Some(h) = &m.latency {
            let Json::Obj(map) = &mut o else { unreachable!() };
            map.insert("latency".to_string(), histogram_json(h));
        }
        // model identity travels only when known, so pre-kernel peers
        // see exactly the document they always saw
        if !m.kernel.is_empty() {
            let Json::Obj(map) = &mut o else { unreachable!() };
            map.insert("kernel".to_string(), Json::Str(m.kernel.clone()));
            map.insert("bits".to_string(), (m.bits as u64).into());
        }
        o
    }

    /// Tolerant decode of [`config_metrics_json`]: a peer that
    /// predates the bucketed `"latency"` object (summary-only) still
    /// parses — its histogram just stays `None`, and the merge falls
    /// back to counters.
    pub fn config_metrics_from_json(v: &Json) -> Result<ConfigMetrics> {
        let mut m = ConfigMetrics::new();
        m.requests = v.get("requests")?.as_i64()?.max(0) as u64;
        m.batches = v.get("batches")?.as_i64()?.max(0) as u64;
        m.batched_samples = v.get("batched_samples")?.as_i64()?.max(0) as u64;
        m.sim_samples = v.get("sim_samples")?.as_i64()?.max(0) as u64;
        m.sim_cycles = v.get("sim_cycles")?.as_i64()?.max(0) as u64;
        m.energy_mj = v.get("energy_mj")?.as_f64()?;
        m.baseline_cycles_per_inf = v.get("baseline_cycles_per_inf")?.as_f64()?;
        m.latency = match v.opt("latency") {
            Some(h) => Some(histogram_from_json(h)?),
            None => None,
        };
        // peers that predate mixed kernels omit the model identity;
        // empty/zero means unknown and the merge treats it as fillable
        m.kernel =
            v.opt("kernel").and_then(|k| k.as_str().ok()).unwrap_or_default().to_string();
        m.bits = v.opt("bits").and_then(|b| b.as_i64().ok()).unwrap_or(0).clamp(0, 255) as u8;
        Ok(m)
    }

    /// The whole `/v1/metrics` document.
    pub fn metrics_body(
        configs: &HashMap<String, ConfigMetrics>,
        engine: &EngineMetrics,
        net: &super::NetMetricsSnapshot,
    ) -> Json {
        let mut cfg = std::collections::BTreeMap::new();
        for (k, m) in configs {
            cfg.insert(k.clone(), config_metrics_json(m));
        }
        obj([
            ("configs", Json::Obj(cfg)),
            ("engine", engine_metrics_json(engine)),
            (
                "net",
                obj([
                    ("accepted", net.accepted.into()),
                    ("active", net.active.into()),
                    ("closed", net.closed.into()),
                    ("timed_out", net.timed_out.into()),
                    ("reading", net.reading.into()),
                    ("writing", net.writing.into()),
                    ("idle", net.idle.into()),
                    ("shed", net.shed.into()),
                    ("requests", net.requests.into()),
                    ("bytes_in", net.bytes_in.into()),
                    ("bytes_out", net.bytes_out.into()),
                ]),
            ),
        ])
    }
}

/// Outcome of one multi-threaded HTTP client drive (the wire twin of
/// [`crate::util::benchkit::drive_clients`]).
#[derive(Debug)]
pub struct HttpDriveResult {
    /// Requests answered `200`.
    pub served: u64,
    /// Answers equal to the test-set label.
    pub label_correct: u64,
    /// Answers that diverged from `svm::infer::predict` (only counted
    /// when reference models are supplied; must be 0).
    pub native_mismatch: u64,
    /// Requests shed by admission control (`503`).
    pub shed: u64,
    pub wall: Duration,
    /// Client-observed wall latency of successful requests.
    pub latency: Histogram,
}

/// Drive a wire server from `workers` threads over real test vectors,
/// round-robining configs — same access pattern as
/// `benchkit::drive_clients`, but over loopback (or real) sockets, so
/// the §6 bit-exactness contract can be checked across the wire.
/// `503` answers count as shed (not errors); any other non-200 answer
/// fails the drive.
pub fn drive_http(
    addr: &str,
    testsets: &[(String, TestSet)],
    n_requests: usize,
    workers: usize,
    check_models: Option<&HashMap<String, QuantModel>>,
) -> Result<HttpDriveResult> {
    assert!(workers > 0 && !testsets.is_empty());
    let correct = AtomicU64::new(0);
    let mismatch = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    let shed = AtomicU64::new(0);
    let latency = Mutex::new(Histogram::new());
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for w in 0..workers {
            let (correct, mismatch, served, shed) = (&correct, &mismatch, &served, &shed);
            let latency = &latency;
            handles.push(scope.spawn(move || -> Result<()> {
                let mut client = HttpClient::new(addr);
                for i in 0..n_requests / workers {
                    let (key, test) = &testsets[(w + i) % testsets.len()];
                    let idx = (w * 7919 + i * 31) % test.len();
                    let x = &test.x_q[idx];
                    let t_req = Instant::now();
                    let resp = client.post_json("/v1/infer", &wire::infer_body(key, x))?;
                    match resp.status {
                        200 => {
                            latency.lock().unwrap().record(t_req.elapsed());
                            let pred = resp.json()?.get("pred")?.as_i32()?;
                            served.fetch_add(1, Ordering::Relaxed);
                            if pred == test.y[idx] {
                                correct.fetch_add(1, Ordering::Relaxed);
                            }
                            if let Some(models) = check_models {
                                if pred != infer::predict(&models[key], x) {
                                    mismatch.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        503 => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        s => bail!("unexpected status {s}: {}", resp.body),
                    }
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("http drive worker panicked").context("http drive worker")?;
        }
        Ok(())
    })?;
    Ok(HttpDriveResult {
        served: served.load(Ordering::Relaxed),
        label_correct: correct.load(Ordering::Relaxed),
        native_mismatch: mismatch.load(Ordering::Relaxed),
        shed: shed.load(Ordering::Relaxed),
        wall: t0.elapsed(),
        latency: latency.into_inner().unwrap(),
    })
}

/// Outcome of one device-scale streaming drive
/// ([`drive_streaming`]).  Throughput numbers cover the steady-state
/// rounds only (the connect round warms every keep-alive session and
/// is excluded).
#[derive(Debug)]
pub struct StreamDriveResult {
    /// Concurrent keep-alive device sessions held open.
    pub devices: usize,
    /// Steady-state requests answered `200`.
    pub served: u64,
    /// Steady-state requests shed with `503`.
    pub shed: u64,
    /// Steady-state requests that timed out or died at the transport —
    /// a front that cannot hold this many sessions (the pool at device
    /// scale) starves connections, and the device reconnects next
    /// round.  Zero on a healthy front.
    pub stalled: u64,
    /// Answers that diverged from `svm::infer::predict` (bit-exactness
    /// over the wire; must be 0).  Counted across every round.
    pub native_mismatch: u64,
    /// Wall time of the steady-state rounds.
    pub wall: Duration,
    /// Client-observed latency of steady-state successes.
    pub latency: Histogram,
    /// Keep-alive reuses summed over every device client — at 10k
    /// devices this is what keeps the ephemeral-port range alive.
    pub connections_reused: u64,
}

/// Drive a wire server with `s.n_devices` concurrent keep-alive device
/// sessions from a handful of client threads: each thread owns
/// `devices/threads` devices, each device its own [`HttpClient`]
/// (→ one open socket per device), and every round submits one
/// windowed feature vector per device to its affine config.  Round 0
/// establishes the sessions (staggered so the listener backlog never
/// overflows) and is excluded from the timed window; predictions are
/// checked bit-exact against `svm::infer::predict` throughout.
pub fn drive_streaming(
    addr: &str,
    s: &crate::farm::scenario::Streaming,
    models: &[(String, QuantModel)],
    rounds: usize,
    client_threads: usize,
) -> Result<StreamDriveResult> {
    assert!(rounds >= 2, "need a connect round plus at least one timed round");
    assert!(!models.is_empty());
    let threads = client_threads.clamp(1, s.n_devices.max(1));
    let served = AtomicU64::new(0);
    let shed = AtomicU64::new(0);
    let stalled = AtomicU64::new(0);
    let mismatch = AtomicU64::new(0);
    let reused = AtomicU64::new(0);
    let latency = Mutex::new(Histogram::new());
    // all threads (plus the timer below) rendezvous once every session
    // is connected and warmed, so the timed window is pure steady state
    let warm = std::sync::Barrier::new(threads + 1);
    let mut wall = Duration::ZERO;
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for w in 0..threads {
            let (served, shed, stalled) = (&served, &shed, &stalled);
            let (mismatch, reused) = (&mismatch, &reused);
            let (latency, warm) = (&latency, &warm);
            handles.push(scope.spawn(move || -> Result<()> {
                let devices: Vec<usize> = (w..s.n_devices).step_by(threads).collect();
                let opts = HttpClientOpts {
                    // well above a healthy front's p99, well below the
                    // bench-killing default: a starved connection is
                    // counted and retried, not waited out for 10s
                    io_timeout: Duration::from_millis(2_500),
                    ..Default::default()
                };
                let mut clients: Vec<HttpClient> =
                    devices.iter().map(|_| HttpClient::with_opts(addr, opts.clone())).collect();
                for r in 0..rounds {
                    let timed = r > 0;
                    for (di, &device) in devices.iter().enumerate() {
                        if r == 0 && di % 64 == 63 {
                            // pace the connect storm below the
                            // listener backlog
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        let cfg = s.config_of(device) % models.len();
                        let (key, model) = &models[cfg];
                        let x = s.window_features(device, r as u64, model.n_features);
                        let t_req = Instant::now();
                        match clients[di].post_json("/v1/infer", &wire::infer_body(key, &x)) {
                            Ok(resp) => match resp.status {
                                200 => {
                                    if timed {
                                        latency.lock().unwrap().record(t_req.elapsed());
                                        served.fetch_add(1, Ordering::Relaxed);
                                    }
                                    let pred = resp.json()?.get("pred")?.as_i32()?;
                                    if pred != infer::predict(model, &x) {
                                        mismatch.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                                503 if timed => {
                                    shed.fetch_add(1, Ordering::Relaxed);
                                }
                                503 => {}
                                status => bail!("unexpected status {status}: {}", resp.body),
                            },
                            // a front that cannot hold this session
                            // parked it unanswered: count the stall,
                            // reconnect next round
                            Err(NetError::Timeout(_)) | Err(NetError::Io(_)) => {
                                if timed {
                                    stalled.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(e) => {
                                return Err(e)
                                    .with_context(|| format!("device {device} round {r}"))
                            }
                        }
                    }
                    if r == 0 {
                        warm.wait();
                    }
                }
                for c in &mut clients {
                    reused.fetch_add(c.connections_reused(), Ordering::Relaxed);
                    // RST close: a 10k-session teardown must not park
                    // the ephemeral-port range in TIME_WAIT
                    c.close_abortive();
                }
                Ok(())
            }));
        }
        warm.wait();
        let t0 = Instant::now();
        for h in handles {
            h.join().expect("streaming drive thread panicked").context("streaming drive")?;
        }
        wall = t0.elapsed();
        Ok(())
    })?;
    Ok(StreamDriveResult {
        devices: s.n_devices,
        served: served.load(Ordering::Relaxed),
        shed: shed.load(Ordering::Relaxed),
        stalled: stalled.load(Ordering::Relaxed),
        native_mismatch: mismatch.load(Ordering::Relaxed),
        wall,
        latency: latency.into_inner().unwrap(),
        connections_reused: reused.load(Ordering::Relaxed),
    })
}

#[cfg(test)]
mod tests {
    use super::wire;
    use crate::engine::{ServeError, SimCost};
    use crate::farm::{FarmMetrics, FastPathMetrics, ShardMetrics};
    use crate::util::json::Json;

    #[test]
    fn typed_errors_round_trip_the_wire_encoding() {
        for e in [
            ServeError::UnknownConfig("iris_ovr_w4".into()),
            ServeError::Overloaded,
            ServeError::ServerDown,
            ServeError::Dropped,
            ServeError::Engine("boom".into()),
        ] {
            let body = wire::error_body(&e);
            let parsed = Json::parse(&body.to_string()).unwrap();
            assert_eq!(wire::error_from_json(&parsed), e, "{body:?}");
        }
    }

    #[test]
    fn status_mapping_is_stable() {
        assert_eq!(wire::status_for(&ServeError::UnknownConfig("k".into())), 404);
        assert_eq!(wire::status_for(&ServeError::Overloaded), 503);
        assert_eq!(wire::status_for(&ServeError::Engine("x".into())), 500);
    }

    #[test]
    fn samples_and_sim_costs_round_trip() {
        let j = wire::sim_json(Some(SimCost { cycles: 1234, energy_mj: 0.5 }));
        let back = wire::sim_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap().unwrap();
        assert_eq!(back.cycles, 1234);
        assert!((back.energy_mj - 0.5).abs() < 1e-12);
        assert!(wire::sim_from_json(&Json::Null).unwrap().is_none());
    }

    #[test]
    fn farm_metrics_round_trip() {
        let f = FarmMetrics {
            shards: vec![
                ShardMetrics { jobs: 3, sim_cycles: 999, model_loads: 1 },
                ShardMetrics { jobs: 5, sim_cycles: 1000, model_loads: 2 },
            ],
            spills: 4,
            fast: FastPathMetrics {
                fast_jobs: 40,
                fast_cycles: 123_456,
                audits: 5,
                mismatches: 1,
                fastpath_configs: 2,
                poisoned_configs: 1,
            },
        };
        let j = Json::parse(&wire::farm_json(&f).to_string()).unwrap();
        let back = wire::farm_from_json(&j).unwrap();
        assert_eq!(back.spills, 4);
        assert_eq!(back.shards.len(), 2);
        assert_eq!(back.total_jobs(), 48, "fast jobs ride the wire too");
        assert_eq!(back.shards[1].sim_cycles, 1000);
        assert_eq!(back.fast, f.fast);
    }

    #[test]
    fn farm_metrics_tolerate_pre_fastpath_peers() {
        // a server predating the fast path sends no "fast" object
        let v = Json::parse(
            r#"{"spills":0,"shards":[{"jobs":2,"sim_cycles":70,"model_loads":1}]}"#,
        )
        .unwrap();
        let back = wire::farm_from_json(&v).unwrap();
        assert_eq!(back.fast, FastPathMetrics::default());
        assert_eq!(back.total_jobs(), 2);
    }

    #[test]
    fn config_metrics_round_trip_full_histogram_buckets() {
        use crate::coordinator::metrics::ConfigMetrics;
        let mut m = ConfigMetrics::new();
        m.requests = 7;
        m.batches = 3;
        m.batched_samples = 7;
        m.sim_samples = 7;
        m.sim_cycles = 420_000;
        m.energy_mj = 9.38;
        m.baseline_cycles_per_inf = 2_100_000.0;
        m.kernel = "rbf".into();
        m.bits = 8;
        let h = m.latency.as_mut().unwrap();
        for us in [3u64, 42, 42, 180, 950, 12_000, 88_000] {
            h.record_us(us);
        }
        let j = Json::parse(&wire::config_metrics_json(&m).to_string()).unwrap();
        let back = wire::config_metrics_from_json(&j).unwrap();
        assert_eq!(back.requests, 7);
        assert_eq!(back.sim_cycles, 420_000);
        assert_eq!(back.kernel, "rbf", "model identity rides the wire");
        assert_eq!(back.bits, 8);
        let hb = back.latency.as_ref().expect("buckets ride the wire");
        let ha = m.latency.as_ref().unwrap();
        assert_eq!(hb.counts(), ha.counts(), "bucket-exact round trip");
        assert_eq!(hb.sum_us(), ha.sum_us());
        assert_eq!(hb.max_us(), ha.max_us());
        assert_eq!(hb.quantile_us(0.99), ha.quantile_us(0.99));
        // and the summary quantiles still ride alongside for old peers
        assert!(j.get("p99_us").unwrap().as_i64().unwrap() > 0);
    }

    #[test]
    fn config_metrics_tolerate_summary_only_peers() {
        // a pre-bucketed peer sends summary quantiles but no "latency"
        // object: the decode must still succeed, histogram-less
        let v = Json::parse(
            r#"{"requests":5,"batches":2,"batched_samples":5,"sim_samples":5,
                "sim_cycles":100,"energy_mj":1.5,"baseline_cycles_per_inf":0,
                "p50_us":10,"p99_us":20,"mean_us":12.0,"max_us":25}"#,
        )
        .unwrap();
        let back = wire::config_metrics_from_json(&v).unwrap();
        assert_eq!(back.requests, 5);
        assert!((back.energy_mj - 1.5).abs() < 1e-12);
        assert!(back.latency.is_none(), "summary-only peers decode without buckets");
        assert!(back.kernel.is_empty(), "pre-kernel peers decode as unknown family");
        assert_eq!(back.bits, 0);
    }

    #[test]
    fn profiles_ride_the_engine_metrics_wire() {
        use crate::engine::EngineMetrics;
        use crate::obs::{BlockProfiler, ConfigProfile, Region};
        let mut p = ConfigProfile::new();
        let mut run = BlockProfiler::new();
        run.record(0, 10, 0);
        run.record(4, 90, 16);
        p.absorb(&run, &[Region { name: "dot_loop", start_word: 4, end_word: 8 }]);
        let mut em = EngineMetrics { engine: "accel".into(), ..Default::default() };
        em.profiles.insert("iris_w4".to_string(), p.clone());
        let j = Json::parse(&wire::engine_metrics_json(&em).to_string()).unwrap();
        let back = wire::profiles_from_json(j.opt("profiles"));
        assert_eq!(back.get("iris_w4"), Some(&p), "counter-exact round trip");
        // total == sum of regions survives the wire (conservation)
        let b = &back["iris_w4"];
        assert_eq!(b.regions.values().sum::<u64>(), b.total_cycles);
    }

    #[test]
    fn engine_metrics_tolerate_pre_profiler_peers() {
        // a pre-profiler node sends no "profiles" key; an empty local
        // profile map sends none either — both directions decode clean
        let v = Json::parse(r#"{"name":"accel","farm":null}"#).unwrap();
        assert!(wire::profiles_from_json(v.opt("profiles")).is_empty());
        let em = crate::engine::EngineMetrics { engine: "accel".into(), ..Default::default() };
        let j = wire::engine_metrics_json(&em);
        assert!(j.opt("profiles").is_none(), "no samples: wire document unchanged");
        // a malformed entry drops alone instead of failing the snapshot
        let v = Json::parse(
            r#"{"a":{"sampled_runs":1,"total_cycles":5,"regions":{"x":5}},"b":{"bogus":true}}"#,
        )
        .unwrap();
        let back = wire::profiles_from_json(Some(&v));
        assert_eq!(back.len(), 1);
        assert_eq!(back["a"].total_cycles, 5);
    }

    #[test]
    fn unknown_error_shape_degrades_to_engine() {
        let v = Json::parse(r#"{"weird": true}"#).unwrap();
        assert!(matches!(wire::error_from_json(&v), ServeError::Engine(_)));
        let v = Json::parse(r#"{"error":{"kind":"martian"}}"#).unwrap();
        assert!(matches!(wire::error_from_json(&v), ServeError::Engine(_)));
    }
}
