//! Host-side harness: build a program, load it into an SoC, feed test
//! samples, collect per-inference cycle statistics.
//!
//! [`CompiledProgram`] is the build-once artifact: generated machine
//! code plus its block translation ([`crate::soc::DecodedProgram`]) in
//! an `Arc`.  Any number of [`ProgramRunner`]s (e.g. the farm's
//! shards) instantiate from the same compiled program without
//! re-generating or re-decoding anything — each runner only allocates
//! its own SoC memory.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::accel::kernel::KernelAccel;
use crate::accel::svm::SvmAccel;
use crate::kernel::Kernel;
use crate::serv::{CycleStats, Exit, TimingConfig};
use crate::soc::{DecodedProgram, Soc};
use crate::svm::model::QuantModel;
use crate::svm::pack;

use super::{accel, baseline, BuiltProgram, ProgramKind, ProgramOpts};

/// Default per-inference cycle budget (Dermatology baseline runs ~10^7).
pub const DEFAULT_BUDGET: u64 = 500_000_000;

/// A generated inference program compiled (block-translated) exactly
/// once, shareable across any number of runners and farm shards.
pub struct CompiledProgram {
    prog: BuiltProgram,
    decoded: Arc<DecodedProgram>,
    bits: u8,
    n_features: usize,
    kernel: Kernel,
}

impl CompiledProgram {
    /// Compile the software-only ("w/o accel") program for a model.
    pub fn baseline(m: &QuantModel) -> Result<Arc<CompiledProgram>> {
        let prog = baseline::build(m)?;
        Ok(Arc::new(CompiledProgram {
            decoded: Arc::new(DecodedProgram::translate(&prog.image)),
            prog,
            bits: m.bits,
            n_features: m.n_features,
            kernel: m.kernel,
        }))
    }

    /// Compile the accelerated (Algorithm 1) program for a model.
    pub fn accelerated(m: &QuantModel, opts: ProgramOpts) -> Result<Arc<CompiledProgram>> {
        let prog = accel::build(m, opts)?;
        Ok(Arc::new(CompiledProgram {
            decoded: Arc::new(DecodedProgram::translate(&prog.image)),
            prog,
            bits: m.bits,
            n_features: m.n_features,
            kernel: m.kernel,
        }))
    }

    pub fn kind(&self) -> ProgramKind {
        self.prog.kind
    }

    /// The kernel this program was generated for (drives which CFU a
    /// runner registers and how features are packed).
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn built(&self) -> &BuiltProgram {
        &self.prog
    }

    /// The shared block translation (one per compiled program, however
    /// many runners execute it).
    pub fn decoded(&self) -> &Arc<DecodedProgram> {
        &self.decoded
    }
}

pub struct ProgramRunner {
    soc: Soc,
    prog: Arc<CompiledProgram>,
    budget: u64,
}

impl ProgramRunner {
    /// Software-only configuration ("w/o accel"): no CFU is registered —
    /// if the program tried to issue an accelerator instruction the SoC
    /// would fault, proving the baseline really is pure RV32I.
    pub fn baseline(m: &QuantModel, timing: TimingConfig) -> Result<ProgramRunner> {
        Self::from_compiled(&CompiledProgram::baseline(m)?, timing)
    }

    /// Accelerated configuration: SVM CFU at funct7 = 1.
    pub fn accelerated(m: &QuantModel, timing: TimingConfig, opts: ProgramOpts) -> Result<ProgramRunner> {
        Self::from_compiled(&CompiledProgram::accelerated(m, opts)?, timing)
    }

    /// Instantiate a runner from an already-compiled program: no
    /// program generation, no decode — just a fresh SoC over the
    /// shared translation.
    pub fn from_compiled(c: &Arc<CompiledProgram>, timing: TimingConfig) -> Result<ProgramRunner> {
        let mut soc = Soc::with_program(Arc::clone(c.decoded()), timing);
        if c.kind() == ProgramKind::Accelerated {
            if c.kernel == Kernel::Linear {
                soc.register_cfu(crate::isa::CFU_FUNCT7_SVM, Box::new(SvmAccel::new()))?;
            } else {
                soc.register_cfu(crate::isa::CFU_FUNCT7_KSVM, Box::new(KernelAccel::new()))?;
            }
        }
        Ok(ProgramRunner { soc, prog: Arc::clone(c), budget: DEFAULT_BUDGET })
    }

    pub fn kind(&self) -> ProgramKind {
        self.prog.kind()
    }

    pub fn program(&self) -> &BuiltProgram {
        self.prog.built()
    }

    pub fn set_budget(&mut self, cycles: u64) {
        self.budget = cycles;
    }

    /// Mutable access to the SoC (tracing harnesses).
    pub fn soc_mut(&mut self) -> &mut Soc {
        &mut self.soc
    }

    /// Write the feature words for one sample into the program's buffer.
    pub fn poke_features(&mut self, x_q: &[i32]) -> Result<()> {
        if x_q.len() != self.prog.n_features {
            bail!("expected {} features, got {}", self.prog.n_features, x_q.len());
        }
        if x_q.iter().any(|&v| !(0..=15).contains(&v)) {
            bail!("features must be 4-bit unsigned");
        }
        let built = self.prog.built();
        let words: Vec<u32> = match (built.kind, self.prog.kernel) {
            (ProgramKind::Baseline, _) => x_q.iter().map(|&v| v as u32).collect(),
            (ProgramKind::Accelerated, Kernel::Linear) => {
                pack::feature_words(x_q, self.prog.bits)
            }
            // kernel programs: 8x4-bit lanes per word, no bias lane
            (ProgramKind::Accelerated, _) => pack::kernel_feature_words(x_q),
        };
        debug_assert_eq!(words.len(), built.n_feature_words);
        self.soc.mem.poke_words(built.feature_addr, &words);
        Ok(())
    }

    /// Run one inference; returns (predicted class, cycle stats).
    pub fn run_sample(&mut self, x_q: &[i32]) -> Result<(i32, CycleStats)> {
        self.soc.rearm();
        self.poke_features(x_q)?;
        let r = self.soc.run(self.budget)?;
        match r.exit {
            Exit::Ecall { a0, .. } => Ok((a0 as i32, r.stats)),
            Exit::Ebreak => bail!("program hit ebreak"),
        }
    }

    /// [`run_sample`](Self::run_sample) under the sampled continuous
    /// profiler: per-block cycle attribution accumulates into `prof`
    /// (symbolize via `self.program().regions`).  Same engine, same
    /// bit-identical prediction and stats; on success
    /// `prof.attributed()` equals `stats.total()` bit-exactly.
    pub fn run_sample_profiled(
        &mut self,
        x_q: &[i32],
        prof: &mut crate::obs::BlockProfiler,
    ) -> Result<(i32, CycleStats)> {
        self.soc.rearm();
        self.poke_features(x_q)?;
        let r = self.soc.run_profiled(self.budget, prof)?;
        match r.exit {
            Exit::Ecall { a0, .. } => Ok((a0 as i32, r.stats)),
            Exit::Ebreak => bail!("program hit ebreak"),
        }
    }

    /// Run the whole test set; returns (accuracy, mean per-inference
    /// stats, aggregate stats).
    pub fn run_test_set(
        &mut self,
        x: &[Vec<i32>],
        y: &[i32],
        limit: Option<usize>,
    ) -> Result<TestSetResult> {
        let n = limit.unwrap_or(x.len()).min(x.len());
        if n == 0 {
            bail!("empty test set");
        }
        let mut agg = CycleStats::default();
        let mut correct = 0usize;
        for i in 0..n {
            let (pred, stats) = self.run_sample(&x[i])?;
            agg.merge(&stats);
            if pred == y[i] {
                correct += 1;
            }
        }
        Ok(TestSetResult {
            n_samples: n,
            accuracy: correct as f64 / n as f64,
            cycles_per_inference: agg.total() as f64 / n as f64,
            agg,
        })
    }
}

/// Aggregate result over a test set.
#[derive(Debug, Clone, Copy)]
pub struct TestSetResult {
    pub n_samples: usize,
    pub accuracy: f64,
    pub cycles_per_inference: f64,
    pub agg: CycleStats,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::model::Strategy;

    fn tiny_model() -> QuantModel {
        QuantModel {
            dataset: "tiny".into(),
            strategy: Strategy::Ovr,
            bits: 4,
            n_classes: 2,
            n_features: 2,
            weights: vec![vec![7, -7], vec![-7, 7]],
            biases: vec![0, 0],
            pairs: vec![(0, 0), (1, 1)],
            scale: 1.0,
            kernel: Kernel::Linear,
            support: Vec::new(),
            kparams: crate::kernel::KernelParams::default(),
        }
    }

    #[test]
    fn run_test_set_accuracy() {
        let m = tiny_model();
        let x = vec![vec![15, 0], vec![0, 15], vec![12, 3], vec![1, 9]];
        let y = vec![0, 1, 0, 1];
        for mut r in [
            ProgramRunner::baseline(&m, TimingConfig::ideal_mem()).unwrap(),
            ProgramRunner::accelerated(&m, TimingConfig::ideal_mem(), ProgramOpts::default())
                .unwrap(),
        ] {
            let res = r.run_test_set(&x, &y, None).unwrap();
            assert_eq!(res.accuracy, 1.0, "{:?}", r.kind());
            assert!(res.cycles_per_inference > 0.0);
            assert_eq!(res.n_samples, 4);
        }
    }

    #[test]
    fn feature_validation() {
        let m = tiny_model();
        let mut r = ProgramRunner::baseline(&m, TimingConfig::ideal_mem()).unwrap();
        assert!(r.run_sample(&[16, 0]).is_err());
        assert!(r.run_sample(&[1]).is_err());
    }

    #[test]
    fn runners_share_one_compiled_translation() {
        let m = tiny_model();
        let c = CompiledProgram::accelerated(&m, ProgramOpts::default()).unwrap();
        let mut r1 = ProgramRunner::from_compiled(&c, TimingConfig::ideal_mem()).unwrap();
        let mut r2 = ProgramRunner::from_compiled(&c, TimingConfig::ideal_mem()).unwrap();
        // both SoCs execute the same Arc'd DecodedProgram
        assert!(Arc::ptr_eq(r1.soc_mut().program(), r2.soc_mut().program()));
        assert!(Arc::strong_count(c.decoded()) >= 3, "compiled + two runners");
        let (p1, s1) = r1.run_sample(&[9, 2]).unwrap();
        let (p2, s2) = r2.run_sample(&[9, 2]).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(s1, s2);
    }

    #[test]
    fn profiled_run_conserves_cycles_and_symbolizes() {
        let m = tiny_model();
        let mut r =
            ProgramRunner::accelerated(&m, TimingConfig::flexic(), ProgramOpts::default())
                .unwrap();
        let (p_ref, s_ref) = r.run_sample(&[9, 2]).unwrap();
        let mut prof = crate::obs::BlockProfiler::new();
        let (p, s) = r.run_sample_profiled(&[9, 2], &mut prof).unwrap();
        assert_eq!((p, s), (p_ref, s_ref), "profiling must not perturb execution");
        assert_eq!(prof.attributed(), s.total(), "conservation: every cycle attributed");
        let mut cp = crate::obs::ConfigProfile::new();
        cp.absorb(&prof, &r.program().regions);
        assert_eq!(cp.total_cycles, s.total());
        assert!(cp.regions.contains_key("dot_loop"), "{:?}", cp.regions);
        assert!(cp.regions.contains_key("cfu"), "{:?}", cp.regions);
        assert!(!cp.regions.contains_key("other"), "all accel blocks are mapped: {:?}", cp.regions);
    }

    #[test]
    fn repeated_runs_are_deterministic() {
        let m = tiny_model();
        let mut r =
            ProgramRunner::accelerated(&m, TimingConfig::flexic(), ProgramOpts::default())
                .unwrap();
        let (p1, s1) = r.run_sample(&[9, 2]).unwrap();
        let (p2, s2) = r.run_sample(&[9, 2]).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(s1, s2, "cycle counts must be reproducible");
    }
}
